"""Shared neural-net building blocks (pure-functional, pytree params).

Conventions:
  - params are nested dicts of jnp arrays;
  - compute dtype follows the input activation dtype (bf16 on TPU), while
    normalization statistics and softmax run in float32;
  - initializers take an explicit PRNG key (no global state).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------
def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32, scale: Optional[float] = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out)) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype=jnp.float32):
    return (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def rmsnorm(x, scale, eps: float = 1e-5):
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(dtype)


def layernorm(x, scale, bias, eps: float = 1e-5):
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float):
    """Inverse frequencies, shape (head_dim//2,)."""
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, D) or (..., S, D); positions: (..., S) int32."""
    if theta <= 0:
        return x
    d = x.shape[-1]
    inv = rope_freqs(d, theta)                                 # (d/2,)
    ang = positions[..., None].astype(jnp.float32) * inv       # (..., S, d/2)
    if x.ndim == ang.ndim + 1:                                 # head axis present
        ang = ang[..., None, :]                                # (..., S, 1, d/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x32 = x.astype(jnp.float32)
    x1, x2 = x32[..., ::2], x32[..., 1::2]
    out = jnp.stack([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.reshape(x.shape).astype(x.dtype)


def sinusoidal_positions(length: int, d: int, dtype=jnp.float32):
    """Classic transformer sinusoidal table (length, d) — whisper encoder."""
    pos = jnp.arange(length, dtype=jnp.float32)[:, None]
    div = jnp.exp(jnp.arange(0, d, 2, dtype=jnp.float32) * (-math.log(10000.0) / d))
    tab = jnp.zeros((length, d), dtype=jnp.float32)
    tab = tab.at[:, 0::2].set(jnp.sin(pos * div))
    tab = tab.at[:, 1::2].set(jnp.cos(pos * div))
    return tab.astype(dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------
def swiglu_init(key, d: int, d_ff: int, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, d, d_ff, dtype),
        "w_up": dense_init(k2, d, d_ff, dtype),
        "w_down": dense_init(k3, d_ff, d, dtype),
    }


def swiglu(x, p):
    g = jax.nn.silu(x @ p["w_gate"])
    return (g * (x @ p["w_up"])) @ p["w_down"]


def gelu_mlp_init(key, d: int, d_ff: int, dtype=jnp.float32):
    k1, k2 = jax.random.split(key)
    return {"w_in": dense_init(k1, d, d_ff, dtype),
            "b_in": jnp.zeros((d_ff,), dtype),
            "w_out": dense_init(k2, d_ff, d, dtype),
            "b_out": jnp.zeros((d,), dtype)}


def gelu_mlp(x, p):
    h = jax.nn.gelu(x @ p["w_in"] + p["b_in"])
    return h @ p["w_out"] + p["b_out"]


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------
def softmax_xent(logits, labels, mask=None):
    """Mean cross-entropy; logits (..., V) fp-any, labels (...) int32.

    mask (...) in {0,1} optionally excludes positions (padding)."""
    logits32 = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits32, axis=-1)
    gold = jnp.take_along_axis(logits32, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        mask = mask.astype(jnp.float32)
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def accuracy(logits, labels, mask=None):
    pred = jnp.argmax(logits, axis=-1)
    hit = (pred == labels).astype(jnp.float32)
    if mask is not None:
        mask = mask.astype(jnp.float32)
        return jnp.sum(hit * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(hit)

"""Mamba2 (SSD — state-space duality) block, pure-JAX reference path.

Implements the chunked SSD algorithm of arXiv:2405.21060: within a chunk the
recurrence is computed as a masked quadratic form (MXU-friendly); across
chunks a sequential ``lax.scan`` carries the (H, N, P) state.  The Pallas
kernel in ``repro.kernels.ssd_scan`` implements the same chunk body with
explicit VMEM tiling; this module is its oracle.

Block layout (mamba2):
  in_proj -> [z | x | B | C | dt]; causal depthwise conv over [x|B|C];
  dt = softplus(dt + bias); a = dt * A (A = -exp(A_log) per head);
  y = SSD(x, a, dt, B, C) + D * x;  out = out_proj(y * silu(z)).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import SSMConfig
from repro.models.layers import dense_init


# ---------------------------------------------------------------------------
# Chunked SSD scan (the compute core)
# ---------------------------------------------------------------------------
def ssd_chunked(x, dt, A, Bm, Cm, chunk: int, h0=None):
    """x: (B, S, H, P); dt: (B, S, H) (already softplus'ed); A: (H,) negative;
    Bm/Cm: (B, S, H, N) (groups already broadcast to heads).
    Returns (y: (B, S, H, P), h_final: (B, H, N, P))."""
    B_, S, H, P = x.shape
    N = Bm.shape[-1]
    L = min(chunk, S)
    pad = (-S) % L
    if pad:
        zf = lambda t: jnp.pad(t, [(0, 0), (0, pad)] + [(0, 0)] * (t.ndim - 2))
        x, dt, Bm, Cm = zf(x), zf(dt), zf(Bm), zf(Cm)
    nc = x.shape[1] // L

    a = dt * A[None, None, :]                                  # (B,S,H) <= 0
    rs = lambda t: t.reshape((B_, nc, L) + t.shape[2:]).transpose(
        (1, 0, 2) + tuple(range(3, t.ndim + 1)))
    xc, dtc, ac, Bc, Cc = rs(x), rs(dt), rs(a), rs(Bm), rs(Cm)  # (nc,B,L,...)

    if h0 is None:
        h0 = jnp.zeros((B_, H, N, P), jnp.float32)

    def chunk_step(h_prev, inp):
        xk, dtk, ak, Bk, Ck = inp                              # (B,L,...)
        ak = ak.astype(jnp.float32)
        acum = jnp.cumsum(ak, axis=1)                          # (B,L,H) inclusive
        # ---- intra-chunk (quadratic) ----
        seg = acum[:, :, None, :] - acum[:, None, :, :]        # (B,t,s,H)
        tri = (jnp.arange(L)[:, None] >= jnp.arange(L)[None, :])
        decay = jnp.exp(jnp.where(tri[None, :, :, None], seg, -jnp.inf))
        scores = jnp.einsum("blhn,bmhn->blmh", Ck, Bk,
                            preferred_element_type=jnp.float32)
        M = scores * decay                                     # (B,t,s,H)
        xdt = xk.astype(jnp.float32) * dtk[..., None]
        y_intra = jnp.einsum("blmh,bmhp->blhp", M, xdt)
        # ---- contribution of the incoming state ----
        y_inter = jnp.einsum("blhn,bhnp->blhp",
                             Ck.astype(jnp.float32) * jnp.exp(acum)[..., None],
                             h_prev)
        # ---- state update ----
        decay_to_end = jnp.exp(acum[:, -1:, :] - acum)         # (B,L,H)
        h_new = (jnp.exp(acum[:, -1])[:, :, None, None] * h_prev +
                 jnp.einsum("blhn,blhp->bhnp",
                            Bk.astype(jnp.float32) * decay_to_end[..., None],
                            xdt))
        return h_new, (y_intra + y_inter)

    h_final, ys = lax.scan(chunk_step, h0, (xc, dtc, ac, Bc, Cc))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B_, nc * L, H, P)
    return y[:, :S].astype(x.dtype), h_final


def ssd_decode_step(x, dt, A, Bm, Cm, h):
    """Single-token SSD update.  x: (B,H,P); dt: (B,H); Bm/Cm: (B,H,N);
    h: (B,H,N,P).  Returns (y: (B,H,P), h_new)."""
    a = jnp.exp((dt * A[None, :]).astype(jnp.float32))         # (B,H)
    xdt = x.astype(jnp.float32) * dt[..., None]
    h_new = (a[..., None, None] * h +
             jnp.einsum("bhn,bhp->bhnp", Bm.astype(jnp.float32), xdt))
    y = jnp.einsum("bhn,bhnp->bhp", Cm.astype(jnp.float32), h_new)
    return y.astype(x.dtype), h_new


# ---------------------------------------------------------------------------
# Depthwise causal conv (width d_conv) over the channel-last layout
# ---------------------------------------------------------------------------
def causal_conv(x, w, cache: Optional[jax.Array] = None):
    """x: (B, S, C); w: (d_conv, C).  cache: (B, d_conv-1, C) past inputs.
    Returns (y: (B,S,C), new_cache)."""
    dconv = w.shape[0]
    if cache is None:
        cache = jnp.zeros((x.shape[0], dconv - 1, x.shape[-1]), x.dtype)
    ext = jnp.concatenate([cache, x], axis=1)                  # (B, S+dc-1, C)
    y = sum(ext[:, i:i + x.shape[1]] * w[i][None, None] for i in range(dconv))
    new_cache = ext[:, -(dconv - 1):] if dconv > 1 else cache
    return y, new_cache


def causal_conv_step(x, w, cache):
    """One token: x (B, C); cache (B, d_conv-1, C)."""
    ext = jnp.concatenate([cache, x[:, None]], axis=1)         # (B, dc, C)
    y = jnp.einsum("bkc,kc->bc", ext, w)
    return y, ext[:, 1:]


# ---------------------------------------------------------------------------
# Full mamba2 mixer block
# ---------------------------------------------------------------------------
def mamba_init(key, d_model: int, cfg: SSMConfig, dtype=jnp.float32):
    d_in = cfg.expand * d_model
    H = d_in // cfg.d_head
    G, N = cfg.n_groups, cfg.d_state
    conv_dim = d_in + 2 * G * N
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "in_proj": dense_init(k1, d_model, 2 * d_in + 2 * G * N + H, dtype),
        "conv_w": (jax.random.normal(k2, (cfg.d_conv, conv_dim))
                   / math.sqrt(cfg.d_conv)).astype(dtype),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "out_proj": dense_init(k3, d_in, d_model, dtype),
    }


def _split_proj(zxbcdt, d_in: int, G: int, N: int, H: int):
    z = zxbcdt[..., :d_in]
    x = zxbcdt[..., d_in:2 * d_in]
    Bm = zxbcdt[..., 2 * d_in:2 * d_in + G * N]
    Cm = zxbcdt[..., 2 * d_in + G * N:2 * d_in + 2 * G * N]
    dt = zxbcdt[..., 2 * d_in + 2 * G * N:]
    return z, x, Bm, Cm, dt


def mamba_block(u, p, cfg: SSMConfig):
    """u: (B, S, d_model) -> (B, S, d_model). Train / prefill (full seq)."""
    B_, S, d_model = u.shape
    d_in = cfg.expand * d_model
    H = d_in // cfg.d_head
    G, N, P = cfg.n_groups, cfg.d_state, cfg.d_head

    zxbcdt = u @ p["in_proj"]
    z, xr, Bm, Cm, dt = _split_proj(zxbcdt, d_in, G, N, H)
    xbc, _ = causal_conv(jnp.concatenate([xr, Bm, Cm], axis=-1), p["conv_w"])
    xbc = jax.nn.silu(xbc)
    xr, Bm, Cm = (xbc[..., :d_in], xbc[..., d_in:d_in + G * N],
                  xbc[..., d_in + G * N:])
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])

    x_h = xr.reshape(B_, S, H, P)
    rep = H // G
    B_h = jnp.repeat(Bm.reshape(B_, S, G, N), rep, axis=2)
    C_h = jnp.repeat(Cm.reshape(B_, S, G, N), rep, axis=2)
    y, _ = ssd_chunked(x_h, dt, A, B_h, C_h, cfg.chunk)
    y = y + x_h * p["D"][None, None, :, None].astype(y.dtype)
    y = y.reshape(B_, S, d_in) * jax.nn.silu(z)
    return y @ p["out_proj"]


def mamba_make_cache(batch: int, d_model: int, cfg: SSMConfig, dtype):
    d_in = cfg.expand * d_model
    H = d_in // cfg.d_head
    conv_dim = d_in + 2 * cfg.n_groups * cfg.d_state
    return {
        "ssm": jnp.zeros((batch, H, cfg.d_state, cfg.d_head), jnp.float32),
        "conv": jnp.zeros((batch, cfg.d_conv - 1, conv_dim), dtype),
    }


def mamba_block_decode(u, p, cfg: SSMConfig, cache):
    """u: (B, d_model) one token; cache: {'ssm', 'conv'}."""
    B_, d_model = u.shape
    d_in = cfg.expand * d_model
    H = d_in // cfg.d_head
    G, N, P = cfg.n_groups, cfg.d_state, cfg.d_head

    zxbcdt = u @ p["in_proj"]
    z, xr, Bm, Cm, dt = _split_proj(zxbcdt, d_in, G, N, H)
    xbc, conv_cache = causal_conv_step(
        jnp.concatenate([xr, Bm, Cm], axis=-1), p["conv_w"], cache["conv"])
    xbc = jax.nn.silu(xbc)
    xr, Bm, Cm = (xbc[..., :d_in], xbc[..., d_in:d_in + G * N],
                  xbc[..., d_in + G * N:])
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])

    rep = H // G
    x_h = xr.reshape(B_, H, P)
    B_h = jnp.repeat(Bm.reshape(B_, G, N), rep, axis=1)
    C_h = jnp.repeat(Cm.reshape(B_, G, N), rep, axis=1)
    y, ssm = ssd_decode_step(x_h, dt, A, B_h, C_h, cache["ssm"])
    y = y + x_h * p["D"][None, :, None].astype(y.dtype)
    y = y.reshape(B_, d_in) * jax.nn.silu(z)
    return y @ p["out_proj"], {"ssm": ssm, "conv": conv_cache}

"""The paper's own experimental models (§4.1.2, §4.2.2, §4.3.2).

- FedAvg CNN for split CIFAR-10 / FEMNIST: conv5x5 -> relu -> maxpool, twice,
  then fully-connected layers with ReLU + dropout and a softmax output.
- Character-level GRU for Shakespeare: embed(256) -> GRU(1024) -> softmax.

Pure-functional (params pytrees), CPU-trainable — used by the paper-claim
validation benchmarks.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.paper_models import CNNConfig, GRUConfig
from repro.models.layers import dense_init, softmax_xent


# ---------------------------------------------------------------------------
# CNN
# ---------------------------------------------------------------------------
def cnn_init(cfg: CNNConfig, key):
    keys = jax.random.split(key, 2 + len(cfg.fc) + 1)
    c1, c2 = cfg.conv_channels
    k = cfg.conv_kernel
    params = {
        "conv1_w": jax.random.normal(keys[0], (k, k, cfg.in_channels, c1))
        * math.sqrt(2.0 / (k * k * cfg.in_channels)),
        "conv1_b": jnp.zeros((c1,)),
        "conv2_w": jax.random.normal(keys[1], (k, k, c1, c2))
        * math.sqrt(2.0 / (k * k * c1)),
        "conv2_b": jnp.zeros((c2,)),
    }
    # infer flattened dim
    s = cfg.image_size
    for _ in range(2):
        s = _pooled_size(s, cfg.pool, cfg.pool_stride)
    d = s * s * c2
    dims = (d,) + cfg.fc + (cfg.num_classes,)
    for i in range(len(dims) - 1):
        # He-style hidden init; small final layer (init loss ~ ln(classes),
        # soft initial curvature — keeps UGA's HVP sweep well-conditioned)
        scale = math.sqrt(2.0 / dims[i])
        if i == len(dims) - 2:
            scale *= 0.1
        params[f"fc{i}_w"] = dense_init(keys[2 + i], dims[i], dims[i + 1],
                                        scale=scale)
        params[f"fc{i}_b"] = jnp.zeros((dims[i + 1],))
    return params


def _pooled_size(s: int, pool: int, stride: int) -> int:
    return (s - pool) // stride + 1


def _maxpool(x, pool: int, stride: int):
    return lax.reduce_window(x, -jnp.inf, lax.max,
                             (1, pool, pool, 1), (1, stride, stride, 1),
                             "VALID")


def cnn_apply(params, cfg: CNNConfig, images, *, rng: Optional[jax.Array] = None):
    """images: (B, H, W, C) float32 -> logits (B, num_classes)."""
    x = images
    for i, (w, b) in enumerate(((params["conv1_w"], params["conv1_b"]),
                                (params["conv2_w"], params["conv2_b"]))):
        x = lax.conv_general_dilated(
            x, w, window_strides=(1, 1), padding="SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC")) + b
        x = jax.nn.relu(x)
        x = _maxpool(x, cfg.pool, cfg.pool_stride)
    x = x.reshape(x.shape[0], -1)
    n_fc = len(cfg.fc) + 1
    for i in range(n_fc):
        x = x @ params[f"fc{i}_w"] + params[f"fc{i}_b"]
        if i < n_fc - 1:
            x = jax.nn.relu(x)
            if rng is not None and cfg.dropout > 0:
                rng, sub = jax.random.split(rng)
                keep = jax.random.bernoulli(sub, 1 - cfg.dropout, x.shape)
                x = jnp.where(keep, x / (1 - cfg.dropout), 0.0)
    return x


def cnn_loss(params, cfg: CNNConfig, batch, rng=None):
    logits = cnn_apply(params, cfg, batch["x"], rng=rng)
    return softmax_xent(logits, batch["y"])


# ---------------------------------------------------------------------------
# GRU char-LM
# ---------------------------------------------------------------------------
def gru_init(cfg: GRUConfig, key):
    ke, kz, kr, kh, ko = jax.random.split(key, 5)
    e, h = cfg.embed_dim, cfg.hidden

    def gate(k):
        k1, k2 = jax.random.split(k)
        return {"wx": dense_init(k1, e, h), "wh": dense_init(k2, h, h),
                "b": jnp.zeros((h,))}

    return {
        "embed": jax.random.normal(ke, (cfg.vocab_size, e)) * 0.02,
        "z": gate(kz), "r": gate(kr), "h": gate(kh),
        "out_w": dense_init(ko, h, cfg.vocab_size),
        "out_b": jnp.zeros((cfg.vocab_size,)),
    }


def _gru_cell(params, x, h):
    z = jax.nn.sigmoid(x @ params["z"]["wx"] + h @ params["z"]["wh"] + params["z"]["b"])
    r = jax.nn.sigmoid(x @ params["r"]["wx"] + h @ params["r"]["wh"] + params["r"]["b"])
    hh = jnp.tanh(x @ params["h"]["wx"] + (r * h) @ params["h"]["wh"] + params["h"]["b"])
    return (1 - z) * h + z * hh


def gru_apply(params, cfg: GRUConfig, tokens):
    """tokens: (B, S) int32 -> logits (B, S, V)."""
    B, S = tokens.shape
    x = params["embed"][tokens]                      # (B,S,e)

    def step(h, xt):
        h = _gru_cell(params, xt, h)
        return h, h

    h0 = jnp.zeros((B, cfg.hidden))
    _, hs = lax.scan(step, h0, x.transpose(1, 0, 2))
    hs = hs.transpose(1, 0, 2)                       # (B,S,hidden)
    return hs @ params["out_w"] + params["out_b"]


def gru_loss(params, cfg: GRUConfig, batch, rng=None):
    """Next-char prediction: batch {'tokens': (B,S)} — shift internally."""
    tokens = batch["tokens"]
    logits = gru_apply(params, cfg, tokens[:, :-1])
    return softmax_xent(logits, tokens[:, 1:])

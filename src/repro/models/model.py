"""Unified Model API — the object the federated runtime and the launchers
consume.  A :class:`Model` bundles init / loss / prefill / decode for one
architecture so that the FL algorithms (repro.core) stay model-agnostic,
exactly as the paper requires.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax

from repro.configs.base import ArchConfig
from repro.models import transformer
from repro.models.layers import accuracy, softmax_xent

PyTree = Any
Batch = Dict[str, jax.Array]


@dataclasses.dataclass(frozen=True)
class Model:
    """init(key) -> params; loss(params, batch, rng) -> (loss, metrics);
    prefill(params, batch) -> (logits, cache);
    decode(params, tokens, cache) -> (logits, cache)."""
    name: str
    init: Callable[..., PyTree]
    loss: Callable[..., Any]
    prefill: Optional[Callable[..., Any]] = None
    decode: Optional[Callable[..., Any]] = None
    make_cache: Optional[Callable[..., PyTree]] = None
    cfg: Any = None


def build_model(cfg: ArchConfig, *, dtype=None, remat: bool = True,
                decode_window: int = 0, loss_chunk: int = 2048) -> Model:
    """Build the transformer-family model for an assigned architecture.

    decode_window > 0 selects the sliding-window decode variant (ring-buffer
    cache of that size) — used by the ``long_500k`` shape for dense archs.
    """

    def init(key):
        return transformer.init_transformer(cfg, key, dtype)

    def loss(params, batch: Batch, rng=None):
        return transformer.lm_loss_chunked(
            params, batch["tokens"], cfg,
            enc_embeds=batch.get("enc_embeds"), mask=batch.get("mask"),
            remat=remat, chunk=loss_chunk)

    def prefill(params, batch: Batch, cache_len: Optional[int] = None):
        # return_hidden: only the LAST position goes through the vocab
        # projection — the full (B, S, V) logits would dominate prefill HBM
        # at 32k x 100-200k vocab (§Perf it.8)
        h, aux, cache = transformer.forward(
            params, batch["tokens"], cfg,
            enc_embeds=batch.get("enc_embeds"), collect_cache=True,
            remat=remat, return_hidden=True)
        head = (params["embed"].T if cfg.tie_embeddings
                else params["head"])
        logits_last = h[:, -1] @ head
        if cache_len is not None:
            cache = transformer.pad_cache(cache, cfg, cache_len)
        return logits_last, cache

    def decode(params, tokens, cache):
        return transformer.decode_step(params, tokens, cache, cfg,
                                       window=decode_window)

    def make_cache(batch: int, cache_len: int):
        return transformer.make_cache(cfg, batch, cache_len, dtype,
                                      window=decode_window)

    return Model(name=cfg.name, init=init, loss=loss, prefill=prefill,
                 decode=decode, make_cache=make_cache, cfg=cfg)


def build_paper_cnn(cfg, *_, **__) -> Model:
    from repro.configs.paper_models import CNNConfig
    from repro.models import smallnets
    assert isinstance(cfg, CNNConfig)

    def loss(params, batch, rng=None):
        logits = smallnets.cnn_apply(params, cfg, batch["x"], rng=rng)
        l = softmax_xent(logits, batch["y"])
        return l, {"xent": l, "acc": accuracy(logits, batch["y"])}

    return Model(name=cfg.name, init=lambda k: smallnets.cnn_init(cfg, k),
                 loss=loss, cfg=cfg)


def build_paper_gru(cfg, *_, **__) -> Model:
    from repro.configs.paper_models import GRUConfig
    from repro.models import smallnets
    assert isinstance(cfg, GRUConfig)

    def loss(params, batch, rng=None):
        tokens = batch["tokens"]
        logits = smallnets.gru_apply(params, cfg, tokens[:, :-1])
        l = softmax_xent(logits, tokens[:, 1:])
        return l, {"xent": l, "acc": accuracy(logits, tokens[:, 1:])}

    return Model(name=cfg.name, init=lambda k: smallnets.gru_init(cfg, k),
                 loss=loss, cfg=cfg)

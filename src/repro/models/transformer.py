"""Unified transformer stack covering all assigned architecture families.

The stack is organized in *periods*: the layer-kind pattern of an
architecture (e.g. jamba's ``mmmmAmmm`` with MoE every other layer) repeats
with period ``P = lcm(attn_period, cross_every, moe.every)``; parameters for
each position-in-period are stacked over ``num_layers // P`` and the whole
network is a single ``lax.scan`` over periods (bounded HLO size for 100-layer
models, per-period ``jax.checkpoint`` for activation memory).

Families:
  dense   — GQA attention + SwiGLU           (phi3, minicpm, smollm)
  moe     — + capacity-based MoE FFN         (llama4-scout) / MLA (deepseek)
  ssm     — mamba2 SSD blocks, no MLP        (mamba2-780m)
  hybrid  — 1:7 attn:mamba interleave + MoE  (jamba)
  vlm     — cross-attn image layers, stub projector  (llama-3.2-vision)
  audio   — whisper enc-dec, stub frame embeddings   (whisper-large-v3)
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ATTN, ArchConfig, CROSS, MAMBA
from repro.models import attention as attn_lib
from repro.models import ssm as ssm_lib
from repro.models.layers import (dense_init, embed_init, gelu_mlp,
                                 gelu_mlp_init, layernorm, rmsnorm,
                                 sinusoidal_positions, swiglu, swiglu_init)
from repro.models.moe import moe_ffn, moe_init

PyTree = Any

# Optional sharding hint for residual-stream activations (B, S, d), set by
# the launcher (repro.models.moe.EXPERT_AXIS-style module hint): GSPMD loses
# the batch-dim sharding through vmap+scan+custom_vjp boundaries and
# replicates per-client compute across the model axis (§Perf it.5) — the
# constraint pins it.  None = let GSPMD choose (smoke tests, no mesh).
ACT_SPEC = None


def set_activation_spec(spec):
    global ACT_SPEC
    ACT_SPEC = spec


def _constrain_act(h):
    if ACT_SPEC is None:
        return h
    try:
        return jax.lax.with_sharding_constraint(h, ACT_SPEC)
    except Exception:   # no ambient mesh — hint is best-effort
        return h


def _lcm(*xs):
    out = 1
    for x in xs:
        x = max(int(x), 1)
        out = out * x // math.gcd(out, x)
    return out


def period_of(cfg: ArchConfig) -> int:
    p = _lcm(cfg.attn_period, cfg.cross_every or 1,
             cfg.moe.every if cfg.moe else 1)
    assert cfg.num_layers % p == 0, (cfg.name, cfg.num_layers, p)
    return p


def _dtype(cfg: ArchConfig, override=None):
    return override or jnp.dtype(cfg.dtype)


def _has_moe(cfg: ArchConfig, j: int) -> bool:
    return cfg.moe is not None and (j % cfg.moe.every == cfg.moe.every - 1)


def _has_mlp(cfg: ArchConfig, j: int) -> bool:
    return _has_moe(cfg, j) or cfg.d_ff > 0


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------
def _init_layer(key, cfg: ArchConfig, kind: str, j: int, dtype):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {"norm1": jnp.ones((d,), jnp.float32)}
    if kind == MAMBA:
        p["mamba"] = ssm_lib.mamba_init(ks[0], d, cfg.ssm, dtype)
    elif cfg.mla is not None and kind == ATTN:
        p["attn"] = attn_lib.mla_init(ks[0], d, cfg.num_heads, hd,
                                      cfg.mla.kv_lora_rank,
                                      cfg.mla.rope_head_dim, dtype)
    else:  # ATTN or CROSS with plain GQA
        p["attn"] = attn_lib.gqa_init(ks[0], d, cfg.num_heads,
                                      cfg.num_kv_heads, hd, dtype)
    if _has_mlp(cfg, j):
        p["norm2"] = jnp.ones((d,), jnp.float32)
        if _has_moe(cfg, j):
            p["mlp"] = moe_init(ks[1], d, cfg.moe, cfg.d_ff, dtype)
        else:
            p["mlp"] = swiglu_init(ks[1], d, cfg.d_ff, dtype)
    return p


def _init_encoder(key, cfg: ArchConfig, dtype):
    enc = cfg.encoder
    p: Dict[str, Any] = {}
    if enc.enc_dim != cfg.d_model:
        p["proj"] = dense_init(key, enc.enc_dim, cfg.d_model, dtype)
    if enc.enc_layers > 0:
        eff = enc.enc_ff or 4 * enc.enc_dim
        hd = enc.enc_dim // enc.enc_heads

        def one(k):
            k1, k2 = jax.random.split(k)
            return {
                "ln1_s": jnp.ones((enc.enc_dim,), jnp.float32),
                "ln1_b": jnp.zeros((enc.enc_dim,), jnp.float32),
                "attn": attn_lib.gqa_init(k1, enc.enc_dim, enc.enc_heads,
                                          enc.enc_heads, hd, dtype),
                "ln2_s": jnp.ones((enc.enc_dim,), jnp.float32),
                "ln2_b": jnp.zeros((enc.enc_dim,), jnp.float32),
                "mlp": gelu_mlp_init(k2, enc.enc_dim, eff, dtype),
            }

        p["layers"] = jax.vmap(one)(jax.random.split(key, enc.enc_layers))
        p["ln_f_s"] = jnp.ones((enc.enc_dim,), jnp.float32)
        p["ln_f_b"] = jnp.zeros((enc.enc_dim,), jnp.float32)
    return p


def init_transformer(cfg: ArchConfig, key, dtype=None) -> PyTree:
    dtype = _dtype(cfg, dtype)
    prd = period_of(cfg)
    n_periods = cfg.num_layers // prd
    kinds = cfg.layer_kinds()[:prd]
    k_embed, k_head, k_enc, *k_blocks = jax.random.split(key, 3 + prd)
    params: Dict[str, Any] = {
        "embed": embed_init(k_embed, cfg.vocab_size, cfg.d_model, dtype),
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["head"] = dense_init(k_head, cfg.d_model, cfg.vocab_size, dtype)
    blocks = []
    for j, kind in enumerate(kinds):
        init_j = partial(_init_layer, cfg=cfg, kind=kind, j=j, dtype=dtype)
        blocks.append(jax.vmap(lambda k: init_j(k))(
            jax.random.split(k_blocks[j], n_periods)))
    params["blocks"] = tuple(blocks)
    if cfg.encoder is not None:
        params["encoder"] = _init_encoder(k_enc, cfg, dtype)
    return params


# ---------------------------------------------------------------------------
# Encoder (stub frontend -> optional transformer encoder)
# ---------------------------------------------------------------------------
def encode(params: PyTree, enc_embeds, cfg: ArchConfig):
    """enc_embeds: (B, L, enc_dim) precomputed frame/patch embeddings
    (the modality frontend stub).  Returns (B, L, d_model)."""
    enc = cfg.encoder
    p = params.get("encoder", {})
    h = enc_embeds
    if enc.enc_layers > 0:
        h = h + sinusoidal_positions(h.shape[1], enc.enc_dim, h.dtype)[None]

        def enc_layer(h, lp):
            a_in = layernorm(h, lp["ln1_s"], lp["ln1_b"], cfg.norm_eps)
            q, k, v = attn_lib.gqa_project_qkv(
                a_in, lp["attn"], enc.enc_heads, enc.enc_heads,
                enc.enc_dim // enc.enc_heads)
            a = attn_lib.attend(q, k, v, causal=False)
            h = h + a.reshape(h.shape[0], h.shape[1], -1) @ lp["attn"]["wo"]
            m_in = layernorm(h, lp["ln2_s"], lp["ln2_b"], cfg.norm_eps)
            return h + gelu_mlp(m_in, lp["mlp"]), None

        h, _ = lax.scan(jax.checkpoint(enc_layer), h, p["layers"])
        h = layernorm(h, p["ln_f_s"], p["ln_f_b"], cfg.norm_eps)
    if "proj" in p:
        h = h @ p["proj"]
    return h


# ---------------------------------------------------------------------------
# Full-sequence forward (train / prefill)
# ---------------------------------------------------------------------------
def _apply_layer(h, lp, kind: str, j: int, cfg: ArchConfig, positions,
                 enc_out, collect_cache: bool):
    """One sub-layer of a period.  Returns (h, aux, cache_entry)."""
    B, S, d = h.shape
    hd = cfg.resolved_head_dim
    aux = jnp.zeros((), jnp.float32)
    cache_entry = None
    x = rmsnorm(h, lp["norm1"], cfg.norm_eps)
    if kind == MAMBA:
        if collect_cache:
            # prefill: need the final SSD + conv state — rerun pieces inline
            y, cache_entry = _mamba_prefill(x, lp["mamba"], cfg)
        else:
            y = ssm_lib.mamba_block(x, lp["mamba"], cfg.ssm)
    elif cfg.mla is not None and kind == ATTN:
        y = attn_lib.mla_attention(
            x, lp["attn"], positions, num_heads=cfg.num_heads, head_dim=hd,
            rope_head_dim=cfg.mla.rope_head_dim, rope_theta=cfg.rope_theta)
        if collect_cache:
            ckv = x @ lp["attn"]["w_dkv"]
            krope = attn_lib.apply_rope_1h(x @ lp["attn"]["w_kr"], positions,
                                           cfg.rope_theta)
            cache_entry = {"ckv": ckv, "krope": krope}
    elif kind == CROSS:
        q = (x @ lp["attn"]["wq"]).reshape(B, S, cfg.num_heads, hd)
        ek = (enc_out @ lp["attn"]["wk"]).reshape(
            B, enc_out.shape[1], cfg.num_kv_heads, hd)
        ev = (enc_out @ lp["attn"]["wv"]).reshape(
            B, enc_out.shape[1], cfg.num_kv_heads, hd)
        a = attn_lib.attend(q, ek, ev, causal=False)
        y = a.reshape(B, S, -1) @ lp["attn"]["wo"]
        if collect_cache:
            cache_entry = {"k": ek, "v": ev}
    else:  # plain GQA self-attention
        from repro.models.layers import apply_rope
        q, k, v = attn_lib.gqa_project_qkv(x, lp["attn"], cfg.num_heads,
                                           cfg.num_kv_heads, hd)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        a = attn_lib.attend(q, k, v, causal=True)
        y = a.reshape(B, S, -1) @ lp["attn"]["wo"]
        if collect_cache:
            cache_entry = {"k": k, "v": v}
    h = h + y
    if "mlp" in lp:
        x2 = rmsnorm(h, lp["norm2"], cfg.norm_eps)
        if _has_moe(cfg, j):
            y2, a = moe_ffn(x2, lp["mlp"], cfg.moe)
            aux = aux + a
        else:
            y2 = swiglu(x2, lp["mlp"])
        h = h + y2
    return h, aux, cache_entry


def _mamba_prefill(x, p, cfg: ArchConfig):
    """mamba_block that also returns the end-of-sequence decode cache."""
    s = cfg.ssm
    B_, S, d_model = x.shape
    d_in = s.expand * d_model
    H = d_in // s.d_head
    G, N, P = s.n_groups, s.d_state, s.d_head
    zxbcdt = x @ p["in_proj"]
    z, xr, Bm, Cm, dt = ssm_lib._split_proj(zxbcdt, d_in, G, N, H)
    xbc_in = jnp.concatenate([xr, Bm, Cm], axis=-1)
    xbc, _ = ssm_lib.causal_conv(xbc_in, p["conv_w"])
    conv_cache = xbc_in[:, -(s.d_conv - 1):] if s.d_conv > 1 else \
        jnp.zeros((B_, 0, xbc_in.shape[-1]), xbc_in.dtype)
    xbc = jax.nn.silu(xbc)
    xr, Bm, Cm = (xbc[..., :d_in], xbc[..., d_in:d_in + G * N],
                  xbc[..., d_in + G * N:])
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    rep = H // G
    x_h = xr.reshape(B_, S, H, P)
    B_h = jnp.repeat(Bm.reshape(B_, S, G, N), rep, axis=2)
    C_h = jnp.repeat(Cm.reshape(B_, S, G, N), rep, axis=2)
    y, h_final = ssm_lib.ssd_chunked(x_h, dt, A, B_h, C_h, s.chunk)
    y = y + x_h * p["D"][None, None, :, None].astype(y.dtype)
    y = y.reshape(B_, S, d_in) * jax.nn.silu(z)
    return y @ p["out_proj"], {"ssm": h_final, "conv": conv_cache}


def forward(params: PyTree, tokens, cfg: ArchConfig, *,
            enc_embeds=None, collect_cache: bool = False,
            remat: bool = True, return_hidden: bool = False):
    """tokens: (B, S) int32 -> logits (B, S, V).

    Returns (logits, aux_loss) or (logits, aux_loss, cache) when
    ``collect_cache`` (prefill).  With ``return_hidden`` the first element
    is the pre-head hidden state (B, S, d) instead of logits — used by the
    chunked LM loss to avoid materializing the full (B, S, V) logits."""
    B, S = tokens.shape
    prd = period_of(cfg)
    kinds = cfg.layer_kinds()[:prd]
    h = params["embed"][tokens]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    if cfg.rope_theta <= 0:  # whisper-style: absolute sinusoidal positions
        h = h + sinusoidal_positions(S, cfg.d_model, h.dtype)[None]
    enc_out = None
    if cfg.encoder is not None:
        assert enc_embeds is not None, "encoder arch needs enc_embeds input"
        enc_out = encode(params, enc_embeds, cfg)

    def period_fn(carry, block_params):
        h, aux = carry
        h = _constrain_act(h)
        caches = []
        for j, kind in enumerate(kinds):
            h, a, ce = _apply_layer(h, block_params[j], kind, j, cfg,
                                    positions, enc_out, collect_cache)
            aux = aux + a
            caches.append(ce)
        return (_constrain_act(h), aux), tuple(caches) if collect_cache else None

    fn = jax.checkpoint(period_fn, prevent_cse=False) if remat else period_fn
    (h, aux), caches = lax.scan(fn, (h, jnp.zeros((), jnp.float32)),
                                params["blocks"])
    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    if return_hidden:
        out = h
    else:
        out = h @ (params["embed"].T if cfg.tie_embeddings
                   else params["head"])
    if collect_cache:
        cache = {"layers": caches, "index": jnp.array(S, jnp.int32)}
        if enc_out is not None:
            cache["enc_out"] = enc_out
        return out, aux, cache
    return out, aux


def lm_loss_chunked(params: PyTree, tokens, cfg: ArchConfig, *,
                    enc_embeds=None, mask=None, remat: bool = True,
                    chunk: int = 2048):
    """Next-token loss with the vocab projection + xent computed in sequence
    chunks (lax.scan) so the (B, S, V) logits never fully materialize —
    required for the 100k-200k-vocab architectures at 4k-32k sequ: the full
    fp32 logits would dominate HBM.  Returns (loss, metrics)."""
    from repro.models.layers import softmax_xent, accuracy  # local import
    inputs, labels = tokens[:, :-1], tokens[:, 1:]
    if mask is not None:
        mask = mask[:, 1:]
    h, aux = forward(params, inputs, cfg, enc_embeds=enc_embeds,
                     remat=remat, return_hidden=True)
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    B, S, d = h.shape
    C = min(chunk, S)
    pad = (-S) % C
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad))) if mask is not None else \
            jnp.pad(jnp.ones((B, S), jnp.float32), ((0, 0), (0, pad)))
    elif mask is None:
        mask = jnp.ones((B, S), jnp.float32)
    nchunks = h.shape[1] // C
    hs = h.reshape(B, nchunks, C, d).transpose(1, 0, 2, 3)
    ls = labels.reshape(B, nchunks, C).transpose(1, 0, 2)
    ms = mask.reshape(B, nchunks, C).transpose(1, 0, 2)

    def chunk_fn(carry, inp):
        nll_sum, hit_sum, cnt = carry
        hc, lc, mc = inp
        logits = hc @ head
        logits32 = logits.astype(jnp.float32)
        logz = jax.nn.logsumexp(logits32, axis=-1)
        gold = jnp.take_along_axis(logits32, lc[..., None], axis=-1)[..., 0]
        m = mc.astype(jnp.float32)
        nll_sum = nll_sum + jnp.sum((logz - gold) * m)
        hit_sum = hit_sum + jnp.sum(
            (jnp.argmax(logits32, axis=-1) == lc).astype(jnp.float32) * m)
        return (nll_sum, hit_sum, cnt + jnp.sum(m)), None

    body = jax.checkpoint(chunk_fn, prevent_cse=False) if remat else chunk_fn
    (nll, hit, cnt), _ = lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32),
               jnp.zeros((), jnp.float32)), (hs, ls, ms))
    cnt = jnp.maximum(cnt, 1.0)
    xent = nll / cnt
    return xent + aux, {"xent": xent, "aux": aux, "acc": hit / cnt}


def pad_cache(cache: PyTree, cfg: ArchConfig, cache_len: int) -> PyTree:
    """Grow a prefill-produced cache's KV sequence axis to ``cache_len`` so
    decode steps can append.  Mamba (constant state) and cross-attn (constant
    encoder length) entries pass through untouched."""
    prd = period_of(cfg)
    kinds = cfg.layer_kinds()[:prd]

    def pad_seq(x):  # (n_periods, B, S, ...) -> (n_periods, B, cache_len, ...)
        S = x.shape[2]
        if S >= cache_len:
            return x
        pad = [(0, 0)] * x.ndim
        pad[2] = (0, cache_len - S)
        return jnp.pad(x, pad)

    layers = []
    for kind, ce in zip(kinds, cache["layers"]):
        if kind == MAMBA or kind == CROSS:
            layers.append(ce)
        else:
            layers.append(jax.tree.map(pad_seq, ce))
    out = dict(cache)
    out["layers"] = tuple(layers)
    return out


# ---------------------------------------------------------------------------
# Decode (one token against per-layer caches)
# ---------------------------------------------------------------------------
def make_cache(cfg: ArchConfig, batch: int, cache_len: int, dtype=None,
               window: int = 0) -> PyTree:
    """Zero-initialized decode cache.  ``cache_len`` is the KV cache length
    for attention layers (== window when a sliding-window variant is used).
    Mamba layers carry constant-size state; cross layers carry the encoder
    KV (constant length enc_len)."""
    dtype = _dtype(cfg, dtype)
    prd = period_of(cfg)
    n_periods = cfg.num_layers // prd
    kinds = cfg.layer_kinds()[:prd]
    hd = cfg.resolved_head_dim
    S = window if window > 0 else cache_len

    def stack(tree):
        return jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (n_periods,) + x.shape), tree)

    layers = []
    for kind in kinds:
        if kind == MAMBA:
            ce = ssm_lib.mamba_make_cache(batch, cfg.d_model, cfg.ssm, dtype)
        elif cfg.mla is not None and kind == ATTN:
            ce = {"ckv": jnp.zeros((batch, S, cfg.mla.kv_lora_rank), dtype),
                  "krope": jnp.zeros((batch, S, cfg.mla.rope_head_dim), dtype)}
        elif kind == CROSS:
            L = cfg.encoder.enc_len
            ce = {"k": jnp.zeros((batch, L, cfg.num_kv_heads, hd), dtype),
                  "v": jnp.zeros((batch, L, cfg.num_kv_heads, hd), dtype)}
        else:
            ce = {"k": jnp.zeros((batch, S, cfg.num_kv_heads, hd), dtype),
                  "v": jnp.zeros((batch, S, cfg.num_kv_heads, hd), dtype)}
        layers.append(stack(ce))
    return {"layers": tuple(layers), "index": jnp.zeros((), jnp.int32)}


def _decode_layer(h, lp, ce, kind: str, cfg: ArchConfig, index, window: int):
    """h: (B, d).  Returns (h, new_cache_entry)."""
    from repro.models.layers import apply_rope
    B, d = h.shape
    hd = cfg.resolved_head_dim
    x = rmsnorm(h, lp["norm1"], cfg.norm_eps)
    if kind == MAMBA:
        y, ce = ssm_lib.mamba_block_decode(x, lp["mamba"], cfg.ssm, ce)
    elif cfg.mla is not None and kind == ATTN:
        y, ckv, krope = attn_lib.mla_decode_absorbed(
            x, lp["attn"], ce["ckv"], ce["krope"], index,
            num_heads=cfg.num_heads, head_dim=hd,
            rope_head_dim=cfg.mla.rope_head_dim, rope_theta=cfg.rope_theta)
        ce = {"ckv": ckv, "krope": krope}
    elif kind == CROSS:
        q = (x @ lp["attn"]["wq"]).reshape(B, cfg.num_heads, hd)
        a = attn_lib.decode_attention(q, ce["k"], ce["v"],
                                      jnp.asarray(ce["k"].shape[1] - 1))
        y = a.reshape(B, -1) @ lp["attn"]["wo"]
    else:
        pos = jnp.full((B, 1), index, jnp.int32)
        q = (x @ lp["attn"]["wq"]).reshape(B, 1, cfg.num_heads, hd)
        k = (x @ lp["attn"]["wk"]).reshape(B, 1, cfg.num_kv_heads, hd)
        v = (x @ lp["attn"]["wv"]).reshape(B, cfg.num_kv_heads, hd)
        q = apply_rope(q, pos, cfg.rope_theta)[:, 0]
        k = apply_rope(k, pos, cfg.rope_theta)[:, 0]
        S = ce["k"].shape[1]
        slot = (index % S) if window > 0 else index
        k_cache = lax.dynamic_update_slice_in_dim(
            ce["k"], k[:, None].astype(ce["k"].dtype), slot, axis=1)
        v_cache = lax.dynamic_update_slice_in_dim(
            ce["v"], v[:, None].astype(ce["v"].dtype), slot, axis=1)
        a = attn_lib.decode_attention(q, k_cache, v_cache, index,
                                      window=window)
        y = a.reshape(B, -1) @ lp["attn"]["wo"]
        ce = {"k": k_cache, "v": v_cache}
    h = h + y
    if "mlp" in lp:
        x2 = rmsnorm(h, lp["norm2"], cfg.norm_eps)
        if "router" in lp["mlp"]:
            y2, _ = moe_ffn(x2[:, None, :], lp["mlp"], cfg.moe)
            y2 = y2[:, 0]
        else:
            y2 = swiglu(x2, lp["mlp"])
        h = h + y2
    return h, ce


def decode_step(params: PyTree, tokens, cache: PyTree, cfg: ArchConfig, *,
                window: int = 0):
    """tokens: (B,) or (B,1) int32 — ONE new token per sequence.
    Returns (logits (B, V), new_cache)."""
    tokens = tokens.reshape(tokens.shape[0])
    prd = period_of(cfg)
    kinds = cfg.layer_kinds()[:prd]
    index = cache["index"]
    h = params["embed"][tokens]
    if cfg.rope_theta <= 0:
        # absolute sinusoidal position of the current token
        d = cfg.d_model
        pos = jnp.asarray(index, jnp.float32)
        div = jnp.exp(jnp.arange(0, d, 2, dtype=jnp.float32)
                      * (-math.log(10000.0) / d))
        pe = jnp.zeros((d,), jnp.float32)
        pe = pe.at[0::2].set(jnp.sin(pos * div)).at[1::2].set(jnp.cos(pos * div))
        h = h + pe.astype(h.dtype)[None]

    def period_fn(h, xs):
        block_params, ces = xs
        new_ces = []
        for j, kind in enumerate(kinds):
            h, ce = _decode_layer(h, block_params[j], ces[j], kind, cfg,
                                  index, window)
            new_ces.append(ce)
        return h, tuple(new_ces)

    h, new_layer_caches = lax.scan(period_fn, h,
                                   (params["blocks"], cache["layers"]))
    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    logits = h @ (params["embed"].T if cfg.tie_embeddings else params["head"])
    new_cache = dict(cache)
    new_cache["layers"] = new_layer_caches
    new_cache["index"] = index + 1
    return logits, new_cache

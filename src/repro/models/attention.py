"""Attention variants (pure JAX / XLA path).

``chunked_attention`` is a blocked online-softmax ("flash"-style) attention
written with two nested ``lax.scan``s so that the (S x S) score matrix is
never materialized — this is the XLA fallback used by the multi-pod dry-run
(Pallas/Mosaic custom calls do not lower on the CPU backend) and the oracle
the Pallas kernel is validated against.

Supports: causal masking, sliding windows, GQA (q heads grouped over kv
heads), cross-attention (causal=False), and Dk != Dv (needed by MLA whose
keys carry a decoupled RoPE slice).

Decode paths:
  - ``decode_attention``       : one-token query against a (possibly ring-
                                 buffer windowed) KV cache;
  - ``flash_decode_partial`` / ``combine_partials``: sequence-sharded decode
    for the 500k cache — each shard produces (m, l, o) partials which are
    combined with pmax/psum inside ``shard_map`` (see sharding/longctx.py).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

NEG_INF = -1e30


def _pad_to(x, axis: int, mult: int):
    s = x.shape[axis]
    rem = (-s) % mult
    if rem == 0:
        return x, s
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad), s


# ---------------------------------------------------------------------------
# Blocked online-softmax attention (train / prefill)
# ---------------------------------------------------------------------------
def chunked_attention(q, k, v, *, causal: bool = True, window: int = 0,
                      q_offset=0, q_block: int = 512, kv_block: int = 1024,
                      kv_len: Optional[jax.Array] = None):
    """q: (B, Sq, H, Dk); k: (B, Skv, Hkv, Dk); v: (B, Skv, Hkv, Dv).

    window > 0 restricts attention to the last `window` positions (causal
    only).  q_offset: absolute position of q[0] (int or scalar array) for
    continued decoding / paged prefill.  kv_len: (B,) valid kv lengths.
    Returns (B, Sq, H, Dv).
    """
    B, Sq, H, Dk = q.shape
    Skv, Hkv, Dv = k.shape[1], k.shape[2], v.shape[-1]
    assert H % Hkv == 0, (H, Hkv)
    G = H // Hkv
    scale = 1.0 / math.sqrt(Dk)
    q_block = min(q_block, Sq)
    kv_block = min(kv_block, Skv)

    qp, Sq0 = _pad_to(q, 1, q_block)
    kp, Skv0 = _pad_to(k, 1, kv_block)
    vp, _ = _pad_to(v, 1, kv_block)
    nq, nk = qp.shape[1] // q_block, kp.shape[1] // kv_block

    # (nq, B, qb, Hkv, G, Dk)
    qs = qp.reshape(B, nq, q_block, Hkv, G, Dk).transpose(1, 0, 2, 3, 4, 5)
    ks = kp.reshape(B, nk, kv_block, Hkv, Dk).transpose(1, 0, 2, 3, 4)
    vs = vp.reshape(B, nk, kv_block, Hkv, Dv).transpose(1, 0, 2, 3, 4)

    valid_len = kv_len if kv_len is not None else jnp.full((B,), Skv0, jnp.int32)

    def q_step(_, qi):
        qblk, qidx = qi                       # (B, qb, Hkv, G, Dk), scalar
        q_pos = q_offset + qidx * q_block + jnp.arange(q_block)       # (qb,)

        def kv_step(carry, ki):
            m, l, acc = carry
            kblk, vblk, kidx = ki
            k_pos = kidx * kv_block + jnp.arange(kv_block)            # (kb,)
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qblk, kblk,
                           preferred_element_type=jnp.float32) * scale
            # validity: (B, 1, 1, 1, kb) — padded / beyond-kv_len slots
            valid = (k_pos[None, :] < valid_len[:, None])[:, None, None, None, :]
            rel = (q_pos[:, None] - k_pos[None, :])[None, None, None]  # (1,1,1,qb,kb)
            mask = valid
            if causal:
                mask = mask & (rel >= 0)
            if window > 0:
                mask = mask & (rel < window)
            s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))               # (B,Hkv,G,qb)
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p.astype(vblk.dtype), vblk,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, Hkv, G, q_block), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, q_block), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, q_block, Dv), jnp.float32)
        (m, l, acc), _ = lax.scan(kv_step, (m0, l0, a0),
                                  (ks, vs, jnp.arange(nk)))
        out = acc / jnp.maximum(l, 1e-30)[..., None]                  # (B,Hkv,G,qb,Dv)
        return None, out.transpose(0, 3, 1, 2, 4)                     # (B,qb,Hkv,G,Dv)

    _, outs = lax.scan(q_step, None, (qs, jnp.arange(nq)))            # (nq,B,qb,...)
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, nq * q_block, H, Dv)
    return out[:, :Sq0].astype(v.dtype)


# ---------------------------------------------------------------------------
# Flash attention with custom VJP (memory-optimal train path)
# ---------------------------------------------------------------------------
# Naive reverse-mode through the online-softmax scan saves the (m, l, acc)
# carry for every KV block — O(S^2/blk) residual memory, which blew the HBM
# budget in the first dry-run (EXPERIMENTS.md §Perf iteration 1).  The
# custom VJP stores only (q, k, v, out, lse) and recomputes the probability
# blocks in the backward pass — the standard flash-attention backward, and
# the exact scheme the Pallas kernel (repro.kernels.flash_attention) uses.

def _block_bias(qidx, kidx, q_block, kv_block, causal, window):
    """Rank-2 additive mask (qb, kb) in fp32 — rank-2 so that XLA's
    loop-invariant hoisting (which materializes a stacked buffer of every
    scan step's mask) costs O(nq*nk*qb*kb), not O(... * B * H) — the
    broadcast-pred blow-up of §Perf iteration 1."""
    q_pos = qidx * q_block + jnp.arange(q_block)
    k_pos = kidx * kv_block + jnp.arange(kv_block)
    rel = q_pos[:, None] - k_pos[None, :]
    bias = jnp.zeros((q_block, kv_block), jnp.float32)
    if causal:
        bias = jnp.where(rel >= 0, bias, NEG_INF)
    if window > 0:
        bias = jnp.where(rel < window, bias, NEG_INF)
    return bias


def _fa_fwd_inner(q, k, v, causal, window, q_block, kv_block):
    """Returns (out (B,Sq,H,Dv), lse (B,Hkv,G,Sq))."""
    B, Sq, H, Dk = q.shape
    Skv, Hkv, Dv = k.shape[1], k.shape[2], v.shape[-1]
    G = H // Hkv
    scale = 1.0 / math.sqrt(Dk)
    nq, nk = Sq // q_block, Skv // kv_block
    qs = q.reshape(B, nq, q_block, Hkv, G, Dk).transpose(1, 0, 2, 3, 4, 5)
    ks = k.reshape(B, nk, kv_block, Hkv, Dk).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(B, nk, kv_block, Hkv, Dv).transpose(1, 0, 2, 3, 4)

    def q_step(_, qi):
        qblk, qidx = qi

        # checkpoint: when the whole attention is differentiated a second
        # time (UGA's keep-trace trajectory), the backward of this scan must
        # recompute the block body instead of stacking per-(qb,kb) p-block
        # residuals across the period scan — the 1 TB/chip blow-up of §Perf
        # iteration 1.
        @partial(jax.checkpoint, prevent_cse=False)
        def kv_step(carry, ki):
            m, l, acc = carry
            kblk, vblk, kidx = ki
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qblk, kblk,
                           preferred_element_type=jnp.float32) * scale
            s = s + _block_bias(qidx, kidx, q_block, kv_block, causal, window)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p.astype(vblk.dtype), vblk,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, Hkv, G, q_block), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, q_block), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, q_block, Dv), jnp.float32)
        (m, l, acc), _ = lax.scan(kv_step, (m0, l0, a0),
                                  (ks, vs, jnp.arange(nk)))
        l_safe = jnp.maximum(l, 1e-30)
        out = (acc / l_safe[..., None]).transpose(0, 3, 1, 2, 4)
        lse = m + jnp.log(l_safe)
        return None, (out, lse)

    _, (outs, lses) = lax.scan(q_step, None, (qs, jnp.arange(nq)))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, H, Dv)
    lse = lses.transpose(1, 2, 3, 0, 4).reshape(B, Hkv, G, Sq)
    return out.astype(v.dtype), lse


def _fa_bwd_inner(q, k, v, out, lse, do, causal, window, q_block, kv_block):
    B, Sq, H, Dk = q.shape
    Skv, Hkv, Dv = k.shape[1], k.shape[2], v.shape[-1]
    G = H // Hkv
    scale = 1.0 / math.sqrt(Dk)
    nq, nk = Sq // q_block, Skv // kv_block
    qs = q.reshape(B, nq, q_block, Hkv, G, Dk).transpose(1, 0, 2, 3, 4, 5)
    ks = k.reshape(B, nk, kv_block, Hkv, Dk).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(B, nk, kv_block, Hkv, Dv).transpose(1, 0, 2, 3, 4)
    dos = do.reshape(B, nq, q_block, Hkv, G, Dv).transpose(1, 0, 2, 3, 4, 5)
    lses = lse.reshape(B, Hkv, G, nq, q_block).transpose(3, 0, 1, 2, 4)
    # delta = rowsum(do * out): (B,Sq,H) -> block layout (nq,B,Hkv,G,qb)
    delta = jnp.sum(do.astype(jnp.float32) * out.astype(jnp.float32), axis=-1)
    deltas = delta.reshape(B, nq, q_block, Hkv, G).transpose(1, 0, 3, 4, 2)

    def q_step(carry, qi):
        dk_acc, dv_acc = carry                     # (nk,B,kb,Hkv,Dk/Dv) f32
        qblk, doblk, lseblk, dblk, qidx = qi

        @partial(jax.checkpoint, prevent_cse=False)
        def kv_step(dq_acc, ki):
            kblk, vblk, kidx = ki
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qblk, kblk,
                           preferred_element_type=jnp.float32) * scale
            s = s + _block_bias(qidx, kidx, q_block, kv_block, causal, window)
            p = jnp.exp(s - lseblk[..., None])     # (B,Hkv,G,qb,kb)
            dp = jnp.einsum("bqhgd,bkhd->bhgqk", doblk, vblk,
                            preferred_element_type=jnp.float32)
            ds = p * (dp - dblk[..., None]) * scale
            dq_c = jnp.einsum("bhgqk,bkhd->bqhgd", ds, kblk,
                              preferred_element_type=jnp.float32)
            dk_c = jnp.einsum("bhgqk,bqhgd->bkhd", ds, qblk,
                              preferred_element_type=jnp.float32)
            dv_c = jnp.einsum("bhgqk,bqhgd->bkhd",
                              p.astype(jnp.float32), doblk.astype(jnp.float32),
                              preferred_element_type=jnp.float32)
            return dq_acc + dq_c, (dk_c, dv_c)

        dq0 = jnp.zeros((B, q_block, Hkv, G, Dk), jnp.float32)
        dq, (dk_c, dv_c) = lax.scan(kv_step, dq0,
                                    (ks, vs, jnp.arange(nk)))
        return (dk_acc + dk_c, dv_acc + dv_c), dq

    dk0 = jnp.zeros((nk, B, kv_block, Hkv, Dk), jnp.float32)
    dv0 = jnp.zeros((nk, B, kv_block, Hkv, Dv), jnp.float32)
    (dk_blocks, dv_blocks), dqs = lax.scan(
        q_step, (dk0, dv0), (qs, dos, lses, deltas, jnp.arange(nq)))
    dq = dqs.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, H, Dk)
    dk = dk_blocks.transpose(1, 0, 2, 3, 4).reshape(B, Skv, Hkv, Dk)
    dv = dv_blocks.transpose(1, 0, 2, 3, 4).reshape(B, Skv, Hkv, Dv)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def flash_attention(q, k, v, causal: bool = True, window: int = 0,
                    q_block: int = 512, kv_block: int = 512):
    """Memory-optimal blocked attention for train/prefill.

    q: (B,Sq,H,Dk), k: (B,Skv,Hkv,Dk), v: (B,Skv,Hkv,Dv); Sq/Skv must be
    multiples of the block sizes (callers pad).  GQA via H = G*Hkv.
    """
    out, _ = _fa_fwd_inner(q, k, v, causal, window,
                           min(q_block, q.shape[1]),
                           min(kv_block, k.shape[1]))
    return out


def _fa_fwd(q, k, v, causal, window, q_block, kv_block):
    out, lse = _fa_fwd_inner(q, k, v, causal, window,
                             min(q_block, q.shape[1]),
                             min(kv_block, k.shape[1]))
    return out, (q, k, v, out, lse)


def _fa_bwd(causal, window, q_block, kv_block, res, do):
    q, k, v, out, lse = res
    dq, dk, dv = _fa_bwd_inner(q, k, v, out, lse, do, causal, window,
                               min(q_block, q.shape[1]),
                               min(kv_block, k.shape[1]))
    return dq, dk, dv


flash_attention.defvjp(_fa_fwd, _fa_bwd)


def attend(q, k, v, *, causal: bool = True, window: int = 0,
           q_block: int = 512, kv_block: int = 512):
    """Dispatch: flash (custom-vjp) path when shapes are block-divisible,
    else the plain chunked scan (small smoke shapes)."""
    qb = min(q_block, q.shape[1])
    kb = min(kv_block, k.shape[1])
    if q.shape[1] % qb == 0 and k.shape[1] % kb == 0:
        return flash_attention(q, k, v, causal, window, qb, kb)
    return chunked_attention(q, k, v, causal=causal, window=window,
                             q_block=qb, kv_block=kb)


def simple_attention(q, k, v, *, causal: bool = True, window: int = 0,
                     q_offset=0, kv_len: Optional[jax.Array] = None):
    """Direct softmax attention — oracle for tests; same semantics."""
    B, Sq, H, Dk = q.shape
    Skv, Hkv, Dv = k.shape[1], k.shape[2], v.shape[-1]
    G = H // Hkv
    qg = q.reshape(B, Sq, Hkv, G, Dk)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                   preferred_element_type=jnp.float32) / math.sqrt(Dk)
    q_pos = q_offset + jnp.arange(Sq)
    k_pos = jnp.arange(Skv)
    rel = q_pos[:, None] - k_pos[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask = mask & (rel >= 0)
    if window > 0:
        mask = mask & (rel < window)
    mask = jnp.broadcast_to(mask[None, None, None], s.shape)
    if kv_len is not None:
        mask = mask & (k_pos[None, None, None, None, :] <
                       kv_len[:, None, None, None, None].astype(k_pos.dtype))
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v.dtype), v)
    return out.reshape(B, Sq, H, Dv)


# ---------------------------------------------------------------------------
# Decode (one new token against a cache)
# ---------------------------------------------------------------------------
def decode_attention(q, k_cache, v_cache, index, *, window: int = 0):
    """q: (B, H, Dk); caches: (B, S, Hkv, Dk/Dv); index: scalar int32 —
    number of tokens already in the cache (the new token's position).

    With window > 0 the cache is a ring buffer of size S == window and every
    slot written so far is valid (slot_pos = index - distance handled by the
    caller's ring arithmetic; validity simply requires slot < min(index+1, S)
    after the caller wrote the current token at index % S).
    """
    B, S, Hkv, Dk = k_cache.shape
    Dv = v_cache.shape[-1]
    H = q.shape[1]
    G = H // Hkv
    qg = q.reshape(B, Hkv, G, Dk)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg, k_cache,
                   preferred_element_type=jnp.float32) / math.sqrt(Dk)
    k_pos = jnp.arange(S)
    if window > 0:
        valid = k_pos < jnp.minimum(index + 1, S)          # ring buffer
    else:
        valid = k_pos <= index
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", p.astype(v_cache.dtype), v_cache)
    return out.reshape(B, H, Dv)


def flash_decode_partial(q, k_shard, v_shard, index, shard_offset):
    """Per-shard online-softmax partials for sequence-sharded decode.

    q: (B, H, Dk); k/v_shard: (B, S_loc, Hkv, D*); shard_offset: scalar —
    absolute position of this shard's first cache slot.
    Returns (m, l, o): (B,H), (B,H), (B,H,Dv) — combine with
    ``combine_partials`` (psum/pmax over the sequence-sharding axis).
    """
    B, S_loc, Hkv, Dk = k_shard.shape
    H = q.shape[1]
    G = H // Hkv
    qg = q.reshape(B, Hkv, G, Dk)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg, k_shard,
                   preferred_element_type=jnp.float32) / math.sqrt(Dk)
    pos = shard_offset + jnp.arange(S_loc)
    s = jnp.where((pos <= index)[None, None, None, :], s, NEG_INF)
    m = jnp.max(s, axis=-1)                                   # (B,Hkv,G)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bhgk,bkhd->bhgd", p.astype(v_shard.dtype), v_shard)
    Dv = v_shard.shape[-1]
    return (m.reshape(B, H), l.reshape(B, H),
            o.reshape(B, H, Dv).astype(jnp.float32))


def combine_partials(m, l, o, axis_name: str):
    """Combine flash-decode partials across `axis_name` (inside shard_map)."""
    m_g = lax.pmax(m, axis_name)
    corr = jnp.exp(m - m_g)
    l_g = lax.psum(l * corr, axis_name)
    o_g = lax.psum(o * corr[..., None], axis_name)
    return o_g / jnp.maximum(l_g, 1e-30)[..., None]


# ---------------------------------------------------------------------------
# GQA projection block (q/k/v/o) shared by the transformer stack
# ---------------------------------------------------------------------------
def gqa_init(key, d_model: int, num_heads: int, num_kv_heads: int,
             head_dim: int, dtype=jnp.float32):
    from repro.models.layers import dense_init
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": dense_init(kq, d_model, num_heads * head_dim, dtype),
        "wk": dense_init(kk, d_model, num_kv_heads * head_dim, dtype),
        "wv": dense_init(kv, d_model, num_kv_heads * head_dim, dtype),
        "wo": dense_init(ko, num_heads * head_dim, d_model, dtype,
                         scale=1.0 / math.sqrt(num_heads * head_dim)),
    }


def gqa_project_qkv(x, p, num_heads: int, num_kv_heads: int, head_dim: int):
    B, S, _ = x.shape
    q = (x @ p["wq"]).reshape(B, S, num_heads, head_dim)
    k = (x @ p["wk"]).reshape(B, S, num_kv_heads, head_dim)
    v = (x @ p["wv"]).reshape(B, S, num_kv_heads, head_dim)
    return q, k, v


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2 multi-head latent attention)
# ---------------------------------------------------------------------------
def mla_init(key, d_model: int, num_heads: int, head_dim: int,
             kv_lora_rank: int, rope_head_dim: int, dtype=jnp.float32):
    from repro.models.layers import dense_init
    keys = jax.random.split(key, 6)
    return {
        "w_dkv": dense_init(keys[0], d_model, kv_lora_rank, dtype),
        "w_kr": dense_init(keys[1], d_model, rope_head_dim, dtype),
        "w_uk": (jax.random.normal(keys[2], (kv_lora_rank, num_heads, head_dim))
                 / math.sqrt(kv_lora_rank)).astype(dtype),
        "w_uv": (jax.random.normal(keys[3], (kv_lora_rank, num_heads, head_dim))
                 / math.sqrt(kv_lora_rank)).astype(dtype),
        "wq": dense_init(keys[4], d_model,
                         num_heads * (head_dim + rope_head_dim), dtype),
        "wo": dense_init(keys[5], num_heads * head_dim, d_model, dtype,
                         scale=1.0 / math.sqrt(num_heads * head_dim)),
    }


def mla_attention(x, p, positions, *, num_heads: int, head_dim: int,
                  rope_head_dim: int, rope_theta: float, causal: bool = True):
    """Training/prefill MLA: expand the latent kv and run standard attention
    with Dk = head_dim + rope_head_dim, Dv = head_dim."""
    B, S, _ = x.shape
    ckv = x @ p["w_dkv"]                                       # (B,S,r)
    k_rope = apply_rope_1h(x @ p["w_kr"], positions, rope_theta)  # (B,S,rd)
    k_nope = jnp.einsum("bsr,rhd->bshd", ckv, p["w_uk"])
    v = jnp.einsum("bsr,rhd->bshd", ckv, p["w_uv"])
    q = (x @ p["wq"]).reshape(B, S, num_heads, head_dim + rope_head_dim)
    q_nope, q_rope = q[..., :head_dim], q[..., head_dim:]
    from repro.models.layers import apply_rope
    q_rope = apply_rope(q_rope, positions, rope_theta)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  (B, S, num_heads, rope_head_dim))], axis=-1)
    out = attend(q, k, v, causal=causal)
    return out.reshape(B, S, num_heads * head_dim) @ p["wo"]


def apply_rope_1h(x, positions, theta):
    """RoPE on a single shared head: x (B,S,D)."""
    from repro.models.layers import apply_rope
    return apply_rope(x, positions, theta)


def mla_decode_absorbed(x, p, ckv_cache, krope_cache, index, *,
                        num_heads: int, head_dim: int, rope_head_dim: int,
                        rope_theta: float):
    """Absorbed-matmul MLA decode: scores/values computed directly in the
    compressed latent space — the cache stores only (ckv, k_rope).

    x: (B, d_model) current-token activations; caches (B, S, r)/(B, S, rd);
    index: scalar position.  Returns (B, d_model), updated caches.
    """
    B = x.shape[0]
    pos = jnp.full((B, 1), index, jnp.int32)
    ckv_new = x @ p["w_dkv"]                                   # (B, r)
    krope_new = apply_rope_1h((x @ p["w_kr"])[:, None, :], pos,
                              rope_theta)[:, 0]                # (B, rd)
    ckv_cache = lax.dynamic_update_slice_in_dim(
        ckv_cache, ckv_new[:, None, :].astype(ckv_cache.dtype), index, axis=1)
    krope_cache = lax.dynamic_update_slice_in_dim(
        krope_cache, krope_new[:, None, :].astype(krope_cache.dtype), index, axis=1)

    q = (x @ p["wq"]).reshape(B, num_heads, head_dim + rope_head_dim)
    q_nope, q_rope = q[..., :head_dim], q[..., head_dim:]
    from repro.models.layers import apply_rope
    q_rope = apply_rope(q_rope[:, None], pos, rope_theta)[:, 0]
    # absorb W_uk into the query: q_lat (B, H, r)
    q_lat = jnp.einsum("bhd,rhd->bhr", q_nope, p["w_uk"])
    s = (jnp.einsum("bhr,bsr->bhs", q_lat, ckv_cache,
                    preferred_element_type=jnp.float32)
         + jnp.einsum("bhd,bsd->bhs", q_rope, krope_cache,
                      preferred_element_type=jnp.float32))
    s = s / math.sqrt(head_dim + rope_head_dim)
    valid = jnp.arange(ckv_cache.shape[1]) <= index
    s = jnp.where(valid[None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bhs,bsr->bhr", w.astype(ckv_cache.dtype), ckv_cache)
    o = jnp.einsum("bhr,rhd->bhd", o_lat, p["w_uv"])           # (B,H,hd)
    out = o.reshape(B, num_heads * head_dim) @ p["wo"]
    return out, ckv_cache, krope_cache

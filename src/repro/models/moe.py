"""Mixture-of-Experts FFN (top-k routing, capacity-based).

Two dispatch implementations:

  * ``moe_ffn`` (default) — **gather/scatter dispatch**: expert slot
    assignments are computed as integer indices (scatter for slot->token,
    gather for token->slot), so dispatch/combine cost O(G*E*C*d) memory and
    ZERO matmul FLOPs.  The Switch-style dense (G,S,E,C) one-hot einsum
    formulation costs O(G*S*E*C) memory (quadratic in group size — 10+ GB
    per chip for deepseek's E=64, K=6 at 4k sequences, §Perf iteration 4)
    and E*C*d matmul FLOPs per token.

  * ``moe_ffn_einsum`` — the dense einsum reference (kept as the oracle;
    equality is property-tested).

Tokens are grouped (``group_size`` per group); each expert accepts
``capacity = ceil(top_k * group_size / E * capacity_factor)`` tokens per
group; overflow drops (standard capacity semantics; the aux loss keeps load
balanced).  The expert axis shards over the mesh ``model`` axis (GSPMD
inserts the all-to-alls).  UGA's second-order gradient flows through the
router via the combine weights.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.models.layers import dense_init

# Mesh axis that owns the expert dimension of activations (set by the
# launcher; None = let GSPMD choose).  A module-level hint rather than a
# config field because it is a property of the launch mesh, not the model.
EXPERT_AXIS = None

# Dispatch implementation selector ("gather" | "einsum") — both are exact
# (property-tested equal); they trade FLOPs (einsum pays O(E*C*d) dispatch
# matmuls) against GSPMD friendliness (gather's scatter-add backward lowers
# to replicate+all-reduce under sharded operands: 5x collective bytes and
# 6x HBM on deepseek train — EXPERIMENTS.md §Perf it.6).  einsum wins.
MOE_IMPL = "einsum"


def set_moe_impl(impl: str):
    global MOE_IMPL
    assert impl in ("gather", "einsum")
    MOE_IMPL = impl


def set_expert_axis(axis):
    global EXPERT_AXIS
    EXPERT_AXIS = axis


def _constrain_experts(x, spec_builder):
    if EXPERT_AXIS is None:
        return x
    from jax.sharding import PartitionSpec as P
    try:
        return jax.lax.with_sharding_constraint(x, spec_builder(P, EXPERT_AXIS))
    except Exception:   # no ambient mesh (smoke tests) — hint is best-effort
        return x


def moe_init(key, d_model: int, cfg: MoEConfig, d_ff_dense: int, dtype=jnp.float32):
    de = cfg.d_expert or d_ff_dense
    k_r, k_g, k_u, k_d, k_s = jax.random.split(key, 5)
    E = cfg.num_experts
    p = {
        "router": dense_init(k_r, d_model, E, jnp.float32),  # router in fp32
        "w_gate": (jax.random.normal(k_g, (E, d_model, de)) / math.sqrt(d_model)).astype(dtype),
        "w_up": (jax.random.normal(k_u, (E, d_model, de)) / math.sqrt(d_model)).astype(dtype),
        "w_down": (jax.random.normal(k_d, (E, de, d_model)) / math.sqrt(de)).astype(dtype),
    }
    if cfg.num_shared:
        ks = jax.random.split(k_s, 3)
        ds = (cfg.d_expert or d_ff_dense) * cfg.num_shared
        p["shared"] = {
            "w_gate": dense_init(ks[0], d_model, ds, dtype),
            "w_up": dense_init(ks[1], d_model, ds, dtype),
            "w_down": dense_init(ks[2], ds, d_model, dtype),
        }
    return p


def _route(xg, p, cfg: MoEConfig):
    """Shared routing math.  xg: (G, S, d).
    Returns (gate_vals, expert_idx, pos_in_e, keep, probs, C)."""
    G, S, _ = xg.shape
    E, K = cfg.num_experts, cfg.top_k
    logits = xg.astype(jnp.float32) @ p["router"]              # (G,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)            # (G,S,K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)
    C = max(int(math.ceil(K * S / E * cfg.capacity_factor)), 1)
    # position of each (token, k) inside its expert queue, priority k=0 first
    onehot = jax.nn.one_hot(expert_idx, E, dtype=jnp.int32)    # (G,S,K,E)
    oh_flat = onehot.transpose(0, 2, 1, 3).reshape(G, K * S, E)
    pos_flat = jnp.cumsum(oh_flat, axis=1) - oh_flat
    pos = pos_flat.reshape(G, K, S, E).transpose(0, 2, 1, 3)   # (G,S,K,E)
    pos_in_e = jnp.sum(pos * onehot, axis=-1)                  # (G,S,K)
    keep = pos_in_e < C
    return gate_vals, expert_idx, pos_in_e, keep, probs, C


def _aux_loss(probs, expert_idx, cfg: MoEConfig):
    E = cfg.num_experts
    me = jnp.mean(probs, axis=1)                               # (G,E)
    ce = jnp.mean(jax.nn.one_hot(expert_idx[..., 0], E,
                                 dtype=jnp.float32), axis=1)
    return cfg.aux_loss_coef * E * jnp.mean(jnp.sum(me * ce, axis=-1))


def _group(x, cfg: MoEConfig):
    d = x.shape[-1]
    tokens = x.reshape(-1, d)
    T = tokens.shape[0]
    # decode (S=1): every token is its own group, so C >= 1 keeps every
    # routed token instead of making B independent decode steps compete for
    # one group's capacity.  Training shapes (S>1) keep cross-sequence
    # grouping unchanged.
    if x.ndim > 1 and x.shape[-2] == 1:
        gs = 1
    else:
        gs = min(cfg.group_size, T)
    pad = (-T) % gs
    if pad:
        tokens = jnp.concatenate(
            [tokens, jnp.zeros((pad, d), tokens.dtype)], axis=0)
    return tokens.reshape(-1, gs, d), T, pad


def moe_ffn(x, p, cfg: MoEConfig) -> Tuple[jax.Array, jax.Array]:
    """Dispatch per the MOE_IMPL selector."""
    if MOE_IMPL == "einsum":
        return moe_ffn_einsum(x, p, cfg)
    return moe_ffn_gather(x, p, cfg)


def moe_ffn_gather(x, p, cfg: MoEConfig) -> Tuple[jax.Array, jax.Array]:
    """Gather/scatter dispatch.  x: (..., S, d) -> (same, aux_loss)."""
    orig_shape = x.shape
    d = x.shape[-1]
    xg, T, pad = _group(x, cfg)
    G, S, _ = xg.shape
    E, K = cfg.num_experts, cfg.top_k
    gate_vals, expert_idx, pos_in_e, keep, probs, C = _route(xg, p, cfg)
    gate_vals = gate_vals * keep.astype(gate_vals.dtype)

    # ---- dispatch: scatter token ids into (G, E, C) slots, then gather ----
    gidx = jnp.arange(G)[:, None, None]
    s_idx = jnp.broadcast_to(jnp.arange(S)[None, :, None], (G, S, K))
    slot_src = jnp.zeros((G, E, C), jnp.int32)
    # dropped (keep=False) entries write to a scratch slot via clamped pos
    pos_w = jnp.where(keep, pos_in_e, C - 1)
    slot_src = slot_src.at[gidx, expert_idx, pos_w].max(
        jnp.where(keep, s_idx + 1, 0))         # +1: 0 means empty slot
    slot_valid = slot_src > 0
    slot_tok = jnp.maximum(slot_src - 1, 0)                    # (G,E,C)
    xe = jnp.take_along_axis(
        xg, slot_tok.reshape(G, E * C)[..., None], axis=1
    ).reshape(G, E, C, d)
    xe = xe * slot_valid[..., None].astype(xe.dtype)

    # ---- expert FFN: (E, G*C, d) x (E, d, de) ----
    xe_f = xe.transpose(1, 0, 2, 3).reshape(E, G * C, d)
    xe_f = _constrain_experts(xe_f, lambda P, a: P(a, None, None))
    h = jax.nn.silu(jnp.einsum("end,edf->enf", xe_f, p["w_gate"])) * \
        jnp.einsum("end,edf->enf", xe_f, p["w_up"])
    ye_f = jnp.einsum("enf,efd->end", h, p["w_down"])
    ye_f = _constrain_experts(ye_f, lambda P, a: P(a, None, None))
    ye = ye_f.reshape(E, G, C, d).transpose(1, 0, 2, 3)        # (G,E,C,d)

    # ---- combine: gather each token's K expert outputs ----
    flat_slot = (expert_idx * C + pos_w).reshape(G, S * K)     # (G,S*K)
    yk = jnp.take_along_axis(
        ye.reshape(G, E * C, d), flat_slot[..., None], axis=1
    ).reshape(G, S, K, d)
    y = jnp.sum(yk * gate_vals[..., None].astype(yk.dtype), axis=2)

    if "shared" in p:
        from repro.models.layers import swiglu
        y = y + swiglu(xg, p["shared"])

    aux = _aux_loss(probs, expert_idx, cfg)
    y = y.reshape(-1, d)
    if pad:
        y = y[:T]
    return y.reshape(orig_shape), aux


def moe_ffn_einsum(x, p, cfg: MoEConfig) -> Tuple[jax.Array, jax.Array]:
    """Dense one-hot einsum dispatch (Switch-Transformer formulation) —
    reference implementation / oracle for ``moe_ffn``."""
    orig_shape = x.shape
    d = x.shape[-1]
    xg, T, pad = _group(x, cfg)
    G, S, _ = xg.shape
    E, K = cfg.num_experts, cfg.top_k
    gate_vals, expert_idx, pos_in_e, keep, probs, C = _route(xg, p, cfg)
    gate_vals = gate_vals * keep.astype(gate_vals.dtype)

    onehot = jax.nn.one_hot(expert_idx, E, dtype=jnp.float32)
    combine = jnp.einsum(
        "gske,gskc->gsec",
        onehot * gate_vals[..., None],
        jax.nn.one_hot(pos_in_e, C, dtype=jnp.float32) * keep[..., None])
    dispatch = (combine > 0).astype(xg.dtype)                  # (G,S,E,C)

    xe = jnp.einsum("gsec,gsd->gecd", dispatch, xg)
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, p["w_gate"])) * \
        jnp.einsum("gecd,edf->gecf", xe, p["w_up"])
    ye = jnp.einsum("gecf,efd->gecd", h, p["w_down"])
    y = jnp.einsum("gsec,gecd->gsd", combine.astype(ye.dtype), ye)

    if "shared" in p:
        from repro.models.layers import swiglu
        y = y + swiglu(xg, p["shared"])

    aux = _aux_loss(probs, expert_idx, cfg)
    y = y.reshape(-1, d)
    if pad:
        y = y[:T]
    return y.reshape(orig_shape), aux

"""Communication-compression subsystem: the GradientCodec registry
(``none`` / ``int8`` / ``sign1bit`` / ``topk`` + ``register_codec``), the
per-client error-feedback state, and the uplink byte accounting — the
fourth plugin registry next to algorithms / executors / engines."""
from repro.comm.codecs import (GradientCodec, available_codecs, get_codec,
                               register_codec, resolve_codec)
from repro.comm.transport import (client_coded_accumulate,
                                  coded_aggregate_stacked,
                                  comm_bytes_per_client, init_comm_state)

__all__ = ["GradientCodec", "register_codec", "get_codec",
           "available_codecs", "resolve_codec", "init_comm_state",
           "comm_bytes_per_client", "client_coded_accumulate",
           "coded_aggregate_stacked"]

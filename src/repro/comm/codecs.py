"""GradientCodec plugin registry — the communication-compression uplink.

The paper motivates FedAvg by the "potential heavy communication costs" of
shipping raw updates; this registry models that uplink.  A
:class:`GradientCodec` encodes ONE client's gradient — per dtype group, in
the fused engine's flat ``(rows, LANES)`` fp32 layout
(:mod:`repro.core.flat`) — into a transport payload, and decodes it on the
server side before the Eq. (14) aggregation.  The round builder threads
codecs through the cohort executors (:meth:`repro.core.executors.
CohortExecutor.run_coded`), so every client algorithm composes with every
codec unchanged.

Built-ins (registered like algorithms/executors/engines, via the shared
``core/registry.py`` helper):

  * ``none``     — identity / no codec (the round bypasses the comm stage
    entirely, so it is bit-identical to a codec-free build);
  * ``int8``     — symmetric per-group int8 quantization with one fp32
    scale ``amax / 127`` per group (~4x uplink reduction);
  * ``sign1bit`` — signSGD-style 1-bit sign + one per-group magnitude
    ``mean |g|`` (~32x);
  * ``topk``     — magnitude sparsification: the ``FedConfig.topk_ratio``
    fraction of largest-|g| elements ships as (value, index) pairs.

Error feedback (``FedConfig.error_feedback``): each client keeps the
compression residual ``e = (g + residual) - decode(encode(g + residual))``
in the server state's ``state["comm"]`` slot (a per-client buffer stack,
threaded through checkpoints like ``ctrl``), so quantization error
re-enters the next round's transmission instead of being lost — the
standard EF-SGD memory that restores convergence under aggressive codecs.

Hot-path kernels live in :mod:`repro.kernels.comm` (Pallas pack/unpack +
decode-fused FMA, with jnp ``ref`` oracles); ``topk`` is pure jnp — its
gather/scatter transport does not map onto the flat-tile HBM sweeps the
kernel family is built from.

Register a new codec with :func:`register_codec`; the factory receives the
:class:`~repro.configs.base.FedConfig`.  Lossy codecs are *post*-meta-mode
only for now — a straight-through/differentiable codec for
``through_aggregation`` is a ROADMAP follow-up.
"""
from __future__ import annotations

from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.flat import GroupSpec, LANES
from repro.core.registry import Registry
from repro.kernels.comm import ops as C

PyTree = Any

__all__ = ["GradientCodec", "register_codec", "get_codec",
           "available_codecs", "resolve_codec"]


class GradientCodec:
    """Protocol.  All methods operate on ONE dtype group at a time;
    payloads are pytrees of arrays with static shapes derived from the
    :class:`~repro.core.flat.GroupSpec`, so they scan/jit cleanly."""
    name: str = "?"
    lossy: bool = True          # False: decode(encode(g)) == g exactly

    def encode(self, group: GroupSpec, g: jax.Array) -> PyTree:
        """(rows, LANES) fp32 gradient -> transport payload."""
        raise NotImplementedError

    def decode(self, group: GroupSpec, payload: PyTree) -> jax.Array:
        """Transport payload -> (rows, LANES) fp32 reconstruction.  Pad
        elements (flat index >= group.size) must decode to exact zero."""
        raise NotImplementedError

    def encode_ef(self, group: GroupSpec, e: jax.Array
                  ) -> Tuple[PyTree, jax.Array]:
        """Encode the error-compensated gradient ``e = g + residual`` and
        return (payload, new_residual = e - decode(payload)).  Codecs with
        a fused quantize+error kernel override this to keep EF at one
        sweep; the default costs one extra decode."""
        payload = self.encode(group, e)
        return payload, e - self.decode(group, payload)

    def decode_fma(self, group: GroupSpec, acc: jax.Array, payload: PyTree,
                   w) -> jax.Array:
        """Server-side streaming aggregate: ``acc + w * decode(payload)``
        (w = the client's normalized Eq. 14 weight).  Codecs with a
        decode-fused FMA kernel override this."""
        return acc + jnp.asarray(w, jnp.float32) * self.decode(group, payload)

    def payload_bytes(self, group: GroupSpec) -> int:
        """Uplink bytes one client ships for this group (static python
        int).  Measured on the transported information — group.size true
        elements plus per-group scalars — not the padded buffer layout."""
        raise NotImplementedError


_CODECS = Registry("gradient codec", "repro.comm.codecs.register_codec")


def register_codec(name: str):
    """Decorator registering a codec factory ``factory(fed) -> codec``."""
    def deco(factory: Callable) -> Callable:
        _CODECS.register(name, factory)
        return factory
    return deco


def get_codec(name: str) -> Callable:
    return _CODECS.get(name)


def available_codecs() -> tuple:
    return _CODECS.names()


def resolve_codec(fed, *, codec: Optional[str] = None) -> GradientCodec:
    """An explicit registry name wins, then ``fed.codec`` (default
    'none')."""
    if codec is None:
        codec = getattr(fed, "codec", "none")
    return get_codec(codec)(fed)


# ---------------------------------------------------------------------------
# built-in codecs
# ---------------------------------------------------------------------------
@register_codec("none")
class NoneCodec(GradientCodec):
    """Identity transport: fp32 ships as-is.  ``lossy = False`` makes the
    round builder bypass the comm stage entirely, so 'none' is bit-
    identical to a codec-free round on every executor/engine."""
    name = "none"
    lossy = False

    def __init__(self, fed=None):
        del fed

    def encode(self, group, g):
        return g

    def decode(self, group, payload):
        return payload

    def payload_bytes(self, group):
        return 4 * group.size


@register_codec("int8")
class Int8Codec(GradientCodec):
    """Symmetric per-group int8: one fp32 scale ``amax / 127`` per dtype
    group, round-to-nearest quantization (``kernels/comm``: quantize and
    EF-residual in one sweep, decode fused into the aggregate FMA)."""
    name = "int8"
    lossy = True

    def __init__(self, fed=None, *, use_ref: bool = False,
                 interpret: Optional[bool] = None):
        del fed
        self._kw = dict(use_ref=use_ref, interpret=interpret)

    def _scale(self, g):
        amax = jnp.max(jnp.abs(g))
        return jnp.maximum(amax, 1e-30) / 127.0

    def encode(self, group, g):
        scale = self._scale(g)
        q = C.quantize_i8(g, 1.0 / scale, scale, **self._kw)
        return {"q": q, "scale": scale}

    def encode_ef(self, group, e):
        scale = self._scale(e)
        q, err = C.quantize_i8(e, 1.0 / scale, scale, with_error=True,
                               **self._kw)
        return {"q": q, "scale": scale}, err

    def decode(self, group, payload):
        return payload["q"].astype(jnp.float32) * payload["scale"]

    def decode_fma(self, group, acc, payload, w):
        return C.dequant_i8_fma(acc, payload["q"], payload["scale"] * w,
                                **self._kw)

    def payload_bytes(self, group):
        return group.size + 4                       # int8 elements + scale


@register_codec("sign1bit")
class Sign1BitCodec(GradientCodec):
    """signSGD-style 1-bit: sign bits packed 8-per-uint8 plus one per-group
    magnitude ``mu = mean |g|`` (over the true elements; the unpack kernels
    mask the layout pad back to zero)."""
    name = "sign1bit"
    lossy = True

    def __init__(self, fed=None, *, use_ref: bool = False,
                 interpret: Optional[bool] = None):
        del fed
        self._kw = dict(use_ref=use_ref, interpret=interpret)

    def _mu(self, group, g):
        return jnp.sum(jnp.abs(g)) / jnp.float32(group.size)

    def encode(self, group, g):
        mu = self._mu(group, g)
        bits = C.sign_pack(g, mu, group.size, **self._kw)
        return {"bits": bits, "mu": mu}

    def encode_ef(self, group, e):
        mu = self._mu(group, e)
        bits, err = C.sign_pack(e, mu, group.size, with_error=True,
                                **self._kw)
        return {"bits": bits, "mu": mu}, err

    def decode(self, group, payload):
        zeros = jnp.zeros((group.rows, LANES), jnp.float32)
        return C.sign_unpack_fma(zeros, payload["bits"], payload["mu"],
                                 group.size, **self._kw)

    def decode_fma(self, group, acc, payload, w):
        return C.sign_unpack_fma(acc, payload["bits"], payload["mu"] * w,
                                 group.size, **self._kw)

    def payload_bytes(self, group):
        return -(-group.size // 8) + 4              # ceil(size/8) bits + mu


@register_codec("topk")
class TopKCodec(GradientCodec):
    """Magnitude sparsification: the ``FedConfig.topk_ratio`` fraction of
    largest-|g| elements per group ships as (fp32 value, int32 index)
    pairs.  Pure jnp (``lax.top_k`` + scatter): index transport has no
    flat-tile HBM-sweep form, so no Pallas kernel — see the module
    docstring."""
    name = "topk"
    lossy = True

    def __init__(self, fed=None):
        self._ratio = getattr(fed, "topk_ratio", 0.01) if fed is not None \
            else 0.01

    def _k(self, group: GroupSpec) -> int:
        return max(1, min(group.size, int(round(group.size * self._ratio))))

    def encode(self, group, g):
        flat = g.reshape(-1)
        _, idx = jax.lax.top_k(jnp.abs(flat), self._k(group))
        return {"values": jnp.take(flat, idx), "indices": idx}

    def decode(self, group, payload):
        flat = jnp.zeros((group.rows * LANES,), jnp.float32)
        flat = flat.at[payload["indices"]].set(payload["values"])
        return flat.reshape(group.rows, LANES)

    def payload_bytes(self, group):
        return 8 * self._k(group)                   # fp32 value + i32 index

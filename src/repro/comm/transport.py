"""Uplink simulation glue: per-client encode -> decode -> Eq. (14)
accumulate over the flat dtype-group buffers, plus the per-client
error-feedback state and the measured-bytes accounting.

The server-side aggregate of decoded gradients is a streaming accumulation
(one client at a time), so every cohort executor shares
:func:`client_coded_accumulate`:

  * the chunked streaming core (which the chunked/vmap/scan registrations
    and each shard of the two-tier sharded topology all run —
    :func:`repro.core.aggregate.chunked_cohort_gradient_coded`) computes a
    chunk of client gradients in parallel, then runs
    :func:`coded_aggregate_stacked` — a ``lax.scan`` over the chunk's
    stacked cohort axis — for the codec stage (encode/decode is a few
    flat sweeps per client, negligible next to the local updates, and the
    scan keeps the Pallas codec kernels un-batched);
  * the legacy scan path calls it directly inside its cohort scan (the
    client gradient is already computed one at a time there — see
    :func:`repro.core.aggregate.scan_cohort_gradient_coded`).

Error-feedback state layout (``state["comm"]``): ``{"residual": tuple}``
with one ``(cohort, rows, LANES)`` fp32 buffer per dtype group — client k's
residual lives in slot k of the stack, exactly like ``ctrl["w_logits"]``
keys clients by cohort slot.  It threads through ``init_server_state`` and
checkpoint save/restore like every other server-state entry.
"""
from __future__ import annotations

from typing import Any, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.comm.codecs import GradientCodec
from repro.core.flat import LANES, FlatSpec

PyTree = Any


def init_comm_state(fed, spec: FlatSpec) -> PyTree:
    """Zero per-client error-feedback residuals in the comm-state layout."""
    return {"residual": tuple(
        jnp.zeros((fed.cohort, g.rows, LANES), jnp.float32)
        for g in spec.groups)}


def comm_bytes_per_client(codec: GradientCodec, spec: FlatSpec) -> int:
    """Measured uplink bytes ONE client ships per round under ``codec``
    (static python int — payload shapes/dtypes are trace-time constants)."""
    return sum(codec.payload_bytes(g) for g in spec.groups)


def client_coded_accumulate(codec: GradientCodec, spec: FlatSpec,
                            accs, g_bufs, w, residuals
                            ) -> Tuple[tuple, Optional[tuple]]:
    """One client's uplink across all dtype groups.

    accs/g_bufs: per-group (rows, LANES) fp32 accumulators / gradient;
    w: this client's normalized aggregation weight; residuals: per-group
    error-feedback memory or None.  Returns (new_accs, new_residuals).

    The decode always fuses straight into the aggregate FMA
    (``decode_fma`` — e.g. the int8 ``dequant_i8_fma_pass``); with EF the
    encode additionally emits the residual in its own sweep
    (``encode_ef``), so EF costs no extra HBM pass over the plain path.

    A client with w == 0 did not transmit — a straggler dropped by the
    participation mask (``repro.core.round``), or a zero-n_k client.  Its
    aggregate contribution is already zero, and its EF memory must stay
    UNCHANGED: overwriting it would discard the decoded part of the error
    as if the server had received it, breaking the EF telescoping for
    every dropped round.
    """
    new_accs, new_res = [], []
    if residuals is None:
        for group, acc, g in zip(spec.groups, accs, g_bufs):
            payload = codec.encode(group, g)
            new_accs.append(codec.decode_fma(group, acc, payload, w))
        return tuple(new_accs), None
    transmitted = (jnp.asarray(w, jnp.float32) > 0.0).astype(jnp.float32)
    for group, acc, g, res in zip(spec.groups, accs, g_bufs, residuals):
        payload, r_new = codec.encode_ef(group, g + res)
        new_accs.append(codec.decode_fma(group, acc, payload, w))
        new_res.append(transmitted * r_new + (1.0 - transmitted) * res)
    return tuple(new_accs), tuple(new_res)


def coded_aggregate_stacked(codec: GradientCodec, spec: FlatSpec,
                            g_groups, client_weights: jax.Array,
                            residuals: Optional[tuple]
                            ) -> Tuple[List[jax.Array], Optional[tuple]]:
    """The vmap executor's codec stage: per-client encode/decode over
    ALREADY-stacked ``(cohort, rows, LANES)`` gradient buffers, accumulated
    into the Eq. (14) weighted mean one client at a time.

    Returns (G_groups, new_residuals) — G_groups in the same layout
    ``repro.kernels.fused_update.ops.flat_weighted_aggregate`` produces
    (list of (rows, LANES) fp32), new_residuals stacked back to
    (cohort, rows, LANES) per group (or None without error feedback)."""
    from repro.core import flat as flat_mod           # lazy: import cycle
    w = client_weights.astype(jnp.float32)
    w = w / jnp.maximum(jnp.sum(w), 1e-30)

    def body(accs, xs):
        g_k, w_k, res_k = xs
        accs, r_new = client_coded_accumulate(codec, spec, accs, g_k, w_k,
                                              res_k)
        return accs, r_new

    acc0 = tuple(flat_mod.zeros_flat(spec))
    G, new_res = lax.scan(body, acc0, (tuple(g_groups), w, residuals))
    return list(G), new_res


def coded_decode_stacked(codec: GradientCodec, spec: FlatSpec,
                         g_groups, client_weights: jax.Array,
                         residuals: Optional[tuple]
                         ) -> Tuple[List[jax.Array], Optional[tuple]]:
    """The buffered-async executor's codec stage: encode/decode each
    client's delta INDIVIDUALLY, without aggregating — the async delta pool
    (``repro.core.async_round``) must store what the server actually
    received, because pooled deltas from different rounds are combined only
    at flush time with staleness-dependent weights unknown at encode time.

    Same per-client uplink as :func:`client_coded_accumulate` minus the
    FMA: with EF the payload is error-compensated against the client's
    ``state["comm"]`` slot, and a non-transmitting client (w == 0: masked
    out, crashed, or dropped by fault injection) keeps its residual
    byte-identical — the server received nothing, so no error was
    committed.

    Returns (decoded stacks — list of (cohort, rows, LANES) fp32 per dtype
    group — and new_residuals stacked per group, or None without EF)."""
    w = client_weights.astype(jnp.float32)

    def body(carry, xs):
        g_k, w_k, res_k = xs
        dec_k, res_out = [], []
        if res_k is None:
            for group, g in zip(spec.groups, g_k):
                dec_k.append(codec.decode(group, codec.encode(group, g)))
            return carry, (tuple(dec_k), None)
        transmitted = (jnp.asarray(w_k, jnp.float32) > 0.0
                       ).astype(jnp.float32)
        for group, g, res in zip(spec.groups, g_k, res_k):
            payload, r_new = codec.encode_ef(group, g + res)
            dec_k.append(codec.decode(group, payload))
            res_out.append(transmitted * r_new + (1.0 - transmitted) * res)
        return carry, (tuple(dec_k), tuple(res_out))

    _, (dec, new_res) = lax.scan(body, (), (tuple(g_groups), w, residuals))
    return list(dec), new_res

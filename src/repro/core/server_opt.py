"""Server-side optimizers (FedOpt family).

The aggregated quantity G is gradient-like: for UGA it is the *unbiased*
gradient Eq.(14); for FedAvg/FedProx it is the pseudo-gradient
(w_t - mean_k w_k) so that plain SGD with lr=1 reproduces vanilla FedAvg
parameter averaging exactly.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


def init_state(name: str, params: PyTree) -> PyTree:
    zeros = lambda: jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    if name == "sgd":
        return {}
    if name == "sgdm":
        return {"m": zeros()}
    if name in ("adam", "yogi"):
        return {"m": zeros(), "v": zeros(), "t": jnp.zeros((), jnp.int32)}
    raise ValueError(name)


def apply(name: str, state: PyTree, params: PyTree, grad: PyTree, lr,
          *, momentum: float = 0.9, b1: float = 0.9, b2: float = 0.99,
          eps: float = 1e-8) -> Tuple[PyTree, PyTree]:
    """Returns (new_params, new_state).  Math in fp32; params keep dtype."""
    g32 = jax.tree.map(lambda g: g.astype(jnp.float32), grad)

    def upd(p, d):
        return (p.astype(jnp.float32) - lr * d).astype(p.dtype)

    if name == "sgd":
        return jax.tree.map(upd, params, g32), state
    if name == "sgdm":
        m = jax.tree.map(lambda m, g: momentum * m + g, state["m"], g32)
        return jax.tree.map(upd, params, m), {"m": m}
    if name in ("adam", "yogi"):
        t = state["t"] + 1
        m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], g32)
        if name == "adam":
            v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g,
                             state["v"], g32)
        else:  # yogi
            v = jax.tree.map(
                lambda v, g: v - (1 - b2) * jnp.sign(v - g * g) * g * g,
                state["v"], g32)
        mh = jax.tree.map(lambda m: m / (1 - b1 ** t.astype(jnp.float32)), m)
        vh = jax.tree.map(lambda v: v / (1 - b2 ** t.astype(jnp.float32)), v)
        step = jax.tree.map(lambda m, v: m / (jnp.sqrt(v) + eps), mh, vh)
        return (jax.tree.map(upd, params, step),
                {"m": m, "v": v, "t": t})
    raise ValueError(name)

"""Tiny shared name->plugin registry behind the ClientAlgorithm /
CohortExecutor / ServerEngine registries (one implementation of the
duplicate-name check and the actionable unknown-name error)."""
from __future__ import annotations

from typing import Any, Dict


class Registry:
    def __init__(self, kind: str, register_hint: str):
        self._kind = kind            # e.g. "client algorithm"
        self._hint = register_hint   # e.g. "repro.core.algorithms.register_algorithm"
        self._items: Dict[str, Any] = {}

    def register(self, name: str, value: Any) -> Any:
        if name in self._items:
            raise ValueError(f"{self._kind} {name!r} already registered")
        self._items[name] = value
        return value

    def get(self, name: str) -> Any:
        try:
            return self._items[name]
        except KeyError:
            raise ValueError(
                f"unknown {self._kind} {name!r}; registered: "
                f"{self.names()} (register new ones with "
                f"{self._hint})") from None

    def names(self) -> tuple:
        return tuple(sorted(self._items))

"""Central registry of every constant rng fold tag in the repo.

Every headline reproducibility claim — participation streams invariant to
``rounds_per_call`` chunking, fault streams bit-reproducible under the run
seed, chunk-size-invariant aggregation — rests on the *same* derivation
discipline: a stream is separated from its siblings by folding a dedicated
constant out of a parent key (``jax.random.fold_in``).  Two streams folding
the SAME constant out of the same key are the same stream, which is exactly
the silent per-client weighting bias FedAgg (arXiv:2303.15799) shows
compounds across rounds.  This module is the single place those constants
live, so the collision is structurally impossible:

  * tags are declared once, here, and imported everywhere they are used
    (the ``fedlint`` static analyzer rejects inline constant tags — rule
    FL101 — and duplicate registry values — FL102);
  * :data:`TAGS` + the import-time uniqueness check below (and the
    ``tests/test_rngtags.py`` unit test) keep the registry collision-free;
  * the historical stream values are pinned bit-exact by a regression test,
    so centralizing the constants can never silently reseed a run.

Key lineage (who folds what out of what):

    run key (PRNGKey(seed))
      └─ round key  = fold_in(run_key, ROUND_OFFSET + round_idx)   [trainer]
           ├─ split -> (client key, meta key)                      [round]
           ├─ fold_in(round key, PARTICIPATION_FOLD)               [round]
           └─ fold_in(round key, FAULT_FOLD)                       [faults]
    client key (one row of split(client key, cohort))
      ├─ fold_in(client key, i)  for local step i < EVAL_FOLD      [client]
      └─ fold_in(client key, EVAL_FOLD)   gradient evaluation      [client]

Host-side numpy streams seed ``np.random.default_rng`` with tuples; their
dedicated components live here too (``META_SAMPLE_SEED``, ``SPEED_SEED``).
"""
from __future__ import annotations

import jax

__all__ = ["PARTICIPATION_FOLD", "FAULT_FOLD", "EVAL_FOLD", "ROUND_OFFSET",
           "META_SAMPLE_SEED", "SPEED_SEED", "TAGS", "round_key"]

# ---------------------------------------------------------------------------
# device-side fold tags (jax.random.fold_in off a jax PRNG key)
# ---------------------------------------------------------------------------
# participation mask: folded off the ROUND key, separate from the
# client/meta split so participation=1 keeps historical streams bit-exact
# (repro.core.round.participation_mask)
PARTICIPATION_FOLD = 0x5712A661

# client fault streams: folded off the ROUND key, separate from the
# participation fold and the client/meta split (repro.sim.faults)
FAULT_FOLD = 0x00FA0175

# gradient-evaluation rng of a client local update: folded off the CLIENT
# key, above any reachable local step index i (steps fold their loop index
# directly, so EVAL_FOLD doubles as the step-count ceiling)
# (repro.core.client)
EVAL_FOLD = 10_000

# per-round key derivation off the RUN key: round r uses
# fold_in(run_key, ROUND_OFFSET + r) — see :func:`round_key`
# (repro.core.trainer)
ROUND_OFFSET = 0

# ---------------------------------------------------------------------------
# host-side numpy seed-tuple components (np.random.default_rng((seed, TAG,
# ...)) — a dedicated component separates a host stream from its siblings)
# ---------------------------------------------------------------------------
# D_meta sampling stream: (seed, META_SAMPLE_SEED, round_idx), vs the
# cohort sampling stream's (seed, round_idx) (repro.data.pipeline)
META_SAMPLE_SEED = 7_777

# persistent heavy-tail client speeds: (seed, SPEED_SEED)
# (repro.sim.faults.heavy_tail_speeds)
SPEED_SEED = 0x5BEED

# ---------------------------------------------------------------------------
# registry + uniqueness
# ---------------------------------------------------------------------------
TAGS = {
    "PARTICIPATION_FOLD": PARTICIPATION_FOLD,
    "FAULT_FOLD": FAULT_FOLD,
    "EVAL_FOLD": EVAL_FOLD,
    "ROUND_OFFSET": ROUND_OFFSET,
    "META_SAMPLE_SEED": META_SAMPLE_SEED,
    "SPEED_SEED": SPEED_SEED,
}


def _check_unique() -> None:
    seen = {}
    for name, value in TAGS.items():
        if value in seen:
            raise ValueError(
                f"rng tag collision: {name} and {seen[value]} both use "
                f"{value:#x} — two streams folding the same constant out "
                "of the same key are the SAME stream (silent correlation "
                "bias); pick a fresh constant")
        seen[value] = name


_check_unique()


def round_key(key: jax.Array, round_idx) -> jax.Array:
    """The per-round key of round ``round_idx`` under run key ``key``.

    Every per-round stream — the client/meta split, the participation
    mask's fold, the fault streams' fold — derives from this one key, so
    the streams are invariant to how rounds are batched
    (``rounds_per_call`` chunking, async ticks, host-side retry
    recomputation)."""
    return jax.random.fold_in(key, ROUND_OFFSET + round_idx)

"""One federated round as a single jit-able SPMD program (Fig. 1):

    distribute -> local updating (UGA / FedAvg / FedProx)
                -> unbiased aggregation -> server optimizer -> FedMeta step.

``make_federated_round(model, fed)`` returns ``round_fn(state, cohort_batch,
meta_batch, client_weights, rng) -> (state, metrics)`` suitable for
``jax.jit`` with in/out shardings from ``repro.sharding``.

Two server-step engines (``fed.fused_update``):

  * legacy (False) — tree-map stages: ``weighted_mean`` -> clip-norm scale
    -> fp32 cast -> ``server_opt.apply`` — 5+ full-model traversals.
  * fused (True) — the flat-buffer Pallas engine
    (``repro.kernels.fused_update``): vmap cohorts reduce + ||G||^2 in one
    HBM pass over the gradient stack; scan cohorts stream the reduce as one
    FMA sweep per client (the scan carry IS the flat buffers); both finish
    with the clip + optimizer + param write pass.

``fed.meta_mode`` picks the FedMeta step: ``"post"`` (Eq. 20 parameter
step after aggregation, default) or ``"through_aggregation"`` (fused engine
only, vmap or scan cohorts: hypergradients of the D_meta loss through the
server step update a controllable per-client-weights + server-lr state —
see ``core/meta.py``).

``rounds_per_call=K`` wraps the round body in ``lax.scan`` so drivers
compile K rounds into ONE donated program and sync metrics to host once per
K rounds; the returned function then takes K-stacked inputs
``(cohort_batches (K, cohort, ...), meta_batches (K, ...),
client_weights (K, cohort), rngs (K, ...))`` and returns K-stacked metrics.
``rounds_per_call=1`` keeps the exact legacy signature.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import FedConfig
from repro.core import server_opt
from repro.core.aggregate import cohort_gradient, scan_cohort_gradient_flat
from repro.core.client import make_client_update
from repro.core.flat import make_flat_spec
from repro.core.meta import (meta_update, meta_update_through_aggregation,
                             meta_update_through_aggregation_scan)
from repro.kernels.fused_update.ops import (fused_apply_flat,
                                            fused_server_update,
                                            init_flat_opt_state)
from repro.models.model import Model

PyTree = Any


def resolve_server_lr(fed: FedConfig) -> float:
    """Effective eta_g.  FedAvg/FedProx pseudo-gradients are exact parameter
    averages only under *plain-SGD* with a unit step, so lr is forced to 1.0
    exactly there; every other combination — UGA (the paper's eta_g), or a
    FedOpt server optimizer (FedAdam/FedYogi/FedAvgM on pseudo-gradients) —
    honors ``fed.server_lr``."""
    if fed.algorithm == "uga" or fed.server_opt != "sgd":
        return fed.server_lr
    return 1.0


def init_server_state(model: Model, fed: FedConfig, key) -> PyTree:
    params = model.init(key)
    if fed.fused_update:
        opt = init_flat_opt_state(fed.server_opt, make_flat_spec(params))
    else:
        opt = server_opt.init_state(fed.server_opt, params)
    state = {
        "params": params,
        "opt": opt,
        "round": jnp.zeros((), jnp.int32),
    }
    if fed.meta and fed.meta_mode == "through_aggregation":
        # Controllable aggregation: per-client log weight multipliers and a
        # log server step size, meta-learned through the fused VJP.
        state["ctrl"] = {
            "w_logits": jnp.zeros((fed.cohort,), jnp.float32),
            "log_lr": jnp.log(jnp.float32(resolve_server_lr(fed))),
        }
    return state


def grad_global_norm(g: PyTree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(g)))


def make_federated_round(model: Model, fed: FedConfig, *,
                         spmd_axis_name=None, grad_shardings=None,
                         rounds_per_call: int = 1):
    """``spmd_axis_name``: mesh axes the cohort dimension is sharded over
    (client-parallel strategy) — forwarded to ``jax.vmap`` so per-client
    intermediates shard instead of replicate.  ``grad_shardings``: explicit
    NamedShardings for the stacked per-client gradients (cohort, *param) —
    prevents GSPMD from all-gathering per-client expert gradients before the
    weighted mean.  ``rounds_per_call``: scan K rounds into one program."""
    client_update = make_client_update(
        fed.algorithm, model.loss, local_steps=fed.local_steps,
        local_epochs=fed.local_epochs, prox_mu=fed.prox_mu,
        remat=fed.remat_local_steps)
    agg_dtype = jnp.dtype(fed.grad_agg_dtype)
    server_lr = resolve_server_lr(fed)
    through_agg = fed.meta and fed.meta_mode == "through_aggregation"
    if through_agg and not fed.fused_update:
        # FedConfig validates this too, but guard here for configs built
        # around __post_init__ (python -O, object.__setattr__): the legacy
        # tree-map branch has no ctrl hypergradient path, so tracing would
        # die on an undefined new_ctrl.
        raise ValueError(
            "meta_mode='through_aggregation' requires fused_update=True: "
            "the hypergradients flow through the fused engine's custom "
            "VJP; the legacy tree-map server step cannot update the "
            "'ctrl' slot. Set FedConfig(fused_update=True) or use "
            "meta_mode='post'.")
    if through_agg and grad_shardings is not None:
        raise ValueError(
            "meta_mode='through_aggregation' is unsupported with "
            "grad_shardings: sharded cohorts pre-aggregate per leaf, so "
            "per-client weight hypergradients are unavailable. Drop "
            "grad_shardings (vmap/scan cohorts both support "
            "through_aggregation) or use meta_mode='post'.")

    def one_round(state: PyTree, cohort_batch: PyTree, meta_batch: PyTree,
                  client_weights: jax.Array, rng: jax.Array
                  ) -> Tuple[PyTree, Dict[str, jax.Array]]:
        params = state["params"]
        r = state["round"].astype(jnp.float32)
        lr_c = fed.client_lr * (fed.lr_decay ** r)

        rng_c, rng_m = jax.random.split(rng)

        if fed.fused_update:
            meta_metrics = {}
            if fed.cohort_strategy == "scan" and grad_shardings is None:
                # Client-sequential cohort fusion: the scan carry is the
                # flat (rows, LANES) fp32 dtype-group buffers themselves —
                # K streaming Pallas FMAs (one per client), then the same
                # clip+optimizer+write pass.  No pytree-carry tree-maps,
                # no flatten round-trip of the aggregate.
                if through_agg:
                    (new_params, opt_state, gn_post, client_loss,
                     new_ctrl, meta_metrics) = \
                        meta_update_through_aggregation_scan(
                            model.loss, client_update, params, cohort_batch,
                            client_weights, lr_c, rng_c, state["opt"],
                            meta_batch, state["ctrl"], opt=fed.server_opt,
                            clip_norm=fed.clip_norm,
                            momentum=fed.server_momentum,
                            ctrl_lr=fed.ctrl_lr, rng=rng_m)
                else:
                    spec = make_flat_spec(params)
                    G_groups, client_loss = scan_cohort_gradient_flat(
                        client_update, params, cohort_batch, client_weights,
                        lr_c, rng_c, spec=spec)
                    new_params, opt_state, gn_post = fused_apply_flat(
                        params, G_groups, state["opt"], opt=fed.server_opt,
                        lr=server_lr, clip_norm=fed.clip_norm,
                        momentum=fed.server_momentum, spec=spec)
            else:
                if fed.cohort_strategy == "vmap" and grad_shardings is None:
                    g_stack, client_loss = cohort_gradient(
                        client_update, params, cohort_batch, client_weights,
                        lr_c, rng_c, strategy="vmap", agg_dtype=agg_dtype,
                        spmd_axis_name=spmd_axis_name, aggregate=False)
                    w_fused = client_weights
                else:
                    # Sharded cohorts (grad_shardings) keep the per-leaf
                    # weighted mean so its sharding constraints stay
                    # attached — the flat stack can't express them yet and
                    # GSPMD would all-gather the (cohort, *model) stack
                    # (the 37x HBM blow-up).  The fused engine still does
                    # clip+optimizer+write over the result.
                    G, client_loss = cohort_gradient(
                        client_update, params, cohort_batch, client_weights,
                        lr_c, rng_c, strategy=fed.cohort_strategy,
                        agg_dtype=agg_dtype, spmd_axis_name=spmd_axis_name,
                        grad_shardings=grad_shardings)
                    g_stack = jax.tree.map(lambda x: x[None], G)
                    w_fused = jnp.ones((1,), jnp.float32)
                if through_agg:
                    new_params, opt_state, gn_post, new_ctrl, meta_metrics \
                        = meta_update_through_aggregation(
                            model.loss, params, g_stack, w_fused,
                            state["opt"], meta_batch, state["ctrl"],
                            opt=fed.server_opt, clip_norm=fed.clip_norm,
                            momentum=fed.server_momentum,
                            ctrl_lr=fed.ctrl_lr, rng=rng_m)
                else:
                    new_params, opt_state, gn_post = fused_server_update(
                        params, g_stack, w_fused, state["opt"],
                        opt=fed.server_opt, lr=server_lr,
                        clip_norm=fed.clip_norm,
                        momentum=fed.server_momentum)
            # one metrics assembly for every fused arm: rounds_per_call
            # chunking (lax.scan) needs identical keys per config, so the
            # strategy/mode branches must not each grow their own dict
            metrics = {"client_loss": client_loss, "grad_norm": gn_post,
                       **meta_metrics}
        else:
            G, client_loss = cohort_gradient(
                client_update, params, cohort_batch, client_weights, lr_c,
                rng_c, strategy=fed.cohort_strategy, agg_dtype=agg_dtype,
                spmd_axis_name=spmd_axis_name, grad_shardings=grad_shardings)

            if fed.clip_norm > 0:
                gn = grad_global_norm(G)
                scale = jnp.minimum(1.0,
                                    fed.clip_norm / jnp.maximum(gn, 1e-9))
                G = jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                            ).astype(g.dtype), G)

            new_params, opt_state = server_opt.apply(
                fed.server_opt, state["opt"], params, G, server_lr,
                momentum=fed.server_momentum)
            metrics = {"client_loss": client_loss,
                       "grad_norm": grad_global_norm(G)}

        if fed.meta and not through_agg:
            lr_m = fed.meta_lr * (fed.lr_decay ** r)
            new_params, meta_loss = meta_update(
                model.loss, new_params, meta_batch, lr_m, rng_m)
            metrics["meta_loss"] = meta_loss

        new_state = {"params": new_params, "opt": opt_state,
                     "round": state["round"] + 1}
        if through_agg:
            new_state["ctrl"] = new_ctrl
        return new_state, metrics

    if rounds_per_call == 1:
        return one_round

    assert rounds_per_call > 1, rounds_per_call

    def round_fn(state: PyTree, cohort_batches: PyTree, meta_batches: PyTree,
                 client_weights: jax.Array, rngs: jax.Array
                 ) -> Tuple[PyTree, Dict[str, jax.Array]]:
        def body(st, xs):
            cb, mb, w, r = xs
            return one_round(st, cb, mb, w, r)

        return lax.scan(body, state,
                        (cohort_batches, meta_batches, client_weights, rngs))

    return round_fn


class RoundFnCache:
    """Jitted round programs keyed by chunk size, for drivers that mix
    full ``rounds_per_call`` chunks with a tail remainder — every driver
    shares this cache instead of re-implementing the per-k jit dict."""

    def __init__(self, model: Model, fed: FedConfig, *, donate: bool = True,
                 **round_kwargs):
        self._make = lambda k: make_federated_round(
            model, fed, rounds_per_call=k, **round_kwargs)
        self._donate = donate
        self._fns: Dict[int, Any] = {}

    def __call__(self, k: int):
        if k not in self._fns:
            self._fns[k] = jax.jit(
                self._make(k),
                donate_argnums=(0,) if self._donate else ())
        return self._fns[k]


def stack_round_inputs(cohort_batches, meta_batches, client_weights, rngs):
    """K per-round host samples -> the K-stacked device inputs of a
    ``rounds_per_call=K`` round_fn (leaves gain a leading K axis)."""
    stack = lambda *xs: jnp.stack([jnp.asarray(x) for x in xs])
    return (jax.tree.map(stack, *cohort_batches),
            jax.tree.map(stack, *meta_batches),
            stack(*client_weights),
            stack(*rngs))

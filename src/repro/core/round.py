"""One federated round as a single jit-able SPMD program (Fig. 1), built by
COMPOSING three plugin registries instead of a hand-wired branch tree:

    distribute -> local updating   (ClientAlgorithm registry,
                                    repro.core.algorithms: uga / fedavg /
                                    fedprox / fednova / yours)
               -> uplink codec     (GradientCodec registry, repro.comm:
                                    none / int8 / sign1bit / topk, with
                                    optional per-client error feedback in
                                    state["comm"] — the lossy-transport
                                    simulation, post-meta-mode only)
               -> unbiased aggregation (CohortExecutor registry,
                                    repro.core.executors: vmap / scan /
                                    sharded -> a uniform aggregate handle)
               -> server update    (ServerEngine registry,
                                    repro.core.engines: legacy_tree /
                                    fused_flat, with declared
                                    meta_capabilities)
               -> FedMeta step     (core/meta.py: "post" Eq. 20, or
                                    "through_aggregation" hypergradients if
                                    the engine declares the capability).

``make_federated_round(model, fed)`` returns ``round_fn(state, cohort_batch,
meta_batch, client_weights, rng) -> (state, metrics)`` suitable for
``jax.jit`` with in/out shardings from ``repro.sharding``.  The executor
and engine are resolved from ``fed`` (``cohort_strategy``, ``fused_update``,
``grad_shardings``) or overridden by registry name via the ``algorithm`` /
``executor`` / ``engine`` keywords; every supported combination is
numerically identical to the pre-registry (PR 3) paths (equivalence-matrix
tested).

Partial participation / straggler dropout: ``fed.participation < 1`` draws
a per-round Bernoulli mask over the cohort and zeroes dropped clients'
aggregation weights — inside the existing weighted-mean / fused-accumulate
math, so every executor and engine supports it unchanged (a w=0 client
contributes nothing to Eq. 14 and the surviving weights renormalize).

``rounds_per_call=K`` wraps the round body in ``lax.scan`` so drivers
compile K rounds into ONE donated program and sync metrics to host once per
K rounds; the returned function then takes K-stacked inputs
``(cohort_batches (K, cohort, ...), meta_batches (K, ...),
client_weights (K, cohort), rngs (K, ...))`` and returns K-stacked metrics.
``rounds_per_call=1`` keeps the exact legacy signature.  Drivers should not
call this module directly any more — :class:`repro.core.trainer.
FederatedTrainer` owns the jit cache, chunked sampling, checkpoint/resume
and history assembly.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import FedConfig
from repro.core.algorithms import get_algorithm
from repro.core.engines import resolve_engine, tree_global_norm
from repro.core.executors import resolve_executor
from repro.core.flat import flatten_tree, make_flat_spec
from repro.core.meta import meta_update, meta_update_through_cohort
from repro.core.rngtags import PARTICIPATION_FOLD
from repro.core.sanitize import (check_flat_groups, checkify_round,
                                 throw_if_error)
from repro.models.model import Model
from repro.sim.faults import client_failed_mask, fault_streams, resolve_faults

PyTree = Any


def resolve_server_lr(fed: FedConfig) -> float:
    """Effective eta_g.  Algorithms registered with
    ``pseudo_gradient=True`` (fedavg/fedprox) produce parameter deltas that
    are exact parameter averages only under *plain-SGD* with a unit step,
    so lr is forced to 1.0 exactly there; every other combination — a true-
    gradient algorithm (UGA's eta_g, FedNova's normalized direction) or a
    FedOpt server optimizer (FedAdam/FedYogi/FedAvgM on pseudo-gradients) —
    honors ``fed.server_lr``."""
    if not get_algorithm(fed.algorithm).pseudo_gradient \
            or fed.server_opt != "sgd":
        return fed.server_lr
    return 1.0


def init_server_state(model: Model, fed: FedConfig, key, *,
                      engine: Optional[str] = None) -> PyTree:
    params = model.init(key)
    eng = resolve_engine(fed, engine=engine)
    state = {
        "params": params,
        "opt": eng.init_state(params),
        "round": jnp.zeros((), jnp.int32),
    }
    if fed.meta and fed.meta_mode == "through_aggregation":
        # Controllable aggregation: per-client log weight multipliers and a
        # log server step size, meta-learned through the engine's VJP.
        state["ctrl"] = {
            "w_logits": jnp.zeros((fed.cohort,), jnp.float32),
            "log_lr": jnp.log(jnp.float32(resolve_server_lr(fed))),
        }
    # lazy: repro.comm imports repro.core.flat, which triggers this package
    from repro.comm import init_comm_state, resolve_codec
    if fed.error_feedback and resolve_codec(fed).lossy:
        # Per-client compression residuals (repro.comm): zero EF memory per
        # cohort slot, threaded through checkpoints exactly like ctrl.
        state["comm"] = init_comm_state(fed, make_flat_spec(params))
    if getattr(eng, "is_async", False):
        # Buffered-async delta pool + staleness counters: part of server
        # state, so checkpoints capture a mid-run pool bit-exactly.
        from repro.core.async_round import init_async_state
        state["async"] = init_async_state(fed, make_flat_spec(params))
    return state


# back-compat name (pre-registry callers import it from here)
grad_global_norm = tree_global_norm


def participation_mask(rng: jax.Array, cohort: int, rate: float) -> jax.Array:
    """Per-round straggler mask: keep each client with prob ``rate``.
    Derived from a fold of the round rng so enabling participation never
    perturbs the client/meta rng streams.  An all-zero draw (every client
    dropped) is legal: the round program guards the server step with
    ``stepped = sum(weights) > 0`` and leaves params/opt/ctrl bit-unchanged
    for that round — the old silent fall-back to full participation
    over-trained exactly when the fleet was at its flakiest."""
    keep = jax.random.bernoulli(jax.random.fold_in(rng, PARTICIPATION_FOLD),
                                p=rate, shape=(cohort,))
    return keep.astype(jnp.float32)


def make_federated_round(model: Model, fed: FedConfig, *,
                         spmd_axis_name=None, grad_shardings=None,
                         rounds_per_call: int = 1,
                         algorithm: Optional[str] = None,
                         executor: Optional[str] = None,
                         engine: Optional[str] = None,
                         sanitize: bool = False):
    """Compose (algorithm, executor, engine) into one round program.

    ``spmd_axis_name``: mesh axes the cohort dimension is sharded over
    (client-parallel strategy) — forwarded to ``jax.vmap`` so per-client
    intermediates shard instead of replicate.  ``grad_shardings``: explicit
    NamedShardings for the stacked per-client gradients (cohort, *param) —
    selects the sharded executor, which keeps the per-leaf weighted mean so
    GSPMD never all-gathers the stack.  ``rounds_per_call``: scan K rounds
    into one program.  ``algorithm`` / ``executor`` / ``engine``: registry
    names overriding the ``fed``-derived defaults (``fed.algorithm``,
    ``fed.cohort_strategy`` + shardings, ``fed.fused_update``).
    ``sanitize``: plant :func:`repro.core.sanitize.check_flat_groups`
    probes on the post-round flat parameter buffers (and, async, on the
    decoded per-client deltas); inert unless the round program is
    transformed by :func:`repro.core.sanitize.checkify_round` — which
    :class:`RoundFnCache` does when built with ``sanitize=True``."""
    eng_probe = resolve_engine(fed, engine=engine)
    if getattr(eng_probe, "is_async", False):
        # Asynchronous engines replace the whole round SHAPE, not just the
        # server apply: route to the buffered-async tick program, which
        # shares one_round's signature so chunking below reuses unchanged.
        if grad_shardings is not None:
            raise ValueError(
                "engine='buffered_async' keeps a replicated delta pool "
                "(per-client staleness slots), so per-leaf grad_shardings "
                "cannot apply; drop grad_shardings or use a synchronous "
                "engine")
        from repro.core.async_round import make_async_tick
        return _chunk_rounds(
            make_async_tick(model, fed, algorithm=algorithm,
                            executor=executor, engine=engine,
                            spmd_axis_name=spmd_axis_name,
                            sanitize=sanitize),
            rounds_per_call)

    faults = resolve_faults(fed)
    if faults.garble > 0:
        if getattr(fed, "fault_garble", -1.0) >= 0:
            raise ValueError(
                f"fault_garble={fed.fault_garble} needs "
                "engine='buffered_async': payload corruption acts on the "
                "pooled per-client deltas, which only the async runtime "
                "models — synchronous engines see faults at the "
                "aggregation-weight level (drop/crash/timeout). Use the "
                "buffered_async engine or drop fault_garble.")
        # profile-carried garble (e.g. fault_profile='flaky') downgrades
        # silently on sync engines: the profile describes the fleet, and
        # the sync barrier simply cannot observe payload corruption
        faults = dataclasses.replace(faults, garble=0.0)

    alg = get_algorithm(algorithm if algorithm is not None
                        else fed.algorithm)
    client_update = alg.build(
        model.loss, local_steps=fed.local_steps,
        local_epochs=fed.local_epochs, prox_mu=fed.prox_mu,
        remat=fed.remat_local_steps)
    exe = resolve_executor(fed, spmd_axis_name=spmd_axis_name,
                           grad_shardings=grad_shardings, executor=executor)
    eng = eng_probe

    kinds = exe.produces & eng.accepts
    if not kinds:
        raise ValueError(
            f"cohort executor {exe.name!r} produces {sorted(exe.produces)} "
            f"but server engine {eng.name!r} accepts {sorted(eng.accepts)}: "
            "no common aggregate-handle kind")
    kind = eng.preferred if eng.preferred in kinds else next(iter(kinds))

    server_lr = resolve_server_lr(fed)
    through_agg = fed.meta and fed.meta_mode == "through_aggregation"
    if through_agg and "through_aggregation" not in eng.meta_capabilities:
        # FedConfig validates this too, but re-check against the resolved
        # engine for configs built around __post_init__ (python -O,
        # object.__setattr__) and for registry-selected engines: without
        # the capability there is no ctrl hypergradient path.
        raise ValueError(
            f"meta_mode='through_aggregation' needs a server engine "
            f"declaring the 'through_aggregation' capability, but "
            f"{eng.name!r} declares {sorted(eng.meta_capabilities)}: the "
            "hypergradients flow through the fused engine's custom VJP. "
            "Set FedConfig(fused_update=True) (the fused_flat engine) or "
            "use meta_mode='post'.")
    if through_agg and not exe.supports_reweight:
        raise ValueError(
            f"meta_mode='through_aggregation' needs a cohort executor that "
            f"supports reweightable aggregation, but {exe.name!r} does "
            "not. Every built-in synchronous executor (vmap/scan/chunked "
            "and the two-tier sharded topology) supports it; only "
            "custom executors without a reweightable form and the async "
            "delta pool lack the per-client weight hypergradients. Use "
            "one of those executors or meta_mode='post'.")

    # lazy: repro.comm imports repro.core.flat, which triggers this package
    from repro.comm import comm_bytes_per_client, resolve_codec
    codec = resolve_codec(fed)
    lossy_codec = codec.lossy
    if lossy_codec:
        # FedConfig validates the built-in combinations too, but re-check
        # against the RESOLVED plugins (registry-name overrides, custom
        # executors/engines) so a lossy codec never silently runs a path
        # that drops the compression or differentiates through it.
        if through_agg:
            raise ValueError(
                f"codec={fed.codec!r} with "
                "meta_mode='through_aggregation' would differentiate "
                "through a non-differentiable quantizer (the hypergradient "
                "would silently treat the decoded gradients as exact). "
                "Lossy codecs are meta_mode='post' only for now — a "
                "straight-through codec VJP is a ROADMAP follow-up. Use "
                "meta_mode='post' or codec='none'.")
        if "lossy" not in exe.codec_capabilities:
            raise ValueError(
                f"codec={fed.codec!r} needs a cohort executor declaring "
                f"the 'lossy' codec capability, but {exe.name!r} declares "
                f"{sorted(exe.codec_capabilities)}. Every built-in "
                "executor (vmap/scan/chunked/sharded and the async delta "
                "pool) streams a per-client uplink and declares 'lossy'; "
                "a custom executor that pre-aggregates before the uplink "
                "cannot compress per client. Use one of the built-in "
                "executors or codec='none'.")
        if "lossy" not in eng.codec_capabilities:
            raise ValueError(
                f"codec={fed.codec!r} needs a server engine declaring the "
                f"'lossy' codec capability, but {eng.name!r} declares "
                f"{sorted(eng.codec_capabilities)}: lossy codecs decode "
                "into the flat dtype-group buffers the fused engine "
                "consumes. Set FedConfig(fused_update=True) (the "
                "fused_flat engine) or use codec='none'.")
    use_ef = lossy_codec and fed.error_feedback

    def one_round(state: PyTree, cohort_batch: PyTree, meta_batch: PyTree,
                  client_weights: jax.Array, rng: jax.Array
                  ) -> Tuple[PyTree, Dict[str, jax.Array]]:
        params = state["params"]
        r = state["round"].astype(jnp.float32)
        lr_c = fed.client_lr * (fed.lr_decay ** r)

        # NOTE: the 2-way split below is load-bearing for reproducibility —
        # the participation mask folds out of ``rng`` separately so that
        # participation=1 configs keep the exact historical rng streams.
        rng_c, rng_m = jax.random.split(rng)
        part_metrics = {}
        if fed.participation < 1.0:
            mask = participation_mask(rng, client_weights.shape[0],
                                      fed.participation)
            client_weights = client_weights * mask
            part_metrics = {"participants": jnp.sum(mask)}

        if faults.active:
            # crash/drop (and, past the round deadline, straggling) zero a
            # client's aggregation weight — inside the existing weighted
            # mean, so every executor/engine handles faults unchanged, and
            # (with EF codecs) a failed client's residual slot freezes
            fs = fault_streams(rng, client_weights.shape[0], faults)
            failed = client_failed_mask(fs, faults)
            client_weights = client_weights * (~failed).astype(jnp.float32)
            part_metrics = {
                **part_metrics,
                "arrivals": jnp.sum((client_weights > 0).astype(
                    jnp.float32)),
                "fault_crashed": jnp.sum(fs.crashed.astype(jnp.float32)),
                "fault_dropped": jnp.sum(fs.dropped.astype(jnp.float32)),
            }
            if faults.deadline > 0:
                late = ((fs.latency + fs.delay.astype(jnp.float32))
                        > faults.deadline)
                part_metrics["fault_timeout"] = jnp.sum(
                    late.astype(jnp.float32))

        meta_metrics = {}
        comm_metrics = {}
        new_comm = None
        if through_agg:
            rw = exe.reweightable(client_update, params, cohort_batch,
                                  client_weights, lr_c, rng_c)
            (new_params, opt_state, gn_post, client_loss, new_ctrl,
             meta_metrics) = meta_update_through_cohort(
                model.loss, rw, client_weights, params, state["opt"],
                meta_batch, state["ctrl"], engine=eng,
                ctrl_lr=fed.ctrl_lr, rng=rng_m)
        elif lossy_codec:
            handle, client_loss, new_comm = exe.run_coded(
                client_update, params, cohort_batch, client_weights, lr_c,
                rng_c, codec=codec, comm=state.get("comm"))
            new_params, opt_state, gn_post = eng.apply(
                params, handle, state["opt"], lr=server_lr)
            # measured uplink bytes: per-client payload size (static — the
            # codec's transport shapes) times the clients that reported
            bytes_pc = comm_bytes_per_client(codec, make_flat_spec(params))
            n_up = part_metrics.get(
                "participants", jnp.float32(client_weights.shape[0]))
            comm_metrics = {"comm_bytes": jnp.float32(bytes_pc) * n_up}
        else:
            handle, client_loss = exe.run(
                client_update, params, cohort_batch, client_weights, lr_c,
                rng_c, kind=kind)
            new_params, opt_state, gn_post = eng.apply(
                params, handle, state["opt"], lr=server_lr)

        # one metrics assembly for every arm: rounds_per_call chunking
        # (lax.scan) needs identical keys per config, so the executor/
        # engine/mode combinations must not each grow their own dict
        metrics = {"client_loss": client_loss, "grad_norm": gn_post,
                   **part_metrics, **meta_metrics, **comm_metrics}

        if fed.meta and not through_agg:
            lr_m = fed.meta_lr * (fed.lr_decay ** r)
            new_params, meta_loss = meta_update(
                model.loss, new_params, meta_batch, lr_m, rng_m)
            metrics["meta_loss"] = meta_loss

        new_state = {"params": new_params, "opt": opt_state,
                     "round": state["round"] + 1}
        if through_agg:
            new_state["ctrl"] = new_ctrl
        if use_ef:
            new_state["comm"] = new_comm

        if fed.participation < 1.0 or faults.active:
            # Degradation policy: a round whose entire cohort failed (mask
            # or faults) must be a no-op server step — params/opt/ctrl/comm
            # stay bit-identical (where(True, x, _) is a bitwise identity,
            # so surviving rounds are untouched).  Only the round counter
            # advances.  Metric keys stay fixed for lax.scan chunking; the
            # degenerate round's loss/norm values are gated to 0.
            stepped = jnp.sum(client_weights) > 0.0
            new_state = {
                k: (v if k == "round"
                    else jax.tree.map(
                        lambda a, b: jnp.where(stepped, a, b), v, state[k]))
                for k, v in new_state.items()}
            for mk in ("client_loss", "grad_norm", "meta_loss"):
                if mk in metrics:
                    metrics[mk] = jnp.where(stepped, metrics[mk], 0.0)
        if sanitize:
            spec = make_flat_spec(params)
            check_flat_groups(
                spec, flatten_tree(spec, new_state["params"]),
                "post-round server params (sync round)")
        return new_state, metrics

    return _chunk_rounds(one_round, rounds_per_call)


def _chunk_rounds(one_round, rounds_per_call: int):
    """Shared ``rounds_per_call`` wrapper (sync rounds AND async ticks):
    scan K rounds into one donated program over K-stacked inputs."""
    if rounds_per_call == 1:
        return one_round

    assert rounds_per_call > 1, rounds_per_call

    def round_fn(state: PyTree, cohort_batches: PyTree, meta_batches: PyTree,
                 client_weights: jax.Array, rngs: jax.Array
                 ) -> Tuple[PyTree, Dict[str, jax.Array]]:
        def body(st, xs):
            cb, mb, w, r = xs
            return one_round(st, cb, mb, w, r)

        return lax.scan(body, state,
                        (cohort_batches, meta_batches, client_weights, rngs))

    return round_fn


class RoundFnCache:
    """Jitted round programs keyed by chunk size, for drivers that mix
    full ``rounds_per_call`` chunks with a tail remainder — every driver
    shares this cache instead of re-implementing the per-k jit dict.

    ``sanitize=True`` jits each program under
    :func:`repro.core.sanitize.checkify_round` and raises the checkified
    error host-side after every call, so a NaN
    fires the round it appears with the planted probes' message instead of
    poisoning later rounds silently."""

    def __init__(self, model: Model, fed: FedConfig, *, donate: bool = True,
                 sanitize: bool = False, **round_kwargs):
        self._make = lambda k: make_federated_round(
            model, fed, rounds_per_call=k, sanitize=sanitize,
            **round_kwargs)
        self._donate = donate
        self._sanitize = sanitize
        self._fns: Dict[int, Any] = {}

    def __call__(self, k: int):
        if k not in self._fns:
            donate = (0,) if self._donate else ()
            if self._sanitize:
                # checkify_round keeps the positional signature (the error
                # value is an extra OUTPUT), so state stays argnum 0
                jitted = jax.jit(checkify_round(self._make(k)),
                                 donate_argnums=donate)

                def checked(*args, _fn=jitted):
                    err, out = _fn(*args)
                    throw_if_error(err)
                    return out

                self._fns[k] = checked
            else:
                self._fns[k] = jax.jit(self._make(k), donate_argnums=donate)
        return self._fns[k]


def stack_round_inputs(cohort_batches, meta_batches, client_weights, rngs):
    """K per-round host samples -> the K-stacked device inputs of a
    ``rounds_per_call=K`` round_fn (leaves gain a leading K axis)."""
    stack = lambda *xs: jnp.stack([jnp.asarray(x) for x in xs])
    return (jax.tree.map(stack, *cohort_batches),
            jax.tree.map(stack, *meta_batches),
            stack(*client_weights),
            stack(*rngs))

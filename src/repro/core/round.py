"""One federated round as a single jit-able SPMD program (Fig. 1):

    distribute -> local updating (UGA / FedAvg / FedProx)
                -> unbiased aggregation -> server optimizer -> FedMeta step.

``make_federated_round(model, fed)`` returns ``round_fn(state, cohort_batch,
meta_batch, client_weights, rng) -> (state, metrics)`` suitable for
``jax.jit`` with in/out shardings from ``repro.sharding``.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import FedConfig
from repro.core import server_opt
from repro.core.aggregate import cohort_gradient
from repro.core.client import make_client_update
from repro.core.meta import meta_update
from repro.models.model import Model

PyTree = Any


def init_server_state(model: Model, fed: FedConfig, key) -> PyTree:
    params = model.init(key)
    return {
        "params": params,
        "opt": server_opt.init_state(fed.server_opt, params),
        "round": jnp.zeros((), jnp.int32),
    }


def grad_global_norm(g: PyTree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(g)))


def make_federated_round(model: Model, fed: FedConfig, *,
                         spmd_axis_name=None, grad_shardings=None):
    """``spmd_axis_name``: mesh axes the cohort dimension is sharded over
    (client-parallel strategy) — forwarded to ``jax.vmap`` so per-client
    intermediates shard instead of replicate.  ``grad_shardings``: explicit
    NamedShardings for the stacked per-client gradients (cohort, *param) —
    prevents GSPMD from all-gathering per-client expert gradients before the
    weighted mean."""
    client_update = make_client_update(
        fed.algorithm, model.loss, local_steps=fed.local_steps,
        prox_mu=fed.prox_mu, remat=fed.remat_local_steps)
    agg_dtype = jnp.dtype(fed.grad_agg_dtype)

    # FedAvg pseudo-gradients are exact parameter averages only with a unit
    # server step; UGA uses the paper's eta_g.
    server_lr = fed.server_lr if fed.algorithm == "uga" else 1.0

    def round_fn(state: PyTree, cohort_batch: PyTree, meta_batch: PyTree,
                 client_weights: jax.Array, rng: jax.Array
                 ) -> Tuple[PyTree, Dict[str, jax.Array]]:
        params = state["params"]
        r = state["round"].astype(jnp.float32)
        lr_c = fed.client_lr * (fed.lr_decay ** r)

        rng_c, rng_m = jax.random.split(rng)
        G, client_loss = cohort_gradient(
            client_update, params, cohort_batch, client_weights, lr_c,
            rng_c, strategy=fed.cohort_strategy, agg_dtype=agg_dtype,
            spmd_axis_name=spmd_axis_name, grad_shardings=grad_shardings)

        if fed.clip_norm > 0:
            gn = grad_global_norm(G)
            scale = jnp.minimum(1.0, fed.clip_norm / jnp.maximum(gn, 1e-9))
            G = jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                        ).astype(g.dtype), G)

        new_params, opt_state = server_opt.apply(
            fed.server_opt, state["opt"], params, G, server_lr,
            momentum=fed.server_momentum)

        metrics = {"client_loss": client_loss, "grad_norm": grad_global_norm(G)}
        if fed.meta:
            lr_m = fed.meta_lr * (fed.lr_decay ** r)
            new_params, meta_loss = meta_update(
                model.loss, new_params, meta_batch, lr_m, rng_m)
            metrics["meta_loss"] = meta_loss

        new_state = {"params": new_params, "opt": opt_state,
                     "round": state["round"] + 1}
        return new_state, metrics

    return round_fn

"""Client local-update strategies — the heart of the paper.

Every strategy maps (loss_fn, w_t, client_batch, lr, rng) -> G_k, a
gradient-like pytree aggregated by the server:

  * ``uga_update``     — §3.1: keep-trace gradient descent for the first
    S-1 steps (the whole local SGD trajectory stays inside the autodiff
    trace) followed by gradient *evaluation* of the final parameters on the
    full client batch, differentiated w.r.t. the INITIAL parameters w_t.
    All G_k are derivatives of the same w_t => unbiased aggregation Eq.(14).

  * ``fedavg_update``  — vanilla local SGD; G_k = w_t - w_k^final is the
    pseudo-gradient (server SGD with lr=1 == exact FedAvg averaging).

  * ``fedprox_update`` — fedavg + proximal term mu/2 ||w - w_t||^2 on every
    local step (Li et al., 2018).

The microbatch schedule: the client batch (b, ...) is split into
``local_steps`` microbatches along the example axis and cycled for
``local_epochs`` passes, matching the paper's B/E notation.  UGA consumes
the first (epochs*steps - 1) microbatches with keep-trace SGD and evaluates
on the WHOLE client batch (the paper evaluates on the full local data D_k).
"""
from __future__ import annotations

from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.rngtags import EVAL_FOLD

PyTree = Any
# loss_fn(params, batch, rng) -> (scalar_loss, metrics)
LossFn = Callable[..., Tuple[jax.Array, Any]]


def _split_microbatches(batch: PyTree, steps: int) -> PyTree:
    """(b, ...) leaves -> (steps, b//steps, ...)."""
    def rs(x):
        b = x.shape[0]
        assert b % steps == 0, f"client batch {b} not divisible by {steps} steps"
        return x.reshape((steps, b // steps) + x.shape[1:])
    return jax.tree.map(rs, batch)


def _microbatch_at(mbs: PyTree, i, steps: int) -> PyTree:
    """Microbatch for global step ``i``, cycling the schedule over epochs.
    Dynamic-indexing ``i % steps`` inside the scan replaces the old
    ``jnp.tile`` epoch expansion, which materialized ``epochs`` HBM copies
    of every client batch (equality with the tiled path property-tested)."""
    return jax.tree.map(lambda x: x[i % steps], mbs)


def _sgd_steps(loss_fn: LossFn, w, mbs, lr, rng, *, prox_mu: float = 0.0,
               w_ref: Optional[PyTree] = None, remat: bool = True,
               n_steps: Optional[int] = None):
    """Run SGD for ``n_steps`` (default: one pass) cycling the microbatch
    schedule ``mbs`` (leaves (steps, b, ...)).  Differentiable (keep-trace)
    by construction — functional updates never leave the autodiff trace."""
    steps = jax.tree.leaves(mbs)[0].shape[0]
    if n_steps is None:
        n_steps = steps

    def step(w, i):
        mb = _microbatch_at(mbs, i, steps)
        step_rng = jax.random.fold_in(rng, i) if rng is not None else None

        def local_loss(wi):
            l, _ = loss_fn(wi, mb, step_rng)
            if prox_mu > 0.0 and w_ref is not None:
                sq = sum(jnp.sum(jnp.square(a.astype(jnp.float32) -
                                            b.astype(jnp.float32)))
                         for a, b in zip(jax.tree.leaves(wi),
                                         jax.tree.leaves(w_ref)))
                l = l + 0.5 * prox_mu * sq
            return l

        g = jax.grad(local_loss)(w)
        w = jax.tree.map(lambda p, gi: (p.astype(jnp.float32)
                                        - lr * gi.astype(jnp.float32)
                                        ).astype(p.dtype), w, g)
        return w, None

    body = jax.checkpoint(step, prevent_cse=False) if remat else step
    w, _ = lax.scan(body, w, jnp.arange(n_steps))
    return w


def uga_update(loss_fn: LossFn, w_t: PyTree, batch: PyTree, lr, rng=None, *,
               local_steps: int = 2, local_epochs: int = 1,
               remat: bool = True) -> Tuple[PyTree, jax.Array]:
    """Unbiased gradient aggregation client update (Algorithm 1) —
    memory-optimal form.

    The keep-trace gradient g_k = grad_{w_t} L(h_k(w_t); D_k) is computed as
    an explicit reverse sweep over the local SGD trajectory with
    Hessian-vector products:

        w_{i+1} = w_i - lr * g_i(w_i)                      (forward, saved w_i)
        v_S     = grad L(w_S; D_k)                         (gradient evaluation)
        v_i     = v_{i+1} - lr * H_i(w_i) v_{i+1}          (reverse, HVP)

    Each HVP is a jvp-of-grad (forward-over-reverse) — one gradient pass of
    memory, no reverse-over-reverse residual stacking.  This is EXACTLY the
    same mathematics as differentiating the keep-trace trajectory (the
    autodiff form is kept as ``uga_update_autodiff`` and equality is
    property-tested); it cut the dry-run HBM footprint ~40x (§Perf it. 1).

    Returns (g_k, eval_loss)."""
    n_kt = local_steps * local_epochs - 1          # keep-trace steps
    mbs = _split_microbatches(batch, local_steps)
    eval_rng = jax.random.fold_in(rng, EVAL_FOLD) if rng is not None else None

    def local_loss(w, mb, i):
        step_rng = jax.random.fold_in(rng, i) if rng is not None else None
        return loss_fn(w, mb, step_rng)[0]

    if n_kt == 0:
        eval_loss, g = jax.value_and_grad(
            lambda w: loss_fn(w, batch, eval_rng)[0])(w_t)
        return g, eval_loss

    # ---- forward: local SGD, saving the pre-step parameters ----
    def fstep(w, i):
        mb = _microbatch_at(mbs, i, local_steps)
        g = jax.grad(local_loss)(w, mb, i)
        w_next = jax.tree.map(
            lambda p, gi: (p.astype(jnp.float32)
                           - lr * gi.astype(jnp.float32)).astype(p.dtype),
            w, g)
        return w_next, w

    fbody = jax.checkpoint(fstep, prevent_cse=False) if remat else fstep
    w_k, ws = lax.scan(fbody, w_t, jnp.arange(n_kt))

    # ---- gradient evaluation on the WHOLE client batch (last epoch) ----
    eval_loss, v = jax.value_and_grad(
        lambda w: loss_fn(w, batch, eval_rng)[0])(w_k)
    v = jax.tree.map(lambda x: x.astype(jnp.float32), v)

    # ---- reverse: v <- v - lr * H v via jvp-of-grad ----
    def bstep(v, inp):
        w_i, i = inp
        mb = _microbatch_at(mbs, i, local_steps)

        def gfun(w):
            return jax.grad(local_loss)(w, mb, i)

        tangent = jax.tree.map(lambda p, t: t.astype(p.dtype), w_i, v)
        hvp = jax.jvp(gfun, (w_i,), (tangent,))[1]
        v = jax.tree.map(
            lambda a, h: a - lr * h.astype(jnp.float32), v, hvp)
        return v, None

    bbody = jax.checkpoint(bstep, prevent_cse=False) if remat else bstep
    g_k, _ = lax.scan(bbody, v, (ws, jnp.arange(n_kt)), reverse=True)
    return g_k, eval_loss


def uga_update_autodiff(loss_fn: LossFn, w_t: PyTree, batch: PyTree, lr,
                        rng=None, *, local_steps: int = 2,
                        local_epochs: int = 1, remat: bool = True
                        ) -> Tuple[PyTree, jax.Array]:
    """Reference form of UGA: let autodiff differentiate straight through the
    keep-trace trajectory.  Identical math to ``uga_update`` (tested); kept
    as the oracle because it is line-for-line the paper's Algorithm 1."""
    n_kt = local_steps * local_epochs - 1
    mbs = _split_microbatches(batch, local_steps)

    def traced_objective(w0):
        if n_kt > 0:
            w_k = _sgd_steps(loss_fn, w0, mbs, lr, rng, remat=remat,
                             n_steps=n_kt)
        else:
            w_k = w0
        eval_rng = (jax.random.fold_in(rng, EVAL_FOLD)
                    if rng is not None else None)
        l, _ = loss_fn(w_k, batch, eval_rng)       # gradient evaluation
        return l

    eval_loss, g_k = jax.value_and_grad(traced_objective)(w_t)
    return g_k, eval_loss


def fedavg_update(loss_fn: LossFn, w_t: PyTree, batch: PyTree, lr, rng=None, *,
                  local_steps: int = 2, local_epochs: int = 1,
                  prox_mu: float = 0.0, remat: bool = True
                  ) -> Tuple[PyTree, jax.Array]:
    """Vanilla FedAvg (optionally FedProx) local update.

    Returns (pseudo_grad, final_loss); pseudo_grad = w_t - w_k.  The local
    trajectory is explicitly cut from the trace (stop_gradient) — this IS
    the biased path the paper analyses in §2.1."""
    mbs = _split_microbatches(batch, local_steps)
    w_k = _sgd_steps(loss_fn, w_t, mbs, lr, rng, prox_mu=prox_mu,
                     w_ref=w_t, remat=remat,
                     n_steps=local_steps * local_epochs)
    w_k = jax.lax.stop_gradient(w_k)
    l, _ = loss_fn(w_k, batch, None)
    pseudo = jax.tree.map(
        lambda a, b: (a.astype(jnp.float32) - b.astype(jnp.float32)),
        w_t, w_k)
    return pseudo, l


def make_client_update(algorithm: str, loss_fn: LossFn, *, local_steps: int,
                       local_epochs: int = 1, prox_mu: float = 0.0,
                       remat: bool = True):
    """Bind a strategy: (w_t, batch, lr, rng) -> (G_k, client_loss).

    Back-compat shim over the :mod:`repro.core.algorithms` registry — any
    algorithm registered there (built-ins plus user plugins) resolves."""
    from repro.core.algorithms import get_algorithm   # lazy: import cycle
    return get_algorithm(algorithm).build(
        loss_fn, local_steps=local_steps, local_epochs=local_epochs,
        prox_mu=prox_mu, remat=remat)

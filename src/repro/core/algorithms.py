"""Client-algorithm plugin registry — add a federated algorithm in ONE file.

A :class:`ClientAlgorithm` owns everything the round engine needs to know
about a local-update rule:

  * ``build(loss_fn, *, local_steps, local_epochs, prox_mu, remat)`` — a
    factory returning the client update ``(w_t, batch, lr, rng) ->
    (G_k, client_loss)``, where ``G_k`` is the gradient-like quantity the
    server aggregates (Eq. 14);
  * ``pseudo_gradient`` — the aggregation semantics of ``G_k``.  True means
    ``G_k`` is a parameter delta (``w_t - w_k``) whose weighted mean under a
    *plain-SGD unit-step* server IS the FedAvg parameter average, so the
    server lr is forced to 1.0 exactly there (see
    :func:`repro.core.round.resolve_server_lr`).  False means ``G_k`` is a
    true gradient (UGA) or a normalized direction (FedNova) and the server
    honors ``FedConfig.server_lr`` everywhere.

How to add an algorithm in one file (no edits to ``core/round.py``)::

    # myalgo.py — anywhere importable
    from repro.core.algorithms import register_algorithm

    @register_algorithm("myalgo", pseudo_gradient=False,
                        description="my local update rule")
    def build_myalgo(loss_fn, *, local_steps, local_epochs, prox_mu, remat):
        def update(w_t, batch, lr, rng):
            ...                      # any JAX computation
            return g_k, client_loss
        return update

Importing ``myalgo`` makes ``FedConfig(algorithm="myalgo")``,
``make_federated_round``, ``FederatedTrainer`` and
``launch/train.py --algorithm myalgo`` all work, on every cohort executor
and server engine — the registries compose.

The paper's algorithms (fedavg / uga / fedprox) are registrations of the
strategies in :mod:`repro.core.client`; ``fednova`` below is the proof that
a new algorithm lands purely through this registry.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable

import jax

from repro.core.client import fedavg_update, uga_update
from repro.core.registry import Registry

__all__ = ["ClientAlgorithm", "register_algorithm", "get_algorithm",
           "available_algorithms", "fednova_update"]


@dataclasses.dataclass(frozen=True)
class ClientAlgorithm:
    """One registered local-update rule (see module docstring)."""
    name: str
    build: Callable            # (loss_fn, *, local_steps, local_epochs,
    #                             prox_mu, remat) -> client_update
    pseudo_gradient: bool      # True: G_k = w_t - w_k (delta semantics);
    #                            plain-SGD server lr is forced to 1.0
    description: str = ""


_ALGORITHMS = Registry("client algorithm",
                       "repro.core.algorithms.register_algorithm")


def register_algorithm(name: str, *, pseudo_gradient: bool = False,
                       description: str = ""):
    """Decorator registering a client-update factory under ``name``."""
    def deco(build: Callable) -> Callable:
        _ALGORITHMS.register(name, ClientAlgorithm(
            name=name, build=build, pseudo_gradient=pseudo_gradient,
            description=description or (build.__doc__ or "").strip()))
        return build
    return deco


def get_algorithm(name: str) -> ClientAlgorithm:
    return _ALGORITHMS.get(name)


def available_algorithms() -> tuple:
    return _ALGORITHMS.names()


# ---------------------------------------------------------------------------
# built-in registrations: the paper's three algorithms
# ---------------------------------------------------------------------------
@register_algorithm("uga", pseudo_gradient=False,
                    description="keep-trace GD + gradient evaluation "
                                "(unbiased aggregation, paper §3.1)")
def _build_uga(loss_fn, *, local_steps, local_epochs, prox_mu, remat):
    del prox_mu
    return partial(uga_update, loss_fn, local_steps=local_steps,
                   local_epochs=local_epochs, remat=remat)


@register_algorithm("fedavg", pseudo_gradient=True,
                    description="local SGD, delta aggregation (biased "
                                "baseline, paper §2.1)")
def _build_fedavg(loss_fn, *, local_steps, local_epochs, prox_mu, remat):
    del prox_mu
    return partial(fedavg_update, loss_fn, local_steps=local_steps,
                   local_epochs=local_epochs, remat=remat)


@register_algorithm("fedprox", pseudo_gradient=True,
                    description="fedavg + proximal term mu/2 ||w - w_t||^2 "
                                "(Li et al., 2018)")
def _build_fedprox(loss_fn, *, local_steps, local_epochs, prox_mu, remat):
    return partial(fedavg_update, loss_fn, local_steps=local_steps,
                   local_epochs=local_epochs, prox_mu=prox_mu, remat=remat)


# ---------------------------------------------------------------------------
# FedNova — normalized averaging, shipped purely through the registry
# ---------------------------------------------------------------------------
def fednova_update(loss_fn, w_t, batch, lr, rng=None, *, local_steps: int = 2,
                   local_epochs: int = 1, prox_mu: float = 0.0,
                   remat: bool = True):
    """FedNova-style normalized averaging (Wang et al., 2020).

    The local delta is divided by the client's local step count
    tau_k = local_steps * local_epochs, so the aggregated direction is the
    *per-step average progress*: heterogeneous tau_k no longer biases the
    mean toward clients that ran longer (the objective-inconsistency
    FedNova fixes).  The server honors ``server_lr`` (the effective tau);
    with ``server_opt="sgd"`` and ``server_lr = tau`` (uniform tau_k) it
    reproduces FedAvg exactly.
    """
    pseudo, l = fedavg_update(loss_fn, w_t, batch, lr, rng,
                              local_steps=local_steps,
                              local_epochs=local_epochs, prox_mu=prox_mu,
                              remat=remat)
    tau = float(local_steps * local_epochs)
    return jax.tree.map(lambda g: g / tau, pseudo), l


register_algorithm("fednova", pseudo_gradient=False,
                   description="tau_k-normalized delta averaging "
                               "(FedNova, Wang et al. 2020)")(
    lambda loss_fn, *, local_steps, local_epochs, prox_mu, remat:
        partial(fednova_update, loss_fn, local_steps=local_steps,
                local_epochs=local_epochs, prox_mu=prox_mu, remat=remat))

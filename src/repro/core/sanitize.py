"""Runtime sanitizer: checkify-instrumented federated rounds.

fedlint (``repro.analysis.fedlint``) proves *static* discipline — rng tags,
kernel contracts, capability declarations.  This module is its runtime
counterpart: ``--sanitize`` (``repro.launch.train``) turns on
``jax_debug_nans``, re-jits the round program under
:mod:`jax.experimental.checkify`, and plants :func:`check_flat_groups`
probes on the flat aggregate buffers — so a NaN/Inf payload (a garbled uplink, an exploding
local step, a bad codec decode) is caught the round it happens, with an
error that names the offending flat dtype group instead of surfacing rounds
later as a silently-poisoned parameter tree.

The sanitizer is strictly additive: with ``sanitize=False`` (the default)
no checkify transform runs and the jitted round program is bit-identical to
the unsanitized build.
"""
from __future__ import annotations

from jax import numpy as jnp
from jax.experimental import checkify

__all__ = ["sanitize_errors", "check_flat_groups", "checkify_round",
           "throw_if_error"]

# The error set --sanitize runs under: the explicit check_flat_groups
# probes below (user checks).  Two checkify error classes are deliberately
# NOT in the set:
#   * float_checks — checkify reports the FIRST failed check, so a
#     per-primitive NaN-genesis check would shadow the flat-group probe,
#     and it is the probe whose message names the aggregation buffer and
#     the recovery path; --sanitize turns on jax_debug_nans to localize
#     genesis instead;
#   * index_checks — jax 0.4.37's checkify rule for scatter (the transpose
#     of gather under autodiff, produced by every take_along_axis-style
#     loss) raises `IndexError: tuple index out of range` at trace time;
#     re-add `checkify.index_checks` here once jax is bumped past that bug.
sanitize_errors = checkify.user_checks


def check_flat_groups(spec, bufs, where: str) -> None:
    """Probe every flat dtype-group buffer for non-finite values.

    ``spec`` is the :class:`repro.core.flat.FlatSpec` describing ``bufs``
    (one fp32 ``(rows, 128)`` buffer per dtype group, or with leading batch
    axes).  Must run inside a function transformed by
    :func:`checkify_round`; outside it the checks are silently discarded by
    design (checkify's functionalization), which is what keeps the default
    path untransformed.  The error message names the flat group and the
    probe site so the failure is actionable without a device debugger."""
    for i, (g, buf) in enumerate(zip(spec.groups, bufs)):
        bad = jnp.size(buf) - jnp.sum(jnp.isfinite(buf).astype(jnp.int32))
        checkify.check(
            bad == 0,
            f"sanitize: {{n}} non-finite element(s) in flat group {i} "
            f"(dtype {g.dtype}, {g.rows}x128 fp32 buffer) at {where}; "
            "map elements back to parameter leaves with "
            "repro.core.flat.unflatten_tree",
            n=bad)


def checkify_round(fn):
    """Transform a round_fn for jit under the sanitizer's error set.  The
    result returns ``(err, (state, metrics))``; raise host-side with
    :func:`throw_if_error` after the call."""
    return checkify.checkify(fn, errors=sanitize_errors)


# host-side raise of a checkified error value (no-op when no check fired)
throw_if_error = checkify.check_error

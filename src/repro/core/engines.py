"""Server-engine plugin registry — WHAT the server does with an aggregate.

A :class:`ServerEngine` consumes the uniform aggregate handle a cohort
executor produced (:mod:`repro.core.executors`) and applies the server-side
update (clip -> optimizer -> parameter write).  Engines declare:

  * ``accepts`` / ``preferred`` — which handle kinds they consume, so the
    round builder can ask the executor for the right one (``FusedFlatEngine``
    prefers flat buffers but still accepts a sharded tree by wrapping it as
    a one-client stack, exactly the pre-redesign fallback);
  * ``meta_capabilities`` — which FedMeta modes the engine can power.
    ``"through_aggregation"`` means the engine's apply is differentiable
    w.r.t. the aggregate and the step size (the fused engine's hand-written
    custom VJP), so hypergradients of the D_meta loss can flow into the
    controllable per-client-weights state.  What used to be a ValueError
    maze over ``fused_update`` flags is now this capability check.

Built-ins:

  * ``legacy_tree`` — the tree-map stages (weighted mean consumed as a
    pytree -> clip-norm scale -> fp32 cast -> ``server_opt.apply``);
  * ``fused_flat`` — the flat-buffer Pallas engine
    (``repro.kernels.fused_update``): clip + optimizer + param write in one
    HBM pass over per-dtype-group buffers, differentiable end to end.

Register alternatives with :func:`register_engine` (e.g. a sign-SGD or
quantized engine) and select them via
``make_federated_round(..., engine="name")``.
"""
from __future__ import annotations

from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import server_opt
from repro.core.executors import FlatAggregate, TreeAggregate
from repro.core.flat import flat_sq_norm, make_flat_spec
from repro.core.registry import Registry
from repro.kernels.fused_update.ops import (flat_apply_groups,
                                            fused_server_update,
                                            init_flat_opt_state)

PyTree = Any

__all__ = ["ServerEngine", "LegacyTreeEngine", "FusedFlatEngine",
           "BufferedAsyncEngine",
           "register_engine", "get_engine", "available_engines",
           "resolve_engine", "tree_global_norm"]


def tree_global_norm(g: PyTree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(g)))


class ServerEngine:
    """Protocol.  Engines are constructed per-config via the registry
    factory ``factory(fed) -> ServerEngine``."""
    name: str = "?"
    accepts: frozenset = frozenset()          # handle kinds consumed
    preferred: str = "tree"                   # kind to request if available
    meta_capabilities: frozenset = frozenset({"post"})
    # which GradientCodec classes this engine can sit behind: lossy codecs
    # decode into flat dtype-group buffers (repro.comm), so only engines
    # consuming flat handles can declare "lossy"
    codec_capabilities: frozenset = frozenset({"none"})
    # True routes the round builder through the async tick program
    # (repro.core.async_round) instead of the synchronous barrier round —
    # part of the declared capability surface (fedlint FL301)
    is_async: bool = False

    def init_state(self, params: PyTree) -> PyTree:
        raise NotImplementedError

    def apply(self, params: PyTree, handle, opt_state: PyTree, *, lr
              ) -> Tuple[PyTree, PyTree, jax.Array]:
        """Clip + optimizer + write.  Returns (new_params, new_opt_state,
        grad_norm_after_clip)."""
        raise NotImplementedError


_ENGINES = Registry("server engine", "repro.core.engines.register_engine")


def register_engine(name: str):
    """Decorator registering an engine factory ``factory(fed) -> engine``."""
    def deco(factory: Callable) -> Callable:
        _ENGINES.register(name, factory)
        return factory
    return deco


def get_engine(name: str) -> Callable:
    return _ENGINES.get(name)


def available_engines() -> tuple:
    return _ENGINES.names()


def resolve_engine(fed, *, engine: Optional[str] = None) -> ServerEngine:
    """An explicit registry name wins, then ``fed.engine``, then
    ``fed.fused_update`` selects fused_flat / legacy_tree."""
    if engine is None:
        engine = getattr(fed, "engine", None)
    if engine is None:
        engine = "fused_flat" if fed.fused_update else "legacy_tree"
    return get_engine(engine)(fed)


# ---------------------------------------------------------------------------
# built-in engines
# ---------------------------------------------------------------------------
@register_engine("legacy_tree")
class LegacyTreeEngine(ServerEngine):
    """Tree-map reference engine: clip-norm scale over the aggregate pytree
    then ``server_opt.apply`` — 5+ full-model traversals, no custom VJP, so
    only ``meta_mode="post"`` is available."""
    name = "legacy_tree"
    accepts = frozenset({"tree"})
    preferred = "tree"
    meta_capabilities = frozenset({"post"})

    def __init__(self, fed):
        self._opt = fed.server_opt
        self._clip = fed.clip_norm
        self._momentum = fed.server_momentum

    def init_state(self, params):
        return server_opt.init_state(self._opt, params)

    def apply(self, params, handle, opt_state, *, lr):
        assert isinstance(handle, TreeAggregate), type(handle)
        G = handle.tree
        if self._clip > 0:
            gn = tree_global_norm(G)
            scale = jnp.minimum(1.0,
                                self._clip / jnp.maximum(gn, 1e-9))
            G = jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                        ).astype(g.dtype), G)
        new_params, new_opt = server_opt.apply(
            self._opt, opt_state, params, G, lr, momentum=self._momentum)
        return new_params, new_opt, tree_global_norm(G)


@register_engine("fused_flat")
class FusedFlatEngine(ServerEngine):
    """Flat-buffer Pallas engine (``repro.kernels.fused_update``): one HBM
    pass for clip + sgd/sgdm/adam/yogi + param write, hand-written custom
    VJP — declares the ``through_aggregation`` capability."""
    name = "fused_flat"
    accepts = frozenset({"flat", "tree"})
    preferred = "flat"
    meta_capabilities = frozenset({"post", "through_aggregation"})
    codec_capabilities = frozenset({"none", "lossy"})

    def __init__(self, fed):
        self._opt = fed.server_opt
        self._clip = fed.clip_norm
        self._momentum = fed.server_momentum

    def init_state(self, params):
        return init_flat_opt_state(self._opt, make_flat_spec(params))

    def apply(self, params, handle, opt_state, *, lr):
        if isinstance(handle, TreeAggregate):
            # pre-aggregated tree handles (custom executors; the built-in
            # four all produce flat): run the engine over a one-client
            # stack so the flat layout needn't re-express the tree
            g_stack = jax.tree.map(lambda x: x[None], handle.tree)
            return fused_server_update(
                params, g_stack, jnp.ones((1,), jnp.float32), opt_state,
                opt=self._opt, lr=lr, clip_norm=self._clip,
                momentum=self._momentum)
        assert isinstance(handle, FlatAggregate), type(handle)
        gn = (jnp.sqrt(handle.sq_norm) if handle.sq_norm is not None
              else jnp.sqrt(flat_sq_norm(handle.groups)))
        return flat_apply_groups(
            handle.spec, handle.groups, gn, params, opt_state,
            opt=self._opt, lr=lr, clip_norm=self._clip,
            momentum=self._momentum)


@register_engine("buffered_async")
class BufferedAsyncEngine(FusedFlatEngine):
    """Fault-tolerant buffered-asynchronous server engine (FedBuff-style).

    The per-flush apply — staleness-weighted mean already streamed into
    flat buffers, then clip -> optimizer -> parameter write — is inherited
    unchanged from :class:`FusedFlatEngine`; what changes is the ROUND
    SHAPE: ``is_async = True`` makes the round builder
    (``repro.core.round.make_federated_round``) route through the tick
    program in :mod:`repro.core.async_round`, which holds the bounded
    delta pool (``state["async"]``), per-delta staleness counters and the
    every-K-arrivals flush policy.  ``meta_mode='post'`` only: the flush is
    conditional (``lax.cond``), so there is no fixed aggregation graph for
    through-aggregation hypergradients to flow through."""
    name = "buffered_async"
    is_async = True
    accepts = frozenset({"flat"})
    preferred = "flat"
    meta_capabilities = frozenset({"post"})
    codec_capabilities = frozenset({"none", "lossy"})

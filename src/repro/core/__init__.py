"""The paper's contribution: UGA (§3.1) + FedMeta (§3.2) as composable,
model- and task-agnostic strategies over arbitrary JAX models — exposed
through three plugin registries plus a facade:

  * :mod:`repro.core.algorithms` — ClientAlgorithm registry (what a client
    computes): uga / fedavg / fedprox / fednova / register your own;
  * :mod:`repro.core.executors` — CohortExecutor registry (how the cohort
    runs): vmap / scan / sharded, yielding uniform aggregate handles;
  * :mod:`repro.core.engines` — ServerEngine registry (what the server
    does with the aggregate): legacy_tree / fused_flat, with declared
    FedMeta capabilities;
  * :class:`repro.core.trainer.FederatedTrainer` — the one driver loop
    (jit cache, chunked sampling, checkpoint/resume, history).

``make_federated_round`` / ``init_server_state`` / ``RoundFnCache`` /
``stack_round_inputs`` keep their pre-registry signatures (thin
compositions over the registries) so existing callers run unmodified.
"""
from repro.core.aggregate import (cohort_gradient, scan_cohort_deltas_flat,
                                  scan_cohort_gradient_flat, weighted_mean)
from repro.core.async_round import (init_async_state, make_async_tick,
                                    resolve_async_shape, staleness_discount)
from repro.core.algorithms import (available_algorithms, get_algorithm,
                                   register_algorithm)
from repro.core.client import (fedavg_update, make_client_update, uga_update)
from repro.core.engines import (available_engines, get_engine,
                                register_engine, resolve_engine)
from repro.core.executors import (available_executors, get_executor,
                                  register_executor, resolve_executor)
from repro.core.meta import (meta_update, meta_update_through_aggregation,
                             meta_update_through_aggregation_scan,
                             meta_update_through_cohort)
from repro.core.round import (init_server_state, make_federated_round,
                              grad_global_norm, participation_mask,
                              resolve_server_lr, RoundFnCache,
                              stack_round_inputs)
from repro.core.trainer import FederatedTrainer
from repro.core import server_opt

__all__ = ["cohort_gradient", "scan_cohort_deltas_flat",
           "scan_cohort_gradient_flat", "weighted_mean",
           "init_async_state", "make_async_tick", "resolve_async_shape",
           "staleness_discount",
           "fedavg_update", "uga_update",
           "make_client_update", "meta_update",
           "meta_update_through_aggregation",
           "meta_update_through_aggregation_scan",
           "meta_update_through_cohort", "init_server_state",
           "make_federated_round", "grad_global_norm", "participation_mask",
           "resolve_server_lr", "server_opt", "RoundFnCache",
           "stack_round_inputs",
           "register_algorithm", "get_algorithm", "available_algorithms",
           "register_executor", "get_executor", "available_executors",
           "resolve_executor",
           "register_engine", "get_engine", "available_engines",
           "resolve_engine",
           "FederatedTrainer"]

"""The paper's contribution: UGA (§3.1) + FedMeta (§3.2) as composable,
model- and task-agnostic strategies over arbitrary JAX models."""
from repro.core.aggregate import (cohort_gradient, scan_cohort_gradient_flat,
                                  weighted_mean)
from repro.core.client import (fedavg_update, make_client_update, uga_update)
from repro.core.meta import (meta_update, meta_update_through_aggregation,
                             meta_update_through_aggregation_scan)
from repro.core.round import (init_server_state, make_federated_round,
                              grad_global_norm, resolve_server_lr,
                              RoundFnCache, stack_round_inputs)
from repro.core import server_opt

__all__ = ["cohort_gradient", "scan_cohort_gradient_flat", "weighted_mean",
           "fedavg_update", "uga_update",
           "make_client_update", "meta_update",
           "meta_update_through_aggregation",
           "meta_update_through_aggregation_scan", "init_server_state",
           "make_federated_round", "grad_global_norm", "resolve_server_lr",
           "server_opt", "RoundFnCache", "stack_round_inputs"]

"""Buffered asynchronous federation runtime (FedBuff-style) as ONE jitted
tick program.

The synchronous round (``repro.core.round``) is a barrier: every cohort
delta must arrive before the server steps, so one straggler stalls the
whole round.  The ``buffered_async`` engine replaces the barrier with a
**bounded pool of coded client deltas** carrying per-delta staleness
counters, and the server steps every ``K = FedConfig.async_buffer``
arrivals with staleness-discounted weights — the buffered-async scheme of
Nguyen et al. (FedBuff, 2022) with the robust staleness weighting of
arXiv:2205.10864, layered over this repo's fused flat-buffer kernels.

One **tick** (what ``round_fn`` runs per ``state["round"]`` increment) is
the simulated dispatch period: the server hands the current parameters to
a fresh cohort, their deltas finish locally, and each delta enters the
pool stamped with the server version it was computed against plus a
delivery tick (``tick + delay`` under a delay fault).  Then the server
flushes every K **arrived** deltas (delivered, not yet consumed):

  * flush weights are ``n_k * discount(staleness)`` with ``staleness =
    server_version - delta_version`` and ``discount`` from
    ``FedConfig.staleness_mode`` (``invsqrt``: ``1/sqrt(1+s)``, the FedBuff
    default; ``inv``; ``none``);
  * the weighted mean streams through the SAME fused flat-buffer FMA
    (``kernels/fused_update::accumulate_pass``) and fused
    clip->optimizer->write pass as the synchronous scan strategy — a
    fault-free tick with ``K = async_capacity = cohort`` is **bit-identical**
    to the synchronous ``cohort_strategy="scan"`` fused round
    (regression-gated by ``benchmarks/async_throughput.py``);
  * the server version increments per flush, staling every delta still in
    the pool; ``async_max_staleness`` optionally evicts arrived deltas
    whose staleness exceeds the bound.

Faults (``repro.sim.faults``) act where a real system would see them:
crash/drop zero a delta's pool weight (it never arrives), delay pushes its
delivery tick, garble scales the decoded payload.  Lossy uplink codecs
(``repro.comm``) ride the same per-client slots: the pool stores DECODED
deltas and error-feedback residuals live in ``state["comm"]`` exactly as
in the sync rounds (a crashed/dropped client's residual stays
byte-identical — it never transmitted).

Pool state (``state["async"]``) checkpoints/restores like every other
server-state slot, so a mid-run save/resume is bit-identical, buffer and
staleness counters included.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.algorithms import get_algorithm
from repro.core.engines import resolve_engine
from repro.core.executors import FlatAggregate, get_executor
from repro.core.flat import LANES, FlatSpec, make_flat_spec, zeros_flat
from repro.core.meta import meta_update
from repro.core.round import participation_mask, resolve_server_lr
from repro.core.sanitize import check_flat_groups
from repro.kernels.fused_update.ops import flat_accumulate
from repro.models.model import Model
from repro.sim.faults import fault_streams, resolve_faults

PyTree = Any

STALENESS_HIST_BINS = 8     # staleness histogram: counts of s in 0..6, 7+

_INT32_MAX = jnp.iinfo(jnp.int32).max


def resolve_async_shape(fed) -> Tuple[int, int]:
    """(K, capacity): server steps every K arrivals; the pool holds
    ``capacity`` delta slots.  Defaults: K = cohort (one step per fault-free
    tick), capacity = 2 * cohort (headroom for delayed arrivals).  K >
    capacity could never flush (the deadlock FedConfig rejects)."""
    k = int(getattr(fed, "async_buffer", 0)) or fed.cohort
    cap = int(getattr(fed, "async_capacity", 0)) or 2 * fed.cohort
    return k, cap


def staleness_discount(mode: str):
    """Staleness -> weight multiplier.  ``discount(0) == 1.0`` exactly in
    every mode, so a fresh delta's weight is bit-unchanged."""
    if mode == "none":
        return lambda s: jnp.ones_like(s)
    if mode == "inv":
        return lambda s: 1.0 / (1.0 + s)
    if mode == "invsqrt":
        return lambda s: 1.0 / jnp.sqrt(1.0 + s)
    raise ValueError(
        f"unknown staleness_mode {mode!r}; expected 'none', 'inv' or "
        "'invsqrt' (the FedBuff 1/sqrt(1+s) default)")


def init_async_state(fed, spec: FlatSpec) -> PyTree:
    """The delta pool: per-dtype-group ``(capacity, rows, LANES)`` fp32
    slots plus per-slot weight / version / delivery-tick vectors and the
    server version counter.  ``weight == 0`` marks a free slot."""
    _, cap = resolve_async_shape(fed)
    return {
        "pool": tuple(jnp.zeros((cap, g.rows, LANES), jnp.float32)
                      for g in spec.groups),
        "weight": jnp.zeros((cap,), jnp.float32),
        "version": jnp.zeros((cap,), jnp.int32),
        "deliver": jnp.zeros((cap,), jnp.int32),
        "server_version": jnp.zeros((), jnp.int32),
    }


def make_async_tick(model: Model, fed, *, algorithm: Optional[str] = None,
                    executor: Optional[str] = None,
                    engine: Optional[str] = None, spmd_axis_name=None,
                    sanitize: bool = False):
    """Build ``one_tick(state, cohort_batch, meta_batch, client_weights,
    rng) -> (state, metrics)`` — same signature as the synchronous
    ``one_round``, so ``rounds_per_call`` chunking, the trainer and the
    checkpoint layout all reuse unchanged."""
    alg = get_algorithm(algorithm if algorithm is not None
                        else fed.algorithm)
    client_update = alg.build(
        model.loss, local_steps=fed.local_steps,
        local_epochs=fed.local_epochs, prox_mu=fed.prox_mu,
        remat=fed.remat_local_steps)
    if executor not in (None, "buffered_async"):
        raise ValueError(
            f"engine='buffered_async' runs its own delta-pooling executor; "
            f"executor={executor!r} cannot be composed with it. Drop the "
            "executor override (fed.cohort_strategy picks the vmap/scan "
            "base the deltas are computed with).")
    exe = get_executor("buffered_async")(fed, spmd_axis_name=spmd_axis_name,
                                         grad_shardings=None)
    eng = resolve_engine(fed, engine=engine)
    faults = resolve_faults(fed)
    # lazy: repro.comm imports repro.core.flat, which triggers this package
    from repro.comm import comm_bytes_per_client, resolve_codec
    codec = resolve_codec(fed)
    use_ef = codec.lossy and fed.error_feedback
    K, cap = resolve_async_shape(fed)
    if K > cap:
        raise ValueError(
            f"async_buffer={K} exceeds async_capacity={cap}: the pool can "
            "never hold K deltas, so the server would never step "
            "(deadlock). Raise async_capacity or lower async_buffer.")
    max_steps = max(cap // K, 1)
    server_lr = resolve_server_lr(fed)
    discount = staleness_discount(fed.staleness_mode)
    max_stale = int(getattr(fed, "async_max_staleness", 0))
    accum = flat_accumulate()

    def one_tick(state: PyTree, cohort_batch: PyTree, meta_batch: PyTree,
                 client_weights: jax.Array, rng: jax.Array
                 ) -> Tuple[PyTree, Dict[str, jax.Array]]:
        params = state["params"]
        a = state["async"]
        tick = state["round"]
        r = tick.astype(jnp.float32)
        lr_c = fed.client_lr * (fed.lr_decay ** r)
        cohort = client_weights.shape[0]
        spec = make_flat_spec(params)

        # same 2-way split + participation fold as the sync round, so a
        # fault-free K=cohort tick replays the sync rng streams exactly
        rng_c, rng_m = jax.random.split(rng)
        w_in = client_weights
        part_metrics = {}
        if fed.participation < 1.0:
            mask = participation_mask(rng, cohort, fed.participation)
            w_in = w_in * mask
            part_metrics = {"participants": jnp.sum(mask)}

        fault_metrics = {}
        if faults.active:
            fs = fault_streams(rng, cohort, faults)
            # crashed/dropped reports never reach the pool; their zero
            # weight also keeps them out of the loss metric and (with EF
            # codecs) freezes their residual slot — they never transmitted
            w_in = w_in * fs.alive
            delay = fs.delay
            fault_metrics = {
                "fault_crashed": jnp.sum(fs.crashed.astype(jnp.float32)),
                "fault_dropped": jnp.sum(fs.dropped.astype(jnp.float32)),
                "fault_delayed": jnp.sum(fs.delayed.astype(jnp.float32)),
            }
        else:
            fs = None
            delay = jnp.zeros((cohort,), jnp.int32)

        # ---- local updates -> per-client DECODED flat deltas ------------
        comm_metrics = {}
        new_comm = None
        if codec.lossy:
            g_groups, client_loss, new_res = exe.run_deltas_coded(
                client_update, params, cohort_batch, w_in, lr_c, rng_c,
                spec=spec, codec=codec, comm=state.get("comm"))
            if use_ef:
                new_comm = {"residual": new_res}
            bytes_pc = comm_bytes_per_client(codec, spec)
            n_up = jnp.sum((w_in > 0).astype(jnp.float32))
            comm_metrics = {"comm_bytes": jnp.float32(bytes_pc) * n_up}
        else:
            g_groups, client_loss = exe.run_deltas(
                client_update, params, cohort_batch, w_in, lr_c, rng_c,
                spec=spec)
        if faults.active and faults.garble > 0:
            # payload corruption happens on the wire: AFTER codec
            # decode, BEFORE pooling (ungarbled multipliers are exactly
            # 1.0, an IEEE no-op)
            g_groups = [g * fs.garble_mult[:, None, None] for g in g_groups]
        if sanitize:
            # probe the decoded (and possibly garbled) payloads BEFORE they
            # enter the pool: a NaN caught here names the uplink, not a
            # server step several flushes later
            check_flat_groups(spec, g_groups,
                              "decoded client deltas before pool insert "
                              "(async tick)")

        # ---- pool insert (evict-stalest on overflow) --------------------
        v_now = a["server_version"]
        cand_w = jnp.concatenate([a["weight"], w_in.astype(jnp.float32)])
        cand_v = jnp.concatenate(
            [a["version"], jnp.full((cohort,), v_now, jnp.int32)])
        cand_d = jnp.concatenate([a["deliver"], tick + delay])
        occupied = cand_w > 0.0
        # ascending sort key: newest version first, free slots last; the
        # stable sort keeps insertion order within a version, so a
        # fault-free tick lands the cohort in client order (bit-identity
        # with the sync scan accumulation depends on this)
        sort_key = jnp.where(occupied, -cand_v, _INT32_MAX)
        keep = jnp.argsort(sort_key, stable=True)[:cap]
        pool = tuple(jnp.concatenate([p, g], axis=0)[keep]
                     for p, g in zip(a["pool"], g_groups))
        pw = cand_w[keep]
        pv = cand_v[keep]
        pd = cand_d[keep]
        overflow = (jnp.sum(occupied.astype(jnp.float32))
                    - jnp.sum((pw > 0).astype(jnp.float32)))
        arrivals = jnp.sum(((pw > 0) & (pd == tick)).astype(jnp.float32))

        # ---- flush every K arrived deltas -------------------------------
        slot_idx = jnp.arange(cap, dtype=jnp.int32)

        def flush(args):
            params_f, opt_f, pw_f, ver_f, st = args
            eligible = (pw_f > 0.0) & (pd <= tick)
            if max_stale > 0:
                eligible = eligible & ((ver_f - pv) <= max_stale)
            # select the K earliest-delivered eligible deltas, slot index
            # breaking ties (deterministic FIFO)
            sel_key = jnp.where(eligible, pd * (cap + 1) + slot_idx,
                                _INT32_MAX)
            rank = jnp.argsort(jnp.argsort(sel_key))
            sel = (rank < K) & eligible
            s = (ver_f - pv).astype(jnp.float32)
            w_eff = pw_f * discount(s) * sel.astype(jnp.float32)
            wsum = jnp.maximum(jnp.sum(w_eff), 1e-30)
            wn = w_eff / wsum

            # streaming FMA over the pool slots — the same accumulate_pass
            # sequence as scan_cohort_gradient_flat, so a fault-free
            # K=cap=cohort flush reproduces the sync scan bits exactly
            def acc_body(accs, xs):
                gs, wi = xs
                return tuple(accum(acc, g, wi)
                             for acc, g in zip(accs, gs)), None

            accs, _ = lax.scan(acc_body, tuple(zeros_flat(spec)), (pool, wn))
            handle = FlatAggregate(list(accs), spec, sq_norm=None)
            new_p, new_o, gn = eng.apply(params_f, handle, opt_f,
                                         lr=server_lr)

            s_sel = jnp.where(sel, s, 0.0)
            bins = jnp.clip(s.astype(jnp.int32), 0, STALENESS_HIST_BINS - 1)
            hist_add = jnp.sum(
                jax.nn.one_hot(bins, STALENESS_HIST_BINS, dtype=jnp.float32)
                * sel.astype(jnp.float32)[:, None], axis=0)
            st = {
                "steps": st["steps"] + 1,
                "grad_norm": gn,
                "staleness_sum": st["staleness_sum"] + jnp.sum(s_sel),
                "staleness_cnt": (st["staleness_cnt"]
                                  + jnp.sum(sel.astype(jnp.float32))),
                "staleness_max": jnp.maximum(st["staleness_max"],
                                             jnp.max(s_sel)),
                "staleness_hist": st["staleness_hist"] + hist_add,
            }
            return new_p, new_o, jnp.where(sel, 0.0, pw_f), ver_f + 1, st

        def attempt(_, carry):
            _, _, pw_c, ver_c, _ = carry
            eligible = (pw_c > 0.0) & (pd <= tick)
            if max_stale > 0:
                eligible = eligible & ((ver_c - pv) <= max_stale)
            cnt = jnp.sum(eligible.astype(jnp.int32))
            return lax.cond(cnt >= K, flush, lambda c: c, carry)

        st0 = {"steps": jnp.zeros((), jnp.int32),
               "grad_norm": jnp.zeros((), jnp.float32),
               "staleness_sum": jnp.zeros((), jnp.float32),
               "staleness_cnt": jnp.zeros((), jnp.float32),
               "staleness_max": jnp.zeros((), jnp.float32),
               "staleness_hist": jnp.zeros((STALENESS_HIST_BINS,),
                                           jnp.float32)}
        new_params, new_opt, pw_fin, v_fin, st = lax.fori_loop(
            0, max_steps, attempt, (params, state["opt"], pw, v_now, st0))

        if max_stale > 0:
            # arrived deltas the staleness bound evicted this tick: still
            # occupying weight but permanently ineligible — clear them so
            # the pool doesn't silt up, and count them
            stale_now = ((pw_fin > 0.0) & (pd <= tick)
                         & ((v_fin - pv) > max_stale))
            fault_metrics = {**fault_metrics,
                             "expired": jnp.sum(stale_now.astype(
                                 jnp.float32))}
            pw_fin = jnp.where(stale_now, 0.0, pw_fin)

        metrics = {
            "client_loss": client_loss,
            "grad_norm": st["grad_norm"],
            "arrivals": arrivals,
            "server_steps": st["steps"].astype(jnp.float32),
            "buffer_fill": jnp.sum((pw_fin > 0).astype(jnp.float32)),
            "overflow_dropped": overflow,
            "staleness_mean": (st["staleness_sum"]
                               / jnp.maximum(st["staleness_cnt"], 1.0)),
            "staleness_max": st["staleness_max"],
            "staleness_hist": st["staleness_hist"],
            **part_metrics, **fault_metrics, **comm_metrics,
        }

        if fed.meta:
            # post-aggregation FedMeta step, once per tick, gated on the
            # server having stepped at all (a no-flush tick must leave
            # params bit-unchanged); where(True, x, _) is a bitwise
            # identity, so fault-free ticks keep the sync meta bits
            lr_m = fed.meta_lr * (fed.lr_decay ** r)
            m_params, meta_loss = meta_update(
                model.loss, new_params, meta_batch, lr_m, rng_m)
            stepped = st["steps"] > 0
            new_params = jax.tree.map(
                lambda m, n: jnp.where(stepped, m, n), m_params, new_params)
            metrics["meta_loss"] = jnp.where(stepped, meta_loss, 0.0)

        new_state = {
            "params": new_params, "opt": new_opt, "round": tick + 1,
            "async": {"pool": pool, "weight": pw_fin, "version": pv,
                      "deliver": pd, "server_version": v_fin},
        }
        if use_ef:
            new_state["comm"] = new_comm
        return new_state, metrics

    return one_tick

"""FedMeta — controllable meta updating (§3.2, Algorithm 2).

After aggregation the server takes one gradient step on the curated meta
training set D_meta (Eq. 20), giving every round the same, *controllable*
optimization objective regardless of which clients were sampled.
"""
from __future__ import annotations

from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


def meta_update(loss_fn: Callable, params: PyTree, meta_batch: PyTree,
                meta_lr, rng=None) -> Tuple[PyTree, jax.Array]:
    """w <- w - eta_meta * grad L(w; D_meta).  Returns (params, meta_loss)."""

    def obj(w):
        l, _ = loss_fn(w, meta_batch, rng)
        return l

    meta_loss, g = jax.value_and_grad(obj)(params)
    new = jax.tree.map(
        lambda p, gi: (p.astype(jnp.float32)
                       - meta_lr * gi.astype(jnp.float32)).astype(p.dtype),
        params, g)
    return new, meta_loss

"""FedMeta — controllable meta updating (§3.2, Algorithm 2).

Two meta modes:

  * ``meta_update`` (``meta_mode='post'``, the paper's Eq. 20): after
    aggregation the server takes one gradient step on the curated meta
    training set D_meta, giving every round the same, *controllable*
    optimization objective regardless of which clients were sampled.

  * ``meta_update_through_aggregation`` (``meta_mode='through_aggregation'``):
    instead of stepping the parameters directly, differentiate the D_meta
    loss *through* the Eq. (14) aggregation and the server optimizer — the
    fused engine's hand-written custom VJP (``kernels/fused_update``) makes
    this two extra flat HBM sweeps — producing hypergradients w.r.t. the
    per-client aggregation weight multipliers and the server step size.
    Those live in the server state's controllable slot
    ``ctrl = {"w_logits": (cohort,), "log_lr": ()}`` (log-space so
    effective weights/lr stay positive) and are updated by one SGD step
    with ``ctrl_lr`` per round — the meta-learned-aggregation scheme of
    FedAgg / MAML-style FL personalization grafted onto the paper's
    controllable meta update.  ``meta_update_through_aggregation_scan`` is
    the same scheme under client-sequential (scan) cohorts, where the
    streaming flat accumulation's custom VJP supplies the per-client
    weight cotangents without ever stacking the cohort gradients.

Since the plugin-API redesign the round builder uses the strategy-agnostic
``meta_update_through_cohort``: it differentiates through a
:class:`repro.core.executors.ReweightableCohort` (vmap reweights its
retained gradient stack; scan re-runs the streaming accumulation) and any
:class:`repro.core.engines.ServerEngine` declaring the
``through_aggregation`` capability.  The two strategy-specific functions
below are kept as the tested reference forms and for back-compat.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.flat import make_flat_spec
from repro.kernels.fused_update.ops import (fused_apply_flat,
                                            fused_server_update)

PyTree = Any


def meta_update(loss_fn: Callable, params: PyTree, meta_batch: PyTree,
                meta_lr, rng=None) -> Tuple[PyTree, jax.Array]:
    """w <- w - eta_meta * grad L(w; D_meta).  Returns (params, meta_loss)."""

    def obj(w):
        l, _ = loss_fn(w, meta_batch, rng)
        return l

    meta_loss, g = jax.value_and_grad(obj)(params)
    new = jax.tree.map(
        lambda p, gi: (p.astype(jnp.float32)
                       - meta_lr * gi.astype(jnp.float32)).astype(p.dtype),
        params, g)
    return new, meta_loss


def meta_update_through_cohort(
        loss_fn: Callable, reweightable, client_weights: jax.Array,
        params: PyTree, opt_state: PyTree, meta_batch: PyTree,
        ctrl: Dict[str, jax.Array], *, engine, ctrl_lr, rng=None
        ) -> Tuple[PyTree, PyTree, jax.Array, jax.Array,
                   Dict[str, jax.Array], Dict[str, jax.Array]]:
    """Executor/engine-agnostic controllable aggregation — the plugin-API
    form of the two strategy-specific functions below (which it supersedes;
    they are kept for back-compat).

    ``reweightable`` is a :class:`repro.core.executors.ReweightableCohort`
    whose ``aggregate(weights)`` re-runs Eq. (14) under new weights
    (differentiably); ``engine`` is a :class:`repro.core.engines.ServerEngine`
    declaring the ``through_aggregation`` capability.  The objective takes
    this round's server step under eff_w = n_k * exp(w_logits) and step
    size exp(log_lr), and one SGD step with ``ctrl_lr`` on the D_meta-loss
    hypergradients updates the controllable state.

    Returns (new_params, new_opt_state, grad_norm_after_clip, client_loss,
    new_ctrl, metrics)."""

    def objective(w_logits, log_lr):
        eff_w = client_weights.astype(jnp.float32) * jnp.exp(w_logits)
        handle, client_loss = reweightable.aggregate(eff_w)
        new_p, new_opt, gn = engine.apply(params, handle, opt_state,
                                          lr=jnp.exp(log_lr))
        l, _ = loss_fn(new_p, meta_batch, rng)
        return l, (new_p, new_opt, gn, client_loss)

    (meta_loss, (new_p, new_opt, gn, client_loss)), (d_wl, d_llr) = \
        jax.value_and_grad(objective, argnums=(0, 1), has_aux=True)(
            ctrl["w_logits"], ctrl["log_lr"])
    new_ctrl = {"w_logits": ctrl["w_logits"] - ctrl_lr * d_wl,
                "log_lr": ctrl["log_lr"] - ctrl_lr * d_llr}
    metrics = {"meta_loss": meta_loss,
               "ctrl_w_gnorm": jnp.sqrt(jnp.sum(d_wl * d_wl)),
               "ctrl_lr_grad": d_llr,
               "server_lr_eff": jnp.exp(ctrl["log_lr"])}
    return new_p, new_opt, gn, client_loss, new_ctrl, metrics


def meta_update_through_aggregation(
        loss_fn: Callable, params: PyTree, grad_stack: PyTree,
        client_weights: jax.Array, opt_state: PyTree, meta_batch: PyTree,
        ctrl: Dict[str, jax.Array], *, opt: str, clip_norm: float,
        momentum: float, ctrl_lr, rng=None
        ) -> Tuple[PyTree, PyTree, jax.Array, Dict[str, jax.Array],
                   Dict[str, jax.Array]]:
    """Take this round's fused server step under the controllable weights
    eff_w = n_k * exp(w_logits) and step size exp(log_lr), and update the
    controllable state by the hypergradient of the D_meta loss through
    that step (the fused engine's custom VJP).

    grad_stack: stacked per-client gradients (cohort leading axis);
    client_weights: (cohort,) n_k; ctrl: {"w_logits": (cohort,),
    "log_lr": ()}.  Returns (new_params, new_opt_state,
    grad_norm_after_clip, new_ctrl, metrics) — metrics carry the meta loss
    plus the hypergradient norms so drivers can gate on finiteness."""

    def objective(w_logits, log_lr):
        eff_w = client_weights.astype(jnp.float32) * jnp.exp(w_logits)
        new_p, new_opt, gn = fused_server_update(
            params, grad_stack, eff_w, opt_state, opt=opt,
            lr=jnp.exp(log_lr), clip_norm=clip_norm, momentum=momentum)
        l, _ = loss_fn(new_p, meta_batch, rng)
        return l, (new_p, new_opt, gn)

    (meta_loss, (new_p, new_opt, gn)), (d_wl, d_llr) = jax.value_and_grad(
        objective, argnums=(0, 1), has_aux=True)(
            ctrl["w_logits"], ctrl["log_lr"])
    new_ctrl = {"w_logits": ctrl["w_logits"] - ctrl_lr * d_wl,
                "log_lr": ctrl["log_lr"] - ctrl_lr * d_llr}
    metrics = {"meta_loss": meta_loss,
               "ctrl_w_gnorm": jnp.sqrt(jnp.sum(d_wl * d_wl)),
               "ctrl_lr_grad": d_llr,
               "server_lr_eff": jnp.exp(ctrl["log_lr"])}
    return new_p, new_opt, gn, new_ctrl, metrics


def meta_update_through_aggregation_scan(
        loss_fn: Callable, client_update: Callable, params: PyTree,
        cohort_batch: PyTree, client_weights: jax.Array, client_lr, rng_c,
        opt_state: PyTree, meta_batch: PyTree, ctrl: Dict[str, jax.Array],
        *, opt: str, clip_norm: float, momentum: float, ctrl_lr, rng=None
        ) -> Tuple[PyTree, PyTree, jax.Array, jax.Array,
                   Dict[str, jax.Array], Dict[str, jax.Array]]:
    """Controllable aggregation under the client-sequential (scan) cohort
    strategy.  Per-client gradients are never stacked: the objective runs
    the cohort scan with the streaming flat accumulation
    (:func:`repro.core.aggregate.scan_cohort_gradient_flat`), whose
    accumulate custom VJP emits the per-client weight hypergradients
    dw_k = <g_k, dG> with g_k recomputed under ``jax.checkpoint`` — so the
    backward holds one client trajectory's residuals at a time and the
    hypergradients match the vmap path's to fp32 reduction order.

    Note the cost asymmetry vs the vmap path: vmap stores the (cohort,
    *model) gradient stack and never reruns clients; scan stores nothing
    and reruns each client's local update once inside the backward sweep.

    Returns (new_params, new_opt_state, grad_norm_after_clip, client_loss,
    new_ctrl, metrics); ``client_loss`` is weighted by the raw n_k (the
    aggregation uses the controllable eff_w), so the metric matches what
    the vmap branch reports in every round."""
    from repro.core.aggregate import scan_cohort_gradient_flat
    spec = make_flat_spec(params)

    def objective(w_logits, log_lr):
        eff_w = client_weights.astype(jnp.float32) * jnp.exp(w_logits)
        G_groups, client_loss = scan_cohort_gradient_flat(
            client_update, params, cohort_batch, eff_w, client_lr, rng_c,
            spec=spec, loss_weights=client_weights)
        new_p, new_opt, gn = fused_apply_flat(
            params, G_groups, opt_state, opt=opt, lr=jnp.exp(log_lr),
            clip_norm=clip_norm, momentum=momentum, spec=spec)
        l, _ = loss_fn(new_p, meta_batch, rng)
        return l, (new_p, new_opt, gn, client_loss)

    (meta_loss, (new_p, new_opt, gn, client_loss)), (d_wl, d_llr) = \
        jax.value_and_grad(objective, argnums=(0, 1), has_aux=True)(
            ctrl["w_logits"], ctrl["log_lr"])
    new_ctrl = {"w_logits": ctrl["w_logits"] - ctrl_lr * d_wl,
                "log_lr": ctrl["log_lr"] - ctrl_lr * d_llr}
    metrics = {"meta_loss": meta_loss,
               "ctrl_w_gnorm": jnp.sqrt(jnp.sum(d_wl * d_wl)),
               "ctrl_lr_grad": d_llr,
               "server_lr_eff": jnp.exp(ctrl["log_lr"])}
    return new_p, new_opt, gn, client_loss, new_ctrl, metrics

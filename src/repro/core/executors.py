"""Cohort-executor plugin registry — HOW a round runs its cohort.

A :class:`CohortExecutor` owns the execution strategy for the per-client
local updates (client-parallel vmap, client-sequential scan, or explicitly
sharded cohorts) and always yields a **uniform aggregate handle** so server
engines never inspect the strategy:

  * :class:`FlatAggregate` — the fused engine's per-dtype-group
    ``(rows, LANES)`` fp32 buffers holding the Eq. (14) weighted mean
    (``sq_norm`` carries ``||G||^2`` when pass 1 already reduced it);
  * :class:`TreeAggregate` — the weighted-mean pytree, possibly carrying
    sharding constraints (the form the legacy tree-map engine and the
    sharded cohort path consume).

Executors declare which handle kinds they can ``produce``; engines declare
which they ``accept`` (see :mod:`repro.core.engines`) and the round builder
picks the overlap.  Executors that retain (vmap) or can re-run (scan) the
per-client gradients additionally support :meth:`CohortExecutor.reweightable`
— a differentiable ``weights -> handle`` closure, which is what
``meta_mode="through_aggregation"`` differentiates for its per-client
weight hypergradients.  The sharded executor pre-aggregates per leaf, so it
declares ``supports_reweight = False``.

Register a new strategy with :func:`register_executor`; the factory
receives the :class:`~repro.configs.base.FedConfig` plus the round
builder's sharding arguments.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.aggregate import (cohort_gradient, scan_cohort_deltas_flat,
                                  scan_cohort_gradient_flat)
from repro.core.flat import FlatSpec, make_flat_spec
from repro.core.registry import Registry
from repro.kernels.fused_update.ops import flat_weighted_aggregate

PyTree = Any

__all__ = ["FlatAggregate", "TreeAggregate", "ReweightableCohort",
           "CohortExecutor", "register_executor", "get_executor",
           "available_executors", "resolve_executor"]


# ---------------------------------------------------------------------------
# aggregate handles
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class FlatAggregate:
    """Eq. (14) weighted mean in the fused engine's flat layout."""
    groups: list                       # per-dtype-group (rows, LANES) fp32
    spec: FlatSpec
    sq_norm: Optional[jax.Array] = None   # ||G||^2 if pass 1 computed it


@dataclasses.dataclass
class TreeAggregate:
    """Eq. (14) weighted mean as a pytree (sharding constraints intact)."""
    tree: PyTree


@dataclasses.dataclass
class ReweightableCohort:
    """A cohort whose aggregation can be re-run under different weights.

    ``aggregate(weights)`` is differentiable w.r.t. ``weights`` and returns
    ``(handle, client_loss)`` where the loss metric is weighted by the raw
    n_k the cohort was created with, so it reports the same number no
    matter what effective weights the controllable state chose."""
    aggregate: Callable      # (weights,) -> (handle, client_loss)


# ---------------------------------------------------------------------------
# executor protocol + registry
# ---------------------------------------------------------------------------
class CohortExecutor:
    """Protocol.  Subclass (or duck-type) and register a factory."""
    name: str = "?"
    produces: frozenset = frozenset()        # subset of {"flat", "tree"}
    supports_reweight: bool = False
    # which GradientCodec classes this executor can run: {"none"} means the
    # plain (uncompressed) path only; {"none", "lossy"} adds run_coded —
    # per-client encode/decode on the uplink (repro.comm)
    codec_capabilities: frozenset = frozenset({"none"})

    def run(self, client_update, params, cohort_batch, client_weights,
            lr, rng, *, kind: str) -> Tuple[Any, jax.Array]:
        """Run every client and aggregate.  Returns (handle, client_loss);
        ``kind`` is one of this executor's ``produces``."""
        raise NotImplementedError

    def run_coded(self, client_update, params, cohort_batch, client_weights,
                  lr, rng, *, codec, comm) -> Tuple[Any, jax.Array, Any]:
        """Run every client, pass each gradient through ``codec``'s
        encode/decode (the uplink simulation) and aggregate the decoded
        gradients.  ``comm`` is the error-feedback state
        (``state["comm"]``) or None.  Returns (flat handle, client_loss,
        new_comm).  Only executors declaring the 'lossy' codec capability
        implement this."""
        raise NotImplementedError(
            f"cohort executor {self.name!r} does not support lossy "
            "gradient codecs (declares codec_capabilities="
            f"{sorted(self.codec_capabilities)})")

    def reweightable(self, client_update, params, cohort_batch,
                     client_weights, lr, rng) -> ReweightableCohort:
        """Run (or prepare) the cohort so aggregation can be repeated under
        different weights; ``client_weights`` (n_k) weight the loss
        metric."""
        raise NotImplementedError(
            f"cohort executor {self.name!r} does not support reweightable "
            "aggregation")


_EXECUTORS = Registry("cohort executor",
                      "repro.core.executors.register_executor")


def register_executor(name: str):
    """Decorator registering an executor factory:
    ``factory(fed, *, spmd_axis_name, grad_shardings) -> CohortExecutor``."""
    def deco(factory: Callable) -> Callable:
        _EXECUTORS.register(name, factory)
        return factory
    return deco


def get_executor(name: str) -> Callable:
    return _EXECUTORS.get(name)


def available_executors() -> tuple:
    return _EXECUTORS.names()


def resolve_executor(fed, *, spmd_axis_name=None, grad_shardings=None,
                     executor: Optional[str] = None) -> CohortExecutor:
    """Pick the executor for a round: an explicit registry ``executor``
    name wins; otherwise ``grad_shardings`` selects the sharded executor
    (wrapping ``fed.cohort_strategy``) and ``fed.cohort_strategy`` selects
    vmap/scan."""
    if executor is None:
        executor = "sharded" if grad_shardings is not None \
            else fed.cohort_strategy
    elif grad_shardings is not None and executor != "sharded":
        # an explicit override would silently drop the constraints (the
        # flat/scan paths never attach them) and GSPMD would all-gather
        # the per-client gradient stack — the HBM blow-up the sharded
        # executor exists to prevent; fail loudly instead
        raise ValueError(
            f"grad_shardings is set but executor={executor!r} was "
            "explicitly requested; only the 'sharded' executor honors "
            "per-leaf gradient sharding constraints. Drop the executor "
            "override (grad_shardings selects it automatically) or drop "
            "grad_shardings.")
    return get_executor(executor)(fed, spmd_axis_name=spmd_axis_name,
                                  grad_shardings=grad_shardings)


# ---------------------------------------------------------------------------
# built-in executors
# ---------------------------------------------------------------------------
@register_executor("vmap")
class VmapExecutor(CohortExecutor):
    """Client-parallel: every local trajectory runs simultaneously.
    Produces flat handles by retaining the (cohort, *param) gradient stack
    and running the differentiable aggregate kernel (pass 1), or tree
    handles via the per-leaf weighted mean."""
    name = "vmap"
    produces = frozenset({"flat", "tree"})
    supports_reweight = True
    codec_capabilities = frozenset({"none", "lossy"})

    def __init__(self, fed, *, spmd_axis_name=None, grad_shardings=None):
        self._agg_dtype = jnp.dtype(fed.grad_agg_dtype)
        self._spmd = spmd_axis_name
        self._shardings = grad_shardings     # only the tree path honors it

    def _stack(self, client_update, params, cohort_batch, client_weights,
               lr, rng):
        return cohort_gradient(
            client_update, params, cohort_batch, client_weights, lr, rng,
            strategy="vmap", agg_dtype=self._agg_dtype,
            spmd_axis_name=self._spmd, aggregate=False)

    def run(self, client_update, params, cohort_batch, client_weights,
            lr, rng, *, kind):
        if kind == "tree":
            G, loss = cohort_gradient(
                client_update, params, cohort_batch, client_weights, lr,
                rng, strategy="vmap", agg_dtype=self._agg_dtype,
                spmd_axis_name=self._spmd, grad_shardings=self._shardings)
            return TreeAggregate(G), loss
        g_stack, loss = self._stack(client_update, params, cohort_batch,
                                    client_weights, lr, rng)
        spec = make_flat_spec(params)
        Gs, ssq = flat_weighted_aggregate(spec, g_stack, client_weights)
        return FlatAggregate(Gs, spec, sq_norm=ssq), loss

    def run_coded(self, client_update, params, cohort_batch, client_weights,
                  lr, rng, *, codec, comm):
        # clients still run in parallel; only the uplink stage (encode ->
        # decode -> weighted accumulate, a few flat sweeps per client)
        # walks the stacked cohort axis sequentially (repro.comm.transport)
        from repro.comm.transport import coded_aggregate_stacked
        from repro.core.flat import flatten_stacked
        g_stack, loss = self._stack(client_update, params, cohort_batch,
                                    client_weights, lr, rng)
        spec = make_flat_spec(params)
        g_groups = flatten_stacked(spec, g_stack)
        res = comm["residual"] if comm is not None else None
        Gs, new_res = coded_aggregate_stacked(codec, spec, g_groups,
                                              client_weights, res)
        new_comm = {"residual": new_res} if comm is not None else None
        return FlatAggregate(Gs, spec, sq_norm=None), loss, new_comm

    def reweightable(self, client_update, params, cohort_batch,
                     client_weights, lr, rng):
        # clients run ONCE here (loss already n_k-weighted); aggregate()
        # only re-reduces the retained stack under new weights (cheap,
        # differentiable via the aggregate kernel's custom VJP)
        spec = make_flat_spec(params)
        g_stack, loss = self._stack(client_update, params, cohort_batch,
                                    client_weights, lr, rng)

        def aggregate(weights):
            Gs, ssq = flat_weighted_aggregate(spec, g_stack, weights)
            return FlatAggregate(Gs, spec, sq_norm=ssq), loss

        return ReweightableCohort(aggregate=aggregate)


@register_executor("scan")
class ScanExecutor(CohortExecutor):
    """Client-sequential: one trajectory alive at a time.  Flat handles
    stream each client's flattened gradient into the dtype-group buffers
    (Pallas FMA; the scan carry IS the buffers); tree handles keep the
    legacy pytree carry."""
    name = "scan"
    produces = frozenset({"flat", "tree"})
    supports_reweight = True
    codec_capabilities = frozenset({"none", "lossy"})

    def __init__(self, fed, *, spmd_axis_name=None, grad_shardings=None):
        del spmd_axis_name, grad_shardings
        self._agg_dtype = jnp.dtype(fed.grad_agg_dtype)

    def run(self, client_update, params, cohort_batch, client_weights,
            lr, rng, *, kind):
        if kind == "tree":
            G, loss = cohort_gradient(
                client_update, params, cohort_batch, client_weights, lr,
                rng, strategy="scan", agg_dtype=self._agg_dtype)
            return TreeAggregate(G), loss
        spec = make_flat_spec(params)
        Gs, loss = scan_cohort_gradient_flat(
            client_update, params, cohort_batch, client_weights, lr, rng,
            spec=spec)
        return FlatAggregate(Gs, spec, sq_norm=None), loss

    def run_coded(self, client_update, params, cohort_batch, client_weights,
                  lr, rng, *, codec, comm):
        # the codec slots straight into the cohort scan: each step encodes
        # one client's flat gradient and the decode fuses into the
        # streaming FMA (kernels/comm dequantize-FMA)
        from repro.core.aggregate import scan_cohort_gradient_coded
        spec = make_flat_spec(params)
        res = comm["residual"] if comm is not None else None
        Gs, loss, new_res = scan_cohort_gradient_coded(
            client_update, params, cohort_batch, client_weights, lr, rng,
            spec=spec, codec=codec, residuals=res)
        new_comm = {"residual": new_res} if comm is not None else None
        return FlatAggregate(Gs, spec, sq_norm=None), loss, new_comm

    def reweightable(self, client_update, params, cohort_batch,
                     client_weights, lr, rng):
        # nothing is retained: aggregate() re-runs the streaming scan under
        # the new weights; the accumulate custom VJP supplies per-client
        # weight cotangents with g_k recomputed under jax.checkpoint
        spec = make_flat_spec(params)

        def aggregate(weights):
            Gs, loss = scan_cohort_gradient_flat(
                client_update, params, cohort_batch, weights, lr, rng,
                spec=spec, loss_weights=client_weights)
            return FlatAggregate(Gs, spec, sq_norm=None), loss

        return ReweightableCohort(aggregate=aggregate)


@register_executor("sharded")
class ShardedExecutor(CohortExecutor):
    """Explicitly sharded cohorts (``grad_shardings``): the per-leaf
    weighted mean keeps its sharding constraints attached, so the handle is
    always a tree and the per-client gradients are pre-aggregated — no
    reweightable form (per-client hypergradients are unavailable)."""
    name = "sharded"
    produces = frozenset({"tree"})
    supports_reweight = False

    def __init__(self, fed, *, spmd_axis_name=None, grad_shardings=None):
        if fed.cohort_strategy not in ("vmap", "scan"):
            # this executor wraps a base strategy of cohort_gradient; a
            # registry-only strategy name here would die on the bare
            # ValueError deep inside the cohort scan dispatch
            raise ValueError(
                "the sharded cohort executor wraps a base "
                f"cohort_strategy of 'vmap' or 'scan', got "
                f"{fed.cohort_strategy!r}; drop grad_shardings to run a "
                "custom executor directly")
        self._base = fed.cohort_strategy
        self._agg_dtype = jnp.dtype(fed.grad_agg_dtype)
        self._spmd = spmd_axis_name
        self._shardings = grad_shardings

    def run(self, client_update, params, cohort_batch, client_weights,
            lr, rng, *, kind):
        assert kind == "tree", kind
        G, loss = cohort_gradient(
            client_update, params, cohort_batch, client_weights, lr, rng,
            strategy=self._base, agg_dtype=self._agg_dtype,
            spmd_axis_name=self._spmd, grad_shardings=self._shardings)
        return TreeAggregate(G), loss


@register_executor("buffered_async")
class BufferedAsyncExecutor(CohortExecutor):
    """The buffered-async runtime's cohort stage: runs the local updates
    with the configured base strategy (``fed.cohort_strategy``: vmap or
    scan) but returns the **per-client flat deltas** ``(cohort, rows,
    LANES)`` instead of an aggregate handle — the delta pool
    (:mod:`repro.core.async_round`) consumes each delta individually, with
    its own staleness-weighted flush.  Not selectable as a synchronous
    executor: :meth:`run` raises, pointing at ``engine='buffered_async'``
    (the round builder routes async engines through the tick program)."""
    name = "buffered_async"
    produces = frozenset({"flat"})
    supports_reweight = False
    codec_capabilities = frozenset({"none", "lossy"})

    def __init__(self, fed, *, spmd_axis_name=None, grad_shardings=None):
        if grad_shardings is not None:
            raise ValueError(
                "the buffered_async executor keeps a replicated delta pool "
                "(per-client staleness slots), so per-leaf grad_shardings "
                "cannot apply; drop grad_shardings or use a synchronous "
                "engine")
        if fed.cohort_strategy not in ("vmap", "scan"):
            raise ValueError(
                "the buffered_async executor wraps a base cohort_strategy "
                f"of 'vmap' or 'scan', got {fed.cohort_strategy!r}")
        self._base = fed.cohort_strategy
        self._spmd = spmd_axis_name

    def run(self, client_update, params, cohort_batch, client_weights,
            lr, rng, *, kind):
        raise NotImplementedError(
            "the buffered_async executor produces per-delta stacks for the "
            "async tick program (repro.core.async_round), not a "
            "synchronous aggregate; select engine='buffered_async' so the "
            "round builder routes through it")

    def run_deltas(self, client_update, params, cohort_batch,
                   client_weights, lr, rng, *, spec):
        """(stacked flat deltas per dtype group, weighted client loss).
        ``client_weights`` only weight the loss metric here — aggregation
        weights are the pool's business at flush time."""
        if self._base == "vmap":
            from repro.core.flat import flatten_stacked
            g_stack, loss = cohort_gradient(
                client_update, params, cohort_batch, client_weights, lr,
                rng, strategy="vmap", spmd_axis_name=self._spmd,
                aggregate=False)
            return flatten_stacked(spec, g_stack), loss
        return scan_cohort_deltas_flat(
            client_update, params, cohort_batch, client_weights, lr, rng,
            spec=spec)

    def run_deltas_coded(self, client_update, params, cohort_batch,
                         client_weights, lr, rng, *, spec, codec, comm):
        """:meth:`run_deltas` + the lossy uplink: every delta is encoded,
        (optionally) error-compensated against its ``state["comm"]`` slot
        and decoded server-side BEFORE pooling — the pool stores what the
        server actually received.  Returns (decoded stacks, loss,
        new_residuals)."""
        from repro.comm.transport import coded_decode_stacked
        g_groups, loss = self.run_deltas(
            client_update, params, cohort_batch, client_weights, lr, rng,
            spec=spec)
        res = comm["residual"] if comm is not None else None
        dec, new_res = coded_decode_stacked(codec, spec, g_groups,
                                            client_weights, res)
        return dec, loss, new_res

"""Cohort-executor plugin registry — HOW a round runs its cohort.

A :class:`CohortExecutor` owns the execution strategy for the per-client
local updates and always yields a **uniform aggregate handle** so server
engines never inspect the strategy:

  * :class:`FlatAggregate` — the fused engine's per-dtype-group
    ``(rows, LANES)`` fp32 buffers holding the Eq. (14) weighted mean
    (``sq_norm`` carries ``||G||^2`` when pass 1 already reduced it);
  * :class:`TreeAggregate` — the weighted-mean pytree (the form the legacy
    tree-map engine consumes).

Every synchronous strategy is a registration over ONE chunked streaming
core (:class:`ChunkedExecutor` — ``repro.core.aggregate``'s
``_stream_flat_chunks``): the cohort is split into ``FedConfig.
cohort_chunk``-sized slices, clients vmap within a slice, and each slice's
flat gradients stream into the dtype-group accumulators via the Pallas FMA
kernels, so peak gradient memory is one chunk no matter the cohort.

  * ``chunked`` — the core itself (``chunk = cohort_chunk``);
  * ``vmap``    — ``chunk = cohort`` (whole cohort in one slice; keeps the
    retained-stack aggregate kernel for its handles);
  * ``scan``    — ``chunk = 1`` (one client trajectory alive at a time);
  * ``sharded`` — the two-tier topology: the cohort axis splits across the
    mesh batch axes under ``shard_map``, each shard streams its slice
    through the same core into per-shard partial accumulators, and a
    ``psum`` reduces them into one :class:`FlatAggregate` whose group
    buffers carry ``PartitionSpec``s (``repro.sharding.specs.
    flat_group_pspecs``).

Because all four share the streaming core, they ALL declare
``supports_reweight = True`` (per-client ``dw_k`` hypergradients via the
accumulate custom VJP, client trajectories recomputed per chunk under
``jax.checkpoint``) and lossy ``codec_capabilities`` (chunk-local
decode-FMA via ``kernels/comm``) — including ``sharded``, which used to
pre-aggregate per leaf and declare both unsupported.

Register a new strategy with :func:`register_executor`; the factory
receives the :class:`~repro.configs.base.FedConfig` plus the round
builder's sharding arguments.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.aggregate import (_chunk_cohort_inputs, _stream_flat_chunks,
                                  chunked_cohort_gradient_coded,
                                  chunked_cohort_gradient_flat,
                                  cohort_gradient, scan_cohort_deltas_flat,
                                  scan_cohort_gradient_flat)
from repro.core.flat import (FlatSpec, constrain_groups, make_flat_spec,
                             unflatten_tree, with_pspecs)
from repro.core.registry import Registry
from repro.kernels.fused_update.ops import flat_weighted_aggregate

PyTree = Any

__all__ = ["FlatAggregate", "TreeAggregate", "ReweightableCohort",
           "CohortExecutor", "register_executor", "get_executor",
           "available_executors", "resolve_executor"]


# ---------------------------------------------------------------------------
# aggregate handles
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class FlatAggregate:
    """Eq. (14) weighted mean in the fused engine's flat layout."""
    groups: list                       # per-dtype-group (rows, LANES) fp32
    spec: FlatSpec
    sq_norm: Optional[jax.Array] = None   # ||G||^2 if pass 1 computed it


@dataclasses.dataclass
class TreeAggregate:
    """Eq. (14) weighted mean as a pytree (sharding constraints intact)."""
    tree: PyTree


@dataclasses.dataclass
class ReweightableCohort:
    """A cohort whose aggregation can be re-run under different weights.

    ``aggregate(weights)`` is differentiable w.r.t. ``weights`` and returns
    ``(handle, client_loss)`` where the loss metric is weighted by the raw
    n_k the cohort was created with, so it reports the same number no
    matter what effective weights the controllable state chose."""
    aggregate: Callable      # (weights,) -> (handle, client_loss)


# ---------------------------------------------------------------------------
# executor protocol + registry
# ---------------------------------------------------------------------------
class CohortExecutor:
    """Protocol.  Subclass (or duck-type) and register a factory."""
    name: str = "?"
    produces: frozenset = frozenset()        # subset of {"flat", "tree"}
    supports_reweight: bool = False
    # which GradientCodec classes this executor can run: {"none"} means the
    # plain (uncompressed) path only; {"none", "lossy"} adds run_coded —
    # per-client encode/decode on the uplink (repro.comm)
    codec_capabilities: frozenset = frozenset({"none"})

    def run(self, client_update, params, cohort_batch, client_weights,
            lr, rng, *, kind: str) -> Tuple[Any, jax.Array]:
        """Run every client and aggregate.  Returns (handle, client_loss);
        ``kind`` is one of this executor's ``produces``."""
        raise NotImplementedError

    def run_coded(self, client_update, params, cohort_batch, client_weights,
                  lr, rng, *, codec, comm) -> Tuple[Any, jax.Array, Any]:
        """Run every client, pass each gradient through ``codec``'s
        encode/decode (the uplink simulation) and aggregate the decoded
        gradients.  ``comm`` is the error-feedback state
        (``state["comm"]``) or None.  Returns (flat handle, client_loss,
        new_comm).  Only executors declaring the 'lossy' codec capability
        implement this."""
        raise NotImplementedError(
            f"cohort executor {self.name!r} does not support lossy "
            "gradient codecs (declares codec_capabilities="
            f"{sorted(self.codec_capabilities)})")

    def reweightable(self, client_update, params, cohort_batch,
                     client_weights, lr, rng) -> ReweightableCohort:
        """Run (or prepare) the cohort so aggregation can be repeated under
        different weights; ``client_weights`` (n_k) weight the loss
        metric."""
        raise NotImplementedError(
            f"cohort executor {self.name!r} does not support reweightable "
            "aggregation")


_EXECUTORS = Registry("cohort executor",
                      "repro.core.executors.register_executor")


def register_executor(name: str):
    """Decorator registering an executor factory:
    ``factory(fed, *, spmd_axis_name, grad_shardings) -> CohortExecutor``."""
    def deco(factory: Callable) -> Callable:
        _EXECUTORS.register(name, factory)
        return factory
    return deco


def get_executor(name: str) -> Callable:
    return _EXECUTORS.get(name)


def available_executors() -> tuple:
    return _EXECUTORS.names()


def resolve_executor(fed, *, spmd_axis_name=None, grad_shardings=None,
                     executor: Optional[str] = None) -> CohortExecutor:
    """Pick the executor for a round: an explicit registry ``executor``
    name wins; otherwise ``grad_shardings`` selects the two-tier sharded
    executor, ``fed.cohort_chunk`` selects the chunked streaming executor,
    and ``fed.cohort_strategy`` selects vmap/scan."""
    if executor is None:
        if grad_shardings is not None:
            executor = "sharded"
        elif fed.cohort_chunk is not None:
            executor = "chunked"
        else:
            executor = fed.cohort_strategy
    elif grad_shardings is not None and executor != "sharded":
        # an explicit override would silently drop the constraints: only
        # the 'sharded' executor turns grad_shardings into its two-tier
        # shard_map topology (cohort split across the mesh batch axes,
        # partial flat accumulators psum-reduced).  Any other executor
        # ignores them and GSPMD would replicate the per-chunk gradient
        # buffers on every shard — the HBM blow-up the sharded executor
        # exists to prevent; fail loudly instead
        raise ValueError(
            f"grad_shardings is set but executor={executor!r} was "
            "explicitly requested; only the 'sharded' executor honors "
            "per-leaf gradient sharding constraints (two-tier shard_map "
            "aggregation). Drop the executor override (grad_shardings "
            "selects it automatically) or drop grad_shardings.")
    return get_executor(executor)(fed, spmd_axis_name=spmd_axis_name,
                                  grad_shardings=grad_shardings)


# ---------------------------------------------------------------------------
# built-in executors
# ---------------------------------------------------------------------------
@register_executor("chunked")
class ChunkedExecutor(CohortExecutor):
    """The chunked streaming core: ``cohort_chunk`` clients vmap per slice,
    each slice's flat gradients FMA into the per-dtype-group accumulators
    (Pallas streaming kernels), chunks run under an outer ``lax.scan`` with
    ``jax.checkpoint`` — peak gradient memory is ONE chunk, and the fp32
    accumulation order (hence every output bit) is invariant to the chunk
    size.  vmap/scan/sharded subclass this with pinned chunk sizes."""
    name = "chunked"
    produces = frozenset({"flat", "tree"})
    supports_reweight = True
    codec_capabilities = frozenset({"none", "lossy"})

    def __init__(self, fed, *, spmd_axis_name=None, grad_shardings=None):
        self._agg_dtype = jnp.dtype(fed.grad_agg_dtype)
        self._spmd = spmd_axis_name
        self._shardings = grad_shardings
        self._chunk = (None if fed.cohort_chunk is None
                       else int(fed.cohort_chunk))

    def _chunk_for(self, cohort: int) -> int:
        return cohort if self._chunk is None else self._chunk

    def _make_spec(self, params) -> FlatSpec:
        return make_flat_spec(params)

    # -- the one streaming primitive subclasses override -------------------
    def _flat(self, client_update, params, cohort_batch, client_weights,
              lr, rng, *, spec, loss_weights=None):
        return chunked_cohort_gradient_flat(
            client_update, params, cohort_batch, client_weights, lr, rng,
            spec=spec, chunk=self._chunk_for(client_weights.shape[0]),
            loss_weights=loss_weights, spmd_axis_name=self._spmd)

    def _coded(self, client_update, params, cohort_batch, client_weights,
               lr, rng, *, spec, codec, residuals):
        return chunked_cohort_gradient_coded(
            client_update, params, cohort_batch, client_weights, lr, rng,
            spec=spec, chunk=self._chunk_for(client_weights.shape[0]),
            codec=codec, residuals=residuals, spmd_axis_name=self._spmd)

    # -- uniform handle construction on top --------------------------------
    def run(self, client_update, params, cohort_batch, client_weights,
            lr, rng, *, kind):
        spec = self._make_spec(params)
        Gs, loss = self._flat(client_update, params, cohort_batch,
                              client_weights, lr, rng, spec=spec)
        if kind == "tree":
            # same streamed fp32 buffers, viewed as a pytree in agg dtype
            return TreeAggregate(
                unflatten_tree(spec, Gs, dtype=self._agg_dtype)), loss
        return FlatAggregate(Gs, spec, sq_norm=None), loss

    def run_coded(self, client_update, params, cohort_batch, client_weights,
                  lr, rng, *, codec, comm):
        spec = self._make_spec(params)
        res = comm["residual"] if comm is not None else None
        Gs, loss, new_res = self._coded(
            client_update, params, cohort_batch, client_weights, lr, rng,
            spec=spec, codec=codec, residuals=res)
        new_comm = {"residual": new_res} if comm is not None else None
        return FlatAggregate(Gs, spec, sq_norm=None), loss, new_comm

    def reweightable(self, client_update, params, cohort_batch,
                     client_weights, lr, rng):
        # nothing is retained: aggregate() re-streams the chunks under the
        # new weights; the accumulate custom VJP supplies per-client weight
        # cotangents with g_k recomputed chunk-by-chunk under
        # jax.checkpoint — through_aggregation at one chunk of memory
        spec = self._make_spec(params)

        def aggregate(weights):
            Gs, loss = self._flat(client_update, params, cohort_batch,
                                  weights, lr, rng, spec=spec,
                                  loss_weights=client_weights)
            return FlatAggregate(Gs, spec, sq_norm=None), loss

        return ReweightableCohort(aggregate=aggregate)


@register_executor("vmap")
class VmapExecutor(ChunkedExecutor):
    """Client-parallel: the whole cohort is one chunk.  Keeps the
    retained-stack fast path for its plain/reweightable handles — every
    local trajectory runs simultaneously, the (cohort, *param) gradient
    stack stays live, and the differentiable aggregate kernel (pass 1)
    reduces it, fusing the clip-norm ``||G||^2``.  The coded path streams
    through the chunked core (chunk = cohort: one vmap, then the
    per-client uplink scan)."""
    name = "vmap"

    def __init__(self, fed, *, spmd_axis_name=None, grad_shardings=None):
        super().__init__(fed, spmd_axis_name=spmd_axis_name,
                         grad_shardings=grad_shardings)
        self._chunk = None               # whole cohort in one slice

    def _stack(self, client_update, params, cohort_batch, client_weights,
               lr, rng):
        return cohort_gradient(
            client_update, params, cohort_batch, client_weights, lr, rng,
            strategy="vmap", agg_dtype=self._agg_dtype,
            spmd_axis_name=self._spmd, aggregate=False)

    def run(self, client_update, params, cohort_batch, client_weights,
            lr, rng, *, kind):
        if kind == "tree":
            G, loss = cohort_gradient(
                client_update, params, cohort_batch, client_weights, lr,
                rng, strategy="vmap", agg_dtype=self._agg_dtype,
                spmd_axis_name=self._spmd, grad_shardings=self._shardings)
            return TreeAggregate(G), loss
        g_stack, loss = self._stack(client_update, params, cohort_batch,
                                    client_weights, lr, rng)
        spec = make_flat_spec(params)
        Gs, ssq = flat_weighted_aggregate(spec, g_stack, client_weights)
        return FlatAggregate(Gs, spec, sq_norm=ssq), loss

    def reweightable(self, client_update, params, cohort_batch,
                     client_weights, lr, rng):
        # clients run ONCE here (loss already n_k-weighted); aggregate()
        # only re-reduces the retained stack under new weights (cheap,
        # differentiable via the aggregate kernel's custom VJP)
        spec = make_flat_spec(params)
        g_stack, loss = self._stack(client_update, params, cohort_batch,
                                    client_weights, lr, rng)

        def aggregate(weights):
            Gs, ssq = flat_weighted_aggregate(spec, g_stack, weights)
            return FlatAggregate(Gs, spec, sq_norm=ssq), loss

        return ReweightableCohort(aggregate=aggregate)


@register_executor("scan")
class ScanExecutor(ChunkedExecutor):
    """Client-sequential: the chunked core pinned at chunk = 1, one
    trajectory alive at a time.  The streamed forward (plain and coded)
    is inherited; the reweightable form keeps the dedicated cohort scan
    (:func:`repro.core.aggregate.scan_cohort_gradient_flat`) whose
    backward accumulation order the through_aggregation ctrl tests pin."""
    name = "scan"

    def __init__(self, fed, *, spmd_axis_name=None, grad_shardings=None):
        super().__init__(fed, spmd_axis_name=None, grad_shardings=None)
        self._chunk = 1                  # one client per slice

    def reweightable(self, client_update, params, cohort_batch,
                     client_weights, lr, rng):
        spec = self._make_spec(params)

        def aggregate(weights):
            Gs, loss = scan_cohort_gradient_flat(
                client_update, params, cohort_batch, weights, lr, rng,
                spec=spec, loss_weights=client_weights)
            return FlatAggregate(Gs, spec, sq_norm=None), loss

        return ReweightableCohort(aggregate=aggregate)


def _mesh_from_shardings(shardings) -> Optional[Any]:
    """The device mesh behind a grad_shardings pytree (first NamedSharding
    leaf), or None when the constraints carry no mesh (e.g. plain
    PartitionSpecs or placeholder trees) — then the sharded executor
    degrades to the single-process chunked core."""
    from jax.sharding import NamedSharding
    for leaf in jax.tree.leaves(shardings):
        if isinstance(leaf, NamedSharding):
            return leaf.mesh
    return None


@register_executor("sharded")
class ShardedExecutor(ChunkedExecutor):
    """Two-tier aggregation topology for explicitly sharded cohorts
    (``grad_shardings``).

    Tier 1: ``shard_map`` over the mesh batch axes splits the cohort —
    every shard runs its slice of clients through the chunked streaming
    core into PARTIAL per-dtype-group flat accumulators (the pre-normalized
    client weights make partial sums combine exactly).  Tier 2: one
    ``psum`` over the batch axes reduces the partials into the same
    :class:`FlatAggregate` handle every engine consumes, and the group
    buffers keep ``PartitionSpec``s (rows over the model axis, via
    :func:`repro.sharding.specs.flat_group_pspecs`) so GSPMD never
    replicates them.

    Because tier 1 IS the chunked core, the two-tier path supports
    everything the single-process executors do: ``through_aggregation``
    reweighting (per-client dw_k hypergradients recomputed per chunk under
    ``jax.checkpoint``, differentiated straight through the psum) and lossy
    codecs (chunk-local decode-FMA, per-client error-feedback residuals
    sharded over the cohort axis)."""
    name = "sharded"

    def __init__(self, fed, *, spmd_axis_name=None, grad_shardings=None):
        super().__init__(fed, spmd_axis_name=None,
                         grad_shardings=grad_shardings)
        self._mesh = _mesh_from_shardings(grad_shardings)
        if self._mesh is not None:
            from repro.sharding.specs import batch_axes
            ba = batch_axes(self._mesh)
            self._ba = ba[0] if len(ba) == 1 else ba

    def _make_spec(self, params) -> FlatSpec:
        spec = make_flat_spec(params)
        if self._mesh is not None:
            from repro.sharding.specs import flat_group_pspecs
            spec = with_pspecs(spec, flat_group_pspecs(spec, self._mesh))
        return spec

    def _two_tier(self, client_update, params, cohort_batch, client_weights,
                  lr, rng, *, spec, loss_weights=None, codec=None,
                  residuals=None):
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.sharding.specs import axis_size

        mesh, ba = self._mesh, self._ba
        n_shards = axis_size(mesh, ba)
        cohort = client_weights.shape[0]
        has_rng = rng is not None
        rngs = (jax.random.split(rng, cohort) if has_rng
                else jnp.zeros((cohort, 2), jnp.uint32))
        # normalize weights GLOBALLY (over the true cohort) so per-shard
        # partial FMAs psum to exactly the Eq. (14) weighted mean
        w32 = client_weights.astype(jnp.float32)
        wsum = jnp.maximum(jnp.sum(w32), 1e-30)
        # loss normalization issued as its own reduce (not aliased to
        # wsum), exactly like chunked_cohort_gradient_flat, keeping the
        # loss metric bit-identical to the single-host chunked core
        lw32 = (w32 if loss_weights is None
                else loss_weights.astype(jnp.float32))
        lwsum = jnp.maximum(jnp.sum(lw32), 1e-30)
        wn, lwn = w32 / wsum, lw32 / lwsum
        # pad the cohort to a shard multiple: replicated client-0 rows with
        # weight 0 (inert — acc + 0*g == acc; residual slots stay zero)
        pad = (-cohort) % n_shards
        if pad:
            def rep0(x):
                return jnp.concatenate(
                    [x, jnp.repeat(x[:1], pad, axis=0)], axis=0)
            cohort_batch = jax.tree.map(rep0, cohort_batch)
            rngs = rep0(rngs)
            wn = jnp.concatenate([wn, jnp.zeros((pad,), wn.dtype)])
            lwn = jnp.concatenate([lwn, jnp.zeros((pad,), lwn.dtype)])
        per_shard = (cohort + pad) // n_shards
        lchunk = max(1, min(self._chunk_for(cohort), per_shard))
        res_p = None
        if residuals is not None:
            res_p = jax.tree.map(
                lambda x: (jnp.concatenate(
                    [x, jnp.zeros((pad,) + x.shape[1:], x.dtype)])
                    if pad else x),
                tuple(residuals))

        def tier1(w_t, batch_l, wn_l, lwn_l, rngs_l, res_l):
            # local slice -> chunked stream -> partial accumulators
            batch_c, wn_c, lwn_c, rng_c, n_chunks, lpad = \
                _chunk_cohort_inputs(batch_l, wn_l, lwn_l, rngs_l, lchunk)
            res_c = None
            if res_l is not None:
                res_c = jax.tree.map(
                    lambda x: (jnp.concatenate(
                        [x, jnp.zeros((lpad,) + x.shape[1:], x.dtype)])
                        if lpad else x).reshape(
                            (n_chunks, lchunk) + x.shape[1:]),
                    res_l)
            G, loss, res_out = _stream_flat_chunks(
                client_update, w_t, lr, batch_c, wn_c, lwn_c, rng_c,
                spec=spec, has_rng=has_rng, codec=codec, residuals_c=res_c)
            # tier 2: the cross-shard reduce into the global aggregate
            G = tuple(jax.lax.psum(g, ba) for g in G)
            loss = jax.lax.psum(loss, ba)
            if res_out is not None:
                res_out = jax.tree.map(
                    lambda x: x.reshape((n_chunks * lchunk,) + x.shape[2:])
                    [:per_shard], res_out)
            return G, loss, res_out

        # the jit is required even under an outer jit: shard_map bodies
        # containing remat/custom_vjp calls cannot be evaluated eagerly
        fn = jax.jit(shard_map(
            tier1, mesh=mesh,
            in_specs=(P(), P(self._ba), P(self._ba), P(self._ba),
                      P(self._ba), P(self._ba)),
            out_specs=(P(), P(), P(self._ba)),
            # the accumulate/aggregate custom_vjp kernels inside the shard
            # body break shard_map's replication-rule inference
            check_rep=False))
        G, loss, res_out = fn(params, cohort_batch, wn, lwn, rngs, res_p)
        G = constrain_groups(spec, G, mesh)
        new_res = None
        if residuals is not None:
            new_res = jax.tree.map(lambda x: x[:cohort], res_out)
        return list(G), loss, new_res

    def _flat(self, client_update, params, cohort_batch, client_weights,
              lr, rng, *, spec, loss_weights=None):
        if self._mesh is None:
            return super()._flat(
                client_update, params, cohort_batch, client_weights, lr,
                rng, spec=spec, loss_weights=loss_weights)
        Gs, loss, _ = self._two_tier(
            client_update, params, cohort_batch, client_weights, lr, rng,
            spec=spec, loss_weights=loss_weights)
        return Gs, loss

    def _coded(self, client_update, params, cohort_batch, client_weights,
               lr, rng, *, spec, codec, residuals):
        if self._mesh is None:
            return super()._coded(
                client_update, params, cohort_batch, client_weights, lr,
                rng, spec=spec, codec=codec, residuals=residuals)
        return self._two_tier(
            client_update, params, cohort_batch, client_weights, lr, rng,
            spec=spec, codec=codec, residuals=residuals)


@register_executor("buffered_async")
class BufferedAsyncExecutor(CohortExecutor):
    """The buffered-async runtime's cohort stage: runs the local updates
    with the configured base strategy (``fed.cohort_strategy``: vmap or
    scan) but returns the **per-client flat deltas** ``(cohort, rows,
    LANES)`` instead of an aggregate handle — the delta pool
    (:mod:`repro.core.async_round`) consumes each delta individually, with
    its own staleness-weighted flush.  Not selectable as a synchronous
    executor: :meth:`run` raises, pointing at ``engine='buffered_async'``
    (the round builder routes async engines through the tick program)."""
    name = "buffered_async"
    produces = frozenset({"flat"})
    supports_reweight = False
    codec_capabilities = frozenset({"none", "lossy"})

    def __init__(self, fed, *, spmd_axis_name=None, grad_shardings=None):
        if grad_shardings is not None:
            raise ValueError(
                "the buffered_async executor keeps a replicated delta pool "
                "(per-client staleness slots), so per-leaf grad_shardings "
                "cannot apply; drop grad_shardings or use a synchronous "
                "engine")
        if fed.cohort_chunk is not None:
            raise ValueError(
                "cohort_chunk streams clients through an aggregate "
                "accumulator, but the buffered_async executor must keep "
                "every client's delta individually for the staleness pool "
                "— there is nothing to chunk. Drop cohort_chunk or use a "
                "synchronous engine.")
        if fed.cohort_strategy not in ("vmap", "scan"):
            raise ValueError(
                "the buffered_async executor wraps a base cohort_strategy "
                f"of 'vmap' or 'scan', got {fed.cohort_strategy!r}")
        self._base = fed.cohort_strategy
        self._spmd = spmd_axis_name

    def run(self, client_update, params, cohort_batch, client_weights,
            lr, rng, *, kind):
        raise NotImplementedError(
            "the buffered_async executor produces per-delta stacks for the "
            "async tick program (repro.core.async_round), not a "
            "synchronous aggregate; select engine='buffered_async' so the "
            "round builder routes through it")

    def run_deltas(self, client_update, params, cohort_batch,
                   client_weights, lr, rng, *, spec):
        """(stacked flat deltas per dtype group, weighted client loss).
        ``client_weights`` only weight the loss metric here — aggregation
        weights are the pool's business at flush time."""
        if self._base == "vmap":
            from repro.core.flat import flatten_stacked
            g_stack, loss = cohort_gradient(
                client_update, params, cohort_batch, client_weights, lr,
                rng, strategy="vmap", spmd_axis_name=self._spmd,
                aggregate=False)
            return flatten_stacked(spec, g_stack), loss
        return scan_cohort_deltas_flat(
            client_update, params, cohort_batch, client_weights, lr, rng,
            spec=spec)

    def run_deltas_coded(self, client_update, params, cohort_batch,
                         client_weights, lr, rng, *, spec, codec, comm):
        """:meth:`run_deltas` + the lossy uplink: every delta is encoded,
        (optionally) error-compensated against its ``state["comm"]`` slot
        and decoded server-side BEFORE pooling — the pool stores what the
        server actually received.  Returns (decoded stacks, loss,
        new_residuals)."""
        from repro.comm.transport import coded_decode_stacked
        g_groups, loss = self.run_deltas(
            client_update, params, cohort_batch, client_weights, lr, rng,
            spec=spec)
        res = comm["residual"] if comm is not None else None
        dec, new_res = coded_decode_stacked(codec, spec, g_groups,
                                            client_weights, res)
        return dec, loss, new_res

"""Unbiased weighted aggregation over the cohort (Eq. 14) and the two cohort
execution strategies:

  * ``vmap`` (client-parallel): every client's local trajectory runs
    simultaneously — maximal throughput, per-client parameter copies live
    at once (right for <~1B learners);
  * ``scan`` (client-sequential): clients run one at a time and the weighted
    gradient accumulates in the carry — one trajectory alive at a time over
    FSDP-sharded parameters (right for 90B/398B learners).  The fused
    engine's form is :func:`scan_cohort_gradient_flat`, whose carry is the
    flat-buffer layout of ``repro.core.flat`` and whose accumulate is the
    Pallas streaming FMA (``kernels/fused_update``) — no pytree-carry
    tree-maps, and its custom VJP yields per-client weight hypergradients
    (``meta_mode="through_aggregation"`` under scan cohorts).

Both produce bit-identical math (property-tested).  Under pjit, the cohort
axis of ``cohort_batch`` is sharded over the mesh (data, pod) axes so the
weighted mean lowers to an all-reduce over ICI/DCN — the FL parameter-server
gather, TPU-style.
"""
from __future__ import annotations

from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

PyTree = Any


def weighted_mean(trees: PyTree, weights: jax.Array, dtype=jnp.float32):
    """trees: pytree with leading cohort axis; weights: (cohort,) n_k."""
    w = weights.astype(jnp.float32)
    w = w / jnp.maximum(jnp.sum(w), 1e-30)

    def agg(x):
        wx = w.reshape((-1,) + (1,) * (x.ndim - 1)).astype(jnp.float32)
        return jnp.sum(x.astype(jnp.float32) * wx, axis=0).astype(dtype)

    return jax.tree.map(agg, trees)


def cohort_gradient(client_update: Callable, w_t: PyTree, cohort_batch: PyTree,
                    client_weights: jax.Array, lr, rng, *,
                    strategy: str = "vmap", agg_dtype=jnp.float32,
                    spmd_axis_name=None, grad_shardings=None,
                    aggregate: bool = True) -> Tuple[PyTree, jax.Array]:
    """Run ``client_update`` for every client and aggregate Eq.(14).

    cohort_batch: leaves (cohort, b, ...); client_weights: (cohort,) = n_k.
    ``spmd_axis_name`` (e.g. ("pod","data")) pins every per-client
    intermediate — local parameter trajectories, per-client gradients — to
    the mesh cohort axes instead of letting GSPMD replicate them (the 37x
    HBM blow-up of §Perf iteration 1).  Returns (G, mean_client_loss).

    ``aggregate=False`` (vmap strategy only) skips the weighted mean and
    returns the *stacked* per-client gradients (cohort, *param) so the
    fused server engine can do the Eq.(14) reduce inside its Pallas pass
    together with the clip-norm sum-of-squares."""
    cohort = client_weights.shape[0]
    rngs = (jax.random.split(rng, cohort) if rng is not None
            else jnp.zeros((cohort, 2), jnp.uint32))

    if strategy == "vmap":
        def one(batch, r):
            return client_update(w_t, batch,
                                 lr, r if rng is not None else None)
        g_all, losses = jax.vmap(one, spmd_axis_name=spmd_axis_name)(
            cohort_batch, rngs)
        if grad_shardings is not None:
            g_all = jax.lax.with_sharding_constraint(g_all, grad_shardings)
        wsum = jnp.maximum(jnp.sum(client_weights.astype(jnp.float32)), 1e-30)
        mean_loss = jnp.sum(losses * client_weights.astype(jnp.float32)) / wsum
        if not aggregate:
            return g_all, mean_loss
        G = weighted_mean(g_all, client_weights, agg_dtype)
        return G, mean_loss

    if strategy == "scan":
        if not aggregate:
            raise NotImplementedError(
                "stacked gradients defeat the point of the scan strategy "
                "(one client trajectory alive at a time); the fused engine "
                "streams the accumulation instead — use "
                "scan_cohort_gradient_flat")
        wsum = jnp.maximum(jnp.sum(client_weights.astype(jnp.float32)), 1e-30)

        def body(carry, inp):
            G_acc, l_acc = carry
            batch, weight, r = inp
            g_k, l_k = client_update(
                w_t, batch, lr, r if rng is not None else None)
            wk = weight.astype(jnp.float32) / wsum
            G_acc = jax.tree.map(
                lambda a, g: a + wk * g.astype(jnp.float32), G_acc, g_k)
            return (G_acc, l_acc + wk * l_k), None

        G0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), w_t)
        body = jax.checkpoint(body, prevent_cse=False)
        (G, mean_loss), _ = lax.scan(
            body, (G0, jnp.zeros((), jnp.float32)),
            (cohort_batch, client_weights, rngs))
        G = jax.tree.map(lambda g: g.astype(agg_dtype), G)
        return G, mean_loss

    raise ValueError(strategy)


def scan_cohort_gradient_flat(client_update: Callable, w_t: PyTree,
                              cohort_batch: PyTree,
                              client_weights: jax.Array, lr, rng, *,
                              spec, loss_weights: Optional[jax.Array] = None,
                              use_ref: bool = False,
                              interpret: Optional[bool] = None
                              ) -> Tuple[list, jax.Array]:
    """Client-sequential cohort execution fused into the flat-buffer engine.

    The scan carry IS the fused engine's per-dtype-group ``(rows, LANES)``
    fp32 buffers: each step runs one client's local update, flattens its
    gradient (:func:`repro.core.flat.flatten_tree` — one client in flat
    form at a time), and FMAs it into the accumulators with the Pallas
    ``accumulate_pass`` kernel — one HBM sweep per client, no pytree-carry
    tree-maps, no flatten round-trip of the aggregate.  Same per-client rng
    split and fp32 accumulation order as ``cohort_gradient(strategy=
    "scan")``, so results are bit-compatible with the legacy carry.

    Differentiable w.r.t. ``client_weights``: the accumulate custom VJP
    emits dw_k = <g_k, dG> with g_k recomputed under ``jax.checkpoint``
    (one client trajectory's residuals alive at a time) — exactly the
    ``meta_mode="through_aggregation"`` hypergradient.

    Returns (G_groups, mean_loss): the Eq. (14) weighted-mean flat buffers
    (list, one per dtype group of ``spec``) plus the weighted mean client
    loss.  Feed G_groups to ``fused_apply_flat`` for clip+optimizer+write.
    ``loss_weights`` (default: ``client_weights``) weights the loss metric
    separately from the aggregation — through_aggregation aggregates with
    the controllable eff_w but reports the n_k-weighted loss so the metric
    means the same thing on every strategy.
    """
    from repro.core import flat as flat_mod           # lazy: import cycle
    from repro.kernels.fused_update.ops import flat_accumulate

    cohort = client_weights.shape[0]
    rngs = (jax.random.split(rng, cohort) if rng is not None
            else jnp.zeros((cohort, 2), jnp.uint32))
    w32 = client_weights.astype(jnp.float32)
    wsum = jnp.maximum(jnp.sum(w32), 1e-30)
    lw32 = (w32 if loss_weights is None
            else loss_weights.astype(jnp.float32))
    lwsum = (wsum if loss_weights is None
             else jnp.maximum(jnp.sum(lw32), 1e-30))
    accum = flat_accumulate(use_ref, interpret)

    def body(carry, inp):
        accs, l_acc = carry
        batch, weight, lweight, r = inp
        g_k, l_k = client_update(
            w_t, batch, lr, r if rng is not None else None)
        wk = weight / wsum
        g_bufs = flat_mod.flatten_tree(spec, g_k)
        accs = tuple(accum(a, g, wk) for a, g in zip(accs, g_bufs))
        return (accs, l_acc + (lweight / lwsum) * l_k), None

    body = jax.checkpoint(body, prevent_cse=False)
    acc0 = tuple(flat_mod.zeros_flat(spec))
    (G, mean_loss), _ = lax.scan(
        body, (acc0, jnp.zeros((), jnp.float32)),
        (cohort_batch, w32, lw32, rngs))
    return list(G), mean_loss


def scan_cohort_deltas_flat(client_update: Callable, w_t: PyTree,
                            cohort_batch: PyTree,
                            client_weights: jax.Array, lr, rng, *,
                            spec, loss_weights: Optional[jax.Array] = None
                            ) -> Tuple[list, jax.Array]:
    """Client-sequential local updates that KEEP the per-client flat deltas
    — ``(cohort, rows, LANES)`` stacked buffers per dtype group — instead
    of accumulating them: the buffered-async pool
    (:mod:`repro.core.async_round`) needs each delta individually, so the
    scan's ys-stacking replaces the carry accumulation.  (This gives up the
    scan strategy's one-delta-alive memory profile; the async runtime pays
    it because the pool holds per-delta state anyway.)

    Per-client rng split and the sequential loss accumulation order are
    IDENTICAL to :func:`scan_cohort_gradient_flat`, so feeding these deltas
    through the same streaming-FMA sequence reproduces the synchronous
    scan aggregation bit-for-bit — the fault-free async == sync gate."""
    from repro.core import flat as flat_mod           # lazy: import cycle

    cohort = client_weights.shape[0]
    rngs = (jax.random.split(rng, cohort) if rng is not None
            else jnp.zeros((cohort, 2), jnp.uint32))
    lw32 = (client_weights if loss_weights is None
            else loss_weights).astype(jnp.float32)
    lwsum = jnp.maximum(jnp.sum(lw32), 1e-30)

    def body(l_acc, inp):
        batch, lweight, r = inp
        g_k, l_k = client_update(
            w_t, batch, lr, r if rng is not None else None)
        g_bufs = flat_mod.flatten_tree(spec, g_k)
        return l_acc + (lweight / lwsum) * l_k, tuple(g_bufs)

    body = jax.checkpoint(body, prevent_cse=False)
    mean_loss, stacked = lax.scan(
        body, jnp.zeros((), jnp.float32), (cohort_batch, lw32, rngs))
    return list(stacked), mean_loss


def scan_cohort_gradient_coded(client_update: Callable, w_t: PyTree,
                               cohort_batch: PyTree,
                               client_weights: jax.Array, lr, rng, *,
                               spec, codec, residuals: Optional[tuple] = None
                               ) -> Tuple[list, jax.Array, Optional[tuple]]:
    """:func:`scan_cohort_gradient_flat` with a lossy uplink codec
    (:mod:`repro.comm`) between each client and the accumulator: client k's
    flattened gradient is encoded, (optionally) error-compensated against
    its ``residuals`` slot, decoded server-side and folded into the flat
    Eq. (14) accumulators — for ``int8``/``sign1bit`` the decode fuses into
    the streaming FMA itself (``kernels/comm`` dequantize-FMA), so a coded
    client costs one encode sweep plus the same single FMA sweep per group
    as the uncompressed path (error feedback rides the encode sweep).

    residuals: per-group ``(cohort, rows, LANES)`` error-feedback stacks
    (``state["comm"]["residual"]``) or None.  Returns (G_groups, mean_loss,
    new_residuals) with new_residuals stacked in cohort order (None when
    ``residuals`` is None).  Not differentiable w.r.t. the weights — lossy
    codecs are ``meta_mode='post'``-only (guarded by the round builder)."""
    from repro.comm.transport import client_coded_accumulate  # lazy: cycle
    from repro.core import flat as flat_mod           # lazy: import cycle

    cohort = client_weights.shape[0]
    rngs = (jax.random.split(rng, cohort) if rng is not None
            else jnp.zeros((cohort, 2), jnp.uint32))
    w32 = client_weights.astype(jnp.float32)
    wsum = jnp.maximum(jnp.sum(w32), 1e-30)

    def body(carry, inp):
        accs, l_acc = carry
        batch, weight, r, res_k = inp
        g_k, l_k = client_update(
            w_t, batch, lr, r if rng is not None else None)
        wk = weight / wsum
        g_bufs = flat_mod.flatten_tree(spec, g_k)
        accs, r_new = client_coded_accumulate(codec, spec, accs, g_bufs,
                                              wk, res_k)
        return (accs, l_acc + wk * l_k), r_new

    body = jax.checkpoint(body, prevent_cse=False)
    acc0 = tuple(flat_mod.zeros_flat(spec))
    (G, mean_loss), new_res = lax.scan(
        body, (acc0, jnp.zeros((), jnp.float32)),
        (cohort_batch, w32, rngs, residuals))
    return list(G), mean_loss, new_res

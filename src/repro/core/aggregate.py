"""Unbiased weighted aggregation over the cohort (Eq. 14) and the two cohort
execution strategies:

  * ``vmap`` (client-parallel): every client's local trajectory runs
    simultaneously — maximal throughput, per-client parameter copies live
    at once (right for <~1B learners);
  * ``scan`` (client-sequential): clients run one at a time and the weighted
    gradient accumulates in the carry — one trajectory alive at a time over
    FSDP-sharded parameters (right for 90B/398B learners).  The fused
    engine's form is :func:`scan_cohort_gradient_flat`, whose carry is the
    flat-buffer layout of ``repro.core.flat`` and whose accumulate is the
    Pallas streaming FMA (``kernels/fused_update``) — no pytree-carry
    tree-maps, and its custom VJP yields per-client weight hypergradients
    (``meta_mode="through_aggregation"`` under scan cohorts).

Both produce bit-identical math (property-tested).  Under pjit, the cohort
axis of ``cohort_batch`` is sharded over the mesh (data, pod) axes so the
weighted mean lowers to an all-reduce over ICI/DCN — the FL parameter-server
gather, TPU-style.

:func:`chunked_cohort_gradient_flat` generalizes both into ONE streaming
core (``FedConfig.cohort_chunk``): the cohort is split into chunk-sized
slices, client training is vmapped *within* a chunk, and each chunk's flat
gradients stream into the per-dtype-group accumulators with the same Pallas
FMA — peak gradient memory is one chunk, the accumulation order is global
client order, so every fp32 bit is invariant to the chunk size.  A ragged
final chunk is padded with zero-weight clients (``acc + 0*g == acc``
bitwise).  The normalized weights are computed OUTSIDE the scans (one
vectorized divide) so the through-aggregation backward never accumulates a
shared-constant cotangent inside the nested scans — that is what keeps the
ctrl hypergradients chunk-invariant too, not just the forward pass.
"""
from __future__ import annotations

from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

PyTree = Any


def weighted_mean(trees: PyTree, weights: jax.Array, dtype=jnp.float32):
    """trees: pytree with leading cohort axis; weights: (cohort,) n_k."""
    w = weights.astype(jnp.float32)
    w = w / jnp.maximum(jnp.sum(w), 1e-30)

    def agg(x):
        wx = w.reshape((-1,) + (1,) * (x.ndim - 1)).astype(jnp.float32)
        return jnp.sum(x.astype(jnp.float32) * wx, axis=0).astype(dtype)

    return jax.tree.map(agg, trees)


def cohort_gradient(client_update: Callable, w_t: PyTree, cohort_batch: PyTree,
                    client_weights: jax.Array, lr, rng, *,
                    strategy: str = "vmap", agg_dtype=jnp.float32,
                    spmd_axis_name=None, grad_shardings=None,
                    aggregate: bool = True) -> Tuple[PyTree, jax.Array]:
    """Run ``client_update`` for every client and aggregate Eq.(14).

    cohort_batch: leaves (cohort, b, ...); client_weights: (cohort,) = n_k.
    ``spmd_axis_name`` (e.g. ("pod","data")) pins every per-client
    intermediate — local parameter trajectories, per-client gradients — to
    the mesh cohort axes instead of letting GSPMD replicate them (the 37x
    HBM blow-up of §Perf iteration 1).  Returns (G, mean_client_loss).

    ``aggregate=False`` (vmap strategy only) skips the weighted mean and
    returns the *stacked* per-client gradients (cohort, *param) so the
    fused server engine can do the Eq.(14) reduce inside its Pallas pass
    together with the clip-norm sum-of-squares."""
    cohort = client_weights.shape[0]
    rngs = (jax.random.split(rng, cohort) if rng is not None
            else jnp.zeros((cohort, 2), jnp.uint32))

    if strategy == "vmap":
        def one(batch, r):
            return client_update(w_t, batch,
                                 lr, r if rng is not None else None)
        g_all, losses = jax.vmap(one, spmd_axis_name=spmd_axis_name)(
            cohort_batch, rngs)
        if grad_shardings is not None:
            g_all = jax.lax.with_sharding_constraint(g_all, grad_shardings)
        wsum = jnp.maximum(jnp.sum(client_weights.astype(jnp.float32)), 1e-30)
        mean_loss = jnp.sum(losses * client_weights.astype(jnp.float32)) / wsum
        if not aggregate:
            return g_all, mean_loss
        G = weighted_mean(g_all, client_weights, agg_dtype)
        return G, mean_loss

    if strategy == "scan":
        if not aggregate:
            raise NotImplementedError(
                "stacked gradients defeat the point of the scan strategy "
                "(one client trajectory alive at a time); the fused engine "
                "streams the accumulation instead — use "
                "scan_cohort_gradient_flat")
        wsum = jnp.maximum(jnp.sum(client_weights.astype(jnp.float32)), 1e-30)

        def body(carry, inp):
            G_acc, l_acc = carry
            batch, weight, r = inp
            g_k, l_k = client_update(
                w_t, batch, lr, r if rng is not None else None)
            wk = weight.astype(jnp.float32) / wsum
            G_acc = jax.tree.map(
                lambda a, g: a + wk * g.astype(jnp.float32), G_acc, g_k)
            return (G_acc, l_acc + wk * l_k), None

        G0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), w_t)
        body = jax.checkpoint(body, prevent_cse=False)
        (G, mean_loss), _ = lax.scan(
            body, (G0, jnp.zeros((), jnp.float32)),
            (cohort_batch, client_weights, rngs))
        G = jax.tree.map(lambda g: g.astype(agg_dtype), G)
        return G, mean_loss

    raise ValueError(strategy)


def scan_cohort_gradient_flat(client_update: Callable, w_t: PyTree,
                              cohort_batch: PyTree,
                              client_weights: jax.Array, lr, rng, *,
                              spec, loss_weights: Optional[jax.Array] = None,
                              use_ref: bool = False,
                              interpret: Optional[bool] = None
                              ) -> Tuple[list, jax.Array]:
    """Client-sequential cohort execution fused into the flat-buffer engine.

    The scan carry IS the fused engine's per-dtype-group ``(rows, LANES)``
    fp32 buffers: each step runs one client's local update, flattens its
    gradient (:func:`repro.core.flat.flatten_tree` — one client in flat
    form at a time), and FMAs it into the accumulators with the Pallas
    ``accumulate_pass`` kernel — one HBM sweep per client, no pytree-carry
    tree-maps, no flatten round-trip of the aggregate.  Same per-client rng
    split and fp32 accumulation order as ``cohort_gradient(strategy=
    "scan")``, so results are bit-compatible with the legacy carry.

    Differentiable w.r.t. ``client_weights``: the accumulate custom VJP
    emits dw_k = <g_k, dG> with g_k recomputed under ``jax.checkpoint``
    (one client trajectory's residuals alive at a time) — exactly the
    ``meta_mode="through_aggregation"`` hypergradient.

    Returns (G_groups, mean_loss): the Eq. (14) weighted-mean flat buffers
    (list, one per dtype group of ``spec``) plus the weighted mean client
    loss.  Feed G_groups to ``fused_apply_flat`` for clip+optimizer+write.
    ``loss_weights`` (default: ``client_weights``) weights the loss metric
    separately from the aggregation — through_aggregation aggregates with
    the controllable eff_w but reports the n_k-weighted loss so the metric
    means the same thing on every strategy.
    """
    from repro.core import flat as flat_mod           # lazy: import cycle
    from repro.kernels.fused_update.ops import flat_accumulate

    cohort = client_weights.shape[0]
    rngs = (jax.random.split(rng, cohort) if rng is not None
            else jnp.zeros((cohort, 2), jnp.uint32))
    w32 = client_weights.astype(jnp.float32)
    wsum = jnp.maximum(jnp.sum(w32), 1e-30)
    lw32 = (w32 if loss_weights is None
            else loss_weights.astype(jnp.float32))
    lwsum = (wsum if loss_weights is None
             else jnp.maximum(jnp.sum(lw32), 1e-30))
    accum = flat_accumulate(use_ref, interpret)

    def body(carry, inp):
        accs, l_acc = carry
        batch, weight, lweight, r = inp
        g_k, l_k = client_update(
            w_t, batch, lr, r if rng is not None else None)
        wk = weight / wsum
        g_bufs = flat_mod.flatten_tree(spec, g_k)
        accs = tuple(accum(a, g, wk) for a, g in zip(accs, g_bufs))
        return (accs, l_acc + (lweight / lwsum) * l_k), None

    body = jax.checkpoint(body, prevent_cse=False)
    acc0 = tuple(flat_mod.zeros_flat(spec))
    (G, mean_loss), _ = lax.scan(
        body, (acc0, jnp.zeros((), jnp.float32)),
        (cohort_batch, w32, lw32, rngs))
    return list(G), mean_loss


def _chunk_cohort_inputs(cohort_batch: PyTree, wn: jax.Array, lwn: jax.Array,
                         rngs: jax.Array, chunk: int):
    """(cohort, ...) round inputs -> (n_chunks, chunk, ...) slices.

    A ragged final chunk is ZERO-WEIGHT padded: pad slots replicate client
    0's batch/rng (their gradients stay finite) but carry normalized weight
    0, and ``acc + 0 * g == acc`` bitwise for finite g — the padding is
    mathematically inert, never silently-wrong math (regression-tested in
    tests/test_chunked_executor.py)."""
    cohort = wn.shape[0]
    n_chunks = -(-cohort // chunk)
    pad = n_chunks * chunk - cohort

    def rep0(x):
        if pad:
            x = jnp.concatenate([x, jnp.repeat(x[:1], pad, axis=0)], axis=0)
        return x.reshape((n_chunks, chunk) + x.shape[1:])

    def zero(v):
        if pad:
            v = jnp.concatenate([v, jnp.zeros((pad,), v.dtype)])
        return v.reshape(n_chunks, chunk)

    return (jax.tree.map(rep0, cohort_batch), zero(wn), zero(lwn),
            rep0(rngs), n_chunks, pad)


def _stream_flat_chunks(client_update: Callable, w_t: PyTree, lr,
                        batch_c: PyTree, wn_c: jax.Array, lwn_c: jax.Array,
                        rng_c: jax.Array, *, spec, has_rng: bool,
                        spmd_axis_name=None, use_ref: bool = False,
                        interpret: Optional[bool] = None, codec=None,
                        residuals_c: Optional[tuple] = None):
    """The chunked streaming core shared by the chunked/vmap/scan executors
    and each shard of the two-tier sharded executor.

    Outer ``lax.scan`` over chunks; within a chunk the clients vmap, then an
    inner ``lax.scan`` FMAs each client's flat gradient into the per-dtype-
    group accumulators IN GLOBAL CLIENT ORDER — so the fp32 accumulation
    sequence (and every bit of the result) is invariant to the chunk size.
    Weights arrive pre-normalized (see the module docstring for why that
    also makes the through-aggregation backward chunk-invariant).  Each
    chunk body runs under ``jax.checkpoint``: the backward sweep recomputes
    one chunk of client trajectories at a time, which is where the per-chunk
    dw_k hypergradient recompute of ``meta_mode='through_aggregation'``
    comes from.

    ``codec`` switches the inner step to the lossy uplink
    (:func:`repro.comm.transport.client_coded_accumulate`, decode fused into
    the FMA); ``residuals_c`` are the matching (n_chunks, chunk, rows,
    LANES) error-feedback slices.  Returns (accs, loss, new_residuals_c)."""
    from repro.core import flat as flat_mod           # lazy: import cycle
    from repro.kernels.fused_update.ops import flat_accumulate
    if codec is not None:
        from repro.comm.transport import client_coded_accumulate
    accum = flat_accumulate(use_ref, interpret)
    coded = codec is not None
    # Keep the normalized loss weights opaque to the algebraic simplifier
    # so the metric accumulation below stays a literal mul-then-add in
    # every chunk graph (defensive: the loss chain is plain XLA ops, unlike
    # the gradient FMA whose Pallas call is already an optimization
    # boundary).
    lwn_c = lax.optimization_barrier(lwn_c)

    def chunk_body(carry, inp):
        accs, l_acc = carry
        if coded:
            cb, wc, lwc, rc, res_c = inp
        else:
            cb, wc, lwc, rc = inp

        def one(batch, r):
            return client_update(w_t, batch, lr, r if has_rng else None)

        if wn_c.shape[1] == 1 and spmd_axis_name is None:
            # chunk width 1 (the scan registration): run the client
            # UNBATCHED.  A width-1 vmap changes how XLA:CPU emits the
            # client loss reduction (observed 1-ulp per-client loss drift),
            # and this path is pinned bit-identical to the unbatched
            # legacy-scan and async-delta bodies.
            g_one, l_one = one(jax.tree.map(lambda x: x[0], cb), rc[0])
            g_stack = jax.tree.map(lambda x: x[None], g_one)
            losses = l_one[None]
        else:
            g_stack, losses = jax.vmap(one, spmd_axis_name=spmd_axis_name)(
                cb, rc)
        g_bufs = tuple(flat_mod.flatten_stacked(spec, g_stack))

        def client_body(c2, kin):
            a2, l2 = c2
            if coded:
                gk, wk, lwk, lk, res_k = kin
                a2, r_new = client_coded_accumulate(codec, spec, a2, gk,
                                                    wk, res_k)
            else:
                gk, wk, lwk, lk = kin
                a2 = tuple(accum(a, g, wk) for a, g in zip(a2, gk))
                r_new = None
            return (a2, l2 + lwk * lk), r_new

        xs = ((g_bufs, wc, lwc, losses, res_c) if coded
              else (g_bufs, wc, lwc, losses))
        (accs, l_acc), r_new_c = lax.scan(client_body, (accs, l_acc), xs)
        return (accs, l_acc), r_new_c

    chunk_body = jax.checkpoint(chunk_body, prevent_cse=False)
    acc0 = tuple(flat_mod.zeros_flat(spec))
    xs = ((batch_c, wn_c, lwn_c, rng_c, residuals_c) if coded
          else (batch_c, wn_c, lwn_c, rng_c))
    (G, mean_loss), res_out = lax.scan(
        chunk_body, (acc0, jnp.zeros((), jnp.float32)), xs)
    return G, mean_loss, res_out


def chunked_cohort_gradient_flat(client_update: Callable, w_t: PyTree,
                                 cohort_batch: PyTree,
                                 client_weights: jax.Array, lr, rng, *,
                                 spec, chunk: int,
                                 loss_weights: Optional[jax.Array] = None,
                                 spmd_axis_name=None, use_ref: bool = False,
                                 interpret: Optional[bool] = None
                                 ) -> Tuple[list, jax.Array]:
    """Chunked streaming cohort execution — the general core behind the
    ``chunked`` executor, of which ``scan`` is the chunk=1 pin.

    Same per-client rng split (over the TRUE cohort, so rng streams are
    chunking-invariant), the same normalized FMA weights and the same
    sequential loss accumulation as :func:`scan_cohort_gradient_flat`, so
    ``chunk=1`` reproduces the scan path bit-for-bit while larger chunks
    trade peak gradient memory (one chunk of trajectories) for vmap
    throughput.  Differentiable w.r.t. ``client_weights`` exactly like the
    scan form.  Returns (G_groups, mean_loss)."""
    cohort = client_weights.shape[0]
    chunk = max(1, min(int(chunk), cohort))
    rngs = (jax.random.split(rng, cohort) if rng is not None
            else jnp.zeros((cohort, 2), jnp.uint32))
    w32 = client_weights.astype(jnp.float32)
    wsum = jnp.maximum(jnp.sum(w32), 1e-30)
    # the loss-metric normalization is issued as its own reduce rather
    # than aliasing wsum (defensive): the metric chain then keeps the same
    # shape in every chunk graph no matter how the gradient normalization
    # fuses with its chunk-size-dependent consumers
    lw32 = (w32 if loss_weights is None
            else loss_weights.astype(jnp.float32))
    lwsum = jnp.maximum(jnp.sum(lw32), 1e-30)
    batch_c, wn_c, lwn_c, rng_c, _, _ = _chunk_cohort_inputs(
        cohort_batch, w32 / wsum, lw32 / lwsum, rngs, chunk)
    G, mean_loss, _ = _stream_flat_chunks(
        client_update, w_t, lr, batch_c, wn_c, lwn_c, rng_c, spec=spec,
        has_rng=rng is not None, spmd_axis_name=spmd_axis_name,
        use_ref=use_ref, interpret=interpret)
    return list(G), mean_loss


def chunked_cohort_gradient_coded(client_update: Callable, w_t: PyTree,
                                  cohort_batch: PyTree,
                                  client_weights: jax.Array, lr, rng, *,
                                  spec, chunk: int, codec,
                                  residuals: Optional[tuple] = None,
                                  spmd_axis_name=None
                                  ) -> Tuple[list, jax.Array,
                                             Optional[tuple]]:
    """:func:`chunked_cohort_gradient_flat` with the lossy uplink codec
    between each client and the accumulator (chunk-local decode-FMA via
    ``kernels/comm``) — the chunked generalization of
    :func:`scan_cohort_gradient_coded` (identical at ``chunk=1``; loss is
    weighted by the aggregation weights like the scan-coded path).

    ``residuals``: per-group ``(cohort, rows, LANES)`` error-feedback
    stacks or None.  Pad slots of a ragged chunk carry weight 0, so the
    codec's transmitted-gate leaves their (zero) residuals untouched and
    the unpad slice drops them.  Returns (G_groups, mean_loss,
    new_residuals) in cohort order."""
    cohort = client_weights.shape[0]
    chunk = max(1, min(int(chunk), cohort))
    rngs = (jax.random.split(rng, cohort) if rng is not None
            else jnp.zeros((cohort, 2), jnp.uint32))
    w32 = client_weights.astype(jnp.float32)
    wsum = jnp.maximum(jnp.sum(w32), 1e-30)
    wn = w32 / wsum
    batch_c, wn_c, lwn_c, rng_c, n_chunks, pad = _chunk_cohort_inputs(
        cohort_batch, wn, wn, rngs, chunk)
    res_c = None
    if residuals is not None:
        def pad_res(x):
            if pad:
                x = jnp.concatenate(
                    [x, jnp.zeros((pad,) + x.shape[1:], x.dtype)], axis=0)
            return x.reshape((n_chunks, chunk) + x.shape[1:])
        res_c = jax.tree.map(pad_res, tuple(residuals))
    G, mean_loss, res_out = _stream_flat_chunks(
        client_update, w_t, lr, batch_c, wn_c, lwn_c, rng_c, spec=spec,
        has_rng=rng is not None, spmd_axis_name=spmd_axis_name,
        codec=codec, residuals_c=res_c)
    new_res = None
    if residuals is not None:
        new_res = jax.tree.map(
            lambda x: x.reshape((n_chunks * chunk,) + x.shape[2:])[:cohort],
            res_out)
    return list(G), mean_loss, new_res


def scan_cohort_deltas_flat(client_update: Callable, w_t: PyTree,
                            cohort_batch: PyTree,
                            client_weights: jax.Array, lr, rng, *,
                            spec, loss_weights: Optional[jax.Array] = None
                            ) -> Tuple[list, jax.Array]:
    """Client-sequential local updates that KEEP the per-client flat deltas
    — ``(cohort, rows, LANES)`` stacked buffers per dtype group — instead
    of accumulating them: the buffered-async pool
    (:mod:`repro.core.async_round`) needs each delta individually, so the
    scan's ys-stacking replaces the carry accumulation.  (This gives up the
    scan strategy's one-delta-alive memory profile; the async runtime pays
    it because the pool holds per-delta state anyway.)

    Per-client rng split and the sequential loss accumulation order are
    IDENTICAL to :func:`scan_cohort_gradient_flat`, so feeding these deltas
    through the same streaming-FMA sequence reproduces the synchronous
    scan aggregation bit-for-bit — the fault-free async == sync gate."""
    from repro.core import flat as flat_mod           # lazy: import cycle

    cohort = client_weights.shape[0]
    rngs = (jax.random.split(rng, cohort) if rng is not None
            else jnp.zeros((cohort, 2), jnp.uint32))
    lw32 = (client_weights if loss_weights is None
            else loss_weights).astype(jnp.float32)
    lwsum = jnp.maximum(jnp.sum(lw32), 1e-30)

    def body(l_acc, inp):
        batch, lweight, r = inp
        g_k, l_k = client_update(
            w_t, batch, lr, r if rng is not None else None)
        g_bufs = flat_mod.flatten_tree(spec, g_k)
        return l_acc + (lweight / lwsum) * l_k, tuple(g_bufs)

    body = jax.checkpoint(body, prevent_cse=False)
    mean_loss, stacked = lax.scan(
        body, jnp.zeros((), jnp.float32), (cohort_batch, lw32, rngs))
    return list(stacked), mean_loss


def scan_cohort_gradient_coded(client_update: Callable, w_t: PyTree,
                               cohort_batch: PyTree,
                               client_weights: jax.Array, lr, rng, *,
                               spec, codec, residuals: Optional[tuple] = None
                               ) -> Tuple[list, jax.Array, Optional[tuple]]:
    """:func:`scan_cohort_gradient_flat` with a lossy uplink codec
    (:mod:`repro.comm`) between each client and the accumulator: client k's
    flattened gradient is encoded, (optionally) error-compensated against
    its ``residuals`` slot, decoded server-side and folded into the flat
    Eq. (14) accumulators — for ``int8``/``sign1bit`` the decode fuses into
    the streaming FMA itself (``kernels/comm`` dequantize-FMA), so a coded
    client costs one encode sweep plus the same single FMA sweep per group
    as the uncompressed path (error feedback rides the encode sweep).

    residuals: per-group ``(cohort, rows, LANES)`` error-feedback stacks
    (``state["comm"]["residual"]``) or None.  Returns (G_groups, mean_loss,
    new_residuals) with new_residuals stacked in cohort order (None when
    ``residuals`` is None).  Not differentiable w.r.t. the weights — lossy
    codecs are ``meta_mode='post'``-only (guarded by the round builder)."""
    from repro.comm.transport import client_coded_accumulate  # lazy: cycle
    from repro.core import flat as flat_mod           # lazy: import cycle

    cohort = client_weights.shape[0]
    rngs = (jax.random.split(rng, cohort) if rng is not None
            else jnp.zeros((cohort, 2), jnp.uint32))
    w32 = client_weights.astype(jnp.float32)
    wsum = jnp.maximum(jnp.sum(w32), 1e-30)

    def body(carry, inp):
        accs, l_acc = carry
        batch, weight, r, res_k = inp
        g_k, l_k = client_update(
            w_t, batch, lr, r if rng is not None else None)
        wk = weight / wsum
        g_bufs = flat_mod.flatten_tree(spec, g_k)
        accs, r_new = client_coded_accumulate(codec, spec, accs, g_bufs,
                                              wk, res_k)
        return (accs, l_acc + wk * l_k), r_new

    body = jax.checkpoint(body, prevent_cse=False)
    acc0 = tuple(flat_mod.zeros_flat(spec))
    (G, mean_loss), new_res = lax.scan(
        body, (acc0, jnp.zeros((), jnp.float32)),
        (cohort_batch, w32, rngs, residuals))
    return list(G), mean_loss, new_res

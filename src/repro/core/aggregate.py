"""Unbiased weighted aggregation over the cohort (Eq. 14) and the two cohort
execution strategies:

  * ``vmap`` (client-parallel): every client's local trajectory runs
    simultaneously — maximal throughput, per-client parameter copies live
    at once (right for <~1B learners);
  * ``scan`` (client-sequential): clients run one at a time and the weighted
    gradient accumulates in the carry — one trajectory alive at a time over
    FSDP-sharded parameters (right for 90B/398B learners).

Both produce bit-identical math (property-tested).  Under pjit, the cohort
axis of ``cohort_batch`` is sharded over the mesh (data, pod) axes so the
weighted mean lowers to an all-reduce over ICI/DCN — the FL parameter-server
gather, TPU-style.
"""
from __future__ import annotations

from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

PyTree = Any


def weighted_mean(trees: PyTree, weights: jax.Array, dtype=jnp.float32):
    """trees: pytree with leading cohort axis; weights: (cohort,) n_k."""
    w = weights.astype(jnp.float32)
    w = w / jnp.maximum(jnp.sum(w), 1e-30)

    def agg(x):
        wx = w.reshape((-1,) + (1,) * (x.ndim - 1)).astype(jnp.float32)
        return jnp.sum(x.astype(jnp.float32) * wx, axis=0).astype(dtype)

    return jax.tree.map(agg, trees)


def cohort_gradient(client_update: Callable, w_t: PyTree, cohort_batch: PyTree,
                    client_weights: jax.Array, lr, rng, *,
                    strategy: str = "vmap", agg_dtype=jnp.float32,
                    spmd_axis_name=None, grad_shardings=None,
                    aggregate: bool = True) -> Tuple[PyTree, jax.Array]:
    """Run ``client_update`` for every client and aggregate Eq.(14).

    cohort_batch: leaves (cohort, b, ...); client_weights: (cohort,) = n_k.
    ``spmd_axis_name`` (e.g. ("pod","data")) pins every per-client
    intermediate — local parameter trajectories, per-client gradients — to
    the mesh cohort axes instead of letting GSPMD replicate them (the 37x
    HBM blow-up of §Perf iteration 1).  Returns (G, mean_client_loss).

    ``aggregate=False`` (vmap strategy only) skips the weighted mean and
    returns the *stacked* per-client gradients (cohort, *param) so the
    fused server engine can do the Eq.(14) reduce inside its Pallas pass
    together with the clip-norm sum-of-squares."""
    cohort = client_weights.shape[0]
    rngs = (jax.random.split(rng, cohort) if rng is not None
            else jnp.zeros((cohort, 2), jnp.uint32))

    if strategy == "vmap":
        def one(batch, r):
            return client_update(w_t, batch,
                                 lr, r if rng is not None else None)
        g_all, losses = jax.vmap(one, spmd_axis_name=spmd_axis_name)(
            cohort_batch, rngs)
        if grad_shardings is not None:
            g_all = jax.lax.with_sharding_constraint(g_all, grad_shardings)
        wsum = jnp.maximum(jnp.sum(client_weights.astype(jnp.float32)), 1e-30)
        mean_loss = jnp.sum(losses * client_weights.astype(jnp.float32)) / wsum
        if not aggregate:
            return g_all, mean_loss
        G = weighted_mean(g_all, client_weights, agg_dtype)
        return G, mean_loss

    if strategy == "scan":
        if not aggregate:
            raise NotImplementedError(
                "stacked gradients defeat the point of the scan strategy "
                "(one client trajectory alive at a time); the fused engine "
                "feeds the scan-accumulated G through its clip+apply pass "
                "instead — see ROADMAP 'scan-strategy cohort fusion'")
        wsum = jnp.maximum(jnp.sum(client_weights.astype(jnp.float32)), 1e-30)

        def body(carry, inp):
            G_acc, l_acc = carry
            batch, weight, r = inp
            g_k, l_k = client_update(
                w_t, batch, lr, r if rng is not None else None)
            wk = weight.astype(jnp.float32) / wsum
            G_acc = jax.tree.map(
                lambda a, g: a + wk * g.astype(jnp.float32), G_acc, g_k)
            return (G_acc, l_acc + wk * l_k), None

        G0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), w_t)
        body = jax.checkpoint(body, prevent_cse=False)
        (G, mean_loss), _ = lax.scan(
            body, (G0, jnp.zeros((), jnp.float32)),
            (cohort_batch, client_weights, rngs))
        G = jax.tree.map(lambda g: g.astype(agg_dtype), G)
        return G, mean_loss

    raise ValueError(strategy)

"""Flat-buffer view of parameter/gradient pytrees for the fused server
update engine.

The server hot path (aggregate -> clip -> optimizer apply) is element-wise
over every parameter, so the pytree structure only costs traversals there.
This module gives the round engine a *flat* view: leaves are grouped by
their original dtype, raveled, cast to fp32 and packed into one contiguous
``(rows, 128)`` fp32 buffer per dtype group with **static** element offsets
computed at trace time.  ``rows`` is padded to a multiple of ``row_align``
(8 = the fp32 sublane tile) so the Pallas kernels in
``repro.kernels.fused_update`` can tile the buffer directly; the zero pad
is mathematically inert for every supported optimizer (0-gradient => 0
update) and is dropped again by :func:`unflatten_tree`.

Round-trip contract (property-tested): ``unflatten_tree(spec,
flatten_tree(spec, tree))`` preserves structure, shapes and dtypes, with
values equal up to the fp32 cast the legacy tree-map path performs anyway.
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any

LANES = 128           # TPU lane dimension; last axis of every flat buffer


@dataclasses.dataclass(frozen=True)
class LeafSpec:
    index: int                     # position in jax.tree flatten order
    shape: Tuple[int, ...]
    dtype: str                     # original dtype (cast-back target)
    offset: int                    # element offset inside the group buffer
    size: int


@dataclasses.dataclass(frozen=True)
class GroupSpec:
    dtype: str                     # shared original dtype of the leaves
    leaves: Tuple[LeafSpec, ...]
    size: int                      # total elements (before padding)
    rows: int                      # padded row count: rows * LANES >= size
    # optional jax.sharding.PartitionSpec for the (rows, LANES) buffer —
    # attached by the two-tier sharded executor (via
    # repro.sharding.specs.flat_group_pspecs) so engines can keep the
    # aggregate buffers row-partitioned across the model axis instead of
    # replicating them after the cross-shard psum.  None = replicated.
    pspec: Any = None


@dataclasses.dataclass(frozen=True)
class FlatSpec:
    treedef: Any
    groups: Tuple[GroupSpec, ...]

    @property
    def num_leaves(self) -> int:
        return sum(len(g.leaves) for g in self.groups)


def make_flat_spec(tree: PyTree, *, row_align: int = 8) -> FlatSpec:
    """Build the static layout for ``tree`` (works on arrays or
    ShapeDtypeStructs).  Groups are keyed by original leaf dtype in first-
    appearance order; offsets follow tree-flatten order within a group."""
    leaves, treedef = jax.tree.flatten(tree)
    by_dtype: dict = {}
    for i, leaf in enumerate(leaves):
        dt = jnp.dtype(leaf.dtype).name
        by_dtype.setdefault(dt, []).append((i, leaf))
    groups = []
    for dt, members in by_dtype.items():
        specs, off = [], 0
        for i, leaf in members:
            size = int(np.prod(leaf.shape)) if leaf.shape else 1
            specs.append(LeafSpec(index=i, shape=tuple(leaf.shape), dtype=dt,
                                  offset=off, size=size))
            off += size
        rows = -(-off // LANES)                      # ceil
        rows = -(-rows // row_align) * row_align     # pad to sublane tile
        groups.append(GroupSpec(dtype=dt, leaves=tuple(specs), size=off,
                                rows=rows))
    return FlatSpec(treedef=treedef, groups=tuple(groups))


def _pack(parts: Sequence[jax.Array], size: int, rows: int,
          lead: Tuple[int, ...] = ()) -> jax.Array:
    buf = jnp.concatenate(parts, axis=-1) if len(parts) > 1 else parts[0]
    pad = rows * LANES - size
    if pad:
        buf = jnp.pad(buf, [(0, 0)] * len(lead) + [(0, pad)])
    return buf.reshape(lead + (rows, LANES))


def flatten_tree(spec: FlatSpec, tree: PyTree) -> List[jax.Array]:
    """tree -> one (rows, LANES) fp32 buffer per dtype group.

    Also the *per-client streaming* flatten of the scan cohort strategy:
    called once per client inside the cohort scan, so only ONE client's
    gradient is ever in flat form — the (cohort, rows, LANES) stack of
    :func:`flatten_stacked` never materializes."""
    leaves = jax.tree.leaves(tree)
    out = []
    for g in spec.groups:
        parts = [leaves[l.index].astype(jnp.float32).reshape(l.size)
                 for l in g.leaves]
        out.append(_pack(parts, g.size, g.rows))
    return out


def flatten_stacked(spec: FlatSpec, tree: PyTree) -> List[jax.Array]:
    """tree with a leading cohort axis on every leaf -> one
    (cohort, rows, LANES) fp32 buffer per dtype group."""
    leaves = jax.tree.leaves(tree)
    cohort = leaves[0].shape[0]
    out = []
    for g in spec.groups:
        parts = [leaves[l.index].astype(jnp.float32).reshape(cohort, l.size)
                 for l in g.leaves]
        out.append(_pack(parts, g.size, g.rows, lead=(cohort,)))
    return out


def unflatten_tree(spec: FlatSpec, bufs: Sequence[jax.Array],
                   dtype=None) -> PyTree:
    """Inverse of :func:`flatten_tree` — original structure/shapes/dtypes.

    ``dtype`` overrides the cast-back target for every leaf: the chunked
    executor's tree handle aggregates in fp32 flat buffers but must hand
    the engine a tree in ``grad_agg_dtype`` (one cast, not a lossy
    fp32 -> leaf-dtype -> agg-dtype double hop)."""
    leaves: List[Any] = [None] * spec.num_leaves
    for g, buf in zip(spec.groups, bufs):
        flat = buf.reshape(g.rows * LANES)
        for l in g.leaves:
            x = jax.lax.slice(flat, (l.offset,), (l.offset + l.size,))
            leaves[l.index] = x.reshape(l.shape).astype(
                jnp.dtype(l.dtype) if dtype is None else jnp.dtype(dtype))
    return jax.tree.unflatten(spec.treedef, leaves)


def unflatten_stacked(spec: FlatSpec, bufs: Sequence[jax.Array]) -> PyTree:
    """Inverse of :func:`flatten_stacked` — buffers with a leading cohort
    axis ``(cohort, rows, LANES)`` back to the original structure with the
    cohort axis on every leaf.  Completes the round-trip API for stacked
    buffers; nothing on the hot path calls it (the custom-VJP boundary
    sits at buffer level), but it is the tool for offline inspection of
    per-client cotangents in model coordinates."""
    leaves: List[Any] = [None] * spec.num_leaves
    for g, buf in zip(spec.groups, bufs):
        cohort = buf.shape[0]
        flat = buf.reshape(cohort, g.rows * LANES)
        for l in g.leaves:
            x = jax.lax.slice(flat, (0, l.offset), (cohort, l.offset + l.size))
            leaves[l.index] = x.reshape((cohort,) + l.shape).astype(
                jnp.dtype(l.dtype))
    return jax.tree.unflatten(spec.treedef, leaves)


def with_pspecs(spec: FlatSpec, pspecs: Sequence[Any]) -> FlatSpec:
    """Attach one ``PartitionSpec`` per dtype group (see
    :func:`repro.sharding.specs.flat_group_pspecs`).  The spec stays a
    static trace-time constant — the pspec rides along exactly like
    ``rows`` so every consumer of the group buffers (engines, codecs,
    checkpointing) can recover the intended placement."""
    assert len(pspecs) == len(spec.groups), (len(pspecs), len(spec.groups))
    return FlatSpec(treedef=spec.treedef, groups=tuple(
        dataclasses.replace(g, pspec=p)
        for g, p in zip(spec.groups, pspecs)))


def constrain_groups(spec: FlatSpec, bufs: Sequence[jax.Array],
                     mesh=None) -> List[jax.Array]:
    """Apply each group's ``pspec`` as a ``with_sharding_constraint`` so
    GSPMD keeps the aggregate buffers partitioned (a no-op for groups
    without a pspec, or when no mesh is known)."""
    if mesh is None:
        return list(bufs)
    from jax.sharding import NamedSharding
    out = []
    for g, b in zip(spec.groups, bufs):
        if g.pspec is not None:
            b = jax.lax.with_sharding_constraint(
                b, NamedSharding(mesh, g.pspec))
        out.append(b)
    return out


def zeros_flat(spec: FlatSpec) -> List[jax.Array]:
    """Zero fp32 buffers in the spec's layout (optimizer state slots and
    the scan strategy's streaming accumulator carry)."""
    return [jnp.zeros((g.rows, LANES), jnp.float32) for g in spec.groups]


def flat_sq_norm(bufs: Sequence[jax.Array]) -> jax.Array:
    """||tree||^2 over flat group buffers.  The zero pad contributes
    nothing, so this equals the per-leaf sum of squares exactly."""
    ssq = jnp.float32(0.0)
    for b in bufs:
        ssq = ssq + jnp.sum(b * b)
    return ssq

"""FederatedTrainer — the one driver loop every entry point shares.

Before this facade, ``launch/train.py``, ``benchmarks/common.py`` and the
examples each re-implemented the same loop: a :class:`~repro.core.round.
RoundFnCache` of jitted round programs, per-chunk host sampling,
``stack_round_inputs`` for ``rounds_per_call`` chunking, checkpoint/resume
of the full server state, and per-round history assembly — with separate
``k == 1`` / ``k > 1`` branches in each copy.  The trainer owns all of it
once:

    trainer = FederatedTrainer(model, fed, rounds_per_call=4, seed=0)
    trainer.restore(path)                      # optional resume
    history = trainer.run(data, rounds=100, cohort=8, batch=32)
    trainer.save(path)

``run`` samples each chunk from a :class:`~repro.data.pipeline.
FederatedData`, dispatches one donated program per chunk (metrics sync to
host once per chunk), and returns one record per round
(``{"round": r, **metrics}``).  Hooks:

  * ``sample_meta(data, round_idx, meta_batch, sample)`` — override D_meta
    sampling (default: ``data.sample_meta`` when ``fed.meta``, else None so
    no meta batch is ever shipped);
  * ``on_records(recs, trainer)`` — called after every chunk with that
    chunk's records (eval scheduling, early stopping, custom logging).

Plugin selection (``algorithm`` / ``executor`` / ``engine`` registry names)
passes through to :func:`repro.core.round.make_federated_round`.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import restore as ckpt_restore
from repro.checkpoint import save as ckpt_save
from repro.configs.base import FedConfig
from repro.core.rngtags import round_key
from repro.core.round import (RoundFnCache, init_server_state,
                              stack_round_inputs)
from repro.data.pipeline import FederatedData
from repro.models.model import Model
from repro.sim.faults import client_failed_mask, fault_streams, resolve_faults

PyTree = Any

__all__ = ["FederatedTrainer"]


class FederatedTrainer:
    """Owns server state + jitted round programs + the chunked host loop."""

    def __init__(self, model: Model, fed: FedConfig, *,
                 rounds_per_call: int = 1, donate: bool = True,
                 seed: int = 0, key: Optional[jax.Array] = None,
                 engine: Optional[str] = None, sanitize: bool = False,
                 **round_kwargs):
        self.model = model
        self.fed = fed
        self.rounds_per_call = max(int(rounds_per_call), 1)
        if engine is not None:
            round_kwargs["engine"] = engine
        self._cache = RoundFnCache(model, fed, donate=donate,
                                   sanitize=sanitize, **round_kwargs)
        self.key = key if key is not None else jax.random.PRNGKey(seed)
        self.state = init_server_state(model, fed, self.key, engine=engine)
        self.history: List[Dict[str, float]] = []
        # retry-with-backoff bookkeeping (fed.retry_backoff > 0): failed
        # client id -> attempts so far, and due round -> ids to re-enqueue
        self._retry_attempts: Dict[int, int] = {}
        self._retry_due: Dict[int, List[int]] = {}

    # ---- state management -------------------------------------------------
    @property
    def round(self) -> int:
        """Host-side round counter (syncs the device scalar)."""
        return int(self.state["round"])

    def save(self, path: str, extra: Optional[dict] = None) -> None:
        """Full server state — params, optimizer state (incl. the fused
        engine's tuple-structured flat buffers), the controllable-weights
        slot when present, and the round counter — so :meth:`restore`
        continues mid-run without losing FedOpt momentum or meta-learned
        weights."""
        ckpt_save(path, self.state, extra=extra or {})

    def restore(self, path: str) -> dict:
        """Resume from a checkpoint written by :meth:`save`; returns the
        checkpoint's ``extra`` metadata."""
        self.state, extra = ckpt_restore(path, self.state)
        return extra

    # ---- the driver loop --------------------------------------------------
    def run(self, data: FederatedData, *, rounds: int, cohort: int,
            batch: int, meta_batch: int = 32, share: Optional[bool] = None,
            sample_meta: Optional[Callable] = None,
            on_records: Optional[Callable] = None, log_every: int = 0,
            log_fn: Callable = print) -> List[Dict[str, float]]:
        """Train from the current round counter up to ``rounds`` total.
        Returns this call's per-round records (also appended to
        ``self.history``)."""
        share = self.fed.share if share is None else share
        t0 = time.time()
        run_history: List[Dict[str, float]] = []
        r = self.round
        faults = resolve_faults(self.fed)
        # degradation policy: with faults on and retry_backoff > 0, clients
        # whose report was lost (crash / drop / past the round deadline) are
        # re-enqueued retry_backoff * 2^attempt rounds later, retry_max
        # consecutive failures per client
        retry_on = (self.fed.retry_backoff > 0 and faults.active
                    and (faults.crash > 0 or faults.drop > 0
                         or faults.deadline > 0))
        while r < rounds:
            k = min(self.rounds_per_call, rounds - r)
            due = [self._retry_due.pop(r + j, None) if retry_on else None
                   for j in range(k)]
            samples = [data.sample_round(r + j, cohort=cohort, batch=batch,
                                         share=share, include=due[j])
                       for j in range(k)]
            metas = [self._sample_meta(sample_meta, data, r + j, meta_batch,
                                       samples[j])
                     for j in range(k)]
            rngs = [round_key(self.key, r + j) for j in range(k)]
            metrics = self._dispatch(samples, metas, rngs)

            # THE record assembly — every driver shares this one.  Vector
            # metrics (e.g. the async runtime's staleness_hist) become
            # plain lists so records stay JSON-serializable.
            recs = [{name: (float(v[j]) if jnp.ndim(v[j]) == 0
                            else np.asarray(v[j], dtype=float).tolist())
                     for name, v in metrics.items()}
                    for j in range(k)]
            if retry_on:
                self._schedule_retries(samples, rngs, recs, due, r, k,
                                       faults)
            for j, rec in enumerate(recs):
                rec["round"] = r + j
                run_history.append(rec)
                self.history.append(rec)
                if log_every and ((r + j) % log_every == 0
                                  or r + j == rounds - 1):
                    log_fn(f"[train] round {r + j:4d} " +
                           " ".join(f"{name}={v:.4f}"
                                    for name, v in rec.items()
                                    if name != "round"
                                    and isinstance(v, float)) +
                           f" ({time.time() - t0:.1f}s)")
            if on_records is not None:
                on_records(recs, self)
            r += k
        return run_history

    def _schedule_retries(self, samples, rngs, recs, due, r, k, faults):
        """Host-side mirror of the jitted round's fault draws: the fold in
        :func:`repro.sim.faults.fault_streams` is deterministic in the
        round rng, so recomputing the streams here agrees bit-for-bit with
        what the device masked out.  Failed clients are re-enqueued with
        exponential backoff, deferred past the current chunk (the chunk's
        cohorts were already sampled)."""
        cohort = len(samples[0]["clients"])
        for j in range(k):
            fs = fault_streams(rngs[j], cohort, faults)
            failed = np.asarray(client_failed_mask(fs, faults))
            clients = np.asarray(samples[j]["clients"])
            recs[j]["retried"] = float(len(set(due[j] or [])
                                          & set(clients.tolist())))
            for cid in clients[~failed]:
                self._retry_attempts.pop(int(cid), None)
            for cid in clients[failed]:
                cid = int(cid)
                a = self._retry_attempts.get(cid, 0)
                if a >= self.fed.retry_max:
                    continue
                self._retry_attempts[cid] = a + 1
                due_round = max(r + j + self.fed.retry_backoff * (2 ** a),
                                r + k)
                self._retry_due.setdefault(due_round, []).append(cid)

    def _sample_meta(self, sample_meta, data, round_idx, meta_batch, sample):
        if sample_meta is not None:
            return sample_meta(data, round_idx, meta_batch, sample)
        # No FedMeta step -> no D_meta sampling: the round_fn never touches
        # meta_batch when fed.meta is False, so ship None (an empty pytree
        # threads through stack_round_inputs and jit untouched)
        return data.sample_meta(round_idx, meta_batch) if self.fed.meta \
            else None

    def _dispatch(self, samples, metas, rngs) -> Dict[str, jax.Array]:
        """One donated program for the chunk; metrics come back with a
        leading K axis for k == 1 too, so record assembly exists once."""
        k = len(samples)
        if k == 1:
            self.state, metrics = self._cache(1)(
                self.state,
                jax.tree.map(jnp.asarray, samples[0]["cohort_batch"]),
                jax.tree.map(jnp.asarray, metas[0]),
                jnp.asarray(samples[0]["client_weights"]), rngs[0])
            return {name: v[None] for name, v in metrics.items()}
        cb, mb, wts, rks = stack_round_inputs(
            [s["cohort_batch"] for s in samples], metas,
            [s["client_weights"] for s in samples], rngs)
        self.state, metrics = self._cache(k)(self.state, cb, mb, wts, rks)
        return metrics

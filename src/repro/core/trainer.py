"""FederatedTrainer — the one driver loop every entry point shares.

Before this facade, ``launch/train.py``, ``benchmarks/common.py`` and the
examples each re-implemented the same loop: a :class:`~repro.core.round.
RoundFnCache` of jitted round programs, per-chunk host sampling,
``stack_round_inputs`` for ``rounds_per_call`` chunking, checkpoint/resume
of the full server state, and per-round history assembly — with separate
``k == 1`` / ``k > 1`` branches in each copy.  The trainer owns all of it
once:

    trainer = FederatedTrainer(model, fed, rounds_per_call=4, seed=0,
                               tracker="jsonl", run_dir="runs/exp0")
    trainer.restore(path)                      # optional resume
    history = trainer.run(data, rounds=100, cohort=8, batch=32)
    trainer.save(path)
    trainer.finish()

``run`` samples each chunk from a :class:`~repro.data.pipeline.
FederatedData`, dispatches one donated program per chunk (metrics sync to
host once per chunk), and returns one record per round
(``{"round": r, **metrics}``).  Hooks:

  * ``sample_meta(data, round_idx, meta_batch, sample)`` — override D_meta
    sampling (default: ``data.sample_meta`` when ``fed.meta``, else None so
    no meta batch is ever shipped);
  * ``on_records(recs, trainer)`` — called after every chunk with that
    chunk's records (eval scheduling, early stopping, custom logging).

Observability (``repro.obs``): every record is fed to the trainer's
:class:`~repro.obs.MetricsTracker` (``tracker=`` — a registry name,
instance, or comma list; default ``noop``), each chunk's host phases
(``sample_stack`` / ``dispatch`` / ``device_sync`` / ``checkpoint``) are
emitted as ``phase`` events, and ``profile=N`` captures a JAX trace for
rounds ``[profile_start, profile_start+N)`` into ``run_dir/profile``.
The analysis layer rides on top: ``trace_summary=True`` parses the
closed capture into a ``profile_summary`` event (top ops by self time,
busy/gap, per-phase attribution — ``repro.obs.trace_analysis``) and
``roofline=True`` emits a ``roofline`` event per compiled chunk program
(trip-count-aware predicted cost vs the measured dispatch + device-sync
throughput — ``repro.roofline.live``).
The legacy ``log_every``/``log_fn`` arguments still work: they compose a
``console`` tracker into the run's sink.

Managed checkpointing: ``checkpoint_every=N`` (with a ``run_dir``) saves
the full server state — and the run history, so a resumed run carries its
curve — every N rounds plus once at run end, through a background
:class:`~repro.checkpoint.CheckpointManager` with ``keep_last`` /
``keep_every`` retention; ``resume_latest()`` picks up the newest blob.

Plugin selection (``algorithm`` / ``executor`` / ``engine`` registry names)
passes through to :func:`repro.core.round.make_federated_round`.
"""
from __future__ import annotations

import contextlib
import os
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.checkpoint import restore as ckpt_restore
from repro.checkpoint import save as ckpt_save
from repro.configs.base import FedConfig
from repro.core.rngtags import round_key
from repro.core.round import (RoundFnCache, init_server_state,
                              stack_round_inputs)
from repro.data.pipeline import FederatedData
from repro.models.model import Model
from repro.sim.faults import client_failed_mask, fault_streams, resolve_faults

# NOTE: repro.obs imports live inside methods: obs's tracker registry is
# built on repro.core.registry, and importing it at module scope from here
# (repro.core's own __init__ imports the trainer) would be circular.

PyTree = Any

__all__ = ["FederatedTrainer"]


class FederatedTrainer:
    """Owns server state + jitted round programs + the chunked host loop."""

    def __init__(self, model: Model, fed: FedConfig, *,
                 rounds_per_call: int = 1, donate: bool = True,
                 seed: int = 0, key: Optional[jax.Array] = None,
                 engine: Optional[str] = None, sanitize: bool = False,
                 tracker=None, run_dir: Optional[str] = None,
                 checkpoint_every: Optional[int] = None,
                 keep_last: int = 3, keep_every: int = 0,
                 profile: int = 0, profile_start: int = 0,
                 trace_summary: bool = False, trace_top_k: int = 15,
                 roofline: bool = False,
                 **round_kwargs):
        self.model = model
        self.fed = fed
        self.rounds_per_call = max(int(rounds_per_call), 1)
        if engine is not None:
            round_kwargs["engine"] = engine
        self._cache = RoundFnCache(model, fed, donate=donate,
                                   sanitize=sanitize, **round_kwargs)
        self.key = key if key is not None else jax.random.PRNGKey(seed)
        self.state = init_server_state(model, fed, self.key, engine=engine)
        self.history: List[Dict[str, float]] = []
        # retry-with-backoff bookkeeping (fed.retry_backoff > 0): failed
        # client id -> attempts so far, and due round -> ids to re-enqueue
        self._retry_attempts: Dict[int, int] = {}
        self._retry_due: Dict[int, List[int]] = {}
        # ---- observability ------------------------------------------------
        from repro.obs.profiler import RoundProfiler
        from repro.obs.trackers import resolve_tracker
        self.run_dir = run_dir
        self.tracker = resolve_tracker(tracker, run_dir=run_dir)
        self.profiler = RoundProfiler(run_dir, start=profile_start,
                                      rounds=profile, tracker=self.tracker)
        # ---- analysis layer (PR 10) ---------------------------------------
        # trace_summary: when the --profile window closes, parse the trace
        # into a profile_summary tracker event (obs/trace_analysis);
        # roofline: AOT-compile each distinct chunk program once, run the
        # trip-count-aware cost model, and emit a roofline event with
        # predicted vs measured rounds/s (roofline/live)
        if trace_summary and profile <= 0:
            raise ValueError(
                "trace_summary summarizes the profiler's capture and needs "
                "an open window; pass profile=N (train.py --profile N) "
                "alongside trace_summary")
        self._trace_summary = bool(trace_summary)
        self._trace_top_k = int(trace_top_k)
        self._roofline = bool(roofline)
        self._roofline_events: Dict[int, Optional[dict]] = {}
        self._ckpt_every = checkpoint_every
        self.manager: Optional[CheckpointManager] = None
        if checkpoint_every is not None:
            if run_dir is None:
                raise ValueError(
                    "managed checkpointing (checkpoint_every=N) writes "
                    "under the run directory; pass run_dir= as well, or "
                    "use save(path) for one-shot checkpoints")
            self.manager = CheckpointManager(
                os.path.join(run_dir, "checkpoints"),
                keep_last=keep_last, keep_every=keep_every)
        self._last_managed_step: Optional[int] = None

    # ---- state management -------------------------------------------------
    @property
    def round(self) -> int:
        """Host-side round counter (syncs the device scalar)."""
        return int(self.state["round"])

    def save(self, path: str, extra: Optional[dict] = None) -> None:
        """Full server state — params, optimizer state (incl. the fused
        engine's tuple-structured flat buffers), the controllable-weights
        slot when present, and the round counter — plus the run history,
        so :meth:`restore` continues mid-run without losing FedOpt
        momentum, meta-learned weights, or the metrics curve."""
        ckpt_save(path, self.state,
                  extra={**(extra or {}), "history": self.history})

    def restore(self, path: str) -> dict:
        """Resume from a checkpoint written by :meth:`save`; restores the
        run history alongside the server state and returns the
        checkpoint's ``extra`` metadata (minus the internal history
        slot)."""
        self.state, extra = ckpt_restore(path, self.state)
        self.history = list(extra.pop("history", self.history))
        return extra

    def resume_latest(self) -> Optional[int]:
        """Restore the newest managed checkpoint (``--resume auto``);
        returns its step, or None when the store is empty/absent."""
        if self.manager is None:
            return None
        hit = self.manager.restore_latest(self.state)
        if hit is None:
            return None
        self.state, extra, step = hit
        self.history = list(extra.pop("history", self.history))
        self._last_managed_step = step
        return step

    def finish(self) -> None:
        """Flush + close the tracker, profiler, and checkpoint manager
        (idempotent).  Drivers that own the run call this once at exit;
        callers that passed a shared tracker instance should close it
        themselves instead."""
        was_active = self.profiler.active
        self.profiler.close()
        if was_active:
            # the run ended inside the capture window; the aborted trace
            # is still on disk, so the summary still lands
            self._emit_trace_summary(self.tracker)
        if self.manager is not None:
            self.manager.close()
        self.tracker.finish()

    # ---- the driver loop --------------------------------------------------
    def run(self, data: FederatedData, *, rounds: int, cohort: int,
            batch: int, meta_batch: int = 32, share: Optional[bool] = None,
            sample_meta: Optional[Callable] = None,
            on_records: Optional[Callable] = None, log_every: int = 0,
            log_fn: Callable = print,
            tracker=None) -> List[Dict[str, float]]:
        """Train from the current round counter up to ``rounds`` total.
        Returns this call's per-round records (also appended to
        ``self.history`` and fed to the tracker).  ``tracker=`` overrides
        the trainer's sink for this call; ``log_every`` composes the
        classic console line in."""
        from repro.obs.trackers import (CompositeTracker, ConsoleTracker,
                                        resolve_tracker)
        share = self.fed.share if share is None else share
        # trackers THIS call constructs (registry-resolved overrides, the
        # log_every console) are finished before returning so their buffers
        # flush; self.tracker and caller-passed instances outlive the call
        owned: List[Any] = []
        trk = self.tracker if tracker is None \
            else resolve_tracker(tracker, run_dir=self.run_dir, owned=owned)
        if log_every:
            console = ConsoleTracker(every=log_every, log_fn=log_fn)
            owned.append(console)
            trk = CompositeTracker([trk, console])
        try:
            return self._run_tracked(
                data, trk, rounds=rounds, cohort=cohort, batch=batch,
                meta_batch=meta_batch, share=share, sample_meta=sample_meta,
                on_records=on_records)
        finally:
            for t in owned:
                t.finish()

    def _run_tracked(self, data: FederatedData, trk, *, rounds: int,
                     cohort: int, batch: int, meta_batch: int, share: bool,
                     sample_meta: Optional[Callable],
                     on_records: Optional[Callable]
                     ) -> List[Dict[str, float]]:
        from repro.obs.trackers import span
        run_history: List[Dict[str, float]] = []
        r = self.round
        trk.log_event("run_start", {
            "start_round": r, "rounds": rounds, "final_round": rounds - 1,
            "cohort": cohort, "batch": batch,
            "rounds_per_call": self.rounds_per_call})
        faults = resolve_faults(self.fed)
        # degradation policy: with faults on and retry_backoff > 0, clients
        # whose report was lost (crash / drop / past the round deadline) are
        # re-enqueued retry_backoff * 2^attempt rounds later, retry_max
        # consecutive failures per client
        retry_on = (self.fed.retry_backoff > 0 and faults.active
                    and (faults.crash > 0 or faults.drop > 0
                         or faults.deadline > 0))
        loop_s, rounds_measured = 0.0, 0
        while r < rounds:
            k = min(self.rounds_per_call, rounds - r)
            with span(trk, "sample_stack", round=r, k=k):
                due = [self._retry_due.pop(r + j, None) if retry_on
                       else None for j in range(k)]
                samples = [data.sample_round(r + j, cohort=cohort,
                                             batch=batch, share=share,
                                             include=due[j])
                           for j in range(k)]
                metas = [self._sample_meta(sample_meta, data, r + j,
                                           meta_batch, samples[j])
                         for j in range(k)]
                rngs = [round_key(self.key, r + j) for j in range(k)]
                staged = self._stage_inputs(samples, metas, rngs)
            if self._roofline and k not in self._roofline_events:
                # before dispatch: staged buffers may be donated by the
                # round program; the abstract shapes must be read first
                self._prepare_roofline(k, staged)
            self.profiler.maybe_start(r, k)
            with span(trk, "dispatch", round=r, k=k) as sp_d, \
                    self._phase_annotation("dispatch"):
                metrics = self._dispatch(k, staged)
            with span(trk, "device_sync", round=r, k=k) as sp_s, \
                    self._phase_annotation("device_sync"):
                # the dispatch span above measures enqueue time only (jax
                # dispatch is async); this one is the actual device work
                # left to drain — together they expose the overlap
                metrics = jax.block_until_ready(metrics)
            was_profiling = self.profiler.active
            self.profiler.maybe_stop(r + k)
            if was_profiling and not self.profiler.active:
                self._emit_trace_summary(trk)
            loop_s += sp_d["dur_s"] + sp_s["dur_s"]
            rounds_measured += k

            # THE record assembly — every driver shares this one.  Vector
            # metrics (e.g. the async runtime's staleness_hist) become
            # plain lists so records stay JSON-serializable.
            recs = [{name: (float(v[j]) if jnp.ndim(v[j]) == 0
                            else np.asarray(v[j], dtype=float).tolist())
                     for name, v in metrics.items()}
                    for j in range(k)]
            if retry_on:
                self._schedule_retries(samples, rngs, recs, due, r, k,
                                       faults)
            for j, rec in enumerate(recs):
                rec["round"] = r + j
                run_history.append(rec)
                self.history.append(rec)
                trk.log_metrics(r + j, rec)
            if on_records is not None:
                on_records(recs, self)
            r += k
            if self.manager is not None and self._ckpt_every \
                    and (r // self._ckpt_every) > ((r - k)
                                                   // self._ckpt_every):
                with span(trk, "checkpoint", round=r - 1):
                    self._save_managed(r)
        if self.manager is not None and self._last_managed_step != r:
            with span(trk, "checkpoint", round=r - 1):
                self._save_managed(r)
        if self._roofline:
            self._emit_roofline(trk, loop_s, rounds_measured)
        trk.log_event("run_finish", {"final_round": rounds - 1,
                                     "rounds_completed": len(run_history)})
        return run_history

    # ---- analysis-layer hooks (PR 10) -------------------------------------
    def _phase_annotation(self, name: str):
        """The trace twin of the ``span()`` event: while the profiler is
        capturing, wrap the phase in a ``repro.phase.<name>``
        TraceAnnotation so ``obs/trace_analysis`` can attribute device
        op self-time to phases.  A no-op context outside the window."""
        if self.profiler.active:
            return jax.profiler.TraceAnnotation(f"repro.phase.{name}")
        return contextlib.nullcontext()

    def _emit_trace_summary(self, trk) -> None:
        if not self._trace_summary:
            return
        from repro.obs.trace_analysis import emit_profile_summary
        emit_profile_summary(trk, self.profiler.trace_dir,
                             top_k=self._trace_top_k)

    def _prepare_roofline(self, k: int, staged) -> None:
        """AOT lower + compile the chunk program for ``k`` on abstract
        stand-ins of the real staged inputs and cache its cost-model
        event payload.  Runs once per distinct k, outside the profiler
        window and the phase spans (analysis time is recorded in the
        event, not smeared into the measured phases)."""
        from repro.roofline.live import round_roofline_event
        absargs = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(jnp.shape(x),
                                           jnp.result_type(x)),
            (self.state, *staged))
        # sanitize-mode rounds are checkify closures without .lower —
        # round_roofline_event returns None and the event is skipped
        self._roofline_events[k] = round_roofline_event(
            self._cache(k), absargs, rounds_per_call=k)

    def _emit_roofline(self, trk, loop_s: float, rounds_measured: int
                       ) -> None:
        """One ``roofline`` event per compiled chunk program, with this
        run's measured dispatch + device-sync throughput attached so
        prediction and measurement share a metrics.jsonl line."""
        for k in sorted(self._roofline_events):
            ev = self._roofline_events[k]
            if ev is None:
                continue
            payload = dict(ev)
            payload["rounds_measured"] = rounds_measured
            payload["measured_s_per_round"] = \
                (loop_s / rounds_measured) if rounds_measured else 0.0
            payload["measured_rounds_per_s"] = \
                (rounds_measured / loop_s) if loop_s > 0 else 0.0
            trk.log_event("roofline", payload)

    def _save_managed(self, step: int) -> None:
        self.manager.save(step, self.state,
                          extra={"history": self.history})
        self._last_managed_step = step

    def _schedule_retries(self, samples, rngs, recs, due, r, k, faults):
        """Host-side mirror of the jitted round's fault draws: the fold in
        :func:`repro.sim.faults.fault_streams` is deterministic in the
        round rng, so recomputing the streams here agrees bit-for-bit with
        what the device masked out.  Failed clients are re-enqueued with
        exponential backoff, deferred past the current chunk (the chunk's
        cohorts were already sampled)."""
        cohort = len(samples[0]["clients"])
        for j in range(k):
            fs = fault_streams(rngs[j], cohort, faults)
            failed = np.asarray(client_failed_mask(fs, faults))
            clients = np.asarray(samples[j]["clients"])
            recs[j]["retried"] = float(len(set(due[j] or [])
                                          & set(clients.tolist())))
            for cid in clients[~failed]:
                self._retry_attempts.pop(int(cid), None)
            for cid in clients[failed]:
                cid = int(cid)
                a = self._retry_attempts.get(cid, 0)
                if a >= self.fed.retry_max:
                    continue
                self._retry_attempts[cid] = a + 1
                due_round = max(r + j + self.fed.retry_backoff * (2 ** a),
                                r + k)
                self._retry_due.setdefault(due_round, []).append(cid)

    def _sample_meta(self, sample_meta, data, round_idx, meta_batch, sample):
        if sample_meta is not None:
            return sample_meta(data, round_idx, meta_batch, sample)
        # No FedMeta step -> no D_meta sampling: the round_fn never touches
        # meta_batch when fed.meta is False, so ship None (an empty pytree
        # threads through stack_round_inputs and jit untouched)
        return data.sample_meta(round_idx, meta_batch) if self.fed.meta \
            else None

    def _stage_inputs(self, samples, metas, rngs):
        """Host-side staging (device transfer for k == 1, the
        ``stack_round_inputs`` chunk stack for k > 1) — split from
        dispatch so the ``sample_stack`` phase span covers it."""
        k = len(samples)
        if k == 1:
            return (jax.tree.map(jnp.asarray, samples[0]["cohort_batch"]),
                    jax.tree.map(jnp.asarray, metas[0]),
                    jnp.asarray(samples[0]["client_weights"]), rngs[0])
        return stack_round_inputs(
            [s["cohort_batch"] for s in samples], metas,
            [s["client_weights"] for s in samples], rngs)

    def _dispatch(self, k: int, staged) -> Dict[str, jax.Array]:
        """One donated program for the chunk; metrics come back with a
        leading K axis for k == 1 too, so record assembly exists once."""
        self.state, metrics = self._cache(k)(self.state, *staged)
        if k == 1:
            return {name: v[None] for name, v in metrics.items()}
        return metrics

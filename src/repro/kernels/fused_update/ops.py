"""Public engine for the fused server-side update.

:func:`fused_server_update` replaces the legacy 5+ tree-traversal server
step (``weighted_mean`` -> clip-norm scale -> fp32 cast -> optimizer ``upd``
-> param write) with exactly two HBM sweeps over flat per-dtype-group fp32
buffers (layout: ``repro.core.flat``):

  pass 1  kernels.aggregate_pass   cohort-weighted mean + ||G||^2
  pass 2  kernels.update_pass      clip scale + sgd/sgdm/adam/yogi + write

The client-sequential (scan) strategy streams pass 1 instead: the cohort
scan carries the flat group buffers and FMAs each client's flattened
gradient into them with :func:`flat_accumulate`
(``kernels.accumulate_pass``), then :func:`fused_apply_flat` runs pass 2
on the result — same engine, no stacked (cohort, rows, LANES) tensor ever
materializes.

Numerics match ``repro.core.server_opt.apply`` on the clipped fp32 mean to
<= 1e-5 relative (tested against both the pure-jnp ``ref`` oracle and the
legacy tree-map path).  ``use_ref=True`` swaps the Pallas kernels for the
oracle; ``interpret`` defaults to True off-TPU so the same code path runs
in the CPU tier-1 suite.

The engine is **differentiable**: each kernel pair is wrapped in a
``jax.custom_vjp`` (:func:`_agg_vjp` / :func:`_upd_vjp`) whose backward is
the hand-written ``aggregate_pass_bwd`` / ``update_pass_bwd`` Pallas
kernel (or the matching ``ref`` oracle under ``use_ref=True``), so
``jax.grad`` through :func:`fused_server_update` — w.r.t. the stacked
per-client gradients, the client weights, the learning rate and the
parameters — costs two more flat HBM sweeps instead of XLA
re-differentiating the engine.  Only the tiny scalar glue (weight
normalization, ||G||, clip scale, bias corrections) is left to XLA.  This
is what powers ``meta_mode="through_aggregation"`` (``core/meta.py``):
hypergradients of the meta loss w.r.t. per-client aggregation weights and
the server step size.
"""
from __future__ import annotations

import functools
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import flat as flat_mod
from repro.core.flat import FlatSpec, make_flat_spec
from repro.kernels.fused_update import kernel as K
from repro.kernels.fused_update import ref as R

PyTree = Any

# tree traversals per server step, for the BENCH report (legacy counts one
# full-model jax.tree.map per stage: weighted_mean, clip scale, g32 cast,
# m, v, step, param write — opt-dependent; fused is always two HBM sweeps)
TRAVERSALS_LEGACY = {"sgd": 4, "sgdm": 5, "adam": 8, "yogi": 8}
TRAVERSALS_FUSED = 2


def init_flat_opt_state(opt: str, spec: FlatSpec) -> PyTree:
    """Optimizer state in the flat layout (one fp32 buffer per dtype group,
    mirroring ``server_opt.init_state``'s per-leaf zeros)."""
    zeros = lambda: tuple(flat_mod.zeros_flat(spec))
    if opt == "sgd":
        return {}
    if opt == "sgdm":
        return {"m": zeros()}
    if opt in ("adam", "yogi"):
        return {"m": zeros(), "v": zeros(), "t": jnp.zeros((), jnp.int32)}
    raise ValueError(opt)


@functools.lru_cache(maxsize=None)
def _agg_vjp(use_ref: bool, interpret: bool):
    """custom_vjp over the aggregate pass: (g_stack, w_norm) -> (G, ssq)."""

    @jax.custom_vjp
    def agg(g_stack, w_norm):
        if use_ref:
            return R.aggregate_ref(g_stack, w_norm)
        return K.aggregate_pass(g_stack, w_norm, interpret=interpret)

    def fwd(g_stack, w_norm):
        G, ssq = agg(g_stack, w_norm)
        return (G, ssq), (g_stack, w_norm, G)

    def bwd(res, cts):
        g_stack, w_norm, G = res
        dG, dssq = cts
        if use_ref:
            return R.aggregate_bwd_ref(g_stack, w_norm, G, dG, dssq)
        return K.aggregate_pass_bwd(g_stack, w_norm, G, dG, dssq,
                                    interpret=interpret)

    agg.defvjp(fwd, bwd)
    return agg


@functools.lru_cache(maxsize=None)
def _acc_vjp(use_ref: bool, interpret: bool):
    """custom_vjp over the streaming accumulate: (acc, g, w) -> acc + w*g.

    The scan strategy carries the flat group buffers and calls this once
    per client per group.  The backward is (d_acc, w*d_out, <g, d_out>) —
    the accumulator cotangent is the identity, so the cotangent arriving at
    step k is the cotangent of the FINAL aggregate, making dw_k = <g_k, dG>
    the through-aggregation weight hypergradient."""

    @jax.custom_vjp
    def accum(acc, g, w):
        if use_ref:
            return R.accumulate_ref(acc, g, w)
        return K.accumulate_pass(acc, g, w, interpret=interpret)

    def fwd(acc, g, w):
        return accum(acc, g, w), (g, w)

    def bwd(res, d_out):
        g, w = res
        if use_ref:
            dg, dw = R.accumulate_bwd_ref(g, w, d_out)
        else:
            dg, dw = K.accumulate_pass_bwd(g, w, d_out, interpret=interpret)
        return d_out, dg, dw

    accum.defvjp(fwd, bwd)
    return accum


def flat_accumulate(use_ref: bool = False, interpret: Optional[bool] = None):
    """Public getter for the cached streaming-accumulate custom VJP
    (``(acc, g, w) -> acc + w*g`` over one (rows, LANES) fp32 group)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return _acc_vjp(use_ref, interpret)


@functools.lru_cache(maxsize=None)
def _upd_vjp(opt: str, momentum: float, b1: float, b2: float, eps: float,
             use_ref: bool, interpret: bool):
    """custom_vjp over the update pass:
    (G, p, m, v, scalars) -> (new_p, new_m, new_v).

    m/v (and their outputs/cotangents) are None for optimizers without the
    slot — None is an empty pytree, so custom_vjp threads it through.  The
    scalar cotangent covers [scale, lr, bc1, bc2]; lr's flows to meta-
    learned server step sizes, bc1/bc2's die at the int step counter."""
    hp = dict(opt=opt, momentum=momentum, b1=b1, b2=b2, eps=eps)

    @jax.custom_vjp
    def upd(G, p, m, v, scalars):
        if use_ref:
            return R.update_ref(G, p, m, v, scalars, **hp)
        return K.update_pass(G, p, m, v, scalars, interpret=interpret, **hp)

    def fwd(G, p, m, v, scalars):
        out = upd(G, p, m, v, scalars)
        return out, (G, m, v, scalars)

    def bwd(res, cts):
        G, m, v, scalars = res
        d_new_p, d_new_m, d_new_v = cts
        if use_ref:
            dG, dm, dv, dscal = R.update_bwd_ref(
                G, m, v, scalars, d_new_p, d_new_m, d_new_v, **hp)
        else:
            dG, dm, dv, dscal = K.update_pass_bwd(
                G, m, v, scalars, d_new_p, d_new_m, d_new_v,
                interpret=interpret, **hp)
        return dG, d_new_p, dm, dv, dscal    # dp = d_new_p (p' = p - lr*d)

    upd.defvjp(fwd, bwd)
    return upd


def flat_weighted_aggregate(spec: FlatSpec, grad_stack: PyTree,
                            client_weights: jax.Array, *,
                            use_ref: bool = False,
                            interpret: Optional[bool] = None
                            ) -> Tuple[list, jax.Array]:
    """Pass 1 alone: normalize ``client_weights``, flatten the stacked
    per-client gradients and run the differentiable aggregate kernel per
    dtype group.  Returns (G_groups, ssq) where ``ssq = ||G||^2`` summed
    over groups — exactly the interior of :func:`fused_server_update`, so
    cohort executors can produce the Eq. (14) flat weighted mean as a
    uniform handle and leave pass 2 to the server engine."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    w = client_weights.astype(jnp.float32)
    w = w / jnp.maximum(jnp.sum(w), 1e-30)
    g_groups = flat_mod.flatten_stacked(spec, grad_stack)
    agg = _agg_vjp(use_ref, interpret)
    Gs, ssq = [], jnp.float32(0.0)
    for g_stack in g_groups:
        G, s = agg(g_stack, w)
        Gs.append(G)
        ssq = ssq + s
    return Gs, ssq


def flat_apply_groups(spec: FlatSpec, G_groups, gn, params: PyTree,
                      opt_state: PyTree, *, opt: str, lr,
                      clip_norm: float = 0.0, momentum: float = 0.9,
                      b1: float = 0.9, b2: float = 0.99, eps: float = 1e-8,
                      use_ref: bool = False,
                      interpret: Optional[bool] = None
                      ) -> Tuple[PyTree, PyTree, jax.Array]:
    """Pass 2 alone (public form of the shared ``_apply_groups``): clip
    scale + optimizer + param write over aggregated flat buffers, with the
    pre-clip global norm ``gn`` supplied by the caller (the aggregate
    kernel's ssq, or :func:`repro.core.flat.flat_sq_norm` for streamed
    accumulations).  Returns (new_params, new_opt_state, gn_after_clip)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return _apply_groups(spec, list(G_groups), gn, params, opt_state,
                         opt=opt, lr=lr, clip_norm=clip_norm,
                         momentum=momentum, b1=b1, b2=b2, eps=eps,
                         use_ref=use_ref, interpret=interpret)


def fused_server_update(params: PyTree, grad_stack: PyTree,
                        client_weights: jax.Array, opt_state: PyTree, *,
                        opt: str = "sgd", lr, clip_norm: float = 0.0,
                        momentum: float = 0.9, b1: float = 0.9,
                        b2: float = 0.99, eps: float = 1e-8,
                        spec: Optional[FlatSpec] = None,
                        use_ref: bool = False,
                        interpret: Optional[bool] = None
                        ) -> Tuple[PyTree, PyTree, jax.Array]:
    """One fused server step over stacked per-client gradients.

    grad_stack: pytree matching ``params`` with a leading cohort axis on
    every leaf; client_weights: (cohort,) n_k (un-normalized);
    opt_state: flat state from :func:`init_flat_opt_state`.
    Returns (new_params, new_opt_state, grad_norm_after_clip)."""
    if spec is None:
        spec = make_flat_spec(params)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    Gs, ssq = flat_weighted_aggregate(spec, grad_stack, client_weights,
                                      use_ref=use_ref, interpret=interpret)

    return _apply_groups(spec, Gs, jnp.sqrt(ssq), params, opt_state,
                         opt=opt, lr=lr, clip_norm=clip_norm,
                         momentum=momentum, b1=b1, b2=b2, eps=eps,
                         use_ref=use_ref, interpret=interpret)


def fused_apply_flat(params: PyTree, G_groups, opt_state: PyTree, *,
                     opt: str = "sgd", lr, clip_norm: float = 0.0,
                     momentum: float = 0.9, b1: float = 0.9,
                     b2: float = 0.99, eps: float = 1e-8,
                     spec: Optional[FlatSpec] = None,
                     use_ref: bool = False,
                     interpret: Optional[bool] = None
                     ) -> Tuple[PyTree, PyTree, jax.Array]:
    """The clip+optimizer+write half of the engine over ALREADY-aggregated
    flat buffers — the scan strategy's entry point, where pass 1 happened
    as K streaming :func:`flat_accumulate` FMAs inside the cohort scan.

    G_groups: one (rows, LANES) fp32 buffer per dtype group of ``spec``
    holding the Eq. (14) weighted mean.  ||G||^2 is reduced here with plain
    jnp (one extra flat read; its VJP is the trivial 2G so no kernel is
    warranted).  Returns (new_params, new_opt_state, grad_norm_after_clip)
    exactly like :func:`fused_server_update`."""
    if spec is None:
        spec = make_flat_spec(params)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    gn = jnp.sqrt(flat_mod.flat_sq_norm(G_groups))
    return _apply_groups(spec, list(G_groups), gn, params,
                         opt_state, opt=opt, lr=lr, clip_norm=clip_norm,
                         momentum=momentum, b1=b1, b2=b2, eps=eps,
                         use_ref=use_ref, interpret=interpret)


def _apply_groups(spec: FlatSpec, Gs, gn, params: PyTree, opt_state: PyTree,
                  *, opt: str, lr, clip_norm: float, momentum: float,
                  b1: float, b2: float, eps: float, use_ref: bool,
                  interpret: bool) -> Tuple[PyTree, PyTree, jax.Array]:
    """Shared pass 2: clip scale + optimizer + param write over the flat
    dtype groups.  ``gn`` is the pre-clip global gradient norm."""
    upd = _upd_vjp(opt, momentum, b1, b2, eps, use_ref, interpret)
    p_groups = flat_mod.flatten_tree(spec, params)

    if clip_norm > 0:
        scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gn, 1e-9))
    else:
        scale = jnp.float32(1.0)

    if opt in ("adam", "yogi"):
        t = opt_state["t"] + 1
        tf = t.astype(jnp.float32)
        bc1 = 1.0 / (1.0 - b1 ** tf)
        bc2 = 1.0 / (1.0 - b2 ** tf)
    else:
        t = None
        bc1 = bc2 = jnp.float32(1.0)
    scalars = jnp.stack([scale, jnp.float32(lr), bc1, bc2]).reshape(1, 4)

    ms = opt_state.get("m", (None,) * len(spec.groups))
    vs = opt_state.get("v", (None,) * len(spec.groups))
    new_p, new_m, new_v = [], [], []
    for G, p, m, v in zip(Gs, p_groups, ms, vs):
        np_, nm, nv = upd(G, p, m, v, scalars)
        new_p.append(np_)
        new_m.append(nm)
        new_v.append(nv)

    new_params = flat_mod.unflatten_tree(spec, new_p)
    if opt == "sgd":
        new_state: PyTree = {}
    elif opt == "sgdm":
        new_state = {"m": tuple(new_m)}
    else:
        new_state = {"m": tuple(new_m), "v": tuple(new_v), "t": t}
    return new_params, new_state, gn * scale

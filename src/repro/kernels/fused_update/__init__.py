from repro.kernels.fused_update.ops import (fused_server_update,
                                            init_flat_opt_state)

__all__ = ["fused_server_update", "init_flat_opt_state"]

"""Pure-jnp oracle for the fused server-update kernels.

Operates on the flat-buffer layout of ``repro.core.flat`` with exactly the
two-pass structure of the Pallas kernels:

  pass 1  (aggregate):  G = sum_k w_k g_k   and   ssq = ||G||^2
  pass 2  (apply):      d = optimizer(G * scale);  p <- p - lr * d

The per-optimizer math mirrors ``repro.core.server_opt.apply`` line for
line (fp32 throughout); bias corrections for adam/yogi arrive as the
precomputed scalars bc1 = 1/(1-b1^t), bc2 = 1/(1-b2^t).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def aggregate_ref(g_stack: jax.Array, w_norm: jax.Array
                  ) -> Tuple[jax.Array, jax.Array]:
    """g_stack: (cohort, rows, lanes) fp32; w_norm: (cohort,) normalized.
    Returns (G (rows, lanes) fp32, ssq scalar fp32)."""
    G = jnp.sum(g_stack * w_norm[:, None, None].astype(jnp.float32), axis=0)
    return G, jnp.sum(G * G)


def update_ref(G: jax.Array, p: jax.Array, m: Optional[jax.Array],
               v: Optional[jax.Array], *, opt: str, scale, lr,
               momentum: float = 0.9, b1: float = 0.9, b2: float = 0.99,
               eps: float = 1e-8, bc1=1.0, bc2=1.0):
    """One flat-buffer optimizer step.  Returns (new_p, new_m, new_v) with
    None slots matching the optimizer's state arity."""
    g = G * scale
    if opt == "sgd":
        return p - lr * g, None, None
    if opt == "sgdm":
        m_new = momentum * m + g
        return p - lr * m_new, m_new, None
    if opt in ("adam", "yogi"):
        m_new = b1 * m + (1.0 - b1) * g
        if opt == "adam":
            v_new = b2 * v + (1.0 - b2) * g * g
        else:
            v_new = v - (1.0 - b2) * jnp.sign(v - g * g) * g * g
        step = (m_new * bc1) / (jnp.sqrt(v_new * bc2) + eps)
        return p - lr * step, m_new, v_new
    raise ValueError(opt)

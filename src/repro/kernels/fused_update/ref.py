"""Pure-jnp oracle for the fused server-update kernels.

Operates on the flat-buffer layout of ``repro.core.flat`` with exactly the
two-pass structure of the Pallas kernels:

  pass 1  (aggregate):  G = sum_k w_k g_k   and   ssq = ||G||^2
  pass 2  (apply):      d = optimizer(G * scale);  p <- p - lr * d

plus the scan strategy's streaming form of pass 1 (:func:`accumulate_ref`:
``acc + w_k g_k``, one client at a time).

The per-optimizer math mirrors ``repro.core.server_opt.apply`` line for
line (fp32 throughout); bias corrections for adam/yogi arrive as the
precomputed scalars bc1 = 1/(1-b1^t), bc2 = 1/(1-b2^t).

:func:`aggregate_bwd_ref` / :func:`update_bwd_ref` are the matching
hand-derived VJPs — the oracles for ``kernel.aggregate_pass_bwd`` /
``kernel.update_pass_bwd`` and the ``use_ref=True`` arm of the
``jax.custom_vjp`` ops in ``ops.py``.  Same conventions as the kernels:
yogi's ``sign`` is locally constant and the adam/yogi ``1/(2 sqrt)``
factor is zero-guarded so padded (all-zero) rows backprop exact zeros.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def aggregate_ref(g_stack: jax.Array, w_norm: jax.Array
                  ) -> Tuple[jax.Array, jax.Array]:
    """g_stack: (cohort, rows, lanes) fp32; w_norm: (cohort,) normalized.
    Returns (G (rows, lanes) fp32, ssq scalar fp32)."""
    G = jnp.sum(g_stack * w_norm[:, None, None].astype(jnp.float32), axis=0)
    return G, jnp.sum(G * G)


def accumulate_ref(acc: jax.Array, g: jax.Array, w) -> jax.Array:
    """Streaming Eq. (14) term (scan strategy): ``acc + w * g`` over one
    client's flat (rows, lanes) fp32 gradient buffer."""
    return acc + jnp.asarray(w, jnp.float32) * g


def accumulate_bwd_ref(g: jax.Array, w, d_out: jax.Array
                       ) -> Tuple[jax.Array, jax.Array]:
    """VJP of :func:`accumulate_ref` w.r.t. (g, w); the accumulator
    cotangent is the identity and handled by the caller.
    dg = w d_out, dw = <g, d_out>."""
    return jnp.asarray(w, jnp.float32) * d_out, jnp.sum(g * d_out)


def update_ref(G: jax.Array, p: jax.Array, m: Optional[jax.Array],
               v: Optional[jax.Array], scalars: jax.Array, *, opt: str,
               momentum: float = 0.9, b1: float = 0.9, b2: float = 0.99,
               eps: float = 1e-8):
    """One flat-buffer optimizer step.  ``scalars`` is the same (1, 4)
    [scale, lr, bc1, bc2] operand ``kernel.update_pass`` takes (signature
    parity is the fedlint FL202 contract: the oracle must be callable
    exactly like the kernel).  Returns (new_p, new_m, new_v) with None
    slots matching the optimizer's state arity."""
    scale, lr, bc1, bc2 = (scalars[0, 0], scalars[0, 1], scalars[0, 2],
                           scalars[0, 3])
    g = G * scale
    if opt == "sgd":
        return p - lr * g, None, None
    if opt == "sgdm":
        m_new = momentum * m + g
        return p - lr * m_new, m_new, None
    if opt in ("adam", "yogi"):
        m_new = b1 * m + (1.0 - b1) * g
        if opt == "adam":
            v_new = b2 * v + (1.0 - b2) * g * g
        else:
            v_new = v - (1.0 - b2) * jnp.sign(v - g * g) * g * g
        step = (m_new * bc1) / (jnp.sqrt(v_new * bc2) + eps)
        return p - lr * step, m_new, v_new
    raise ValueError(opt)


def aggregate_bwd_ref(g_stack: jax.Array, w_norm: jax.Array, G: jax.Array,
                      dG: jax.Array, dssq) -> Tuple[jax.Array, jax.Array]:
    """VJP of :func:`aggregate_ref`: dg_k = w_k (dG + 2 dssq G),
    dw_k = <g_k, dG + 2 dssq G>.  Returns (dg_stack, dw (cohort,))."""
    dGt = dG + 2.0 * jnp.float32(dssq) * G
    dg = w_norm[:, None, None].astype(jnp.float32) * dGt[None]
    dw = jnp.sum(g_stack * dGt[None], axis=(1, 2))
    return dg, dw


def update_bwd_ref(G: jax.Array, m: Optional[jax.Array],
                   v: Optional[jax.Array], scalars: jax.Array,
                   d_new_p: jax.Array, d_new_m: Optional[jax.Array],
                   d_new_v: Optional[jax.Array], *, opt: str,
                   momentum: float = 0.9, b1: float = 0.9, b2: float = 0.99,
                   eps: float = 1e-8):
    """VJP of :func:`update_ref` w.r.t. (G, m, v, scalars); the param
    cotangent is the identity and handled by the caller.  scalars is the
    (1, 4) [scale, lr, bc1, bc2] operand of the forward; the recurrence is
    replayed from the (G, m, v) residuals.  Returns (dG, dm, dv,
    dscalars (1, 4)) with None slots matching the optimizer arity."""
    s = scalars[0, 0]
    lr = scalars[0, 1]
    g = G * s
    dbc1 = dbc2 = jnp.float32(0.0)

    if opt == "sgd":
        dg = -lr * d_new_p
        dlr = -jnp.sum(g * d_new_p)
        dm = dv = None
    elif opt == "sgdm":
        m_new = momentum * m + g
        dmn = d_new_m - lr * d_new_p
        dlr = -jnp.sum(m_new * d_new_p)
        dg = dmn
        dm, dv = momentum * dmn, None
    elif opt in ("adam", "yogi"):
        bc1 = scalars[0, 2]
        bc2 = scalars[0, 3]
        m_new = b1 * m + (1.0 - b1) * g
        if opt == "adam":
            v_new = b2 * v + (1.0 - b2) * g * g
        else:
            sgn = jnp.sign(v - g * g)
            v_new = v - (1.0 - b2) * sgn * g * g
        rs = jnp.sqrt(v_new * bc2)
        denom = rs + eps
        step = m_new * bc1 / denom
        dstep = -lr * d_new_p
        dlr = -jnp.sum(step * d_new_p)
        dmn = d_new_m + dstep * (bc1 / denom)
        dbc1 = jnp.sum(dstep * m_new / denom)
        ddenom = -dstep * step / denom
        inv2rs = jnp.where(rs > 0.0, 0.5 / jnp.maximum(rs, 1e-30), 0.0)
        dvn = d_new_v + ddenom * bc2 * inv2rs
        dbc2 = jnp.sum(ddenom * v_new * inv2rs)
        dm = b1 * dmn
        if opt == "adam":
            dv = b2 * dvn
            dg = (1.0 - b1) * dmn + 2.0 * (1.0 - b2) * g * dvn
        else:
            dv = dvn
            dg = (1.0 - b1) * dmn - 2.0 * (1.0 - b2) * sgn * g * dvn
    else:
        raise ValueError(opt)

    dscal = jnp.stack([jnp.sum(G * dg), dlr, dbc1, dbc2]).reshape(1, 4)
    return s * dg, dm, dv, dscal

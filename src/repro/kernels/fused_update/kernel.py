"""Pallas TPU kernels for the fused server update (aggregate -> clip ->
apply) over flat fp32 buffers (layout: ``repro.core.flat``).

Two forward kernels, at most two passes over HBM per round:

  * :func:`aggregate_pass` — grid walks row tiles of the stacked client
    gradients ``(cohort, rows, LANES)``; each step reduces the cohort axis
    with the normalized weights (Eq. 14) and accumulates the global
    sum-of-squares into a (1, 1) output revisited by every grid step (TPU
    grids are sequential, so the accumulation is well-defined — same idiom
    as the flash_attention kv axis).
  * :func:`update_pass` — grid walks row tiles of the aggregated gradient,
    applies the clip scale and the server optimizer (sgd/sgdm/adam/yogi)
    and writes the new parameters (+ m/v slots) in one sweep.  Traced
    scalars (clip scale, lr, bias corrections) ride in a (1, 4) SMEM
    operand; static hyper-parameters (momentum, b1, b2, eps) are baked in.

A third forward kernel serves the client-sequential (scan) cohort
strategy, where the per-client gradients are never stacked:

  * :func:`accumulate_pass` — fused-multiply-add of ONE client's flattened
    gradient into the group accumulator, ``acc + w_k * g_k``, in a single
    HBM sweep.  The scan carry is the flat buffer itself, so a scan round
    is K streaming accumulates plus the same :func:`update_pass` — no
    pytree-carry tree-maps, no flatten round-trip of the aggregate.

Backward kernels give each pair a hand-written VJP (wired up by the
``jax.custom_vjp`` ops in ``ops.py``) so meta-learning *through* the
aggregation never falls back to XLA re-differentiating the engine:

  * :func:`aggregate_pass_bwd` — scatters the total cotangent of the mean
    ``dG + 2*dssq*G`` back to the ``(cohort, rows, LANES)`` stack
    (``dg_k = w_k * dGt``) and accumulates the per-client weight cotangents
    ``dw_k = <g_k, dGt>`` into a (cohort, 1) output revisited by every grid
    step.
  * :func:`accumulate_pass_bwd` — for the streaming FMA: ``d_acc`` is the
    identity (handled by the caller), ``dg_k = w_k * d_out`` and
    ``dw_k = <g_k, d_out>`` accumulated into a (1, 1) output.  Because the
    accumulator cotangent passes through later scan steps unchanged,
    ``d_out`` at step k IS the cotangent of the final aggregate, so
    ``dw_k = <g_k, dG>`` — exactly the through-aggregation hypergradient
    (g_k is recomputed under ``jax.checkpoint`` by the surrounding scan,
    one client trajectory alive at a time).
  * :func:`update_pass_bwd` — replays the optimizer recurrence from the
    saved (G, m, v, scalars) residuals and pushes the output cotangents
    (d new_p, d new_m, d new_v) back into gradient / opt-state cotangents
    plus the (1, 4) scalar cotangents [dscale, dlr, dbc1, dbc2].  ``sign``
    in yogi is treated as locally constant (the same zero-derivative
    convention XLA autodiff uses for ``jnp.sign``), and the ``sqrt`` factor
    is zero-guarded so the zero-padded tail rows of the flat layout produce
    exact zeros instead of ``0 * inf`` NaNs.

All kernels run on CPU with ``interpret=True`` (how the tier-1 suite
validates them) and lower through Mosaic on TPU unchanged.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU memory spaces; interpret mode works without them
    from jax.experimental.pallas import tpu as pltpu
except Exception:  # pragma: no cover
    pltpu = None

from repro.core.flat import LANES

# scalar operand layout for update_pass: [scale, lr, bc1, bc2]
N_SCALARS = 4


def _block_rows(rows: int, target: int = 256) -> int:
    """Largest power-of-two row tile <= target that divides ``rows``
    (rows is a multiple of 8 by construction of FlatSpec)."""
    br = min(target, rows)
    while rows % br:
        br //= 2
    return max(br, 1)


def _scalar_spec(cols: int, interpret: bool):
    """(1, cols) scalar-operand placement: SMEM on real TPUs, default
    memory in interpret mode (where pltpu may be unavailable)."""
    if pltpu is not None and not interpret:
        return pl.BlockSpec((1, cols), lambda i: (0, 0),
                            memory_space=pltpu.SMEM)
    return pl.BlockSpec((1, cols), lambda i: (0, 0))


# ---------------------------------------------------------------------------
# Pass 1: weighted cohort reduce + global sum-of-squares
# ---------------------------------------------------------------------------
def _aggregate_kernel(w_ref, g_ref, out_ref, ssq_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        ssq_ref[0, 0] = jnp.float32(0.0)

    g = g_ref[...]                                    # (cohort, br, LANES)
    w = w_ref[...]                                    # (cohort, 1)
    G = jnp.sum(g * w[:, :, None], axis=0)            # (br, LANES)
    out_ref[...] = G
    ssq_ref[0, 0] += jnp.sum(G * G)


def aggregate_pass(g_stack: jax.Array, w_norm: jax.Array, *,
                   block_rows: int = 256, interpret: bool = False
                   ) -> Tuple[jax.Array, jax.Array]:
    """g_stack: (cohort, rows, LANES) fp32; w_norm: (cohort,) normalized
    weights.  Returns (G (rows, LANES) fp32, ssq () fp32)."""
    cohort, rows, lanes = g_stack.shape
    assert lanes == LANES, g_stack.shape
    br = _block_rows(rows, block_rows)
    G, ssq = pl.pallas_call(
        _aggregate_kernel,
        grid=(rows // br,),
        in_specs=[
            pl.BlockSpec((cohort, 1), lambda i: (0, 0)),
            pl.BlockSpec((cohort, br, LANES), lambda i: (0, i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((br, LANES), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, LANES), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(w_norm.astype(jnp.float32).reshape(cohort, 1), g_stack)
    return G, ssq[0, 0]


# ---------------------------------------------------------------------------
# Streaming pass (scan strategy): acc <- acc + w_k * g_k in one HBM sweep
# ---------------------------------------------------------------------------
def _accumulate_kernel(w_ref, acc_ref, g_ref, out_ref):
    out_ref[...] = acc_ref[...] + w_ref[0, 0] * g_ref[...]


def accumulate_pass(acc: jax.Array, g: jax.Array, w, *,
                    block_rows: int = 256, interpret: bool = False
                    ) -> jax.Array:
    """acc/g: (rows, LANES) fp32; w: scalar normalized client weight.
    Returns ``acc + w * g`` — the per-client streaming Eq. (14) term the
    scan strategy carries instead of a pytree."""
    rows, lanes = acc.shape
    assert lanes == LANES, acc.shape
    br = _block_rows(rows, block_rows)
    tile = pl.BlockSpec((br, LANES), lambda i: (i, 0))
    w_spec = _scalar_spec(1, interpret)
    out = pl.pallas_call(
        _accumulate_kernel,
        grid=(rows // br,),
        in_specs=[w_spec, tile, tile],
        out_specs=tile,
        out_shape=jax.ShapeDtypeStruct((rows, LANES), jnp.float32),
        interpret=interpret,
    )(jnp.asarray(w, jnp.float32).reshape(1, 1), acc, g)
    return out


def _accumulate_bwd_kernel(w_ref, g_ref, dout_ref, dg_ref, dw_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        dw_ref[0, 0] = jnp.float32(0.0)

    dout = dout_ref[...]
    dg_ref[...] = w_ref[0, 0] * dout                  # dg_k = w_k d_out
    dw_ref[0, 0] += jnp.sum(g_ref[...] * dout)        # dw_k = <g_k, d_out>


def accumulate_pass_bwd(g: jax.Array, w, d_out: jax.Array, *,
                        block_rows: int = 256, interpret: bool = False
                        ) -> Tuple[jax.Array, jax.Array]:
    """VJP of :func:`accumulate_pass` w.r.t. (g, w); the accumulator
    cotangent is the identity and handled by the caller.  Returns
    (dg (rows, LANES), dw ())."""
    rows, lanes = g.shape
    assert lanes == LANES, g.shape
    br = _block_rows(rows, block_rows)
    tile = pl.BlockSpec((br, LANES), lambda i: (i, 0))
    w_spec = _scalar_spec(1, interpret)
    dg, dw = pl.pallas_call(
        _accumulate_bwd_kernel,
        grid=(rows // br,),
        in_specs=[w_spec, tile, tile],
        out_specs=[tile, pl.BlockSpec((1, 1), lambda i: (0, 0))],
        out_shape=[
            jax.ShapeDtypeStruct((rows, LANES), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(jnp.asarray(w, jnp.float32).reshape(1, 1), g, d_out)
    return dg, dw[0, 0]


# ---------------------------------------------------------------------------
# Pass 2: clip-scale + server optimizer + parameter write
# ---------------------------------------------------------------------------
def _update_kernel(scal_ref, *refs, opt: str, momentum: float, b1: float,
                   b2: float, eps: float):
    scale = scal_ref[0, 0]
    lr = scal_ref[0, 1]
    g = refs[0][...] * scale                          # clipped gradient tile
    p = refs[1][...]

    if opt == "sgd":
        new_p_ref = refs[2]
        new_p_ref[...] = p - lr * g
        return
    if opt == "sgdm":
        m_ref, new_p_ref, new_m_ref = refs[2], refs[3], refs[4]
        m = momentum * m_ref[...] + g
        new_m_ref[...] = m
        new_p_ref[...] = p - lr * m
        return
    # adam / yogi
    bc1 = scal_ref[0, 2]
    bc2 = scal_ref[0, 3]
    m_ref, v_ref = refs[2], refs[3]
    new_p_ref, new_m_ref, new_v_ref = refs[4], refs[5], refs[6]
    m = b1 * m_ref[...] + (1.0 - b1) * g
    if opt == "adam":
        v = b2 * v_ref[...] + (1.0 - b2) * g * g
    else:  # yogi
        v0 = v_ref[...]
        v = v0 - (1.0 - b2) * jnp.sign(v0 - g * g) * g * g
    new_m_ref[...] = m
    new_v_ref[...] = v
    new_p_ref[...] = p - lr * (m * bc1) / (jnp.sqrt(v * bc2) + eps)


def update_pass(G: jax.Array, p: jax.Array, m: Optional[jax.Array],
                v: Optional[jax.Array], scalars: jax.Array, *, opt: str,
                momentum: float = 0.9, b1: float = 0.9, b2: float = 0.99,
                eps: float = 1e-8, block_rows: int = 256,
                interpret: bool = False):
    """One fused optimizer sweep over a flat buffer group.

    scalars: (1, N_SCALARS) fp32 = [scale, lr, bc1, bc2] (traced).
    Returns (new_p, new_m, new_v) with None slots per optimizer arity."""
    rows, lanes = G.shape
    assert lanes == LANES, G.shape
    br = _block_rows(rows, block_rows)
    tile = pl.BlockSpec((br, LANES), lambda i: (i, 0))
    scal_spec = _scalar_spec(N_SCALARS, interpret)
    buf = jax.ShapeDtypeStruct((rows, LANES), jnp.float32)

    state_in = {"sgd": [], "sgdm": [m], "adam": [m, v], "yogi": [m, v]}[opt]
    n_out = 1 + len(state_in)
    kernel = functools.partial(_update_kernel, opt=opt, momentum=momentum,
                               b1=b1, b2=b2, eps=eps)
    outs = pl.pallas_call(
        kernel,
        grid=(rows // br,),
        in_specs=[scal_spec] + [tile] * (2 + len(state_in)),
        out_specs=[tile] * n_out,
        out_shape=[buf] * n_out,
        interpret=interpret,
    )(scalars.astype(jnp.float32), G, p, *state_in)
    new_p = outs[0]
    new_m = outs[1] if len(outs) > 1 else None
    new_v = outs[2] if len(outs) > 2 else None
    return new_p, new_m, new_v


# ---------------------------------------------------------------------------
# Backward pass 1: cotangent-of-mean scatter + per-client weight cotangents
# ---------------------------------------------------------------------------
def _aggregate_bwd_kernel(w_ref, dssq_ref, g_ref, G_ref, dG_ref,
                          dg_ref, dw_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        dw_ref[...] = jnp.zeros_like(dw_ref)

    # total mean cotangent: forward was G = sum_k w_k g_k, ssq = <G, G>
    dGt = dG_ref[...] + 2.0 * dssq_ref[0, 0] * G_ref[...]     # (br, LANES)
    dg_ref[...] = w_ref[...][:, :, None] * dGt[None, :, :]    # dg_k = w_k dGt
    dw_ref[...] += jnp.sum(jnp.sum(g_ref[...] * dGt[None, :, :], axis=2),
                           axis=1, keepdims=True)             # dw_k = <g_k,dGt>


def aggregate_pass_bwd(g_stack: jax.Array, w_norm: jax.Array, G: jax.Array,
                       dG: jax.Array, dssq: jax.Array, *,
                       block_rows: int = 256, interpret: bool = False
                       ) -> Tuple[jax.Array, jax.Array]:
    """VJP of :func:`aggregate_pass` w.r.t. (g_stack, w_norm).

    g_stack/(dG, dssq): primals/cotangents as produced by the forward; G is
    the saved forward output.  Returns (dg_stack (cohort, rows, LANES),
    dw (cohort,))."""
    cohort, rows, lanes = g_stack.shape
    assert lanes == LANES, g_stack.shape
    br = _block_rows(rows, block_rows)
    dg, dw = pl.pallas_call(
        _aggregate_bwd_kernel,
        grid=(rows // br,),
        in_specs=[
            pl.BlockSpec((cohort, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((cohort, br, LANES), lambda i: (0, i, 0)),
            pl.BlockSpec((br, LANES), lambda i: (i, 0)),
            pl.BlockSpec((br, LANES), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((cohort, br, LANES), lambda i: (0, i, 0)),
            pl.BlockSpec((cohort, 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((cohort, rows, LANES), jnp.float32),
            jax.ShapeDtypeStruct((cohort, 1), jnp.float32),
        ],
        interpret=interpret,
    )(w_norm.astype(jnp.float32).reshape(cohort, 1),
      dssq.astype(jnp.float32).reshape(1, 1), g_stack, G, dG)
    return dg, dw[:, 0]


# ---------------------------------------------------------------------------
# Backward pass 2: cotangents through clip-scale + optimizer recurrence
# ---------------------------------------------------------------------------
def _update_bwd_kernel(scal_ref, *refs, opt: str, momentum: float, b1: float,
                       b2: float, eps: float):
    i = pl.program_id(0)
    s = scal_ref[0, 0]
    lr = scal_ref[0, 1]
    G = refs[0][...]
    g = G * s                                         # clipped gradient tile
    dbc1 = dbc2 = jnp.float32(0.0)

    if opt == "sgd":
        # p' = p - lr * g
        dpn_ref, dG_ref, dscal_ref = refs[1], refs[2], refs[3]
        dpn = dpn_ref[...]
        dg = -lr * dpn
        dlr = -jnp.sum(g * dpn)
    elif opt == "sgdm":
        # m' = mu m + g;  p' = p - lr m'
        m_ref, dpn_ref, dmn_ct_ref = refs[1], refs[2], refs[3]
        dG_ref, dm_ref, dscal_ref = refs[4], refs[5], refs[6]
        dpn = dpn_ref[...]
        m_new = momentum * m_ref[...] + g
        dmn = dmn_ct_ref[...] - lr * dpn
        dlr = -jnp.sum(m_new * dpn)
        dg = dmn
        dm_ref[...] = momentum * dmn
    else:  # adam / yogi: p' = p - lr * (m' bc1) / (sqrt(v' bc2) + eps)
        bc1 = scal_ref[0, 2]
        bc2 = scal_ref[0, 3]
        m_ref, v_ref = refs[1], refs[2]
        dpn_ref, dmn_ct_ref, dvn_ct_ref = refs[3], refs[4], refs[5]
        dG_ref, dm_ref, dv_ref, dscal_ref = refs[6], refs[7], refs[8], refs[9]
        dpn = dpn_ref[...]
        m_new = b1 * m_ref[...] + (1.0 - b1) * g
        if opt == "adam":
            v_new = b2 * v_ref[...] + (1.0 - b2) * g * g
        else:  # yogi (sign treated locally constant, like XLA's jnp.sign)
            sgn = jnp.sign(v_ref[...] - g * g)
            v_new = v_ref[...] - (1.0 - b2) * sgn * g * g
        rs = jnp.sqrt(v_new * bc2)
        denom = rs + eps
        step = m_new * bc1 / denom
        dstep = -lr * dpn
        dlr = -jnp.sum(step * dpn)
        dmn = dmn_ct_ref[...] + dstep * (bc1 / denom)
        dbc1 = jnp.sum(dstep * m_new / denom)
        ddenom = -dstep * step / denom
        # d sqrt blows up at 0; the padded tail rows (g = m = v = 0) must
        # stay exact zeros, so zero-guard the 1/(2 sqrt) factor.
        inv2rs = jnp.where(rs > 0.0, 0.5 / jnp.maximum(rs, 1e-30), 0.0)
        dvn = dvn_ct_ref[...] + ddenom * bc2 * inv2rs
        dbc2 = jnp.sum(ddenom * v_new * inv2rs)
        dm_ref[...] = b1 * dmn
        if opt == "adam":
            dv_ref[...] = b2 * dvn
            dg = (1.0 - b1) * dmn + 2.0 * (1.0 - b2) * g * dvn
        else:
            dv_ref[...] = dvn
            dg = (1.0 - b1) * dmn - 2.0 * (1.0 - b2) * sgn * g * dvn

    dG_ref[...] = s * dg

    @pl.when(i == 0)
    def _init():
        dscal_ref[0, 0] = jnp.float32(0.0)
        dscal_ref[0, 1] = jnp.float32(0.0)
        dscal_ref[0, 2] = jnp.float32(0.0)
        dscal_ref[0, 3] = jnp.float32(0.0)

    dscal_ref[0, 0] += jnp.sum(G * dg)                # dscale
    dscal_ref[0, 1] += dlr
    dscal_ref[0, 2] += dbc1
    dscal_ref[0, 3] += dbc2


def update_pass_bwd(G: jax.Array, m: Optional[jax.Array],
                    v: Optional[jax.Array], scalars: jax.Array,
                    d_new_p: jax.Array, d_new_m: Optional[jax.Array],
                    d_new_v: Optional[jax.Array], *, opt: str,
                    momentum: float = 0.9, b1: float = 0.9, b2: float = 0.99,
                    eps: float = 1e-8, block_rows: int = 256,
                    interpret: bool = False):
    """VJP of :func:`update_pass` w.r.t. (G, m, v, scalars); the param
    cotangent is the identity (p' = p - lr * step) and handled by the
    caller.  (G, m, v, scalars) are the saved forward residuals — the
    optimizer recurrence is replayed in-kernel rather than saving m'/v'.

    Returns (dG, dm, dv, dscalars (1, N_SCALARS)) with None slots matching
    the optimizer's state arity."""
    rows, lanes = G.shape
    assert lanes == LANES, G.shape
    br = _block_rows(rows, block_rows)
    tile = pl.BlockSpec((br, LANES), lambda i: (i, 0))
    # same SMEM placement as the forward's scalar operand; the (1, 4)
    # cotangent OUTPUT stays in VMEM like the forward's (1, 1) ssq
    scal_in = _scalar_spec(N_SCALARS, interpret)
    scal_out = pl.BlockSpec((1, N_SCALARS), lambda i: (0, 0))
    buf = jax.ShapeDtypeStruct((rows, LANES), jnp.float32)
    scal_buf = jax.ShapeDtypeStruct((1, N_SCALARS), jnp.float32)

    state_in = {"sgd": [], "sgdm": [m], "adam": [m, v], "yogi": [m, v]}[opt]
    ct_in = {"sgd": [d_new_p], "sgdm": [d_new_p, d_new_m],
             "adam": [d_new_p, d_new_m, d_new_v],
             "yogi": [d_new_p, d_new_m, d_new_v]}[opt]
    n_state = len(state_in)
    kernel = functools.partial(_update_bwd_kernel, opt=opt, momentum=momentum,
                               b1=b1, b2=b2, eps=eps)
    outs = pl.pallas_call(
        kernel,
        grid=(rows // br,),
        in_specs=[scal_in] + [tile] * (1 + n_state + len(ct_in)),
        out_specs=[tile] * (1 + n_state) + [scal_out],
        out_shape=[buf] * (1 + n_state) + [scal_buf],
        interpret=interpret,
    )(scalars.astype(jnp.float32), G, *state_in, *ct_in)
    dG = outs[0]
    dm = outs[1] if n_state >= 1 else None
    dv = outs[2] if n_state >= 2 else None
    return dG, dm, dv, outs[-1]

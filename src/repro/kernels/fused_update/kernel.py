"""Pallas TPU kernels for the fused server update (aggregate -> clip ->
apply) over flat fp32 buffers (layout: ``repro.core.flat``).

Two kernels, at most two passes over HBM per round:

  * :func:`aggregate_pass` — grid walks row tiles of the stacked client
    gradients ``(cohort, rows, LANES)``; each step reduces the cohort axis
    with the normalized weights (Eq. 14) and accumulates the global
    sum-of-squares into a (1, 1) output revisited by every grid step (TPU
    grids are sequential, so the accumulation is well-defined — same idiom
    as the flash_attention kv axis).
  * :func:`update_pass` — grid walks row tiles of the aggregated gradient,
    applies the clip scale and the server optimizer (sgd/sgdm/adam/yogi)
    and writes the new parameters (+ m/v slots) in one sweep.  Traced
    scalars (clip scale, lr, bias corrections) ride in a (1, 4) SMEM
    operand; static hyper-parameters (momentum, b1, b2, eps) are baked in.

Both kernels run on CPU with ``interpret=True`` (how the tier-1 suite
validates them) and lower through Mosaic on TPU unchanged.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU memory spaces; interpret mode works without them
    from jax.experimental.pallas import tpu as pltpu
except Exception:  # pragma: no cover
    pltpu = None

from repro.core.flat import LANES

# scalar operand layout for update_pass: [scale, lr, bc1, bc2]
N_SCALARS = 4


def _block_rows(rows: int, target: int = 256) -> int:
    """Largest power-of-two row tile <= target that divides ``rows``
    (rows is a multiple of 8 by construction of FlatSpec)."""
    br = min(target, rows)
    while rows % br:
        br //= 2
    return max(br, 1)


# ---------------------------------------------------------------------------
# Pass 1: weighted cohort reduce + global sum-of-squares
# ---------------------------------------------------------------------------
def _aggregate_kernel(w_ref, g_ref, out_ref, ssq_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        ssq_ref[0, 0] = jnp.float32(0.0)

    g = g_ref[...]                                    # (cohort, br, LANES)
    w = w_ref[...]                                    # (cohort, 1)
    G = jnp.sum(g * w[:, :, None], axis=0)            # (br, LANES)
    out_ref[...] = G
    ssq_ref[0, 0] += jnp.sum(G * G)


def aggregate_pass(g_stack: jax.Array, w_norm: jax.Array, *,
                   block_rows: int = 256, interpret: bool = False
                   ) -> Tuple[jax.Array, jax.Array]:
    """g_stack: (cohort, rows, LANES) fp32; w_norm: (cohort,) normalized
    weights.  Returns (G (rows, LANES) fp32, ssq () fp32)."""
    cohort, rows, lanes = g_stack.shape
    assert lanes == LANES, g_stack.shape
    br = _block_rows(rows, block_rows)
    G, ssq = pl.pallas_call(
        _aggregate_kernel,
        grid=(rows // br,),
        in_specs=[
            pl.BlockSpec((cohort, 1), lambda i: (0, 0)),
            pl.BlockSpec((cohort, br, LANES), lambda i: (0, i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((br, LANES), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, LANES), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(w_norm.astype(jnp.float32).reshape(cohort, 1), g_stack)
    return G, ssq[0, 0]


# ---------------------------------------------------------------------------
# Pass 2: clip-scale + server optimizer + parameter write
# ---------------------------------------------------------------------------
def _update_kernel(scal_ref, *refs, opt: str, momentum: float, b1: float,
                   b2: float, eps: float):
    scale = scal_ref[0, 0]
    lr = scal_ref[0, 1]
    g = refs[0][...] * scale                          # clipped gradient tile
    p = refs[1][...]

    if opt == "sgd":
        new_p_ref = refs[2]
        new_p_ref[...] = p - lr * g
        return
    if opt == "sgdm":
        m_ref, new_p_ref, new_m_ref = refs[2], refs[3], refs[4]
        m = momentum * m_ref[...] + g
        new_m_ref[...] = m
        new_p_ref[...] = p - lr * m
        return
    # adam / yogi
    bc1 = scal_ref[0, 2]
    bc2 = scal_ref[0, 3]
    m_ref, v_ref = refs[2], refs[3]
    new_p_ref, new_m_ref, new_v_ref = refs[4], refs[5], refs[6]
    m = b1 * m_ref[...] + (1.0 - b1) * g
    if opt == "adam":
        v = b2 * v_ref[...] + (1.0 - b2) * g * g
    else:  # yogi
        v0 = v_ref[...]
        v = v0 - (1.0 - b2) * jnp.sign(v0 - g * g) * g * g
    new_m_ref[...] = m
    new_v_ref[...] = v
    new_p_ref[...] = p - lr * (m * bc1) / (jnp.sqrt(v * bc2) + eps)


def update_pass(G: jax.Array, p: jax.Array, m: Optional[jax.Array],
                v: Optional[jax.Array], scalars: jax.Array, *, opt: str,
                momentum: float = 0.9, b1: float = 0.9, b2: float = 0.99,
                eps: float = 1e-8, block_rows: int = 256,
                interpret: bool = False):
    """One fused optimizer sweep over a flat buffer group.

    scalars: (1, N_SCALARS) fp32 = [scale, lr, bc1, bc2] (traced).
    Returns (new_p, new_m, new_v) with None slots per optimizer arity."""
    rows, lanes = G.shape
    assert lanes == LANES, G.shape
    br = _block_rows(rows, block_rows)
    tile = pl.BlockSpec((br, LANES), lambda i: (i, 0))
    scal_spec = (pl.BlockSpec((1, N_SCALARS), lambda i: (0, 0),
                              memory_space=pltpu.SMEM)
                 if pltpu is not None and not interpret
                 else pl.BlockSpec((1, N_SCALARS), lambda i: (0, 0)))
    buf = jax.ShapeDtypeStruct((rows, LANES), jnp.float32)

    state_in = {"sgd": [], "sgdm": [m], "adam": [m, v], "yogi": [m, v]}[opt]
    n_out = 1 + len(state_in)
    kernel = functools.partial(_update_kernel, opt=opt, momentum=momentum,
                               b1=b1, b2=b2, eps=eps)
    outs = pl.pallas_call(
        kernel,
        grid=(rows // br,),
        in_specs=[scal_spec] + [tile] * (2 + len(state_in)),
        out_specs=[tile] * n_out,
        out_shape=[buf] * n_out,
        interpret=interpret,
    )(scalars.astype(jnp.float32), G, p, *state_in)
    new_p = outs[0]
    new_m = outs[1] if len(outs) > 1 else None
    new_v = outs[2] if len(outs) > 2 else None
    return new_p, new_m, new_v

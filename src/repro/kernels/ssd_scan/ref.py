"""Pure-jnp oracle for the SSD scan kernel — sequential recurrence, the
definitionally-correct form: h_t = exp(a_t) h_{t-1} + dt_t B_t x_t^T;
y_t = C_t h_t."""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax


def ssd_ref(x, dt, a, Bm, Cm):
    """x: (BH, S, P); dt/a: (BH, S, 1); Bm/Cm: (BH, S, N) -> (BH, S, P)."""
    BH, S, P = x.shape
    N = Bm.shape[-1]

    def step(h, inp):
        xt, dtt, at, bt, ct = inp          # (BH,P),(BH,1),(BH,1),(BH,N),(BH,N)
        h = (jnp.exp(at.astype(jnp.float32))[..., None] * h +
             jnp.einsum("bn,bp->bnp", bt.astype(jnp.float32),
                        xt.astype(jnp.float32) * dtt.astype(jnp.float32)))
        y = jnp.einsum("bn,bnp->bp", ct.astype(jnp.float32), h)
        return h, y

    h0 = jnp.zeros((BH, N, P), jnp.float32)
    sw = lambda t: t.transpose(1, 0, 2)
    _, ys = lax.scan(step, h0, (sw(x), sw(dt), sw(a), sw(Bm), sw(Cm)))
    return ys.transpose(1, 0, 2).astype(x.dtype)

"""Pallas TPU kernel for the Mamba2 SSD chunked scan.

State-space duality (arXiv:2405.21060): within a chunk of length L the
recurrence collapses to a masked quadratic form (MXU work); across chunks a
sequential state recurrence carries h (N x P) in VMEM scratch.

  grid = (B*H, S/L)        (chunk axis innermost => sequential on TPU)
  x tile  (L, P)  VMEM     dt/a tiles (L,) via (L,1)
  B,C     (L, N)  VMEM
  scratch h (N, P) float32 VMEM — the inter-chunk state

L=chunk (default 256) and N/P are 64/128 for the assigned archs — MXU
aligned.  Decay math in fp32 exactly as the oracle (ref.py / models.ssm).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, h_ref, *,
                L: int, nchunks: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0].astype(jnp.float32)            # (L, P)
    dt = dt_ref[0].astype(jnp.float32)          # (L, 1)
    a = a_ref[0].astype(jnp.float32)            # (L, 1)  a = dt * A  (<= 0)
    Bm = b_ref[0].astype(jnp.float32)           # (L, N)
    Cm = c_ref[0].astype(jnp.float32)           # (L, N)

    acum = jnp.cumsum(a, axis=0)                # (L, 1) inclusive
    # intra-chunk: (C B^T ⊙ decay) (x*dt)
    seg = acum - acum.reshape(1, L)             # (L, L): acum[t] - acum[s]
    tri = (jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
           >= jax.lax.broadcasted_iota(jnp.int32, (L, L), 1))
    decay = jnp.where(tri, jnp.exp(seg), 0.0)
    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    M = scores * decay                          # (L, L)
    xdt = x * dt                                # (L, P)
    y = jax.lax.dot_general(M, xdt, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    # inter-chunk contribution of the carried state
    y = y + jax.lax.dot_general(Cm * jnp.exp(acum), h_ref[...],
                                (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    y_ref[0] = y.astype(y_ref.dtype)
    # state update: h <- exp(sum a) h + sum_s exp(acum[-1]-acum[s]) dt_s B_s x_s^T
    decay_to_end = jnp.exp(acum[L - 1:L] - acum)            # (L, 1)
    h_new = (jnp.exp(acum[L - 1, 0]) * h_ref[...] +
             jax.lax.dot_general(Bm * decay_to_end, xdt,
                                 (((0,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32))
    h_ref[...] = h_new


def ssd_scan_fwd(x, dt, a, Bm, Cm, *, chunk: int = 256,
                 interpret: bool = False):
    """x: (BH, S, P); dt/a: (BH, S, 1); Bm/Cm: (BH, S, N).
    a = dt * A per position (precomputed, <= 0).  Returns y (BH, S, P)."""
    BH, S, P = x.shape
    N = Bm.shape[-1]
    L = min(chunk, S)
    assert S % L == 0, (S, L)
    nchunks = S // L

    kernel = functools.partial(_ssd_kernel, L=L, nchunks=nchunks)
    return pl.pallas_call(
        kernel,
        grid=(BH, nchunks),
        in_specs=[
            pl.BlockSpec((1, L, P), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, L, 1), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, L, 1), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, L, N), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, L, N), lambda bh, ci: (bh, ci, 0)),
        ],
        out_specs=pl.BlockSpec((1, L, P), lambda bh, ci: (bh, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(x, dt, a, Bm, Cm)

"""Jit'd public wrapper for the SSD-scan Pallas kernel.

Takes the framework layout (B, S, H, P) + per-head A, handles head folding
and group-broadcast B/C, interpret-mode switch for CPU validation.
"""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.ssd_scan.kernel import ssd_scan_fwd


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, A, Bm, Cm, *, chunk: int = 256, interpret: bool = False):
    """x: (B, S, H, P); dt: (B, S, H); A: (H,) negative;
    Bm/Cm: (B, S, H, N) (groups pre-broadcast).  Returns (B, S, H, P)."""
    B, S, H, P = x.shape
    a = dt * A[None, None, :]                       # (B,S,H)
    fold = lambda t: t.transpose(0, 2, 1, 3).reshape(B * H, S, t.shape[-1])
    xf = fold(x)
    dtf = fold(dt[..., None])
    af = fold(a[..., None])
    bf = fold(Bm)
    cf = fold(Cm)
    yf = ssd_scan_fwd(xf, dtf, af, bf, cf, chunk=chunk, interpret=interpret)
    return yf.reshape(B, H, S, P).transpose(0, 2, 1, 3)

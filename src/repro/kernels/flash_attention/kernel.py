"""Pallas TPU flash-attention forward kernel.

Blocked online-softmax with explicit VMEM tiling:

  grid = (batch*q_heads, Sq/bq, Skv/bk)   (kv axis innermost => sequential
                                           on TPU, accumulators in VMEM)
  q tile   (bq, D)   VMEM
  k,v tile (bk, D)   VMEM  (kv head = q head // group, via the index map —
                            GQA without materializing repeated KV)
  scratch: m (bq,), l (bq,), acc (bq, D)  float32 VMEM

bq/bk default 512/512 and D is a multiple of the 128-lane MXU dimension for
every assigned arch (head_dim 64/96/128/192) — tiles are hardware-aligned.
Numerics follow the same scheme as the XLA fallback
(repro.models.attention): fp32 max/exp/sum, bf16 operands into the MXU.

Validated on CPU with interpret=True against ref.py (the pure-jnp oracle);
on TPU the same pallas_call lowers through Mosaic.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU memory spaces (ANY/VMEM); interpret mode works without them
    from jax.experimental.pallas import tpu as pltpu
    VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    pltpu = None
    VMEM = None

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
               bq: int, bk: int, nk: int, scale: float, causal: bool,
               window: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                                     # (bq, D)
    k = k_ref[0]                                     # (bk, D)
    v = v_ref[0]
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale  # (bq, bk)

    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    rel = q_pos - k_pos
    if causal:
        s = jnp.where(rel >= 0, s, NEG_INF)
    if window > 0:
        s = jnp.where(rel < window, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        l_safe = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l_safe[:, None]).astype(o_ref.dtype)


def flash_attention_fwd(q, k, v, *, causal: bool = True, window: int = 0,
                        bq: int = 512, bk: int = 512,
                        interpret: bool = False):
    """q: (BH, Sq, D); k/v: (BHkv, Skv, D) with BH = B*H, BHkv = B*Hkv and
    the head axis ordered (b, h) so kv_head = h // group.
    Returns (BH, Sq, D)."""
    BH, Sq, D = q.shape
    BHkv, Skv, _ = k.shape
    assert BH % BHkv == 0
    group = BH // BHkv
    bq = min(bq, Sq)
    bk = min(bk, Skv)
    assert Sq % bq == 0 and Skv % bk == 0, (Sq, bq, Skv, bk)
    nq, nk = Sq // bq, Skv // bk
    scale = 1.0 / math.sqrt(D)

    kernel = functools.partial(_fa_kernel, bq=bq, bk=bk, nk=nk, scale=scale,
                               causal=causal, window=window)
    scratch = [pltpu.VMEM((bq,), jnp.float32),
               pltpu.VMEM((bq,), jnp.float32),
               pltpu.VMEM((bq, D), jnp.float32)]

    # NOTE on the head index maps: q/o tiles walk (bh, qi); k/v tiles share
    # one kv head across `group` q heads (bh // group) — GQA stays a pure
    # indexing fact, no repeated KV in HBM.
    return pl.pallas_call(
        kernel,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, bk, D), lambda bh, qi, ki, group=group:
                         (bh // group, ki, 0)),
            pl.BlockSpec((1, bk, D), lambda bh, qi, ki, group=group:
                         (bh // group, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, D), q.dtype),
        scratch_shapes=scratch,
        interpret=interpret,
    )(q, k, v)

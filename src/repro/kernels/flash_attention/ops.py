"""Jit'd public wrapper for the flash-attention Pallas kernel.

Accepts the framework's (B, S, H, D) layout, handles GQA head folding,
padding to block multiples, and the interpret-mode switch (CPU validation
vs TPU Mosaic lowering).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_fwd


@partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                   "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    bq: int = 512, bk: int = 512, interpret: bool = False):
    """q: (B, Sq, H, D); k/v: (B, Skv, Hkv, D) -> (B, Sq, H, D)."""
    B, Sq, H, D = q.shape
    _, Skv, Hkv, _ = k.shape
    bq = min(bq, Sq)
    bk = min(bk, Skv)
    pad_q = (-Sq) % bq
    pad_k = (-Skv) % bk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        if not causal:
            raise ValueError("non-causal padding needs kv masking; pad "
                             "inputs to block multiples instead")
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, q.shape[1], D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * Hkv, k.shape[1], D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * Hkv, v.shape[1], D)
    of = flash_attention_fwd(qf, kf, vf, causal=causal, window=window,
                             bq=bq, bk=bk, interpret=interpret)
    o = of.reshape(B, H, q.shape[1], D).transpose(0, 2, 1, 3)
    return o[:, :Sq]

"""Pure-jnp oracle for the flash-attention kernel (same (BH, S, D) layout)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q: (BH, Sq, D); k/v: (BHkv, Skv, D); kv head = q head // group."""
    BH, Sq, D = q.shape
    BHkv, Skv, _ = k.shape
    group = BH // BHkv
    k = jnp.repeat(k, group, axis=0)
    v = jnp.repeat(v, group, axis=0)
    s = jnp.einsum("bqd,bkd->bqk", q, k,
                   preferred_element_type=jnp.float32) / math.sqrt(D)
    rel = jnp.arange(Sq)[:, None] - jnp.arange(Skv)[None, :]
    if causal:
        s = jnp.where(rel >= 0, s, NEG_INF)
    if window > 0:
        s = jnp.where(rel < window, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p.astype(v.dtype), v).astype(v.dtype)

"""Pure-jnp oracles for the communication-compression kernels.

Same math as ``kernel.py``, element for element — the ``use_ref=True`` arm
of ``repro.kernels.comm.ops`` and the oracle the kernel tests compare
against (bit-exact for the integer pack stages, same fp32 contraction for
the FMA stages).  The pad convention matches the kernels: int8 pad is
self-inert (0 -> 0), sign decode masks elements with flat index
>= ``n_valid`` back to exact zero.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.comm.kernel import SIGN_PACK


def _sign_bits(g: jax.Array) -> jax.Array:
    return (g >= 0.0).astype(jnp.int32)


def _valid_mask(shape: Tuple[int, int], n_valid) -> jax.Array:
    rows, lanes = shape
    row = jax.lax.broadcasted_iota(jnp.int32, shape, 0)
    lane = jax.lax.broadcasted_iota(jnp.int32, shape, 1)
    return (row * lanes + lane) < n_valid


def quantize_i8_ref(g: jax.Array, inv_scale, scale, *,
                    with_error: bool = False):
    q = jnp.clip(jnp.round(g * jnp.asarray(inv_scale, jnp.float32)),
                 -127.0, 127.0)
    q8 = q.astype(jnp.int8)
    if not with_error:
        return q8
    return q8, g - q * jnp.asarray(scale, jnp.float32)


def dequant_i8_fma_ref(acc: jax.Array, q: jax.Array, scale_w) -> jax.Array:
    return acc + jnp.asarray(scale_w, jnp.float32) * q.astype(jnp.float32)


def sign_pack_ref(g: jax.Array, mu, n_valid: int, *,
                  with_error: bool = False):
    rows, lanes = g.shape
    bits = _sign_bits(g).reshape(rows // SIGN_PACK, SIGN_PACK, lanes)
    shifts = jax.lax.broadcasted_iota(jnp.int32, (1, SIGN_PACK, 1), 1)
    packed = jnp.sum(bits << shifts, axis=1).astype(jnp.uint8)
    if not with_error:
        return packed
    s = (2 * _sign_bits(g) - 1).astype(jnp.float32)
    dec = jnp.asarray(mu, jnp.float32) * jnp.where(
        _valid_mask(g.shape, n_valid), s, 0.0)
    return packed, g - dec


def sign_unpack_fma_ref(acc: jax.Array, packed: jax.Array, mu_w,
                        n_valid: int) -> jax.Array:
    rows, lanes = acc.shape
    shifts = jax.lax.broadcasted_iota(jnp.int32, (1, SIGN_PACK, 1), 1)
    bits = (packed.astype(jnp.int32)[:, None, :] >> shifts) & 1
    s = (2 * bits - 1).astype(jnp.float32).reshape(rows, lanes)
    dec = jnp.where(_valid_mask(acc.shape, n_valid), s, 0.0)
    return acc + jnp.asarray(mu_w, jnp.float32) * dec

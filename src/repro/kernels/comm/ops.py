"""Dispatch layer for the comm kernels — same conventions as
``kernels/fused_update/ops``: ``use_ref=True`` swaps in the pure-jnp
oracle, ``interpret`` defaults to True off-TPU so the identical code path
runs in the CPU tier-1 suite.  These are the primitives the codecs in
``repro.comm.codecs`` compose; nothing here owns scales/magnitudes — the
codec computes those (one jnp reduction) and the kernels do the sweeps.
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.kernels.comm import kernel as K
from repro.kernels.comm import ref as R


def _interp(interpret: Optional[bool]) -> bool:
    return jax.default_backend() != "tpu" if interpret is None else interpret


def quantize_i8(g, inv_scale, scale, *, with_error: bool = False,
                use_ref: bool = False, interpret: Optional[bool] = None):
    if use_ref:
        return R.quantize_i8_ref(g, inv_scale, scale, with_error=with_error)
    return K.quantize_i8_pass(g, inv_scale, scale, with_error=with_error,
                              interpret=_interp(interpret))


def dequant_i8_fma(acc, q, scale_w, *, use_ref: bool = False,
                   interpret: Optional[bool] = None):
    if use_ref:
        return R.dequant_i8_fma_ref(acc, q, scale_w)
    return K.dequant_i8_fma_pass(acc, q, scale_w,
                                 interpret=_interp(interpret))


def sign_pack(g, mu, n_valid: int, *, with_error: bool = False,
              use_ref: bool = False, interpret: Optional[bool] = None):
    if use_ref:
        return R.sign_pack_ref(g, mu, n_valid, with_error=with_error)
    return K.sign_pack_pass(g, mu, n_valid, with_error=with_error,
                            interpret=_interp(interpret))


def sign_unpack_fma(acc, packed, mu_w, n_valid: int, *,
                    use_ref: bool = False,
                    interpret: Optional[bool] = None):
    if use_ref:
        return R.sign_unpack_fma_ref(acc, packed, mu_w, n_valid)
    return K.sign_unpack_fma_pass(acc, packed, mu_w, n_valid,
                                  interpret=_interp(interpret))

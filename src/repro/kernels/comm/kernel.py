"""Pallas TPU kernels for the communication-compression uplink
(``repro.comm``) over flat fp32 buffers (layout: ``repro.core.flat``).

The uplink simulation is: client encodes its flat gradient group, the
server decodes and folds it into the Eq. (14) accumulator.  Each codec
stage is ONE HBM sweep, mirroring the ``kernels/fused_update`` structure:

  * :func:`quantize_i8_pass` — symmetric per-group int8 quantization
    ``q = clip(round(g / scale), -127, 127)``; with ``with_error=True`` it
    also emits the quantization residual ``g - q * scale`` in the same
    sweep (the error-feedback memory, so EF costs no extra pass).
  * :func:`dequant_i8_fma_pass` — decode fused into the streaming FMA of
    the scan cohort strategy: ``acc + (scale * w_k) * q`` — the int8
    analogue of ``fused_update.accumulate_pass`` (scale and the normalized
    client weight fold into ONE scalar, so decode costs nothing extra).
  * :func:`sign_pack_pass` — signSGD-style 1-bit pack: 8 consecutive rows
    of sign bits pack into one uint8 row ``(rows // 8, LANES)``; with
    ``with_error=True`` also emits ``g - mu * sign(g)`` (valid elements
    only — see the padding note below).
  * :func:`sign_unpack_fma_pass` — unpack + decode + FMA in one sweep:
    ``acc + (mu * w_k) * sign``.

Padding note: the flat layout zero-pads each group to a row multiple.  For
int8 the pad is self-inert (g = 0 -> q = 0 -> decode 0), but a sign bit
decodes 0 to ``+mu``, so the unpack kernels mask elements ``>= n_valid``
(the group's true size) back to zero — keeping the "pad is mathematically
inert" invariant every downstream consumer (``flat_sq_norm``, optimizer
slots, error-feedback state) relies on.

All kernels run on CPU with ``interpret=True`` (the tier-1 path) and are
written to lower through Mosaic on TPU (2D ``broadcasted_iota``, sublane
reshapes only); TPU timing is a ROADMAP item alongside the fused-update
backward pair.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.flat import LANES
from repro.kernels.fused_update.kernel import _block_rows, _scalar_spec

SIGN_PACK = 8         # rows of sign bits per packed uint8 row


# ---------------------------------------------------------------------------
# int8: quantize (+ error) / dequantize-FMA
# ---------------------------------------------------------------------------
def _quantize_i8_kernel(scal_ref, g_ref, *out_refs, with_error: bool):
    inv = scal_ref[0, 0]
    g = g_ref[...]
    q = jnp.clip(jnp.round(g * inv), -127.0, 127.0)
    out_refs[0][...] = q.astype(jnp.int8)
    if with_error:
        scale = scal_ref[0, 1]
        out_refs[1][...] = g - q * scale


def quantize_i8_pass(g: jax.Array, inv_scale, scale, *,
                     with_error: bool = False, block_rows: int = 256,
                     interpret: bool = False):
    """g: (rows, LANES) fp32; inv_scale/scale: scalars (scale = amax/127).
    Returns q (rows, LANES) int8, plus the residual ``g - q * scale`` when
    ``with_error`` (error feedback fused into the quantize sweep)."""
    rows, lanes = g.shape
    assert lanes == LANES, g.shape
    br = _block_rows(rows, block_rows)
    tile = pl.BlockSpec((br, LANES), lambda i: (i, 0))
    out_shape = [jax.ShapeDtypeStruct((rows, LANES), jnp.int8)]
    out_specs = [tile]
    if with_error:
        out_shape.append(jax.ShapeDtypeStruct((rows, LANES), jnp.float32))
        out_specs.append(tile)
    scalars = jnp.stack([jnp.asarray(inv_scale, jnp.float32),
                         jnp.asarray(scale, jnp.float32)]).reshape(1, 2)
    outs = pl.pallas_call(
        functools.partial(_quantize_i8_kernel, with_error=with_error),
        grid=(rows // br,),
        in_specs=[_scalar_spec(2, interpret), tile],
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(scalars, g)
    return (outs[0], outs[1]) if with_error else outs[0]


def _dequant_i8_fma_kernel(sw_ref, acc_ref, q_ref, out_ref):
    out_ref[...] = acc_ref[...] + sw_ref[0, 0] * q_ref[...].astype(jnp.float32)


def dequant_i8_fma_pass(acc: jax.Array, q: jax.Array, scale_w, *,
                        block_rows: int = 256, interpret: bool = False
                        ) -> jax.Array:
    """Streaming decode+accumulate: ``acc + scale_w * q`` with
    ``scale_w = scale * w_k`` folded into one scalar — the codec analogue
    of ``fused_update.accumulate_pass``."""
    rows, lanes = acc.shape
    assert lanes == LANES, acc.shape
    br = _block_rows(rows, block_rows)
    tile = pl.BlockSpec((br, LANES), lambda i: (i, 0))
    return pl.pallas_call(
        _dequant_i8_fma_kernel,
        grid=(rows // br,),
        in_specs=[_scalar_spec(1, interpret), tile, tile],
        out_specs=tile,
        out_shape=jax.ShapeDtypeStruct((rows, LANES), jnp.float32),
        interpret=interpret,
    )(jnp.asarray(scale_w, jnp.float32).reshape(1, 1), acc, q)


# ---------------------------------------------------------------------------
# sign1bit: pack (+ error) / unpack-FMA
# ---------------------------------------------------------------------------
def _sign_bits(g: jax.Array) -> jax.Array:
    """1 where g >= 0 else 0 (int32).  sign(0) := +1 so decode is a pure
    two-point alphabet {-mu, +mu}; the pad mask restores exact zeros."""
    return (g >= 0.0).astype(jnp.int32)


def _valid_mask(i, rows_block: int, lanes: int, n_valid) -> jax.Array:
    """Elements of this (rows_block, lanes) tile whose row-major flat index
    (within the whole group buffer) is < n_valid."""
    row = jax.lax.broadcasted_iota(jnp.int32, (rows_block, lanes), 0) \
        + i * rows_block
    lane = jax.lax.broadcasted_iota(jnp.int32, (rows_block, lanes), 1)
    return (row * lanes + lane) < n_valid


def _sign_pack_kernel(scal_ref, n_ref, g_ref, *out_refs, with_error: bool,
                      rows_block: int):
    i = pl.program_id(0)
    g = g_ref[...]                                    # (rows_block, LANES)
    bits = _sign_bits(g).reshape(rows_block // SIGN_PACK, SIGN_PACK, LANES)
    shifts = jax.lax.broadcasted_iota(jnp.int32, (1, SIGN_PACK, 1), 1)
    out_refs[0][...] = jnp.sum(bits << shifts, axis=1).astype(jnp.uint8)
    if with_error:
        mu = scal_ref[0, 0]
        s = (2 * _sign_bits(g) - 1).astype(jnp.float32)
        dec = mu * jnp.where(
            _valid_mask(i, rows_block, LANES, n_ref[0, 0]), s, 0.0)
        out_refs[1][...] = g - dec


def sign_pack_pass(g: jax.Array, mu, n_valid: int, *,
                   with_error: bool = False, block_rows: int = 256,
                   interpret: bool = False):
    """g: (rows, LANES) fp32 -> packed sign bits (rows // 8, LANES) uint8
    (row r of g lands in bit ``r % 8`` of packed row ``r // 8``).  ``mu``
    is the per-group magnitude (mean |g| over the n_valid true elements);
    with ``with_error`` also emits ``g - mu * sign(g)`` (pad masked to 0)
    in the same sweep."""
    rows, lanes = g.shape
    assert lanes == LANES and rows % SIGN_PACK == 0, g.shape
    br = _block_rows(rows, block_rows)
    if br % SIGN_PACK:                     # rows is a multiple of 8, so a
        br = SIGN_PACK                     # full-pack tile always exists
    tile = pl.BlockSpec((br, LANES), lambda i: (i, 0))
    pack_tile = pl.BlockSpec((br // SIGN_PACK, LANES), lambda i: (i, 0))
    out_shape = [jax.ShapeDtypeStruct((rows // SIGN_PACK, LANES), jnp.uint8)]
    out_specs = [pack_tile]
    if with_error:
        out_shape.append(jax.ShapeDtypeStruct((rows, LANES), jnp.float32))
        out_specs.append(tile)
    outs = pl.pallas_call(
        functools.partial(_sign_pack_kernel, with_error=with_error,
                          rows_block=br),
        grid=(rows // br,),
        in_specs=[_scalar_spec(1, interpret),
                  pl.BlockSpec((1, 1), lambda i: (0, 0)), tile],
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(jnp.asarray(mu, jnp.float32).reshape(1, 1),
      jnp.asarray(n_valid, jnp.int32).reshape(1, 1), g)
    return (outs[0], outs[1]) if with_error else outs[0]


def _sign_unpack_fma_kernel(muw_ref, n_ref, acc_ref, p_ref, out_ref, *,
                            rows_block: int):
    i = pl.program_id(0)
    packed = p_ref[...].astype(jnp.int32)             # (rows_block/8, LANES)
    shifts = jax.lax.broadcasted_iota(jnp.int32, (1, SIGN_PACK, 1), 1)
    bits = (packed[:, None, :] >> shifts) & 1
    s = (2 * bits - 1).astype(jnp.float32).reshape(rows_block, LANES)
    dec = jnp.where(_valid_mask(i, rows_block, LANES, n_ref[0, 0]), s, 0.0)
    out_ref[...] = acc_ref[...] + muw_ref[0, 0] * dec


def sign_unpack_fma_pass(acc: jax.Array, packed: jax.Array, mu_w,
                         n_valid: int, *, block_rows: int = 256,
                         interpret: bool = False) -> jax.Array:
    """Unpack + decode + streaming FMA: ``acc + mu_w * sign`` with
    ``mu_w = mu * w_k`` folded into one scalar; packed-pad elements
    (flat index >= n_valid) contribute exact zeros."""
    rows, lanes = acc.shape
    assert lanes == LANES and rows % SIGN_PACK == 0, acc.shape
    assert packed.shape == (rows // SIGN_PACK, LANES), packed.shape
    br = _block_rows(rows, block_rows)
    if br % SIGN_PACK:
        br = SIGN_PACK
    tile = pl.BlockSpec((br, LANES), lambda i: (i, 0))
    pack_tile = pl.BlockSpec((br // SIGN_PACK, LANES), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_sign_unpack_fma_kernel, rows_block=br),
        grid=(rows // br,),
        in_specs=[_scalar_spec(1, interpret),
                  pl.BlockSpec((1, 1), lambda i: (0, 0)), tile, pack_tile],
        out_specs=tile,
        out_shape=jax.ShapeDtypeStruct((rows, LANES), jnp.float32),
        interpret=interpret,
    )(jnp.asarray(mu_w, jnp.float32).reshape(1, 1),
      jnp.asarray(n_valid, jnp.int32).reshape(1, 1), acc, packed)

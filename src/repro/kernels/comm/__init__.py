"""Pallas pack/unpack kernels for the gradient-compression uplink
(``repro.comm``): int8 quantize / dequantize-FMA and 1-bit sign pack /
unpack-FMA over the flat ``(rows, LANES)`` dtype-group buffers of
``repro.core.flat`` — same conventions as ``kernels/fused_update``
(interpret-mode CPU path, pure-jnp ``ref`` oracles, fp32 math)."""
from repro.kernels.comm.ops import (dequant_i8_fma, quantize_i8, sign_pack,
                                    sign_unpack_fma)

__all__ = ["quantize_i8", "dequant_i8_fma", "sign_pack", "sign_unpack_fma"]

"""Pass 1 — RNG discipline (FL101-FL103).

The reproducibility claims rest on constant fold tags drawn from ONE
registry (:mod:`repro.core.rngtags`): every stream separates from its
siblings by folding a dedicated constant, and two streams folding the same
constant out of the same key ARE the same stream.  The rules:

  * **FL101** — a constant rng tag written inline: ``jax.random.fold_in(k,
    0x1234)`` or ``fold_in(k, LOCAL_CONST)`` where the name is a
    module-level int of the same file instead of an import from
    ``repro.core.rngtags``; likewise literal int components of
    ``np.random.default_rng((seed, 7777, ...))`` seed tuples.  Dynamic
    tags (loop indices, parameters, arithmetic on registry names) are the
    sanctioned pattern and never flagged.  ``core/rngtags.py`` itself is
    exempt — it is the registry.
  * **FL102** — two constant tags share a value (registry names and/or
    inline constants): the silent stream collision the registry exists to
    prevent.
  * **FL103** — the same key variable is consumed twice by ``jax.random``
    draws in one straight-line statement list without being re-derived
    (``split`` / ``fold_in`` rebinding) in between — the classic reused-key
    bug.  Branches of an ``if`` are separate lists, so alternative draws
    from one key never false-positive.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.fedlint.core import (Finding, ProjectIndex, SourceFile,
                                         dotted_root, dotted_tail)

# jax.random functions that CONSUME a key passed as first argument.
# fold_in / PRNGKey / key derivation are intentionally absent: deriving two
# different streams from one key via distinct tags is the sanctioned use.
_CONSUMING = frozenset({
    "bernoulli", "uniform", "normal", "randint", "exponential", "gamma",
    "beta", "laplace", "truncated_normal", "choice", "categorical",
    "permutation", "split", "bits", "gumbel", "poisson", "rademacher",
})


def _module_int_consts(sf: SourceFile) -> Dict[str, int]:
    out: Dict[str, int] = {}
    for node in sf.tree.body:
        if (isinstance(node, ast.Assign) and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)
                and isinstance(node.value, ast.Constant)
                and isinstance(node.value.value, int)
                and not isinstance(node.value.value, bool)):
            out[node.targets[0].id] = node.value.value
    return out


def _rngtags_imports(sf: SourceFile) -> Tuple[Set[str], Set[str]]:
    """(names imported FROM the registry, aliases OF the registry module)."""
    names: Set[str] = set()
    modules: Set[str] = set()
    for node in ast.walk(sf.tree):
        if isinstance(node, ast.ImportFrom) and node.module:
            if node.module.endswith("rngtags"):
                names.update(a.asname or a.name for a in node.names)
            elif node.module.endswith("repro.core"):
                for a in node.names:
                    if a.name == "rngtags":
                        modules.add(a.asname or "rngtags")
        elif isinstance(node, ast.Import):
            for a in node.names:
                if a.name.endswith("rngtags"):
                    modules.add(a.asname or a.name.split(".")[-1])
    return names, modules


def _tag_ok(tag: ast.AST, reg_names: Set[str], reg_mods: Set[str],
            local_consts: Dict[str, int]) -> Optional[str]:
    """None if the tag expression is acceptable; else a reason string."""
    if isinstance(tag, ast.Constant) and isinstance(tag.value, int) \
            and not isinstance(tag.value, bool):
        return (f"inline constant rng tag {tag.value:#x}; declare it in "
                "repro.core.rngtags and import it")
    if isinstance(tag, ast.Name):
        if tag.id in reg_names:
            return None
        if tag.id in local_consts:
            return (f"constant rng tag {tag.id} is defined locally; move "
                    "it to repro.core.rngtags (the tag registry) and "
                    "import it")
        return None                       # dynamic (param, loop index, ...)
    if isinstance(tag, ast.Attribute):
        root = dotted_root(tag)
        if root in reg_mods:
            return None
        return None                       # attribute of something else: dynamic
    # BinOp etc: acceptable iff no raw int literal participates at top level
    if isinstance(tag, ast.BinOp):
        for side in (tag.left, tag.right):
            reason = _tag_ok(side, reg_names, reg_mods, local_consts)
            if reason is not None:
                return reason
    return None


def _check_file_tags(sf: SourceFile,
                     inline_tags: List[Tuple[int, str, SourceFile, int]]
                     ) -> List[Finding]:
    findings: List[Finding] = []
    if sf.posix.endswith("core/rngtags.py"):
        return findings                   # the registry itself
    reg_names, reg_mods = _rngtags_imports(sf)
    local_consts = _module_int_consts(sf)

    for node in ast.walk(sf.tree):
        if not isinstance(node, ast.Call):
            continue
        tail = dotted_tail(node.func)
        if tail == "fold_in" and len(node.args) >= 2:
            tag = node.args[1]
            reason = _tag_ok(tag, reg_names, reg_mods, local_consts)
            if reason is not None:
                findings.append(Finding(sf.path, tag.lineno, "FL101",
                                        reason + " (fold_in tag)"))
            if isinstance(tag, ast.Constant) and isinstance(tag.value, int):
                inline_tags.append((tag.value, f"inline fold_in tag", sf,
                                    tag.lineno))
            elif isinstance(tag, ast.Name) and tag.id in local_consts:
                inline_tags.append((local_consts[tag.id],
                                    f"local constant {tag.id}", sf,
                                    tag.lineno))
        elif tail == "default_rng" and node.args:
            seed = node.args[0]
            if isinstance(seed, ast.Tuple):
                for el in seed.elts:
                    if isinstance(el, ast.Constant) \
                            and isinstance(el.value, int) \
                            and not isinstance(el.value, bool):
                        findings.append(Finding(
                            sf.path, el.lineno, "FL101",
                            f"inline constant seed-tuple component "
                            f"{el.value}; host rng streams separate via "
                            "constants from repro.core.rngtags too"))
                        inline_tags.append((el.value,
                                            "inline seed-tuple component",
                                            sf, el.lineno))
                    elif isinstance(el, ast.Name) and el.id in local_consts \
                            and el.id not in reg_names:
                        findings.append(Finding(
                            sf.path, el.lineno, "FL101",
                            f"constant seed-tuple component {el.id} is "
                            "defined locally; move it to "
                            "repro.core.rngtags and import it"))
                        inline_tags.append((local_consts[el.id],
                                            f"local constant {el.id}", sf,
                                            el.lineno))
    return findings


def _check_duplicates(index: ProjectIndex,
                      inline_tags: List[Tuple[int, str, SourceFile, int]]
                      ) -> List[Finding]:
    findings: List[Finding] = []
    seen: Dict[int, str] = {}
    for name, (value, sf, line) in sorted(index.rng_tags.items(),
                                          key=lambda kv: kv[1][2]):
        if value in seen:
            findings.append(Finding(
                sf.path, line, "FL102",
                f"rng tag {name} = {value:#x} collides with {seen[value]}; "
                "two streams folding the same constant out of one key are "
                "the SAME stream"))
        else:
            seen[value] = name
    for value, desc, sf, line in inline_tags:
        if value in seen:
            findings.append(Finding(
                sf.path, line, "FL102",
                f"{desc} = {value:#x} collides with registry tag "
                f"{seen[value]}"))
        else:
            seen[value] = f"{desc} ({sf.path}:{line})"
    return findings


def _consuming_uses(stmt: ast.stmt) -> List[Tuple[str, int]]:
    """(key name, line) for each jax.random draw whose key is a plain Name
    — in THIS statement's own expressions only: nested statement lists
    (loop/if bodies) are analyzed as independent straight-line scopes by
    the caller, and nested function/lambda bodies execute later."""
    out: List[Tuple[str, int]] = []

    def visit(node: ast.AST) -> None:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            return
        if isinstance(node, ast.stmt) and node is not stmt:
            return
        if isinstance(node, ast.Call):
            tail = dotted_tail(node.func)
            if tail in _CONSUMING and node.args \
                    and isinstance(node.args[0], ast.Name):
                root = dotted_root(node.func)
                # require a jax.random-ish chain or bare import: 'random'
                # in the chain or a bare name imported from jax.random
                chain_ok = isinstance(node.func, ast.Name) or root in (
                    "jax", "jrandom", "jr", "random")
                if chain_ok:
                    out.append((node.args[0].id, node.lineno))
        for child in ast.iter_child_nodes(node):
            visit(child)

    visit(stmt)
    return out


def _bound_names(stmt: ast.stmt) -> Set[str]:
    names: Set[str] = set()
    if isinstance(stmt, ast.Assign):
        targets = stmt.targets
    elif isinstance(stmt, (ast.AnnAssign, ast.AugAssign)):
        targets = [stmt.target]
    elif isinstance(stmt, (ast.For, ast.AsyncFor)):
        targets = [stmt.target]
    else:
        targets = []
    for t in targets:
        for node in ast.walk(t):
            if isinstance(node, ast.Name):
                names.add(node.id)
    for node in ast.walk(stmt):
        if isinstance(node, ast.NamedExpr) and isinstance(node.target,
                                                          ast.Name):
            names.add(node.target.id)
    return names


def _check_reuse_in_list(sf: SourceFile, body: List[ast.stmt],
                         findings: List[Finding]) -> None:
    used: Dict[str, int] = {}
    for stmt in body:
        for name, line in _consuming_uses(stmt):
            if name in used:
                findings.append(Finding(
                    sf.path, line, "FL103",
                    f"rng key {name!r} already consumed by a jax.random "
                    f"draw on line {used[name]}; re-derive with split/"
                    "fold_in before drawing again (reused keys correlate "
                    "streams)"))
            else:
                used[name] = line
        for name in _bound_names(stmt):
            used.pop(name, None)
        # recurse into nested statement lists as INDEPENDENT straight-line
        # scopes (if/else arms may legitimately draw from the same key)
        for attr in ("body", "orelse", "finalbody"):
            sub = getattr(stmt, attr, None)
            if isinstance(sub, list) and sub \
                    and isinstance(sub[0], ast.stmt):
                _check_reuse_in_list(sf, sub, findings)
        for handler in getattr(stmt, "handlers", []):
            _check_reuse_in_list(sf, handler.body, findings)
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            pass                           # already covered above via body


def check(index: ProjectIndex) -> List[Finding]:
    findings: List[Finding] = []
    inline_tags: List[Tuple[int, str, SourceFile, int]] = []
    for sf in index.files:
        findings.extend(_check_file_tags(sf, inline_tags))
        _check_reuse_in_list(sf, sf.tree.body, findings)
    findings.extend(_check_duplicates(index, inline_tags))
    return findings

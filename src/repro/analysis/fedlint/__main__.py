"""``python -m repro.analysis.fedlint <paths...>`` — run all passes and
exit 1 if anything is found (the CI ``analyze`` job's contract)."""
from __future__ import annotations

import argparse
import sys

from repro.analysis.fedlint.core import format_findings, run_fedlint


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.fedlint",
        description="repo-specific static analysis: rng-tag discipline, "
                    "kernel/ref/ops contracts, registry capability "
                    "surfaces, jit hygiene")
    ap.add_argument("paths", nargs="+",
                    help="files or directories to analyze (e.g. src/)")
    args = ap.parse_args(argv)
    findings = run_fedlint(args.paths)
    if findings:
        print(format_findings(findings))
        print(f"fedlint: {len(findings)} finding(s)", file=sys.stderr)
        return 1
    print("fedlint: clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())

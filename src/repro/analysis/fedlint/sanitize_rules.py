"""Pass 5 — checkify/sanitizer coverage (FL501).

The ``--sanitize`` runtime mode only means something if the round program
an engine runs under actually contains a probe site: a
``check_flat_groups(...)`` call guarded by ``if sanitize:`` (the probes
are free when the flag is off — checkify discards them — so the guard is
how builders keep the unsanitized program byte-identical).  PR 6/8 put
one in each round builder; a NEW engine (or a refactor of a builder) can
silently ship without one, and ``--sanitize`` then degrades to bare
``jax_debug_nans`` with no named flat-group diagnostics.

  * **FL501** — a ``@register_engine`` class whose round builder
    (``make_async_tick`` for ``is_async = True`` engines,
    ``make_federated_round`` otherwise) contains no
    ``check_flat_groups`` call under an ``if``-test referencing
    ``sanitize`` — and neither the class nor its bases carry such a
    probe in their own methods.

Under-approximation (fedlint's standing contract: what the analysis
cannot resolve it does not flag):

  * the engine's ``is_async`` must resolve to a literal ``True``/``False``
    on the class or a base in the analyzed tree (a missing declaration is
    FL301's finding, not this pass's);
  * the expected builder function must be DEFINED somewhere in the
    analyzed tree — fixture snippets and single-file plugins that never
    carry the builder are silent, only a tree that contains the builder
    without its probe is flagged.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.fedlint.core import (Finding, ProjectIndex, SourceFile,
                                         dotted_tail)

_PROBE = "check_flat_groups"
_GUARD = "sanitize"
_BUILDERS = {True: "make_async_tick", False: "make_federated_round"}


def _test_references_guard(test: ast.AST) -> bool:
    """True when the if-test mentions ``sanitize`` — as a bare name or a
    dotted tail (``self.sanitize`` / ``fed.sanitize``)."""
    for node in ast.walk(test):
        if isinstance(node, ast.Name) and node.id == _GUARD:
            return True
        if isinstance(node, ast.Attribute) and node.attr == _GUARD:
            return True
    return False


def _has_guarded_probe(scope: ast.AST) -> bool:
    """A ``check_flat_groups`` call anywhere under an ``if`` whose test
    references ``sanitize``, transitively nested inside ``scope`` (the
    real probes live in closures the builders return)."""
    for node in ast.walk(scope):
        if isinstance(node, ast.If) and _test_references_guard(node.test):
            for sub in node.body:
                for inner in ast.walk(sub):
                    if isinstance(inner, ast.Call) \
                            and dotted_tail(inner.func) == _PROBE:
                        return True
    return False


def _class_literals(sf: SourceFile) -> Dict[str, Dict[str, object]]:
    """Per-class map of class-level ``attr = <bool literal>`` values
    (ClassInfo stores attr NAMES only; this pass needs ``is_async``'s
    value)."""
    out: Dict[str, Dict[str, object]] = {}
    for node in ast.walk(sf.tree):
        if not isinstance(node, ast.ClassDef):
            continue
        vals: Dict[str, object] = {}
        for item in node.body:
            if isinstance(item, ast.Assign) \
                    and isinstance(item.value, ast.Constant):
                for t in item.targets:
                    if isinstance(t, ast.Name):
                        vals[t.id] = item.value.value
            elif isinstance(item, ast.AnnAssign) \
                    and isinstance(item.target, ast.Name) \
                    and isinstance(item.value, ast.Constant):
                vals[item.target.id] = item.value.value
        out[node.name] = vals
    return out


class _Facts:
    """One scan of the tree: builder defs + their probe status, every
    class's literal attrs, every class's guarded-probe status."""

    def __init__(self, index: ProjectIndex):
        self.builder_probed: Dict[str, bool] = {}
        self.literals: Dict[str, Dict[str, object]] = {}
        self.class_probed: Dict[str, bool] = {}
        for sf in index.files:
            self.literals.update(_class_literals(sf))
            for node in ast.walk(sf.tree):
                if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                        and node.name in _BUILDERS.values():
                    # last definition wins, consistent with the class map
                    self.builder_probed[node.name] = _has_guarded_probe(node)
                elif isinstance(node, ast.ClassDef):
                    self.class_probed[node.name] = _has_guarded_probe(node)
        self._index = index

    def resolve_literal(self, cls: str, attr: str,
                        _seen: Optional[Set[str]] = None) -> Tuple[bool,
                                                                   object]:
        """(found, value) for a class-level literal, walking bases through
        the project class map like ``class_declares``."""
        if _seen is None:
            _seen = set()
        if cls in _seen:
            return False, None
        _seen.add(cls)
        vals = self.literals.get(cls)
        if vals is not None and attr in vals:
            return True, vals[attr]
        info = self._index.classes.get(cls)
        if info is None:
            return False, None
        for b in info.bases:
            found, v = self.resolve_literal(b, attr, _seen)
            if found:
                return True, v
        return False, None

    def class_or_base_probed(self, cls: str,
                             _seen: Optional[Set[str]] = None) -> bool:
        if _seen is None:
            _seen = set()
        if cls in _seen:
            return False
        _seen.add(cls)
        if self.class_probed.get(cls):
            return True
        info = self._index.classes.get(cls)
        if info is None:
            return False
        return any(self.class_or_base_probed(b, _seen) for b in info.bases)


def check(index: ProjectIndex) -> List[Finding]:
    facts = _Facts(index)
    findings: List[Finding] = []
    for sf in index.files:
        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.ClassDef):
                continue
            if not any(dotted_tail(d.func if isinstance(d, ast.Call)
                                   else d) == "register_engine"
                       for d in node.decorator_list):
                continue
            found, is_async = facts.resolve_literal(node.name, "is_async")
            if not found or not isinstance(is_async, bool):
                continue               # FL301's problem, not ours
            builder = _BUILDERS[is_async]
            if builder not in facts.builder_probed:
                continue               # builder not in the analyzed tree
            if facts.builder_probed[builder]:
                continue
            if facts.class_or_base_probed(node.name):
                continue
            findings.append(Finding(
                sf.path, node.lineno, "FL501",
                f"engine {node.name!r} has no sanitize probe site: its "
                f"round builder {builder!r} (and the class itself) never "
                f"calls {_PROBE} under an 'if {_GUARD}:' guard, so "
                "--sanitize runs degrade to bare jax_debug_nans with no "
                "named flat-group diagnostics — restore the guarded "
                "probe in the builder (see repro.core.sanitize)"))
    return findings

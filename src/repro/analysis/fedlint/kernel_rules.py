"""Pass 2 — kernel contracts (FL201-FL204).

Every Pallas kernel in this repo ships as a triple: ``kernel.py`` (the
device code), ``ref.py`` (a pure-jnp oracle the tests and the debug
``use_ref`` path run), ``ops.py`` (the dispatch layer, often wrapping the
pair in a ``jax.custom_vjp``).  The contract a human reviewer checks by
hand — and forgets to — is mechanical:

  * **FL201** — every public ``*_pass`` / ``*_pass_bwd`` in
    ``kernels/<name>/kernel.py`` has the matching oracle in the sibling
    ``ref.py`` (``foo_pass`` -> ``foo_ref``, ``foo_pass_bwd`` ->
    ``foo_bwd_ref``).
  * **FL202** — kernel and oracle have the SAME signature: identical
    positional parameters, identical keyword-only parameters after
    dropping the kernel-side tuning knobs ``block_rows`` / ``interpret``
    (oracles have no tiling).  Signature drift means the ``use_ref`` arm
    silently computes something else.
  * **FL203** — every public ``*_pass`` is referenced in the sibling
    ``ops.py`` from inside a function whose enclosing scope takes a
    ``use_ref`` parameter — i.e. a real kernel/oracle dispatch site
    exists, not just an unconditional kernel call.
  * **FL204** — a ``@jax.custom_vjp`` function must pair with a
    ``f.defvjp(fwd, bwd)`` call (both arguments) in its defining scope;
    a missing defvjp surfaces only at trace time, deep inside a round.
"""
from __future__ import annotations

import ast
import os
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.fedlint.core import (Finding, ProjectIndex, SourceFile,
                                         dotted_tail)

_KERNEL_KNOBS = frozenset({"block_rows", "interpret"})


def _oracle_name(pass_name: str) -> str:
    if pass_name.endswith("_pass_bwd"):
        return pass_name[:-len("_pass_bwd")] + "_bwd_ref"
    assert pass_name.endswith("_pass"), pass_name
    return pass_name[:-len("_pass")] + "_ref"


def _public_passes(sf: SourceFile) -> List[ast.FunctionDef]:
    return [n for n in sf.tree.body
            if isinstance(n, ast.FunctionDef)
            and not n.name.startswith("_")
            and (n.name.endswith("_pass") or n.name.endswith("_pass_bwd"))]


def _top_level_funcs(sf: SourceFile) -> Dict[str, ast.FunctionDef]:
    return {n.name: n for n in sf.tree.body
            if isinstance(n, ast.FunctionDef)}


def _signature(fn: ast.FunctionDef, *, drop_knobs: bool
               ) -> Tuple[Tuple[str, ...], Tuple[str, ...]]:
    pos = tuple(a.arg for a in fn.args.posonlyargs + fn.args.args)
    kw = tuple(sorted(a.arg for a in fn.args.kwonlyargs
                      if not (drop_knobs and a.arg in _KERNEL_KNOBS)))
    return pos, kw


def _kernel_triples(index: ProjectIndex
                    ) -> List[Tuple[SourceFile, Optional[SourceFile],
                                    Optional[SourceFile]]]:
    by_dir: Dict[str, Dict[str, SourceFile]] = {}
    for sf in index.files:
        d, base = os.path.split(sf.path)
        if base in ("kernel.py", "ref.py", "ops.py") \
                and "/kernels/" in sf.posix + "/":
            by_dir.setdefault(d, {})[base] = sf
    return [(m["kernel.py"], m.get("ref.py"), m.get("ops.py"))
            for m in by_dir.values() if "kernel.py" in m]


def _use_ref_dispatch_names(ops: SourceFile) -> Set[str]:
    """Names referenced (as Name or Attribute tail) inside a function whose
    enclosing def chain includes a ``use_ref`` parameter."""
    names: Set[str] = set()

    def visit(node: ast.AST, in_dispatch: bool) -> None:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            args = node.args
            params = {a.arg for a in (args.posonlyargs + args.args
                                      + args.kwonlyargs)}
            in_dispatch = in_dispatch or "use_ref" in params
        if in_dispatch:
            if isinstance(node, ast.Name):
                names.add(node.id)
            elif isinstance(node, ast.Attribute):
                names.add(node.attr)
        for child in ast.iter_child_nodes(node):
            visit(child, in_dispatch)

    visit(ops.tree, False)
    return names


def _check_custom_vjp(sf: SourceFile, findings: List[Finding]) -> None:
    """FL204 within one file: pair every custom_vjp def with a 2-arg
    defvjp call in its defining scope (module body or the enclosing
    function's subtree)."""

    def scope_check(owner_body: List[ast.stmt], scope: ast.AST) -> None:
        decorated: List[ast.FunctionDef] = []
        for stmt in owner_body:
            if isinstance(stmt, ast.FunctionDef):
                for dec in stmt.decorator_list:
                    target = dec.func if isinstance(dec, ast.Call) else dec
                    if dotted_tail(target) == "custom_vjp":
                        decorated.append(stmt)
        if not decorated:
            return
        defvjp_ok: Set[str] = set()
        defvjp_partial: Dict[str, int] = {}
        for node in ast.walk(scope):
            if isinstance(node, ast.Call) \
                    and isinstance(node.func, ast.Attribute) \
                    and node.func.attr == "defvjp" \
                    and isinstance(node.func.value, ast.Name):
                if len(node.args) >= 2:
                    defvjp_ok.add(node.func.value.id)
                else:
                    defvjp_partial[node.func.value.id] = node.lineno
        for fn in decorated:
            if fn.name in defvjp_ok:
                continue
            if fn.name in defvjp_partial:
                findings.append(Finding(
                    sf.path, defvjp_partial[fn.name], "FL204",
                    f"{fn.name}.defvjp needs BOTH fwd and bwd rules"))
            else:
                findings.append(Finding(
                    sf.path, fn.lineno, "FL204",
                    f"custom_vjp function {fn.name!r} has no "
                    f"{fn.name}.defvjp(fwd, bwd) call in its defining "
                    "scope; differentiating it will fail at trace time"))

    scope_check(sf.tree.body, sf.tree)
    for node in ast.walk(sf.tree):
        if isinstance(node, ast.FunctionDef):
            scope_check(node.body, node)


def check(index: ProjectIndex) -> List[Finding]:
    findings: List[Finding] = []
    for kernel, ref, ops in _kernel_triples(index):
        passes = _public_passes(kernel)
        if not passes:
            continue
        ref_funcs = _top_level_funcs(ref) if ref else {}
        dispatch_names = _use_ref_dispatch_names(ops) if ops else set()
        for fn in passes:
            oracle = _oracle_name(fn.name)
            rfn = ref_funcs.get(oracle)
            if rfn is None:
                where = ref.path if ref else os.path.join(
                    os.path.dirname(kernel.path), "ref.py")
                findings.append(Finding(
                    kernel.path, fn.lineno, "FL201",
                    f"kernel pass {fn.name!r} has no oracle {oracle!r} in "
                    f"{where}; every *_pass needs a same-signature pure-"
                    "jnp reference"))
            else:
                kpos, kkw = _signature(fn, drop_knobs=True)
                rpos, rkw = _signature(rfn, drop_knobs=False)
                if (kpos, kkw) != (rpos, rkw):
                    findings.append(Finding(
                        kernel.path, fn.lineno, "FL202",
                        f"signature drift between {fn.name} and {oracle}: "
                        f"kernel ({', '.join(kpos)} * {', '.join(kkw)}) vs "
                        f"oracle ({', '.join(rpos)} * {', '.join(rkw)}) "
                        "(positional must match exactly; kw-only compared "
                        "after dropping block_rows/interpret)"))
            if fn.name not in dispatch_names:
                where = ops.path if ops else os.path.join(
                    os.path.dirname(kernel.path), "ops.py")
                findings.append(Finding(
                    kernel.path, fn.lineno, "FL203",
                    f"kernel pass {fn.name!r} has no use_ref dispatch site "
                    f"in {where}: it must be called from a function whose "
                    "scope takes use_ref, so tests can swap in the oracle"))
    for sf in index.files:
        _check_custom_vjp(sf, findings)
    return findings

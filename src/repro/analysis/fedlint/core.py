"""fedlint driver: file collection, project index, suppression handling.

Pure stdlib (``ast`` + ``re``) by design — the analyzer must run in CI and
pre-commit hooks without importing jax or the package under analysis, so it
parses source text only and never executes repo code.

The passes (``rng_rules`` / ``kernel_rules`` / ``registry_rules`` /
``jit_rules``) each expose ``check(index) -> list[Finding]``.  Cross-file
facts they need — the rng tag registry, FedConfig's field names, the global
class map for capability inheritance — are resolved once here in
:class:`ProjectIndex`.

Suppressions: a finding on line L is dropped when line L (or the line a
multi-line statement starts on) carries ``# fedlint: disable=FLNNN`` (a
comma list of codes, or ``all``).
"""
from __future__ import annotations

import ast
import dataclasses
import os
import re
from typing import Dict, List, Optional, Sequence, Set, Tuple

__all__ = ["Finding", "SourceFile", "ClassInfo", "ProjectIndex",
           "run_fedlint", "format_findings"]

_SUPPRESS_RE = re.compile(
    r"#\s*fedlint:\s*disable=([A-Za-z0-9_,\s]+)")


@dataclasses.dataclass(frozen=True)
class Finding:
    path: str                  # display path (as given on the CLI)
    line: int                  # 1-indexed
    code: str                  # "FLNNN"
    message: str

    def format(self) -> str:
        return f"{self.path}:{self.line}: {self.code} {self.message}"


@dataclasses.dataclass
class SourceFile:
    path: str                          # display path
    tree: ast.Module
    lines: List[str]                   # raw source lines
    suppressions: Dict[int, Set[str]]  # line -> codes disabled there

    @property
    def posix(self) -> str:
        return self.path.replace(os.sep, "/")

    def suppressed(self, line: int, code: str) -> bool:
        codes = self.suppressions.get(line, ())
        return code in codes or "all" in codes


@dataclasses.dataclass
class ClassInfo:
    name: str
    bases: Tuple[str, ...]             # base-class *names* (dotted tail)
    attrs: Set[str]                    # class-level assignments + defs
    file: "SourceFile" = None
    line: int = 0


def dotted_tail(node: ast.AST) -> Optional[str]:
    """Terminal identifier of a Name / dotted Attribute (``jax.random.
    fold_in`` -> ``fold_in``); None for anything else."""
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return None


def dotted_root(node: ast.AST) -> Optional[str]:
    """Leftmost identifier of a dotted chain (``np.random.default_rng`` ->
    ``np``)."""
    while isinstance(node, ast.Attribute):
        node = node.value
    return node.id if isinstance(node, ast.Name) else None


def _parse_suppressions(lines: Sequence[str]) -> Dict[int, Set[str]]:
    out: Dict[int, Set[str]] = {}
    for i, text in enumerate(lines, start=1):
        m = _SUPPRESS_RE.search(text)
        if m:
            out[i] = {c.strip() for c in m.group(1).split(",") if c.strip()}
    return out


def _collect_py_files(paths: Sequence[str]) -> List[str]:
    files: List[str] = []
    for p in paths:
        if os.path.isdir(p):
            for root, _dirs, names in os.walk(p):
                for n in sorted(names):
                    if n.endswith(".py"):
                        files.append(os.path.join(root, n))
        elif p.endswith(".py"):
            files.append(p)
    return files


class ProjectIndex:
    """Parsed project + the cross-file facts the passes share."""

    def __init__(self, files: List[SourceFile]):
        self.files = files
        self.classes: Dict[str, ClassInfo] = {}
        self.fedconfig_fields: Set[str] = set()
        self.rng_tags: Dict[str, Tuple[int, SourceFile, int]] = {}
        self.rngtags_file: Optional[SourceFile] = None
        for sf in files:
            self._index_file(sf)

    # -- construction -------------------------------------------------------
    def _index_file(self, sf: SourceFile) -> None:
        is_rngtags = sf.posix.endswith("core/rngtags.py")
        if is_rngtags:
            self.rngtags_file = sf
        for node in ast.walk(sf.tree):
            if isinstance(node, ast.ClassDef):
                attrs: Set[str] = set()
                for item in node.body:
                    if isinstance(item, ast.Assign):
                        attrs.update(t.id for t in item.targets
                                     if isinstance(t, ast.Name))
                    elif isinstance(item, ast.AnnAssign) and isinstance(
                            item.target, ast.Name):
                        attrs.add(item.target.id)
                    elif isinstance(item, (ast.FunctionDef,
                                           ast.AsyncFunctionDef)):
                        attrs.add(item.name)
                bases = tuple(b for b in (dotted_tail(x) for x in node.bases)
                              if b)
                # last definition wins; names are unique in this repo
                self.classes[node.name] = ClassInfo(
                    name=node.name, bases=bases, attrs=attrs, file=sf,
                    line=node.lineno)
                if node.name == "FedConfig":
                    self.fedconfig_fields = {
                        item.target.id for item in node.body
                        if isinstance(item, ast.AnnAssign)
                        and isinstance(item.target, ast.Name)}
        if is_rngtags:
            for node in sf.tree.body:
                if (isinstance(node, ast.Assign)
                        and len(node.targets) == 1
                        and isinstance(node.targets[0], ast.Name)
                        and isinstance(node.value, ast.Constant)
                        and isinstance(node.value.value, int)):
                    self.rng_tags[node.targets[0].id] = (
                        node.value.value, sf, node.lineno)

    # -- queries ------------------------------------------------------------
    def class_declares(self, cls: str, attr: str,
                       _seen: Optional[Set[str]] = None) -> bool:
        """True if ``cls`` (or any base reachable through the project-wide
        class map) assigns ``attr`` at class level.  Unknown bases (e.g.
        stdlib/jax classes) contribute nothing."""
        if _seen is None:
            _seen = set()
        if cls in _seen:
            return False
        _seen.add(cls)
        info = self.classes.get(cls)
        if info is None:
            return False
        if attr in info.attrs:
            return True
        return any(self.class_declares(b, attr, _seen) for b in info.bases)


def load_project(paths: Sequence[str]) -> Tuple[ProjectIndex, List[Finding]]:
    """Parse every .py under ``paths``.  Unparseable files become FL001
    findings rather than a crash (the analyzer must always report)."""
    files: List[SourceFile] = []
    errors: List[Finding] = []
    for fpath in _collect_py_files(paths):
        try:
            with open(fpath, "r", encoding="utf-8") as fh:
                src = fh.read()
            tree = ast.parse(src, filename=fpath)
        except (SyntaxError, UnicodeDecodeError, OSError) as e:
            line = getattr(e, "lineno", 1) or 1
            errors.append(Finding(fpath, line, "FL001",
                                  f"cannot analyze file: {e}"))
            continue
        lines = src.splitlines()
        files.append(SourceFile(path=fpath, tree=tree, lines=lines,
                                suppressions=_parse_suppressions(lines)))
    return ProjectIndex(files), errors


def run_fedlint(paths: Sequence[str]) -> List[Finding]:
    """All five passes over ``paths``; returns suppression-filtered
    findings sorted by (path, line, code)."""
    # local imports keep core.py import-cycle-free for the pass modules
    from repro.analysis.fedlint import (jit_rules, kernel_rules,
                                        registry_rules, rng_rules,
                                        sanitize_rules)
    index, findings = load_project(paths)
    for mod in (rng_rules, kernel_rules, registry_rules, jit_rules,
                sanitize_rules):
        findings.extend(mod.check(index))
    by_path = {sf.path: sf for sf in index.files}
    kept = [f for f in findings
            if f.path not in by_path
            or not by_path[f.path].suppressed(f.line, f.code)]
    return sorted(kept, key=lambda f: (f.path, f.line, f.code))


def format_findings(findings: Sequence[Finding]) -> str:
    return "\n".join(f.format() for f in findings)

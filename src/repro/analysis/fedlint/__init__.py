"""fedlint — the repo-specific static analyzer.

Five passes over the source tree (pure stdlib ``ast``, no jax import, no
code execution):

  ======  ==================================================================
  FL001   file cannot be parsed
  FL101   inline constant rng tag (belongs in repro.core.rngtags)
  FL102   two constant rng tags share a value (stream collision)
  FL103   rng key consumed twice without re-derivation
  FL201   kernel ``*_pass`` without a matching ``ref.py`` oracle
  FL202   kernel/oracle signature drift
  FL203   kernel pass without a ``use_ref`` dispatch site in ``ops.py``
  FL204   ``custom_vjp`` without a paired ``defvjp(fwd, bwd)``
  FL301   registered class missing capability declarations /
          ``register_algorithm`` without ``pseudo_gradient=``
  FL302   ValueError guidance naming a nonexistent config field
  FL401   host sync (``.item()`` / ``float()`` on tracer) in a traced body
  FL402   host numpy call in a traced body
  FL403   wall-clock read in a traced body
  FL501   registered engine whose round builder lost its sanitize-guarded
          ``check_flat_groups`` probe site
  ======  ==================================================================

CLI::

    python -m repro.analysis.fedlint src/            # exit 1 on findings

Per-line suppression::

    key = jax.random.fold_in(k, 7)   # fedlint: disable=FL101

API: :func:`run_fedlint` returns the findings programmatically.
"""
from repro.analysis.fedlint.core import (Finding, format_findings,
                                         run_fedlint)

__all__ = ["Finding", "run_fedlint", "format_findings"]

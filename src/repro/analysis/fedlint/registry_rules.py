"""Pass 3 — registry capability surfaces (FL301-FL302).

The round builder composes plugins by interrogating DECLARED capabilities
(``exe.produces & eng.accepts``, ``"lossy" in eng.codec_capabilities``,
``getattr(eng, "is_async", False)``...).  A registered class that forgot a
declaration doesn't fail loudly — ``getattr`` defaults paper over it and
the plugin silently loses a feature.  Likewise the config-guard
ValueErrors: a message telling the user to set a field that doesn't exist
on FedConfig points at nothing.

  * **FL301** — every ``@register_executor`` class must declare (possibly
    via bases, resolved across the whole analyzed tree) ``produces``,
    ``supports_reweight`` and ``codec_capabilities``; every
    ``@register_engine`` class: ``accepts``, ``preferred``,
    ``meta_capabilities``, ``codec_capabilities`` and ``is_async``; every
    ``@register_codec`` class: ``lossy``.  Every ``register_algorithm``
    call site must pass ``pseudo_gradient=`` explicitly (the server-lr
    semantics hinge on it).
  * **FL302** — ``raise ValueError(...)`` message text that names a config
    field with ``some_field=...`` must name a REAL field: a FedConfig
    field, a parameter of the enclosing function(s), or an attribute of
    the enclosing class.  Catches guard messages left stale by config
    renames.
"""
from __future__ import annotations

import ast
import re
from typing import List, Set

from repro.analysis.fedlint.core import (Finding, ProjectIndex, SourceFile,
                                         dotted_tail)

_REQUIRED_ATTRS = {
    "register_executor": ("produces", "supports_reweight",
                          "codec_capabilities"),
    "register_engine": ("accepts", "preferred", "meta_capabilities",
                        "codec_capabilities", "is_async"),
    "register_codec": ("lossy",),
}

# underscore-containing identifier immediately followed by '=' (not '==')
_FIELD_TOKEN = re.compile(r"\b([a-z][a-z0-9]*(?:_[a-z0-9]+)+)=(?!=)")


def _check_registered_classes(index: ProjectIndex, sf: SourceFile,
                              findings: List[Finding]) -> None:
    for node in ast.walk(sf.tree):
        if not isinstance(node, ast.ClassDef):
            continue
        for dec in node.decorator_list:
            target = dec.func if isinstance(dec, ast.Call) else dec
            reg = dotted_tail(target)
            required = _REQUIRED_ATTRS.get(reg or "")
            if not required:
                continue
            missing = [a for a in required
                       if not index.class_declares(node.name, a)]
            if missing:
                findings.append(Finding(
                    sf.path, node.lineno, "FL301",
                    f"{reg} class {node.name!r} does not declare its full "
                    f"capability surface: missing {', '.join(missing)} "
                    "(declare on the class or inherit from a base that "
                    "does — getattr defaults silently disable features)"))


def _check_algorithm_calls(sf: SourceFile,
                           findings: List[Finding]) -> None:
    for node in ast.walk(sf.tree):
        if isinstance(node, ast.Call) \
                and dotted_tail(node.func) == "register_algorithm" \
                and node.args:                     # skip the def itself
            kwargs = {kw.arg for kw in node.keywords}
            if "pseudo_gradient" not in kwargs:
                findings.append(Finding(
                    sf.path, node.lineno, "FL301",
                    "register_algorithm call without an explicit "
                    "pseudo_gradient= declaration; resolve_server_lr's "
                    "lr=1.0 forcing hinges on it — declare it even when "
                    "the default would do"))


def _literal_text(call: ast.Call) -> str:
    """Concatenated literal fragments of the exception message (Constant
    strings + the Constant parts of f-strings); formatted values are
    replaced by a space so tokens never merge across them."""
    parts: List[str] = []
    for arg in call.args:
        for node in ast.walk(arg):
            if isinstance(node, ast.Constant) and isinstance(node.value,
                                                            str):
                parts.append(node.value)
            elif isinstance(node, ast.FormattedValue):
                parts.append(" ")
    return " ".join(parts)


def _enclosing_valid_names(stack: List[ast.AST],
                           index: ProjectIndex) -> Set[str]:
    valid: Set[str] = set(index.fedconfig_fields)
    for node in stack:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            a = node.args
            valid.update(p.arg for p in a.posonlyargs + a.args + a.kwonlyargs)
            if a.vararg:
                valid.add(a.vararg.arg)
            if a.kwarg:
                valid.add(a.kwarg.arg)
        elif isinstance(node, ast.ClassDef):
            info = index.classes.get(node.name)
            if info is not None:
                valid.update(info.attrs)
    return valid


def _check_value_errors(index: ProjectIndex, sf: SourceFile,
                        findings: List[Finding]) -> None:
    def visit(node: ast.AST, stack: List[ast.AST]) -> None:
        if isinstance(node, ast.Raise) and isinstance(node.exc, ast.Call) \
                and dotted_tail(node.exc.func) == "ValueError":
            text = _literal_text(node.exc)
            tokens = set(_FIELD_TOKEN.findall(text))
            valid = _enclosing_valid_names(stack, index) if tokens else set()
            for tok in sorted(tokens):
                if tok not in valid:
                    findings.append(Finding(
                        sf.path, node.lineno, "FL302",
                        f"ValueError message names {tok!r} as a settable "
                        "field, but it is not a FedConfig field, a "
                        "parameter of the enclosing function, or an "
                        "attribute of the enclosing class — the guidance "
                        "points at nothing the user can set"))
        is_scope = isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                     ast.ClassDef))
        if is_scope:
            stack = stack + [node]
        for child in ast.iter_child_nodes(node):
            visit(child, stack)

    visit(sf.tree, [])


def check(index: ProjectIndex) -> List[Finding]:
    findings: List[Finding] = []
    for sf in index.files:
        _check_registered_classes(index, sf, findings)
        _check_algorithm_calls(sf, findings)
        _check_value_errors(index, sf, findings)
    return findings

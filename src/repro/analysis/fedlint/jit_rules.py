"""Pass 4 — jit hygiene (FL401-FL403).

Host-side operations inside a traced body either fail at trace time deep
in a round program, silently force a device sync (``.item()``, ``float()``
on a tracer), or bake a trace-time constant where a per-call value was
intended (``time.time()``, ``np.random``).  The pass flags, inside any
function it can prove is traced:

  * **FL401** — ``.item()`` calls, and ``float()`` / ``int()`` / ``bool()``
    on a non-literal argument (tracer -> concretization error or sync);
  * **FL402** — ``np.*`` / ``numpy.*`` calls (host numpy does not trace;
    results freeze into the compiled program);
  * **FL403** — ``time.time()`` / ``time.perf_counter()`` /
    ``time.monotonic()`` (frozen at trace time — measures compilation, not
    execution).

"Traced" = decorated with ``jit`` / ``pjit`` / ``shard_map`` (directly or
via ``functools.partial``), passed by name or lambda to ``jax.jit`` /
``shard_map`` / ``lax.scan`` / ``lax.fori_loop`` / ``lax.while_loop`` /
``lax.cond`` (optionally wrapped in ``jax.checkpoint`` / ``remat``), or
nested inside such a function.  Anything the analysis cannot resolve
(functions returned from builders and jitted elsewhere) is out of scope —
the pass under-approximates rather than false-positives.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set

from repro.analysis.fedlint.core import (Finding, ProjectIndex, SourceFile,
                                         dotted_root, dotted_tail)

_JIT_DECOS = frozenset({"jit", "pjit", "shard_map"})
_TIME_FUNCS = frozenset({"time", "perf_counter", "monotonic",
                         "process_time"})
_WRAPPERS = frozenset({"checkpoint", "remat"})

FuncNode = ast.AST     # FunctionDef | AsyncFunctionDef | Lambda


def _is_jit_decorator(dec: ast.AST) -> bool:
    target = dec
    if isinstance(dec, ast.Call):
        target = dec.func
        # functools.partial(jax.jit, ...) used as a decorator factory
        if dotted_tail(target) == "partial" and dec.args \
                and dotted_tail(dec.args[0]) in _JIT_DECOS:
            return True
    return dotted_tail(target) in _JIT_DECOS


def _resolve_func_ref(node: ast.AST, defs: Dict[str, FuncNode]
                      ) -> Optional[FuncNode]:
    """A Name bound to a local def, a Lambda, or either wrapped in
    jax.checkpoint/remat."""
    if isinstance(node, ast.Lambda):
        return node
    if isinstance(node, ast.Name):
        return defs.get(node.id)
    if isinstance(node, ast.Call) and dotted_tail(node.func) in _WRAPPERS \
            and node.args:
        return _resolve_func_ref(node.args[0], defs)
    return None


def _collect_defs(tree: ast.AST) -> Dict[str, FuncNode]:
    """name -> def node, flat over the whole file (names are unique enough
    in practice; a collision only risks a false negative)."""
    defs: Dict[str, FuncNode] = {}
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            defs[node.name] = node
        elif isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name) \
                and isinstance(node.value, ast.Lambda):
            defs[node.targets[0].id] = node.value
    return defs


def _traced_roots(sf: SourceFile) -> Set[FuncNode]:
    defs = _collect_defs(sf.tree)
    roots: Set[FuncNode] = set()
    for node in ast.walk(sf.tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if any(_is_jit_decorator(d) for d in node.decorator_list):
                roots.add(node)
        elif isinstance(node, ast.Call):
            tail = dotted_tail(node.func)
            cands: List[ast.AST] = []
            if tail in ("jit", "pjit", "shard_map") and node.args:
                cands = [node.args[0]]
            elif tail == "scan" and node.args:
                cands = [node.args[0]]
            elif tail == "fori_loop" and len(node.args) >= 3:
                cands = [node.args[2]]
            elif tail == "while_loop" and len(node.args) >= 2:
                cands = node.args[:2]
            elif tail == "cond" and len(node.args) >= 3:
                cands = node.args[1:3]
            for c in cands:
                fn = _resolve_func_ref(c, defs)
                if fn is not None:
                    roots.add(fn)
    return roots


def _flag_in_body(sf: SourceFile, fn: FuncNode,
                  findings: List[Finding], seen: Set[int]) -> None:
    for node in ast.walk(fn):
        if id(node) in seen or not isinstance(node, ast.Call):
            continue
        seen.add(id(node))
        tail = dotted_tail(node.func)
        root = dotted_root(node.func) if isinstance(node.func,
                                                    ast.Attribute) else None
        if tail == "item" and isinstance(node.func, ast.Attribute):
            findings.append(Finding(
                sf.path, node.lineno, "FL401",
                ".item() inside a traced body forces a device sync (or a "
                "tracer concretization error); keep values on device or "
                "move the read outside jit"))
        elif isinstance(node.func, ast.Name) \
                and node.func.id in ("float", "int", "bool") \
                and node.args and not isinstance(node.args[0], ast.Constant):
            findings.append(Finding(
                sf.path, node.lineno, "FL401",
                f"{node.func.id}() on a non-literal inside a traced body "
                "concretizes a tracer; use jnp casts "
                "(x.astype/jnp.float32) instead"))
        elif root in ("np", "numpy"):
            findings.append(Finding(
                sf.path, node.lineno, "FL402",
                f"host numpy call {ast.unparse(node.func)}() inside a "
                "traced body freezes its result at trace time; use jnp"))
        elif root == "time" and tail in _TIME_FUNCS:
            findings.append(Finding(
                sf.path, node.lineno, "FL403",
                f"time.{tail}() inside a traced body is evaluated ONCE at "
                "trace time — it measures compilation, not execution; "
                "time on the host around the jitted call"))


def check(index: ProjectIndex) -> List[Finding]:
    findings: List[Finding] = []
    for sf in index.files:
        seen: Set[int] = set()
        for root_fn in _traced_roots(sf):
            _flag_in_body(sf, root_fn, findings, seen)
    return findings

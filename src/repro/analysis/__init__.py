"""Static-analysis tooling for the repo's own invariants.

General-purpose linters cannot see this codebase's contracts — rng fold
tags drawn from one registry, kernel/ref/ops triples with matching
signatures, registry classes declaring their full capability surface, jit
bodies free of host synchronization.  :mod:`repro.analysis.fedlint` checks
exactly those, from the CLI (``python -m repro.analysis.fedlint src/``)
and in CI.
"""

from repro.sharding.specs import (batch_axes, cache_shardings,
                                  cohort_batch_shardings, fsdp_axes,
                                  param_shardings, param_spec, replicated,
                                  simple_batch_shardings, state_shardings)

__all__ = ["param_spec", "param_shardings", "state_shardings",
           "cohort_batch_shardings", "simple_batch_shardings",
           "cache_shardings", "replicated", "fsdp_axes", "batch_axes"]

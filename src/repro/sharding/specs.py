"""PartitionSpec rules for parameters, server state, batches and caches.

Sharding strategy (DESIGN.md §7):
  * params: 2D "FSDP x TP" — the input/embedding dim shards over the FSDP
    axes (``data``, plus ``pod`` for the client-sequential strategy in the
    multi-pod mesh), the output/head/expert dim over ``model`` (TP);
  * cohort/batch axes shard over (``pod``, ``data``);
  * decode KV caches shard batch over ``data`` and the cache sequence over
    ``model`` (GSPMD turns softmax over the sharded axis into a collective
    — flash-decode-by-compiler); the 500k B=1 cache shards sequence over
    ``data`` as well.

Every rule degrades to replication when a dim is not divisible by the axis
size (e.g. whisper's 51866 vocab) — recorded by ``explain()`` for the
roofline notes.
"""
from __future__ import annotations

import re
from typing import Any, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any


def axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


def _maybe(mesh: Mesh, axes, dim: int):
    """Use `axes` for a dim only if it divides evenly, else replicate.
    Singleton tuples collapse to the bare name — older jax PartitionSpecs
    do not normalize ('data',) == 'data'."""
    if not (axes and dim % axis_size(mesh, axes) == 0):
        return None
    if isinstance(axes, tuple) and len(axes) == 1:
        return axes[0]
    return axes


def fsdp_axes(mesh: Mesh, strategy: str):
    """FSDP axes for the parameter input-dim: the pod axis joins FSDP under
    the client-sequential (scan) strategy; under client-parallel (vmap) the
    pods are pure data-parallel replicas."""
    if "pod" in mesh.axis_names and strategy == "scan":
        return ("pod", "data")
    return ("data",)


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------
_IN_OUT = {"wq", "wk", "wv", "w_gate", "w_up", "in_proj", "w_dkv", "w_kr",
           "router", "proj", "w_in", "wx", "wh", "out_w"}       # (d_in, d_out)
_OUT_IN = {"wo", "w_down", "out_proj", "w_out"}                 # (d_out, d_in)
_REPL = {"dt_bias", "A_log", "D", "b", "b_in", "b_out", "out_b",
         "ln1_s", "ln1_b", "ln2_s", "ln2_b", "ln_f_s", "ln_f_b"}


def param_spec(path: str, shape: Tuple[int, ...], mesh: Mesh,
               strategy: str = "vmap") -> P:
    """Spec for one parameter leaf.  ``path`` is '/'-joined key names."""
    fs = fsdp_axes(mesh, strategy)
    parts = path.split("/")
    name = parts[-1]
    # stacked leading axes: blocks/<i>/... (n_periods) and encoder/layers/...
    n_stack = 0
    if "blocks" in parts or ("layers" in parts and "encoder" in parts):
        n_stack = 1
    core = shape[n_stack:]
    lead = (None,) * n_stack

    def spec(*axes):
        return P(*(lead + axes))

    if name in _REPL or len(core) <= 1:
        if name == "embed" and len(core) == 2:
            pass  # fall through
        else:
            return P(*((None,) * len(shape)))
    if name == "embed":
        return spec(_maybe(mesh, "model", core[0]), _maybe(mesh, fs, core[1]))
    if name == "head":
        return spec(_maybe(mesh, fs, core[0]), _maybe(mesh, "model", core[1]))
    if name == "conv_w":
        return spec(None, _maybe(mesh, "model", core[1]))
    if name in ("w_uk", "w_uv"):  # (r, H, hd)
        return spec(_maybe(mesh, fs, core[0]),
                    _maybe(mesh, "model", core[1]), None)
    if len(core) == 3:            # MoE experts (E, a, b)
        e = _maybe(mesh, "model", core[0])
        if name in _OUT_IN:       # (E, de, d)
            return spec(e, None, _maybe(mesh, fs, core[2]))
        return spec(e, _maybe(mesh, fs, core[1]), None)
    if name in _OUT_IN:
        return spec(_maybe(mesh, "model", core[0]), _maybe(mesh, fs, core[1]))
    if name in _IN_OUT:
        return spec(_maybe(mesh, fs, core[0]), _maybe(mesh, "model", core[1]))
    # fallback: shard the largest divisible dim over model, next over fsdp
    axes: list = [None] * len(core)
    order = sorted(range(len(core)), key=lambda i: -core[i])
    if order and _maybe(mesh, "model", core[order[0]]):
        axes[order[0]] = "model"
    if len(order) > 1 and _maybe(mesh, fs, core[order[1]]):
        axes[order[1]] = fs
    return spec(*axes)


def tree_paths(tree: PyTree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                      for p in path) for path, _ in flat]
    return flat, treedef, paths


def param_shardings(params_shape: PyTree, mesh: Mesh,
                    strategy: str = "vmap") -> PyTree:
    """NamedShardings for a params(-like) pytree of ShapeDtypeStructs.
    Also used for optimizer state (leaf paths mirror param paths)."""
    flat, treedef, paths = tree_paths(params_shape)
    out = []
    for (path, leaf), pstr in zip(flat, paths):
        spec = param_spec(pstr, tuple(leaf.shape), mesh, strategy)
        out.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, out)


def cohort_grad_shardings(params_shape: PyTree, mesh: Mesh,
                          strategy: str = "vmap") -> PyTree:
    """Specs for the stacked per-client gradients (cohort, *param_dims):
    cohort over (pod, data), remaining dims per ``param_spec``."""
    ba = batch_axes(mesh)
    flat, treedef, paths = tree_paths(params_shape)
    out = []
    for (path, leaf), pstr in zip(flat, paths):
        spec = param_spec(pstr, tuple(leaf.shape), mesh, strategy)
        # drop any use of the batch axes inside the param spec (the cohort
        # axis owns them), then prepend the cohort axis
        def strip(e):
            if e is None:
                return None
            es = (e,) if isinstance(e, str) else tuple(e)
            es = tuple(a for a in es if a not in ba)
            return es if es else None
        inner = tuple(strip(e) for e in spec)
        out.append(NamedSharding(mesh, P(ba, *inner)))
    return jax.tree_util.tree_unflatten(treedef, out)


def flat_group_pspecs(spec, mesh: Mesh) -> Tuple[P, ...]:
    """One PartitionSpec per flat dtype-group buffer (``(rows, LANES)``
    fp32, see :mod:`repro.core.flat`): rows shard over the model axis when
    divisible, lanes stay whole (LANES=128 is the hardware lane tile).
    The batch axes are deliberately NOT used — the cohort dimension was
    already reduced away by the two-tier psum, and the row dimension is
    the only thing left worth splitting."""
    ax = "model" if "model" in mesh.axis_names else None
    return tuple(P(_maybe(mesh, ax, g.rows), None) for g in spec.groups)


def flat_group_shardings(spec, mesh: Mesh) -> Tuple[NamedSharding, ...]:
    """:func:`flat_group_pspecs` as NamedShardings (jit in/out placement
    for the aggregate buffers and optimizer-state slots)."""
    return tuple(NamedSharding(mesh, p)
                 for p in flat_group_pspecs(spec, mesh))


def state_shardings(state_shape: PyTree, mesh: Mesh,
                    strategy: str = "vmap") -> PyTree:
    """Server state {params, opt, round}: opt moments mirror param specs."""
    flat, treedef, paths = tree_paths(state_shape)
    out = []
    for (path, leaf), pstr in zip(flat, paths):
        if pstr == "round" or pstr.endswith("/t") or leaf.ndim == 0:
            out.append(NamedSharding(mesh, P()))
            continue
        core = re.sub(r"^(params|opt/m|opt/v)/", "", pstr)
        spec = param_spec(core, tuple(leaf.shape), mesh, strategy)
        out.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, out)


# ---------------------------------------------------------------------------
# Batch / cache specs
# ---------------------------------------------------------------------------
def batch_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def cohort_batch_shardings(batch_shape: PyTree, mesh: Mesh,
                           strategy: str = "vmap") -> PyTree:
    """cohort_batch leaves (cohort, b, ...).

    vmap: cohort shards over (pod, data) and the per-client example axis b
    over model — every chip holds a (1-client, b/16-example) activation
    slice, so per-period activation residuals shard 256-way; scan: cohort is
    the sequential axis — b shards over (data, model)."""
    ba = batch_axes(mesh)

    def one(leaf):
        if strategy == "vmap":
            spec = (_maybe(mesh, ba, leaf.shape[0]),
                    _maybe(mesh, "model", leaf.shape[1])) + \
                   (None,) * (leaf.ndim - 2)
        else:
            b_ax = _maybe(mesh, ("data", "model"), leaf.shape[1]) or \
                _maybe(mesh, "data", leaf.shape[1])
            spec = (None, b_ax) + (None,) * (leaf.ndim - 2)
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(one, batch_shape)


def simple_batch_shardings(batch_shape: PyTree, mesh: Mesh) -> PyTree:
    """Batches with a leading example axis (meta batch, prefill batch)."""
    ba = batch_axes(mesh)

    def one(leaf):
        spec = (_maybe(mesh, ba, leaf.shape[0]),) + (None,) * (leaf.ndim - 1)
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(one, batch_shape)


def cache_shardings(cache_shape: PyTree, mesh: Mesh, *,
                    seq_axes_for_b1=("data",)) -> PyTree:
    """Decode cache: leaves are either
      (n_periods, B, S, ...)   KV-like   -> B over (pod,data), S over model
      (n_periods, B, H, N, P)  SSM state -> B over (pod,data), H over model
      (n_periods, B, k, C)     conv      -> B over (pod,data), C over model
    When B == 1 (long_500k) the batch axis cannot shard: the KV sequence
    axis takes the FSDP axes instead."""
    ba = batch_axes(mesh)

    def one(path_str, leaf):
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        shape = leaf.shape
        B = shape[1]
        b_ax = _maybe(mesh, ba, B)
        if "ssm" in path_str:                       # (np, B, H, N, P)
            return NamedSharding(mesh, P(
                None, b_ax, _maybe(mesh, "model", shape[2]), None, None))
        if "conv" in path_str:                      # (np, B, k, C)
            return NamedSharding(mesh, P(
                None, b_ax, None, _maybe(mesh, "model", shape[3])))
        # KV-like: (np, B, S, ...) — ckv/krope are (np, B, S, r)
        if B == 1:
            s_ax = _maybe(mesh, seq_axes_for_b1, shape[2])
            rest = [None] * (leaf.ndim - 3)
            return NamedSharding(mesh, P(None, None, s_ax, *rest))
        s_ax = _maybe(mesh, "model", shape[2])
        rest = [None] * (leaf.ndim - 3)
        return NamedSharding(mesh, P(None, b_ax, s_ax, *rest))

    flat, treedef, paths = tree_paths(cache_shape)
    out = [one(p, leaf) for (path, leaf), p in zip(flat, paths)]
    return jax.tree_util.tree_unflatten(treedef, out)


def replicated(tree: PyTree, mesh: Mesh) -> PyTree:
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)

"""Sequence-parallel flash decode (the long_500k B=1 path, optimized form).

The baseline decode path lets GSPMD handle a sequence-sharded KV cache
(softmax over the sharded axis becomes compiler-chosen collectives).  This
module is the explicit shard_map version: every device computes the
online-softmax partials (m, l, o) over its local cache shard and the
partials are combined with pmax/psum — one small collective per layer
instead of whatever GSPMD infers.

Used by the perf experiments; exact vs ``decode_attention`` (tested).
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh, PartitionSpec as P

from repro.models.attention import combine_partials, flash_decode_partial


def sharded_flash_decode(q, k_cache, v_cache, index, *, mesh: Mesh,
                         axis: str = "data"):
    """q: (B, H, Dk); caches: (B, S, Hkv, D*) with S sharded over ``axis``;
    index: scalar int32 (global).  Returns (B, H, Dv)."""
    n = mesh.shape[axis]
    S = k_cache.shape[1]
    assert S % n == 0, (S, n)
    loc = S // n

    def local(q, k, v, index):
        shard = jax.lax.axis_index(axis)
        m, l, o = flash_decode_partial(q, k, v, index, shard * loc)
        return combine_partials(m, l, o, axis)

    if hasattr(jax, "shard_map"):           # jax >= 0.6
        smap, check_kw = jax.shard_map, "check_vma"
    else:                                   # jax 0.4.x
        from jax.experimental.shard_map import shard_map as smap
        check_kw = "check_rep"
    fn = smap(
        local, mesh=mesh,
        in_specs=(P(), P(None, axis, None, None), P(None, axis, None, None),
                  P()),
        out_specs=P(),
        **{check_kw: False},
    )
    return fn(q, k_cache, v_cache, index).astype(v_cache.dtype)

"""Synthetic datasets (offline container — no downloads).

Stand-ins preserve the *cardinality and statistical structure* of the
paper's datasets so that the paper's relative claims (method ordering,
convergence-speed ratios) are testable:

  * ``synthetic_images``  — gaussian class-prototype images with per-writer
    style shifts (split CIFAR-10 / FEMNIST stand-in).  Writer style = a
    fixed affine distortion of the prototypes, so partition-by-writer yields
    genuinely non-IID clients (like FEMNIST's handwriting).
  * ``synthetic_chars``   — per-role Markov chains over a 90-char alphabet
    (Shakespeare stand-in): each "speaking role" has its own transition
    matrix mixture weight -> extreme non-IID, as in LEAF.
  * ``synthetic_tokens``  — integer LM streams for the transformer archs.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class ImageDataset:
    x: np.ndarray          # (N, H, W, C) float32
    y: np.ndarray          # (N,) int32
    writer: np.ndarray     # (N,) int32 — style/writer id


def synthetic_images(rng: np.random.Generator, *, n: int, image_size: int,
                     channels: int, num_classes: int, num_writers: int,
                     noise: float = 0.35, style_strength: float = 0.5,
                     label_skew_alpha: float = 0.0) -> ImageDataset:
    """label_skew_alpha > 0 adds per-writer Dir(alpha) class priors on top
    of the style shift — FEMNIST-by-writer is severely non-IID in both."""
    protos = rng.normal(0, 1, (num_classes, image_size, image_size, channels))
    # writer style: per-writer gain/bias field (smooth low-rank distortion)
    gains = 1.0 + style_strength * rng.normal(
        0, 1, (num_writers, image_size, 1, channels))
    biases = style_strength * rng.normal(
        0, 1, (num_writers, 1, image_size, channels))
    w = rng.integers(0, num_writers, n).astype(np.int32)
    if label_skew_alpha > 0:
        priors = rng.dirichlet(np.full(num_classes, label_skew_alpha),
                               size=num_writers)
        u = rng.random(n)
        y = (u[:, None] < np.cumsum(priors[w], axis=1)).argmax(
            axis=1).astype(np.int32)
    else:
        y = rng.integers(0, num_classes, n).astype(np.int32)
    x = protos[y] * gains[w] + biases[w] + noise * rng.normal(
        0, 1, (n, image_size, image_size, channels))
    return ImageDataset(x=x.astype(np.float32), y=y, writer=w)


@dataclasses.dataclass
class CharDataset:
    tokens: np.ndarray     # (N, S) int32 sequences
    role: np.ndarray       # (N,) int32 — speaking-role id


def synthetic_chars(rng: np.random.Generator, *, n: int, seq_len: int,
                    vocab: int = 90, num_roles: int = 100,
                    n_modes: int = 8) -> CharDataset:
    """Each role samples from its own mixture of ``n_modes`` shared Markov
    transition matrices — roles are highly non-IID but share structure
    (learnable by a global model)."""
    base = rng.dirichlet(np.ones(vocab) * 0.1, size=(n_modes, vocab))
    role_mix = rng.dirichlet(np.ones(n_modes) * 0.3, size=num_roles)
    role = rng.integers(0, num_roles, n).astype(np.int32)
    toks = np.zeros((n, seq_len), np.int32)
    toks[:, 0] = rng.integers(0, vocab, n)
    # per-role transition matrix (num_roles, vocab, vocab)
    trans = np.einsum("rm,mvw->rvw", role_mix, base)
    cum = np.cumsum(trans, axis=-1)
    u = rng.random((n, seq_len))
    for t in range(1, seq_len):
        c = cum[role, toks[:, t - 1]]                  # (n, vocab)
        toks[:, t] = (u[:, t, None] < c).argmax(axis=-1)
    return CharDataset(tokens=toks, role=role)


def synthetic_tokens(rng: np.random.Generator, *, n: int, seq_len: int,
                     vocab: int, num_clients: int) -> CharDataset:
    """Cheap LM streams with per-client unigram skew (zipfian, shifted)."""
    base = 1.0 / (1.0 + np.arange(vocab)) ** 1.1
    client = rng.integers(0, num_clients, n).astype(np.int32)
    shift = rng.integers(0, vocab, num_clients)
    toks = np.zeros((n, seq_len), np.int32)
    for c in range(num_clients):
        idx = np.where(client == c)[0]
        if idx.size == 0:
            continue
        p = np.roll(base, shift[c]); p = p / p.sum()
        toks[idx] = rng.choice(vocab, size=(idx.size, seq_len), p=p)
    return CharDataset(tokens=toks, role=client)

"""Client partitioners + meta-set construction.

  * ``partition_iid``       — §4.1: uniform random split (split CIFAR-10);
  * ``partition_dirichlet`` — label-skew non-IID (Dir(alpha) over classes);
  * ``partition_by_writer`` — §4.2/§4.3: one writer/role per client (FEMNIST
    / Shakespeare style, the paper's non-IID settings);
  * ``make_meta_set``       — §3.2/§4.4: sample the server meta set D_meta,
    optionally with a controlled writer-overlap rate vs the training
    population (Fig. 5's 0/25/50/75/100% overlap experiment).
"""
from __future__ import annotations

from typing import List, Sequence

import numpy as np


def partition_iid(rng: np.random.Generator, n: int, num_clients: int
                  ) -> List[np.ndarray]:
    perm = rng.permutation(n)
    return [np.sort(s) for s in np.array_split(perm, num_clients)]


def partition_dirichlet(rng: np.random.Generator, labels: np.ndarray,
                        num_clients: int, alpha: float = 0.3,
                        min_per_client: int = 8) -> List[np.ndarray]:
    classes = np.unique(labels)
    while True:
        buckets: List[List[int]] = [[] for _ in range(num_clients)]
        for c in classes:
            idx = np.where(labels == c)[0]
            rng.shuffle(idx)
            p = rng.dirichlet(np.full(num_clients, alpha))
            cuts = (np.cumsum(p)[:-1] * idx.size).astype(int)
            for b, part in zip(buckets, np.split(idx, cuts)):
                b.extend(part.tolist())
        if min(len(b) for b in buckets) >= min_per_client:
            return [np.sort(np.array(b)) for b in buckets]


def partition_by_writer(writer_ids: np.ndarray, writers: Sequence[int]
                        ) -> List[np.ndarray]:
    """One client per writer/role id, in the given order."""
    return [np.where(writer_ids == w)[0] for w in writers]


def make_meta_set(rng: np.random.Generator, writer_ids: np.ndarray,
                  train_writers: Sequence[int], aux_writers: Sequence[int],
                  *, overlap: float, fraction: float = 0.01
                  ) -> np.ndarray:
    """Sample ~``fraction`` of examples for D_meta from a writer population
    with the given overlap rate vs the training writers (§4.4): a fraction
    ``overlap`` of the meta writers come from ``train_writers``, the rest
    from the disjoint ``aux_writers``."""
    k = max(len(train_writers), 1)
    n_in = int(round(overlap * k))
    chosen = (list(rng.choice(np.asarray(train_writers), n_in, replace=False))
              + list(rng.choice(np.asarray(aux_writers), k - n_in,
                                replace=False)))
    pool = np.concatenate([np.where(writer_ids == w)[0] for w in chosen])
    n_meta = max(int(round(fraction * writer_ids.size)), 1)
    n_meta = min(n_meta, pool.size)
    return np.sort(rng.choice(pool, n_meta, replace=False))

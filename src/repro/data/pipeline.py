"""Cohort scheduler + per-round batch assembly (host side).

``FederatedData`` owns the client partition and produces, per round t:
  - the random client set S_t (fraction C of K clients, Algorithm 1 line 4),
  - ``cohort_batch``: pytree with leaves (cohort, b, ...) — resampled from
    each selected client's local examples,
  - ``client_weights``: (cohort,) = n_k (the FedAvg weighting),
  - optional FedShare injection: a slice of the globally shared set is mixed
    into every client batch (Zhao et al., 2018).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.rngtags import META_SAMPLE_SEED


@dataclasses.dataclass
class FederatedData:
    arrays: Dict[str, np.ndarray]        # full dataset, leaves (N, ...)
    client_indices: List[np.ndarray]     # per-client example ids
    meta_indices: Optional[np.ndarray] = None
    shared_indices: Optional[np.ndarray] = None   # FedShare global set
    seed: int = 0
    client_speeds: Optional[np.ndarray] = None    # (num_clients,) relative
                                        # compute speeds for simulated-time
                                        # accounting (see repro.sim.faults.
                                        # heavy_tail_speeds); sample_round
                                        # ships the cohort's slice when set

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)

    @property
    def num_clients(self) -> int:
        return len(self.client_indices)

    def _gather(self, idx: np.ndarray) -> Dict[str, np.ndarray]:
        return {k: v[idx] for k, v in self.arrays.items()}

    def sample_round(self, round_idx: int, *, cohort: int, batch: int,
                     share: bool = False, share_fraction: float = 0.5,
                     include: Optional[Sequence[int]] = None
                     ) -> Dict:
        """Returns {'cohort_batch', 'client_weights', 'clients'}.

        ``include``: client ids that MUST be in this round's cohort — the
        trainer's retry-with-backoff policy re-enqueues clients whose
        report was lost to a fault.  They overwrite cohort slots whose
        random draw is not itself in ``include`` (so at most ``cohort``
        retries land per round).  The rng call sequence is identical for
        ``include=None`` / ``include=[]``, keeping retry-free streams
        bit-identical to historical runs."""
        if cohort > self.num_clients:
            # numpy's replace=False error ("Cannot take a larger sample...")
            # names neither quantity; fail with both numbers and the fix
            raise ValueError(
                f"sample_round(cohort={cohort}) cannot draw that many "
                f"distinct clients from num_clients={self.num_clients}; "
                "lower the cohort (C*K) or partition the data into more "
                "clients")
        rng = np.random.default_rng((self.seed, round_idx))
        clients = rng.choice(self.num_clients, size=cohort, replace=False)
        if include:
            want = [int(c) for c in dict.fromkeys(include)
                    if 0 <= int(c) < self.num_clients]
            missing = [c for c in want if c not in set(clients.tolist())]
            free = [i for i, c in enumerate(clients.tolist())
                    if c not in set(want)]
            for slot, c in zip(free, missing[:cohort]):
                clients[slot] = c
        batches, weights = [], []
        n_share = int(batch * share_fraction) if share else 0
        if n_share and self.shared_indices is None:
            # Without this, the share slice is silently skipped and every
            # client batch comes back batch - n_share examples short — a
            # shape mismatch (or quietly smaller batches) far downstream.
            raise ValueError(
                f"sample_round(share=True) with share_fraction="
                f"{share_fraction} needs a FedShare global set, but "
                "FederatedData.shared_indices is None; configure "
                "shared_indices or call with share=False")
        for c in clients:
            idx = self.client_indices[c]
            take = rng.choice(idx, size=batch - n_share,
                              replace=idx.size < batch - n_share)
            if n_share:
                sh = rng.choice(self.shared_indices, size=n_share,
                                replace=self.shared_indices.size < n_share)
                take = np.concatenate([take, sh])
                rng.shuffle(take)
            batches.append(self._gather(take))
            weights.append(idx.size)
        cohort_batch = {k: np.stack([b[k] for b in batches])
                        for k in batches[0]}
        sample = {
            "cohort_batch": cohort_batch,
            "client_weights": np.asarray(weights, np.float32),
            "clients": clients,
        }
        if self.client_speeds is not None:
            sample["client_speeds"] = np.asarray(
                self.client_speeds, np.float32)[clients]
        return sample

    def sample_meta(self, round_idx: int, batch: int) -> Dict[str, np.ndarray]:
        assert self.meta_indices is not None, "no meta set configured"
        rng = np.random.default_rng((self.seed, META_SAMPLE_SEED, round_idx))
        take = rng.choice(self.meta_indices, size=batch,
                          replace=self.meta_indices.size < batch)
        return self._gather(take)

    def eval_batches(self, idx: np.ndarray, batch: int):
        for i in range(0, idx.size, batch):
            yield self._gather(idx[i:i + batch])

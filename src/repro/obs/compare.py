"""``python -m repro.obs.compare BASE CAND`` — the regression-watch CLI.

BASE and CAND are either two run directories (each holding a jsonl
tracker's ``metrics.jsonl``) or two ``BENCH_*.json`` verdict files; the
mode is picked from what the paths are.  Exit codes:

  0  within tolerances
  1  regression breach (a throughput/phase/loss/bytes/memory delta past
     its tolerance, or a bench gate flipped true -> false)
  2  refusal — the two inputs are not comparable (schema / round-count /
     bench-config mismatch), named in the output

See :mod:`repro.obs.regress` for the comparison semantics and tolerance
directions.  The CI ``regress`` job runs this against the checked-in
bench baselines with loose perf tolerances (shared runners) — gates and
schema stay strict.
"""
from __future__ import annotations

import argparse
import os
import sys
from typing import List, Optional

from repro.obs.regress import (Tolerances, compare_bench_files,
                               compare_run_dirs)

__all__ = ["main"]


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.compare",
        description="Compare two run dirs (metrics.jsonl) or two "
                    "BENCH_*.json files; exit 1 on a regression breach, "
                    "2 on a schema refusal.")
    ap.add_argument("base", help="baseline run dir or BENCH_*.json")
    ap.add_argument("cand", help="candidate run dir or BENCH_*.json")
    ap.add_argument("--perf-rel-tol", type=float, default=0.25,
                    help="allowed fractional DROP in rounds/s and bench "
                         "*_per_s/speedup leaves (default 0.25)")
    ap.add_argument("--phase-rel-tol", type=float, default=0.25,
                    help="allowed fractional GROWTH per phase span total")
    ap.add_argument("--loss-rel-tol", type=float, default=0.02,
                    help="allowed fractional GROWTH in final loss")
    ap.add_argument("--bytes-rel-tol", type=float, default=0.01,
                    help="two-sided comm/bytes tolerance (deterministic "
                         "payloads — movement means the codec changed)")
    ap.add_argument("--mem-rel-tol", type=float, default=0.10,
                    help="allowed fractional GROWTH in peak temp bytes")
    ap.add_argument("--pct-tol", type=float, default=10.0,
                    help="allowed absolute growth of *_pct bench leaves "
                         "in percentage points")
    ap.add_argument("--ignore-config", action="append", default=[],
                    metavar="KEY",
                    help="bench meta.config key allowed to differ "
                         "(repeatable), e.g. --ignore-config fast")
    ap.add_argument("--quiet", action="store_true",
                    help="print breaches/refusals only")
    args = ap.parse_args(argv)

    tol = Tolerances(perf_rel=args.perf_rel_tol,
                     phase_rel=args.phase_rel_tol,
                     loss_rel=args.loss_rel_tol,
                     bytes_rel=args.bytes_rel_tol,
                     mem_rel=args.mem_rel_tol,
                     pct_points=args.pct_tol)

    both_files = os.path.isfile(args.base) and os.path.isfile(args.cand)
    both_dirs = os.path.isdir(args.base) and os.path.isdir(args.cand)
    if both_files:
        code, deltas = compare_bench_files(
            args.base, args.cand, tol, ignore_config=args.ignore_config)
        mode = "bench"
    elif both_dirs:
        code, deltas = compare_run_dirs(args.base, args.cand, tol)
        mode = "run-dir"
    else:
        print(f"[compare] REFUSE: {args.base!r} and {args.cand!r} must "
              "both be run directories or both be BENCH_*.json files",
              file=sys.stderr)
        return 2

    shown = 0
    for d in deltas:
        if args.quiet and d.status in ("ok", "info"):
            continue
        print("[compare] " + d.format())
        shown += 1
    n_breach = sum(d.status == "BREACH" for d in deltas)
    n_refuse = sum(d.status == "REFUSE" for d in deltas)
    verdict = ("NOT COMPARABLE" if code == 2
               else "REGRESSION" if code == 1 else "PASS")
    print(f"[compare] {mode} {args.base} vs {args.cand}: {verdict} "
          f"({len(deltas)} checks, {n_breach} breaches, "
          f"{n_refuse} refusals)")
    return code


if __name__ == "__main__":
    raise SystemExit(main())

"""``python -m repro.obs <subcommand>`` — observability CLI front door.

  report   summarize a run dir's metrics.jsonl (repro.obs.report)
  compare  regression-diff two run dirs / BENCH files (repro.obs.compare)

Both are also runnable directly (``python -m repro.obs.report`` /
``python -m repro.obs.compare``).
"""
from __future__ import annotations

import sys


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    if not argv or argv[0] in ("-h", "--help"):
        print(__doc__.strip())
        return 0 if argv else 2
    cmd, rest = argv[0], argv[1:]
    if cmd == "report":
        from repro.obs.report import main as sub
        return sub(rest)
    if cmd == "compare":
        from repro.obs.compare import main as sub
        return sub(rest)
    print(f"unknown subcommand {cmd!r}; expected 'report' or 'compare'",
          file=sys.stderr)
    return 2


if __name__ == "__main__":
    raise SystemExit(main())

"""Round-window JAX profiler — the device-side half of ``--profile N``.

:class:`RoundProfiler` captures a ``jax.profiler`` trace for the round
window ``[start, start + rounds)`` into ``<run_dir>/profile/``.  The
trainer calls :meth:`maybe_start` before dispatching a chunk and
:meth:`maybe_stop` after the chunk's device sync; because a chunk spans
``rounds_per_call`` rounds, the window is widened to chunk boundaries
(you get at least the rounds you asked for, never fewer).  Start/stop are
emitted as ``profile_start`` / ``profile_stop`` tracker events so the
trace window is locatable in the metrics stream.

The capture is the standard XLA profile (``plugins/profile/<ts>/
*.xplane.pb`` + ``*.trace.json.gz``) viewable in TensorBoard's profile
plugin or ``chrome://tracing`` / Perfetto after gunzip.  Host-side phase
timings (sample/stack, dispatch, device-sync, checkpoint) come from the
tracker ``phase`` events instead — :func:`repro.obs.span` — so the two
views line up by round index.
"""
from __future__ import annotations

import os
from typing import Optional

import jax

from repro.obs.trackers import MetricsTracker, NoopTracker

__all__ = ["RoundProfiler"]


class RoundProfiler:
    """One capture window per run.  Inert when ``rounds <= 0``."""

    def __init__(self, run_dir: Optional[str], *, start: int = 0,
                 rounds: int = 0,
                 tracker: Optional[MetricsTracker] = None):
        if rounds > 0 and run_dir is None:
            raise ValueError(
                "profiling writes a trace directory and needs a run "
                "directory; pass one (FederatedTrainer's run_dir argument "
                "/ train.py --run-dir)")
        self.start = int(start)
        self.rounds = int(rounds)
        self.trace_dir = (os.path.join(run_dir, "profile")
                          if run_dir is not None else None)
        self._tracker = tracker if tracker is not None else NoopTracker()
        self._active = False
        self._done = rounds <= 0

    @property
    def active(self) -> bool:
        return self._active

    def maybe_start(self, round_idx: int, k: int = 1) -> bool:
        """Open the capture if the chunk ``[round_idx, round_idx + k)``
        overlaps the window — ``k`` is the chunk length, so a window
        starting mid-chunk still opens on the chunk that contains it
        (the widening the class docstring promises).  Returns True iff
        the trace is running."""
        if not self._done and not self._active \
                and round_idx + k > self.start:
            os.makedirs(self.trace_dir, exist_ok=True)
            jax.profiler.start_trace(self.trace_dir)
            self._active = True
            self._tracker.log_event("profile_start",
                                    {"round": round_idx,
                                     "trace_dir": self.trace_dir})
        return self._active

    def maybe_stop(self, next_round: int) -> None:
        """Close the capture once the window is fully covered
        (``next_round`` = first round of the NEXT chunk).  Call after the
        chunk's device sync so the captured ops actually executed."""
        if self._active and next_round >= self.start + self.rounds:
            jax.profiler.stop_trace()
            self._active = False
            self._done = True
            self._tracker.log_event("profile_stop",
                                    {"round": next_round - 1,
                                     "trace_dir": self.trace_dir})

    def close(self) -> None:
        """Abort an open capture (run ended inside the window)."""
        if self._active:
            jax.profiler.stop_trace()
            self._active = False
            self._done = True
            self._tracker.log_event("profile_stop",
                                    {"round": -1,
                                     "trace_dir": self.trace_dir})

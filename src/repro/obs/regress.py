"""Cross-run regression watch — two runs in, deltas and a verdict out.

A perf regression used to surface only when someone opened a perfetto
trace by hand.  This module makes runs self-comparing: it summarizes a
run dir's ``metrics.jsonl`` (or a ``BENCH_*.json`` verdict file) into
the handful of numbers that matter — rounds/s from the dispatch +
device-sync spans, per-phase span totals, loss, comm bytes, peak temp
memory from the ``roofline`` event — and diffs two of them against
directional relative tolerances:

  * throughput (``rounds_per_s``, any ``*_per_s`` / ``*speedup`` bench
    leaf) may only DROP by the perf tolerance;
  * phase totals, final loss, and peak temp bytes may only GROW;
  * comm bytes are two-sided (the uplink payload is deterministic —
    movement either way means the codec/schema changed);
  * boolean bench gates (``pass_*`` / ``gates``) are strict: a
    true -> false flip is always a breach, whatever the tolerances.

Schema misalignment — different ``round_metric_keys`` sets, different
round counts, a ``meta`` stamp naming a different bench/config — is a
*refusal*, not a pass or a breach: comparing apples to oranges exits 2
with a message naming the mismatched field.  The CLI wrapper is
``python -m repro.obs.compare BASE CAND`` (see ``repro.obs.compare``),
wired as the CI ``regress`` job.  Stdlib-only; no jax import.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Any, Dict, Iterable, List, Optional, Tuple

__all__ = ["Tolerances", "Delta", "read_jsonl", "summarize_run",
           "compare_run_dirs", "compare_bench_files"]

OK, INFO, WARN, BREACH, REFUSE = "ok", "info", "warn", "BREACH", "REFUSE"


@dataclasses.dataclass
class Tolerances:
    """Relative tolerances, all as fractions (0.25 = 25%).  Defaults are
    loose enough for shared CI runners; tighten locally."""
    perf_rel: float = 0.25     # rounds/s (and bench *_per_s) may drop this
    phase_rel: float = 0.25    # per-phase span totals may grow this
    loss_rel: float = 0.02     # final loss may grow this
    bytes_rel: float = 0.01    # comm_bytes delta, two-sided
    mem_rel: float = 0.10      # peak temp bytes may grow this
    pct_points: float = 10.0   # *_pct bench leaves: absolute points
    phase_abs_s: float = 0.05  # additive slack for near-zero phase totals


@dataclasses.dataclass
class Delta:
    name: str
    base: Any
    cand: Any
    status: str                # ok / info / warn / BREACH / REFUSE
    note: str = ""

    def format(self) -> str:
        return f"[{self.status:>6}] {self.name}: base={self.base!r} " \
               f"cand={self.cand!r}" + (f" — {self.note}" if self.note
                                        else "")


def read_jsonl(path: str) -> List[dict]:
    out = []
    with open(path, encoding="utf-8") as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out


def summarize_run(run_dir: str) -> Dict[str, Any]:
    """One run dir -> the comparison summary.  Reads the jsonl tracker's
    ``metrics.jsonl`` (records + events); raises FileNotFoundError with
    a hint when the run was not jsonl-tracked."""
    path = os.path.join(run_dir, "metrics.jsonl")
    if not os.path.isfile(path):
        raise FileNotFoundError(
            f"{path} not found — regression compare reads the jsonl "
            "tracker's output; run with --tracker jsonl --run-dir "
            f"{run_dir!r} (or point at a dir that has one)")
    records, events = [], []
    for rec in read_jsonl(path):
        (records if rec.get("kind") == "metrics" else events).append(rec)

    metric_keys: set = set()
    for r in records:
        metric_keys |= set(r) - {"kind"}
    losses = [r["client_loss"] for r in records if "client_loss" in r]

    phase_s: Dict[str, float] = {}
    event_counts: Dict[str, int] = {}
    roofline: Optional[dict] = None
    n_profile_summaries = 0
    for e in events:
        name = e.get("event", "?")
        event_counts[name] = event_counts.get(name, 0) + 1
        if name == "phase":
            p = e.get("phase", "?")
            phase_s[p] = phase_s.get(p, 0.0) + float(e.get("dur_s", 0.0))
        elif name == "roofline":
            roofline = e                    # keep the newest
        elif name == "profile_summary":
            n_profile_summaries += 1

    loop_s = phase_s.get("dispatch", 0.0) + phase_s.get("device_sync", 0.0)
    comm = None
    if "comm_bytes" in metric_keys:
        comm = sum(float(r.get("comm_bytes", 0.0)) for r in records)
    peak = None
    if roofline is not None:
        peak = (roofline.get("memory") or {}).get("temp_size_in_bytes")
    return {
        "run_dir": run_dir,
        "rounds": len(records),
        "metric_keys": sorted(metric_keys),
        "final_loss": losses[-1] if losses else None,
        "mean_loss": sum(losses) / len(losses) if losses else None,
        "min_loss": min(losses) if losses else None,
        "phase_s": {k: round(v, 6) for k, v in sorted(phase_s.items())},
        "rounds_per_s": (len(records) / loop_s) if loop_s > 0 else None,
        "comm_bytes": comm,
        "peak_temp_bytes": peak,
        "event_counts": dict(sorted(event_counts.items())),
        "n_profile_summaries": n_profile_summaries,
        "roofline": roofline,
    }


def _code(deltas: Iterable[Delta]) -> int:
    statuses = {d.status for d in deltas}
    if REFUSE in statuses:
        return 2
    return 1 if BREACH in statuses else 0


# ---------------------------------------------------------------------------
# run-dir mode
# ---------------------------------------------------------------------------
def compare_run_dirs(base_dir: str, cand_dir: str,
                     tol: Optional[Tolerances] = None
                     ) -> Tuple[int, List[Delta]]:
    tol = tol or Tolerances()
    b, c = summarize_run(base_dir), summarize_run(cand_dir)
    deltas: List[Delta] = []

    if b["metric_keys"] != c["metric_keys"]:
        only_b = sorted(set(b["metric_keys"]) - set(c["metric_keys"]))
        only_c = sorted(set(c["metric_keys"]) - set(b["metric_keys"]))
        deltas.append(Delta(
            "metric_keys", b["metric_keys"], c["metric_keys"], REFUSE,
            f"round_metric_keys schema differs (base-only: {only_b}, "
            f"cand-only: {only_c}) — different configs are not comparable"))
        return 2, deltas
    if b["rounds"] != c["rounds"]:
        deltas.append(Delta(
            "rounds", b["rounds"], c["rounds"], REFUSE,
            "different round counts — loss/throughput horizons differ"))
        return 2, deltas

    def rel(base, cand):
        return (cand - base) / abs(base) if base else 0.0

    # throughput: lower is a regression
    rb, rc = b["rounds_per_s"], c["rounds_per_s"]
    if rb is not None and rc is not None:
        drop = -rel(rb, rc)
        deltas.append(Delta(
            "rounds_per_s", round(rb, 4), round(rc, 4),
            BREACH if drop > tol.perf_rel else OK,
            f"{drop:+.1%} drop vs {tol.perf_rel:.0%} tol"))
    else:
        deltas.append(Delta("rounds_per_s", rb, rc, INFO,
                            "no dispatch/device_sync spans in one run"))

    # per-phase totals: growth is a regression
    for p in sorted(set(b["phase_s"]) | set(c["phase_s"])):
        pb = b["phase_s"].get(p, 0.0)
        pc = c["phase_s"].get(p, 0.0)
        limit = pb * (1.0 + tol.phase_rel) + tol.phase_abs_s
        deltas.append(Delta(
            f"phase_s.{p}", round(pb, 4), round(pc, 4),
            BREACH if pc > limit else OK,
            f"limit {limit:.4f}s ({tol.phase_rel:.0%} + "
            f"{tol.phase_abs_s}s slack)"))

    # final loss: growth is a regression (numerics, so a tight default)
    lb, lc = b["final_loss"], c["final_loss"]
    if lb is not None and lc is not None:
        limit = lb + abs(lb) * tol.loss_rel
        deltas.append(Delta(
            "final_loss", round(lb, 6), round(lc, 6),
            BREACH if lc > limit + 1e-12 else OK,
            f"limit {limit:.6f} ({tol.loss_rel:.1%})"))
    for k in ("mean_loss", "min_loss"):
        if b[k] is not None and c[k] is not None:
            deltas.append(Delta(k, round(b[k], 6), round(c[k], 6), INFO))

    # comm bytes: deterministic payload — two-sided
    cb, cc = b["comm_bytes"], c["comm_bytes"]
    if cb is not None and cc is not None:
        deltas.append(Delta(
            "comm_bytes", cb, cc,
            BREACH if abs(cc - cb) > tol.bytes_rel * max(abs(cb), 1.0)
            else OK, f"two-sided {tol.bytes_rel:.1%} tol"))

    # peak temp memory from the roofline event: growth is a regression
    mb, mc = b["peak_temp_bytes"], c["peak_temp_bytes"]
    if mb is not None and mc is not None:
        deltas.append(Delta(
            "peak_temp_bytes", mb, mc,
            BREACH if mc > mb * (1.0 + tol.mem_rel) else OK,
            f"{tol.mem_rel:.0%} growth tol"))
    elif mb is not None or mc is not None:
        deltas.append(Delta("peak_temp_bytes", mb, mc, INFO,
                            "roofline event present in only one run"))
    return _code(deltas), deltas


# ---------------------------------------------------------------------------
# bench-file mode
# ---------------------------------------------------------------------------
_HIGHER_BETTER = ("per_s", "speedup", "throughput_ratio", "relative")
_LOWER_BETTER_S = ("wall_s", "lower_s", "compile_s")


def _classify_leaf(name: str) -> str:
    leaf = name.rsplit(".", 1)[-1]
    if any(t in leaf for t in _HIGHER_BETTER):
        return "higher_better"
    if leaf.endswith("_pct"):
        return "pct"
    if any(t in leaf for t in _LOWER_BETTER_S):
        return "lower_better"
    if "bytes" in leaf:
        return "bytes"
    return "info"


def _walk(name: str, b: Any, c: Any, deltas: List[Delta],
          tol: Tolerances) -> None:
    if isinstance(b, dict) and isinstance(c, dict):
        for k in sorted(set(b) | set(c)):
            sub = f"{name}.{k}" if name else str(k)
            if k not in b or k not in c:
                deltas.append(Delta(sub, b.get(k, "<absent>"),
                                    c.get(k, "<absent>"), REFUSE,
                                    "key present in only one report — "
                                    "bench schema drift"))
                continue
            _walk(sub, b[k], c[k], deltas, tol)
        return
    if isinstance(b, bool) and isinstance(c, bool):
        if b and not c:
            deltas.append(Delta(name, b, c, BREACH,
                                "gate flipped true -> false"))
        elif c and not b:
            deltas.append(Delta(name, b, c, INFO, "gate now passes"))
        return
    if isinstance(b, (int, float)) and isinstance(c, (int, float)):
        kind = _classify_leaf(name)
        if kind == "higher_better":
            drop = (b - c) / abs(b) if b else 0.0
            if drop > tol.perf_rel:
                deltas.append(Delta(name, b, c, BREACH,
                                    f"{drop:+.1%} drop vs "
                                    f"{tol.perf_rel:.0%} tol"))
        elif kind == "lower_better":
            grow = (c - b) / abs(b) if b else 0.0
            if grow > tol.perf_rel:
                deltas.append(Delta(name, b, c, BREACH,
                                    f"{grow:+.1%} growth vs "
                                    f"{tol.perf_rel:.0%} tol"))
        elif kind == "pct":
            if c - b > tol.pct_points:
                deltas.append(Delta(name, b, c, BREACH,
                                    f"+{c - b:.2f} points vs "
                                    f"{tol.pct_points} tol"))
        elif kind == "bytes":
            if abs(c - b) > tol.bytes_rel * max(abs(b), 1.0):
                deltas.append(Delta(name, b, c, BREACH,
                                    f"two-sided {tol.bytes_rel:.1%} tol"))
        return                               # other numerics: gates own them
    if isinstance(b, (list, tuple)) and isinstance(c, (list, tuple)):
        if len(b) != len(c):
            deltas.append(Delta(name, f"len {len(b)}", f"len {len(c)}",
                                REFUSE, "sequence length differs — "
                                "bench schema drift"))
        return
    if b != c:
        deltas.append(Delta(name, b, c, REFUSE,
                            "non-numeric value differs — bench schema "
                            "drift"))


def compare_bench_files(base_path: str, cand_path: str,
                        tol: Optional[Tolerances] = None,
                        ignore_config: Iterable[str] = ()
                        ) -> Tuple[int, List[Delta]]:
    """Diff two ``BENCH_*.json`` verdict files.  The ``meta`` stamp
    (``benchmarks.common.write_bench_report``) guards apples-to-oranges:
    a different ``bench`` name or any differing ``config`` key (unless
    listed in ``ignore_config``) refuses with exit 2; host/jax_version
    drift only warns (that is exactly what CI compares across)."""
    tol = tol or Tolerances()
    ignore = set(ignore_config)
    with open(base_path, encoding="utf-8") as f:
        base = json.load(f)
    with open(cand_path, encoding="utf-8") as f:
        cand = json.load(f)
    deltas: List[Delta] = []

    bmeta, cmeta = base.pop("meta", None), cand.pop("meta", None)
    if bmeta is None or cmeta is None:
        deltas.append(Delta("meta", bool(bmeta), bool(cmeta), WARN,
                            "missing meta stamp (pre-unification bench "
                            "file) — comparing bodies unchecked"))
    else:
        if bmeta.get("bench") != cmeta.get("bench"):
            deltas.append(Delta("meta.bench", bmeta.get("bench"),
                                cmeta.get("bench"), REFUSE,
                                "different benchmarks are not comparable"))
            return 2, deltas
        bcfg = bmeta.get("config") or {}
        ccfg = cmeta.get("config") or {}
        for k in sorted(set(bcfg) | set(ccfg)):
            if k in ignore:
                continue
            if bcfg.get(k) != ccfg.get(k):
                deltas.append(Delta(
                    f"meta.config.{k}", bcfg.get(k), ccfg.get(k), REFUSE,
                    "bench configs differ — pass --ignore-config "
                    f"{k} to compare anyway"))
        if any(d.status == REFUSE for d in deltas):
            return 2, deltas
        for k in ("host", "jax_version"):
            if bmeta.get(k) != cmeta.get(k):
                deltas.append(Delta(f"meta.{k}", bmeta.get(k),
                                    cmeta.get(k), WARN,
                                    "environment differs — perf deltas "
                                    "are cross-machine"))
    # the body's own benchmark/config copies are covered by the meta
    # check above (and would re-refuse under --ignore-config otherwise)
    for rep in (base, cand):
        if bmeta is not None and cmeta is not None:
            rep.pop("benchmark", None)
            rep.pop("config", None)
    _walk("", base, cand, deltas, tol)
    return _code(deltas), deltas

"""``python -m repro.obs report <run_dir>`` — a run dir at a glance.

Pretty-prints the jsonl tracker's ``metrics.jsonl`` as the summary
:func:`repro.obs.regress.summarize_run` computes: loss figures, rounds/s
from the dispatch + device-sync spans, per-phase span totals, comm-bytes
totals, event counts, and — when the run emitted them — the roofline
prediction and the profiled top ops.  No jq, no trace UI.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List, Optional

from repro.obs.regress import summarize_run

__all__ = ["format_run_report", "main"]


def _fmt_bytes(n) -> str:
    if n is None:
        return "-"
    n = float(n)
    for unit in ("B", "KiB", "MiB", "GiB", "TiB"):
        if abs(n) < 1024 or unit == "TiB":
            return f"{n:.1f} {unit}" if unit != "B" else f"{int(n)} B"
        n /= 1024
    return f"{n:.1f} TiB"


def _row(label: str, value) -> str:
    return f"  {label:<24} {value}"


def format_run_report(s: dict) -> str:
    lines: List[str] = [f"run report: {s['run_dir']}", ""]
    lines.append(_row("rounds", s["rounds"]))
    for k in ("final_loss", "mean_loss", "min_loss"):
        v = s[k]
        lines.append(_row(k, f"{v:.6f}" if v is not None else "-"))
    rps = s["rounds_per_s"]
    lines.append(_row("rounds_per_s",
                      f"{rps:.3f} (from dispatch+device_sync spans)"
                      if rps is not None else "- (no spans logged)"))
    lines.append(_row("comm_bytes_total", _fmt_bytes(s["comm_bytes"])))
    lines.append(_row("peak_temp_bytes", _fmt_bytes(s["peak_temp_bytes"])))
    if s["phase_s"]:
        lines.append("")
        lines.append("  phase span totals:")
        for p, v in s["phase_s"].items():
            lines.append(f"    {p:<22} {v:10.4f} s")
    if s["event_counts"]:
        lines.append("")
        lines.append("  events: " + ", ".join(
            f"{k}x{v}" for k, v in s["event_counts"].items()))
    rl = s.get("roofline")
    if rl:
        lines.append("")
        lines.append("  roofline (per compiled round, v5e model):")
        for k in ("rounds_per_call", "bottleneck", "flops_per_round",
                  "bytes_per_round", "collective_bytes_per_round",
                  "predicted_rounds_per_s", "measured_rounds_per_s",
                  "loop_ratio"):
            if k in rl:
                v = rl[k]
                lines.append(f"    {k:<26} "
                             + (f"{v:.6g}" if isinstance(v, float)
                                else str(v)))
    if s.get("n_profile_summaries"):
        lines.append("")
        lines.append(f"  profile summaries: {s['n_profile_summaries']} "
                     "(see profile_summary events in metrics.jsonl)")
    return "\n".join(lines)


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs report",
        description="Summarize a run dir's metrics.jsonl.")
    ap.add_argument("run_dir")
    ap.add_argument("--json", action="store_true",
                    help="emit the raw summary dict as JSON instead")
    args = ap.parse_args(argv)
    try:
        s = summarize_run(args.run_dir)
    except FileNotFoundError as e:
        print(f"[report] {e}", file=sys.stderr)
        return 2
    if args.json:
        print(json.dumps(s, indent=1))
    else:
        print(format_run_report(s))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

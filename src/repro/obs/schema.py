"""The round-metrics schema — which keys a tracker will see, per config.

The round programs assemble ONE metrics dict per config (``lax.scan``
chunking already forces identical keys across rounds), so the key set is
a pure function of :class:`~repro.configs.base.FedConfig`.  This module
states that function in one place; ``tests/test_metrics_schema.py`` pins
real trainer records against it, so trackers (and anything downstream —
the csv header, dashboards, bench curve readers) can rely on the
documented names instead of probing.

Key catalog
-----------

Always (sync and async):
  ``round``        host round index (added by the trainer)
  ``client_loss``  cohort-weighted mean local loss
  ``grad_norm``    post-aggregation global gradient/delta norm

Sync rounds add:
  ``participants``   when ``participation < 1``
  ``arrivals`` / ``fault_crashed`` / ``fault_dropped``
                     when a fault profile is active
  ``fault_timeout``  when additionally ``round_deadline > 0``
  ``comm_bytes``     when the codec is lossy (measured uplink bytes)
  ``meta_loss``      when ``meta=True`` (post-aggregation FedMeta)
  ``ctrl_w_gnorm`` / ``ctrl_lr_grad`` / ``server_lr_eff``
                     additionally when ``meta_mode="through_aggregation"``

Async (``buffered_async``) ticks add:
  ``arrivals`` / ``server_steps`` / ``buffer_fill`` / ``overflow_dropped``
  ``staleness_mean`` / ``staleness_max``
  ``staleness_hist`` (a VECTOR — list in records — of
                     ``STALENESS_HIST_BINS`` counts)
  ``participants``   when ``participation < 1``
  ``fault_crashed`` / ``fault_dropped`` / ``fault_delayed``
                     when a fault profile is active
  ``expired``        when ``async_max_staleness > 0``
  ``comm_bytes``     when the codec is lossy
  ``meta_loss``      when ``meta=True``

The trainer adds:
  ``retried``        when the degradation policy is live
                     (``retry_backoff > 0`` and a loss-making fault
                     profile: crash, drop, or a round deadline)
"""
from __future__ import annotations

from typing import FrozenSet

from repro.configs.base import FedConfig
from repro.sim.faults import resolve_faults

__all__ = ["round_metric_keys", "VECTOR_METRICS",
           "ROOFLINE_EVENT_KEYS", "PROFILE_SUMMARY_EVENT_KEYS"]

# metrics whose per-round value is a vector (a list in records / jsonl,
# a JSON-encoded cell in csv) rather than a scalar float
VECTOR_METRICS: FrozenSet[str] = frozenset({"staleness_hist"})

# ---------------------------------------------------------------------------
# analysis-event schemas (PR 10) — the two structured events the trainer
# emits beyond phase/profiler/checkpoint markers.  The jsonl tracker adds
# its envelope ("kind"/"event"/"t") on top of these payload keys;
# tests/test_metrics_schema.py pins live trainer events against both.
# ---------------------------------------------------------------------------

# one per compiled round program (trainer roofline=True): the trip-count-
# aware cost model's per-round prediction (repro.roofline.live) plus the
# measured rounds/s from the dispatch + device-sync spans
ROOFLINE_EVENT_KEYS: FrozenSet[str] = frozenset({
    "rounds_per_call", "flops_per_round", "bytes_per_round",
    "collective_bytes_per_round", "per_collective", "compute_s_per_round",
    "memory_s_per_round", "collective_s_per_round", "bottleneck",
    "predicted_rounds_per_s", "loop_ratio", "xla_flops", "memory",
    "analysis_s", "measured_rounds_per_s", "measured_s_per_round",
    "rounds_measured"})

# one per captured trace (trainer trace_summary=True): the top-K
# self-time table and busy/gap/phase attribution
# (repro.obs.trace_analysis.summarize_trace)
PROFILE_SUMMARY_EVENT_KEYS: FrozenSet[str] = frozenset({
    "trace", "top_k", "n_events", "n_op_events", "n_ops", "wall_us",
    "busy_us", "gap_us", "busy_frac", "total_self_us", "top_ops",
    "phase_self_us"})


def round_metric_keys(fed: FedConfig, *, trainer: bool = True
                      ) -> FrozenSet[str]:
    """The exact key set of one round record under ``fed``.

    ``trainer=True`` (default) describes :class:`FederatedTrainer`
    records — including ``round`` and the retry-policy counter;
    ``trainer=False`` describes the raw jitted round program's metrics.
    """
    faults = resolve_faults(fed)
    is_async = fed.engine == "buffered_async" \
        or fed.cohort_strategy == "buffered_async"
    keys = {"client_loss", "grad_norm"}
    if fed.participation < 1.0:
        keys.add("participants")

    if is_async:
        keys |= {"arrivals", "server_steps", "buffer_fill",
                 "overflow_dropped", "staleness_mean", "staleness_max",
                 "staleness_hist"}
        if faults.active:
            keys |= {"fault_crashed", "fault_dropped", "fault_delayed"}
        if int(getattr(fed, "async_max_staleness", 0)) > 0:
            keys.add("expired")
        if fed.meta:
            keys.add("meta_loss")
    else:
        if faults.active:
            keys |= {"arrivals", "fault_crashed", "fault_dropped"}
            if faults.deadline > 0:
                keys.add("fault_timeout")
        if fed.meta:
            keys.add("meta_loss")
            if fed.meta_mode == "through_aggregation":
                keys |= {"ctrl_w_gnorm", "ctrl_lr_grad", "server_lr_eff"}

    from repro.comm.codecs import get_codec
    if get_codec(fed.codec).lossy:
        keys.add("comm_bytes")

    if trainer:
        keys.add("round")
        retry_on = (fed.retry_backoff > 0 and faults.active
                    and (faults.crash > 0 or faults.drop > 0
                         or faults.deadline > 0))
        if retry_on:
            keys.add("retried")
    return frozenset(keys)

"""Observability subsystem — the fifth plugin registry, plus analysis.

``repro.obs`` is where runs report what happened: pluggable
:class:`MetricsTracker` sinks for per-round metrics and events
(``noop`` / ``console`` / ``jsonl`` / ``csv`` / ``composite`` built in,
``tensorboard`` behind an optional-dependency gate,
:func:`register_tracker` for plugins), host-side phase :func:`span`
timing, the :class:`RoundProfiler` capturing a JAX trace for a round
window, and the documented round-metrics schema
(:func:`round_metric_keys`).

On top of that substrate sits the analysis layer (PR 10): trace
analytics (:mod:`repro.obs.trace_analysis` — per-op self time, busy/gap,
phase attribution, streamed as ``profile_summary`` events), the live
roofline hook (``roofline`` events via :mod:`repro.roofline.live`), and
the cross-run regression watch (:mod:`repro.obs.regress`, CLI
``python -m repro.obs.compare`` / ``python -m repro.obs report``).
Wired through ``FederatedTrainer(tracker=..., run_dir=...,
trace_summary=..., roofline=...)`` and ``train.py --tracker/--run-dir/
--profile/--trace-summary/--roofline``.
"""
from repro.obs.profiler import RoundProfiler
from repro.obs.regress import (Tolerances, compare_bench_files,
                               compare_run_dirs, summarize_run)
from repro.obs.schema import (PROFILE_SUMMARY_EVENT_KEYS,
                              ROOFLINE_EVENT_KEYS, VECTOR_METRICS,
                              round_metric_keys)
from repro.obs.trace_analysis import (emit_profile_summary, find_trace_file,
                                      summarize_trace)
from repro.obs.trackers import (CompositeTracker, ConsoleTracker,
                                CsvTracker, JsonlTracker, MetricsTracker,
                                NoopTracker, TensorBoardTracker,
                                available_trackers, get_tracker,
                                register_tracker, resolve_tracker, span)

__all__ = ["MetricsTracker", "NoopTracker", "ConsoleTracker",
           "JsonlTracker", "CsvTracker", "CompositeTracker",
           "TensorBoardTracker", "register_tracker", "get_tracker",
           "available_trackers", "resolve_tracker", "span",
           "RoundProfiler", "round_metric_keys", "VECTOR_METRICS",
           "ROOFLINE_EVENT_KEYS", "PROFILE_SUMMARY_EVENT_KEYS",
           "summarize_trace", "find_trace_file", "emit_profile_summary",
           "summarize_run", "compare_run_dirs", "compare_bench_files",
           "Tolerances"]

"""Observability subsystem — the fifth plugin registry.

``repro.obs`` is where runs report what happened: pluggable
:class:`MetricsTracker` sinks for per-round metrics and events
(``noop`` / ``console`` / ``jsonl`` / ``csv`` / ``composite`` built in,
:func:`register_tracker` for plugins), host-side phase :func:`span`
timing, the :class:`RoundProfiler` capturing a JAX trace for a round
window, and the documented round-metrics schema
(:func:`round_metric_keys`).  Wired through
``FederatedTrainer(tracker=..., run_dir=...)`` and
``train.py --tracker/--run-dir/--profile``.
"""
from repro.obs.profiler import RoundProfiler
from repro.obs.schema import VECTOR_METRICS, round_metric_keys
from repro.obs.trackers import (CompositeTracker, ConsoleTracker,
                                CsvTracker, JsonlTracker, MetricsTracker,
                                NoopTracker, available_trackers,
                                get_tracker, register_tracker,
                                resolve_tracker, span)

__all__ = ["MetricsTracker", "NoopTracker", "ConsoleTracker",
           "JsonlTracker", "CsvTracker", "CompositeTracker",
           "register_tracker", "get_tracker", "available_trackers",
           "resolve_tracker", "span", "RoundProfiler",
           "round_metric_keys", "VECTOR_METRICS"]

"""Trace analytics — the profiler's answers without opening a trace UI.

:class:`~repro.obs.profiler.RoundProfiler` (``--profile N``) writes the
standard XLA capture (``plugins/profile/<ts>/*.trace.json.gz``).  This
module parses that Chrome-trace JSON into the numbers a regression hunt
actually needs:

  * per-op **self time** — duration minus nested children on the same
    (pid, tid) lane — aggregated by op name into a top-K table;
  * **busy vs gap** time: the union of op intervals vs the op stream's
    wall window (a growing gap = dispatch stalls, not slower kernels);
  * **per-phase attribution**: while the capture is open the trainer
    wraps dispatch / device-sync in
    ``jax.profiler.TraceAnnotation("repro.phase.<name>")`` (the trace
    twin of the tracker's ``span()`` events), so each op's self time is
    credited to the phase window(s) overlapping it.  Ops outside every
    window — e.g. compilation running inside the capture — land in
    ``_unattributed`` rather than disappearing.

:func:`emit_profile_summary` streams the result into the active tracker
as a ``profile_summary`` event (keys pinned by
``repro.obs.schema.PROFILE_SUMMARY_EVENT_KEYS``) — that is how
``train.py --profile N --trace-summary`` lands in ``metrics.jsonl``.
Everything here is stdlib-only; no jax import.
"""
from __future__ import annotations

import glob
import gzip
import json
import os
from typing import Any, Dict, List, Optional, Sequence, Tuple

__all__ = ["PHASE_PREFIX", "load_trace", "find_trace_file", "op_events",
           "phase_windows", "self_times", "interval_union_us", "summarize",
           "summarize_trace", "emit_profile_summary"]

# TraceAnnotation prefix the trainer uses while the profiler is active;
# the suffix is the span() phase name (dispatch / device_sync)
PHASE_PREFIX = "repro.phase."


def load_trace(path: str) -> Dict[str, Any]:
    """Chrome-trace JSON, gzipped (``.trace.json.gz``) or plain."""
    opener = gzip.open if path.endswith(".gz") else open
    with opener(path, "rt", encoding="utf-8") as f:
        return json.load(f)


def find_trace_file(root: str) -> Optional[str]:
    """Newest ``*.trace.json(.gz)`` under ``root`` — a run dir, the
    profiler's ``<run_dir>/profile`` dir, or a direct file path."""
    if os.path.isfile(root):
        return root
    hits: List[str] = []
    for pat in ("*.trace.json.gz", "*.trace.json"):
        hits += glob.glob(os.path.join(root, "**", pat), recursive=True)
    return max(hits, key=os.path.getmtime) if hits else None


def _complete_events(trace: Dict[str, Any]) -> List[dict]:
    """Chrome ``"X"`` (complete) events with a ts + dur, the only kind
    that carries an interval."""
    return [e for e in trace.get("traceEvents", ())
            if isinstance(e, dict) and e.get("ph") == "X"
            and isinstance(e.get("ts"), (int, float))
            and isinstance(e.get("dur"), (int, float))]


def op_events(trace: Dict[str, Any]) -> List[dict]:
    """The device op stream: complete events tagged with an ``hlo_op``
    arg (XLA's per-op execution rows).  Backends that tag nothing fall
    back to every complete event on a device-named process, minus our
    own phase annotations."""
    evs = _complete_events(trace)
    ops = [e for e in evs
           if isinstance(e.get("args"), dict) and "hlo_op" in e["args"]]
    if ops:
        return ops
    dev = {e.get("pid") for e in trace.get("traceEvents", ())
           if isinstance(e, dict) and e.get("ph") == "M"
           and e.get("name") == "process_name"
           and "device" in str((e.get("args") or {}).get("name", "")).lower()}
    return [e for e in evs if e.get("pid") in dev
            and not str(e.get("name", "")).startswith(PHASE_PREFIX)]


def phase_windows(trace: Dict[str, Any]) -> List[Tuple[str, float, float]]:
    """``(phase, start_us, end_us)`` for every ``repro.phase.*``
    annotation; one phase recurs once per profiled chunk."""
    out = []
    for e in _complete_events(trace):
        name = str(e.get("name", ""))
        if name.startswith(PHASE_PREFIX):
            ts = float(e["ts"])
            out.append((name[len(PHASE_PREFIX):], ts, ts + float(e["dur"])))
    out.sort(key=lambda w: (w[1], w[0]))
    return out


def self_times(events: Sequence[dict]) -> List[float]:
    """Per-event self time (us), aligned with ``events``: each event's
    duration minus its direct children's durations on the same
    (pid, tid) lane.  Chrome complete events nest by containment, so a
    start-time sweep with an open-interval stack recovers the tree."""
    selfs = [float(e["dur"]) for e in events]
    lanes: Dict[Tuple[Any, Any], List[int]] = {}
    for i, e in enumerate(events):
        lanes.setdefault((e.get("pid"), e.get("tid")), []).append(i)
    for idx in lanes.values():
        idx.sort(key=lambda i: (float(events[i]["ts"]),
                                -float(events[i]["dur"])))
        stack: List[Tuple[float, int]] = []      # (end_us, event index)
        for i in idx:
            ts = float(events[i]["ts"])
            dur = float(events[i]["dur"])
            while stack and stack[-1][0] <= ts:
                stack.pop()
            if stack:
                selfs[stack[-1][1]] -= dur
            stack.append((ts + dur, i))
    return [max(s, 0.0) for s in selfs]


def interval_union_us(events: Sequence[dict]) -> float:
    """Total covered microseconds of the events' merged intervals."""
    iv = sorted((float(e["ts"]), float(e["ts"]) + float(e["dur"]))
                for e in events)
    total, cur_s, cur_e = 0.0, None, None
    for s, e in iv:
        if cur_e is None or s > cur_e:
            if cur_e is not None:
                total += cur_e - cur_s
            cur_s, cur_e = s, e
        else:
            cur_e = max(cur_e, e)
    if cur_e is not None:
        total += cur_e - cur_s
    return total


def summarize(trace: Dict[str, Any], top_k: int = 15) -> Dict[str, Any]:
    """One trace -> one ``profile_summary`` payload (sans the ``trace``
    path :func:`summarize_trace` adds)."""
    evs = _complete_events(trace)
    ops = op_events(trace)
    selfs = self_times(ops)
    windows = phase_windows(trace)

    agg: Dict[str, List[float]] = {}
    for e, s in zip(ops, selfs):
        a = agg.setdefault(str(e.get("name", "?")), [0.0, 0.0, 0])
        a[0] += s
        a[1] += float(e["dur"])
        a[2] += 1
    top = sorted(agg.items(), key=lambda kv: (-kv[1][0], kv[0]))[:top_k]

    phase: Dict[str, float] = {}
    for e, s in zip(ops, selfs):
        ts, dur = float(e["ts"]), float(e["dur"])
        end, covered = ts + dur, 0.0
        for name, ws, we in windows:
            ov = min(end, we) - max(ts, ws)
            if ov > 0 and dur > 0:
                phase[name] = phase.get(name, 0.0) + s * (ov / dur)
                covered += ov
        if dur > covered:
            phase["_unattributed"] = phase.get("_unattributed", 0.0) \
                + s * ((dur - covered) / dur)

    wall = busy = 0.0
    if ops:
        t0 = min(float(e["ts"]) for e in ops)
        t1 = max(float(e["ts"]) + float(e["dur"]) for e in ops)
        wall = t1 - t0
        busy = interval_union_us(ops)
    return {
        "top_k": int(top_k),
        "n_events": len(evs),
        "n_op_events": len(ops),
        "n_ops": len(agg),
        "wall_us": round(wall, 3),
        "busy_us": round(busy, 3),
        "gap_us": round(max(wall - busy, 0.0), 3),
        "busy_frac": round(busy / wall, 6) if wall > 0 else 0.0,
        "total_self_us": round(sum(selfs), 3),
        "top_ops": [{"op": n, "self_us": round(v[0], 3),
                     "total_us": round(v[1], 3), "count": int(v[2])}
                    for n, v in top],
        "phase_self_us": {n: round(v, 3) for n, v in sorted(phase.items())},
    }


def summarize_trace(path: str, top_k: int = 15) -> Dict[str, Any]:
    out = summarize(load_trace(path), top_k=top_k)
    out["trace"] = path
    return out


def emit_profile_summary(tracker, root: Optional[str],
                         top_k: int = 15) -> Optional[Dict[str, Any]]:
    """Summarize the newest trace under ``root`` into the tracker as a
    ``profile_summary`` event; returns the payload, or None when no
    trace file exists (nothing captured yet)."""
    path = find_trace_file(root) if root else None
    if path is None:
        return None
    summary = summarize_trace(path, top_k=top_k)
    tracker.log_event("profile_summary", summary)
    return summary

"""MetricsTracker — the FIFTH plugin registry: where round metrics go.

Before this subsystem every driver reported progress its own way: the
trainer had an optional ``log_every`` print, ``train.py --history-out``
dumped JSON after the fact, and each benchmark hand-rolled its curve
collection.  A :class:`MetricsTracker` is the one sink they all share:

  * ``log_metrics(round_idx, metrics)`` — one per-round record (the
    trainer's history dict: plain floats / ints / lists, already
    host-synced and JSON-serializable);
  * ``log_event(name, data)`` — out-of-band events: the trainer's
    ``run_start`` / ``run_finish``, the per-phase wall-clock spans
    (``phase`` events from :func:`span`: sample/stack, dispatch,
    device-sync, checkpoint), profiler start/stop, benchmark arm markers;
  * ``finish()`` — flush + close (idempotent).

Built-ins (registered like algorithms/executors/engines/codecs, through
the shared :class:`repro.core.registry.Registry`):

  ============  =========================================================
  ``noop``      drops everything — the default; a noop-tracked run is
                bit-identical to an untracked one (gated by
                ``benchmarks/obs_overhead.py``)
  ``console``   the trainer's classic ``[train] round N k=v ...`` line
                every ``every`` rounds
  ``jsonl``     one JSON object per line in ``<run_dir>/metrics.jsonl``
                (records AND events, distinguished by ``"kind"``)
  ``csv``       ``<run_dir>/metrics.csv`` with a header pinned to the
                first record's key set (the schema
                ``repro.obs.schema.round_metric_keys`` guarantees is
                stable per config); events go to ``<run_dir>/events.csv``
  ``composite`` fan-out to several trackers (``resolve_tracker`` builds
                one from a comma list: ``--tracker jsonl,console``)
  ============  =========================================================

``tensorboard`` is also registered, behind an optional-dependency gate:
it needs a ``SummaryWriter`` backend (``tensorboardX``, or torch's
bundled copy) and raises an actionable ImportError naming the pip
install when neither is importable — minimal installs (CI) use the
always-available trackers above instead.

Register alternatives (a wandb/tensorboard bridge, a socket shipper) with
:func:`register_tracker`; any registered name is selectable via
``FederatedTrainer(..., tracker="name")`` and ``train.py --tracker name``.
"""
from __future__ import annotations

import contextlib
import csv as _csv
import json
import os
import time
from typing import Any, Callable, Dict, Iterable, Optional, Sequence

from repro.core.registry import Registry

__all__ = ["MetricsTracker", "NoopTracker", "ConsoleTracker",
           "JsonlTracker", "CsvTracker", "CompositeTracker",
           "TensorBoardTracker", "register_tracker", "get_tracker",
           "available_trackers", "resolve_tracker", "span"]


class MetricsTracker:
    """Protocol.  Trackers are constructed per-run via the registry
    factory ``factory(run_dir=None, **kw) -> MetricsTracker``; file-backed
    trackers put their artifacts under ``run_dir``."""
    name: str = "?"

    def log_metrics(self, round_idx: int, metrics: Dict[str, Any]) -> None:
        raise NotImplementedError

    def log_event(self, name: str, data: Optional[Dict[str, Any]] = None
                  ) -> None:
        raise NotImplementedError

    def finish(self) -> None:
        """Flush and close; must be safe to call more than once."""


_TRACKERS = Registry("metrics tracker", "repro.obs.register_tracker")


def register_tracker(name: str):
    """Decorator registering a tracker factory
    ``factory(run_dir=None, **kw) -> MetricsTracker``."""
    def deco(factory: Callable) -> Callable:
        _TRACKERS.register(name, factory)
        return factory
    return deco


def get_tracker(name: str) -> Callable:
    return _TRACKERS.get(name)


def available_trackers() -> tuple:
    return _TRACKERS.names()


def resolve_tracker(spec, *, run_dir: Optional[str] = None,
                    owned: Optional[list] = None,
                    **kw) -> "MetricsTracker":
    """One resolution path for every driver:

      * ``None`` -> the ``noop`` tracker;
      * a :class:`MetricsTracker` instance -> itself;
      * a registry name -> ``factory(run_dir=run_dir, **kw)``;
      * a comma list (``"jsonl,console"``) or a sequence of any of the
        above -> a :class:`CompositeTracker` over the resolved parts.

    ``owned`` (when given) collects the trackers this call CONSTRUCTED —
    registry-built leaves, not passed-through instances — so a scoped
    caller (e.g. a per-``run()`` override) can ``finish()`` exactly what
    it created and never close a tracker the user still holds.
    """
    if spec is None:
        return NoopTracker()
    if isinstance(spec, MetricsTracker):
        return spec
    if isinstance(spec, str):
        if "," in spec:
            spec = [s.strip() for s in spec.split(",") if s.strip()]
        else:
            t = get_tracker(spec)(run_dir=run_dir, **kw)
            if owned is not None:
                owned.append(t)
            return t
    if isinstance(spec, (list, tuple)):
        return CompositeTracker(
            [resolve_tracker(s, run_dir=run_dir, owned=owned, **kw)
             for s in spec])
    raise ValueError(
        f"cannot resolve a metrics tracker from {spec!r}; expected None, a "
        f"MetricsTracker, a registered name {available_trackers()}, a "
        "comma list of names, or a sequence of those")


def _require_run_dir(run_dir: Optional[str], tracker: str, artifact: str
                     ) -> str:
    if run_dir is None:
        raise ValueError(
            f"the {tracker!r} tracker writes {artifact} and needs a run "
            "directory; pass one (FederatedTrainer's run_dir argument / "
            "train.py --run-dir) or use the 'noop'/'console' tracker")
    os.makedirs(run_dir, exist_ok=True)
    return run_dir


@contextlib.contextmanager
def span(tracker: MetricsTracker, phase: str, **data):
    """Wall-clock span emitted as a ``phase`` tracker event — the
    round-phase profiler's host-side half.  The trainer wraps each chunk's
    sample/stack, dispatch, device-sync (``block_until_ready``) and
    checkpoint stages so async-dispatch-vs-compute overlap is visible in
    the event stream (a long ``device_sync`` next to a short ``dispatch``
    IS the overlap).

    Yields a dict that carries ``dur_s`` after the block exits, so the
    caller can read the measured duration back without re-timing (the
    trainer's measured-rounds/s accounting for the roofline event)."""
    info = dict(data)
    t0 = time.perf_counter()
    try:
        yield info
    finally:
        info["dur_s"] = time.perf_counter() - t0
        tracker.log_event("phase", {"phase": phase, **info})


# ---------------------------------------------------------------------------
# built-in trackers
# ---------------------------------------------------------------------------
@register_tracker("noop")
class NoopTracker(MetricsTracker):
    """Drops everything.  The default: an untracked run and a noop-tracked
    run execute the same jitted programs on the same streams, so they are
    bit-identical (``benchmarks/obs_overhead.py`` gates it)."""
    name = "noop"

    def __init__(self, run_dir: Optional[str] = None):
        del run_dir

    def log_metrics(self, round_idx, metrics):
        pass

    def log_event(self, name, data=None):
        pass

    def finish(self):
        pass


@register_tracker("console")
class ConsoleTracker(MetricsTracker):
    """The classic trainer progress line, every ``every`` rounds (plus the
    final round, learned from the trainer's ``run_start`` event)."""
    name = "console"

    def __init__(self, run_dir: Optional[str] = None, *, every: int = 1,
                 log_fn: Callable = print):
        del run_dir
        self._every = max(int(every), 1)
        self._log = log_fn
        self._t0 = time.perf_counter()
        self._final_round: Optional[int] = None

    def log_metrics(self, round_idx, metrics):
        if round_idx % self._every and round_idx != self._final_round:
            return
        body = " ".join(f"{k}={v:.4f}" for k, v in metrics.items()
                        if k != "round" and isinstance(v, float))
        self._log(f"[train] round {round_idx:4d} {body} "
                  f"({time.perf_counter() - self._t0:.1f}s)")

    def log_event(self, name, data=None):
        if name == "run_start" and data and "final_round" in data:
            self._final_round = int(data["final_round"])

    def finish(self):
        pass


class _FileTracker(MetricsTracker):
    """Shared lazy-open / idempotent-close plumbing for file-backed
    trackers."""

    def __init__(self):
        self._closed = False

    def _check_open(self, what: str):
        if self._closed:
            raise RuntimeError(
                f"{self.name} tracker received {what} after finish(); "
                "trackers are closed once per run — build a new one (or "
                "delay finish()) for further logging")

    def finish(self):
        self._closed = True


@register_tracker("jsonl")
class JsonlTracker(_FileTracker):
    """One JSON object per line in ``<run_dir>/metrics.jsonl``:

        {"kind": "metrics", "round": 3, "client_loss": ..., ...}
        {"kind": "event", "event": "phase", "t": ..., "phase": "dispatch",
         "dur_s": ...}

    Append-mode, so a ``--resume`` run extends the same file; ``t`` is a
    host ``time.time()`` stamp on events.  Flushed on every ``run_finish``
    event and on :meth:`finish`."""
    name = "jsonl"

    def __init__(self, run_dir: Optional[str] = None,
                 filename: str = "metrics.jsonl"):
        super().__init__()
        run_dir = _require_run_dir(run_dir, self.name, "metrics.jsonl")
        self.path = os.path.join(run_dir, filename)
        self._fh = open(self.path, "a", encoding="utf-8")

    def log_metrics(self, round_idx, metrics):
        self._check_open("a metrics record")
        rec = {"kind": "metrics", "round": int(round_idx)}
        rec.update((k, v) for k, v in metrics.items() if k != "round")
        self._fh.write(json.dumps(rec) + "\n")

    def log_event(self, name, data=None):
        self._check_open("an event")
        rec = {"kind": "event", "event": name, "t": time.time()}
        rec.update(data or {})
        self._fh.write(json.dumps(rec) + "\n")
        if name == "run_finish":
            self._fh.flush()

    def finish(self):
        if not self._closed:
            self._fh.flush()
            self._fh.close()
        super().finish()


@register_tracker("csv")
class CsvTracker(_FileTracker):
    """``<run_dir>/metrics.csv`` — header pinned to the FIRST record's
    sorted key set.  A record with different keys raises (per-config the
    round metrics schema is stable — ``repro.obs.schema`` documents and
    ``tests/test_metrics_schema.py`` pins it — so drift here means a
    driver mixed configs into one file).  Vector metrics (e.g.
    ``staleness_hist``) are JSON-encoded in their cell.  Events land in
    ``<run_dir>/events.csv`` as ``(t, event, json_payload)``.

    Append-mode like jsonl, so a ``--resume`` run extends the same file
    instead of truncating the earlier rounds: an existing file's header
    row becomes the pinned header (resuming under a different config
    raises on the first record).  Flushed on every ``run_finish`` event
    and on :meth:`finish`."""
    name = "csv"

    def __init__(self, run_dir: Optional[str] = None,
                 filename: str = "metrics.csv"):
        super().__init__()
        run_dir = _require_run_dir(run_dir, self.name, "metrics.csv")
        self.path = os.path.join(run_dir, filename)
        self.events_path = os.path.join(run_dir, "events.csv")
        self._header: Optional[Sequence[str]] = self._existing_header(
            self.path)
        self._fh = open(self.path, "a", newline="", encoding="utf-8")
        self._writer = _csv.writer(self._fh)
        self._efh = None

    @staticmethod
    def _existing_header(path: str) -> Optional[Sequence[str]]:
        if not (os.path.exists(path) and os.path.getsize(path) > 0):
            return None
        with open(path, "r", newline="", encoding="utf-8") as f:
            return next(_csv.reader(f), None)

    def log_metrics(self, round_idx, metrics):
        self._check_open("a metrics record")
        rec = {"round": int(round_idx),
               **{k: v for k, v in metrics.items() if k != "round"}}
        if self._header is None:
            self._header = ["round"] + sorted(k for k in rec if k != "round")
            self._writer.writerow(self._header)
        missing = set(self._header) - set(rec)
        extra = set(rec) - set(self._header)
        if missing or extra:
            raise ValueError(
                f"csv tracker header is pinned to the first record's keys "
                f"{list(self._header)} but this record differs "
                f"(missing: {sorted(missing)}, new: {sorted(extra)}); "
                "per-config round metrics are schema-stable "
                "(repro.obs.schema) — use one tracker per config, or the "
                "jsonl tracker for mixed streams")
        self._writer.writerow(
            [json.dumps(rec[k]) if isinstance(rec[k], (list, tuple))
             else rec[k] for k in self._header])

    def log_event(self, name, data=None):
        self._check_open("an event")
        if self._efh is None:
            fresh = self._existing_header(self.events_path) is None
            self._efh = open(self.events_path, "a", newline="",
                             encoding="utf-8")
            self._ewriter = _csv.writer(self._efh)
            if fresh:
                self._ewriter.writerow(["t", "event", "data"])
        self._ewriter.writerow([time.time(), name, json.dumps(data or {})])
        if name == "run_finish":
            self._fh.flush()
            self._efh.flush()

    def finish(self):
        if not self._closed:
            self._fh.flush()
            self._fh.close()
            if self._efh is not None:
                self._efh.flush()
                self._efh.close()
        super().finish()


@register_tracker("composite")
class CompositeTracker(MetricsTracker):
    """Fan-out to several trackers (``resolve_tracker("jsonl,console")``).
    ``finish`` closes every child; children added by the trainer's
    ``log_every`` back-compat path are owned by the run that built them."""
    name = "composite"

    def __init__(self, trackers: Iterable[MetricsTracker] = (),
                 run_dir: Optional[str] = None):
        del run_dir
        self.trackers = list(trackers)

    def log_metrics(self, round_idx, metrics):
        for t in self.trackers:
            t.log_metrics(round_idx, metrics)

    def log_event(self, name, data=None):
        for t in self.trackers:
            t.log_event(name, data)

    def finish(self):
        for t in self.trackers:
            t.finish()


def _summary_writer_cls():
    """The optional-dependency gate for the tensorboard tracker: prefer
    ``tensorboardX`` (pure-python, no TF), fall back to torch's bundled
    writer, and otherwise raise an ImportError that names the install —
    the registry factory stays importable either way, so
    ``available_trackers()`` always lists the name."""
    try:
        from tensorboardX import SummaryWriter
        return SummaryWriter
    except ImportError:
        pass
    try:
        from torch.utils.tensorboard import SummaryWriter
        return SummaryWriter
    except ImportError as e:
        raise ImportError(
            "the 'tensorboard' tracker needs a SummaryWriter backend and "
            "neither 'tensorboardX' nor 'torch' is installed; pip install "
            "tensorboardX (the lightweight extra) — or use the built-in "
            "jsonl/csv trackers, which need nothing") from e


@register_tracker("tensorboard")
class TensorBoardTracker(_FileTracker):
    """TensorBoard event files under ``<run_dir>/tb/`` — scalars from
    every round record (vector metrics like ``staleness_hist`` become
    histograms when the backend supports them, and are skipped
    otherwise), plus per-phase ``span`` durations on their round step.
    Other events are counted, not plotted — the jsonl stream stays the
    full-fidelity record; this is the dashboard view."""
    name = "tensorboard"

    def __init__(self, run_dir: Optional[str] = None,
                 subdir: str = "tb"):
        super().__init__()
        cls = _summary_writer_cls()
        run_dir = _require_run_dir(run_dir, self.name,
                                   "tensorboard event files")
        self.log_dir = os.path.join(run_dir, subdir)
        self._writer = cls(self.log_dir)

    def log_metrics(self, round_idx, metrics):
        self._check_open("a metrics record")
        for k, v in metrics.items():
            if k == "round":
                continue
            if isinstance(v, (list, tuple)):
                try:
                    self._writer.add_histogram(f"round/{k}", list(v),
                                               int(round_idx))
                except Exception:  # noqa: BLE001 — backend-optional
                    pass
            elif isinstance(v, (int, float)):
                self._writer.add_scalar(f"round/{k}", float(v),
                                        int(round_idx))

    def log_event(self, name, data=None):
        self._check_open("an event")
        data = data or {}
        if name == "phase" and "dur_s" in data:
            self._writer.add_scalar(f"phase/{data.get('phase', '?')}_s",
                                    float(data["dur_s"]),
                                    int(data.get("round", 0)))
        elif name == "roofline":
            for k in ("predicted_rounds_per_s", "measured_rounds_per_s"):
                v = data.get(k)
                if isinstance(v, (int, float)):
                    self._writer.add_scalar(f"roofline/{k}", float(v),
                                            int(data.get("rounds_per_call",
                                                         0)))

    def finish(self):
        if not self._closed:
            self._writer.flush()
            self._writer.close()
        super().finish()

"""minicpm-2b [dense] — llama-like arch trained with the WSD
(warmup-stable-decay) schedule. [arXiv:2404.06395]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="minicpm-2b",
    family="dense",
    num_layers=40,
    d_model=2304,
    num_heads=36,
    num_kv_heads=36,
    d_ff=5760,
    vocab_size=122753,
    tie_embeddings=True,     # MiniCPM ties embeddings
    sliding_window=8192,     # long_500k variant only (DESIGN.md §5)
    source="arXiv:2404.06395",
)

SMOKE = ArchConfig(
    name="minicpm-2b-smoke",
    family="dense",
    num_layers=2,
    d_model=144,
    num_heads=4,
    num_kv_heads=4,
    d_ff=288,
    vocab_size=512,
    tie_embeddings=True,
    sliding_window=64,
    source="reduced variant of arXiv:2404.06395",
)

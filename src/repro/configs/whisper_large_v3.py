"""whisper-large-v3 [audio] — encoder-decoder transformer backbone; the
mel-spectrogram + conv feature extractor frontend is a STUB (input_specs()
provides precomputed frame embeddings). [arXiv:2212.04356]

The assignment specifies the decoder backbone: 32L d_model=1280 20H
(kv=20) d_ff=5120 vocab=51866.  Whisper-large has a matching 32-layer
encoder over 1500 frames.
"""
from repro.configs.base import ArchConfig, EncoderConfig

CONFIG = ArchConfig(
    name="whisper-large-v3",
    family="audio",
    num_layers=32,
    d_model=1280,
    num_heads=20,
    num_kv_heads=20,
    d_ff=5120,
    vocab_size=51866,
    rope_theta=0.0,          # whisper uses learned absolute positions
    cross_every=2,           # decoder: cross-attention every other layer
    encoder=EncoderConfig(enc_layers=32, enc_len=1500, enc_dim=1280,
                          enc_heads=20, enc_ff=5120),
    source="arXiv:2212.04356",
)

SMOKE = ArchConfig(
    name="whisper-large-v3-smoke",
    family="audio",
    num_layers=2,
    d_model=256,
    num_heads=4,
    num_kv_heads=4,
    d_ff=512,
    vocab_size=512,
    rope_theta=0.0,
    cross_every=2,
    encoder=EncoderConfig(enc_layers=2, enc_len=64, enc_dim=256,
                          enc_heads=4, enc_ff=512),
    source="reduced variant of arXiv:2212.04356",
)

"""The paper's own experimental models (§4): the FedAvg CNNs for split
CIFAR-10 / FEMNIST and the character-level GRU for Shakespeare.

These are small, actually-trainable-on-CPU models used by the paper-claim
validation benchmarks; they are built by ``repro.models.smallnets`` rather
than the transformer stack.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class CNNConfig:
    """§4.1.2 / §4.2.2 — the FedAvg CNN."""
    name: str
    image_size: int
    in_channels: int
    num_classes: int
    conv_channels: Tuple[int, int]
    conv_kernel: int = 5
    pool: int = 3                  # CIFAR: 3x3/2 pooling; FEMNIST: 2x2/2
    pool_stride: int = 2
    fc: Tuple[int, ...] = (384, 192)
    dropout: float = 0.2


@dataclasses.dataclass(frozen=True)
class GRUConfig:
    """§4.3.2 — character-level GRU language model."""
    name: str
    vocab_size: int = 90           # printable charset used by LEAF Shakespeare
    embed_dim: int = 256
    hidden: int = 1024
    seq_len: int = 80


CIFAR_CNN = CNNConfig(
    name="paper-cifar-cnn",
    image_size=32, in_channels=3, num_classes=10,
    conv_channels=(64, 64), conv_kernel=5, pool=3, pool_stride=2,
    fc=(384, 192),
)

FEMNIST_CNN = CNNConfig(
    name="paper-femnist-cnn",
    image_size=28, in_channels=1, num_classes=62,
    conv_channels=(32, 64), conv_kernel=5, pool=2, pool_stride=2,
    fc=(512,),
)

SHAKESPEARE_GRU = GRUConfig(name="paper-shakespeare-gru")

# Reduced variants for fast tests / CI-style benchmark smoke.
CIFAR_CNN_SMOKE = dataclasses.replace(
    CIFAR_CNN, name="paper-cifar-cnn-smoke", conv_channels=(8, 8), fc=(32, 16))
FEMNIST_CNN_SMOKE = dataclasses.replace(
    FEMNIST_CNN, name="paper-femnist-cnn-smoke", conv_channels=(8, 8), fc=(32,))
SHAKESPEARE_GRU_SMOKE = dataclasses.replace(
    SHAKESPEARE_GRU, name="paper-shakespeare-gru-smoke", embed_dim=16,
    hidden=32, seq_len=20)

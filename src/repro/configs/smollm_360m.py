"""smollm-360m [dense] — llama-arch small. [hf:HuggingFaceTB/SmolLM-135M]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="smollm-360m",
    family="dense",
    num_layers=32,
    d_model=960,
    num_heads=15,
    num_kv_heads=5,
    d_ff=2560,
    vocab_size=49152,
    tie_embeddings=True,
    sliding_window=8192,   # long_500k variant only (DESIGN.md §5)
    source="hf:HuggingFaceTB/SmolLM-135M (360M variant)",
)

SMOKE = ArchConfig(
    name="smollm-360m-smoke",
    family="dense",
    num_layers=2,
    d_model=192,
    num_heads=3,
    num_kv_heads=1,
    d_ff=512,
    vocab_size=512,
    tie_embeddings=True,
    sliding_window=64,
    source="reduced variant of hf:HuggingFaceTB/SmolLM-135M",
)

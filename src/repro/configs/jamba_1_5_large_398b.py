"""jamba-1.5-large-398b [hybrid] — Mamba+attention 1:7 interleave with MoE.
[arXiv:2403.19887]

72 layers in 9 groups of 8 (1 attention : 7 mamba); MoE (16 experts, top-2)
replaces the dense MLP every other layer (Jamba e/2 spacing).
"""
from repro.configs.base import ArchConfig, MoEConfig, SSMConfig

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    attn_period=8,           # 1 attn per 8 layers, rest mamba
    moe=MoEConfig(num_experts=16, top_k=2, every=2),
    ssm=SSMConfig(d_state=128, d_head=128, expand=2, chunk=256),
    source="arXiv:2403.19887",
)

SMOKE = ArchConfig(
    name="jamba-1.5-large-398b-smoke",
    family="hybrid",
    num_layers=2,            # 1 mamba + 1 attn (attn_period=2)
    d_model=256,
    num_heads=4,
    num_kv_heads=2,
    d_ff=512,
    vocab_size=512,
    attn_period=2,
    moe=MoEConfig(num_experts=4, top_k=2, every=2),
    ssm=SSMConfig(d_state=16, d_head=64, expand=2, chunk=32),
    source="reduced variant of arXiv:2403.19887",
)

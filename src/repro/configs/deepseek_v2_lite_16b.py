"""deepseek-v2-lite-16b [moe] — MLA (kv_lora=512) + fine-grained MoE.
[arXiv:2405.04434]

Assignment line: "MoE 64e top-6 — MLA kv_lora=512, 2 shared+160 routed
top-6".  The "160 routed" clause matches full DeepSeek-V2, not -lite; we
follow the primary numbers given for this assignment: 64 routed experts,
top-6, 2 shared, per-expert FFN width 1408 (=d_ff).  First layer is dense
in the real model; for uniformity of the scanned stack we apply MoE on
every layer (noted deviation).
"""
from repro.configs.base import ArchConfig, MLAConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    num_layers=27,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,         # MLA: kv heads == q heads post up-projection
    d_ff=1408,               # per-expert width
    vocab_size=102400,
    head_dim=128,
    moe=MoEConfig(num_experts=64, top_k=6, num_shared=2, d_expert=1408),
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=None, rope_head_dim=64),
    source="arXiv:2405.04434",
)

SMOKE = ArchConfig(
    name="deepseek-v2-lite-16b-smoke",
    family="moe",
    num_layers=2,
    d_model=256,
    num_heads=4,
    num_kv_heads=4,
    d_ff=128,
    vocab_size=512,
    head_dim=64,
    moe=MoEConfig(num_experts=4, top_k=2, num_shared=1, d_expert=128),
    mla=MLAConfig(kv_lora_rank=64, rope_head_dim=32),
    source="reduced variant of arXiv:2405.04434",
)

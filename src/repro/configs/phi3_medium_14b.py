"""phi3-medium-14b [dense] — RoPE SwiGLU GQA. [arXiv:2404.14219]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="phi3-medium-14b",
    family="dense",
    num_layers=40,
    d_model=5120,
    num_heads=40,
    num_kv_heads=10,
    d_ff=17920,
    vocab_size=100352,
    sliding_window=8192,   # long_500k variant only (DESIGN.md §5)
    source="arXiv:2404.14219",
)

SMOKE = ArchConfig(
    name="phi3-medium-14b-smoke",
    family="dense",
    num_layers=2,
    d_model=256,
    num_heads=8,
    num_kv_heads=2,
    d_ff=512,
    vocab_size=512,
    sliding_window=64,
    source="reduced variant of arXiv:2404.14219",
)

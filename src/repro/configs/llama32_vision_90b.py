"""llama-3.2-vision-90b [vlm] — cross-attn image layers; ViT vision encoder
+ projector is a STUB (input_specs() supplies patch embeddings).
[hf:meta-llama/Llama-3.2-11B-Vision, scaled to the 90B assignment numbers]
"""
from repro.configs.base import ArchConfig, EncoderConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    num_layers=100,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    rope_theta=500_000.0,
    cross_every=10,          # every 10th layer cross-attends to image tokens
    # Stub vision tower output: 1601 patch embeddings (1 tile), projected
    # to d_model by input_specs(); enc_layers=0 => projector-only stub.
    encoder=EncoderConfig(enc_layers=0, enc_len=1601, enc_dim=8192),
    sliding_window=8192,     # long_500k variant only: self-attn layers
                             # windowed, cross-attn layers are constant-size
    source="hf:meta-llama/Llama-3.2-11B-Vision (90B assignment numbers)",
)

SMOKE = ArchConfig(
    name="llama-3.2-vision-90b-smoke",
    family="vlm",
    num_layers=2,
    d_model=256,
    num_heads=8,
    num_kv_heads=2,
    d_ff=512,
    vocab_size=512,
    cross_every=2,
    encoder=EncoderConfig(enc_layers=0, enc_len=32, enc_dim=256),
    sliding_window=64,
    source="reduced variant of hf:meta-llama/Llama-3.2-11B-Vision",
)

"""llama4-scout-17b-a16e [moe] — 16-expert top-1 MoE with shared expert,
early-fusion multimodal (text path only here; fusion embeds are data).
Scout natively uses chunked attention (iRoPE), so the sliding-window
long-context variant is faithful. [hf:meta-llama/Llama-4-Scout-17B-16E]
"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=202048,
    rope_theta=500_000.0,
    moe=MoEConfig(num_experts=16, top_k=1, num_shared=1, every=1),
    sliding_window=8192,     # native chunked-attention analogue
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
)

SMOKE = ArchConfig(
    name="llama4-scout-17b-a16e-smoke",
    family="moe",
    num_layers=2,
    d_model=256,
    num_heads=4,
    num_kv_heads=2,
    d_ff=256,
    vocab_size=512,
    moe=MoEConfig(num_experts=4, top_k=1, num_shared=1),
    sliding_window=64,
    source="reduced variant of hf:meta-llama/Llama-4-Scout-17B-16E",
)

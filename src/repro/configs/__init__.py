"""Config registry: ``get_arch(name)`` / ``get_smoke(name)`` / ``ARCHS``."""
from __future__ import annotations

from repro.configs.base import (ArchConfig, FedConfig, MLAConfig, MoEConfig,
                                SHAPES, SSMConfig, ShapeConfig, EncoderConfig)

from repro.configs import (phi3_mini_3_8b, whisper_large_v3, minicpm_2b,
                           llama32_vision_90b, jamba_1_5_large_398b,
                           deepseek_v2_lite_16b, llama4_scout_17b_a16e,
                           smollm_360m, mamba2_780m, phi3_medium_14b)

_MODULES = {
    "phi3-mini-3.8b": phi3_mini_3_8b,
    "whisper-large-v3": whisper_large_v3,
    "minicpm-2b": minicpm_2b,
    "llama-3.2-vision-90b": llama32_vision_90b,
    "jamba-1.5-large-398b": jamba_1_5_large_398b,
    "deepseek-v2-lite-16b": deepseek_v2_lite_16b,
    "llama4-scout-17b-a16e": llama4_scout_17b_a16e,
    "smollm-360m": smollm_360m,
    "mamba2-780m": mamba2_780m,
    "phi3-medium-14b": phi3_medium_14b,
}

ARCHS = tuple(_MODULES.keys())

# (arch, shape) pairs excluded from the matrix, with the documented reason
# (DESIGN.md §5).  Everything else in ARCHS x SHAPES must lower + compile.
SKIPS = {
    ("whisper-large-v3", "long_500k"):
        "encoder-decoder audio model: 500k-token transcript decode is not "
        "meaningful and the decoder is full-attention by construction",
}


def get_arch(name: str) -> ArchConfig:
    if name.endswith("-smoke"):
        return get_smoke(name[: -len("-smoke")])
    return _MODULES[name].CONFIG


def get_smoke(name: str) -> ArchConfig:
    return _MODULES[name].SMOKE


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


def matrix():
    """All (arch, shape) pairs that must pass the dry-run."""
    out = []
    for a in ARCHS:
        for s in SHAPES:
            if (a, s) in SKIPS:
                continue
            out.append((a, s))
    return out


__all__ = ["ArchConfig", "FedConfig", "MoEConfig", "MLAConfig", "SSMConfig",
           "EncoderConfig", "ShapeConfig", "SHAPES", "ARCHS", "SKIPS",
           "get_arch", "get_smoke", "get_shape", "matrix"]

"""Architecture / shape / federated configuration dataclasses.

Every assigned architecture is described by an :class:`ArchConfig` (exact
numbers from the assignment, source cited in each ``configs/<id>.py``) plus a
``smoke()`` reduced variant (2 layers, d_model<=512, <=4 experts) used by the
CPU smoke tests.  The four assigned input shapes live in ``SHAPES``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Layer kinds
# ---------------------------------------------------------------------------
ATTN = "attn"            # GQA self-attention
MAMBA = "mamba"          # Mamba2 SSD block
CROSS = "cross"          # cross-attention (VLM image layers / enc-dec)


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    num_shared: int = 0                 # always-on shared experts (deepseek)
    d_expert: Optional[int] = None      # per-expert FFN width (None -> d_ff)
    every: int = 1                      # MoE MLP every `every`-th layer
    aux_loss_coef: float = 0.01         # router load-balance aux loss
    capacity_factor: float = 1.25       # expert capacity = K*gs/E * this
    group_size: int = 4096              # tokens per dispatch group


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 multi-head latent attention."""
    kv_lora_rank: int = 512
    q_lora_rank: Optional[int] = None   # None -> dense q projection (v2-lite)
    rope_head_dim: int = 64             # decoupled RoPE key dimension


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba2 SSD block hyper-parameters."""
    d_state: int = 128
    d_head: int = 64                    # P in SSD; heads = d_inner // d_head
    expand: int = 2                     # d_inner = expand * d_model
    chunk: int = 256                    # SSD chunk length
    d_conv: int = 4                     # depthwise conv width
    n_groups: int = 1                   # B/C projection groups (per-group, not per-head)


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    """Stub-frontend encoder (audio/vision).  The conv/mel (whisper) or ViT
    (VLM) frontend is NOT implemented (per assignment carve-out); inputs are
    precomputed frame/patch embeddings of shape (batch, enc_len, enc_dim)."""
    enc_layers: int
    enc_len: int                        # number of frames / image tokens
    enc_dim: int                        # embedding dim delivered by the stub
    enc_heads: int = 16
    enc_ff: int = 0                     # 0 -> 4*enc_dim


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                         # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int                      # query heads (0 for attn-free)
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                   # 0 -> d_model // num_heads
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    encoder: Optional[EncoderConfig] = None
    # hybrid interleave: within each group of `attn_period` layers, one is
    # attention and the rest are `MAMBA` (jamba: 1:7 -> attn_period=8).
    attn_period: int = 1                # 1 => every layer is attention
    cross_every: int = 0                # >0: every k-th layer is cross-attn (vlm)
    sliding_window: int = 0             # >0: sliding-window attention variant
    dtype: str = "bfloat16"
    source: str = ""                    # citation for the exact numbers

    # ---- derived -----------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    def layer_kinds(self) -> Tuple[str, ...]:
        """Sequence of layer kinds, length == num_layers."""
        kinds = []
        for i in range(self.num_layers):
            if self.cross_every and (i % self.cross_every == self.cross_every - 1):
                kinds.append(CROSS)
            elif self.attn_period > 1:
                # jamba-style: attention once per period (in the middle),
                # mamba elsewhere.
                kinds.append(ATTN if i % self.attn_period == self.attn_period // 2
                             else MAMBA)
            elif self.family == "ssm":
                kinds.append(MAMBA)
            else:
                kinds.append(ATTN)
        return tuple(kinds)

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + per-layer blocks)."""
        d, v = self.d_model, self.vocab_size
        hd = self.resolved_head_dim
        total = v * d                                    # token embedding
        if not self.tie_embeddings:
            total += v * d                               # lm head
        kinds = self.layer_kinds()
        for i, kind in enumerate(kinds):
            total += 2 * d                               # 2 RMSNorm scales
            if kind == MAMBA:
                s = self.ssm or SSMConfig()
                d_in = s.expand * d
                heads = d_in // s.d_head
                # in_proj -> [z, x, B, C, dt]; B/C are per-group
                total += d * (2 * d_in + 2 * s.n_groups * s.d_state + heads)
                total += (d_in + 2 * s.n_groups * s.d_state) * s.d_conv  # conv over x,B,C
                total += 2 * heads                       # A, D per head
                total += d_in * d                        # out_proj
            elif kind in (ATTN, CROSS):
                if self.mla is not None:
                    m = self.mla
                    q_dim = self.num_heads * (hd + m.rope_head_dim)
                    total += d * (m.kv_lora_rank + m.rope_head_dim)       # kv down
                    total += m.kv_lora_rank * self.num_heads * 2 * hd     # kv up
                    total += d * q_dim                                    # q proj
                    total += self.num_heads * hd * d                      # o proj
                else:
                    total += d * self.num_heads * hd                      # q
                    total += 2 * d * self.num_kv_heads * hd               # k,v
                    total += self.num_heads * hd * d                      # o
            # MLP / MoE (mamba blocks in jamba also carry an MLP per layer)
            total += self._mlp_params(i)
        if self.encoder is not None:
            e = self.encoder
            eff = e.enc_ff or 4 * e.enc_dim
            per = 4 * e.enc_dim * e.enc_dim + 3 * e.enc_dim * eff + 2 * e.enc_dim
            total += e.enc_layers * per
        return total

    def _mlp_params(self, layer_idx: int) -> int:
        d = self.d_model
        if self.moe is not None and (layer_idx % self.moe.every == self.moe.every - 1):
            m = self.moe
            de = m.d_expert or self.d_ff
            routed = m.num_experts * 3 * d * de          # swiglu experts
            shared = m.num_shared * 3 * d * de
            router = d * m.num_experts
            return routed + shared + router
        if self.d_ff == 0:
            return 0                                     # attn-free pure SSM
        return 3 * d * self.d_ff                         # swiglu dense

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top_k + shared experts only)."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        de = m.d_expert or self.d_ff
        n_moe_layers = sum(1 for i in range(self.num_layers)
                           if i % m.every == m.every - 1)
        inactive = n_moe_layers * (m.num_experts - m.top_k) * 3 * self.d_model * de
        return self.param_count() - inactive


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                            # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k":    ShapeConfig("train_4k",    4_096,   256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768,   32, "prefill"),
    "decode_32k":  ShapeConfig("decode_32k",  32_768,  128, "decode"),
    "long_500k":   ShapeConfig("long_500k",  524_288,    1, "decode"),
}


# ---------------------------------------------------------------------------
# Federated configuration (the paper's knobs)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class FedConfig:
    """Paper notation: B local batch, E local epochs, C client fraction.

    algorithm: any name in the ClientAlgorithm registry
    (``repro.core.algorithms``).  Built-ins:
      'fedavg'   — FedAvg local SGD, delta aggregation (biased)   [paper baseline]
      'uga'      — keep-trace GD + gradient evaluation (unbiased) [paper §3.1]
      'fedprox'  — FedAvg + proximal term mu/2 ||w - w_t||^2      [paper baseline]
      'fednova'  — tau_k-normalized delta averaging               [Wang et al. 2020]
    New algorithms register via ``repro.core.algorithms.register_algorithm``
    (one file, no core edits) and are accepted here by name.
    meta: FedMeta server meta-update after aggregation            [paper §3.2]
    share: FedShare — inject globally shared samples into client batches.
    """
    algorithm: str = "uga"
    meta: bool = True
    share: bool = False
    cohort: int = 16                    # clients per round (= C*K)
    local_steps: int = 2                # local steps per epoch; UGA: last = grad eval
    local_epochs: int = 1               # E: passes over the local microbatch
                                        # schedule (client batch must divide
                                        # by local_steps; cycled E times)
    client_lr: float = 0.002            # eta   (local SGD)
    server_lr: float = 0.002            # eta_g (aggregation step size)
    meta_lr: float = 0.002              # eta_meta
    prox_mu: float = 2e-4               # FedProx coefficient
    server_opt: str = "sgd"             # sgd | sgdm | adam | yogi
    server_momentum: float = 0.0
    cohort_strategy: str = "vmap"       # vmap (client-parallel) | scan
                                        # (client-sequential) | chunked
                                        # (cohort_chunk-client slices)
    cohort_chunk: Optional[int] = None  # >=1: stream the cohort through the
                                        # chunked executor in slices of this
                                        # many clients — vmap within a
                                        # slice, Pallas FMA accumulation
                                        # across slices, so peak gradient
                                        # memory is one chunk instead of the
                                        # whole cohort.  Results are
                                        # bit-identical for every chunk size
                                        # (a ragged final chunk is padded
                                        # with zero-weight clients).  None
                                        # keeps the configured
                                        # cohort_strategy; incompatible with
                                        # cohort_strategy='scan' (scan IS
                                        # the chunk=1 pin of the same core).
    remat_local_steps: bool = True      # jax.checkpoint each keep-trace step
    lr_decay: float = 1.0               # multiplicative per-round client-lr decay
    grad_agg_dtype: str = "float32"     # dtype of the aggregated gradient
    clip_norm: float = 0.0              # >0: clip the aggregated gradient G
                                        # (tames UGA's HVP amplification — the
                                        # instability the paper notes in §4.5.1)
    fused_update: bool = False          # fused flat-buffer Pallas server step
                                        # (aggregate->clip->apply in 2 HBM
                                        # passes; kernels/fused_update).  False
                                        # keeps the legacy tree-map path.
                                        # Implies fp32 aggregation (the fused
                                        # kernels ignore grad_agg_dtype).
    meta_mode: str = "post"             # 'post': Eq. (20) server meta step
                                        # after aggregation (the paper's §3.2,
                                        # default).  'through_aggregation':
                                        # backprop the D_meta loss THROUGH the
                                        # fused server step (custom-VJP Pallas
                                        # backward) into hypergradients for the
                                        # per-client aggregation weights and
                                        # the server step size, held in the
                                        # server state's 'ctrl' slot and
                                        # updated each round with ctrl_lr.
                                        # Requires fused_update; vmap AND
                                        # scan cohorts supported.
    ctrl_lr: float = 0.01               # hypergradient step size for the
                                        # controllable-weights state
                                        # (meta_mode='through_aggregation')
    participation: float = 1.0          # <1: partial participation /
                                        # straggler dropout — each round
                                        # keeps a client with this prob and
                                        # zeroes dropped clients' weights
                                        # inside the aggregation (every
                                        # executor/engine supports it)
    engine: Optional[str] = None        # server-engine registry name
                                        # (repro.core.engines); None derives
                                        # legacy_tree / fused_flat from
                                        # fused_update.  A registered custom
                                        # engine declaring the
                                        # through_aggregation capability
                                        # makes that meta_mode valid
                                        # regardless of fused_update.
    codec: str = "none"                 # gradient-codec registry name
                                        # (repro.comm): the client->server
                                        # uplink transport.  'none' ships
                                        # fp32 (bit-identical to a codec-
                                        # free round); 'int8' / 'sign1bit'
                                        # / 'topk' are lossy — they need a
                                        # flat-consuming engine
                                        # (fused_update=True) and are
                                        # meta_mode='post' only.
    error_feedback: bool = False        # keep each client's compression
                                        # residual in state["comm"] and add
                                        # it back before the next round's
                                        # encode (EF-SGD memory; restores
                                        # convergence under aggressive
                                        # codecs).  Requires a lossy codec.
    topk_ratio: float = 0.01            # fraction of largest-|g| elements
                                        # the 'topk' codec ships per dtype
                                        # group
    # ---- buffered-async runtime (engine='buffered_async') ----------------
    async_buffer: int = 0               # K: server steps every K arrived
                                        # deltas (0 -> cohort, i.e. one step
                                        # per fault-free tick)
    async_capacity: int = 0             # delta-pool slots (0 -> 2*cohort);
                                        # overflow evicts the stalest delta
    async_max_staleness: int = 0        # >0: evict arrived deltas older
                                        # than this many server versions
    staleness_mode: str = "invsqrt"     # flush-weight discount of a stale
                                        # delta: 'invsqrt' (FedBuff
                                        # 1/sqrt(1+s)) | 'inv' | 'none'
    # ---- client fault injection (repro.sim.faults) ------------------------
    fault_profile: str = "none"         # named profile ('none' | 'flaky' |
                                        # 'stragglers'); fault_* fields >= 0
                                        # override individual rates
    fault_drop: float = -1.0            # P(uplink report lost)
    fault_crash: float = -1.0           # P(client dies mid-round)
    fault_delay: float = -1.0           # P(report delivered rounds late)
    fault_max_delay: int = -1           # late reports land U{1..max_delay}
                                        # ticks late (async pool buffers
                                        # them; the sync barrier waits)
    fault_garble: float = -1.0          # P(payload corrupted) — the async
                                        # delta pool only; explicit garble
                                        # on a sync engine is a config error
    fault_garble_scale: float = -1.0    # corrupted payload multiplier range
    fault_speed_tail: float = -1.0      # lognormal sigma of client compute
                                        # time (simulated-latency model)
    round_deadline: float = 0.0         # sync barrier only: >0 drops any
                                        # client whose simulated completion
                                        # exceeds this many round-units
                                        # (async replaces the barrier — use
                                        # async_max_staleness there)
    retry_backoff: int = 0              # trainer policy: >0 re-enqueues a
                                        # crashed/dropped/timed-out client
                                        # after backoff * 2^attempt rounds
    retry_max: int = 3                  # retry attempts per client failure

    def __post_init__(self):
        # registry-backed validation (lazy imports: repro.core modules
        # import this one at module load, the registries only at use time)
        from repro.core.algorithms import get_algorithm
        from repro.core.executors import available_executors
        get_algorithm(self.algorithm)          # raises naming the registry
        # "sharded" is a modifier executor (selected by grad_shardings,
        # wrapping THIS field as its base strategy), not a base strategy
        base_strategies = tuple(n for n in available_executors()
                                if n != "sharded")
        if self.cohort_strategy not in base_strategies:
            raise ValueError(
                f"unknown cohort_strategy {self.cohort_strategy!r}; "
                f"registered base cohort executors: {base_strategies} "
                "(the 'sharded' executor is selected by passing "
                "grad_shardings to make_federated_round, not here)")
        if self.cohort_chunk is not None:
            if self.cohort_chunk < 1:
                raise ValueError(
                    f"cohort_chunk={self.cohort_chunk} must be >= 1: it is "
                    "the number of clients the chunked executor vmaps per "
                    "streaming slice (a ragged final chunk is padded with "
                    "zero-weight clients, never truncated)")
            if self.cohort_strategy == "scan":
                raise ValueError(
                    f"cohort_chunk={self.cohort_chunk} together with "
                    "cohort_strategy='scan' is ambiguous: scan IS the "
                    "chunked streaming core pinned at chunk=1 (one client "
                    "alive at a time). Drop cohort_chunk to keep scan, or "
                    "drop cohort_strategy='scan' (keep the default 'vmap' "
                    "or set 'chunked') so cohort_chunk selects the slice "
                    "size.")
        elif self.cohort_strategy == "chunked":
            raise ValueError(
                "cohort_strategy='chunked' needs cohort_chunk set: the "
                "chunked executor streams the cohort in cohort_chunk-client "
                "slices. Set e.g. cohort_chunk=8, or use cohort_strategy="
                "'vmap' / 'scan'.")
        assert self.local_steps >= 1
        assert self.local_epochs >= 1
        if not 0.0 < self.participation <= 1.0:
            raise ValueError(
                f"participation={self.participation} must be in (0, 1]: it "
                "is the per-round probability a sampled client reports")
        if self.meta_mode not in ("post", "through_aggregation"):
            # ValueError, not assert: a typo'd mode under python -O would
            # otherwise silently fall through to meta_mode='post' behavior
            raise ValueError(
                f"unknown meta_mode {self.meta_mode!r}; expected 'post' or "
                "'through_aggregation'")
        if self.meta_mode == "through_aggregation":
            # The mode is a *capability the server engine declares*
            # (repro.core.engines); make_federated_round re-checks against
            # the resolved engine, but fail at config time too so the
            # combination is loud in any interpreter mode.
            from repro.core.engines import resolve_engine
            eng = resolve_engine(self)
            if "through_aggregation" not in eng.meta_capabilities:
                raise ValueError(
                    f"meta_mode='through_aggregation' needs a server "
                    f"engine declaring the capability, but {eng.name!r} "
                    f"declares {sorted(eng.meta_capabilities)}; set "
                    "fused_update=True (the fused_flat engine's custom "
                    "VJP) or use meta_mode='post'")
            if not self.server_lr > 0:
                raise ValueError(
                    "meta_mode='through_aggregation' seeds the controllable "
                    "step size as exp(log_lr)=server_lr; server_lr must "
                    "be > 0")
        # communication-compression knobs (repro.comm) — same lazy-import
        # registry validation as the algorithm/executor fields above
        from repro.comm.codecs import get_codec
        if not 0.0 < self.topk_ratio <= 1.0:
            raise ValueError(
                f"topk_ratio={self.topk_ratio} must be in (0, 1]: it is "
                "the fraction of elements the 'topk' codec transmits")
        codec = get_codec(self.codec)(self)    # raises naming the registry
        if self.error_feedback and not codec.lossy:
            raise ValueError(
                f"error_feedback=True with codec={self.codec!r} has no "
                "compression residual to feed back; pick a lossy codec "
                f"(e.g. 'int8', 'sign1bit', 'topk') or drop error_feedback")
        if codec.lossy:
            if self.meta and self.meta_mode == "through_aggregation":
                raise ValueError(
                    f"codec={self.codec!r} cannot combine with meta_mode="
                    "'through_aggregation': the hypergradient would "
                    "differentiate through a non-differentiable quantizer "
                    "(silently treating decoded gradients as exact). Lossy "
                    "codecs are meta_mode='post' only for now — a "
                    "straight-through codec VJP is a ROADMAP follow-up.")
            from repro.core.engines import resolve_engine
            eng = resolve_engine(self)
            if "lossy" not in getattr(eng, "codec_capabilities",
                                      frozenset()):
                raise ValueError(
                    f"codec={self.codec!r} needs a server engine declaring "
                    f"the 'lossy' codec capability, but {eng.name!r} "
                    f"declares {sorted(eng.codec_capabilities)}: lossy "
                    "codecs decode into flat dtype-group buffers. Set "
                    "fused_update=True (the fused_flat engine) or use "
                    "codec='none'.")
        # fault-injection / async-runtime knobs — resolve_faults performs
        # the rate/shape validation (raises naming the bad field)
        from repro.sim.faults import resolve_faults
        resolve_faults(self)
        if self.staleness_mode not in ("none", "inv", "invsqrt"):
            raise ValueError(
                f"unknown staleness_mode {self.staleness_mode!r}; expected "
                "'none', 'inv' or 'invsqrt' (the FedBuff 1/sqrt(1+s) "
                "default)")
        if (self.async_buffer < 0 or self.async_capacity < 0
                or self.async_max_staleness < 0):
            raise ValueError(
                f"async_buffer={self.async_buffer} / async_capacity="
                f"{self.async_capacity} / async_max_staleness="
                f"{self.async_max_staleness} must be >= 0 (0 means the "
                "default: K=cohort, capacity=2*cohort, no staleness bound)")
        if self.retry_backoff < 0 or self.retry_max < 0:
            raise ValueError(
                f"retry_backoff={self.retry_backoff} / retry_max="
                f"{self.retry_max} must be >= 0")
        if self.engine == "buffered_async":
            k = self.async_buffer or self.cohort
            cap = self.async_capacity or 2 * self.cohort
            if k > cap:
                raise ValueError(
                    f"async_buffer={k} exceeds async_capacity={cap}: the "
                    "pool can never hold K deltas, so the server would "
                    "never step (deadlock). Raise async_capacity or lower "
                    "async_buffer.")
            if self.round_deadline > 0:
                raise ValueError(
                    "round_deadline is a synchronous-barrier timeout; the "
                    "buffered_async runtime has no barrier to time out — "
                    "bound lateness with async_max_staleness instead")

"""mamba2-780m [ssm] — attention-free SSD (state-space duality).
[arXiv:2405.21060]
"""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="mamba2-780m",
    family="ssm",
    num_layers=48,
    d_model=1536,
    num_heads=0,             # attention-free
    num_kv_heads=0,
    d_ff=0,                  # mamba block replaces the MLP entirely
    vocab_size=50280,
    tie_embeddings=True,
    ssm=SSMConfig(d_state=128, d_head=64, expand=2, chunk=256),
    source="arXiv:2405.21060",
)

SMOKE = ArchConfig(
    name="mamba2-780m-smoke",
    family="ssm",
    num_layers=2,
    d_model=256,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=512,
    tie_embeddings=True,
    ssm=SSMConfig(d_state=16, d_head=64, expand=2, chunk=32),
    source="reduced variant of arXiv:2405.21060",
)

"""phi3-mini-3.8b [dense] — RoPE SwiGLU GQA. [arXiv:2404.14219]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="phi3-mini-3.8b",
    family="dense",
    num_layers=32,
    d_model=3072,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=32064,
    rope_theta=10_000.0,
    # long_500k runs via an explicitly-configured sliding-window VARIANT
    # (window 8192); the base model is full-attention (see DESIGN.md §5).
    sliding_window=8192,
    source="arXiv:2404.14219",
)

SMOKE = ArchConfig(
    name="phi3-mini-3.8b-smoke",
    family="dense",
    num_layers=2,
    d_model=256,
    num_heads=4,
    num_kv_heads=4,
    d_ff=512,
    vocab_size=512,
    sliding_window=64,
    source="reduced variant of arXiv:2404.14219",
)

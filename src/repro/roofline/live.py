"""Live roofline attribution — the cost model wired onto a real run.

Until PR 10 the roofline machinery only ran inside the multi-pod dry-run
(``launch/dryrun.py``).  This module factors the compiled-program
analysis out of it so the trainer can run the same model on the round
program it is actually dispatching:

  * :func:`compiled_cost_summary` — everything one ``compiled`` object
    yields: XLA's ``cost_analysis`` FLOPs/bytes, the trip-count-aware
    HLO walk (``roofline.hlo_cost`` — XLA counts while bodies once, so
    scan-structured rounds undercount by ~trip-count without it), the
    collective schedule, and ``memory_analysis`` sizes;
  * :func:`round_roofline_event` — one ``roofline`` tracker-event
    payload per compiled round program: per-round FLOPs/bytes/collective
    bytes and the predicted compute/memory/collective seconds + rounds/s
    under the TPU-v5e hardware model (``roofline.analysis`` constants).
    The trainer appends the *measured* rounds/s from its dispatch +
    device-sync spans before emitting, so prediction and measurement sit
    in the same ``metrics.jsonl`` line.  On other backends (CI runs on
    CPU) the prediction stays a v5e what-if; the measured fields are the
    ground truth.

Event keys are pinned by ``repro.obs.schema.ROOFLINE_EVENT_KEYS``.
"""
from __future__ import annotations

import time
from typing import Any, Dict, Optional

from repro.roofline.analysis import parse_collectives, roofline_terms
from repro.roofline.hlo_cost import analyze as hlo_analyze

__all__ = ["compiled_cost_summary", "round_roofline_event"]

_MEM_ATTRS = ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes")


def compiled_cost_summary(compiled) -> Dict[str, Any]:
    """Cost-model summary of one ``jax.stages.Compiled`` program.

    ``bytes_est`` is the memory-term input: raw ``cost_analysis`` bytes
    are fusion-aware but count loop bodies once, so they are scaled by
    the FLOPs correction ratio (same loop structure), keeping
    fusion-level granularity — the convention dryrun.py established."""
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):      # jax 0.4.x: list of one dict
        cost = cost[0] if cost else {}
    xla_flops = float(cost.get("flops", 0.0))
    xla_bytes = float(cost.get("bytes accessed", 0.0))
    hlo = compiled.as_text()
    c = hlo_analyze(hlo)
    loop_ratio = c.flops / max(xla_flops, 1.0)
    memory: Dict[str, int] = {}
    try:
        mem = compiled.memory_analysis()
    except Exception:                        # noqa: BLE001 — backend-optional
        mem = None
    if mem is not None:
        for attr in _MEM_ATTRS:
            v = getattr(mem, attr, None)
            if v is not None:
                memory[attr] = int(v)
    return {
        "xla_flops": xla_flops,
        "xla_bytes_accessed": xla_bytes,
        "hlo_flops": c.flops,
        "hlo_bytes_written": c.bytes_written,
        "collective_bytes": c.collective_bytes,
        "per_collective": dict(c.per_collective),
        "collectives": parse_collectives(hlo),
        "loop_ratio": loop_ratio,
        "bytes_est": xla_bytes * max(loop_ratio, 1.0),
        "memory": memory,
    }


def round_roofline_event(jitted_fn, args, *, rounds_per_call: int = 1
                         ) -> Optional[Dict[str, Any]]:
    """AOT-compile ``jitted_fn(*args)`` (args may be ShapeDtypeStructs)
    and derive the per-round ``roofline`` event payload.  Returns None
    for callables without ``.lower`` — the sanitize path wraps the round
    in a plain checkify closure that cannot be AOT-lowered."""
    lower = getattr(jitted_fn, "lower", None)
    if lower is None:
        return None
    t0 = time.perf_counter()
    compiled = lower(*args).compile()
    s = compiled_cost_summary(compiled)
    rl = roofline_terms(s["hlo_flops"], s["bytes_est"],
                        s["collective_bytes"])
    k = max(int(rounds_per_call), 1)
    t_round = max(rl.compute_s, rl.memory_s, rl.collective_s) / k
    return {
        "rounds_per_call": k,
        "flops_per_round": s["hlo_flops"] / k,
        "bytes_per_round": s["bytes_est"] / k,
        "collective_bytes_per_round": s["collective_bytes"] / k,
        "per_collective": s["per_collective"],
        "compute_s_per_round": rl.compute_s / k,
        "memory_s_per_round": rl.memory_s / k,
        "collective_s_per_round": rl.collective_s / k,
        "bottleneck": rl.bottleneck,
        "predicted_rounds_per_s": (1.0 / t_round) if t_round > 0 else 0.0,
        "loop_ratio": s["loop_ratio"],
        "xla_flops": s["xla_flops"],
        "memory": s["memory"],
        "analysis_s": round(time.perf_counter() - t0, 4),
    }

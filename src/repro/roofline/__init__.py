"""Roofline cost modelling — trip-count-aware HLO analysis plus the
TPU-v5e hardware model.

``analysis`` holds the hardware constants and term derivation,
``hlo_cost`` the trip-count-aware HLO walker, ``live`` the wiring onto a
compiled round program (the trainer's ``roofline=True`` / ``train.py
--roofline`` hook), and ``report`` the ``python -m repro.roofline.report
<run_dir>`` CLI over an emitted ``metrics.jsonl``.
"""
from repro.roofline.analysis import (COLLECTIVE_OPS, HBM_BW, LINK_BW,
                                     PEAK_FLOPS, Roofline,
                                     model_flops_per_round,
                                     parse_collectives, roofline_terms,
                                     shape_bytes)
from repro.roofline.hlo_cost import Cost, analyze
from repro.roofline.live import compiled_cost_summary, round_roofline_event

__all__ = ["PEAK_FLOPS", "HBM_BW", "LINK_BW", "COLLECTIVE_OPS", "Roofline",
           "roofline_terms", "parse_collectives", "shape_bytes",
           "model_flops_per_round", "Cost", "analyze",
           "compiled_cost_summary", "round_roofline_event"]

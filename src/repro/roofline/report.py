"""``python -m repro.roofline.report <run_dir>`` — the roofline view of
an emitted run.

Reads the jsonl tracker's ``metrics.jsonl`` and prints the ``roofline``
event(s) the trainer emitted (``roofline=True`` / ``train.py
--roofline``) side by side with the measured phase spans: predicted
compute/memory/collective seconds per round under the TPU-v5e hardware
model, the predicted bottleneck, and predicted vs measured rounds/s.  On
non-TPU backends the prediction column is a v5e what-if; the measured
column is this machine's ground truth.
"""
from __future__ import annotations

import argparse
import os
from typing import List, Optional

from repro.obs.regress import read_jsonl

__all__ = ["main"]


def _g(v, nd=4):
    if v is None:
        return "-"
    return f"{v:.{nd}g}" if isinstance(v, float) else str(v)


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.roofline.report",
        description="Print the roofline event(s) from a run dir's "
                    "metrics.jsonl.")
    ap.add_argument("run_dir")
    args = ap.parse_args(argv)
    path = os.path.join(args.run_dir, "metrics.jsonl")
    if not os.path.isfile(path):
        print(f"{path} not found — run with --tracker jsonl --run-dir "
              f"{args.run_dir!r} --roofline")
        return 2
    events = [r for r in read_jsonl(path) if r.get("kind") == "event"]
    rooflines = [e for e in events if e.get("event") == "roofline"]
    if not rooflines:
        print(f"no roofline events in {path} — re-run with --roofline")
        return 1
    for ev in rooflines:
        k = ev.get("rounds_per_call", 1)
        print(f"roofline: rounds_per_call={k} "
              f"bottleneck={ev.get('bottleneck')} "
              f"(TPU-v5e hardware model)")
        print(f"  per-round cost     flops={_g(ev.get('flops_per_round'))} "
              f"bytes={_g(ev.get('bytes_per_round'))} "
              f"collective={_g(ev.get('collective_bytes_per_round'))}")
        print(f"  predicted terms    compute={_g(ev.get('compute_s_per_round'))}s "
              f"memory={_g(ev.get('memory_s_per_round'))}s "
              f"collective={_g(ev.get('collective_s_per_round'))}s")
        print(f"  rounds/s           predicted={_g(ev.get('predicted_rounds_per_s'))} "
              f"measured={_g(ev.get('measured_rounds_per_s'))} "
              f"(over {ev.get('rounds_measured', '-')} rounds)")
        mem = ev.get("memory") or {}
        if mem:
            print("  memory_analysis    "
                  + " ".join(f"{a.replace('_size_in_bytes', '')}="
                             f"{v:,}" for a, v in sorted(mem.items())))
        pc = ev.get("per_collective") or {}
        if pc:
            print("  per-collective     "
                  + " ".join(f"{a}={_g(v)}" for a, v in sorted(pc.items())))
        print(f"  loop_ratio={_g(ev.get('loop_ratio'))} "
              f"xla_flops={_g(ev.get('xla_flops'))} "
              f"analysis_s={_g(ev.get('analysis_s'))}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

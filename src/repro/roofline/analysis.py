"""Roofline-term derivation from compiled dry-run artifacts.

The SPMD-partitioned HLO module is a *per-device* program, so
``compiled.cost_analysis()`` FLOPs/bytes and the collective operand sizes
parsed from ``compiled.as_text()`` are per-chip quantities:

    compute term    = flops_per_chip / peak_flops_chip
    memory term     = bytes_per_chip / hbm_bw_chip
    collective term = collective_bytes_per_chip / link_bw

(equivalent to the global formulation HLO_FLOPs / (chips * peak) since
global = per_chip * chips for an SPMD program).

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI (values fixed by the assignment).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def shape_bytes(shape_str: str) -> int:
    """Sum byte sizes of every dtype[dims] occurrence in a shape string
    (handles tuple results)."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def parse_collectives(hlo_text: str) -> Dict[str, int]:
    """Sum result-shape bytes per collective op kind from (post-SPMD)
    optimized HLO text.  Result-shape bytes approximate the per-device
    payload that crosses links (all-gather result = full gathered tensor;
    all-reduce payload ~ 2x(n-1)/n of the tensor — we record raw result
    bytes and keep the convention consistent across iterations)."""
    out: Dict[str, int] = {k: 0 for k in COLLECTIVE_OPS}
    counts: Dict[str, int] = {k: 0 for k in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"^(?:ROOT\s+)?%?[\w\.\-]+\s*=\s*(.+?)\s+([\w\-]+)\(", line)
        if not m:
            continue
        shape_str, op = m.groups()
        # normalize fused variants like all-gather-start / all-reduce-done
        base = None
        for k in COLLECTIVE_OPS:
            if op == k or op.startswith(k + "-"):
                base = k
                break
        if base is None:
            continue
        if op.endswith("-done"):
            continue  # the -start op already carries the shape
        out[base] += shape_bytes(shape_str)
        counts[base] += 1
    out = {k: v for k, v in out.items() if v}
    out["_counts"] = {k: v for k, v in counts.items() if v}
    return out


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    flops_per_chip: float
    bytes_per_chip: float
    coll_bytes_per_chip: float
    bottleneck: str
    model_flops: Optional[float] = None
    flops_ratio: Optional[float] = None   # MODEL_FLOPS / (HLO_FLOPs*chips)

    def to_dict(self):
        return dataclasses.asdict(self)


def roofline_terms(flops_per_chip: float, bytes_per_chip: float,
                   coll_bytes_per_chip: float,
                   model_flops_global: Optional[float] = None,
                   chips: int = 256) -> Roofline:
    c = flops_per_chip / PEAK_FLOPS
    m = bytes_per_chip / HBM_BW
    n = coll_bytes_per_chip / LINK_BW
    terms = {"compute": c, "memory": m, "collective": n}
    bottleneck = max(terms, key=terms.get)
    ratio = None
    if model_flops_global is not None and flops_per_chip > 0:
        ratio = model_flops_global / (flops_per_chip * chips)
    return Roofline(compute_s=c, memory_s=m, collective_s=n,
                    flops_per_chip=flops_per_chip,
                    bytes_per_chip=bytes_per_chip,
                    coll_bytes_per_chip=coll_bytes_per_chip,
                    bottleneck=bottleneck,
                    model_flops=model_flops_global, flops_ratio=ratio)


def model_flops_per_round(arch, shape, fed=None) -> float:
    """MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE) per token-processing
    pass; D = tokens processed.  For the federated train step the tokens are
    processed (local_steps-1) keep-trace fwd+bwd passes + 1 evaluation
    fwd+bwd + (second-order correction ~ another fwd+bwd over the trajectory)
    — we count the *algorithmic* 6*N*D per optimization pass, with
    pass-count = local_steps for UGA and local_steps for FedAvg, + 1 meta
    pass; the dry-run compute term exposes the rest (remat, second order) as
    compiled/useful ratio."""
    n_active = arch.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        passes = (fed.local_steps if fed is not None else 2)
        meta = 1 if (fed is None or fed.meta) else 0
        # + meta batch tokens (64 sequences)
        meta_tokens = 64 * shape.seq_len * meta
        return 6.0 * n_active * (tokens * passes + meta_tokens)
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch

"""Trip-count-aware HLO cost model.

XLA's ``compiled.cost_analysis()`` counts each ``while`` body ONCE — for
scan-structured programs (our per-period layer scan, SSD chunk scan, UGA's
local-step scans) that undercounts FLOPs/bytes/collective-bytes by the trip
count (~num_layers x).  This module parses the post-SPMD optimized HLO text
into a computation call graph, extracts while-loop trip counts from the
loop-condition ``compare(counter, constant(N))`` pattern, and accumulates

  * dot FLOPs          (2 * prod(result_dims) * prod(contracting_dims)),
  * convolution FLOPs  (2 * prod(result_dims) * prod(kernel_spatial) * Cin),
  * result bytes       (write traffic ~ 1/2 of accessed bytes),
  * collective result bytes per op kind,

each multiplied through the call graph (while bodies x trip count; fusion /
call / conditional x 1).  Reduce/scatter/sort ``to_apply`` scalar bodies are
ignored.  All quantities are per-device (the SPMD module is per-device).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

from repro.roofline.analysis import COLLECTIVE_OPS, _DTYPE_BYTES

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*((?:\([^)]*\))|(?:[\w]+\[[\d,]*\]\S*))\s+([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*(?:\([^)]*\))?\s*->.*{\s*$")


def _shape_dims(shape_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype in _DTYPE_BYTES:
            out.append((dtype, [int(d) for d in dims.split(",") if d]))
    return out


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _shape_dims(shape_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class Op:
    name: str
    shape: str
    opcode: str
    rest: str


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[Op]

    def op_shapes(self) -> Dict[str, str]:
        return {o.name: o.shape for o in self.ops}


def parse_computations(hlo: str) -> Dict[str, Computation]:
    """Computation headers sit at column 0 (``%name (params...) -> ty {`` or
    ``ENTRY %name ...{``); ops are indented.  Params may be nested tuples, so
    the name is taken as the first %token."""
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in hlo.splitlines():
        if cur is None:
            if (line and not line[0].isspace()
                    and line.rstrip().endswith("{") and "->" in line):
                m = re.match(r"^(?:ENTRY\s+)?%?([\w\.\-]+)", line.strip())
                if m:
                    cur = Computation(m.group(1), [])
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_RE.match(line)
        if m:
            cur.ops.append(Op(*m.groups()))
    return comps


def _dot_flops(op: Op, shapes: Dict[str, str]) -> float:
    """2 * prod(result) * prod(contracting dims of lhs)."""
    res_dims = _shape_dims(op.shape)
    out_elems = 1
    for _, dims in res_dims:
        for d in dims:
            out_elems *= d
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
    operands = re.findall(r"%([\w\.\-]+)", op.rest)
    if not m or not operands:
        return 2.0 * out_elems  # degenerate
    lhs_shape = shapes.get(operands[0])
    if lhs_shape is None:
        return 2.0 * out_elems
    lhs_dims = _shape_dims(lhs_shape)
    if not lhs_dims:
        return 2.0 * out_elems
    dims = lhs_dims[0][1]
    k = 1
    for ci in (int(x) for x in m.group(1).split(",") if x):
        if ci < len(dims):
            k *= dims[ci]
    return 2.0 * out_elems * k


def _conv_flops(op: Op, shapes: Dict[str, str]) -> float:
    res_dims = _shape_dims(op.shape)
    out_elems = 1
    for _, dims in res_dims:
        for d in dims:
            out_elems *= d
    operands = re.findall(r"%([\w\.\-]+)", op.rest)
    if len(operands) < 2:
        return 2.0 * out_elems
    k_shape = shapes.get(operands[1])
    if not k_shape:
        return 2.0 * out_elems
    kd = _shape_dims(k_shape)[0][1]
    kelems = 1
    for d in kd:
        kelems *= d
    # flops ~ 2 * out_elems * kernel_elems / out_features (features counted
    # in out_elems already); conservative: 2 * out * prod(kernel)/out_feat
    return 2.0 * out_elems * max(kelems // max(kd[-1], 1), 1)


def _trip_count(cond: Computation) -> int:
    """Loop bound from compare(counter, constant(N)) in the condition."""
    consts: Dict[str, int] = {}
    for op in cond.ops:
        if op.opcode == "constant":
            m = re.match(r"(\d+)\)", op.rest)
            if m and op.shape.startswith("s32"):
                consts[op.name] = int(m.group(1))
    for op in cond.ops:
        if op.opcode == "compare":
            for ref in re.findall(r"%([\w\.\-]+)", op.rest):
                if ref in consts:
                    return max(consts[ref], 1)
    # fallback: largest s32 constant in the condition
    return max(consts.values(), default=1)


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes_written: float = 0.0
    collective_bytes: float = 0.0
    per_collective: Dict[str, float] = dataclasses.field(default_factory=dict)

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes_written += other.bytes_written * mult
        self.collective_bytes += other.collective_bytes * mult
        for k, v in other.per_collective.items():
            self.per_collective[k] = self.per_collective.get(k, 0.0) + v * mult


def analyze(hlo: str) -> Cost:
    comps = parse_computations(hlo)
    memo: Dict[str, Cost] = {}

    def comp_cost(name: str) -> Cost:
        if name in memo:
            return memo[name]
        memo[name] = Cost()          # break cycles defensively
        comp = comps.get(name)
        if comp is None:
            return memo[name]
        shapes = comp.op_shapes()
        total = Cost()
        for op in comp.ops:
            if op.opcode in ("parameter", "constant", "get-tuple-element",
                             "tuple"):
                continue
            total.bytes_written += _shape_bytes(op.shape)
            if op.opcode == "dot":
                total.flops += _dot_flops(op, shapes)
            elif op.opcode == "convolution":
                total.flops += _conv_flops(op, shapes)
            base = None
            for k in COLLECTIVE_OPS:
                if op.opcode == k or op.opcode.startswith(k + "-"):
                    base = k
                    break
            if base and not op.opcode.endswith("-done"):
                b = _shape_bytes(op.shape)
                total.collective_bytes += b
                total.per_collective[base] = \
                    total.per_collective.get(base, 0.0) + b
            # call graph
            if op.opcode == "while":
                mc = re.search(r"condition=%?([\w\.\-]+)", op.rest)
                mb = re.search(r"body=%?([\w\.\-]+)", op.rest)
                if mc and mb:
                    trips = _trip_count(comps[mc.group(1)]) \
                        if mc.group(1) in comps else 1
                    total.add(comp_cost(mb.group(1)), trips)
                    total.add(comp_cost(mc.group(1)), trips)
            elif op.opcode == "fusion":
                m = re.search(r"calls=%?([\w\.\-]+)", op.rest)
                if m:
                    total.add(comp_cost(m.group(1)), 1.0)
            elif op.opcode == "call":
                m = re.search(r"to_apply=%?([\w\.\-]+)", op.rest)
                if m:
                    total.add(comp_cost(m.group(1)), 1.0)
            elif op.opcode == "conditional":
                for m in re.findall(r"(?:branch_computations=\{([^}]*)\}|"
                                    r"(?:true|false)_computation=%?([\w\.\-]+))",
                                    op.rest):
                    names = (m[0].split(",") if m[0] else [m[1]])
                    for nm in names:
                        nm = nm.strip().lstrip("%")
                        if nm:
                            total.add(comp_cost(nm), 1.0)
        memo[name] = total
        return total

    entry = None
    for line in hlo.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_RE.match(line.strip())
            if m:
                entry = m.group(1)
                break
    if entry is None:
        # fall back: the computation with the most ops
        entry = max(comps, key=lambda c: len(comps[c].ops))
    return comp_cost(entry)

"""Managed checkpoint store: background saves, retention, a manifest.

``checkpoint/ckpt.py`` gives one crash-safe blob; a long run needs more —
saves that don't stall the round loop, old blobs pruned so a 10k-round
run doesn't hoard disk, and a manifest a fresh process can consult to
resume (``train.py --resume auto``).  :class:`CheckpointManager` owns a
directory:

    run_dir/checkpoints/
      manifest.json            {"steps": [...], "latest": N, ...}
      step_00000040.msgpack    one atomic ckpt.save blob per retained step

Threading model: :meth:`save` snapshots the (possibly donated) device
state to host synchronously — ``np.array(copy=True)`` per leaf plus a
deep copy of ``extra``, the only parts that must happen before the
trainer re-dispatches, since the next round's donation invalidates the
device buffers and the caller keeps mutating live containers (e.g. the
trainer's growing ``history`` list) — then hands serialization +
manifest + pruning to a single daemon worker.  One worker means writes
land in submission order and the manifest never goes backwards.  A
worker failure is re-raised on the next :meth:`save`/:meth:`wait`/
:meth:`close` rather than dying silently, and the failed step is dropped
from the in-memory index so ``latest()`` never points at a blob that was
never written and the same step can be re-saved.

Retention: the newest ``keep_last`` saves always survive; steps divisible
by ``keep_every`` (when > 0) are permanent milestones.  Pruning rewrites
the manifest atomically (tmp + ``os.replace``) with the survivors FIRST,
then unlinks the dropped blob files, so a reader never sees a manifest
naming a half-deleted blob.
"""
from __future__ import annotations

import copy
import json
import os
import queue
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.checkpoint.ckpt import restore as ckpt_restore
from repro.checkpoint.ckpt import save as ckpt_save

PyTree = Any

__all__ = ["CheckpointManager"]

_MANIFEST = "manifest.json"


def _blob_name(step: int) -> str:
    return f"step_{step:08d}.msgpack"


class CheckpointManager:
    """Background-thread checkpoint store with retention over one
    directory.  ``keep_last`` newest saves survive pruning; steps
    divisible by ``keep_every`` (when > 0) are kept forever."""

    def __init__(self, directory: str, *, keep_last: int = 3,
                 keep_every: int = 0, background: bool = True):
        if keep_last < 1:
            raise ValueError(
                f"keep_last={keep_last} must be >= 1: retention always "
                "preserves the newest save (otherwise latest()/resume "
                "would race the pruner)")
        if keep_every < 0:
            raise ValueError(f"keep_every={keep_every} must be >= 0 "
                             "(0 disables milestone retention)")
        self.directory = directory
        self.keep_last = int(keep_last)
        self.keep_every = int(keep_every)
        os.makedirs(directory, exist_ok=True)
        self._manifest = self._read_manifest()
        self._background = bool(background)
        self._queue: "queue.Queue" = queue.Queue()
        # (step, exception) of a failed background write, surfaced on the
        # next save()/wait()/close()
        self._error: Optional[Tuple[int, BaseException]] = None
        self._worker: Optional[threading.Thread] = None
        self._closed = False
        if self._background:
            self._worker = threading.Thread(target=self._drain,
                                            name="ckpt-manager",
                                            daemon=True)
            self._worker.start()

    # ---- public API -------------------------------------------------------
    def save(self, step: int, tree: PyTree,
             extra: Optional[Dict[str, Any]] = None) -> None:
        """Snapshot ``tree`` to host NOW (safe against donation: the
        caller may re-dispatch immediately) and schedule the blob write.
        ``step`` must be strictly increasing across saves."""
        self._raise_pending()
        if self._closed:
            raise RuntimeError("CheckpointManager is closed; create a new "
                               "one to keep saving")
        steps = self._manifest["steps"]
        if steps and step <= steps[-1]:
            raise ValueError(
                f"checkpoint step {step} is not after the last saved step "
                f"{steps[-1]}; the manager orders blobs by step — resuming "
                "into an earlier round needs a fresh directory")
        # np.array(copy=True), not np.asarray: asarray can return a
        # zero-copy VIEW of the device buffer (CPU jax, numpy leaves) and
        # the trainer donates that buffer into the next dispatch — the
        # background writer would then serialize freed/overwritten memory
        host = jax.tree.map(lambda x: np.array(x, copy=True), tree)
        # deep copy, not dict(): a shallow copy still aliases nested
        # containers the caller keeps mutating (the trainer passes its live
        # history list) — the worker would serialize rows appended AFTER
        # this save, and a resume would replay/duplicate them
        snapshot = copy.deepcopy(extra) if extra else {}
        if self._background:
            self._queue.put((step, host, snapshot))
        else:
            self._write(step, host, snapshot)
        # manifest mirror is updated eagerly so latest() reflects pending
        # saves; the on-disk manifest lands when the worker writes the blob
        steps.append(int(step))

    def latest(self) -> Optional[int]:
        """Newest saved (or save-pending) step, or None for an empty
        store.  A fresh process sees the on-disk manifest."""
        steps = self._manifest["steps"]
        return steps[-1] if steps else None

    def path(self, step: int) -> str:
        return os.path.join(self.directory, _blob_name(step))

    def restore_latest(self, like: PyTree
                       ) -> Optional[Tuple[PyTree, Dict[str, Any], int]]:
        """``(tree, extra, step)`` for the newest blob, or None when the
        store is empty.  Drains pending writes first, so a just-saved
        step is restorable immediately."""
        self.wait()
        step = self.latest()
        if step is None:
            return None
        tree, extra = ckpt_restore(self.path(step), like)
        return tree, extra, step

    def wait(self) -> None:
        """Block until every queued save is on disk; re-raise a worker
        failure."""
        if self._background:
            self._queue.join()
        self._raise_pending()

    def close(self) -> None:
        """Drain and stop the worker (idempotent)."""
        if self._closed:
            return
        self.wait()
        self._closed = True
        if self._worker is not None:
            self._queue.put(None)
            self._worker.join()
            self._worker = None
        self._raise_pending()

    def saved_steps(self) -> List[int]:
        """Steps currently retained on disk (post-pruning view)."""
        return list(self._read_manifest()["steps"])

    def _raise_pending(self) -> None:
        if self._error is not None:
            (step, e), self._error = self._error, None
            raise RuntimeError(
                f"a background checkpoint write failed for step {step}; "
                "the round loop continued past it, and the step was dropped "
                "from the store (latest() now names the newest blob actually "
                "on disk) — save that step again, or treat the run as "
                f"unresumable from it ({type(e).__name__}: {e})"
            ) from e

    # ---- worker side ------------------------------------------------------
    def _drain(self) -> None:
        while True:
            item = self._queue.get()
            if item is None:
                self._queue.task_done()
                return
            step, host, extra = item
            try:
                self._write(step, host, extra)
            except BaseException as e:  # surfaced on next save/wait/close
                self._error = (step, e)
                # drop the phantom from the eager mirror: the blob never
                # landed, so latest()/restore_latest() must not name it and
                # the monotonicity check must allow re-saving the step
                # (list ops are atomic under the GIL, so this is safe
                # against the main thread's append)
                try:
                    self._manifest["steps"].remove(step)
                except ValueError:
                    pass
            finally:
                self._queue.task_done()

    def _write(self, step: int, host: PyTree,
               extra: Dict[str, Any]) -> None:
        ckpt_save(self.path(step), host, extra=extra)
        m = self._read_manifest()
        if step not in m["steps"]:
            m["steps"] = sorted(m["steps"] + [int(step)])
        m["latest"] = m["steps"][-1]
        # manifest first, unlink second: a crash (or concurrent reader)
        # between the two sees a manifest whose every named blob exists
        dropped = self._prune_manifest(m)
        self._write_manifest(m)
        for s in dropped:
            try:
                os.remove(self.path(s))
            except FileNotFoundError:
                pass

    def _prune_manifest(self, m: Dict[str, Any]) -> List[int]:
        """Shrink ``m["steps"]`` to the retention set; return the dropped
        steps (whose blobs the caller unlinks AFTER the manifest lands)."""
        steps = m["steps"]
        keep = set(steps[-self.keep_last:])
        if self.keep_every > 0:
            keep |= {s for s in steps if s % self.keep_every == 0}
        dropped = [s for s in steps if s not in keep]
        m["steps"] = sorted(keep)
        return dropped

    # ---- manifest ---------------------------------------------------------
    def _read_manifest(self) -> Dict[str, Any]:
        p = os.path.join(self.directory, _MANIFEST)
        if not os.path.exists(p):
            return {"version": 1, "steps": [], "latest": None,
                    "keep_last": self.keep_last,
                    "keep_every": self.keep_every}
        with open(p, "r", encoding="utf-8") as f:
            m = json.load(f)
        m.setdefault("steps", [])
        return m

    def _write_manifest(self, m: Dict[str, Any]) -> None:
        m["keep_last"] = self.keep_last
        m["keep_every"] = self.keep_every
        p = os.path.join(self.directory, _MANIFEST)
        tmp = f"{p}.tmp.{os.getpid()}"
        with open(tmp, "w", encoding="utf-8") as f:
            json.dump(m, f, indent=1)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, p)

    def __enter__(self) -> "CheckpointManager":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

from repro.checkpoint.ckpt import restore, save
from repro.checkpoint.manager import CheckpointManager

__all__ = ["save", "restore", "CheckpointManager"]

"""Msgpack pytree checkpointing (offline container: no orbax).

Arrays are flattened to a path->(dtype, shape, bytes) table; any pytree of
jnp/np arrays round-trips.  Sharded arrays are gathered to host before
serialization (single-process container) — on a real pod this module would
be replaced by per-shard writes keyed by ``jax.process_index()``; the layout
(one blob per leaf path) is chosen so that switch is mechanical.
"""
from __future__ import annotations

import os
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

PyTree = Any
_SEP = "/"


def _flatten(tree: PyTree) -> Dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        out[key] = np.asarray(leaf)
    return out


def save(path: str, tree: PyTree, *, extra: Dict[str, Any] | None = None):
    """Crash-safe write: serialize to a unique temp file in the target
    directory, fsync, then atomically rename over ``path``.  A writer
    killed at ANY point leaves either the previous checkpoint or the new
    one — never a truncated blob — and no same-named temp for a concurrent
    retry to trip over (the pid-suffixed temp is cleaned up on failure)."""
    flat = _flatten(tree)
    payload = {
        "leaves": {k: {"dtype": str(v.dtype), "shape": list(v.shape),
                       "data": v.tobytes()} for k, v in flat.items()},
        "extra": extra or {},
    }
    tmp = f"{path}.tmp.{os.getpid()}"
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    try:
        with open(tmp, "wb") as f:
            f.write(msgpack.packb(payload, use_bin_type=True))
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.remove(tmp)


def restore(path: str, like: PyTree) -> Tuple[PyTree, Dict[str, Any]]:
    """Restore into the structure of ``like`` (shape/dtype validated).

    ``like`` may be any pytree the blob was saved from — including the full
    server state whose fused optimizer slots are *tuples* of flat buffers
    (``{"m": (buf, ...), ...}``); tuple positions key as their indices, so
    the tuple-structured flat layout round-trips like any dict."""
    with open(path, "rb") as f:
        blob = f.read()
    try:
        payload = msgpack.unpackb(blob, raw=False)
    except Exception as e:
        raise ValueError(
            f"checkpoint {path!r} is not a readable msgpack blob "
            f"({type(e).__name__}: {e}) — truncated or corrupted on disk. "
            "Writers rename atomically, so the PREVIOUS checkpoint (if this "
            "path was ever written successfully) was replaced whole; this "
            "file was damaged after the fact. Re-save or restore an older "
            "copy.") from e
    if not isinstance(payload, dict) or "leaves" not in payload \
            or "extra" not in payload:
        raise ValueError(
            f"checkpoint {path!r} decoded but is not a checkpoint payload: "
            f"expected a dict with 'leaves' and 'extra' keys, got "
            f"{type(payload).__name__} with keys "
            f"{sorted(payload)[:8] if isinstance(payload, dict) else '?'} — "
            "was this file written by repro.checkpoint.save?")
    leaves = payload["leaves"]
    flat_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    out = []
    for p, leaf in flat_like:
        key = _SEP.join(str(getattr(x, "key", getattr(x, "idx", x)))
                        for x in p)
        if key not in leaves:
            raise KeyError(
                f"checkpoint {path!r} has no leaf {key!r} — it was saved "
                f"from a different structure (saved leaves: "
                f"{sorted(leaves)[:8]}...).  Params-only checkpoints from "
                f"older drivers cannot resume a full server state; restore "
                f"them into bare params instead.")
        rec = leaves[key]
        try:
            arr = np.frombuffer(rec["data"],
                                dtype=rec["dtype"]).reshape(rec["shape"])
        except Exception as e:
            raise ValueError(
                f"checkpoint {path!r} leaf {key!r} is corrupt: "
                f"{len(rec.get('data', b''))} payload bytes do not decode "
                f"as dtype={rec.get('dtype')!r} shape={rec.get('shape')!r} "
                f"({type(e).__name__}: {e})") from e
        assert tuple(arr.shape) == tuple(np.shape(leaf)), (key, arr.shape)
        out.append(jnp.asarray(arr, dtype=jnp.asarray(leaf).dtype))
    return jax.tree_util.tree_unflatten(treedef, out), payload["extra"]

"""Seeded client fault injection: the traffic model of the fault-tolerant
async runtime (``repro.core.async_round``) and of the sync round-deadline
policy.

Production FL traffic is not the synchronous lockstep of the paper's
Eq. (14) round: clients crash mid-round, drop their uplink report, deliver
it rounds late, or deliver a corrupted payload, and their completion times
are heavy-tailed.  This module models all of that as **per-round streams
derived from the round rng** — :func:`fault_streams` folds a dedicated
constant out of the round key exactly like the participation mask
(``repro.core.round.participation_mask``), so

  * the streams are deterministic under the run seed,
  * they are invariant to ``rounds_per_call`` chunking (each round's key is
    ``fold_in(run_key, round_idx)`` no matter how rounds are batched), and
  * a fault-free config (``FaultConfig.active == False``) never draws from
    the fold at all, keeping historical runs bit-identical.

Fault taxonomy (per client, per round):

  * **crash** — the client dies mid-round: no local result exists at all;
  * **drop**  — local compute finishes but the uplink report is lost;
  * **delay** — the report arrives ``1..max_delay`` rounds late (the async
    pool buffers it; a sync barrier just waits, unless ``round_deadline``
    times it out);
  * **garble** — the report arrives but the payload is corrupted (scaled by
    ``U(-garble_scale, garble_scale)``).  Only the buffered-async delta
    pool models payload corruption; sync engines treat faults at the
    weight level, so profile-carried garble is zeroed there (an *explicit*
    ``fault_garble`` on a sync engine is a config error).

Latency model (for simulated-time throughput accounting and the sync
deadline): client k completes at ``Exp(stagger) + LogNormal(0,
speed_tail)`` round-units — exponential dispatch jitter (the server sees a
Poisson-like arrival superposition) plus a heavy-tail compute time — with
any delay fault added on top in whole rounds.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

# fold constant separating the fault streams from the round's client/meta
# keys and from the participation mask's fold — one registry entry per
# stream, uniqueness enforced at import time and by fedlint (FL102)
from repro.core.rngtags import FAULT_FOLD, SPEED_SEED


@dataclasses.dataclass(frozen=True)
class FaultConfig:
    """Per-round client fault rates + the latency model.  Frozen and
    hashable so round builders can close over it as a static value."""
    drop: float = 0.0           # P(uplink report lost after local compute)
    crash: float = 0.0          # P(client dies mid-round, nothing reported)
    delay: float = 0.0          # P(report arrives late)
    max_delay: int = 0          # late reports arrive U{1..max_delay} rounds late
    garble: float = 0.0         # P(delivered payload corrupted) — async only
    garble_scale: float = 4.0   # corrupted payloads scale by U(-s, s)
    speed_tail: float = 0.5     # lognormal sigma of client compute time
    stagger: float = 0.1        # Exp(stagger) dispatch jitter (Poisson arrivals)
    deadline: float = 0.0       # sync barrier timeout in simulated round-units
                                # (0: wait forever); copied from
                                # FedConfig.round_deadline by resolve_faults

    @property
    def active(self) -> bool:
        """True iff a round under this config must draw fault streams.
        Gating on this keeps fault-free rounds bit-identical to pre-fault
        builds (no extra rng folds, no extra ops in the jitted graph)."""
        return (self.drop > 0 or self.crash > 0
                or (self.delay > 0 and self.max_delay > 0)
                or self.garble > 0 or self.deadline > 0)


# named profiles selectable via FedConfig.fault_profile / --fault-profile;
# individual fault_* fields override a profile's numbers
FAULT_PROFILES = {
    "none": dict(),
    # a generally unreliable fleet: some of everything
    "flaky": dict(drop=0.08, crash=0.05, delay=0.15, max_delay=3,
                  garble=0.02, garble_scale=4.0, speed_tail=0.5),
    # the benchmark's 20%-stragglers arm: no losses, only lateness
    "stragglers": dict(delay=0.20, max_delay=4, speed_tail=1.0),
}

# (FedConfig field, FaultConfig field) pairs an explicit >= 0 value of
# which overrides the profile default
_OVERRIDES = (("fault_drop", "drop"), ("fault_crash", "crash"),
              ("fault_delay", "delay"), ("fault_max_delay", "max_delay"),
              ("fault_garble", "garble"),
              ("fault_garble_scale", "garble_scale"),
              ("fault_speed_tail", "speed_tail"))


def resolve_faults(fed) -> FaultConfig:
    """``FedConfig -> FaultConfig``: profile defaults + explicit ``fault_*``
    overrides (a negative override means "use the profile's value"), with
    the rate/shape validation that makes bad knobs loud at config time."""
    profile = getattr(fed, "fault_profile", "none")
    if profile not in FAULT_PROFILES:
        raise ValueError(
            f"unknown fault_profile {profile!r}; known profiles: "
            f"{sorted(FAULT_PROFILES)} (rates are overridable per-field "
            "via the fault_* knobs)")
    kw = dict(FAULT_PROFILES[profile])
    for fed_field, fc_field in _OVERRIDES:
        v = getattr(fed, fed_field, -1)
        if v is not None and v >= 0:
            kw[fc_field] = int(v) if fc_field == "max_delay" else float(v)
    kw["deadline"] = float(getattr(fed, "round_deadline", 0.0))
    fc = FaultConfig(**kw)
    for rate_field in ("drop", "crash", "delay", "garble"):
        rate = getattr(fc, rate_field)
        if not 0.0 <= rate <= 1.0:
            raise ValueError(
                f"fault_{rate_field}={rate} must be in [0, 1]: it is a "
                "per-client per-round probability")
    if fc.delay > 0 and fc.max_delay < 1:
        raise ValueError(
            f"fault_delay={fc.delay} > 0 needs fault_max_delay >= 1 "
            "(late reports arrive 1..max_delay rounds late), got "
            f"{fc.max_delay}")
    if fc.garble_scale <= 0 or fc.speed_tail < 0 or fc.stagger < 0:
        # the pre-fedlint message named the FaultConfig internals
        # ("garble_scale=", "speed_tail=", "stagger=") — none of which are
        # settable FedConfig fields, so the error pointed nowhere (FL302)
        raise ValueError(
            f"fault_garble_scale={fc.garble_scale} must be > 0, "
            f"fault_speed_tail={fc.speed_tail} must be >= 0, and the "
            f"dispatch stagger ({fc.stagger}; FaultConfig-only, not a "
            "FedConfig knob) must be >= 0")
    if fc.deadline < 0:
        raise ValueError(
            f"round_deadline={fc.deadline} must be >= 0 (simulated "
            "round-units the sync barrier waits before timing a client "
            "out; 0 waits forever)")
    return fc


class FaultStreams(NamedTuple):
    """One round's fault draws over the cohort (all shape ``(cohort,)``).
    ``alive`` is the float mask of clients whose report reaches the server
    at all; ``latency`` is the simulated completion time in round-units
    EXCLUDING the delay fault (add ``delay`` for arrival time)."""
    alive: jax.Array            # f32 0/1: neither crashed nor dropped
    crashed: jax.Array          # bool
    dropped: jax.Array          # bool (uplink lost; excludes crashed)
    delayed: jax.Array          # bool (among alive)
    delay: jax.Array            # int32 rounds late (0 for on-time/dead)
    garbled: jax.Array          # bool (among alive)
    garble_mult: jax.Array      # f32 payload multiplier (exactly 1.0 unless garbled)
    latency: jax.Array          # f32 completion time (round-units)


def fault_streams(rng: jax.Array, cohort: int, fc: FaultConfig
                  ) -> FaultStreams:
    """Draw one round's fault streams from the round rng.

    The fold keeps the draw independent of the client/meta splits and the
    participation mask; callers gate on ``fc.active`` so fault-free configs
    never reach this function inside a jitted round."""
    key = jax.random.fold_in(rng, FAULT_FOLD)
    (k_crash, k_drop, k_delay, k_late, k_garb, k_scale, k_speed,
     k_start) = jax.random.split(key, 8)
    crashed = jax.random.bernoulli(k_crash, fc.crash, (cohort,))
    dropped = jnp.logical_and(
        jax.random.bernoulli(k_drop, fc.drop, (cohort,)), ~crashed)
    alive_b = ~(crashed | dropped)
    delayed = jnp.logical_and(
        jax.random.bernoulli(k_delay, fc.delay, (cohort,)), alive_b)
    late = jax.random.randint(k_late, (cohort,), 1, max(fc.max_delay, 1) + 1)
    delay = jnp.where(delayed, late, 0).astype(jnp.int32)
    garbled = jnp.logical_and(
        jax.random.bernoulli(k_garb, fc.garble, (cohort,)), alive_b)
    scale = jax.random.uniform(k_scale, (cohort,), jnp.float32,
                               -fc.garble_scale, fc.garble_scale)
    # exactly 1.0 for ungarbled clients: x * 1.0 is an IEEE identity, so a
    # garble-free draw leaves every delta bit-identical
    garble_mult = jnp.where(garbled, scale, jnp.float32(1.0))
    compute = jnp.exp(fc.speed_tail
                      * jax.random.normal(k_speed, (cohort,), jnp.float32))
    start = fc.stagger * jax.random.exponential(k_start, (cohort,),
                                                jnp.float32)
    return FaultStreams(alive=alive_b.astype(jnp.float32), crashed=crashed,
                        dropped=dropped, delayed=delayed, delay=delay,
                        garbled=garbled, garble_mult=garble_mult,
                        latency=start + compute)


def client_failed_mask(fs: FaultStreams, fc: FaultConfig) -> jax.Array:
    """Bool (cohort,): clients whose report the server never observes this
    round — crashed, dropped, or (sync barrier only) past the deadline.
    The trainer's retry-with-backoff policy recomputes this host-side from
    the same round rng, so it agrees bit-for-bit with the jitted round."""
    failed = ~(fs.alive > 0)
    if fc.deadline > 0:
        late = (fs.latency + fs.delay.astype(jnp.float32)) > fc.deadline
        failed = failed | late
    return failed


def heavy_tail_speeds(seed: int, num_clients: int,
                      sigma: float = 0.5) -> np.ndarray:
    """Persistent per-client relative speeds, lognormal with median 1 —
    the host-side hook for heterogeneous fleets: attach the result as
    ``FederatedData.client_speeds`` and ``sample_round`` ships the selected
    cohort's slice for simulated-time accounting (benchmarks, deadline
    studies)."""
    rng = np.random.default_rng((seed, SPEED_SEED))
    return np.exp(sigma * rng.standard_normal(num_clients)).astype(np.float32)

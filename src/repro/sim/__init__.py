"""Client-behavior simulation (repro.sim) — the fault/latency models the
fault-tolerant runtime trains against.

:mod:`repro.sim.faults` owns the seeded per-round fault streams
(drop / crash / delay / garble) and the heavy-tail client latency model the
async engine's throughput accounting and the sync round-deadline policy
share.
"""
from repro.sim.faults import (FAULT_PROFILES, FaultConfig, FaultStreams,
                              client_failed_mask, fault_streams,
                              heavy_tail_speeds, resolve_faults)

__all__ = ["FAULT_PROFILES", "FaultConfig", "FaultStreams",
           "client_failed_mask", "fault_streams", "heavy_tail_speeds",
           "resolve_faults"]

from repro.optim.schedules import (constant, cosine, linear_scaling_lr,
                                   wsd_schedule)
from repro.optim.optimizers import adam_init, adam_step, sgd_step

__all__ = ["constant", "cosine", "wsd_schedule", "linear_scaling_lr",
           "adam_init", "adam_step", "sgd_step"]

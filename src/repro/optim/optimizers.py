"""Plain (non-federated) optimizers — used by the centralized baseline and
the serving-side fine-tune example."""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


def sgd_step(params: PyTree, grads: PyTree, lr) -> PyTree:
    return jax.tree.map(
        lambda p, g: (p.astype(jnp.float32) -
                      lr * g.astype(jnp.float32)).astype(p.dtype),
        params, grads)


def adam_init(params: PyTree) -> PyTree:
    z = lambda: jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    return {"m": z(), "v": z(), "t": jnp.zeros((), jnp.int32)}


def adam_step(params: PyTree, grads: PyTree, state: PyTree, lr, *,
              b1=0.9, b2=0.999, eps=1e-8) -> Tuple[PyTree, PyTree]:
    t = state["t"] + 1
    g32 = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], g32)
    v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], g32)
    tf = t.astype(jnp.float32)
    new = jax.tree.map(
        lambda p, mi, vi: (p.astype(jnp.float32) - lr * (mi / (1 - b1 ** tf)) /
                           (jnp.sqrt(vi / (1 - b2 ** tf)) + eps)).astype(p.dtype),
        params, m, v)
    return new, {"m": m, "v": v, "t": t}

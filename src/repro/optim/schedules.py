"""LR schedules.  Includes WSD (warmup-stable-decay) used by MiniCPM
[arXiv:2404.06395] and the linear-scaling rule [Goyal et al., 2017] the
paper applies for different local batch sizes B (§4.2.3)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine(lr: float, total_steps: int, warmup: int = 0, final_frac: float = 0.1):
    def f(step):
        step = jnp.asarray(step, jnp.float32)
        warm = jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
        prog = jnp.clip((step - warmup) / jnp.maximum(total_steps - warmup, 1),
                        0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return lr * warm * cos
    return f


def wsd_schedule(lr: float, total_steps: int, warmup_frac: float = 0.01,
                 decay_frac: float = 0.1, final_frac: float = 0.01):
    """Warmup-Stable-Decay: linear warmup, long flat stage, sharp decay
    tail — MiniCPM's schedule."""
    warm = max(int(total_steps * warmup_frac), 1)
    decay_start = int(total_steps * (1 - decay_frac))

    def f(step):
        step = jnp.asarray(step, jnp.float32)
        w = jnp.minimum(step / warm, 1.0)
        d = jnp.clip((step - decay_start) /
                     jnp.maximum(total_steps - decay_start, 1), 0.0, 1.0)
        return lr * w * (1.0 - (1.0 - final_frac) * d)
    return f


def linear_scaling_lr(base_lr: float, batch: int, base_batch: int = 64) -> float:
    """lr ~ B (Goyal et al., 2017), as the paper uses for different B."""
    return base_lr * batch / base_batch

"""Mesh construction.  Functions, not module-level constants — importing
this module never touches jax device state."""
from __future__ import annotations

import math

import jax

try:  # jax >= 0.5: explicit axis types (Auto == the pre-0.5 behavior)
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - older jax: every axis is Auto
    AxisType = None


def _mesh(shape, axes, devices):
    if AxisType is None:
        return jax.make_mesh(shape, axes, devices=devices)
    return jax.make_mesh(shape, axes, devices=devices,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """Production v5e meshes: one pod = 256 chips as (data=16, model=16);
    two pods = 512 chips as (pod=2, data=16, model=16)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, found {len(devices)} — "
            "run under XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "(launch/dryrun.py sets this automatically)")
    return _mesh(shape, axes, devices[:n])


def make_auto_mesh(model: int = 1):
    """All visible devices as one (data, model) mesh — the default for
    ``train.py --executor sharded``: the data axis (cohort sharding for the
    two-tier aggregation) takes every device the model axis doesn't."""
    n = len(jax.devices())
    if model < 1 or n % model:
        raise ValueError(
            f"model={model} must be >= 1 and divide the device count {n}")
    return _mesh((n // model, model), ("data", "model"), jax.devices())


def make_debug_mesh(data: int = 1, model: int = 1, *, pod: int = 0):
    """Small mesh for smoke tests (uses however many devices exist)."""
    if pod:
        return _mesh((pod, data, model), ("pod", "data", "model"),
                     jax.devices()[:pod * data * model])
    return _mesh((data, model), ("data", "model"),
                 jax.devices()[:data * model])

"""Serving driver: batched prefill + decode of a (federated-trained) model.

Real execution on whatever devices exist; the production-mesh serving path
is exercised shape-only by ``dryrun.py`` (decode_32k / long_500k).

Usage (CPU-scale example):
  PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m-smoke \
      --batch 4 --prompt-len 32 --gen 16 [--ckpt path]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import restore as ckpt_restore
from repro.configs import get_arch
from repro.models.model import build_model


def generate(model, params, prompts, *, gen_len: int, cache_len: int,
             temperature: float = 0.0, seed: int = 0, enc_embeds=None):
    """prompts: (B, P) int32.  Greedy (or temperature) decoding."""
    B, P = prompts.shape
    batch = {"tokens": prompts}
    if enc_embeds is not None:
        batch["enc_embeds"] = enc_embeds
    prefill = jax.jit(lambda p, b: model.prefill(p, b, cache_len=cache_len))
    decode = jax.jit(model.decode, donate_argnums=(2,))
    logits, cache = prefill(params, batch)
    key = jax.random.PRNGKey(seed)
    out = []
    # split BEFORE the first draw: categorical(key) followed by split(key)
    # would reuse the key state (fedlint FL103), correlating the first
    # token's sample with the rest of the stream
    key, sub = jax.random.split(key)
    tok = (jnp.argmax(logits, -1) if temperature == 0.0 else
           jax.random.categorical(sub, logits / temperature, axis=-1))
    out.append(tok)
    t0 = time.time()
    for _ in range(gen_len - 1):
        logits, cache = decode(params, tok, cache)
        key, sub = jax.random.split(key)
        tok = (jnp.argmax(logits, -1) if temperature == 0.0 else
               jax.random.categorical(sub, logits / temperature, axis=-1))
        out.append(tok)
    dt = time.time() - t0
    toks = jnp.stack(out, axis=1)                      # (B, gen_len)
    return toks, {"decode_s": dt,
                  "tok_per_s": B * max(gen_len - 1, 1) / max(dt, 1e-9)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--window", type=int, default=0)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    model = build_model(cfg, dtype=jnp.float32, decode_window=args.window)
    key = jax.random.PRNGKey(args.seed)
    params = model.init(key)
    if args.ckpt:
        params, extra = ckpt_restore(args.ckpt, params)
        print(f"[serve] restored {args.ckpt} ({extra})")
    rng = np.random.default_rng(args.seed)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size,
                                       (args.batch, args.prompt_len)),
                          jnp.int32)
    enc = None
    if cfg.encoder is not None:
        enc = jnp.asarray(rng.normal(0, 1, (args.batch, cfg.encoder.enc_len,
                                            cfg.encoder.enc_dim)),
                          jnp.float32)
    cache_len = (args.window if args.window
                 else args.prompt_len + args.gen + 1)
    toks, stats = generate(model, params, prompts, gen_len=args.gen,
                           cache_len=cache_len,
                           temperature=args.temperature, enc_embeds=enc)
    print(f"[serve] generated {toks.shape} tokens: "
          f"{stats['tok_per_s']:.1f} tok/s (decode {stats['decode_s']:.2f}s)")
    print("[serve] sample:", np.asarray(toks[0])[:16].tolist())


if __name__ == "__main__":
    main()

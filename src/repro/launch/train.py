"""End-to-end federated training driver.

Runs REAL federated rounds (host data pipeline -> jitted round_fn) on
whatever devices exist — a debug mesh on CPU, the production mesh on a pod.
This is the driver behind ``examples/federated_lm.py`` and the paper-claim
benchmarks.  The loop itself lives in
:class:`repro.core.trainer.FederatedTrainer`; this module only assembles
(model, FedConfig, FederatedData) from CLI flags.

``--algorithm`` accepts ANY name in the ClientAlgorithm registry
(``repro.core.algorithms``) — the built-ins (uga / fedavg / fedprox /
fednova) plus user plugins: ``--plugin my_module`` imports ``my_module``
(repeatable, importable from PYTHONPATH) BEFORE the remaining flags are
parsed, so a one-file ``register_algorithm`` / ``register_executor`` /
``register_engine`` plugin is selectable by name in the same invocation:

  PYTHONPATH=src:. python -m repro.launch.train --plugin myalgo \
      --algorithm myalgo --arch smollm-360m-smoke --rounds 3 ...

Usage (CPU-scale example):
  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m-smoke \
      --rounds 50 --cohort 4 --client-batch 8 --seq 128 --algorithm uga --meta
"""
from __future__ import annotations

import argparse
import importlib
import json
import os
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.comm import available_codecs
from repro.configs import FedConfig, get_arch
from repro.core import FederatedTrainer, available_algorithms
from repro.data.partition import partition_iid
from repro.data.pipeline import FederatedData
from repro.data.synthetic import synthetic_tokens
from repro.models.model import build_model


def build_synthetic_fed_data(cfg, *, num_clients: int, examples: int,
                             seq: int, iid: bool, seed: int = 0,
                             meta_fraction: float = 0.01) -> FederatedData:
    rng = np.random.default_rng(seed)
    ds = synthetic_tokens(rng, n=examples, seq_len=seq + 1,
                          vocab=cfg.vocab_size, num_clients=num_clients)
    arrays = {"tokens": ds.tokens}
    if iid:
        parts = partition_iid(rng, examples, num_clients)
    else:
        parts = [np.where(ds.role == c)[0] for c in range(num_clients)]
        parts = [p if p.size else np.array([0]) for p in parts]
    n_meta = max(int(examples * meta_fraction), 8)
    meta_idx = rng.choice(examples, n_meta, replace=False)
    shared_idx = rng.choice(examples, n_meta, replace=False)
    return FederatedData(arrays=arrays, client_indices=parts,
                         meta_indices=meta_idx, shared_indices=shared_idx,
                         seed=seed)


def run_training(arch: str, *, rounds: int, cohort: int, client_batch: int,
                 seq: int, algorithm: str = "uga", meta: bool = True,
                 share: bool = False, local_steps: int = 2,
                 local_epochs: int = 1, client_lr: float = 0.01,
                 server_lr: Optional[float] = None,
                 meta_lr: Optional[float] = None, server_opt: str = "sgd",
                 meta_mode: str = "post", ctrl_lr: float = 0.01,
                 participation: float = 1.0, codec: str = "none",
                 error_feedback: bool = False, topk_ratio: float = 0.01,
                 num_clients: int = 32, examples: int = 2048,
                 iid: bool = False, seed: int = 0, log_every: int = 10,
                 ckpt_path: Optional[str] = None,
                 resume: Optional[str] = None, strategy: str = "vmap",
                 cohort_chunk: Optional[int] = None,
                 executor: Optional[str] = None, mesh_model: int = 1,
                 dtype=jnp.float32, fused: bool = False,
                 rounds_per_call: int = 1, engine: Optional[str] = None,
                 async_buffer: int = 0, async_capacity: int = 0,
                 async_max_staleness: int = 0,
                 staleness_mode: str = "invsqrt",
                 fault_profile: str = "none", fault_drop: float = -1.0,
                 fault_crash: float = -1.0, fault_delay: float = -1.0,
                 fault_max_delay: int = -1, fault_garble: float = -1.0,
                 fault_garble_scale: float = -1.0,
                 round_deadline: float = 0.0, retry_backoff: int = 0,
                 sanitize: bool = False, tracker: Optional[str] = None,
                 run_dir: Optional[str] = None, profile: int = 0,
                 profile_start: int = 0, trace_summary: bool = False,
                 roofline: bool = False, ckpt_every: int = 0,
                 keep_last: int = 3, keep_every: int = 0):
    """``rounds_per_call=K``: K rounds compile into ONE donated scan program
    and metrics sync to host once per K rounds.  ``fused``: flat-buffer
    Pallas server engine (see kernels/fused_update).  ``resume``: path of a
    full-server-state checkpoint written by ``ckpt_path`` — training
    continues from its round counter toward ``rounds`` total — or
    ``"auto"``: the newest blob in ``run_dir``'s managed checkpoint store.
    ``sanitize``: debug mode — enables ``jax_debug_nans`` and re-jits the
    round under :mod:`jax.experimental.checkify` with NaN/Inf/OOB checks on
    the flat aggregate buffers (see :mod:`repro.core.sanitize`); slower,
    but a poisoned payload fails the round it appears with an error naming
    the flat dtype group.

    Observability (``repro.obs``): ``tracker`` is a registry name or comma
    list (``jsonl,console``) writing under ``run_dir``; ``profile=N``
    captures a JAX trace for rounds ``[profile_start, profile_start+N)``
    into ``run_dir/profile``.  ``trace_summary`` parses that capture
    into a ``profile_summary`` tracker event (top ops by self time,
    busy/gap, per-phase attribution) when the window closes;
    ``roofline`` emits a ``roofline`` event per compiled round program
    (trip-count-aware predicted cost + measured rounds/s — inspect with
    ``python -m repro.roofline.report <run_dir>``).  With a
    ``run_dir``, the trainer keeps a
    managed checkpoint store in ``run_dir/checkpoints`` (a save every
    ``ckpt_every`` rounds — 0: once at run end — with ``keep_last`` /
    ``keep_every`` retention)."""
    cfg = get_arch(arch)
    model = build_model(cfg, dtype=dtype, loss_chunk=256)
    fed = FedConfig(
        algorithm=algorithm, meta=meta, share=share, cohort=cohort,
        local_steps=local_steps, local_epochs=local_epochs,
        client_lr=client_lr,
        server_lr=server_lr if server_lr is not None else client_lr,
        meta_lr=meta_lr if meta_lr is not None else client_lr,
        server_opt=server_opt, meta_mode=meta_mode, ctrl_lr=ctrl_lr,
        participation=participation, codec=codec,
        error_feedback=error_feedback, topk_ratio=topk_ratio,
        cohort_strategy=strategy, cohort_chunk=cohort_chunk,
        lr_decay=0.992, fused_update=fused,
        engine=engine, async_buffer=async_buffer,
        async_capacity=async_capacity,
        async_max_staleness=async_max_staleness,
        staleness_mode=staleness_mode, fault_profile=fault_profile,
        fault_drop=fault_drop, fault_crash=fault_crash,
        fault_delay=fault_delay, fault_max_delay=fault_max_delay,
        fault_garble=fault_garble, fault_garble_scale=fault_garble_scale,
        round_deadline=round_deadline, retry_backoff=retry_backoff)
    if sanitize:
        # catch NaNs in UNsanitized code too (jit deoptimizes and re-checks
        # on a NaN output); the checkify probes stay the primary, named
        # diagnostics — debug_nans is the coarse backstop
        import jax
        jax.config.update("jax_debug_nans", True)
    data = build_synthetic_fed_data(cfg, num_clients=num_clients,
                                    examples=examples, seq=seq, iid=iid,
                                    seed=seed)
    round_kwargs = {}
    if executor == "sharded":
        # two-tier aggregation over every visible device: the cohort axis
        # splits across the mesh data axis, each shard streams its clients
        # through the chunked core, one psum reduces the partials
        import jax
        from repro.launch.mesh import make_auto_mesh
        from repro.sharding.specs import cohort_grad_shardings
        mesh = make_auto_mesh(mesh_model)
        params_shape = jax.eval_shape(
            model.init, jax.random.PRNGKey(seed))
        round_kwargs["grad_shardings"] = cohort_grad_shardings(
            params_shape, mesh, strategy)
        print(f"[train] sharded executor on mesh "
              f"{dict(zip(mesh.axis_names, mesh.devices.shape))}")
    elif executor is not None:
        round_kwargs["executor"] = executor
    trainer = FederatedTrainer(
        model, fed, rounds_per_call=rounds_per_call, seed=seed,
        sanitize=sanitize, tracker=tracker, run_dir=run_dir,
        checkpoint_every=ckpt_every if run_dir is not None else None,
        keep_last=keep_last, keep_every=keep_every, profile=profile,
        profile_start=profile_start, trace_summary=trace_summary,
        roofline=roofline, **round_kwargs)
    if resume == "auto":
        if run_dir is None:
            raise ValueError(
                "--resume auto reads the managed checkpoint store and "
                "needs --run-dir; pass an explicit checkpoint path "
                "otherwise")
        step = trainer.resume_latest()
        print(f"[train] resume auto: "
              + (f"round {step} from {run_dir}/checkpoints" if step
                 is not None else "empty store, starting fresh"))
    elif resume:
        extra = trainer.restore(resume)
        print(f"[train] resumed {resume} at round {trainer.round} "
              f"(saved by arch={extra.get('arch')})")
    meta_bs = min(client_batch * 2, 32)
    history = trainer.run(data, rounds=rounds, cohort=cohort,
                          batch=client_batch, meta_batch=meta_bs,
                          share=share, log_every=log_every)
    if ckpt_path:
        trainer.save(ckpt_path, extra={"arch": arch, "rounds": rounds,
                                       "algorithm": algorithm})
        print(f"[train] saved server state to {ckpt_path}")
    trainer.finish()
    return trainer.state, history


def main():
    # --plugin modules must import (and hit the registries) before the
    # main parser freezes --algorithm's choices
    pre = argparse.ArgumentParser(add_help=False)
    pre.add_argument("--plugin", action="append", default=[],
                     help="module to import before parsing the remaining "
                          "flags — its register_algorithm/executor/engine "
                          "calls make the names selectable (repeatable)")
    plug_args, _ = pre.parse_known_args()
    for mod in plug_args.plugin:
        importlib.import_module(mod)

    ap = argparse.ArgumentParser(parents=[pre])
    ap.add_argument("--arch", required=True)
    ap.add_argument("--rounds", type=int, default=50)
    ap.add_argument("--cohort", type=int, default=4)
    ap.add_argument("--client-batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--algorithm", default="uga",
                    choices=list(available_algorithms()),
                    help="any registered client algorithm "
                         "(repro.core.algorithms)")
    ap.add_argument("--meta", action="store_true")
    ap.add_argument("--no-meta", dest="meta", action="store_false")
    ap.set_defaults(meta=True)
    ap.add_argument("--share", action="store_true")
    ap.add_argument("--local-steps", type=int, default=2)
    ap.add_argument("--local-epochs", type=int, default=1,
                    help="E: passes over the local microbatch schedule")
    ap.add_argument("--client-lr", type=float, default=0.01)
    ap.add_argument("--server-lr", type=float, default=None,
                    help="eta_g (default: --client-lr); applied for "
                         "true-gradient algorithms (uga/fednova) and any "
                         "non-SGD server optimizer")
    ap.add_argument("--meta-lr", type=float, default=None,
                    help="eta_meta (default: --client-lr)")
    ap.add_argument("--server-opt", default="sgd",
                    choices=["sgd", "sgdm", "adam", "yogi"])
    ap.add_argument("--strategy", default="vmap",
                    help="cohort executor: client-parallel vmap, "
                         "client-sequential scan, or any registered "
                         "executor name")
    ap.add_argument("--cohort-chunk", type=int, default=None,
                    help="stream the cohort through the chunked executor "
                         "in slices of this many clients — peak gradient "
                         "memory is one chunk, results are bit-identical "
                         "for every chunk size")
    ap.add_argument("--executor", default=None,
                    help="cohort-executor registry name; 'sharded' builds "
                         "a (data, model) mesh over all visible devices "
                         "and runs the two-tier shard_map aggregation")
    ap.add_argument("--mesh-model", type=int, default=1,
                    help="model-axis size of the --executor sharded mesh "
                         "(the data axis takes the remaining devices)")
    ap.add_argument("--meta-mode", default="post",
                    choices=["post", "through_aggregation"],
                    help="FedMeta step: post-aggregation parameter step, or "
                         "hypergradients through the aggregation (needs an "
                         "engine with the capability, i.e. --fused)")
    ap.add_argument("--ctrl-lr", type=float, default=0.01,
                    help="controllable-weights step size "
                         "(--meta-mode through_aggregation)")
    ap.add_argument("--participation", type=float, default=1.0,
                    help="<1: straggler dropout — per-round probability a "
                         "sampled client reports; dropped clients' weights "
                         "are zeroed inside the aggregation")
    ap.add_argument("--codec", default="none",
                    choices=list(available_codecs()),
                    help="client->server uplink gradient codec "
                         "(repro.comm); lossy codecs need --fused")
    ap.add_argument("--error-feedback", action="store_true",
                    help="keep per-client compression residuals "
                         "(state['comm']) and re-add them before each "
                         "round's encode (needs a lossy --codec)")
    ap.add_argument("--topk-ratio", type=float, default=0.01,
                    help="fraction of elements the 'topk' codec ships")
    ap.add_argument("--num-clients", type=int, default=32)
    ap.add_argument("--log-every", type=int, default=10,
                    help="print a history record every N rounds (0: quiet)")
    ap.add_argument("--examples", type=int, default=2048)
    ap.add_argument("--iid", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--resume", default=None,
                    help="checkpoint written by --ckpt to continue from, "
                         "or 'auto': the newest blob in --run-dir's "
                         "managed store")
    ap.add_argument("--history-out", default=None)
    from repro.obs import available_trackers
    ap.add_argument("--tracker", default=None,
                    help="metrics-tracker registry name or comma list "
                         f"(repro.obs): {', '.join(available_trackers())}; "
                         "file trackers write under --run-dir "
                         "(default: noop)")
    ap.add_argument("--run-dir", default=None,
                    help="run directory for tracker files, profiler "
                         "traces, and the managed checkpoint store")
    ap.add_argument("--profile", type=int, default=0,
                    help="capture a jax.profiler trace for N rounds into "
                         "<run-dir>/profile (0: off)")
    ap.add_argument("--profile-start", type=int, default=0,
                    help="first round of the --profile capture window")
    ap.add_argument("--trace-summary", action="store_true",
                    help="when the --profile window closes, parse the "
                         "trace into a profile_summary tracker event "
                         "(top ops by self time, busy/gap, per-phase "
                         "attribution); needs --profile N")
    ap.add_argument("--roofline", action="store_true",
                    help="emit a roofline tracker event per compiled "
                         "round program: trip-count-aware predicted "
                         "compute/memory/collective cost + measured "
                         "rounds/s (python -m repro.roofline.report "
                         "<run-dir> to inspect)")
    ap.add_argument("--ckpt-every", type=int, default=0,
                    help="managed-store save period in rounds (needs "
                         "--run-dir; 0: one save at run end)")
    ap.add_argument("--keep-last", type=int, default=3,
                    help="managed store: newest saves retained")
    ap.add_argument("--keep-every", type=int, default=0,
                    help="managed store: steps divisible by N are kept "
                         "forever (0: off)")
    ap.add_argument("--fused", action="store_true",
                    help="fused flat-buffer Pallas server engine")
    ap.add_argument("--rounds-per-call", type=int, default=1,
                    help="scan K rounds into one compiled program")
    from repro.core import available_engines
    from repro.sim.faults import FAULT_PROFILES
    ap.add_argument("--engine", default=None,
                    choices=list(available_engines()),
                    help="server-engine registry name (default derives "
                         "legacy_tree/fused_flat from --fused); "
                         "'buffered_async' selects the fault-tolerant "
                         "buffered asynchronous runtime")
    ap.add_argument("--async-buffer", type=int, default=0,
                    help="buffered_async: server steps every K arrived "
                         "deltas (0: cohort)")
    ap.add_argument("--async-capacity", type=int, default=0,
                    help="buffered_async: delta-pool slots (0: 2*cohort)")
    ap.add_argument("--async-max-staleness", type=int, default=0,
                    help="buffered_async: evict deltas staler than this "
                         "many server versions (0: unbounded)")
    ap.add_argument("--staleness-mode", default="invsqrt",
                    choices=["none", "inv", "invsqrt"],
                    help="flush-weight discount of stale deltas")
    ap.add_argument("--fault-profile", default="none",
                    choices=sorted(FAULT_PROFILES),
                    help="named client-fault profile (repro.sim.faults); "
                         "--fault-* flags override individual rates")
    ap.add_argument("--fault-drop", type=float, default=-1.0,
                    help="P(uplink report lost); <0 uses the profile")
    ap.add_argument("--fault-crash", type=float, default=-1.0,
                    help="P(client dies mid-round); <0 uses the profile")
    ap.add_argument("--fault-delay", type=float, default=-1.0,
                    help="P(report arrives rounds late); <0 uses the "
                         "profile")
    ap.add_argument("--fault-max-delay", type=int, default=-1,
                    help="late reports land 1..N rounds late; <0 uses the "
                         "profile")
    ap.add_argument("--fault-garble", type=float, default=-1.0,
                    help="P(payload corrupted) — buffered_async only; <0 "
                         "uses the profile")
    ap.add_argument("--fault-garble-scale", type=float, default=-1.0,
                    help="corrupted payloads scale by U(-s, s); <0 uses "
                         "the profile")
    ap.add_argument("--sanitize", action="store_true",
                    help="debug mode: jax_debug_nans + a checkify-wrapped "
                         "round with NaN/Inf/OOB checks on the flat "
                         "aggregate buffers (repro.core.sanitize)")
    ap.add_argument("--round-deadline", type=float, default=0.0,
                    help="sync barrier timeout in simulated round-units "
                         "(0: wait forever)")
    ap.add_argument("--retry-backoff", type=int, default=0,
                    help=">0: re-enqueue failed clients after "
                         "backoff * 2^attempt rounds")
    args = ap.parse_args()
    state, history = run_training(
        args.arch, rounds=args.rounds, cohort=args.cohort,
        client_batch=args.client_batch, seq=args.seq,
        algorithm=args.algorithm, meta=args.meta, share=args.share,
        local_steps=args.local_steps, local_epochs=args.local_epochs,
        client_lr=args.client_lr, server_lr=args.server_lr,
        meta_lr=args.meta_lr, server_opt=args.server_opt,
        meta_mode=args.meta_mode, ctrl_lr=args.ctrl_lr,
        participation=args.participation, codec=args.codec,
        error_feedback=args.error_feedback, topk_ratio=args.topk_ratio,
        strategy=args.strategy, cohort_chunk=args.cohort_chunk,
        executor=args.executor, mesh_model=args.mesh_model,
        num_clients=args.num_clients,
        log_every=args.log_every,
        examples=args.examples, iid=args.iid, seed=args.seed,
        ckpt_path=args.ckpt, resume=args.resume, fused=args.fused,
        rounds_per_call=args.rounds_per_call, engine=args.engine,
        async_buffer=args.async_buffer, async_capacity=args.async_capacity,
        async_max_staleness=args.async_max_staleness,
        staleness_mode=args.staleness_mode,
        fault_profile=args.fault_profile, fault_drop=args.fault_drop,
        fault_crash=args.fault_crash, fault_delay=args.fault_delay,
        fault_max_delay=args.fault_max_delay,
        fault_garble=args.fault_garble,
        fault_garble_scale=args.fault_garble_scale,
        round_deadline=args.round_deadline,
        retry_backoff=args.retry_backoff, sanitize=args.sanitize,
        tracker=args.tracker, run_dir=args.run_dir, profile=args.profile,
        profile_start=args.profile_start,
        trace_summary=args.trace_summary, roofline=args.roofline,
        ckpt_every=args.ckpt_every,
        keep_last=args.keep_last, keep_every=args.keep_every)
    if args.history_out:
        os.makedirs(os.path.dirname(os.path.abspath(args.history_out)),
                    exist_ok=True)
        with open(args.history_out, "w") as f:
            json.dump(history, f, indent=1)


if __name__ == "__main__":
    main()

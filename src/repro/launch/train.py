"""End-to-end federated training driver.

Runs REAL federated rounds (host data pipeline -> jitted round_fn) on
whatever devices exist — a debug mesh on CPU, the production mesh on a pod.
This is the driver behind ``examples/federated_lm.py`` and the paper-claim
benchmarks.

Usage (CPU-scale example):
  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m-smoke \
      --rounds 50 --cohort 4 --client-batch 8 --seq 128 --algorithm uga --meta
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import sharding as shd
from repro.checkpoint import restore as ckpt_restore
from repro.checkpoint import save as ckpt_save
from repro.configs import FedConfig, get_arch
from repro.core import (init_server_state, RoundFnCache,
                        stack_round_inputs)
from repro.data.partition import partition_iid, partition_dirichlet
from repro.data.pipeline import FederatedData
from repro.data.synthetic import synthetic_tokens
from repro.launch.mesh import make_debug_mesh
from repro.models.model import build_model


def build_synthetic_fed_data(cfg, *, num_clients: int, examples: int,
                             seq: int, iid: bool, seed: int = 0,
                             meta_fraction: float = 0.01) -> FederatedData:
    rng = np.random.default_rng(seed)
    ds = synthetic_tokens(rng, n=examples, seq_len=seq + 1,
                          vocab=cfg.vocab_size, num_clients=num_clients)
    arrays = {"tokens": ds.tokens}
    if iid:
        parts = partition_iid(rng, examples, num_clients)
    else:
        parts = [np.where(ds.role == c)[0] for c in range(num_clients)]
        parts = [p if p.size else np.array([0]) for p in parts]
    n_meta = max(int(examples * meta_fraction), 8)
    meta_idx = rng.choice(examples, n_meta, replace=False)
    shared_idx = rng.choice(examples, n_meta, replace=False)
    return FederatedData(arrays=arrays, client_indices=parts,
                         meta_indices=meta_idx, shared_indices=shared_idx,
                         seed=seed)


def run_training(arch: str, *, rounds: int, cohort: int, client_batch: int,
                 seq: int, algorithm: str = "uga", meta: bool = True,
                 share: bool = False, local_steps: int = 2,
                 local_epochs: int = 1, client_lr: float = 0.01,
                 server_lr: Optional[float] = None,
                 meta_lr: Optional[float] = None, server_opt: str = "sgd",
                 meta_mode: str = "post", ctrl_lr: float = 0.01,
                 num_clients: int = 32, examples: int = 2048,
                 iid: bool = False, seed: int = 0, log_every: int = 10,
                 ckpt_path: Optional[str] = None,
                 resume: Optional[str] = None, strategy: str = "vmap",
                 dtype=jnp.float32, fused: bool = False,
                 rounds_per_call: int = 1):
    """``rounds_per_call=K``: K rounds compile into ONE donated scan program
    and metrics sync to host once per K rounds (the per-round ``float()``
    sync was a fixed ~ms tax per round).  ``fused``: flat-buffer Pallas
    server step (see kernels/fused_update).  ``resume``: path of a
    full-server-state checkpoint written by ``ckpt_path`` — training
    continues from its round counter toward ``rounds`` total."""
    cfg = get_arch(arch)
    model = build_model(cfg, dtype=dtype, loss_chunk=256)
    fed = FedConfig(
        algorithm=algorithm, meta=meta, share=share, cohort=cohort,
        local_steps=local_steps, local_epochs=local_epochs,
        client_lr=client_lr,
        server_lr=server_lr if server_lr is not None else client_lr,
        meta_lr=meta_lr if meta_lr is not None else client_lr,
        server_opt=server_opt, meta_mode=meta_mode, ctrl_lr=ctrl_lr,
        cohort_strategy=strategy, lr_decay=0.992, fused_update=fused)
    data = build_synthetic_fed_data(cfg, num_clients=num_clients,
                                    examples=examples, seq=seq, iid=iid,
                                    seed=seed)
    get_round_fn = RoundFnCache(model, fed)
    key = jax.random.PRNGKey(seed)
    state = init_server_state(model, fed, key)
    start_round = 0
    if resume:
        state, extra = ckpt_restore(resume, state)
        start_round = int(state["round"])
        print(f"[train] resumed {resume} at round {start_round} "
              f"(saved by arch={extra.get('arch')})")
    history = []
    t0 = time.time()
    meta_bs = min(client_batch * 2, 32)
    r = start_round
    while r < rounds:
        k = min(max(rounds_per_call, 1), rounds - r)
        samples = [data.sample_round(r + j, cohort=cohort,
                                     batch=client_batch, share=share)
                   for j in range(k)]
        # No FedMeta step -> no D_meta sampling: the round_fn never touches
        # meta_batch when fed.meta is False, so ship None (an empty pytree
        # threads through stack_round_inputs and jit untouched) instead of
        # sampling+stacking host batches every round — and sample_meta
        # would assert outright when no meta set exists.
        metas = [data.sample_meta(r + j, batch=meta_bs) if fed.meta else None
                 for j in range(k)]
        rngs = [jax.random.fold_in(key, r + j) for j in range(k)]
        if k == 1:
            state, metrics = get_round_fn(1)(
                state, jax.tree.map(jnp.asarray, samples[0]["cohort_batch"]),
                jax.tree.map(jnp.asarray, metas[0]),
                jnp.asarray(samples[0]["client_weights"]), rngs[0])
            recs = [{kk: float(v) for kk, v in metrics.items()}]
        else:
            cb, mb, wts, rks = stack_round_inputs(
                [s["cohort_batch"] for s in samples], metas,
                [s["client_weights"] for s in samples], rngs)
            state, metrics = get_round_fn(k)(state, cb, mb, wts, rks)
            recs = [{kk: float(v[j]) for kk, v in metrics.items()}
                    for j in range(k)]
        for j, rec in enumerate(recs):
            rec["round"] = r + j
            history.append(rec)
            if log_every and ((r + j) % log_every == 0
                              or r + j == rounds - 1):
                print(f"[train] round {r + j:4d} " +
                      " ".join(f"{kk}={v:.4f}" for kk, v in rec.items()
                               if kk != "round") +
                      f" ({time.time()-t0:.1f}s)")
        r += k
    if ckpt_path:
        # Full server state — params, optimizer state (incl. the fused
        # engine's tuple-structured flat buffers), the controllable-weights
        # slot when present, and the round counter — so --resume restarts
        # mid-run without losing FedOpt momentum or meta-learned weights.
        ckpt_save(ckpt_path, state,
                  extra={"arch": arch, "rounds": rounds,
                         "algorithm": algorithm})
        print(f"[train] saved server state to {ckpt_path}")
    return state, history


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--rounds", type=int, default=50)
    ap.add_argument("--cohort", type=int, default=4)
    ap.add_argument("--client-batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--algorithm", default="uga",
                    choices=["uga", "fedavg", "fedprox"])
    ap.add_argument("--meta", action="store_true")
    ap.add_argument("--no-meta", dest="meta", action="store_false")
    ap.set_defaults(meta=True)
    ap.add_argument("--share", action="store_true")
    ap.add_argument("--local-steps", type=int, default=2)
    ap.add_argument("--local-epochs", type=int, default=1,
                    help="E: passes over the local microbatch schedule")
    ap.add_argument("--client-lr", type=float, default=0.01)
    ap.add_argument("--server-lr", type=float, default=None,
                    help="eta_g (default: --client-lr); applied for UGA and "
                         "any non-SGD server optimizer")
    ap.add_argument("--meta-lr", type=float, default=None,
                    help="eta_meta (default: --client-lr)")
    ap.add_argument("--server-opt", default="sgd",
                    choices=["sgd", "sgdm", "adam", "yogi"])
    ap.add_argument("--strategy", default="vmap", choices=["vmap", "scan"],
                    help="cohort execution: client-parallel vmap or "
                         "client-sequential scan")
    ap.add_argument("--meta-mode", default="post",
                    choices=["post", "through_aggregation"],
                    help="FedMeta step: post-aggregation parameter step, or "
                         "hypergradients through the fused aggregation "
                         "(requires --fused)")
    ap.add_argument("--ctrl-lr", type=float, default=0.01,
                    help="controllable-weights step size "
                         "(--meta-mode through_aggregation)")
    ap.add_argument("--num-clients", type=int, default=32)
    ap.add_argument("--examples", type=int, default=2048)
    ap.add_argument("--iid", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--resume", default=None,
                    help="checkpoint written by --ckpt to continue from")
    ap.add_argument("--history-out", default=None)
    ap.add_argument("--fused", action="store_true",
                    help="fused flat-buffer Pallas server step")
    ap.add_argument("--rounds-per-call", type=int, default=1,
                    help="scan K rounds into one compiled program")
    args = ap.parse_args()
    state, history = run_training(
        args.arch, rounds=args.rounds, cohort=args.cohort,
        client_batch=args.client_batch, seq=args.seq,
        algorithm=args.algorithm, meta=args.meta, share=args.share,
        local_steps=args.local_steps, local_epochs=args.local_epochs,
        client_lr=args.client_lr, server_lr=args.server_lr,
        meta_lr=args.meta_lr, server_opt=args.server_opt,
        meta_mode=args.meta_mode, ctrl_lr=args.ctrl_lr,
        strategy=args.strategy, num_clients=args.num_clients,
        examples=args.examples, iid=args.iid, seed=args.seed,
        ckpt_path=args.ckpt, resume=args.resume, fused=args.fused,
        rounds_per_call=args.rounds_per_call)
    if args.history_out:
        os.makedirs(os.path.dirname(os.path.abspath(args.history_out)),
                    exist_ok=True)
        with open(args.history_out, "w") as f:
            json.dump(history, f, indent=1)


if __name__ == "__main__":
    main()

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes with ShapeDtypeStruct stand-ins (no allocation), then
record memory/cost analysis + the collective schedule for §Dry-run/§Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch phi3-mini-3.8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out artifacts/dryrun]

The XLA_FLAGS line above MUST run before any jax import (device count locks
on first init) — that is why it is the first statement of this module.
"""
import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import sharding as shd
from repro.configs import (ARCHS, SHAPES, SKIPS, FedConfig, get_arch,
                           get_shape)
from repro.core import init_server_state, make_federated_round
from repro.launch.mesh import make_production_mesh
from repro.models.model import build_model
from repro.roofline.analysis import model_flops_per_round, roofline_terms
from repro.roofline.live import compiled_cost_summary

SDS = jax.ShapeDtypeStruct

# archs whose parameter count forces the client-sequential cohort strategy
SCAN_THRESHOLD = 20e9


def pick_strategy(arch_cfg) -> str:
    return "scan" if arch_cfg.param_count() > SCAN_THRESHOLD else "vmap"


def fed_for(arch_cfg, mesh, *, algorithm="uga", meta=True,
            strategy: Optional[str] = None, local_steps=2,
            agg_dtype="float32") -> FedConfig:
    strategy = strategy or pick_strategy(arch_cfg)
    if strategy == "vmap":
        cohort = shd.specs.axis_size(mesh, shd.batch_axes(mesh))
    else:
        cohort = 16
    return FedConfig(algorithm=algorithm, meta=meta, cohort=cohort,
                     local_steps=local_steps, cohort_strategy=strategy,
                     grad_agg_dtype=agg_dtype)


def decode_window_for(arch_cfg, shape) -> int:
    """long_500k uses the sliding-window variant for dense/VLM/moe attention
    archs; jamba/mamba2 use their native constant-state / full-cache path."""
    if shape.name == "long_500k" and arch_cfg.family not in ("ssm", "hybrid"):
        return arch_cfg.sliding_window
    return 0


def _token_sds(shape, n, seq):
    return SDS((n, seq), jnp.int32)


def _enc_sds(arch_cfg, lead):
    e = arch_cfg.encoder
    return SDS(tuple(lead) + (e.enc_len, e.enc_dim), jnp.dtype(arch_cfg.dtype))


def build_train_lowerable(arch_cfg, shape, mesh, fed: FedConfig,
                          loss_chunk: int = 2048):
    """Returns (jitted_fn, example_args as ShapeDtypeStructs)."""
    model = build_model(arch_cfg, loss_chunk=loss_chunk)
    spmd_axes = (tuple(shd.batch_axes(mesh))
                 if fed.cohort_strategy == "vmap" else None)
    grad_sh = None
    if fed.cohort_strategy == "vmap":
        params_shape = jax.eval_shape(model.init, SDS((2,), jnp.uint32))
        grad_sh = shd.specs.cohort_grad_shardings(params_shape, mesh,
                                                  fed.cohort_strategy)
    round_fn = make_federated_round(model, fed, spmd_axis_name=spmd_axes,
                                    grad_shardings=grad_sh)
    cohort = fed.cohort
    per_client = shape.global_batch // cohort
    assert per_client >= fed.local_steps, (
        f"{arch_cfg.name}/{shape.name}: per-client batch {per_client} < "
        f"local_steps {fed.local_steps}")
    seq = shape.seq_len

    rng_sds = SDS((2,), jnp.uint32)
    state_shape = jax.eval_shape(
        lambda k: init_server_state(model, fed, k), rng_sds)
    state_sh = shd.state_shardings(state_shape, mesh, fed.cohort_strategy)

    cohort_batch = {"tokens": SDS((cohort, per_client, seq + 1), jnp.int32)}
    meta_batch = {"tokens": SDS((64, seq + 1), jnp.int32)}
    if arch_cfg.encoder is not None:
        cohort_batch["enc_embeds"] = _enc_sds(arch_cfg, (cohort, per_client))
        meta_batch["enc_embeds"] = _enc_sds(arch_cfg, (64,))
    cb_sh = shd.cohort_batch_shardings(cohort_batch, mesh,
                                       fed.cohort_strategy)
    mb_sh = shd.simple_batch_shardings(meta_batch, mesh)
    w_sds = SDS((cohort,), jnp.float32)
    w_sh = (shd.cohort_batch_shardings({"w": SDS((cohort, 1), jnp.float32)},
                                       mesh, fed.cohort_strategy)["w"]
            if fed.cohort_strategy == "vmap"
            else NamedSharding(mesh, P()))
    if fed.cohort_strategy == "vmap":
        w_sh = NamedSharding(mesh, P(shd.batch_axes(mesh)))
    rng_sh = NamedSharding(mesh, P())

    metrics_shape = jax.eval_shape(
        round_fn, state_shape, cohort_batch, meta_batch, w_sds, rng_sds)[1]
    fn = jax.jit(round_fn,
                 in_shardings=(state_sh, cb_sh, mb_sh, w_sh, rng_sh),
                 out_shardings=(state_sh, shd.replicated(metrics_shape, mesh)),
                 donate_argnums=(0,))
    return fn, (state_shape, cohort_batch, meta_batch, w_sds, rng_sds)


def build_prefill_lowerable(arch_cfg, shape, mesh):
    model = build_model(arch_cfg)
    B, seq = shape.global_batch, shape.seq_len
    params_shape = jax.eval_shape(model.init, SDS((2,), jnp.uint32))
    p_sh = shd.param_shardings(params_shape, mesh, "vmap")
    batch = {"tokens": _token_sds(shape, B, seq)}
    if arch_cfg.encoder is not None:
        batch["enc_embeds"] = _enc_sds(arch_cfg, (B,))
    b_sh = shd.simple_batch_shardings(batch, mesh)

    def prefill(params, batch):
        return model.prefill(params, batch)

    # the output KV cache must shard like the decode cache — otherwise it
    # is materialized replicated (~100 GB/chip at 32k, §Perf it.8)
    out_shape = jax.eval_shape(prefill, params_shape, batch)
    logits_sh = shd.simple_batch_shardings({"l": out_shape[0]}, mesh)["l"]
    cache_sh = shd.cache_shardings(out_shape[1], mesh)
    fn = jax.jit(prefill, in_shardings=(p_sh, b_sh),
                 out_shardings=(logits_sh, cache_sh))
    return fn, (params_shape, batch)


def build_decode_lowerable(arch_cfg, shape, mesh, *, window: int = 0):
    model = build_model(arch_cfg, decode_window=window)
    B, seq = shape.global_batch, shape.seq_len
    params_shape = jax.eval_shape(model.init, SDS((2,), jnp.uint32))
    p_sh = shd.param_shardings(params_shape, mesh, "vmap")
    cache_shape = jax.eval_shape(lambda: model.make_cache(B, seq))
    c_sh = shd.cache_shardings(cache_shape, mesh)
    toks = SDS((B,), jnp.int32)
    t_sh = shd.simple_batch_shardings({"t": toks}, mesh)["t"]

    def decode(params, tokens, cache):
        return model.decode(params, tokens, cache)

    fn = jax.jit(decode, in_shardings=(p_sh, t_sh, c_sh),
                 out_shardings=(None, c_sh), donate_argnums=(2,))
    return fn, (params_shape, toks, cache_shape)


def run_one(arch_name: str, shape_name: str, *, multi_pod: bool = False,
            algorithm: str = "uga", strategy: Optional[str] = None,
            local_steps: int = 2, agg_dtype: str = "float32",
            loss_chunk: int = 2048, expert_axis: Optional[str] = None,
            act_spec: str = "on", moe_impl: str = "einsum",
            verbose: bool = True) -> Dict[str, Any]:
    arch_cfg = get_arch(arch_name)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    rec: Dict[str, Any] = {
        "arch": arch_name, "shape": shape_name,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "chips": chips, "algorithm": algorithm,
    }
    fed = None
    from repro.models import moe as moe_lib
    from repro.models import transformer as tf_lib
    # expert-axis wsc hint: measured neutral-to-negative at baseline
    # (EXPERIMENTS.md §Perf) — off by default, flip via --expert-axis
    moe_lib.set_expert_axis(expert_axis)
    moe_lib.set_moe_impl(moe_impl)
    # activation-sharding hint (§Perf it.5): per-client batch over "model"
    # for the client-parallel train path (GSPMD loses it through
    # vmap+scan+custom_vjp and replicates compute otherwise)
    if shape.kind == "train" and act_spec != "off":
        strat = strategy or pick_strategy(arch_cfg)
        # vmap: per-client slice (b, S, d) -> b over "model" (cohort already
        # owns data/pod).  scan: the whole client batch (b=16, S, d) is the
        # activation -> b over "data" and S over "model" (sequence sharding;
        # b alone is not divisible by data*model).
        tf_lib.set_activation_spec(
            P("model", None, None) if strat == "vmap"
            else P("data", None, None))
    else:
        tf_lib.set_activation_spec(None)
    t0 = time.time()
    with mesh:
        if shape.kind == "train":
            fed = fed_for(arch_cfg, mesh, algorithm=algorithm,
                          strategy=strategy, local_steps=local_steps,
                          agg_dtype=agg_dtype)
            rec["cohort_strategy"] = fed.cohort_strategy
            rec["cohort"] = fed.cohort
            fn, args = build_train_lowerable(arch_cfg, shape, mesh, fed,
                                             loss_chunk=loss_chunk)
        elif shape.kind == "prefill":
            fn, args = build_prefill_lowerable(arch_cfg, shape, mesh)
        else:
            window = decode_window_for(arch_cfg, shape)
            rec["decode_window"] = window
            fn, args = build_decode_lowerable(arch_cfg, shape, mesh,
                                              window=window)
        lowered = fn.lower(*args)
        rec["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 2)

    # one compiled-program cost pass shared with the trainer's live
    # roofline hook (repro.roofline.live) — trip-count-aware HLO walk,
    # collective schedule, memory_analysis sizes
    s = compiled_cost_summary(compiled)
    if s["memory"]:
        rec["memory"] = s["memory"]
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):     # jax 0.4.x: list of one dict
        cost = cost[0] if cost else {}
    rec["cost"] = {k: float(v) for k, v in cost.items()
                   if isinstance(v, (int, float)) and (
                       "flops" in k or "bytes" in k or "utilization" not in k)}
    flops, bytes_acc = s["xla_flops"], s["xla_bytes_accessed"]

    coll = s["collectives"]
    rec["collectives"] = coll
    coll_bytes = sum(v for k, v in coll.items() if not k.startswith("_"))
    mf = model_flops_per_round(arch_cfg, shape, fed)
    rl = roofline_terms(flops, bytes_acc, coll_bytes,
                        model_flops_global=mf, chips=chips)
    rec["roofline_raw"] = rl.to_dict()
    # trip-count-aware cost model (XLA cost_analysis counts while bodies
    # once — see roofline/hlo_cost.py); this is the table-of-record.  The
    # memory term uses bytes_est: raw cost_analysis bytes scaled by the
    # flops correction ratio (same loop structure), keeping fusion-level
    # granularity
    rec["hlo_cost"] = {"flops": s["hlo_flops"],
                       "bytes_written": s["hlo_bytes_written"],
                       "collective_bytes": s["collective_bytes"],
                       "per_collective": s["per_collective"],
                       "loop_ratio": s["loop_ratio"]}
    rl2 = roofline_terms(s["hlo_flops"], s["bytes_est"],
                         s["collective_bytes"], model_flops_global=mf,
                         chips=chips)
    rec["roofline"] = rl2.to_dict()
    if verbose:
        print(f"[dryrun] {arch_name} x {shape_name} mesh={rec['mesh']} "
              f"lower={rec['lower_s']}s compile={rec['compile_s']}s "
              f"flops/chip={flops:.3e} bytes/chip={bytes_acc:.3e} "
              f"coll/chip={coll_bytes:.3e} bottleneck={rl.bottleneck}")
        if "memory" in rec:
            print(f"         memory_analysis={rec['memory']}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--algorithm", default="uga",
                    choices=["uga", "fedavg", "fedprox"])
    ap.add_argument("--strategy", default=None, choices=[None, "vmap", "scan"])
    ap.add_argument("--local-steps", type=int, default=2)
    ap.add_argument("--agg-dtype", default="float32")
    ap.add_argument("--loss-chunk", type=int, default=2048)
    ap.add_argument("--expert-axis", default=None)
    ap.add_argument("--act-spec", default="on", choices=["on", "off"])
    ap.add_argument("--moe-impl", default="einsum",
                    choices=["gather", "einsum"])
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    pairs = []
    if args.all:
        for a in ARCHS:
            for s in SHAPES:
                if (a, s) not in SKIPS:
                    pairs.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        pairs = [(args.arch, args.shape)]
    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    os.makedirs(args.out, exist_ok=True)
    failures = []
    for mp in meshes:
        for a, s in pairs:
            tag = f"{a}__{s}__{'2x16x16' if mp else '16x16'}"
            path = os.path.join(args.out, tag + ".json")
            if args.skip_existing and os.path.exists(path):
                print(f"[dryrun] skip existing {tag}")
                continue
            try:
                rec = run_one(a, s, multi_pod=mp, algorithm=args.algorithm,
                              strategy=args.strategy,
                              local_steps=args.local_steps,
                              agg_dtype=args.agg_dtype,
                              loss_chunk=args.loss_chunk,
                              expert_axis=args.expert_axis,
                              act_spec=args.act_spec,
                              moe_impl=args.moe_impl)
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
            except Exception as e:  # noqa: BLE001 — record and continue
                failures.append((tag, repr(e)))
                print(f"[dryrun] FAIL {tag}: {e}")
                traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for t, e in failures:
            print(" ", t, e)
        raise SystemExit(1)
    print("\nall dry-runs passed")


if __name__ == "__main__":
    main()

"""Paper Table 3 — ablation of UGA and FedMeta separately on the FEMNIST
stand-in (E=5, B=64): both alone beat FedAvg; combined is the upper bound."""
from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks.common import rounds_to_accuracy, run_methods
from benchmarks.table2_femnist import make_femnist_standin
from repro.configs import paper_models as pm
from repro.models.model import build_paper_cnn


def run(fast: bool = True):
    rng = np.random.default_rng(2)
    data, ds = make_femnist_standin(rng, n=1200 if fast else 4800,
                                    writers=24 if fast else 60)
    cfg = dataclasses.replace(pm.FEMNIST_CNN_SMOKE, image_size=14,
                              num_classes=10)
    model = build_paper_cnn(cfg)
    eval_idx = rng.choice(len(ds.x), 256, replace=False)
    res = run_methods(
        model, data, methods=["fedavg", "uga", "fedmeta", "fedmeta_uga"],
        rounds=150 if fast else 500, cohort=4, batch=20, local_steps=5,
        lr=0.002, uga_server_lr=0.02, eval_idx=eval_idx, eval_every=5)
    return {m: {"convergence_acc": res[m][-1]["acc"],
                "rounds_to_60": rounds_to_accuracy(res[m], 0.6)}
            for m in ("fedavg", "uga", "fedmeta", "fedmeta_uga")}

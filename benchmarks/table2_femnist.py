"""Paper Table 2 — CNN on FEMNIST (non-IID, by-writer): rounds to accuracy
milestones + convergence accuracy, FedAvg/FedShare/FedProx vs FedMeta w/UGA
(E=5, B=64).  Synthetic by-writer stand-in with strong style non-IID."""
from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks.common import rounds_to_accuracy, run_methods
from repro.configs import paper_models as pm
from repro.data.partition import partition_by_writer
from repro.data.pipeline import FederatedData
from repro.data.synthetic import synthetic_images
from repro.models.model import build_paper_cnn

MILESTONES = (0.5, 0.6, 0.7)


def make_femnist_standin(rng, *, n=2400, writers=40, classes=10, size=14):
    # severe by-writer non-IID: style shift AND Dir(0.2) label skew,
    # matching FEMNIST's character (validated regime, EXPERIMENTS.md)
    ds = synthetic_images(rng, n=n, image_size=size, channels=1,
                          num_classes=classes, num_writers=writers,
                          style_strength=1.2, label_skew_alpha=0.2,
                          noise=0.5)
    parts = partition_by_writer(ds.writer, list(range(writers)))
    parts = [p if p.size else np.array([0]) for p in parts]
    meta = rng.choice(n, max(n // 100, 24), replace=False)
    return FederatedData(arrays={"x": ds.x, "y": ds.y},
                         client_indices=parts, meta_indices=meta,
                         shared_indices=meta.copy(), seed=0), ds


def run(fast: bool = True):
    rng = np.random.default_rng(1)
    data, ds = make_femnist_standin(rng, n=1200 if fast else 4800,
                                    writers=24 if fast else 60)
    cfg = dataclasses.replace(pm.FEMNIST_CNN_SMOKE, image_size=14,
                              num_classes=10)
    model = build_paper_cnn(cfg)
    eval_idx = rng.choice(len(ds.x), 256, replace=False)
    res = run_methods(
        model, data,
        methods=["fedavg", "fedshare", "fedprox", "fedmeta_uga"],
        rounds=150 if fast else 500, cohort=4 if fast else 6,
        batch=20, local_steps=5, lr=0.002, uga_server_lr=0.02,
        eval_idx=eval_idx, eval_every=5)
    out = {}
    for m in ("fedavg", "fedshare", "fedprox", "fedmeta_uga"):
        out[m] = {
            "convergence_acc": res[m][-1]["acc"],
            **{f"rounds_to_{int(t*100)}": rounds_to_accuracy(res[m], t)
               for t in MILESTONES},
        }
    return out

"""Paper Fig. 4 — GRU char-LM on Shakespeare (non-IID roles): accuracy over
rounds for all methods (per-role Markov stand-in)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import run_methods
from repro.configs import paper_models as pm
from repro.data.partition import partition_by_writer
from repro.data.pipeline import FederatedData
from repro.data.synthetic import synthetic_chars
from repro.models.model import build_paper_gru


def run(fast: bool = True):
    rng = np.random.default_rng(3)
    n, roles = (600, 20) if fast else (3000, 100)
    ds = synthetic_chars(rng, n=n, seq_len=24 if fast else 80, vocab=60,
                         num_roles=roles)
    parts = partition_by_writer(ds.role, list(range(roles)))
    parts = [p if p.size else np.array([0]) for p in parts]
    meta = rng.choice(n, max(n // 100, 16), replace=False)
    data = FederatedData(arrays={"tokens": ds.tokens},
                         client_indices=parts, meta_indices=meta,
                         shared_indices=meta.copy(), seed=0)
    import dataclasses
    cfg = dataclasses.replace(pm.SHAKESPEARE_GRU_SMOKE, vocab_size=60,
                              embed_dim=24, hidden=64)
    model = build_paper_gru(cfg)
    eval_idx = rng.choice(n, 128, replace=False)
    res = run_methods(
        model, data,
        methods=["fedavg", "fedshare", "fedprox", "uga", "fedmeta",
                 "fedmeta_uga"],
        rounds=400 if fast else 1200, cohort=4 if fast else 10, batch=8,
        local_steps=4, lr=0.5, uga_server_lr=1.0, clip_norm=0.5,
        lr_decay=0.999, eval_idx=eval_idx, eval_every=50)
    return {m: {"convergence_acc": res[m][-1]["acc"]}
            for m in ("fedavg", "fedshare", "fedprox", "uga", "fedmeta",
                      "fedmeta_uga")}

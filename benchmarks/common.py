"""Shared federated-training benchmark loop.

Each paper table/figure benchmark builds a (model, FederatedData) pair and
calls :func:`run_methods` with the method grid from the paper:

  FedAvg | FedProx | FedShare | UGA | FedMeta | FedMeta w/ UGA

Datasets are synthetic stand-ins with the same cardinality / non-IID
structure as the paper's (offline container — see DESIGN.md §9); the
benchmark output is therefore about the paper's *relative* claims:
method ordering, rounds-to-milestone ratios, and final-accuracy gaps.
"""
from __future__ import annotations

import json
import os
import platform
import time
from typing import Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FedConfig
from repro.core import FederatedTrainer
from repro.data.pipeline import FederatedData

# method name -> FedConfig kwargs (the paper's comparison grid)
METHODS = {
    "fedavg": dict(algorithm="fedavg", meta=False, share=False),
    "fedprox": dict(algorithm="fedprox", meta=False, share=False),
    "fedshare": dict(algorithm="fedavg", meta=False, share=True),
    "uga": dict(algorithm="uga", meta=False, share=False),
    "fedmeta": dict(algorithm="fedavg", meta=True, share=False),
    "fedmeta_uga": dict(algorithm="uga", meta=True, share=False),
}


def bench_tracker(bench: str, run_dir: Optional[str] = None):
    """The benchmarks' shared metric sink: a ``jsonl`` tracker writing
    ``metrics.jsonl`` under ``benchmarks/runs/<bench>/`` (or ``run_dir``).
    Every bench script routes its per-round records and arm/report events
    through this instead of ad-hoc prints, so runs are diffable and
    machine-readable alongside the BENCH_*.json verdicts."""
    from repro.obs import resolve_tracker
    base = run_dir or os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                   "runs", bench)
    os.makedirs(base, exist_ok=True)
    return resolve_tracker("jsonl", run_dir=base)


def write_bench_report(path: str, report: Dict, *, bench: str,
                       config: Optional[Dict] = None) -> Dict:
    """THE ``BENCH_*.json`` writer — every bench script's verdict goes
    through here so the files share one schema.  Prepends a ``meta``
    stamp::

        {"meta": {"bench", "config", "host", "jax_version"}, ...report}

    which is what lets ``python -m repro.obs.compare`` refuse
    apples-to-oranges comparisons (different bench, different config)
    with a message naming the mismatched field, while only *warning* on
    host/jax_version drift (exactly what CI compares across).  Returns
    the stamped report (also printed by most callers)."""
    meta = {"bench": bench,
            "config": dict(config or report.get("config") or {}),
            "host": platform.node(),
            "jax_version": jax.__version__}
    stamped = {"meta": meta, **{k: v for k, v in report.items()
                                if k != "meta"}}
    with open(path, "w") as f:
        json.dump(stamped, f, indent=1)
    return stamped


def evaluate(model, params, data: FederatedData, idx: np.ndarray,
             batch: int = 256, loss_fn=None) -> Dict[str, float]:
    """``loss_fn``: an already-jitted ``model.loss`` — pass it when calling
    in a loop so the eval forward pass compiles once instead of retracing
    op-by-op every round."""
    if loss_fn is None:
        loss_fn = model.loss
    accs, losses, ns = [], [], []
    for b in data.eval_batches(idx, batch):
        b = jax.tree.map(jnp.asarray, b)
        l, m = loss_fn(params, b)
        n = len(jax.tree.leaves(b)[0])
        losses.append(float(l) * n)
        accs.append(float(m.get("acc", jnp.nan)) * n)
        ns.append(n)
    n = sum(ns)
    return {"loss": sum(losses) / n, "acc": sum(accs) / n}


def train_method(model, data: FederatedData, method: str, *, rounds: int,
                 cohort: int, batch: int, local_steps: int, lr: float,
                 eval_idx: np.ndarray, eval_every: int = 5, seed: int = 0,
                 lr_decay: float = 0.996, meta_batch: int = 32,
                 prox_mu: float = 2e-4, uga_server_lr: Optional[float] = None,
                 clip_norm: float = 2.0, fused: bool = True,
                 rounds_per_call: int = 4,
                 tracker=None) -> List[Dict[str, float]]:
    """uga_server_lr: eta_g for the UGA variants — defaults to
    local_steps*lr*2 so one unbiased server step has a per-round
    displacement comparable to FedAvg's local_steps biased ones (the paper
    fixes eta_g = eta and runs 500+ rounds; benchmark budgets are smaller).
    clip_norm tames the HVP amplification the paper notes in §4.5.1.

    ``rounds_per_call=K`` compiles K rounds into one donated lax.scan
    program (one dispatch + one host metric sync per K rounds); eval points
    then land on chunk boundaries instead of every ``eval_every`` exactly.
    ``fused``: flat-buffer Pallas server step (kernels/fused_update).

    The paper tables run fused + chunked by DEFAULT (fused=True,
    rounds_per_call=4): table budgets were re-validated under chunked eval
    — method orderings and rounds-to-milestone figures are unchanged
    (milestone rounds shift by at most rounds_per_call - 1 because eval
    lands on chunk-boundary rounds), and the fused engine agrees with the
    legacy path to <= 1e-5 on the smooth optimizers the tables use.  Pass
    fused=False, rounds_per_call=1 to reproduce the exact legacy loop."""
    kw = METHODS[method]
    if uga_server_lr is None:
        uga_server_lr = 2 * local_steps * lr
    fed = FedConfig(algorithm=kw["algorithm"], meta=kw["meta"],
                    share=kw["share"], cohort=cohort,
                    local_steps=local_steps, client_lr=lr,
                    server_lr=uga_server_lr,
                    meta_lr=lr, lr_decay=lr_decay, prox_mu=prox_mu,
                    clip_norm=clip_norm, fused_update=fused)
    loss_jit = jax.jit(model.loss)
    trainer = FederatedTrainer(model, fed, rounds_per_call=rounds_per_call,
                               seed=seed, tracker=tracker)

    def sample_meta(d, r, mb_size, sample):
        if not kw["meta"]:
            # round_fn never reads meta_batch when meta is off; None (an
            # empty pytree) skips the per-round sample+stack+transfer
            return None
        return d.sample_meta(r, mb_size) if d.meta_indices is not None \
            else jax.tree.map(lambda x: x[:mb_size], sample["cohort_batch"])

    history = []

    def on_records(recs, tr):
        # eval on chunk boundaries when any round in the chunk hits
        # eval_every (or training ends) — the chunked-eval schedule the
        # table budgets were re-validated under
        if any(rec["round"] % eval_every == 0 or rec["round"] == rounds - 1
               for rec in recs):
            ev = evaluate(model, tr.state["params"], data, eval_idx,
                          loss_fn=loss_jit)
            history.append({"round": recs[-1]["round"], **ev,
                            "client_loss": recs[-1]["client_loss"]})

    trainer.run(data, rounds=rounds, cohort=cohort, batch=batch,
                meta_batch=meta_batch, share=kw["share"],
                sample_meta=sample_meta, on_records=on_records)
    return history


def rounds_to_accuracy(history: Sequence[Dict], target: float) -> Optional[int]:
    for h in history:
        if h["acc"] >= target:
            return h["round"]
    return None


def run_methods(model, data, *, methods: Sequence[str], rounds: int,
                cohort: int, batch: int, local_steps: int, lr: float,
                eval_idx: np.ndarray, seed: int = 0, tracker=None, **kw
                ) -> Dict[str, List[Dict]]:
    out = {}
    for m in methods:
        if tracker is not None:
            tracker.log_event("method_start", {"method": m, "rounds": rounds})
        t0 = time.time()
        out[m] = train_method(model, data, m, rounds=rounds, cohort=cohort,
                              batch=batch, local_steps=local_steps, lr=lr,
                              eval_idx=eval_idx, seed=seed, tracker=tracker,
                              **kw)
        out[m + "__wall_s"] = time.time() - t0
        if tracker is not None:
            tracker.log_event("method_finish",
                              {"method": m, "wall_s": out[m + "__wall_s"]})
    return out


def csv_line(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"


def peak_memory_bytes(fn: Callable, *args, **kwargs) -> Dict[str, int]:
    """Peak-HBM measurement for one jittable callable.

    Primary source: ``jax.jit(fn).lower(*args).compile().memory_analysis()``
    — XLA's compiled-program accounting.  ``temp_bytes`` (scratch the
    program allocates between its inputs and outputs) is THE number for
    memory-scaling gates: argument/output sizes grow with e.g. the cohort
    by construction, while the temp footprint is what streaming/chunking
    actually bounds.  Falls back to measuring live device arrays around an
    executed call on backends whose memory analysis is unavailable
    (``temp_bytes = -1`` then, so gates can skip instead of silently
    passing on the wrong quantity).

    Returns {"temp_bytes", "argument_bytes", "output_bytes",
    "generated_code_bytes", "live_bytes"} (missing entries -1)."""
    out = {"temp_bytes": -1, "argument_bytes": -1, "output_bytes": -1,
           "generated_code_bytes": -1, "live_bytes": -1}
    try:
        compiled = jax.jit(fn).lower(*args, **kwargs).compile()
        mem = compiled.memory_analysis()
        out["temp_bytes"] = int(mem.temp_size_in_bytes)
        out["argument_bytes"] = int(mem.argument_size_in_bytes)
        out["output_bytes"] = int(mem.output_size_in_bytes)
        out["generated_code_bytes"] = int(mem.generated_code_size_in_bytes)
    except Exception:
        # fallback: run once and count live device buffers (includes the
        # inputs/outputs themselves — coarser, but monotone in the same
        # blow-ups the gates guard against)
        jax.block_until_ready(jax.jit(fn)(*args, **kwargs))
        live = 0
        for d in jax.live_arrays():
            live += d.nbytes
        out["live_bytes"] = int(live)
    return out

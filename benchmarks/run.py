"""Benchmark entry point — one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV lines (us_per_call = wall time of
the benchmark; derived = the paper-claim verdict for that table)."""
from __future__ import annotations

import argparse
import json
import os
import time


def _fmt(d, digits=3):
    if isinstance(d, dict):
        return "{" + " ".join(f"{k}:{_fmt(v)}" for k, v in d.items()) + "}"
    if isinstance(d, float):
        return f"{d:.{digits}f}"
    return str(d)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale settings (hours); default is fast mode")
    ap.add_argument("--only", default=None)
    ap.add_argument("--out", default="artifacts/bench")
    args, _ = ap.parse_known_args()
    fast = not args.full

    from benchmarks import (fig4_shakespeare, fig5_meta_overlap,
                            roofline_report, table1_cifar, table2_femnist,
                            table3_ablation)
    benches = {
        "table1_split_cifar_iid": table1_cifar.run,
        "table2_femnist_noniid": table2_femnist.run,
        "table3_ablation": table3_ablation.run,
        "fig4_shakespeare_gru": fig4_shakespeare.run,
        "fig5_meta_overlap": fig5_meta_overlap.run,
        "roofline_dryrun": roofline_report.run,
    }
    os.makedirs(args.out, exist_ok=True)
    print("name,us_per_call,derived")
    for name, fn in benches.items():
        if args.only and args.only != name:
            continue
        t0 = time.time()
        try:
            result = fn(fast=fast)
            us = (time.time() - t0) * 1e6
            print(f"{name},{us:.0f},{_fmt(result)}", flush=True)
            with open(os.path.join(args.out, name + ".json"), "w") as f:
                json.dump(result, f, indent=1, default=str)
        except Exception as e:  # noqa: BLE001
            us = (time.time() - t0) * 1e6
            print(f"{name},{us:.0f},ERROR:{e!r}", flush=True)
            raise


if __name__ == "__main__":
    main()

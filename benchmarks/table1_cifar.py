"""Paper Table 1 — CNN on split CIFAR-10 (IID): convergence accuracy of
FedAvg / FedProx / FedShare / FedMeta w/ UGA with E=2,B=64 | E=2,B=128 |
E=5,B=128 (reduced synthetic stand-in; orderings are the claim)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import run_methods
from repro.configs import paper_models as pm
from repro.data.partition import partition_iid
from repro.data.pipeline import FederatedData
from repro.data.synthetic import synthetic_images
from repro.models.model import build_paper_cnn


def _data(rng, n=1600, clients=10):
    ds = synthetic_images(rng, n=n, image_size=16, channels=3,
                          num_classes=10, num_writers=clients,
                          style_strength=0.15)
    meta = rng.choice(n, max(n // 100, 16), replace=False)
    return FederatedData(
        arrays={"x": ds.x, "y": ds.y},
        client_indices=partition_iid(rng, n, clients),
        meta_indices=meta, shared_indices=meta.copy(), seed=0), ds


def run(fast: bool = True):
    import dataclasses
    rng = np.random.default_rng(0)
    data, ds = _data(rng, n=800 if fast else 4000)
    cfg = dataclasses.replace(pm.CIFAR_CNN_SMOKE, image_size=16)
    model = build_paper_cnn(cfg)
    eval_idx = rng.choice(len(ds.x), 256, replace=False)
    settings = [("E2_B64", 2, 32)] if fast else \
        [("E2_B64", 2, 64), ("E2_B128", 2, 128), ("E5_B128", 5, 128)]
    results = {}
    for tag, E, B in settings:
        res = run_methods(
            model, data,
            methods=["fedavg", "fedprox", "fedshare", "fedmeta_uga"],
            rounds=100 if fast else 400, cohort=2, batch=max(B // 8, E * 2),
            local_steps=E, lr=0.002, uga_server_lr=0.01, eval_idx=eval_idx)
        results[tag] = {m: res[m][-1]["acc"] for m in
                        ("fedavg", "fedprox", "fedshare", "fedmeta_uga")}
    return results

"""Cohort scaling: chunked streaming aggregation holds peak memory flat.

The chunked cohort executor streams `cohort_chunk`-client slices through
the Pallas FMA accumulators, so the per-round scratch footprint is ONE
chunk of gradients no matter the cohort.  This bench measures exactly
that, with XLA's compiled-memory accounting (``benchmarks.common.
peak_memory_bytes``): the jitted round program's temp bytes at
cohort = 64 / 256 / 1024 with ``cohort_chunk`` fixed, next to the vmap
executor whose stacked-gradient footprint grows linearly.

Gates (exit non-zero on failure — CI runs ``--fast``):
  * flat memory: cohort=1024 temp bytes <= 1.3x the cohort=64 run at the
    same ``cohort_chunk`` (and the 1024-client round actually executes,
    finite loss);
  * bit identity: chunk in {1, 8, 24 (ragged), cohort} agree bitwise —
    the streaming core accumulates in global client order, so the chunk
    size can never change a round — and chunk=1 reproduces the
    pre-refactor scan streaming round bit-for-bit;
  * vmap agreement: chunk = cohort matches the pre-refactor vmap round
    <= 1e-6.  Not gated bitwise: the vmap executor's aggregate kernel
    reduces the cohort axis in XLA's reduce-tree order (pinned by the
    PR-4 frozen-reference matrix), while the streaming core adds clients
    in order.  Identical in exact arithmetic, they differ by float
    reassociation (~1 ulp of the running sum, observed ~6e-8); pinning
    both bitwise would pin XLA's reduction tree, which isn't stable
    across shapes or backends.  The bench reports the observed distance.
  * hypergradients: two-tier sharded through_aggregation ctrl state
    matches the vmap path <= 1e-5 after a round.

Usage:  PYTHONPATH=src python benchmarks/cohort_scaling.py [--fast]
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FedConfig
from repro.core import init_server_state, make_federated_round
from repro.launch.mesh import make_debug_mesh
from repro.sharding.specs import cohort_grad_shardings
from common import (bench_tracker, peak_memory_bytes,  # noqa: E402
                    write_bench_report)
from round_latency import make_mlp_model, D, CLASSES

BATCH, LOCAL_STEPS, CHUNK = 8, 2, 8


def make_fed(cohort: int, chunk=None, **kw) -> FedConfig:
    return FedConfig(algorithm="uga", meta=kw.pop("meta", False),
                     cohort=cohort, local_steps=LOCAL_STEPS,
                     client_lr=0.05, server_lr=0.1, clip_norm=1.0,
                     fused_update=True, cohort_chunk=chunk, **kw)


def make_inputs(cohort: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    batch = {"x": jnp.asarray(rng.normal(0, 1, (cohort, BATCH, D)),
                              jnp.float32),
             "y": jnp.asarray(rng.integers(0, CLASSES, (cohort, BATCH)),
                              jnp.int32)}
    wts = jnp.asarray(rng.uniform(1.0, 5.0, cohort), jnp.float32)
    return batch, wts


def round_args(model, fed, cohort: int, *, seed: int = 0, meta=None):
    batch, wts = make_inputs(cohort, seed)
    state = init_server_state(model, fed, jax.random.PRNGKey(1))
    return state, batch, meta, wts, jax.random.PRNGKey(7)


def temp_bytes(model, fed, cohort: int, **round_kw) -> int:
    rf = make_federated_round(model, fed, **round_kw)
    mem = peak_memory_bytes(rf, *round_args(model, fed, cohort))
    return mem["temp_bytes"]


def run_round(model, fed, cohort: int, **round_kw):
    rf = jax.jit(make_federated_round(model, fed, **round_kw))
    args = round_args(model, fed, cohort)
    t0 = time.perf_counter()
    state, m = rf(*args)
    jax.block_until_ready(state["params"])
    return state, m, time.perf_counter() - t0


def states_equal(a, b) -> bool:
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(la, lb))


def params_max_abs_diff(a, b) -> float:
    return max(float(jnp.max(jnp.abs(x - y))) for x, y in
               zip(jax.tree.leaves(a["params"]), jax.tree.leaves(b["params"])))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="skip the vmap contrast sweep (CI smoke)")
    ap.add_argument("--out", default="BENCH_cohort_scaling.json")
    ap.add_argument("--run-dir", default=None,
                    help="jsonl tracker dir (default: "
                         "benchmarks/runs/cohort_scaling)")
    args = ap.parse_args()
    trk = bench_tracker("cohort_scaling", args.run_dir)

    model = make_mlp_model()

    # --- memory sweep: chunked temp bytes must stay flat in the cohort ---
    trk.log_event("arm_start", {"arm": "memory_sweep"})
    cohorts = (64, 256, 1024)
    chunked_mem = {c: temp_bytes(model, make_fed(c, CHUNK), c)
                   for c in cohorts}
    mem_available = all(v >= 0 for v in chunked_mem.values())
    mem_ratio = (chunked_mem[1024] / max(chunked_mem[64], 1)
                 if mem_available else -1.0)
    # contrast: the vmap executor materialises the whole stacked cohort
    vmap_mem = ({c: temp_bytes(model, make_fed(c), c) for c in (64, 256)}
                if not args.fast else {})

    # --- the cohort=1024 round actually runs ---
    _, m1024, wall_1024 = run_round(model, make_fed(1024, CHUNK), 1024)
    loss_1024 = float(m1024["client_loss"])

    # --- bit identity at cohort=64 ---
    c = 64
    s_vmap, _, _ = run_round(model, make_fed(c), c)
    s_scan, _, _ = run_round(model, make_fed(c, cohort_strategy="scan"), c)
    s_full, _, _ = run_round(model, make_fed(c, c), c)      # chunk = cohort
    s_one, _, _ = run_round(model, make_fed(c, 1), c)
    s_mid, _, _ = run_round(model, make_fed(c, CHUNK), c)
    s_rag, _, _ = run_round(model, make_fed(c, 24), c)      # ragged 64 % 24
    bit_chunks = (states_equal(s_one, s_mid) and states_equal(s_mid, s_full)
                  and states_equal(s_rag, s_mid))
    bit_scan = states_equal(s_one, s_scan)
    vmap_err = params_max_abs_diff(s_full, s_vmap)

    # --- two-tier sharded through_aggregation ctrl vs vmap ---
    tc = 16
    fed_ta = make_fed(tc, meta=True, meta_mode="through_aggregation")
    meta_b = {"x": make_inputs(tc, 3)[0]["x"][0],
              "y": make_inputs(tc, 3)[0]["y"][0]}
    mesh = make_debug_mesh(1, 1)
    gs = cohort_grad_shardings(
        jax.eval_shape(model.init, jax.random.PRNGKey(1)), mesh)
    fed_ta_c = make_fed(tc, 4, meta=True, meta_mode="through_aggregation")

    def run_ta(fed, **kw):
        rf = jax.jit(make_federated_round(model, fed, **kw))
        state, m = rf(*round_args(model, fed, tc, meta=meta_b))
        return state

    ctrl_v = run_ta(fed_ta)["ctrl"]
    ctrl_s = run_ta(fed_ta_c, grad_shardings=gs)["ctrl"]
    hg_err = max(float(jnp.max(jnp.abs(ctrl_v[k] - ctrl_s[k])))
                 for k in ctrl_v)

    report = {
        "benchmark": "cohort_scaling",
        "config": {"model": f"mlp {D}x128x{CLASSES}", "client_batch": BATCH,
                   "local_steps": LOCAL_STEPS, "cohort_chunk": CHUNK,
                   "algorithm": "uga", "backend": jax.default_backend()},
        "chunked_temp_bytes": {str(c): chunked_mem[c] for c in cohorts},
        "vmap_temp_bytes": {str(c): v for c, v in vmap_mem.items()},
        "temp_ratio_1024_over_64": round(mem_ratio, 4),
        "round_1024": {"wall_s_incl_compile": round(wall_1024, 2),
                       "client_loss": loss_1024},
        "chunk_eq_cohort_vs_vmap_max_abs_err": vmap_err,
        "hypergrad_ctrl_max_abs_err_sharded_vs_vmap": hg_err,
        "pass_memory_flat_1p3x": bool(mem_available and mem_ratio <= 1.3),
        "pass_round_1024_finite": bool(np.isfinite(loss_1024)),
        "pass_chunk_size_invariant_bitwise": bool(bit_chunks),
        "pass_stream_eq_prerefactor_scan_bitwise": bool(bit_scan),
        "pass_chunk_eq_cohort_vs_vmap_1e6": bool(vmap_err <= 1e-6),
        "pass_hypergrad_1e5": bool(hg_err <= 1e-5),
    }
    trk.log_event("bench_report", report)
    trk.finish()
    report = write_bench_report(args.out, report, bench="cohort_scaling")
    print(json.dumps(report, indent=1))
    if not all(v for k, v in report.items() if k.startswith("pass_")):
        sys.exit(1)


if __name__ == "__main__":
    main()

"""Round-engine latency: legacy tree-map server step + per-round dispatch
vs the fused flat-buffer Pallas engine + scanned multi-round driver.

Measures the per-round hot path every benchmark table exercises
(Eq. 14 aggregate -> clip -> server optimizer -> FedMeta step) on the CPU
smoke config, end to end as the drivers actually run it: the legacy
arm dispatches one jitted round per call and syncs metrics to host every
round (exactly the old ``launch/train.py`` loop); the fused arm compiles
``rounds_per_call`` rounds into one donated ``lax.scan`` program and syncs
once per chunk.

A scan-strategy section times the client-sequential cohort the same two
ways: the legacy pytree-carry scan with per-round dispatch vs the
streaming flat-buffer accumulation (the scan carry IS the fused engine's
dtype-group buffers; kernels/fused_update ``accumulate_pass``) under the
scanned driver.

A backward section times the *differentiated* server step — the
meta-through-aggregation hypergradient d(meta loss)/d(client weights,
server lr) — through the fused engine's hand-written custom VJP vs XLA
autodiff through the legacy tree-map path, and gates their agreement.

Emits ``BENCH_round_latency.json``: rounds/s for both arms, speedup,
full-model tree traversals per server step, hypergradient steps/s for
both backward arms, and the fused-vs-legacy numerics agreement for both
directions (forward must be <= 1e-5 relative after a fresh round).

Usage:  PYTHONPATH=src python benchmarks/round_latency.py [--fast]
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from common import bench_tracker, write_bench_report
from repro.configs.base import FedConfig
from repro.core import (init_server_state, make_federated_round,
                        RoundFnCache, server_opt, stack_round_inputs,
                        weighted_mean)
from repro.core import flat as flat_mod
from repro.kernels.fused_update.ops import (TRAVERSALS_FUSED,
                                            TRAVERSALS_LEGACY,
                                            fused_server_update)
from repro.models.model import Model

# CPU smoke config: small enough to run everywhere, large enough that the
# server step and per-round dispatch overheads are both visible.
D, H, CLASSES = 64, 128, 10
COHORT, BATCH, LOCAL_STEPS = 8, 32, 2
SERVER_OPT, CLIP = "adam", 1.0
ROUNDS_PER_CALL = 8


def make_mlp_model():
    def init(k):
        k1, k2 = jax.random.split(k)
        return {"w1": jax.random.normal(k1, (D, H)) * 0.3,
                "w2": jax.random.normal(k2, (H, CLASSES)) * 0.3}

    def loss(w, batch, rng=None):
        logits = jnp.tanh(batch["x"] @ w["w1"]) @ w["w2"]
        l = -jnp.mean(jnp.take_along_axis(
            jax.nn.log_softmax(logits), batch["y"][:, None], 1))
        return l, {}

    return Model(name="bench-mlp", init=init, loss=loss)


def make_fed(fused: bool, server_opt: str = SERVER_OPT,
             strategy: str = "vmap") -> FedConfig:
    return FedConfig(algorithm="uga", meta=True, cohort=COHORT,
                     local_steps=LOCAL_STEPS, client_lr=0.05, server_lr=0.1,
                     meta_lr=0.05, server_opt=server_opt, clip_norm=CLIP,
                     cohort_strategy=strategy, fused_update=fused)


def gen_rounds(n: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    batches, metas = [], []
    for _ in range(n):
        batches.append({
            "x": jnp.asarray(rng.normal(0, 1, (COHORT, BATCH, D)),
                             jnp.float32),
            "y": jnp.asarray(rng.integers(0, CLASSES, (COHORT, BATCH)),
                             jnp.int32)})
        metas.append({"x": batches[-1]["x"][0], "y": batches[-1]["y"][0]})
    wts = jnp.asarray(rng.uniform(1.0, 5.0, COHORT), jnp.float32)
    return batches, metas, wts


def run_legacy(model, rounds: int, strategy: str = "vmap"):
    """One dispatch + one host metric sync per round (the old driver)."""
    fed = make_fed(fused=False, strategy=strategy)
    rf = jax.jit(make_federated_round(model, fed), donate_argnums=(0,))
    key = jax.random.PRNGKey(0)
    batches, metas, wts = gen_rounds(rounds)
    state = init_server_state(model, fed, key)
    state, m = rf(state, batches[0], metas[0], wts, key)   # compile
    float(m["client_loss"])
    state = init_server_state(model, fed, key)
    t0 = time.perf_counter()
    for r in range(rounds):
        state, m = rf(state, batches[r], metas[r], wts,
                      jax.random.fold_in(key, r))
        float(m["client_loss"])                            # per-round sync
    jax.block_until_ready(state["params"])
    return rounds / (time.perf_counter() - t0)


def run_fused_scanned(model, rounds: int, strategy: str = "vmap"):
    """Fused server step, K rounds per dispatch, one sync per chunk."""
    assert rounds % ROUNDS_PER_CALL == 0
    fed = make_fed(fused=True, strategy=strategy)
    rf = RoundFnCache(model, fed)(ROUNDS_PER_CALL)
    key = jax.random.PRNGKey(0)
    batches, metas, wts = gen_rounds(rounds)
    K = ROUNDS_PER_CALL
    chunks = [stack_round_inputs(
        batches[r0:r0 + K], metas[r0:r0 + K], [wts] * K,
        [jax.random.fold_in(key, r0 + j) for j in range(K)])
        for r0 in range(0, rounds, K)]
    state = init_server_state(model, fed, key)
    state, m = rf(state, *chunks[0])                       # compile
    float(m["client_loss"][-1])
    state = init_server_state(model, fed, key)
    t0 = time.perf_counter()
    for cb, mb, wK, rngs in chunks:
        state, m = rf(state, cb, mb, wK, rngs)
        float(m["client_loss"][-1])                        # per-chunk sync
    jax.block_until_ready(state["params"])
    return rounds / (time.perf_counter() - t0)


def numerics_agreement(model, server_opt: str, rounds: int = 1,
                       strategy: str = "vmap") -> float:
    """Max relative param error, fused vs legacy, after ``rounds`` rounds
    of the full pipeline (aggregate -> clip -> ``server_opt`` -> meta).

    The engines reduce in different orders (flat buffer vs per-leaf), so
    G agrees to ~1 fp32 ulp; through the smooth optimizers (sgd/sgdm) that
    stays ~1 ulp in the params — the <=1e-5 acceptance gate.  adam/yogi at
    t=1 step by ~lr*sign(g), so an ulp of difference near g=0 flips a sign
    regardless of implementation; their figure is reported informationally
    and their math is unit-tested against the legacy path on identical
    inputs in tests/test_fused_update.py."""
    key = jax.random.PRNGKey(0)
    batches, metas, wts = gen_rounds(rounds, seed=7)
    params = {}
    for fused in (False, True):
        fed = make_fed(fused, server_opt, strategy)
        rf = jax.jit(make_federated_round(model, fed))
        state = init_server_state(model, fed, key)
        for r in range(rounds):
            state, _ = rf(state, batches[r], metas[r], wts,
                          jax.random.fold_in(key, r))
        params[fused] = state["params"]
    return max(
        float(jnp.max(jnp.abs(a - b) / (jnp.abs(b) + 1e-6)))
        for a, b in zip(jax.tree.leaves(params[True]),
                        jax.tree.leaves(params[False])))


def metrics_agreement(model, server_opt: str = SERVER_OPT,
                      strategy: str = "vmap") -> float:
    """Max relative round-metric (client_loss/grad_norm/meta_loss) diff,
    fused vs legacy, one fresh round of the *benchmarked* configuration.
    The metrics are smooth in the parameters, so this gates the timed
    optimizer (adam) without the sign-step amplification above."""
    key = jax.random.PRNGKey(0)
    batches, metas, wts = gen_rounds(1, seed=7)
    out = {}
    for fused in (False, True):
        fed = make_fed(fused, server_opt, strategy)
        rf = jax.jit(make_federated_round(model, fed))
        state = init_server_state(model, fed, key)
        _, out[fused] = rf(state, batches[0], metas[0], wts, key)
    return max(abs(float(out[True][k]) - float(out[False][k]))
               / (abs(float(out[False][k])) + 1e-9)
               for k in out[False])


def _hypergrad_fns(model):
    """Jitted d(meta loss)/d(w_logits, log_lr) through one adam server step
    over a stacked cohort gradient — the through_aggregation hot path —
    via (a) the fused engine's custom VJP and (b) XLA autodiff through the
    legacy tree-map step.  Warm (t=5) state: the t=1 sign-step's weight
    hypergradient is ~0 (scale-invariant in G) and times nothing real."""
    key = jax.random.PRNGKey(11)
    params = model.init(key)
    spec = flat_mod.make_flat_spec(params)
    rng = np.random.default_rng(11)
    grads = jax.tree.map(
        lambda p: jnp.asarray(rng.normal(0, 0.5, (COHORT,) + p.shape),
                              jnp.float32), params)
    wts = jnp.asarray(rng.uniform(1.0, 5.0, COHORT), jnp.float32)
    meta = {"x": jnp.asarray(rng.normal(0, 1, (BATCH, D)), jnp.float32),
            "y": jnp.asarray(rng.integers(0, CLASSES, BATCH), jnp.int32)}
    m_tree = jax.tree.map(
        lambda p: jnp.asarray(0.3 * rng.normal(0, 1, p.shape), jnp.float32),
        params)
    v_tree = jax.tree.map(
        lambda p: jnp.asarray(0.1 + np.abs(rng.normal(0, 1, p.shape)),
                              jnp.float32), params)
    t0 = jnp.asarray(5, jnp.int32)

    def fused_loss(w_logits, log_lr):
        st = {"m": tuple(flat_mod.flatten_tree(spec, m_tree)),
              "v": tuple(flat_mod.flatten_tree(spec, v_tree)), "t": t0}
        new_p, _, _ = fused_server_update(
            params, grads, wts * jnp.exp(w_logits), st, opt=SERVER_OPT,
            lr=jnp.exp(log_lr), clip_norm=CLIP)
        return model.loss(new_p, meta)[0]

    def legacy_loss(w_logits, log_lr):
        G = weighted_mean(grads, wts * jnp.exp(w_logits))
        gn = jnp.sqrt(sum(jnp.sum(jnp.square(x))
                          for x in jax.tree.leaves(G)))
        s = jnp.minimum(1.0, CLIP / jnp.maximum(gn, 1e-9))
        G = jax.tree.map(lambda x: x * s, G)
        new_p, _ = server_opt.apply(
            SERVER_OPT, {"m": m_tree, "v": v_tree, "t": t0}, params, G,
            jnp.exp(log_lr))
        return model.loss(new_p, meta)[0]

    args = (jnp.zeros((COHORT,), jnp.float32), jnp.log(jnp.float32(0.1)))
    return (jax.jit(jax.grad(fused_loss, argnums=(0, 1))),
            jax.jit(jax.grad(legacy_loss, argnums=(0, 1))), args)


def run_hypergrad(model, iters: int):
    """Time both backward arms; return (per-s fused, per-s legacy,
    agreement rel err scale-normalized over the weight hypergradient)."""
    f_fn, l_fn, args = _hypergrad_fns(model)
    out = {}
    for name, fn in (("fused_vjp", f_fn), ("legacy_autodiff", l_fn)):
        g = fn(*args)
        jax.block_until_ready(g)                       # compile
        t0 = time.perf_counter()
        for _ in range(iters):
            g = fn(*args)
        jax.block_until_ready(g)
        out[name] = iters / (time.perf_counter() - t0)
    (f_wl, f_lr), (l_wl, l_lr) = f_fn(*args), l_fn(*args)
    rel = max(
        float(jnp.max(jnp.abs(f_wl - l_wl))) /
        max(float(jnp.max(jnp.abs(l_wl))), 1e-12),
        abs(float(f_lr) - float(l_lr)) / max(abs(float(l_lr)), 1e-12))
    return out["fused_vjp"], out["legacy_autodiff"], rel


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="fewer timed rounds (CI smoke)")
    ap.add_argument("--out", default="BENCH_round_latency.json")
    ap.add_argument("--run-dir", default=None,
                    help="jsonl tracker dir (default: "
                         "benchmarks/runs/round_latency)")
    args = ap.parse_args()
    rounds = 48 if args.fast else 192
    trk = bench_tracker("round_latency", args.run_dir)

    model = make_mlp_model()
    trk.log_event("arm_start", {"arm": "legacy", "rounds": rounds})
    rps_legacy = run_legacy(model, rounds)
    trk.log_event("arm_start", {"arm": "fused_scanned", "rounds": rounds})
    rps_fused = run_fused_scanned(model, rounds)
    rel_err = max(numerics_agreement(model, "sgd"),
                  numerics_agreement(model, "sgdm"),
                  metrics_agreement(model, SERVER_OPT))
    rel_err_adam = numerics_agreement(model, "adam")
    speedup = rps_fused / rps_legacy
    hg_fused, hg_legacy, hg_rel = run_hypergrad(
        model, iters=rounds * 2)

    # scan strategy (client-sequential): streaming flat-buffer accumulation
    # + scanned driver vs the legacy pytree-carry scan + per-round dispatch
    scan_rounds = max(rounds // 2, ROUNDS_PER_CALL)
    scan_rounds -= scan_rounds % ROUNDS_PER_CALL
    rps_scan_legacy = run_legacy(model, scan_rounds, strategy="scan")
    rps_scan_fused = run_fused_scanned(model, scan_rounds, strategy="scan")
    scan_speedup = rps_scan_fused / rps_scan_legacy
    scan_rel_err = max(numerics_agreement(model, "sgd", strategy="scan"),
                       numerics_agreement(model, "sgdm", strategy="scan"),
                       metrics_agreement(model, SERVER_OPT,
                                         strategy="scan"))

    report = {
        "benchmark": "round_latency",
        "config": {"model": f"mlp {D}x{H}x{CLASSES}", "cohort": COHORT,
                   "client_batch": BATCH, "local_steps": LOCAL_STEPS,
                   "algorithm": "uga+meta", "server_opt": SERVER_OPT,
                   "clip_norm": CLIP, "rounds": rounds,
                   "rounds_per_call": ROUNDS_PER_CALL,
                   "backend": jax.default_backend()},
        "legacy": {"rounds_per_s": round(rps_legacy, 2),
                   "traversals_per_server_step":
                       TRAVERSALS_LEGACY[SERVER_OPT]},
        "fused_scanned": {"rounds_per_s": round(rps_fused, 2),
                          "traversals_per_server_step": TRAVERSALS_FUSED},
        "speedup": round(speedup, 3),
        "numerics_max_rel_err": rel_err,
        "numerics_rel_err_adam_signstep": rel_err_adam,
        # meta-through-aggregation hypergradient (one adam server step +
        # meta loss, d/d(client weights, server lr)); CPU interpret-mode
        # Pallas — the TPU Mosaic timing is a ROADMAP item
        "backward": {
            "hypergrads_per_s_fused_vjp": round(hg_fused, 2),
            "hypergrads_per_s_legacy_autodiff": round(hg_legacy, 2),
            "relative": round(hg_fused / hg_legacy, 3),
            "hypergrad_max_rel_err": hg_rel,
        },
        # client-sequential strategy: the scan carry is the flat dtype-group
        # buffers (K streaming Pallas FMAs + clip/opt/write) vs the legacy
        # pytree carry; the aggregates are bit-identical (tested), so the
        # numerics gate mirrors the vmap one (smooth opts + adam metrics)
        "scan_strategy": {
            "rounds": scan_rounds,
            "legacy": {"rounds_per_s": round(rps_scan_legacy, 2)},
            "fused_scanned": {"rounds_per_s": round(rps_scan_fused, 2)},
            "speedup": round(scan_speedup, 3),
            "numerics_max_rel_err": scan_rel_err,
            "pass_speedup_1p2x": bool(scan_speedup >= 1.2),
            "pass_numerics_1e5": bool(scan_rel_err <= 1e-5),
        },
        "pass_speedup_1p5x": bool(speedup >= 1.5),
        "pass_numerics_1e5": bool(rel_err <= 1e-5),
        # the scalar d/d(log lr) reduces ~20k elements in fp32; the two
        # engines' reduction orders differ by ~sqrt(N)*eps32 ~ 2e-5, so the
        # scalar gate sits at 5e-5 (the per-leaf weight hypergradients
        # agree to ~1e-7; the tests gate those at 1e-5)
        "pass_hypergrad_numerics_5e5": bool(hg_rel <= 5e-5),
    }
    trk.log_event("bench_report", report)
    trk.finish()
    report = write_bench_report(args.out, report, bench="round_latency")
    print(json.dumps(report, indent=1))


if __name__ == "__main__":
    main()

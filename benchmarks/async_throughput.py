"""Buffered-async federation benchmark: rounds-completed per simulated
round-unit under a straggler-heavy fleet, vs the synchronous barrier — plus
the numerics gates the async runtime ships under.

Runs the CPU smoke config (the round_latency MLP) through the REAL driver
(``FederatedTrainer``, ``engine='buffered_async'``, ``rounds_per_call``
chunking) and emits ``BENCH_async_throughput.json``:

  * **simulated-time accounting** (host side, from the SAME seeded fault
    streams the jitted rounds drew — ``repro.sim.faults.fault_streams`` is
    deterministic in the round rng): a synchronous round takes
    ``max_k(latency_k + delay_k)`` round-units (the barrier waits for the
    slowest report), while the async server dispatches a fresh cohort
    every 1.0 round-unit regardless and steps whenever K deltas arrive;
  * **rounds-equivalent throughput**: async server steps consume K deltas
    where a sync round consumes a full cohort, so async work is counted as
    ``server_steps * K / cohort`` — the ratio is not inflated by smaller
    aggregation granularity;
  * numerics gates (the script's self-check — non-zero exit on failure,
    so CI runs it directly):
      - a FAULT-FREE async arm with K = capacity = cohort is bit-identical
        to the synchronous fused-scan round (params + opt state compared
        with np.array_equal, loss curves exactly equal);
      - async under the 'stragglers' profile (20% of reports 1-4 rounds
        late, heavy-tail client speeds) completes >= 1.5x rounds-equivalent
        per simulated round-unit vs the synchronous barrier;
      - its final loss is no WORSE than the synchronous arm's + 1e-2 (the
        staleness-discounted steps may not cost convergence quality; the
        async arm typically lands lower — it takes ~2 server steps per
        dispatch period — so the gate is one-sided, with the signed
        difference reported);
      - every arm's loss curve is finite.

Usage:  PYTHONPATH=src python benchmarks/async_throughput.py [--fast]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from common import bench_tracker, write_bench_report
from repro.configs.base import FedConfig
from repro.core import FederatedTrainer
from repro.data.pipeline import FederatedData
from repro.models.model import Model
from repro.sim.faults import fault_streams, resolve_faults

D, H, CLASSES = 64, 128, 10
COHORT, BATCH, LOCAL_STEPS = 8, 32, 2
ROUNDS_PER_CALL = 4
ASYNC_K = COHORT // 2


def make_mlp_model():
    def init(k):
        k1, k2 = jax.random.split(k)
        return {"w1": jax.random.normal(k1, (D, H)) * 0.3,
                "w2": jax.random.normal(k2, (H, CLASSES)) * 0.3}

    def loss(w, batch, rng=None):
        logits = jnp.tanh(batch["x"] @ w["w1"]) @ w["w2"]
        l = -jnp.mean(jnp.take_along_axis(
            jax.nn.log_softmax(logits), batch["y"][:, None], 1))
        return l, {}

    return Model(name="bench-mlp", init=init, loss=loss)


def make_data(n=2048, clients=16, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(0, 1, (n, D)).astype(np.float32)
    y = rng.integers(0, CLASSES, n).astype(np.int32)
    parts = np.array_split(rng.permutation(n), clients)
    meta = rng.choice(n, 64, replace=False)
    return FederatedData(arrays={"x": x, "y": y}, client_indices=parts,
                         meta_indices=meta, seed=seed)


BASE = FedConfig(algorithm="uga", meta=True, cohort=COHORT,
                 local_steps=LOCAL_STEPS, client_lr=0.05, server_lr=0.1,
                 meta_lr=0.05, clip_norm=1.0, fused_update=True,
                 cohort_strategy="scan")


def run_arm(model, data, fed: FedConfig, rounds: int, tracker=None):
    """One trained arm through the facade; returns (trainer, history,
    rounds_per_s wall-clock)."""
    trainer = FederatedTrainer(model, fed, rounds_per_call=ROUNDS_PER_CALL,
                               seed=0, tracker=tracker)
    t0 = time.perf_counter()
    hist = trainer.run(data, rounds=rounds, cohort=COHORT, batch=BATCH,
                       meta_batch=BATCH)
    rps = rounds / (time.perf_counter() - t0)
    return trainer, hist, rps


def simulated_sync_duration(key, rounds: int, fed: FedConfig) -> float:
    """Round-units the synchronous barrier spends: per round, the max over
    the cohort of (completion latency + delay-fault lateness) — recomputed
    host-side from the same per-round rng folds the device rounds use."""
    fc = resolve_faults(fed)
    total = 0.0
    for r in range(rounds):
        fs = fault_streams(jax.random.fold_in(key, r), COHORT, fc)
        total += float(jnp.max(fs.latency + fs.delay.astype(jnp.float32)))
    return total


def state_leaves(trainer):
    return (jax.tree.leaves(trainer.state["params"])
            + jax.tree.leaves(trainer.state["opt"]))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="fewer rounds (CI smoke); every gate still runs")
    ap.add_argument("--out", default="BENCH_async_throughput.json")
    ap.add_argument("--run-dir", default=None,
                    help="jsonl tracker dir (default: "
                         "benchmarks/runs/async_throughput)")
    args = ap.parse_args()
    rounds = 8 if args.fast else 20
    trk = bench_tracker("async_throughput", args.run_dir)

    model = make_mlp_model()
    data = make_data()

    # arm 1: the synchronous fused-scan barrier (also the bit-identity
    # reference — the 'stragglers' profile only DELAYS reports, and a
    # barrier with no deadline waits for them, so its training bits match
    # the fault-free run exactly; only its simulated time differs)
    fed_sync = BASE
    trk.log_event("arm_start", {"arm": "sync", "rounds": rounds})
    tr_sync, hist_sync, rps_sync = run_arm(model, data, fed_sync, rounds,
                                           tracker=trk)

    # arm 2: fault-free async, K = capacity = cohort -> every tick pools
    # the whole cohort and flushes it in client order through the same
    # fused accumulate/apply kernels: bit-identity gate
    fed_clean = dataclasses.replace(
        BASE, engine="buffered_async", async_buffer=COHORT,
        async_capacity=COHORT)
    trk.log_event("arm_start", {"arm": "async_clean", "rounds": rounds})
    tr_clean, hist_clean, rps_clean = run_arm(model, data, fed_clean, rounds,
                                              tracker=trk)

    # arm 3: async under the 20%-stragglers profile, stepping every K =
    # cohort/2 arrivals with invsqrt staleness discounting
    fed_strag = dataclasses.replace(
        BASE, engine="buffered_async", async_buffer=ASYNC_K,
        async_capacity=2 * COHORT, fault_profile="stragglers")
    trk.log_event("arm_start", {"arm": "async_stragglers", "rounds": rounds})
    tr_strag, hist_strag, rps_strag = run_arm(model, data, fed_strag, rounds,
                                              tracker=trk)

    # ---- simulated-time throughput -------------------------------------
    fed_sync_strag = dataclasses.replace(BASE, fault_profile="stragglers")
    sync_duration = simulated_sync_duration(tr_sync.key, rounds,
                                            fed_sync_strag)
    sync_done = float(rounds)
    async_duration = float(rounds)       # 1.0 round-unit dispatch cadence
    async_done = sum(h["server_steps"] for h in hist_strag) \
        * ASYNC_K / COHORT
    throughput_ratio = (async_done / async_duration) \
        / (sync_done / sync_duration)

    # ---- gates ----------------------------------------------------------
    identical = (
        all(np.array_equal(np.asarray(a), np.asarray(b))
            for a, b in zip(state_leaves(tr_sync), state_leaves(tr_clean)))
        and [h["client_loss"] for h in hist_sync]
        == [h["client_loss"] for h in hist_clean])
    curves = {"sync": [h["client_loss"] for h in hist_sync],
              "async_clean": [h["client_loss"] for h in hist_clean],
              "async_stragglers": [h["client_loss"] for h in hist_strag]}
    loss_diff = curves["async_stragglers"][-1] - curves["sync"][-1]
    loss_gap = max(0.0, loss_diff)       # one-sided: degradation only
    gates = {
        "pass_async_clean_bit_identical": bool(identical),
        "pass_throughput_1p5x": bool(throughput_ratio >= 1.5),
        "pass_final_loss_gap_1e2": bool(loss_gap <= 1e-2),
        "pass_all_finite": bool(all(
            np.isfinite(c).all() for c in curves.values())),
    }

    report = {
        "benchmark": "async_throughput",
        "config": {"model": f"mlp {D}x{H}x{CLASSES}", "cohort": COHORT,
                   "client_batch": BATCH, "local_steps": LOCAL_STEPS,
                   "algorithm": "uga+meta", "rounds": rounds,
                   "rounds_per_call": ROUNDS_PER_CALL,
                   "async_buffer": ASYNC_K,
                   "async_capacity": 2 * COHORT,
                   "staleness_mode": BASE.staleness_mode,
                   "fault_profile": "stragglers",
                   "backend": jax.default_backend()},
        "simulated_time": {
            "sync_round_units": round(sync_duration, 3),
            "async_round_units": round(async_duration, 3),
            "sync_rounds_done": sync_done,
            "async_rounds_equivalent": round(async_done, 3),
            "throughput_ratio": round(throughput_ratio, 3),
        },
        "wall_clock_rounds_per_s": {"sync": round(rps_sync, 2),
                                    "async_clean": round(rps_clean, 2),
                                    "async_stragglers": round(rps_strag, 2)},
        "final_loss": {k: round(c[-1], 5) for k, c in curves.items()},
        "final_loss_diff_async_vs_sync": round(loss_diff, 6),
        "final_loss_gap_async_vs_sync": round(loss_gap, 6),
        "loss_curves": {k: [round(v, 5) for v in c]
                        for k, c in curves.items()},
        "async_metrics_last_round": {
            k: hist_strag[-1].get(k) for k in
            ("arrivals", "server_steps", "buffer_fill", "staleness_mean",
             "staleness_max", "staleness_hist", "fault_delayed")},
        **gates,
    }
    trk.log_event("bench_report", report)
    trk.finish()
    report = write_bench_report(args.out, report, bench="async_throughput")
    print(json.dumps(report, indent=1))
    if not all(gates.values()):
        failed = [k for k, v in gates.items() if not v]
        print(f"[async_throughput] SELF-CHECK FAILED: {failed}",
              file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()

"""Observability overhead benchmark + the PR 9 acceptance gates.

Runs the CPU smoke config (the round_latency MLP, through-aggregation
meta so the ctrl slot is live) through the REAL driver and emits
``BENCH_obs_overhead.json``.  Self-checking (non-zero exit on any gate
failure, so CI runs it directly):

  * **noop bit-identity** — a ``tracker="noop"`` run leaves params, opt
    state, the ctrl slot AND the history records bit-identical to an
    untracked run (observability must never perturb training);
  * **jsonl overhead <= 5%** — steady-state rounds/s with the ``jsonl``
    tracker (every record + phase event serialized to disk) within 5% of
    the untracked arm.  Timing is warm: each arm compiles first, then
    the best of REPS timed continuation segments on the same trainer's
    hot jit cache is compared;
  * **retention exactness** — a managed run saving every round with
    ``keep_last=3`` leaves EXACTLY 3 blobs plus the manifest;
  * **mid-run resume bit-identity** — ``resume_latest()`` from the
    managed store continues bit-identically vs never stopping, for the
    sync fused engine AND ``buffered_async`` (pool state included).

Usage:  PYTHONPATH=src python benchmarks/obs_overhead.py [--fast]
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import sys
import time

import jax
import numpy as np

from common import bench_tracker, write_bench_report
from repro.configs.base import FedConfig
from repro.core import FederatedTrainer
from async_throughput import make_data, make_mlp_model

COHORT, BATCH = 8, 32
ROUNDS_PER_CALL = 4

BASE = FedConfig(algorithm="uga", meta=True,
                 meta_mode="through_aggregation", cohort=COHORT,
                 local_steps=2, client_lr=0.05, server_lr=0.1,
                 meta_lr=0.05, ctrl_lr=0.01, clip_norm=1.0,
                 fused_update=True)

ASYNC = FedConfig(algorithm="uga", meta=True, cohort=COHORT,
                  local_steps=2, client_lr=0.05, server_lr=0.1,
                  meta_lr=0.05, clip_norm=1.0, fused_update=True,
                  cohort_strategy="scan", engine="buffered_async",
                  async_buffer=COHORT // 2, async_capacity=2 * COHORT,
                  fault_profile="stragglers")


def tree_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(la, lb))


def timed_arm(model, data, *, warm: int, seg: int, reps: int,
              **trainer_kw):
    """Compile with a warm segment, then time ``reps`` continuation
    segments on the SAME trainer (hot RoundFnCache; a fresh trainer per
    segment would measure compilation).  Returns (trainer, best
    rounds/s)."""
    tr = FederatedTrainer(model, BASE, rounds_per_call=ROUNDS_PER_CALL,
                          seed=0, **trainer_kw)
    tr.run(data, rounds=warm, cohort=COHORT, batch=BATCH, meta_batch=BATCH)
    best = 0.0
    for i in range(reps):
        t0 = time.perf_counter()
        tr.run(data, rounds=warm + (i + 1) * seg, cohort=COHORT,
               batch=BATCH, meta_batch=BATCH)
        best = max(best, seg / (time.perf_counter() - t0))
    return tr, best


def resume_gate(model, data, fed, run_dir: str):
    """4 managed rounds -> fresh trainer -> resume_latest -> 8 total,
    bit-compared (full state + history) against a straight 8-round run."""
    kw = dict(rounds_per_call=2, seed=0)
    tr = FederatedTrainer(model, fed, run_dir=run_dir, checkpoint_every=2,
                          keep_last=2, **kw)
    tr.run(data, rounds=4, cohort=COHORT, batch=BATCH, meta_batch=BATCH)
    tr.finish()
    tr2 = FederatedTrainer(model, fed, run_dir=run_dir, checkpoint_every=2,
                           keep_last=2, **kw)
    step = tr2.resume_latest()
    tr2.run(data, rounds=8, cohort=COHORT, batch=BATCH, meta_batch=BATCH)
    tr2.finish()
    straight = FederatedTrainer(model, fed, **kw)
    straight.run(data, rounds=8, cohort=COHORT, batch=BATCH,
                 meta_batch=BATCH)
    return (step == 4 and tree_equal(tr2.state, straight.state)
            and tr2.history == straight.history)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="short segments (CI smoke)")
    ap.add_argument("--out", default="BENCH_obs_overhead.json")
    ap.add_argument("--run-dir", default=None,
                    help="scratch + tracker dir (default: "
                         "benchmarks/runs/obs_overhead)")
    args = ap.parse_args()

    warm = 8
    seg = 60 if args.fast else 200
    reps = 3

    run_dir = args.run_dir or os.path.join(
        os.path.dirname(os.path.abspath(__file__)), "runs", "obs_overhead")
    # per-gate scratch must start empty: the manager (correctly) refuses
    # to save step 1 into a directory whose manifest already names step 10
    # from a previous invocation
    for sub in ("jsonl_arm", "retention", "resume_sync", "resume_async"):
        shutil.rmtree(os.path.join(run_dir, sub), ignore_errors=True)
    os.makedirs(run_dir, exist_ok=True)
    trk = bench_tracker("obs_overhead", run_dir)

    model, data = make_mlp_model(), make_data()
    total = warm + reps * seg

    # --- arm 1: untracked reference --------------------------------------
    trk.log_event("arm_start", {"arm": "untracked", "rounds": total})
    un_tr, un_rps = timed_arm(model, data, warm=warm, seg=seg, reps=reps)

    # --- arm 2: noop tracker (bit-identity gate) -------------------------
    trk.log_event("arm_start", {"arm": "noop", "rounds": total})
    noop_tr, noop_rps = timed_arm(model, data, warm=warm, seg=seg,
                                  reps=reps, tracker="noop")
    noop_identical = (tree_equal(un_tr.state, noop_tr.state)
                      and un_tr.history == noop_tr.history)

    # --- arm 3: jsonl tracker (overhead gate) ----------------------------
    js_dir = os.path.join(run_dir, "jsonl_arm")
    trk.log_event("arm_start", {"arm": "jsonl", "rounds": total})
    js_tr, js_rps = timed_arm(model, data, warm=warm, seg=seg, reps=reps,
                              tracker="jsonl", run_dir=js_dir)
    js_tr.finish()
    overhead_pct = 100.0 * (1.0 - js_rps / un_rps)
    jsonl_identical = tree_equal(un_tr.state, js_tr.state)

    # --- retention gate ---------------------------------------------------
    ret_dir = os.path.join(run_dir, "retention")
    ret_tr = FederatedTrainer(model, BASE, rounds_per_call=1, seed=0,
                              run_dir=ret_dir, checkpoint_every=1,
                              keep_last=3)
    ret_tr.run(data, rounds=10, cohort=COHORT, batch=BATCH,
               meta_batch=BATCH)
    ret_tr.finish()
    ck = os.path.join(ret_dir, "checkpoints")
    blobs = sorted(f for f in os.listdir(ck) if f.endswith(".msgpack"))
    retention_ok = (len(blobs) == 3
                    and os.path.exists(os.path.join(ck, "manifest.json"))
                    and ret_tr.manager.saved_steps() == [8, 9, 10])

    # --- mid-run resume gates (sync + buffered_async) --------------------
    resume_sync = resume_gate(model, data, BASE,
                              os.path.join(run_dir, "resume_sync"))
    resume_async = resume_gate(model, data, ASYNC,
                               os.path.join(run_dir, "resume_async"))

    gates = {
        "noop_tracked_run_bit_identical": bool(noop_identical),
        "jsonl_tracked_run_bit_identical": bool(jsonl_identical),
        "jsonl_overhead_within_5pct": bool(overhead_pct <= 5.0),
        "retention_leaves_exactly_keep_last": bool(retention_ok),
        "resume_latest_bit_identical_sync": bool(resume_sync),
        "resume_latest_bit_identical_async": bool(resume_async),
    }
    report = {
        "benchmark": "obs_overhead",
        "config": {"model": "mlp 64-128-10",
                   "meta_mode": "through_aggregation",
                   "cohort": COHORT, "batch": BATCH,
                   "rounds_per_call": ROUNDS_PER_CALL,
                   "warm_rounds": warm, "timed_segment": seg,
                   "reps": reps, "fast": bool(args.fast)},
        "rounds_per_s": {"untracked": round(un_rps, 2),
                         "noop": round(noop_rps, 2),
                         "jsonl": round(js_rps, 2)},
        "jsonl_overhead_pct": round(overhead_pct, 3),
        "retained_blobs": blobs,
        "gates": gates,
        "ok": all(gates.values()),
    }
    trk.log_event("bench_report", report)
    trk.finish()
    report = write_bench_report(args.out, report, bench="obs_overhead")
    print(json.dumps(report, indent=1))
    if not report["ok"]:
        print("obs_overhead: GATE FAILURE", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()

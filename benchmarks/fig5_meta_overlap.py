"""Paper Fig. 5 — controllable D_meta: accuracy (on the TARGET distribution,
i.e. the meta writers' held-out data) of FedAvg vs FedMeta as the overlap
between D_meta's writers and the training population varies.

Paper's claim: FedAvg degrades as overlap drops (it can only fit the
training population); FedMeta stays flat because optimization is steered by
D_meta regardless."""
from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks.common import run_methods
from repro.configs import paper_models as pm
from repro.data.partition import make_meta_set, partition_by_writer
from repro.data.pipeline import FederatedData
from repro.data.synthetic import synthetic_images
from repro.models.model import build_paper_cnn

OVERLAPS = (0.0, 0.5, 1.0)


def run(fast: bool = True):
    rng = np.random.default_rng(4)
    writers = 24 if fast else 60
    n = (writers * 2) * 50
    # population = train writers + auxiliary writers (disjoint styles)
    ds = synthetic_images(rng, n=n, image_size=14, channels=1,
                          num_classes=10, num_writers=writers * 2,
                          style_strength=0.8)
    train_writers = list(range(writers))
    aux_writers = list(range(writers, writers * 2))
    train_idx = np.where(np.isin(ds.writer, train_writers))[0]
    parts = partition_by_writer(ds.writer, train_writers)
    parts = [p if p.size else np.array([train_idx[0]]) for p in parts]

    cfg = dataclasses.replace(pm.FEMNIST_CNN_SMOKE, image_size=14,
                              num_classes=10)
    model = build_paper_cnn(cfg)
    out = {}
    for overlap in OVERLAPS if not fast else (0.0, 1.0):
        meta = make_meta_set(rng, ds.writer, train_writers, aux_writers,
                             overlap=overlap, fraction=0.02)
        data = FederatedData(arrays={"x": ds.x, "y": ds.y},
                             client_indices=parts, meta_indices=meta,
                             shared_indices=meta.copy(), seed=0)
        # target distribution = held-out examples of the meta writers
        meta_writers = np.unique(ds.writer[meta])
        pool = np.where(np.isin(ds.writer, meta_writers))[0]
        eval_idx = np.setdiff1d(pool, meta)[:256]
        res = run_methods(model, data, methods=["fedavg", "fedmeta"],
                          rounds=80 if fast else 300, cohort=4, batch=20,
                          local_steps=2, lr=0.005, eval_idx=eval_idx,
                          eval_every=5)
        out[f"overlap_{int(overlap*100)}"] = {
            "fedavg": res["fedavg"][-1]["acc"],
            "fedmeta": res["fedmeta"][-1]["acc"]}
    return out

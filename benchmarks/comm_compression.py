"""Communication-compression benchmark: uplink bytes/round and rounds/s
for each gradient codec (repro.comm) vs the uncompressed fp32 baseline,
plus the numerics gates the subsystem ships under.

Runs the CPU smoke config (the round_latency MLP) through the REAL driver
(``FederatedTrainer``, fused engine, ``rounds_per_call`` chunking) once per
codec arm and emits ``BENCH_comm_compression.json``:

  * bytes/round (measured from the codecs' transport payloads) and the
    ratio vs shipping raw fp32;
  * rounds/s per arm (the codec stage rides the existing hot path: encode
    + decode-fused FMA are a few extra flat sweeps per client);
  * numerics gates (the script's self-check — it exits non-zero if any
    fails, so CI can run it directly):
      - int8 + error feedback tracks the uncompressed 20-round loss curve
        within 1e-2 (the paper-table loss budget on the smoke config);
      - int8 bytes/round <= 30% of fp32;
      - sign1bit bytes/round <= 5% of fp32;
      - every arm's loss curve is finite.

Usage:  PYTHONPATH=src python benchmarks/comm_compression.py [--fast]
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from common import bench_tracker, write_bench_report
from repro.comm import comm_bytes_per_client, resolve_codec
from repro.configs.base import FedConfig
from repro.core import FederatedTrainer, init_server_state
from repro.core.flat import make_flat_spec
from repro.data.pipeline import FederatedData
from repro.models.model import Model

D, H, CLASSES = 64, 128, 10
COHORT, BATCH, LOCAL_STEPS = 8, 32, 2
ROUNDS_PER_CALL = 4

ARMS = [
    # (label, codec, error_feedback)
    ("none", "none", False),
    ("int8_ef", "int8", True),
    ("int8", "int8", False),
    ("sign1bit_ef", "sign1bit", True),
    ("topk_ef", "topk", True),
]


def make_mlp_model():
    def init(k):
        k1, k2 = jax.random.split(k)
        return {"w1": jax.random.normal(k1, (D, H)) * 0.3,
                "w2": jax.random.normal(k2, (H, CLASSES)) * 0.3}

    def loss(w, batch, rng=None):
        logits = jnp.tanh(batch["x"] @ w["w1"]) @ w["w2"]
        l = -jnp.mean(jnp.take_along_axis(
            jax.nn.log_softmax(logits), batch["y"][:, None], 1))
        return l, {}

    return Model(name="bench-mlp", init=init, loss=loss)


def make_data(n=2048, clients=16, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(0, 1, (n, D)).astype(np.float32)
    y = rng.integers(0, CLASSES, n).astype(np.int32)
    parts = np.array_split(rng.permutation(n), clients)
    meta = rng.choice(n, 64, replace=False)
    return FederatedData(arrays={"x": x, "y": y}, client_indices=parts,
                         meta_indices=meta, seed=seed)


def run_arm(model, data, codec: str, error_feedback: bool, rounds: int,
            tracker=None):
    """One trained arm through the facade; returns (loss_curve,
    bytes_per_round, rounds_per_s)."""
    fed = FedConfig(algorithm="uga", meta=True, cohort=COHORT,
                    local_steps=LOCAL_STEPS, client_lr=0.05, server_lr=0.1,
                    meta_lr=0.05, clip_norm=1.0, fused_update=True,
                    codec=codec, error_feedback=error_feedback)
    trainer = FederatedTrainer(model, fed, rounds_per_call=ROUNDS_PER_CALL,
                               seed=0, tracker=tracker)
    # first run compiles AND yields the gate curve; rewinding the SAME
    # trainer to round 0 keeps its RoundFnCache warm (a fresh trainer
    # would rebuild the jit closures and the timed run would measure
    # compilation, not dispatch), so the second, identical run times
    # steady-state rounds/s
    hist = trainer.run(data, rounds=rounds, cohort=COHORT, batch=BATCH,
                       meta_batch=BATCH)
    curve = [h["client_loss"] for h in hist]
    bytes_round = hist[-1].get("comm_bytes")
    trainer.state = init_server_state(model, fed, trainer.key)
    t0 = time.perf_counter()
    trainer.run(data, rounds=rounds, cohort=COHORT, batch=BATCH,
                meta_batch=BATCH)
    rps = rounds / (time.perf_counter() - t0)
    return curve, bytes_round, rps


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="fewer timed rounds (CI smoke); the 20-round "
                         "numerics gates always run in full")
    ap.add_argument("--out", default="BENCH_comm_compression.json")
    ap.add_argument("--run-dir", default=None,
                    help="jsonl tracker dir (default: "
                         "benchmarks/runs/comm_compression)")
    args = ap.parse_args()
    rounds = 20                      # the gate horizon; timing reuses it
    trk = bench_tracker("comm_compression", args.run_dir)

    model = make_mlp_model()
    data = make_data()
    spec = make_flat_spec(model.init(jax.random.PRNGKey(0)))
    fp32_bytes = COHORT * comm_bytes_per_client(
        resolve_codec(None, codec="none"), spec)

    arms = {}
    for label, codec, ef in ARMS:
        if args.fast and label in ("int8", "topk_ef"):
            continue
        trk.log_event("arm_start", {"arm": label, "codec": codec,
                                    "error_feedback": ef, "rounds": rounds})
        curve, bytes_round, rps = run_arm(model, data, codec, ef, rounds,
                                          tracker=trk)
        arms[label] = {
            "codec": codec, "error_feedback": ef,
            "rounds_per_s": round(rps, 2),
            "bytes_per_round": bytes_round if bytes_round is not None
            else fp32_bytes,
            "bytes_vs_fp32": round(
                (bytes_round if bytes_round is not None else fp32_bytes)
                / fp32_bytes, 4),
            "final_loss": round(curve[-1], 5),
            "loss_curve": [round(v, 5) for v in curve],
        }

    base = arms["none"]["loss_curve"]
    for label, arm in arms.items():
        arm["max_loss_dev_vs_none"] = round(max(
            abs(a - b) for a, b in zip(arm["loss_curve"], base)), 6)

    gates = {
        "pass_int8_ef_loss_1e2":
            bool(arms["int8_ef"]["max_loss_dev_vs_none"] <= 1e-2),
        "pass_int8_bytes_30pct":
            bool(arms["int8_ef"]["bytes_vs_fp32"] <= 0.30),
        "pass_sign1bit_bytes_5pct":
            bool(arms["sign1bit_ef"]["bytes_vs_fp32"] <= 0.05),
        "pass_all_finite": bool(all(
            np.isfinite(arm["loss_curve"]).all() for arm in arms.values())),
    }

    report = {
        "benchmark": "comm_compression",
        "config": {"model": f"mlp {D}x{H}x{CLASSES}", "cohort": COHORT,
                   "client_batch": BATCH, "local_steps": LOCAL_STEPS,
                   "algorithm": "uga+meta", "rounds": rounds,
                   "rounds_per_call": ROUNDS_PER_CALL,
                   "fp32_bytes_per_round": fp32_bytes,
                   "backend": jax.default_backend()},
        "arms": arms,
        **gates,
    }
    trk.log_event("bench_report", report)
    trk.finish()
    report = write_bench_report(args.out, report, bench="comm_compression")
    print(json.dumps(report, indent=1))
    if not all(gates.values()):
        failed = [k for k, v in gates.items() if not v]
        print(f"[comm_compression] SELF-CHECK FAILED: {failed}",
              file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()

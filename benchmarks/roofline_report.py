"""§Roofline report: read the dry-run artifacts and emit the per
(arch x shape x mesh) three-term roofline table (markdown + CSV)."""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

COLS = ("arch", "shape", "mesh", "compute_s", "memory_s", "collective_s",
        "bottleneck", "flops_ratio")


def load(art_dir: str = "artifacts/dryrun") -> List[Dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def table(recs: List[Dict], mesh: str = "16x16") -> str:
    lines = ["| arch | shape | compute (s) | memory (s) | collective (s) | "
             "bottleneck | MODEL/HLO | temp GiB |",
             "|---|---|---|---|---|---|---|---|"]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"])):
        if r["mesh"] != mesh:
            continue
        rl = r["roofline"]
        ratio = rl.get("flops_ratio")
        rs = f"{ratio:.2f}" if ratio is not None else "-"
        temp = r.get("memory", {}).get("temp_size_in_bytes")
        ts = f"{temp/2**30:.1f}" if temp else "-"
        lines.append(
            f"| {r['arch']} | {r['shape']} | {rl['compute_s']:.3e} | "
            f"{rl['memory_s']:.3e} | {rl['collective_s']:.3e} | "
            f"**{rl['bottleneck']}** | {rs} | {ts} |")
    return "\n".join(lines)


def csv(recs: List[Dict]) -> str:
    lines = [",".join(COLS)]
    for r in sorted(recs, key=lambda r: (r["mesh"], r["arch"], r["shape"])):
        rl = r["roofline"]
        ratio = rl.get("flops_ratio")
        lines.append(",".join([
            r["arch"], r["shape"], r["mesh"], f"{rl['compute_s']:.4e}",
            f"{rl['memory_s']:.4e}", f"{rl['collective_s']:.4e}",
            rl["bottleneck"],
            f"{ratio:.3f}" if ratio is not None else ""]))
    return "\n".join(lines)


def run(fast: bool = True):
    recs = load()
    return {"configs": len(recs),
            "bottlenecks": {b: sum(1 for r in recs
                                   if r["roofline"]["bottleneck"] == b)
                            for b in ("compute", "memory", "collective")}}


if __name__ == "__main__":
    recs = load()
    print(table(recs))
    print()
    print(csv(recs))

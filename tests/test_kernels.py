"""Pallas kernel validation (interpret mode): shape/dtype sweeps against the
pure-jnp oracles, per the assignment."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention as fa_kernel
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.ssd_scan.ops import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_ref
from repro.models.ssm import ssd_chunked


@pytest.mark.parametrize("B,S,H,Hkv,D", [
    (2, 128, 4, 2, 64),
    (1, 256, 8, 8, 128),
    (2, 128, 4, 1, 64),
    (1, 192, 2, 2, 96),        # padding path (192 % 128 != 0)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 64)])
def test_flash_kernel_sweep(key, B, S, H, Hkv, D, dtype, causal, window):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, D), dtype)
    k = jax.random.normal(ks[1], (B, S, Hkv, D), dtype)
    v = jax.random.normal(ks[2], (B, S, Hkv, D), dtype)
    out = fa_kernel(q, k, v, causal=causal, window=window, bq=128, bk=128,
                    interpret=True)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * Hkv, S, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * Hkv, S, D)
    ref = attention_ref(qf, kf, vf, causal=causal, window=window)
    ref = ref.reshape(B, H, S, D).transpose(0, 2, 1, 3)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol)


@pytest.mark.parametrize("B,S,H,P,N,chunk", [
    (2, 128, 4, 64, 32, 32),
    (1, 256, 2, 128, 64, 64),
    (2, 64, 8, 64, 16, 16),
    (2, 128, 4, 64, 32, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_kernel_sweep(key, B, S, H, P, N, chunk, dtype):
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (B, S, H, P), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H))).astype(jnp.float32)
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, H, N), dtype)
    Cm = jax.random.normal(ks[4], (B, S, H, N), dtype)
    y = ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, interpret=True)
    a = dt * A[None, None, :]
    fold = lambda t: t.transpose(0, 2, 1, 3).reshape(B * H, S, t.shape[-1])
    yref = ssd_ref(fold(x), fold(dt[..., None]), fold(a[..., None]),
                   fold(Bm), fold(Cm)).reshape(B, H, S, P).transpose(0, 2, 1, 3)
    tol = dict(atol=6e-2, rtol=3e-2) if dtype == jnp.bfloat16 else \
        dict(atol=5e-4, rtol=2e-3)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yref, np.float32), **tol)


def test_ssd_kernel_matches_model_chunked(key):
    """Kernel == the model's jnp chunked implementation (the XLA path the
    dry-run uses) — bitwise-close since both use the chunked algorithm."""
    B, S, H, P, N, chunk = 2, 128, 4, 64, 32, 32
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, H, N))
    Cm = jax.random.normal(ks[4], (B, S, H, N))
    y_kernel = ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, interpret=True)
    y_model, _ = ssd_chunked(x, dt, A, Bm, Cm, chunk)
    np.testing.assert_allclose(np.asarray(y_kernel), np.asarray(y_model),
                               atol=1e-5, rtol=1e-5)

"""Controllable meta-through-aggregation, FedOpt server-lr, local-epochs
threading, and full-server-state checkpoint/resume:

  * ``meta_mode="through_aggregation"`` hypergradients (w.r.t. per-client
    weight logits and log server lr) through the fused custom VJP match
    XLA autodiff through the legacy tree-map server step;
  * the same hypergradients under ``cohort_strategy="scan"`` (streaming
    flat accumulation, g_k recomputed under ``jax.checkpoint``) match the
    vmap path <= 1e-5, and the combination runs under rounds_per_call>1;
  * the mode-combination guards fail loudly (ValueError with the fix named)
    instead of a bare NameError / silently-broadcast ctrl update;
  * one controllable round updates the ctrl state with finite metrics and
    leaves ``meta_mode="post"`` (the default) bit-identical to before;
  * ``server_lr`` regression: forced to 1.0 ONLY for fedavg/fedprox under
    plain-SGD (exact parameter averaging); honored for UGA and for every
    FedOpt server optimizer (FedAdam/FedYogi on pseudo-gradients);
  * ``FedConfig.local_epochs`` threads through ``make_federated_round`` →
    ``make_client_update`` (E>1 == the example-tiled E=1 round) and the
    batch-divisibility contract asserts at trace time;
  * checkpoint save/restore round-trips the FULL server state (params +
    legacy and fused tuple-structured opt state + ctrl + round counter),
    and a mid-run save/restore continues bit-identically.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import restore, save
from repro.configs.base import FedConfig
from repro.core import (init_server_state, make_federated_round,
                        resolve_server_lr, server_opt, weighted_mean)
from repro.models.model import Model


def make_mlp_model(d=10, h=16, classes=4):
    def init(k):
        k1, k2 = jax.random.split(k)
        return {"w1": jax.random.normal(k1, (d, h)) * 0.3,
                "w2": jax.random.normal(k2, (h, classes)) * 0.3}

    def loss(w, batch, rng=None):
        logits = jnp.tanh(batch["x"] @ w["w1"]) @ w["w2"]
        l = -jnp.mean(jnp.take_along_axis(
            jax.nn.log_softmax(logits), batch["y"][:, None], 1))
        return l, {}

    return Model(name="mlp", init=init, loss=loss)


def sample_batch(rng, cohort, b, d=10, classes=4):
    return {"x": jnp.asarray(rng.normal(0, 1, (cohort, b, d)), jnp.float32),
            "y": jnp.asarray(rng.integers(0, classes, (cohort, b)),
                             jnp.int32)}


def _round_inputs(seed=0, cohort=4, b=16):
    rng = np.random.default_rng(seed)
    batch = sample_batch(rng, cohort, b)
    meta = {"x": jnp.asarray(rng.normal(0, 1, (8, 10)), jnp.float32),
            "y": jnp.asarray(rng.integers(0, 4, 8), jnp.int32)}
    wts = jnp.asarray(rng.uniform(1.0, 5.0, cohort), jnp.float32)
    return batch, meta, wts


def tree_equal(a, b):
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# ---------------------------------------------------------------------------
# hypergradients through the fused aggregation == legacy autodiff
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("opt", ["sgd", "adam"])
@pytest.mark.parametrize("clip", [0.0, 1.0])
def test_hypergrad_matches_legacy_autodiff(key, opt, clip):
    model = make_mlp_model()
    params = model.init(key)
    batch, meta, wts = _round_inputs()
    cohort = wts.shape[0]
    grads = jax.tree.map(
        lambda p: jax.random.normal(jax.random.fold_in(key, p.size),
                                    (cohort,) + p.shape), params)
    from repro.core import flat as F
    from repro.kernels.fused_update import ops as O
    spec = F.make_flat_spec(params)
    # adam at t=1 from zeros is a sign-step — scale-invariant in G, so the
    # weight hypergradient is ~0 and both engines return fp32 noise; a warm
    # state makes the step genuinely weight-sensitive.
    m_tree = jax.tree.map(
        lambda p: 0.3 * jax.random.normal(jax.random.fold_in(key, p.size + 3),
                                          p.shape), params)
    v_tree = jax.tree.map(
        lambda p: 0.1 + jnp.abs(jax.random.normal(
            jax.random.fold_in(key, p.size + 4), p.shape)), params)

    def _warm(st, flat):
        if "m" in st:
            st["m"] = tuple(F.flatten_tree(spec, m_tree)) if flat else m_tree
        if "v" in st:
            st["v"] = tuple(F.flatten_tree(spec, v_tree)) if flat else v_tree
            st["t"] = jnp.asarray(5, jnp.int32)
        return st

    def fused_meta_loss(w_logits, log_lr):
        eff_w = wts * jnp.exp(w_logits)
        st = _warm(O.init_flat_opt_state(opt, spec), flat=True)
        new_p, _, _ = O.fused_server_update(
            params, grads, eff_w, st, opt=opt, lr=jnp.exp(log_lr),
            clip_norm=clip)
        return model.loss(new_p, meta)[0]

    def legacy_meta_loss(w_logits, log_lr):
        eff_w = wts * jnp.exp(w_logits)
        G = weighted_mean(grads, eff_w)
        if clip > 0:
            gn = jnp.sqrt(sum(jnp.sum(jnp.square(x))
                              for x in jax.tree.leaves(G)))
            s = jnp.minimum(1.0, clip / jnp.maximum(gn, 1e-9))
            G = jax.tree.map(lambda x: x * s, G)
        st = _warm(server_opt.init_state(opt, params), flat=False)
        new_p, _ = server_opt.apply(opt, st, params, G, jnp.exp(log_lr))
        return model.loss(new_p, meta)[0]

    wl = 0.1 * jax.random.normal(jax.random.fold_in(key, 1), (cohort,))
    llr = jnp.log(jnp.float32(0.2))
    f_wl, f_lr = jax.grad(fused_meta_loss, argnums=(0, 1))(wl, llr)
    l_wl, l_lr = jax.grad(legacy_meta_loss, argnums=(0, 1))(wl, llr)
    scale = max(float(jnp.max(jnp.abs(l_wl))), 1e-8)
    assert float(jnp.max(jnp.abs(f_wl - l_wl))) <= 1e-5 * scale
    np.testing.assert_allclose(float(f_lr), float(l_lr),
                               rtol=1e-4, atol=1e-7)
    assert np.isfinite(np.asarray(f_wl)).all() and np.isfinite(float(f_lr))


def test_through_aggregation_round_updates_ctrl_state(key):
    model = make_mlp_model()
    fed = FedConfig(algorithm="uga", meta=True, cohort=4, local_steps=2,
                    client_lr=0.05, server_lr=0.1, server_opt="adam",
                    clip_norm=1.0, fused_update=True,
                    meta_mode="through_aggregation", ctrl_lr=0.05)
    rf = jax.jit(make_federated_round(model, fed))
    batch, meta, wts = _round_inputs()
    state = init_server_state(model, fed, key)
    assert state["ctrl"]["w_logits"].shape == (4,)
    np.testing.assert_allclose(float(jnp.exp(state["ctrl"]["log_lr"])), 0.1,
                               rtol=1e-6)
    for r in range(2):
        state, m = rf(state, batch, meta, wts, jax.random.fold_in(key, r))
    for name in ("client_loss", "grad_norm", "meta_loss", "ctrl_w_gnorm",
                 "ctrl_lr_grad", "server_lr_eff"):
        assert np.isfinite(float(m[name])), name
    # the hypergradient step moved the controllable state
    assert float(m["ctrl_w_gnorm"]) > 0
    assert not np.allclose(np.asarray(state["ctrl"]["w_logits"]), 0.0)
    assert int(state["round"]) == 2


def test_meta_mode_post_default_unchanged(key):
    """meta_mode='post' must stay bit-identical to a config that never
    heard of meta modes (regression guard on the default path)."""
    model = make_mlp_model()
    batch, meta, wts = _round_inputs()
    states = {}
    for mode in ("post", "through_aggregation"):
        fed = FedConfig(algorithm="uga", meta=True, cohort=4, local_steps=2,
                        client_lr=0.05, server_lr=0.1, server_opt="sgd",
                        fused_update=True, meta_mode=mode)
        st = init_server_state(model, fed, key)
        states[mode], _ = jax.jit(make_federated_round(model, fed))(
            st, batch, meta, wts, key)
    # both modes step the params, but differently (post adds the Eq. 20
    # parameter step; through_aggregation reinvests the signal in ctrl)
    assert "ctrl" not in states["post"]
    assert "ctrl" in states["through_aggregation"]
    assert not tree_equal(states["post"]["params"],
                          states["through_aggregation"]["params"])


def test_through_aggregation_config_validation():
    # ValueError (not a bare assert): must stay loud under python -O, and
    # the message should name the fix
    with pytest.raises(ValueError, match="fused_update=True"):
        FedConfig(meta=True, meta_mode="through_aggregation",
                  fused_update=False)
    with pytest.raises(ValueError, match="server_lr"):
        FedConfig(meta=True, meta_mode="through_aggregation",
                  fused_update=True, server_lr=0.0)
    # scan cohorts are now a SUPPORTED combination (streaming flat
    # accumulation feeds the per-client weight hypergradients)
    FedConfig(meta=True, meta_mode="through_aggregation",
              fused_update=True, cohort_strategy="scan")
    with pytest.raises(ValueError, match="meta_mode"):
        FedConfig(meta_mode="sideways")


def test_through_aggregation_round_guards():
    """make_federated_round re-validates at trace-build time: a config that
    dodged __post_init__ (python -O, object.__setattr__) must not reach the
    legacy branch and die on an undefined new_ctrl.  grad_shardings used to
    be rejected here (the old sharded executor pre-aggregated per leaf);
    the two-tier sharded executor recomputes per-client hypergradients per
    chunk, so the same config now BUILDS — pinned positively."""
    model = make_mlp_model()
    fed = FedConfig(algorithm="uga", meta=True, cohort=4, local_steps=2,
                    fused_update=True, meta_mode="through_aggregation")
    object.__setattr__(fed, "fused_update", False)     # simulate -O bypass
    with pytest.raises(ValueError, match="fused_update=True"):
        make_federated_round(model, fed)

    fed2 = FedConfig(algorithm="uga", meta=True, cohort=4, local_steps=2,
                     fused_update=True, meta_mode="through_aggregation")
    round_fn = make_federated_round(model, fed2,
                                    grad_shardings={"w1": None})
    assert callable(round_fn)


# ---------------------------------------------------------------------------
# through_aggregation under scan cohorts == the vmap path
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("opt,clip", [("sgd", 0.0), ("sgd", 1.0),
                                      ("sgdm", 1.0)])
def test_scan_hypergrads_match_vmap(key, opt, clip):
    """Regression for the old silently-wrong combination: scan used to feed
    a pre-aggregated (1, ...) stack + w_fused=ones(1) into the ctrl update,
    broadcasting against (cohort,) w_logits.  Now the streaming accumulate
    VJP supplies per-client cotangents and one round's ctrl update (ctrl -
    ctrl_lr * hypergrad) must match the vmap path <= 1e-5."""
    model = make_mlp_model()
    batch, meta, wts = _round_inputs()
    ctrls = {}
    for strat in ("vmap", "scan"):
        fed = FedConfig(algorithm="uga", meta=True, cohort=4, local_steps=2,
                        client_lr=0.05, server_lr=0.1, server_opt=opt,
                        clip_norm=clip, fused_update=True,
                        cohort_strategy=strat,
                        meta_mode="through_aggregation", ctrl_lr=1.0)
        st = init_server_state(model, fed, key)
        rf = jax.jit(make_federated_round(model, fed))
        # two rounds: round 2 runs with w_logits != 0, so the client_loss
        # metric parity below also covers the eff_w-vs-n_k weighting
        for r in range(2):
            st, m = rf(st, batch, meta, wts, jax.random.fold_in(key, r))
        ctrls[strat] = (st, m)
    wl_v = np.asarray(ctrls["vmap"][0]["ctrl"]["w_logits"])
    wl_s = np.asarray(ctrls["scan"][0]["ctrl"]["w_logits"])
    scale = max(float(np.max(np.abs(wl_v))), 1e-8)
    assert float(np.max(np.abs(wl_v - wl_s))) <= 1e-5 * scale, (wl_v, wl_s)
    np.testing.assert_allclose(float(ctrls["scan"][0]["ctrl"]["log_lr"]),
                               float(ctrls["vmap"][0]["ctrl"]["log_lr"]),
                               rtol=1e-5, atol=1e-7)
    # same round, same numbers: client/meta losses and params line up too
    for name in ("client_loss", "meta_loss"):
        np.testing.assert_allclose(float(ctrls["scan"][1][name]),
                                   float(ctrls["vmap"][1][name]),
                                   rtol=1e-5, atol=1e-7)
    for a, b in zip(jax.tree.leaves(ctrls["scan"][0]["params"]),
                    jax.tree.leaves(ctrls["vmap"][0]["params"])):
        a, b = np.asarray(a), np.asarray(b)
        assert np.max(np.abs(a - b) / (np.abs(b) + 1e-6)) <= 1e-5


def test_scan_hypergrads_match_vmap_adam_warm(key):
    """adam arm of the scan==vmap hypergradient gate, warm (t=5) state: at
    t=1 from zeros the sign-step's weight hypergradient is ~0 and both
    engines return fp32 cancellation noise (the documented caveat)."""
    model = make_mlp_model()
    params0 = model.init(key)
    from repro.core import flat as F
    spec = F.make_flat_spec(params0)
    batch, meta, wts = _round_inputs()
    m_tree = jax.tree.map(
        lambda p: 0.3 * jax.random.normal(jax.random.fold_in(key, p.size + 3),
                                          p.shape), params0)
    v_tree = jax.tree.map(
        lambda p: 0.1 + jnp.abs(jax.random.normal(
            jax.random.fold_in(key, p.size + 4), p.shape)), params0)
    ctrls = {}
    for strat in ("vmap", "scan"):
        fed = FedConfig(algorithm="uga", meta=True, cohort=4, local_steps=2,
                        client_lr=0.05, server_lr=0.1, server_opt="adam",
                        clip_norm=1.0, fused_update=True,
                        cohort_strategy=strat,
                        meta_mode="through_aggregation", ctrl_lr=1.0)
        st = init_server_state(model, fed, key)
        st["opt"] = {"m": tuple(F.flatten_tree(spec, m_tree)),
                     "v": tuple(F.flatten_tree(spec, v_tree)),
                     "t": jnp.asarray(5, jnp.int32)}
        st, _ = jax.jit(make_federated_round(model, fed))(
            st, batch, meta, wts, key)
        ctrls[strat] = st
    wl_v = np.asarray(ctrls["vmap"]["ctrl"]["w_logits"])
    wl_s = np.asarray(ctrls["scan"]["ctrl"]["w_logits"])
    scale = max(float(np.max(np.abs(wl_v))), 1e-8)
    assert float(np.max(np.abs(wl_v - wl_s))) <= 1e-5 * scale, (wl_v, wl_s)
    np.testing.assert_allclose(float(ctrls["scan"]["ctrl"]["log_lr"]),
                               float(ctrls["vmap"]["ctrl"]["log_lr"]),
                               rtol=1e-5, atol=1e-7)


def test_scan_through_aggregation_rounds_per_call(key):
    """scan + through_aggregation + rounds_per_call>1 (the 90B/398B driver
    shape): nested scans trace, ctrl state moves, metrics stay finite."""
    model = make_mlp_model()
    fed = FedConfig(algorithm="uga", meta=True, cohort=4, local_steps=2,
                    client_lr=0.05, server_lr=0.1, server_opt="adam",
                    clip_norm=1.0, fused_update=True, cohort_strategy="scan",
                    meta_mode="through_aggregation", ctrl_lr=0.05)
    Kr = 2
    batch, meta, wts = _round_inputs()
    rf = jax.jit(make_federated_round(model, fed, rounds_per_call=Kr))
    st = init_server_state(model, fed, key)
    st, m = rf(st,
               jax.tree.map(lambda x: jnp.stack([x] * Kr), batch),
               jax.tree.map(lambda x: jnp.stack([x] * Kr), meta),
               jnp.stack([wts] * Kr),
               jnp.stack([jax.random.fold_in(key, r) for r in range(Kr)]))
    assert int(st["round"]) == Kr
    for name in ("client_loss", "grad_norm", "meta_loss", "ctrl_w_gnorm",
                 "ctrl_lr_grad", "server_lr_eff"):
        assert m[name].shape == (Kr,)
        assert np.isfinite(np.asarray(m[name])).all(), name
    assert not np.allclose(np.asarray(st["ctrl"]["w_logits"]), 0.0)


# ---------------------------------------------------------------------------
# FedOpt server-lr regression (was silently forced to 1.0 for fedavg)
# ---------------------------------------------------------------------------
def test_resolve_server_lr_paths():
    mk = lambda algo, opt: FedConfig(algorithm=algo, server_opt=opt,
                                     server_lr=0.37)
    assert resolve_server_lr(mk("uga", "sgd")) == 0.37
    assert resolve_server_lr(mk("uga", "adam")) == 0.37
    assert resolve_server_lr(mk("fedavg", "sgd")) == 1.0      # exact FedAvg
    assert resolve_server_lr(mk("fedprox", "sgd")) == 1.0
    assert resolve_server_lr(mk("fedavg", "adam")) == 0.37    # FedAdam
    assert resolve_server_lr(mk("fedprox", "yogi")) == 0.37   # FedYogi
    assert resolve_server_lr(mk("fedavg", "sgdm")) == 0.37    # FedAvgM


@pytest.mark.parametrize("fused", [False, True])
def test_fedavg_fedopt_server_lr_applied(key, fused):
    """Under plain SGD fedavg must ignore server_lr (exact averaging);
    under a FedOpt server optimizer two different server_lr values MUST
    produce different parameters (the old code forced both to 1.0)."""
    model = make_mlp_model()
    batch, meta, wts = _round_inputs()

    def run(opt, server_lr):
        fed = FedConfig(algorithm="fedavg", meta=False, cohort=4,
                        local_steps=2, client_lr=0.05, server_lr=server_lr,
                        server_opt=opt, fused_update=fused)
        st = init_server_state(model, fed, key)
        st, _ = jax.jit(make_federated_round(model, fed))(
            st, batch, meta, wts, key)
        return st["params"]

    # plain SGD: server_lr has no effect (lr forced to 1.0 on both)
    assert tree_equal(run("sgd", 0.5), run("sgd", 0.01))
    # FedAdam: server_lr is live again
    p_big, p_small = run("adam", 0.5), run("adam", 0.01)
    assert not tree_equal(p_big, p_small)
    # and scales the step: adam's step saturates to ~lr*sign, so the
    # parameter delta ratio tracks the lr ratio
    p0 = model.init(key)
    d_big = sum(float(jnp.sum(jnp.abs(a - b))) for a, b in
                zip(jax.tree.leaves(p_big), jax.tree.leaves(p0)))
    d_small = sum(float(jnp.sum(jnp.abs(a - b))) for a, b in
                  zip(jax.tree.leaves(p_small), jax.tree.leaves(p0)))
    assert d_big > 10 * d_small


# ---------------------------------------------------------------------------
# local_epochs threading through FedConfig
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("algo", ["uga", "fedavg"])
def test_local_epochs_threads_through_round(key, algo):
    """E>1 through FedConfig == the E=1 round over example-tiled client
    batches with local_steps*E steps (the schedule-equality contract the
    client-level tests prove, now through make_federated_round)."""
    model = make_mlp_model()
    steps, epochs = 2, 3
    batch, meta, wts = _round_inputs(b=12)
    tiled = {k: jnp.tile(v, (1, epochs) + (1,) * (v.ndim - 2))
             for k, v in batch.items()}
    kw = dict(algorithm=algo, meta=True, cohort=4, client_lr=0.05,
              server_lr=0.1, meta_lr=0.05)
    fed_e = FedConfig(local_steps=steps, local_epochs=epochs, **kw)
    fed_1 = FedConfig(local_steps=steps * epochs, local_epochs=1, **kw)
    st_e = init_server_state(model, fed_e, key)
    st_1 = init_server_state(model, fed_1, key)
    st_e, m_e = jax.jit(make_federated_round(model, fed_e))(
        st_e, batch, meta, wts, key)
    st_1, m_1 = jax.jit(make_federated_round(model, fed_1))(
        st_1, tiled, meta, wts, key)
    for a, b in zip(jax.tree.leaves(st_e["params"]),
                    jax.tree.leaves(st_1["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(float(m_e["client_loss"]),
                               float(m_1["client_loss"]),
                               rtol=1e-5, atol=1e-7)


def test_local_steps_batch_divisibility_asserts(key):
    model = make_mlp_model()
    fed = FedConfig(algorithm="uga", meta=False, cohort=2, local_steps=5,
                    local_epochs=2, client_lr=0.05)
    rf = make_federated_round(model, fed)
    batch, meta, wts = _round_inputs(cohort=2, b=12)   # 12 % 5 != 0
    st = init_server_state(model, fed, key)
    with pytest.raises(AssertionError, match="not divisible"):
        jax.jit(rf)(st, batch, meta, wts, key)


# ---------------------------------------------------------------------------
# full-server-state checkpointing + resume
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("fused,opt,mode", [
    (False, "adam", "post"),            # legacy per-leaf m/v/t
    (True, "adam", "post"),             # fused tuple-structured flat state
    (True, "sgdm", "post"),
    (True, "yogi", "through_aggregation"),   # + controllable ctrl slot
])
def test_server_state_checkpoint_roundtrip(key, tmp_path, fused, opt, mode):
    model = make_mlp_model()
    fed = FedConfig(algorithm="uga", meta=True, cohort=4, local_steps=2,
                    client_lr=0.05, server_lr=0.1, server_opt=opt,
                    fused_update=fused, meta_mode=mode)
    batch, meta, wts = _round_inputs()
    rf = jax.jit(make_federated_round(model, fed))
    state = init_server_state(model, fed, key)
    state, _ = rf(state, batch, meta, wts, key)        # non-trivial opt state
    path = os.path.join(tmp_path, "state.msgpack")
    save(path, state, extra={"algorithm": "uga"})
    restored, extra = restore(path, init_server_state(model, fed, key))
    assert extra["algorithm"] == "uga"
    assert jax.tree_util.tree_structure(restored) == \
        jax.tree_util.tree_structure(state)
    assert tree_equal(state, restored)
    assert int(restored["round"]) == 1

    # resuming must continue bit-identically to never having stopped
    state2, _ = rf(state, batch, meta, wts, jax.random.fold_in(key, 1))
    resumed2, _ = rf(restored, batch, meta, wts, jax.random.fold_in(key, 1))
    assert tree_equal(state2, resumed2)


def test_restore_params_only_checkpoint_into_state_errors(key, tmp_path):
    """Old drivers saved bare params; resuming those into a full server
    state must fail loudly, not KeyError deep in the blob."""
    model = make_mlp_model()
    fed = FedConfig(algorithm="uga", server_opt="adam")
    path = os.path.join(tmp_path, "params.msgpack")
    save(path, model.init(key))
    with pytest.raises(KeyError, match="different structure"):
        restore(path, init_server_state(model, fed, key))

"""Property tests for the paper's core algorithms (hypothesis-driven).

System invariants:
  I1  UGA with E=1 equals the central gradient on pooled data (§2.1: the
      one-step case is exactly Eq. (7); unbiasedness base case).
  I2  The HVP-form UGA equals straight autodiff through the keep-trace
      trajectory (implementation equivalence — exact same math).
  I3  Client-parallel (vmap) and client-sequential (scan) cohorts produce
      the same aggregate.
  I4  FedProx with mu=0 is exactly FedAvg.
  I5  Weighted aggregation is permutation-invariant and respects weights.
  I6  FedMeta's update moves params along -grad of the meta loss.
  I7  UGA == FedAvg pseudo-gradient direction at lr->0, E=2 (both reduce to
      the sum of microbatch gradients at w_t).
"""
import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests skip; unit tests still run
    from _hypothesis_stub import given, settings, st

from repro.core.aggregate import cohort_gradient, weighted_mean
from repro.core.client import (fedavg_update, make_client_update, uga_update,
                               uga_update_autodiff)
from repro.core.meta import meta_update

SETTINGS = dict(max_examples=12, deadline=None)


def quad_loss(w, batch, rng=None):
    pred = jnp.tanh(batch["x"] @ w["w1"]) @ w["w2"] + w["b"]
    return jnp.mean((pred - batch["y"]) ** 2), {}


def _problem(seed, cohort=3, b=8, d=5, h=7):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    w = {"w1": jax.random.normal(ks[0], (d, h)),
         "w2": jax.random.normal(ks[1], (h,)),
         "b": jnp.zeros(())}
    batch = {"x": jax.random.normal(ks[2], (cohort, b, d)),
             "y": jax.random.normal(ks[3], (cohort, b))}
    weights = jnp.asarray(np.random.default_rng(seed).integers(
        1, 20, cohort), jnp.float32)
    return w, batch, weights


@settings(**SETTINGS)
@given(seed=st.integers(0, 1000))
def test_I1_uga_e1_unbiased(seed):
    w, batch, weights = _problem(seed)
    cu = make_client_update("uga", quad_loss, local_steps=1)
    G, _ = cohort_gradient(cu, w, batch, weights, 0.05, None)
    # central gradient on the weighted pooled distribution
    def pooled(w0):
        per = jax.vmap(lambda bx, by: quad_loss(w0, {"x": bx, "y": by})[0])(
            batch["x"], batch["y"])
        return jnp.sum(per * weights) / jnp.sum(weights)
    central = jax.grad(pooled)(w)
    for a, b in zip(jax.tree.leaves(G), jax.tree.leaves(central)):
        np.testing.assert_allclose(a, b, atol=1e-5, rtol=1e-4)


@settings(**SETTINGS)
@given(seed=st.integers(0, 1000), steps=st.integers(2, 4),
       epochs=st.integers(1, 2))
def test_I2_hvp_equals_autodiff(seed, steps, epochs):
    w, batch, _ = _problem(seed, cohort=1, b=12)
    bt = jax.tree.map(lambda x: x[0], batch)
    g1, l1 = uga_update(quad_loss, w, bt, 0.1, None,
                        local_steps=steps, local_epochs=epochs)
    g2, l2 = uga_update_autodiff(quad_loss, w, bt, 0.1, None,
                                 local_steps=steps, local_epochs=epochs)
    np.testing.assert_allclose(l1, l2, rtol=1e-5)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(a, b, atol=1e-5, rtol=1e-4)


@settings(**SETTINGS)
@given(seed=st.integers(0, 1000), algo=st.sampled_from(["uga", "fedavg"]))
def test_I3_vmap_equals_scan(seed, algo):
    w, batch, weights = _problem(seed, cohort=4)
    cu = make_client_update(algo, quad_loss, local_steps=2)
    Gv, lv = cohort_gradient(cu, w, batch, weights, 0.05, None,
                             strategy="vmap")
    Gs, ls = cohort_gradient(cu, w, batch, weights, 0.05, None,
                             strategy="scan")
    np.testing.assert_allclose(lv, ls, rtol=1e-5)
    for a, b in zip(jax.tree.leaves(Gv), jax.tree.leaves(Gs)):
        np.testing.assert_allclose(a, b, atol=1e-5, rtol=1e-4)


@settings(**SETTINGS)
@given(seed=st.integers(0, 1000))
def test_I4_fedprox_mu0_is_fedavg(seed):
    w, batch, _ = _problem(seed, cohort=1)
    bt = jax.tree.map(lambda x: x[0], batch)
    fa = make_client_update("fedavg", quad_loss, local_steps=2)
    fp = make_client_update("fedprox", quad_loss, local_steps=2, prox_mu=0.0)
    ga, _ = fa(w, bt, 0.1, None)
    gp, _ = fp(w, bt, 0.1, None)
    for a, b in zip(jax.tree.leaves(ga), jax.tree.leaves(gp)):
        np.testing.assert_allclose(a, b, atol=0, rtol=0)


@settings(**SETTINGS)
@given(seed=st.integers(0, 1000))
def test_I5_weighted_mean_properties(seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(5, 4, 3)), jnp.float32)
    wgt = jnp.asarray(rng.integers(1, 9, 5), jnp.float32)
    m = weighted_mean({"x": x}, wgt)["x"]
    # permutation invariance
    perm = rng.permutation(5)
    m2 = weighted_mean({"x": x[perm]}, wgt[perm])["x"]
    np.testing.assert_allclose(m, m2, atol=1e-6)
    # scale invariance of weights
    m3 = weighted_mean({"x": x}, wgt * 7.0)["x"]
    np.testing.assert_allclose(m, m3, atol=1e-6)
    # equal weights == plain mean
    m4 = weighted_mean({"x": x}, jnp.ones(5))["x"]
    np.testing.assert_allclose(m4, jnp.mean(x, 0), atol=1e-6)


def test_I6_meta_update_descends():
    w, batch, _ = _problem(0, cohort=1)
    bt = jax.tree.map(lambda x: x[0], batch)
    l0 = quad_loss(w, bt)[0]
    w2, meta_l = meta_update(quad_loss, w, bt, 0.05)
    l1 = quad_loss(w2, bt)[0]
    assert float(l1) < float(l0)
    np.testing.assert_allclose(meta_l, l0, rtol=1e-6)


def test_I7_uga_fedavg_agree_at_small_lr():
    w, batch, _ = _problem(3, cohort=1, b=8)
    bt = jax.tree.map(lambda x: x[0], batch)
    # lr small enough for the first-order limit, large enough that the
    # fedavg pseudo-gradient (a parameter DIFFERENCE) isn't fp32-cancelled
    lr = 1e-3
    g_uga, _ = uga_update(quad_loss, w, bt, lr, None, local_steps=2)
    g_fa, _ = fedavg_update(quad_loss, w, bt, lr, None, local_steps=2)
    # fedavg pseudo-grad ~ lr * (g_mb1 + g_mb2) at lr->0; UGA's gradient
    # evaluation over the full batch ~ (g_mb1 + g_mb2)/2 — so
    # g_uga == g_fa / (2*lr) in the limit.
    for a, b in zip(jax.tree.leaves(g_uga), jax.tree.leaves(g_fa)):
        np.testing.assert_allclose(a, b / (2 * lr), rtol=6e-2, atol=6e-3)


def test_gradient_bias_is_real_and_uga_removes_it():
    """§2.1 demonstrated: with heterogeneous clients and E>1, the FedAvg
    pseudo-gradient direction diverges from the true gradient direction;
    UGA's aggregate IS the true gradient of the composed objective."""
    w, batch, weights = _problem(7, cohort=4, b=8)
    lr = 0.2  # large local lr => visible bias

    def cos(a, b):
        fa = jnp.concatenate([x.ravel() for x in jax.tree.leaves(a)])
        fb = jnp.concatenate([x.ravel() for x in jax.tree.leaves(b)])
        return float(fa @ fb / (jnp.linalg.norm(fa) * jnp.linalg.norm(fb)))

    # the UGA objective: mean_k L(h_k(w); D_k) — its true gradient
    def uga_objective(w0):
        def per_client(bx, by):
            bt = {"x": bx, "y": by}
            mb = jax.tree.map(lambda x: x[:4], bt)
            g = jax.grad(lambda ww: quad_loss(ww, mb)[0])(w0)
            w1 = jax.tree.map(lambda p, gi: p - lr * gi, w0, g)
            return quad_loss(w1, bt)[0]
        per = jax.vmap(per_client)(batch["x"], batch["y"])
        return jnp.sum(per * weights) / jnp.sum(weights)

    true_g = jax.grad(uga_objective)(w)
    cu = make_client_update("uga", quad_loss, local_steps=2)
    G_uga, _ = cohort_gradient(cu, w, batch, weights, lr, None)
    fa = make_client_update("fedavg", quad_loss, local_steps=2)
    G_fa, _ = cohort_gradient(fa, w, batch, weights, lr, None)

    assert cos(G_uga, true_g) > 0.9999           # unbiased
    assert cos(G_fa, true_g) < cos(G_uga, true_g)  # fedavg is biased

"""Regression watch (PR 10): ``repro.obs.regress`` + the
``python -m repro.obs.compare`` CLI.

Exit-code contract under test: 0 = within tolerance, 1 = breach,
2 = refusal (schema / config mismatch — apples to oranges).  Run-dir
mode is driven by synthetic hand-written ``metrics.jsonl`` files so the
deltas are exactly computable; bench-file mode by stamped reports from
``benchmarks.common.write_bench_report``.
"""
import json
import os
import sys

import pytest

from repro.obs.compare import main as compare_main
from repro.obs.regress import (Tolerances, compare_bench_files,
                               compare_run_dirs, summarize_run)
from repro.obs.report import main as report_main

# ---------------------------------------------------------------------------
# synthetic run dirs
# ---------------------------------------------------------------------------


def _write_run(run_dir, *, rounds=4, loss0=2.0, loss_step=-0.1,
               dispatch_s=0.10, sync_s=0.02, extra_key=None,
               comm_bytes=None, temp_bytes=1000):
    os.makedirs(run_dir, exist_ok=True)
    lines = [{"kind": "event", "event": "run_start", "t": 0.0}]
    for r in range(rounds):
        rec = {"kind": "metrics", "round": r,
               "client_loss": loss0 + r * loss_step, "grad_norm": 1.0}
        if extra_key:
            rec[extra_key] = 0.0
        if comm_bytes is not None:
            rec["comm_bytes"] = comm_bytes
        lines.append(rec)
        lines.append({"kind": "event", "event": "phase",
                      "phase": "dispatch", "dur_s": dispatch_s})
        lines.append({"kind": "event", "event": "phase",
                      "phase": "device_sync", "dur_s": sync_s})
    lines.append({"kind": "event", "event": "roofline",
                  "rounds_per_call": 1, "predicted_rounds_per_s": 100.0,
                  "memory": {"temp_size_in_bytes": temp_bytes}})
    lines.append({"kind": "event", "event": "run_finish", "t": 1.0})
    with open(os.path.join(run_dir, "metrics.jsonl"), "w") as f:
        for ln in lines:
            f.write(json.dumps(ln) + "\n")
    return run_dir


def test_summarize_run(tmp_path):
    s = summarize_run(_write_run(str(tmp_path / "a")))
    assert s["rounds"] == 4
    assert s["metric_keys"] == ["client_loss", "grad_norm", "round"]
    assert s["final_loss"] == pytest.approx(1.7)
    assert s["min_loss"] == pytest.approx(1.7)
    # 4 rounds / (4 * 0.12 s of dispatch+sync)
    assert s["rounds_per_s"] == pytest.approx(4 / 0.48)
    assert s["phase_s"]["dispatch"] == pytest.approx(0.4)
    assert s["peak_temp_bytes"] == 1000
    assert s["roofline"]["predicted_rounds_per_s"] == 100.0


def test_summarize_run_missing_jsonl(tmp_path):
    with pytest.raises(FileNotFoundError, match="tracker"):
        summarize_run(str(tmp_path))


def test_identical_run_dirs_pass(tmp_path):
    a = _write_run(str(tmp_path / "a"))
    b = _write_run(str(tmp_path / "b"))
    code, deltas = compare_run_dirs(a, b)
    assert code == 0
    assert all(d.status in ("ok", "info") for d in deltas)


def test_throughput_regression_breaches(tmp_path):
    a = _write_run(str(tmp_path / "a"), dispatch_s=0.10)
    # 3x slower dispatch: rounds_per_s drops ~64% > 25% tol, and the
    # dispatch phase total grows 3x > 25% + 0.05 s slack
    b = _write_run(str(tmp_path / "b"), dispatch_s=0.30)
    code, deltas = compare_run_dirs(a, b)
    assert code == 1
    breached = {d.name for d in deltas if d.status == "BREACH"}
    assert "rounds_per_s" in breached
    assert "phase_s.dispatch" in breached
    # loosening the tolerance clears it
    code, _ = compare_run_dirs(a, b, Tolerances(perf_rel=0.95,
                                                phase_rel=3.0))
    assert code == 0


def test_loss_regression_breaches(tmp_path):
    a = _write_run(str(tmp_path / "a"), loss0=2.0)
    b = _write_run(str(tmp_path / "b"), loss0=2.2)   # +10% > 2% tol
    code, deltas = compare_run_dirs(a, b)
    assert any(d.name == "final_loss" and d.status == "BREACH"
               for d in deltas)
    assert code == 1


def test_memory_growth_breaches(tmp_path):
    a = _write_run(str(tmp_path / "a"), temp_bytes=1000)
    b = _write_run(str(tmp_path / "b"), temp_bytes=1200)  # +20% > 10%
    code, deltas = compare_run_dirs(a, b)
    assert any(d.name == "peak_temp_bytes" and d.status == "BREACH"
               for d in deltas)
    assert code == 1


def test_comm_bytes_two_sided(tmp_path):
    a = _write_run(str(tmp_path / "a"), comm_bytes=1000)
    b = _write_run(str(tmp_path / "b"), comm_bytes=900)  # smaller is
    code, deltas = compare_run_dirs(a, b)                # still a delta
    assert any(d.name == "comm_bytes" and d.status == "BREACH"
               for d in deltas)
    assert code == 1


def test_metric_key_drift_refuses(tmp_path):
    a = _write_run(str(tmp_path / "a"))
    b = _write_run(str(tmp_path / "b"), extra_key="meta_loss")
    code, deltas = compare_run_dirs(a, b)
    assert code == 2
    assert deltas[0].status == "REFUSE"
    assert "meta_loss" in deltas[0].note


def test_round_count_mismatch_refuses(tmp_path):
    a = _write_run(str(tmp_path / "a"), rounds=4)
    b = _write_run(str(tmp_path / "b"), rounds=5)
    code, deltas = compare_run_dirs(a, b)
    assert code == 2 and deltas[0].name == "rounds"


# ---------------------------------------------------------------------------
# bench-file mode
# ---------------------------------------------------------------------------
def _bench_report(path, *, bench="round_latency", host="ci-1",
                  jaxv="0.4.37", cohort=8, per_s=50.0, ok=True,
                  bytes_=4096):
    rep = {"meta": {"bench": bench,
                    "config": {"cohort": cohort, "rounds": 10},
                    "host": host, "jax_version": jaxv},
           "rounds_per_s": per_s, "uplink_bytes": bytes_,
           "gates": {"pass_latency": ok}, "note": "synthetic"}
    with open(path, "w") as f:
        json.dump(rep, f)
    return str(path)


def test_bench_identical_pass(tmp_path):
    a = _bench_report(tmp_path / "a.json")
    b = _bench_report(tmp_path / "b.json")
    code, deltas = compare_bench_files(a, b)
    assert code == 0
    assert not [d for d in deltas if d.status in ("BREACH", "REFUSE")]


def test_bench_name_mismatch_refuses(tmp_path):
    a = _bench_report(tmp_path / "a.json", bench="round_latency")
    b = _bench_report(tmp_path / "b.json", bench="cohort_scaling")
    code, deltas = compare_bench_files(a, b)
    assert code == 2 and deltas[0].name == "meta.bench"


def test_bench_config_mismatch_refuses_unless_ignored(tmp_path):
    a = _bench_report(tmp_path / "a.json", cohort=8)
    b = _bench_report(tmp_path / "b.json", cohort=16)
    code, deltas = compare_bench_files(a, b)
    assert code == 2
    refusal = [d for d in deltas if d.status == "REFUSE"][0]
    assert refusal.name == "meta.config.cohort"
    assert "--ignore-config" in refusal.note
    code, _ = compare_bench_files(a, b, ignore_config=("cohort",))
    assert code == 0


def test_bench_host_drift_warns_not_refuses(tmp_path):
    a = _bench_report(tmp_path / "a.json", host="ci-1")
    b = _bench_report(tmp_path / "b.json", host="laptop")
    code, deltas = compare_bench_files(a, b)
    assert code == 0
    assert any(d.name == "meta.host" and d.status == "warn"
               for d in deltas)


def test_bench_gate_flip_breaches(tmp_path):
    a = _bench_report(tmp_path / "a.json", ok=True)
    b = _bench_report(tmp_path / "b.json", ok=False)
    code, deltas = compare_bench_files(a, b)
    assert code == 1
    assert any(d.name == "gates.pass_latency" and d.status == "BREACH"
               for d in deltas)
    # the reverse direction (newly passing) is informational
    code, _ = compare_bench_files(b, a)
    assert code == 0


def test_bench_perf_drop_breaches_and_tolerance_loosens(tmp_path):
    a = _bench_report(tmp_path / "a.json", per_s=50.0)
    b = _bench_report(tmp_path / "b.json", per_s=30.0)   # -40% > 25%
    code, deltas = compare_bench_files(a, b)
    assert code == 1
    assert any(d.name == "rounds_per_s" for d in deltas)
    code, _ = compare_bench_files(a, b, Tolerances(perf_rel=0.5))
    assert code == 0
    # faster is never a breach
    code, _ = compare_bench_files(b, a)
    assert code == 0


def test_bench_bytes_drift_breaches(tmp_path):
    a = _bench_report(tmp_path / "a.json", bytes_=4096)
    b = _bench_report(tmp_path / "b.json", bytes_=4000)
    code, deltas = compare_bench_files(a, b)
    assert code == 1
    assert any(d.name == "uplink_bytes" for d in deltas)


def test_bench_missing_meta_warns(tmp_path):
    a = tmp_path / "a.json"
    a.write_text(json.dumps({"rounds_per_s": 50.0}))     # pre-PR10 file
    b = _bench_report(tmp_path / "b.json", per_s=50.0)
    code, deltas = compare_bench_files(str(a), str(b))
    assert deltas[0].status == "warn" and "meta" in deltas[0].name
    assert code == 2   # body keys then differ -> schema-drift refusal


def test_write_bench_report_stamps_meta(tmp_path):
    import jax
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir,
                                    "benchmarks"))
    try:
        from common import write_bench_report
    finally:
        sys.path.pop(0)
    out = tmp_path / "BENCH_x.json"
    stamped = write_bench_report(str(out), {"ok": True,
                                            "config": {"cohort": 4}},
                                 bench="x")
    on_disk = json.loads(out.read_text())
    assert on_disk == stamped
    assert stamped["meta"]["bench"] == "x"
    assert stamped["meta"]["config"] == {"cohort": 4}
    assert stamped["meta"]["jax_version"] == jax.__version__
    assert stamped["ok"] is True
    # two identically-configured stamped reports compare clean
    out2 = tmp_path / "BENCH_y.json"
    write_bench_report(str(out2), {"ok": True, "config": {"cohort": 4}},
                       bench="x")
    code, _ = compare_bench_files(str(out), str(out2))
    assert code == 0


# ---------------------------------------------------------------------------
# the CLIs
# ---------------------------------------------------------------------------
def test_compare_cli_run_dirs(tmp_path, capsys):
    a = _write_run(str(tmp_path / "a"))
    b = _write_run(str(tmp_path / "b"), dispatch_s=0.30)
    assert compare_main([a, a]) == 0
    assert compare_main([a, b]) == 1
    assert compare_main([a, b, "--perf-rel-tol", "0.95",
                         "--phase-rel-tol", "3.0"]) == 0
    out = capsys.readouterr().out
    assert "PASS" in out and "BREACH" in out


def test_compare_cli_mixed_modes_refuse(tmp_path):
    run = _write_run(str(tmp_path / "a"))
    bench = _bench_report(tmp_path / "b.json")
    assert compare_main([run, bench]) == 2
    assert compare_main([str(tmp_path / "nope"), run]) == 2


def test_compare_cli_bench_files(tmp_path):
    a = _bench_report(tmp_path / "a.json", cohort=8)
    b = _bench_report(tmp_path / "b.json", cohort=16)
    assert compare_main([a, b]) == 2
    assert compare_main([a, b, "--ignore-config", "cohort"]) == 0


def test_report_cli(tmp_path, capsys):
    run = _write_run(str(tmp_path / "a"))
    assert report_main([run]) == 0
    out = capsys.readouterr().out
    assert "rounds" in out and "dispatch" in out
    assert report_main([run, "--json"]) == 0
    parsed = json.loads(capsys.readouterr().out)
    assert parsed["rounds"] == 4
    assert report_main([str(tmp_path / "missing")]) == 2

"""Round-metrics schema pins (PR 9).

``repro.obs.schema.round_metric_keys`` documents exactly which keys a
tracker sees per FedConfig; these tests pin REAL trainer records — sync
``fused_flat`` and ``legacy_tree``, the through-aggregation meta mode,
fault/participation/retry counters, lossy-codec ``comm_bytes``, and the
``buffered_async`` runtime's ``staleness_*`` family — against it, so a
round refactor that drops or renames a metric fails here instead of
silently breaking every downstream consumer.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FedConfig
from repro.core import FederatedTrainer
from repro.data.pipeline import FederatedData
from repro.models.model import Model
from repro.obs import VECTOR_METRICS, round_metric_keys

COHORT, BATCH = 4, 16


def make_mlp_model(d=10, h=16, classes=4):
    def init(k):
        k1, k2 = jax.random.split(k)
        return {"w1": jax.random.normal(k1, (d, h)) * 0.3,
                "w2": jax.random.normal(k2, (h, classes)) * 0.3}

    def loss(w, batch, rng=None):
        logits = jnp.tanh(batch["x"] @ w["w1"]) @ w["w2"]
        l = -jnp.mean(jnp.take_along_axis(
            jax.nn.log_softmax(logits), batch["y"][:, None], 1))
        return l, {}

    return Model(name="mlp", init=init, loss=loss)


def _toy_fed_data(n=256, clients=8, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(0, 1, (n, 10)).astype(np.float32)
    y = rng.integers(0, 4, n).astype(np.int32)
    parts = np.array_split(rng.permutation(n), clients)
    meta = rng.choice(n, 32, replace=False)
    return FederatedData(arrays={"x": x, "y": y}, client_indices=parts,
                         meta_indices=meta, seed=seed)


def _records(fed, rounds=2, rounds_per_call=1):
    model, data = make_mlp_model(), _toy_fed_data()
    tr = FederatedTrainer(model, fed, rounds_per_call=rounds_per_call,
                          seed=0)
    return tr.run(data, rounds=rounds, cohort=COHORT, batch=BATCH,
                  meta_batch=8)


def _assert_schema(fed, recs):
    want = round_metric_keys(fed)
    for rec in recs:
        assert frozenset(rec) == want, (sorted(rec), sorted(want))
        for k, v in rec.items():
            if k == "round":
                assert isinstance(v, int)
            elif k in VECTOR_METRICS:
                assert isinstance(v, list)
            else:
                assert isinstance(v, float)


BASE = FedConfig(cohort=COHORT, local_steps=2, client_lr=0.05,
                 server_lr=0.1, meta_lr=0.05, clip_norm=1.0)


@pytest.mark.parametrize("fused", [True, False],
                         ids=["fused_flat", "legacy_tree"])
def test_sync_plain_and_meta_schema(fused):
    fed = dataclasses.replace(BASE, algorithm="uga", meta=True,
                              fused_update=fused)
    recs = _records(fed)
    _assert_schema(fed, recs)
    assert round_metric_keys(fed) == frozenset(
        {"round", "client_loss", "grad_norm", "meta_loss"})


def test_sync_no_meta_schema():
    fed = dataclasses.replace(BASE, algorithm="fedavg", meta=False)
    _assert_schema(fed, _records(fed, rounds_per_call=2))
    assert round_metric_keys(fed) == frozenset(
        {"round", "client_loss", "grad_norm"})


def test_through_aggregation_ctrl_schema():
    fed = dataclasses.replace(BASE, algorithm="uga", meta=True,
                              fused_update=True,
                              meta_mode="through_aggregation")
    recs = _records(fed)
    _assert_schema(fed, recs)
    assert {"ctrl_w_gnorm", "ctrl_lr_grad", "server_lr_eff",
            "meta_loss"} <= round_metric_keys(fed)


def test_sync_fault_retry_participation_schema():
    fed = dataclasses.replace(BASE, algorithm="fedavg", meta=False,
                              fused_update=True, participation=0.75,
                              fault_profile="flaky", round_deadline=2.0,
                              retry_backoff=2)
    recs = _records(fed, rounds=3)
    _assert_schema(fed, recs)
    assert {"participants", "arrivals", "fault_crashed", "fault_dropped",
            "fault_timeout", "retried"} <= round_metric_keys(fed)


def test_lossy_codec_comm_bytes_schema():
    fed = dataclasses.replace(BASE, algorithm="uga", meta=False,
                              fused_update=True, codec="int8",
                              error_feedback=True)
    recs = _records(fed)
    _assert_schema(fed, recs)
    assert "comm_bytes" in round_metric_keys(fed)
    assert all(rec["comm_bytes"] > 0 for rec in recs)


def test_buffered_async_schema():
    fed = dataclasses.replace(BASE, algorithm="uga", meta=True,
                              fused_update=True, cohort_strategy="scan",
                              engine="buffered_async",
                              async_buffer=COHORT // 2,
                              async_capacity=2 * COHORT,
                              async_max_staleness=4,
                              fault_profile="stragglers")
    recs = _records(fed, rounds=3)
    _assert_schema(fed, recs)
    keys = round_metric_keys(fed)
    assert {"arrivals", "server_steps", "buffer_fill", "overflow_dropped",
            "staleness_mean", "staleness_max", "staleness_hist",
            "fault_crashed", "fault_dropped", "fault_delayed", "expired",
            "meta_loss"} <= keys
    assert "staleness_hist" in VECTOR_METRICS


def test_schema_is_frozen_and_trainer_flag():
    fed = dataclasses.replace(BASE, algorithm="uga", meta=True)
    keys = round_metric_keys(fed)
    assert isinstance(keys, frozenset)
    # trainer=False drops the host-side additions
    raw = round_metric_keys(fed, trainer=False)
    assert "round" not in raw and raw <= keys


# ---------------------------------------------------------------------------
# analysis-event schemas (PR 10): the roofline / profile_summary payloads
# the trainer emits are pinned to the frozensets in repro.obs.schema, the
# same way round records are pinned to round_metric_keys above.
# ---------------------------------------------------------------------------
def test_roofline_event_schema_matches_live_payload():
    from repro.obs import ROOFLINE_EVENT_KEYS
    from repro.roofline.live import round_roofline_event

    fn = jax.jit(lambda x: (x @ x.T).sum())
    ev = round_roofline_event(
        fn, (jax.ShapeDtypeStruct((8, 8), jnp.float32),),
        rounds_per_call=2)
    assert ev is not None
    # live.py produces everything except the trainer's measured_* triple
    measured = {"measured_rounds_per_s", "measured_s_per_round",
                "rounds_measured"}
    assert set(ev) == set(ROOFLINE_EVENT_KEYS) - measured
    assert measured < ROOFLINE_EVENT_KEYS
    assert ev["rounds_per_call"] == 2

    # a callable without .lower (sanitize-mode closure) is skipped, and
    # the skip is a None — not a crash, not a partial event
    assert round_roofline_event(lambda x: x, (1.0,)) is None


def test_profile_summary_event_schema_matches_summarizer():
    from repro.obs import PROFILE_SUMMARY_EVENT_KEYS
    from repro.obs.trace_analysis import summarize

    payload = summarize({"traceEvents": []})
    assert set(payload) | {"trace"} == set(PROFILE_SUMMARY_EVENT_KEYS)

"""--sanitize runtime smoke tests.

A clean sanitized run must be bit-identical to the unsanitized build (the
checkify transform is observability, not arithmetic), and an injected
NaN payload — a garbled async uplink whose multiplier range is infinite —
must be caught the round it happens with an error that names the flat
aggregate group.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from jax.experimental.checkify import JaxRuntimeError

from repro.configs.base import FedConfig
from repro.core import FederatedTrainer
from repro.core.flat import flatten_tree, make_flat_spec
from repro.core.sanitize import (check_flat_groups, checkify_round,
                                 throw_if_error)
from test_async_faults import (COHORT, _toy_fed_data, make_mlp_model,
                               tree_equal)


def _sync_fed():
    return FedConfig(cohort=COHORT, fused_update=True,
                     cohort_strategy="scan", meta=False)


def _async_fed(**over):
    base = FedConfig(cohort=COHORT, fused_update=True,
                     cohort_strategy="scan", meta=False,
                     engine="buffered_async", async_capacity=2 * COHORT)
    return dataclasses.replace(base, **over) if over else base


# ---------------------------------------------------------------------------
# clean runs: sanitizer is additive
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("fed_fn", [_sync_fed, _async_fed],
                         ids=["sync", "async"])
def test_sanitized_clean_run_bit_identical(fed_fn):
    model, data = make_mlp_model(), _toy_fed_data()
    states = []
    for sanitize in (False, True):
        tr = FederatedTrainer(model, fed_fn(), rounds_per_call=1, seed=0,
                              sanitize=sanitize)
        hist = tr.run(data, rounds=2, cohort=COHORT, batch=8)
        assert len(hist) == 2
        states.append(tr.state)
        assert all(np.isfinite(np.asarray(l)).all()
                   for l in jax.tree.leaves(tr.state["params"]))
    assert tree_equal(states[0]["params"], states[1]["params"])


# ---------------------------------------------------------------------------
# injected NaN payload is caught, with the flat group named
# ---------------------------------------------------------------------------
def test_nan_garble_payload_caught_by_sanitizer():
    # garble every alive client; U(-inf, inf) multipliers are NaN, so the
    # decoded deltas hitting the pool are non-finite
    fed = _async_fed(fault_garble=1.0, fault_garble_scale=float("inf"))
    model, data = make_mlp_model(), _toy_fed_data()
    tr = FederatedTrainer(model, fed, rounds_per_call=1, seed=0,
                          sanitize=True)
    with pytest.raises(JaxRuntimeError, match="flat group"):
        tr.run(data, rounds=2, cohort=COHORT, batch=8)


def test_nan_garble_unsanitized_is_silent():
    # the failure mode the sanitizer exists for: without it the poisoned
    # round completes and the NaN lands in the server parameters
    fed = _async_fed(fault_garble=1.0, fault_garble_scale=float("inf"))
    model, data = make_mlp_model(), _toy_fed_data()
    tr = FederatedTrainer(model, fed, rounds_per_call=1, seed=0)
    tr.run(data, rounds=2, cohort=COHORT, batch=8)
    leaves = jax.tree.leaves(tr.state["params"])
    assert any(not np.isfinite(np.asarray(l)).all() for l in leaves)


# ---------------------------------------------------------------------------
# probe unit test: the message is actionable
# ---------------------------------------------------------------------------
def test_check_flat_groups_message_names_group_and_site():
    model = make_mlp_model()
    params = model.init(jax.random.PRNGKey(0))
    spec = make_flat_spec(params)

    def probe(bufs):
        check_flat_groups(spec, bufs, "unit-test probe")
        return bufs

    bufs = flatten_tree(spec, params)
    err, _ = jax.jit(checkify_round(probe))(bufs)
    throw_if_error(err)                       # clean buffers: no error

    poisoned = [b.at[0, 0].set(jnp.nan) for b in bufs]
    err, _ = jax.jit(checkify_round(probe))(poisoned)
    with pytest.raises(JaxRuntimeError) as exc_info:
        throw_if_error(err)
    msg = str(exc_info.value)
    assert "flat group 0" in msg
    assert "unit-test probe" in msg
    assert "unflatten_tree" in msg

"""Observability subsystem (PR 9): tracker registry, phase spans, the
round profiler, the managed checkpoint store, and their trainer wiring.

  * tracker registry: the five built-ins, ``register_tracker`` plugins,
    ``resolve_tracker`` over names / instances / comma lists, actionable
    errors for unknown names and missing run dirs;
  * jsonl/csv round-trip, csv pinned-header enforcement, composite
    fan-out, post-finish logging rejected;
  * trainer integration: every record reaches the tracker, run_start /
    run_finish / phase events bracket it, a noop-tracked run is
    bit-identical to an untracked one, ``--profile``-style capture
    writes a trace directory;
  * history persistence (the PR 9 bugfix): ``save``/``restore`` carries
    ``trainer.history``, and a resumed run's history + state are
    bit-identical to never stopping — sync and ``buffered_async``;
  * CheckpointManager: retention leaves exactly ``keep_last`` blobs (+
    ``keep_every`` milestones), restore_latest round-trips, manifests
    survive process-fresh reads, non-monotonic steps and worker errors
    are loud.
"""
import dataclasses
import json
import os
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs.base import FedConfig
from repro.core import FederatedTrainer
from repro.data.pipeline import FederatedData
from repro.models.model import Model
from repro.obs import (CompositeTracker, JsonlTracker, MetricsTracker,
                       NoopTracker, available_trackers, get_tracker,
                       register_tracker, resolve_tracker, span)

COHORT, BATCH = 4, 16


def make_mlp_model(d=10, h=16, classes=4):
    def init(k):
        k1, k2 = jax.random.split(k)
        return {"w1": jax.random.normal(k1, (d, h)) * 0.3,
                "w2": jax.random.normal(k2, (h, classes)) * 0.3}

    def loss(w, batch, rng=None):
        logits = jnp.tanh(batch["x"] @ w["w1"]) @ w["w2"]
        l = -jnp.mean(jnp.take_along_axis(
            jax.nn.log_softmax(logits), batch["y"][:, None], 1))
        return l, {}

    return Model(name="mlp", init=init, loss=loss)


def _toy_fed_data(n=256, clients=8, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(0, 1, (n, 10)).astype(np.float32)
    y = rng.integers(0, 4, n).astype(np.int32)
    parts = np.array_split(rng.permutation(n), clients)
    meta = rng.choice(n, 32, replace=False)
    return FederatedData(arrays={"x": x, "y": y}, client_indices=parts,
                         meta_indices=meta, seed=seed)


BASE = FedConfig(algorithm="uga", meta=True, cohort=COHORT, local_steps=2,
                 client_lr=0.05, server_lr=0.1, meta_lr=0.05,
                 clip_norm=1.0, fused_update=True)


def tree_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(la, lb))


def read_jsonl(path):
    with open(path) as f:
        return [json.loads(line) for line in f]


# ---------------------------------------------------------------------------
# registry + resolution
# ---------------------------------------------------------------------------
def test_builtin_trackers_registered():
    assert {"noop", "console", "jsonl", "csv",
            "composite"} <= set(available_trackers())


def test_unknown_tracker_is_actionable():
    with pytest.raises(ValueError, match="metrics tracker.*jsonl"):
        get_tracker("wandb")


def test_register_tracker_plugin_and_resolution(tmp_path):
    seen = []

    @register_tracker("obs_test_memory")
    class MemoryTracker(MetricsTracker):
        name = "obs_test_memory"

        def __init__(self, run_dir=None):
            pass

        def log_metrics(self, r, m):
            seen.append((r, m))

        def log_event(self, name, data=None):
            pass

        def finish(self):
            pass

    t = resolve_tracker("obs_test_memory")
    t.log_metrics(0, {"x": 1.0})
    assert seen == [(0, {"x": 1.0})]
    # comma list -> composite; instance passthrough; None -> noop
    combo = resolve_tracker("obs_test_memory,noop", run_dir=str(tmp_path))
    assert isinstance(combo, CompositeTracker)
    assert resolve_tracker(t) is t
    assert isinstance(resolve_tracker(None), NoopTracker)


def test_file_tracker_requires_run_dir():
    with pytest.raises(ValueError, match="run "):
        resolve_tracker("jsonl")
    with pytest.raises(ValueError, match="run "):
        resolve_tracker("csv")


# ---------------------------------------------------------------------------
# jsonl / csv / span behavior
# ---------------------------------------------------------------------------
def test_jsonl_records_events_and_span(tmp_path):
    t = resolve_tracker("jsonl", run_dir=str(tmp_path))
    t.log_metrics(0, {"round": 0, "client_loss": 1.5,
                      "staleness_hist": [1.0, 2.0]})
    with span(t, "dispatch", round=0):
        pass
    t.finish()
    lines = read_jsonl(tmp_path / "metrics.jsonl")
    assert lines[0] == {"kind": "metrics", "round": 0, "client_loss": 1.5,
                        "staleness_hist": [1.0, 2.0]}
    assert lines[1]["kind"] == "event" and lines[1]["event"] == "phase"
    assert lines[1]["phase"] == "dispatch" and lines[1]["dur_s"] >= 0
    with pytest.raises(RuntimeError, match="finish"):
        t.log_metrics(1, {"x": 1.0})
    t.finish()  # idempotent


def test_csv_header_pinned_to_first_record(tmp_path):
    t = resolve_tracker("csv", run_dir=str(tmp_path))
    t.log_metrics(0, {"round": 0, "b": 1.0, "a": 2.0})
    t.log_metrics(1, {"round": 1, "b": 3.0, "a": 4.0})
    with pytest.raises(ValueError, match="pinned"):
        t.log_metrics(2, {"round": 2, "b": 1.0, "c": 9.0})
    t.log_event("run_finish", {})
    t.finish()
    rows = (tmp_path / "metrics.csv").read_text().strip().splitlines()
    assert rows[0] == "round,a,b"
    assert rows[1] == "0,2.0,1.0"
    assert (tmp_path / "events.csv").exists()


def test_csv_tracker_appends_across_resume(tmp_path):
    """A second csv tracker over the same run dir (--resume auto) extends
    the file instead of truncating it, re-pins the on-disk header, and
    writes the events header exactly once."""
    t = resolve_tracker("csv", run_dir=str(tmp_path))
    t.log_metrics(0, {"round": 0, "a": 1.0})
    t.log_event("run_finish", {})
    t.finish()
    t2 = resolve_tracker("csv", run_dir=str(tmp_path))
    t2.log_metrics(1, {"round": 1, "a": 2.0})
    with pytest.raises(ValueError, match="pinned"):
        t2.log_metrics(2, {"round": 2, "b": 3.0})
    t2.log_event("run_finish", {})
    t2.finish()
    rows = (tmp_path / "metrics.csv").read_text().strip().splitlines()
    assert rows == ["round,a", "0,1.0", "1,2.0"]
    erows = (tmp_path / "events.csv").read_text().strip().splitlines()
    assert erows[0] == "t,event,data" and len(erows) == 3


def test_console_tracker_prints_every_and_final(capsys):
    t = resolve_tracker("console")
    t.log_event("run_start", {"final_round": 3})
    for r in range(4):
        t.log_metrics(r, {"round": r, "client_loss": float(r)})
    out = capsys.readouterr().out
    # every=1 default: all rounds printed, floats formatted
    assert out.count("[train] round") == 4
    assert "client_loss=2.0000" in out


# ---------------------------------------------------------------------------
# trainer wiring
# ---------------------------------------------------------------------------
def test_trainer_feeds_tracker_and_events(tmp_path):
    model, data = make_mlp_model(), _toy_fed_data()
    tr = FederatedTrainer(model, BASE, rounds_per_call=2, seed=0,
                          tracker="jsonl", run_dir=str(tmp_path))
    hist = tr.run(data, rounds=4, cohort=COHORT, batch=BATCH, meta_batch=8)
    tr.finish()
    lines = read_jsonl(tmp_path / "metrics.jsonl")
    events = [ln["event"] for ln in lines if ln["kind"] == "event"]
    metrics = [ln for ln in lines if ln["kind"] == "metrics"]
    assert events[0] == "run_start" and events[-1] == "run_finish"
    assert {"sample_stack", "dispatch", "device_sync"} <= {
        ln.get("phase") for ln in lines if ln.get("event") == "phase"}
    assert [m["round"] for m in metrics] == [0, 1, 2, 3]
    # jsonl record content == returned history record
    assert metrics[0]["client_loss"] == hist[0]["client_loss"]


def test_noop_tracked_run_bit_identical_to_untracked():
    model, data = make_mlp_model(), _toy_fed_data()
    a = FederatedTrainer(model, BASE, rounds_per_call=2, seed=0)
    b = FederatedTrainer(model, BASE, rounds_per_call=2, seed=0,
                         tracker="noop")
    ha = a.run(data, rounds=4, cohort=COHORT, batch=BATCH, meta_batch=8)
    hb = b.run(data, rounds=4, cohort=COHORT, batch=BATCH, meta_batch=8)
    assert tree_equal(a.state, b.state)
    assert ha == hb


def test_profiler_writes_trace_window(tmp_path):
    model, data = make_mlp_model(), _toy_fed_data()
    tr = FederatedTrainer(model, BASE, seed=0, tracker="jsonl",
                          run_dir=str(tmp_path), profile=1,
                          profile_start=1)
    tr.run(data, rounds=3, cohort=COHORT, batch=BATCH, meta_batch=8)
    tr.finish()
    trace_root = tmp_path / "profile"
    assert trace_root.is_dir()
    assert any(f.endswith(".xplane.pb")
               for _, _, fs in os.walk(trace_root) for f in fs)
    events = [ln for ln in read_jsonl(tmp_path / "metrics.jsonl")
              if ln["kind"] == "event"]
    starts = [e for e in events if e["event"] == "profile_start"]
    stops = [e for e in events if e["event"] == "profile_stop"]
    assert len(starts) == 1 and len(stops) == 1


def test_run_finishes_per_call_tracker_override(tmp_path):
    """A tracker override resolved inside run() is owned by that call:
    its rows are flushed to disk when run() returns, without the caller
    ever holding (or finishing) the instance."""
    model, data = make_mlp_model(), _toy_fed_data()
    tr = FederatedTrainer(model, BASE, seed=0, run_dir=str(tmp_path))
    tr.run(data, rounds=2, cohort=COHORT, batch=BATCH, meta_batch=8,
           tracker="csv")
    rows = (tmp_path / "metrics.csv").read_text().strip().splitlines()
    assert len(rows) == 3  # header + one row per round
    # a caller-passed INSTANCE stays open across calls (caller owns it)
    shared = resolve_tracker("jsonl", run_dir=str(tmp_path))
    tr.run(data, rounds=4, cohort=COHORT, batch=BATCH, meta_batch=8,
           tracker=shared)
    tr.run(data, rounds=6, cohort=COHORT, batch=BATCH, meta_batch=8,
           tracker=shared)
    shared.finish()
    recs = [ln for ln in read_jsonl(tmp_path / "metrics.jsonl")
            if ln["kind"] == "metrics"]
    assert [m["round"] for m in recs] == [2, 3, 4, 5]
    tr.finish()


def test_profiler_opens_on_chunk_overlapping_window(tmp_path, monkeypatch):
    """profile_start falling mid-chunk must open the capture on the chunk
    that CONTAINS it (window widened to chunk boundaries), not one chunk
    late."""
    from repro.obs.profiler import RoundProfiler
    monkeypatch.setattr(jax.profiler, "start_trace", lambda d: None)
    monkeypatch.setattr(jax.profiler, "stop_trace", lambda: None)
    events = []

    class Rec(MetricsTracker):
        def log_metrics(self, r, m):
            pass

        def log_event(self, name, data=None):
            events.append(name)

        def finish(self):
            pass

    p = RoundProfiler(str(tmp_path), start=5, rounds=1, tracker=Rec())
    # chunks of k=4: [0,4) misses the window, [4,8) contains round 5
    assert not p.maybe_start(0, 4)
    p.maybe_stop(4)
    assert p.maybe_start(4, 4)
    p.maybe_stop(8)
    assert events == ["profile_start", "profile_stop"]


def test_profile_without_run_dir_is_actionable():
    model = make_mlp_model()
    with pytest.raises(ValueError, match="run "):
        FederatedTrainer(model, BASE, seed=0, profile=2)


# ---------------------------------------------------------------------------
# history persistence (the PR 9 bugfix) + manager resume
# ---------------------------------------------------------------------------
def test_save_restore_carries_history_and_extra(tmp_path):
    model, data = make_mlp_model(), _toy_fed_data()
    path = str(tmp_path / "ck.msgpack")
    tr = FederatedTrainer(model, BASE, rounds_per_call=2, seed=0)
    full = tr.run(data, rounds=6, cohort=COHORT, batch=BATCH, meta_batch=8)

    half = FederatedTrainer(model, BASE, rounds_per_call=2, seed=0)
    half.run(data, rounds=2, cohort=COHORT, batch=BATCH, meta_batch=8)
    half.save(path, extra={"arch": "mlp"})

    resumed = FederatedTrainer(model, BASE, rounds_per_call=2, seed=0)
    extra = resumed.restore(path)
    assert extra == {"arch": "mlp"}          # history slot is internal
    assert resumed.history == full[:2]       # the bug: this was [] before
    tail = resumed.run(data, rounds=6, cohort=COHORT, batch=BATCH,
                       meta_batch=8)
    assert tail == full[2:]                  # run() returns this call only
    assert resumed.history == full           # ...while history is complete
    assert tree_equal(resumed.state, tr.state)


@pytest.mark.parametrize("engine", [None, "buffered_async"],
                         ids=["sync", "buffered_async"])
def test_manager_resume_bit_identical_midrun(tmp_path, engine):
    fed = BASE if engine is None else dataclasses.replace(
        BASE, cohort_strategy="scan", engine="buffered_async",
        async_buffer=COHORT // 2, async_capacity=2 * COHORT,
        fault_profile="stragglers")
    model, data = make_mlp_model(), _toy_fed_data()
    rd = str(tmp_path / "run")
    tr = FederatedTrainer(model, fed, rounds_per_call=2, seed=0,
                          run_dir=rd, checkpoint_every=2, keep_last=2)
    tr.run(data, rounds=4, cohort=COHORT, batch=BATCH, meta_batch=8)
    tr.finish()

    # fresh process stand-in: a new trainer over the same run dir
    tr2 = FederatedTrainer(model, fed, rounds_per_call=2, seed=0,
                           run_dir=rd, checkpoint_every=2, keep_last=2)
    step = tr2.resume_latest()
    assert step == 4 and tr2.round == 4 and len(tr2.history) == 4
    tr2.run(data, rounds=8, cohort=COHORT, batch=BATCH, meta_batch=8)
    tr2.finish()

    straight = FederatedTrainer(model, fed, rounds_per_call=2, seed=0)
    straight.run(data, rounds=8, cohort=COHORT, batch=BATCH, meta_batch=8)
    assert tree_equal(tr2.state, straight.state)
    assert tr2.history == straight.history


def test_trainer_checkpoint_every_requires_run_dir():
    model = make_mlp_model()
    with pytest.raises(ValueError, match="run_dir"):
        FederatedTrainer(model, BASE, seed=0, checkpoint_every=2)


# ---------------------------------------------------------------------------
# CheckpointManager retention + failure modes
# ---------------------------------------------------------------------------
def test_manager_retention_exactly_keep_last(tmp_path):
    m = CheckpointManager(str(tmp_path), keep_last=3)
    for s in range(1, 11):
        m.save(s, {"a": np.full((4,), float(s))})
    m.close()
    blobs = sorted(f for f in os.listdir(tmp_path) if f.endswith(".msgpack"))
    assert blobs == ["step_00000008.msgpack", "step_00000009.msgpack",
                     "step_00000010.msgpack"]
    assert m.saved_steps() == [8, 9, 10]


def test_manager_keep_every_milestones_survive(tmp_path):
    m = CheckpointManager(str(tmp_path), keep_last=2, keep_every=5)
    for s in range(1, 13):
        m.save(s, {"a": np.full((2,), float(s))})
    m.close()
    assert m.saved_steps() == [5, 10, 11, 12]


def test_manager_restore_latest_and_fresh_process(tmp_path):
    like = {"a": np.zeros((3,))}
    m = CheckpointManager(str(tmp_path), keep_last=2)
    m.save(3, {"a": np.full((3,), 3.0)}, extra={"tag": "x"})
    m.save(7, {"a": np.full((3,), 7.0)}, extra={"tag": "y"})
    m.close()
    # a fresh manager (new process) reads the on-disk manifest
    m2 = CheckpointManager(str(tmp_path), keep_last=2)
    assert m2.latest() == 7
    tree, extra, step = m2.restore_latest(like)
    assert step == 7 and extra == {"tag": "y"}
    np.testing.assert_array_equal(tree["a"], np.full((3,), 7.0))
    assert m2.restore_latest(like) is not None
    m2.close()
    empty = CheckpointManager(str(tmp_path / "fresh"), keep_last=2)
    assert empty.latest() is None and empty.restore_latest(like) is None
    empty.close()


def test_manager_rejects_non_monotonic_steps(tmp_path):
    m = CheckpointManager(str(tmp_path), keep_last=2)
    m.save(5, {"a": np.zeros((2,))})
    with pytest.raises(ValueError, match="after the last saved step"):
        m.save(5, {"a": np.zeros((2,))})
    with pytest.raises(ValueError, match="after the last saved step"):
        m.save(3, {"a": np.zeros((2,))})
    m.close()


def test_manager_surfaces_worker_errors(tmp_path):
    m = CheckpointManager(str(tmp_path), keep_last=2)
    # a directory squatting on the blob path makes the atomic rename fail
    os.makedirs(m.path(1))
    m.save(1, {"a": np.zeros((2,))})
    with pytest.raises(RuntimeError, match="background checkpoint write"):
        m.wait()


def test_manager_save_snapshots_extra_before_enqueue(tmp_path, monkeypatch):
    """The trainer passes its LIVE history list as extra and keeps
    appending while the background write is in flight; save() must
    snapshot it, or a checkpoint for step N captures rounds >= N and a
    resume replays them."""
    import repro.checkpoint.manager as mgr_mod
    release = threading.Event()
    real_save = mgr_mod.ckpt_save

    def stalled_save(path, tree, *, extra=None):
        assert release.wait(timeout=30)
        real_save(path, tree, extra=extra)

    monkeypatch.setattr(mgr_mod, "ckpt_save", stalled_save)
    m = CheckpointManager(str(tmp_path), keep_last=2)
    hist = [{"round": 0}]
    m.save(1, {"a": np.zeros((2,))}, extra={"history": hist})
    hist.append({"round": 1})  # round loop races ahead of the writer
    release.set()
    _, extra, _ = m.restore_latest({"a": np.zeros((2,))})
    assert extra["history"] == [{"round": 0}]
    m.close()


def test_manager_failed_step_dropped_from_index(tmp_path):
    """A failed background write must not leave a phantom step: latest()
    keeps naming the newest blob actually on disk, and the failed step
    can be re-saved (monotonicity is checked against real saves)."""
    m = CheckpointManager(str(tmp_path), keep_last=2)
    m.save(1, {"a": np.zeros((2,))})
    m.wait()
    os.makedirs(m.path(2))  # a directory squatting on the blob path
    m.save(2, {"a": np.zeros((2,))})
    with pytest.raises(RuntimeError, match="step 2"):
        m.wait()
    assert m.latest() == 1
    tree, _, step = m.restore_latest({"a": np.zeros((2,))})
    assert step == 1
    np.testing.assert_array_equal(tree["a"], np.zeros((2,)))
    os.rmdir(m.path(2))
    m.save(2, {"a": np.ones((2,))})  # the suggested recovery: re-save
    m.wait()
    assert m.latest() == 2
    m.close()


def test_manager_prune_manifest_lands_before_unlink(tmp_path, monkeypatch):
    """Crash-window ordering: at the moment a pruned blob is unlinked,
    the on-disk manifest must already have dropped its step — a reader
    never sees a manifest naming a half-deleted blob."""
    m = CheckpointManager(str(tmp_path), keep_last=1, background=False)
    m.save(1, {"a": np.zeros((2,))})
    unlinked = []
    real_remove = os.remove

    def spy_remove(path, *a, **kw):
        name = os.path.basename(str(path))
        if name.startswith("step_"):
            step = int(name[5:13])
            assert step not in m.saved_steps()
            unlinked.append(step)
        return real_remove(path, *a, **kw)

    monkeypatch.setattr(os, "remove", spy_remove)
    m.save(2, {"a": np.zeros((2,))})
    assert unlinked == [1]
    assert m.saved_steps() == [2]
    m.close()


def test_manager_guards_bad_retention_config(tmp_path):
    with pytest.raises(ValueError, match="keep_last"):
        CheckpointManager(str(tmp_path), keep_last=0)
    with pytest.raises(ValueError, match="keep_every"):
        CheckpointManager(str(tmp_path), keep_every=-1)


def test_manager_donation_safe_snapshot(tmp_path):
    """save() must host-copy before returning: mutating (or donating) the
    device buffer afterwards must not corrupt the pending blob."""
    m = CheckpointManager(str(tmp_path), keep_last=1)
    arr = np.arange(4.0)
    m.save(1, {"a": arr})
    arr += 100.0                   # caller reuses the buffer immediately
    m.wait()
    tree, _, _ = m.restore_latest({"a": np.zeros((4,))})
    np.testing.assert_array_equal(tree["a"], np.arange(4.0))
    m.close()


# ---------------------------------------------------------------------------
# PR 10 additions: span() yields its info dict; the tensorboard tracker's
# optional-dependency gate
# ---------------------------------------------------------------------------
def test_span_yields_info_dict_with_dur(tmp_path):
    t = resolve_tracker("jsonl", run_dir=str(tmp_path))
    with span(t, "dispatch", round=3) as info:
        info["extra"] = 7
    assert info["dur_s"] >= 0          # readable AFTER the block
    t.finish()
    ev = [ln for ln in read_jsonl(tmp_path / "metrics.jsonl")
          if ln["kind"] == "event"][0]
    assert ev["phase"] == "dispatch" and ev["round"] == 3
    assert ev["extra"] == 7 and ev["dur_s"] == info["dur_s"]


def test_tensorboard_tracker_registered_and_gated(tmp_path):
    """'tensorboard' is always listed; constructing it either works (a
    SummaryWriter backend is installed) or raises the actionable
    ImportError naming the install — never a bare module error."""
    assert "tensorboard" in available_trackers()
    factory = get_tracker("tensorboard")
    try:
        import tensorboardX  # noqa: F401
        have_backend = True
    except ImportError:
        try:
            from torch.utils import tensorboard  # noqa: F401
            have_backend = True
        except ImportError:
            have_backend = False

    if not have_backend:
        with pytest.raises(ImportError, match="tensorboardX"):
            factory(run_dir=str(tmp_path))
        return

    t = factory(run_dir=str(tmp_path))
    t.log_metrics(0, {"round": 0, "client_loss": 1.5,
                      "staleness_hist": [1.0, 2.0, 3.0]})
    with span(t, "dispatch", round=0):
        pass
    t.log_event("roofline", {"predicted_rounds_per_s": 10.0,
                             "measured_rounds_per_s": 8.0,
                             "rounds_measured": 4})
    t.finish()
    t.finish()                         # idempotent like the others
    tb = os.path.join(str(tmp_path), "tb")
    assert os.path.isdir(tb)
    assert any("tfevents" in f for f in os.listdir(tb))
    with pytest.raises(RuntimeError, match="finish"):
        t.log_metrics(1, {"round": 1})


def test_tensorboard_tracker_requires_run_dir():
    pytest.importorskip("tensorboardX")
    with pytest.raises(ValueError, match="run_dir"):
        get_tracker("tensorboard")()

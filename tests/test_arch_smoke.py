"""Per-architecture smoke tests (assignment requirement): instantiate the
REDUCED variant of each family (2 layers, d_model<=512, <=4 experts), run
one forward/train step on CPU, assert output shapes + no NaNs; plus
prefill/decode consistency against the full forward."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.base import FedConfig
from repro.core import init_server_state, make_federated_round
from repro.models import transformer
from repro.models.model import build_model

ARCHS = list(configs.ARCHS)


def _batch(cfg, key, B=2, S=32):
    batch = {"tokens": jax.random.randint(key, (B, S + 1), 0,
                                          cfg.vocab_size)}
    if cfg.encoder is not None:
        batch["enc_embeds"] = jax.random.normal(
            key, (B, cfg.encoder.enc_len, cfg.encoder.enc_dim), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(key, arch):
    cfg = configs.get_smoke(arch)
    assert cfg.num_layers <= 2 and cfg.d_model <= 512
    if cfg.moe is not None:
        assert cfg.moe.num_experts <= 4
    model = build_model(cfg, dtype=jnp.float32, loss_chunk=16)
    params = model.init(key)
    batch = _batch(cfg, key)
    logits, aux = transformer.forward(params, batch["tokens"], cfg,
                                      enc_embeds=batch.get("enc_embeds"),
                                      remat=False)
    assert logits.shape == (2, 33, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    # one full federated train step (UGA + meta)
    fed = FedConfig(algorithm="uga", meta=True, cohort=2, local_steps=2,
                    client_lr=0.01)
    round_fn = jax.jit(make_federated_round(model, fed))
    state = init_server_state(model, fed, key)
    cohort_batch = jax.tree.map(
        lambda x: jnp.stack([x, x]), _batch(cfg, key, B=2, S=32))
    meta_batch = _batch(cfg, key, B=2, S=32)
    state2, metrics = round_fn(state, cohort_batch, meta_batch,
                               jnp.ones((2,), jnp.float32), key)
    assert bool(jnp.isfinite(metrics["client_loss"]))
    assert bool(jnp.isfinite(metrics["meta_loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params actually changed
    d = sum(float(jnp.sum(jnp.abs(a - b)))
            for a, b in zip(jax.tree.leaves(state["params"]),
                            jax.tree.leaves(state2["params"])))
    assert d > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_prefill_decode_consistency(key, arch):
    cfg = configs.get_smoke(arch)
    if cfg.moe is not None:  # dropless capacity so decode matches exactly
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    model = build_model(cfg, dtype=jnp.float32)
    params = model.init(key)
    B, S = 2, 16
    toks = jax.random.randint(key, (B, S + 1), 0, cfg.vocab_size)
    batch = {"tokens": toks[:, :S]}
    if cfg.encoder is not None:
        batch["enc_embeds"] = jax.random.normal(
            key, (B, cfg.encoder.enc_len, cfg.encoder.enc_dim), jnp.float32)
    full, _ = transformer.forward(params, toks, cfg,
                                  enc_embeds=batch.get("enc_embeds"),
                                  remat=False)
    last, cache = model.prefill(params, batch, cache_len=S + 4)
    np.testing.assert_allclose(last, full[:, S - 1], atol=2e-4, rtol=1e-3)
    dec, _ = model.decode(params, toks[:, S], cache)
    np.testing.assert_allclose(dec, full[:, S], atol=2e-4, rtol=1e-3)


@pytest.mark.parametrize("arch", ["phi3-mini-3.8b", "smollm-360m"])
def test_sliding_window_decode(key, arch):
    """Windowed ring-buffer decode == full decode while the context still
    fits in the window."""
    cfg = configs.get_smoke(arch)
    W = cfg.sliding_window
    model_w = build_model(cfg, dtype=jnp.float32, decode_window=W)
    model_f = build_model(cfg, dtype=jnp.float32)
    params = model_w.init(key)
    B, S = 1, 8   # S + steps < W
    toks = jax.random.randint(key, (B, S + 3), 0, cfg.vocab_size)
    lw, cw = model_w.prefill(params, {"tokens": toks[:, :S]}, cache_len=W)
    lf, cf = model_f.prefill(params, {"tokens": toks[:, :S]}, cache_len=S + 4)
    np.testing.assert_allclose(lw, lf, atol=2e-4, rtol=1e-3)
    for i in range(2):
        dw, cw = model_w.decode(params, toks[:, S + i], cw)
        df, cf = model_f.decode(params, toks[:, S + i], cf)
        np.testing.assert_allclose(dw, df, atol=2e-4, rtol=1e-3)


def test_param_counts_match_assignment():
    """Analytic parameter counts are in the right ballpark of the names."""
    expect = {
        "phi3-mini-3.8b": (3.5e9, 4.3e9),
        "phi3-medium-14b": (13e9, 16e9),
        "smollm-360m": (0.3e9, 0.45e9),
        "deepseek-v2-lite-16b": (14e9, 18e9),
        "llama4-scout-17b-a16e": (95e9, 115e9),   # total (17B active)
        "jamba-1.5-large-398b": (370e9, 430e9),
        "llama-3.2-vision-90b": (80e9, 95e9),
        "mamba2-780m": (0.7e9, 0.9e9),
        "minicpm-2b": (2.3e9, 3.0e9),
        "whisper-large-v3": (1.4e9, 2.0e9),
    }
    for arch, (lo, hi) in expect.items():
        n = configs.get_arch(arch).param_count()
        assert lo <= n <= hi, (arch, n)
    # MoE active counts
    assert configs.get_arch("llama4-scout-17b-a16e").active_param_count() < 20e9
    assert configs.get_arch("deepseek-v2-lite-16b").active_param_count() < 3.5e9

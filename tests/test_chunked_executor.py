"""Chunked streaming cohort core (the one core vmap/scan/sharded register
over) — the properties the refactor must keep forever:

  * chunk-size invariance, BITWISE: the streaming core accumulates clients
    in global cohort order whatever ``cohort_chunk`` is, so the chunk size
    can never change a round — params, opt state, ctrl and every metric
    agree across chunk in {1, 3, cohort} on {legacy_tree, fused_flat} x
    {post, through_aggregation}, including rounds_per_call > 1 and the
    ragged cohort % chunk != 0 case (zero-weight padding);
  * pre-refactor streaming compat: chunk=1 == cohort_strategy='scan';
    chunk=cohort matches the vmap executor <= 1e-5 (the vmap aggregate
    kernel reduces the cohort axis in XLA reduce-tree order — equal in
    exact arithmetic, ~1 ulp of reassociation in float);
  * rng audit: the participation and fault streams fold out of the ROUND
    rng before the executor runs, so partial participation and fault
    injection are chunking-invariant bitwise (counts and state);
  * two-tier sharded topology == chunked bitwise on a debug mesh, with
    lossy codec + error feedback (residual carry) and through_aggregation
    ctrl hypergradients;
  * guards: cohort_chunk=0, cohort_chunk + cohort_strategy='scan' (config
    time, naming both fields), cohort_chunk + buffered_async (build time).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FedConfig
from repro.core import init_server_state, make_federated_round
from repro.launch.mesh import make_debug_mesh
from repro.sharding.specs import cohort_grad_shardings

from test_plugin_api import make_mlp_model, sample_batch, tree_equal

COHORT = 5          # chunk=3 is the ragged case: 5 % 3 != 0


@pytest.fixture
def key():
    return jax.random.PRNGKey(0)


def _inputs(seed=0, cohort=COHORT, b=8):
    rng = np.random.default_rng(seed)
    batch = sample_batch(rng, cohort, b)
    meta = {"x": jnp.asarray(rng.normal(0, 1, (8, 10)), jnp.float32),
            "y": jnp.asarray(rng.integers(0, 4, 8), jnp.int32)}
    wts = jnp.asarray(rng.uniform(1.0, 5.0, cohort), jnp.float32)
    return batch, meta, wts


def _fed(chunk=None, *, fused=True, mode="post", cohort=COHORT, **kw):
    return FedConfig(algorithm="uga", meta=True, cohort=cohort,
                     local_steps=2, client_lr=0.05, server_lr=0.1,
                     meta_lr=0.05, clip_norm=1.0, lr_decay=0.9,
                     fused_update=fused, meta_mode=mode,
                     cohort_chunk=chunk, **kw)


def _run(model, fed, key, *, rounds=2, rounds_per_call=1, seed_inputs=0,
         **mk_kwargs):
    """Chained rounds (round-1 state feeds round 2) -> (state, metrics)."""
    rf = jax.jit(make_federated_round(model, fed,
                                      rounds_per_call=rounds_per_call,
                                      **mk_kwargs))
    batch, meta, wts = _inputs(seed_inputs, cohort=fed.cohort)
    if rounds_per_call > 1:
        stack = lambda t: jax.tree.map(
            lambda x: jnp.stack([x] * rounds_per_call), t)
        batch, meta = stack(batch), stack(meta)
        wts = jnp.stack([wts] * rounds_per_call)
    state = init_server_state(model, fed, key)
    metrics = None
    for r in range(rounds):
        rngs = jax.random.fold_in(key, r)
        if rounds_per_call > 1:
            rngs = jnp.stack([jax.random.fold_in(rngs, k)
                              for k in range(rounds_per_call)])
        state, metrics = rf(state, batch, meta, wts, rngs)
    return state, metrics


def _assert_identical(out_a, out_b):
    (st_a, m_a), (st_b, m_b) = out_a, out_b
    assert tree_equal(st_a, st_b)
    assert sorted(m_a) == sorted(m_b)
    for name in m_a:
        np.testing.assert_array_equal(np.asarray(m_a[name]),
                                      np.asarray(m_b[name]), err_msg=name)


# ---------------------------------------------------------------------------
# chunk-size invariance matrix (bitwise)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("fused,mode",
                         [(False, "post"),            # legacy_tree engine
                          (True, "post"),             # fused_flat engine
                          (True, "through_aggregation")])
def test_chunk_invariance_matrix_bitwise(key, fused, mode):
    """params + opt + ctrl + every metric identical across chunk sizes,
    two chained rounds; chunk=3 exercises the ragged zero-weight pad."""
    model = make_mlp_model()
    outs = {c: _run(model, _fed(c, fused=fused, mode=mode), key)
            for c in (1, 3, COHORT)}
    _assert_identical(outs[1], outs[3])
    _assert_identical(outs[3], outs[COHORT])


def test_chunk_invariance_rounds_per_call(key):
    """Same gate under the K-chunked round driver (lax.scan over rounds
    wrapping lax.scan over chunks)."""
    model = make_mlp_model()
    outs = {c: _run(model, _fed(c), key, rounds=1, rounds_per_call=2)
            for c in (1, 3, COHORT)}
    _assert_identical(outs[1], outs[3])
    _assert_identical(outs[3], outs[COHORT])


def test_ragged_final_chunk_pads_with_zero_weight(key):
    """Regression for the ragged pad: the pad slot replicates client 0's
    batch with aggregation weight 0, so doubling client 0's weight in the
    REAL slots changes the round, while the pad slot never contributes —
    ragged == exact-divisor bitwise even when client 0 dominates."""
    model = make_mlp_model()
    batch, meta, wts = _inputs()
    wts = wts.at[0].set(100.0)  # if the pad (a client-0 copy) leaked into
    #                             the weighted mean, ragged would diverge
    fed_r, fed_e = _fed(3), _fed(COHORT)
    st = init_server_state(model, fed_r, key)
    rng = jax.random.fold_in(key, 0)
    out_r = jax.jit(make_federated_round(model, fed_r))(
        st, batch, meta, wts, rng)
    out_e = jax.jit(make_federated_round(model, fed_e))(
        st, batch, meta, wts, rng)
    _assert_identical(out_r, out_e)


# ---------------------------------------------------------------------------
# pre-refactor compat
# ---------------------------------------------------------------------------
def test_chunk1_matches_scan_strategy_bitwise(key):
    """chunk=1 IS the pre-refactor scan streaming round."""
    model = make_mlp_model()
    _assert_identical(_run(model, _fed(1), key),
                      _run(model, _fed(None, cohort_strategy="scan"), key))


@pytest.mark.parametrize("mode", ["post", "through_aggregation"])
def test_chunk_eq_cohort_matches_vmap(key, mode):
    """chunk=cohort vs the vmap executor: identical in exact arithmetic;
    <= 1e-5 in float (kernel reduce-tree vs client-order reassociation)."""
    model = make_mlp_model()
    (st_c, m_c) = _run(model, _fed(COHORT, mode=mode), key)
    (st_v, m_v) = _run(model, _fed(None, mode=mode), key)
    for a, b in zip(jax.tree.leaves(st_c), jax.tree.leaves(st_v)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-5, atol=1e-6)
    for name in m_c:
        np.testing.assert_allclose(np.asarray(m_c[name]),
                                   np.asarray(m_v[name]),
                                   rtol=1e-4, atol=1e-6, err_msg=name)


# ---------------------------------------------------------------------------
# rng audit: participation / fault streams are chunking-invariant
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("knobs", [dict(participation=0.6),
                                   dict(fault_profile="flaky"),
                                   dict(participation=0.6,
                                        fault_profile="flaky")])
def test_rng_streams_chunking_invariant(key, knobs):
    """The participation mask and fault streams fold out of the ROUND rng
    before the executor runs (weight zeroing), so which clients drop — and
    the participants/arrivals/fault_* counts — cannot depend on the chunk
    size; neither can the per-client training rng streams."""
    model = make_mlp_model()
    outs = {c: _run(model, _fed(c, cohort=8, **knobs), key)
            for c in (1, 3, 8)}
    _assert_identical(outs[1], outs[3])
    _assert_identical(outs[3], outs[8])
    audited = {"participants", "arrivals", "fault_crashed", "fault_dropped"}
    assert audited & set(outs[8][1]), sorted(outs[8][1])


# ---------------------------------------------------------------------------
# two-tier sharded topology
# ---------------------------------------------------------------------------
def _sharded_kwargs(model, key):
    mesh = make_debug_mesh(1, 1)
    shape = jax.eval_shape(model.init, key)
    return {"grad_shardings": cohort_grad_shardings(shape, mesh)}


@pytest.mark.parametrize("mode", ["post", "through_aggregation"])
def test_sharded_two_tier_matches_chunked_bitwise(key, mode):
    """shard_map + psum partial accumulators reduce to the same flat
    buffers as the single-host streaming core (incl. the ctrl
    hypergradients through the aggregation)."""
    model = make_mlp_model()
    _assert_identical(
        _run(model, _fed(3, mode=mode), key, **_sharded_kwargs(model, key)),
        _run(model, _fed(3, mode=mode), key))


def test_sharded_lossy_codec_error_feedback_matches_chunked(key):
    """sharded declares 'lossy' codec capability: int8 + error feedback
    streams per-client residuals through the two-tier topology — residual
    carry across chained rounds matches the chunked executor bitwise."""
    model = make_mlp_model()
    kw = dict(codec="int8", error_feedback=True)
    _assert_identical(
        _run(model, _fed(3, **kw), key, **_sharded_kwargs(model, key)),
        _run(model, _fed(3, **kw), key))


def test_sharded_supports_reweight_capability():
    from repro.core.executors import get_executor
    fac = get_executor("sharded")
    ex = fac(_fed(3))
    assert ex.supports_reweight
    assert "lossy" in ex.codec_capabilities


# ---------------------------------------------------------------------------
# guards
# ---------------------------------------------------------------------------
def test_cohort_chunk_must_be_positive():
    with pytest.raises(ValueError, match="cohort_chunk"):
        _fed(0)
    with pytest.raises(ValueError, match="cohort_chunk"):
        _fed(-2)
    assert _fed(7).cohort_chunk == 7          # > cohort is fine (one chunk)


def test_cohort_chunk_with_scan_strategy_names_both_fields():
    with pytest.raises(ValueError) as e:
        _fed(2, cohort_strategy="scan")
    assert "cohort_chunk" in str(e.value)
    assert "cohort_strategy" in str(e.value)


def test_buffered_async_rejects_cohort_chunk(key):
    model = make_mlp_model()
    fed = dataclasses.replace(_fed(2, fused=True), meta=False,
                              engine="buffered_async", async_buffer=2)
    with pytest.raises(ValueError, match="cohort_chunk"):
        make_federated_round(model, fed)

"""Sharding spec rules + a real (subprocess) production-mesh dry-run.

The subprocess test IS the e2e proof that the lower+compile machinery works
on the 16x16 production mesh with 512 fake host devices — kept to the
cheapest (arch, shape) so the suite stays fast.
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.launch.mesh import make_debug_mesh
from repro.models.model import build_model
from repro.sharding.specs import (cohort_grad_shardings, param_spec,
                                  param_shardings)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_param_spec_rules():
    mesh = make_debug_mesh(1, 1)
    # 2D projections: in-dim -> data, out-dim -> model
    assert param_spec("blocks/0/attn/wq", (4, 64, 128), mesh) == \
        P(None, "data", "model")
    assert param_spec("blocks/0/attn/wo", (4, 128, 64), mesh) == \
        P(None, "model", "data")
    # embeddings
    assert param_spec("embed", (1024, 64), mesh) == P("model", "data")
    # norms replicate
    assert param_spec("blocks/0/norm1", (4, 64), mesh) == P(None, None)
    assert param_spec("final_norm", (64,), mesh) == P(None)
    # MoE experts: E -> model
    assert param_spec("blocks/0/mlp/w_gate", (4, 8, 64, 32), mesh) == \
        P(None, "model", "data", None)
    assert param_spec("blocks/0/mlp/w_down", (4, 8, 32, 64), mesh) == \
        P(None, "model", None, "data")


def test_param_spec_degrades_on_indivisible():
    """whisper vocab 51866 % 16 != 0 -> embed vocab dim must replicate on a
    16-way mesh axis (divisibility degrade)."""
    mesh = make_debug_mesh(1, 1)  # axis sizes 1 — everything divides
    spec = param_spec("embed", (51866, 1280), mesh)
    assert spec == P("model", "data")  # size-1 axes always divide

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    spec16 = param_spec("embed", (51866, 1280), FakeMesh())
    assert spec16 == P(None, "data")


def test_shardings_cover_every_leaf(key):
    cfg = configs.get_smoke("jamba-1.5-large-398b")
    model = build_model(cfg, dtype=jnp.float32)
    params_shape = jax.eval_shape(model.init, jax.ShapeDtypeStruct(
        (2,), jnp.uint32))
    mesh = make_debug_mesh(1, 1)
    sh = param_shardings(params_shape, mesh)
    assert jax.tree_util.tree_structure(sh) == \
        jax.tree_util.tree_structure(params_shape)
    gsh = cohort_grad_shardings(params_shape, mesh)
    for s in jax.tree.leaves(gsh):
        assert s.spec[0] in (("data",), "data")


@pytest.mark.slow
def test_production_dryrun_subprocess(tmp_path):
    """Real 16x16-mesh lower+compile of the cheapest pair via the actual
    dryrun entry point (sets its own XLA_FLAGS=512 devices)."""
    out = str(tmp_path)
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "smollm-360m", "--shape", "decode_32k", "--out", out],
        env={**os.environ, "PYTHONPATH": SRC}, capture_output=True,
        text=True, timeout=900)
    assert r.returncode == 0, r.stdout + r.stderr
    rec = json.load(open(os.path.join(
        out, "smollm-360m__decode_32k__16x16.json")))
    assert rec["chips"] == 256
    assert rec["roofline"]["bottleneck"] in ("compute", "memory",
                                             "collective")
    assert rec["roofline"]["flops_per_chip"] > 0

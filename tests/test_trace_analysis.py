"""Trace analytics (PR 10): the Chrome-trace parser's math pinned on a
hand-computed miniature fixture, plus the live wiring — a profiled
trainer run lands ``profile_summary`` and ``roofline`` events in
``metrics.jsonl`` with exactly the schema-pinned keys.

Fixture geometry (``tests/fixtures/mini_trace.json.gz``, all times us):

  python lane (pid 1 / tid 10):
    repro.phase.dispatch     [100, 300)
    repro.phase.device_sync  [300, 400)
  device lane (pid 2 / tid 20, args.hlo_op set):
    big_op    [120, 220)   contains small_op [140, 170)
    dot.1     [310, 360)
    orphan_op [500, 540)   outside every phase window

Hand math: selfs big=70 small=30 dot=50 orphan=40 (total 190);
busy = union = 100+50+40 = 190; wall = 540-120 = 420; gap = 230;
phase attribution {dispatch: 100, device_sync: 50, _unattributed: 40}.
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FedConfig
from repro.core import FederatedTrainer
from repro.data.pipeline import FederatedData
from repro.models.model import Model
from repro.obs import (PROFILE_SUMMARY_EVENT_KEYS, ROOFLINE_EVENT_KEYS,
                       emit_profile_summary, find_trace_file)
from repro.obs.trace_analysis import (interval_union_us, load_trace,
                                      op_events, phase_windows, self_times,
                                      summarize, summarize_trace)

FIXTURE = os.path.join(os.path.dirname(__file__), "fixtures",
                       "mini_trace.json.gz")


# ---------------------------------------------------------------------------
# fixture math
# ---------------------------------------------------------------------------
def test_fixture_op_events_and_phase_windows():
    trace = load_trace(FIXTURE)
    ops = op_events(trace)
    assert sorted(e["name"] for e in ops) == \
        ["big_op", "dot.1", "orphan_op", "small_op"]
    windows = phase_windows(trace)
    assert windows == [("dispatch", 100.0, 300.0),
                       ("device_sync", 300.0, 400.0)]


def test_fixture_self_times_subtract_nested_children():
    trace = load_trace(FIXTURE)
    ops = op_events(trace)
    by_name = dict(zip((e["name"] for e in ops), self_times(ops)))
    assert by_name == {"big_op": 70.0, "small_op": 30.0,
                       "dot.1": 50.0, "orphan_op": 40.0}


def test_fixture_interval_union_merges_overlaps():
    trace = load_trace(FIXTURE)
    assert interval_union_us(op_events(trace)) == 190.0
    # the nested child adds no new covered time
    assert interval_union_us([{"ts": 0, "dur": 10},
                              {"ts": 5, "dur": 10},
                              {"ts": 100, "dur": 1}]) == 16.0


def test_fixture_summary_numbers():
    s = summarize(load_trace(FIXTURE))
    assert s["n_events"] == 6          # 2 phase annotations + 4 ops
    assert s["n_op_events"] == 4
    assert s["n_ops"] == 4
    assert s["wall_us"] == 420.0
    assert s["busy_us"] == 190.0
    assert s["gap_us"] == 230.0
    assert s["busy_frac"] == pytest.approx(190.0 / 420.0, abs=1e-6)
    assert s["total_self_us"] == 190.0


def test_fixture_phase_attribution():
    s = summarize(load_trace(FIXTURE))
    # big_op+small_op inside dispatch, dot.1 inside device_sync,
    # orphan_op outside every window -> _unattributed (not dropped)
    assert s["phase_self_us"] == {"_unattributed": 40.0,
                                  "device_sync": 50.0,
                                  "dispatch": 100.0}


def test_fixture_top_ops_ordering_and_truncation():
    s = summarize(load_trace(FIXTURE), top_k=2)
    assert s["top_k"] == 2
    assert [o["op"] for o in s["top_ops"]] == ["big_op", "dot.1"]
    top = summarize(load_trace(FIXTURE))["top_ops"]
    assert [o["op"] for o in top] == \
        ["big_op", "dot.1", "orphan_op", "small_op"]
    assert top[0] == {"op": "big_op", "self_us": 70.0, "total_us": 100.0,
                      "count": 1}


def test_summarize_trace_adds_path_and_schema_matches():
    s = summarize_trace(FIXTURE)
    assert s["trace"] == FIXTURE
    assert set(s) == set(PROFILE_SUMMARY_EVENT_KEYS)


def test_find_trace_file(tmp_path):
    # direct file passthrough
    assert find_trace_file(FIXTURE) == FIXTURE
    # newest-by-mtime under a nested dir, .gz and plain both found
    d = tmp_path / "profile" / "plugins" / "profile" / "2026_08_08"
    d.mkdir(parents=True)
    old = d / "a.trace.json"
    new = d / "b.trace.json.gz"
    old.write_text(json.dumps({"traceEvents": []}))
    import gzip
    with gzip.open(new, "wt") as f:
        f.write(json.dumps({"traceEvents": []}))
    os.utime(old, (1, 1))
    assert find_trace_file(str(tmp_path)) == str(new)
    assert find_trace_file(str(tmp_path / "empty")) is None


class _Recorder:
    def __init__(self):
        self.events = []

    def log_event(self, name, data):
        self.events.append((name, dict(data)))


def test_emit_profile_summary_streams_event(tmp_path):
    trk = _Recorder()
    assert emit_profile_summary(trk, str(tmp_path)) is None  # no trace
    assert emit_profile_summary(trk, FIXTURE)["busy_us"] == 190.0
    assert len(trk.events) == 1
    name, payload = trk.events[0]
    assert name == "profile_summary"
    assert set(payload) == set(PROFILE_SUMMARY_EVENT_KEYS)
    # payload is JSON-serializable as emitted (jsonl tracker contract)
    json.dumps(payload)


def test_self_times_interleaved_lanes_do_not_nest():
    # same window on DIFFERENT lanes: no parent/child relation
    evs = [{"pid": 1, "tid": 1, "ts": 0, "dur": 100, "name": "a"},
           {"pid": 1, "tid": 2, "ts": 10, "dur": 50, "name": "b"}]
    assert self_times(evs) == [100.0, 50.0]


def test_summarize_empty_trace():
    s = summarize({"traceEvents": []})
    assert s["n_op_events"] == 0 and s["wall_us"] == 0.0
    assert s["busy_frac"] == 0.0 and s["top_ops"] == []


# ---------------------------------------------------------------------------
# live wiring: profiled trainer run -> profile_summary + roofline events
# ---------------------------------------------------------------------------
def _mlp_model(d=10, h=16, classes=4):
    def init(k):
        k1, k2 = jax.random.split(k)
        return {"w1": jax.random.normal(k1, (d, h)) * 0.3,
                "w2": jax.random.normal(k2, (h, classes)) * 0.3}

    def loss(w, batch, rng=None):
        logits = jnp.tanh(batch["x"] @ w["w1"]) @ w["w2"]
        l = -jnp.mean(jnp.take_along_axis(
            jax.nn.log_softmax(logits), batch["y"][:, None], 1))
        return l, {}

    return Model(name="mlp", init=init, loss=loss)


def _fed_data(n=256, clients=8, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(0, 1, (n, 10)).astype(np.float32)
    y = rng.integers(0, 4, n).astype(np.int32)
    parts = np.array_split(rng.permutation(n), clients)
    meta = rng.choice(n, 32, replace=False)
    return FederatedData(arrays={"x": x, "y": y}, client_indices=parts,
                         meta_indices=meta, seed=seed)


_FED = FedConfig(algorithm="uga", meta=False, cohort=4, local_steps=2,
                 client_lr=0.05, server_lr=0.1, clip_norm=1.0,
                 fused_update=True)


def _events(run_dir, name):
    out = []
    with open(os.path.join(run_dir, "metrics.jsonl")) as f:
        for line in f:
            rec = json.loads(line)
            if rec.get("kind") == "event" and rec.get("event") == name:
                out.append(rec)
    return out


def test_trainer_trace_summary_and_roofline_events(tmp_path):
    model, data = _mlp_model(), _fed_data()
    tr = FederatedTrainer(model, _FED, seed=0, tracker="jsonl",
                          run_dir=str(tmp_path), profile=1, profile_start=1,
                          trace_summary=True, roofline=True)
    tr.run(data, rounds=3, cohort=4, batch=16, meta_batch=8)
    tr.finish()

    summaries = _events(tmp_path, "profile_summary")
    assert len(summaries) == 1
    payload = {k: v for k, v in summaries[0].items()
               if k not in ("kind", "event", "t")}
    assert set(payload) == set(PROFILE_SUMMARY_EVENT_KEYS)
    assert payload["n_events"] > 0

    rooflines = _events(tmp_path, "roofline")
    assert len(rooflines) == 1
    payload = {k: v for k, v in rooflines[0].items()
               if k not in ("kind", "event", "t")}
    assert set(payload) == set(ROOFLINE_EVENT_KEYS)
    assert payload["rounds_per_call"] == 1
    assert payload["flops_per_round"] > 0
    assert payload["measured_rounds_per_s"] > 0
    assert payload["bottleneck"] in ("compute", "memory", "collective")


def test_trace_summary_without_profile_is_an_error():
    with pytest.raises(ValueError, match="profile"):
        FederatedTrainer(_mlp_model(), _FED, seed=0, trace_summary=True)


def test_roofline_skipped_under_sanitize(tmp_path):
    """Sanitize mode wraps the round fn in a checkify closure with no
    .lower — roofline must skip quietly, not crash the run."""
    model, data = _mlp_model(), _fed_data()
    tr = FederatedTrainer(model, _FED, seed=0, sanitize=True,
                          tracker="jsonl", run_dir=str(tmp_path),
                          roofline=True)
    tr.run(data, rounds=2, cohort=4, batch=16, meta_batch=8)
    tr.finish()
    assert _events(tmp_path, "roofline") == []

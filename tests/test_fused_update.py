"""Fused server-update engine (kernels/fused_update + core/flat):

  * flat-buffer round-trip preserves structure/shapes/dtypes;
  * fused Pallas kernels == pure-jnp ref oracle == legacy tree-map path
    for all four server optimizers, with and without clipping;
  * the custom-VJP backward: ``jax.grad`` through ``fused_server_update``
    (w.r.t. per-client gradient stack, client weights, server lr) ==
    autodiff through the legacy tree-map path, for both the Pallas bwd
    kernels and the ref oracle bwd;
  * rounds_per_call>1 (lax.scan driver) == K sequential single-round calls;
  * the modulo-indexed epoch schedule == the old jnp.tile expansion;
  * scan-strategy cohort fusion: the streaming flat accumulation
    (``accumulate_pass`` + custom VJP) produces BIT-identical aggregates to
    the legacy pytree scan carry, and the fused scan round matches the
    legacy scan round end to end (warm adam/yogi state per the sign-step
    conditioning caveat the vmap tests document).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FedConfig
from repro.core import flat as F
from repro.core import init_server_state, make_federated_round, server_opt
from repro.core.aggregate import (cohort_gradient, scan_cohort_gradient_flat,
                                  weighted_mean)
from repro.core.client import (fedavg_update, make_client_update, uga_update)
from repro.kernels.fused_update import kernel as K
from repro.kernels.fused_update import ops as O
from repro.kernels.fused_update import ref as R
from repro.models.model import Model


def mixed_tree(key):
    ks = jax.random.split(key, 4)
    return {
        "dense": {"w": jax.random.normal(ks[0], (10, 16)),
                  "b": jnp.zeros((16,))},
        "half": jax.random.normal(ks[1], (7, 9)).astype(jnp.bfloat16),
        "scalarish": jax.random.normal(ks[2], (3,)),
        "head": jax.random.normal(ks[3], (16, 4)).astype(jnp.bfloat16),
    }


def make_mlp_model(d=10, h=16, classes=4):
    def init(k):
        k1, k2 = jax.random.split(k)
        return {"w1": jax.random.normal(k1, (d, h)) * 0.3,
                "w2": jax.random.normal(k2, (h, classes)) * 0.3}

    def loss(w, batch, rng=None):
        logits = jnp.tanh(batch["x"] @ w["w1"]) @ w["w2"]
        l = -jnp.mean(jnp.take_along_axis(
            jax.nn.log_softmax(logits), batch["y"][:, None], 1))
        return l, {}

    return Model(name="mlp", init=init, loss=loss)


def sample_batch(rng, cohort, b, d=10, classes=4):
    return {"x": jnp.asarray(rng.normal(0, 1, (cohort, b, d)),
                             jnp.float32),
            "y": jnp.asarray(rng.integers(0, classes, (cohort, b)),
                             jnp.int32)}


# ---------------------------------------------------------------------------
# flat buffers
# ---------------------------------------------------------------------------
def test_flat_roundtrip_structure_and_dtypes(key):
    tree = mixed_tree(key)
    spec = F.make_flat_spec(tree)
    assert len(spec.groups) == 2                     # float32 + bfloat16
    for g in spec.groups:
        assert g.rows % 8 == 0 and g.rows * F.LANES >= g.size
    rt = F.unflatten_tree(spec, F.flatten_tree(spec, tree))
    assert jax.tree_util.tree_structure(rt) == \
        jax.tree_util.tree_structure(tree)
    for a, b in zip(jax.tree.leaves(rt), jax.tree.leaves(tree)):
        assert a.shape == b.shape and a.dtype == b.dtype
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32))


def test_flat_stacked_matches_per_client_flatten(key):
    tree = mixed_tree(key)
    spec = F.make_flat_spec(tree)
    cohort = 3
    stacked = jax.tree.map(
        lambda x: jnp.stack([x.astype(jnp.float32) * (i + 1)
                             for i in range(cohort)]).astype(x.dtype), tree)
    bufs = F.flatten_stacked(spec, stacked)
    for i in range(cohort):
        one = jax.tree.map(lambda x, i=i: x[i], stacked)
        for got, want in zip(bufs, F.flatten_tree(spec, one)):
            np.testing.assert_allclose(np.asarray(got[i]), np.asarray(want))


def test_unflatten_stacked_inverts_flatten_stacked(key):
    tree = mixed_tree(key)
    spec = F.make_flat_spec(tree)
    cohort = 3
    stacked = jax.tree.map(
        lambda x: jnp.stack([x.astype(jnp.float32) * (i + 1)
                             for i in range(cohort)]).astype(x.dtype), tree)
    rt = F.unflatten_stacked(spec, F.flatten_stacked(spec, stacked))
    assert jax.tree_util.tree_structure(rt) == \
        jax.tree_util.tree_structure(stacked)
    for a, b in zip(jax.tree.leaves(rt), jax.tree.leaves(stacked)):
        assert a.shape == b.shape and a.dtype == b.dtype
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32))


# ---------------------------------------------------------------------------
# fused engine vs ref oracle vs legacy tree-map path
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("opt", ["sgd", "sgdm", "adam", "yogi"])
@pytest.mark.parametrize("clip", [0.0, 0.5])
def test_fused_matches_ref_and_legacy(key, opt, clip):
    params = mixed_tree(key)
    spec = F.make_flat_spec(params)
    cohort = 5
    gkey = jax.random.fold_in(key, 9)
    grads = jax.tree.map(
        lambda p: jax.random.normal(
            jax.random.fold_in(gkey, p.size), (cohort,) + p.shape,
            jnp.float32), params)
    wts = jnp.asarray([1.0, 2.0, 3.0, 4.0, 5.0])
    lr = 0.07

    out = {}
    for use_ref in (False, True):
        st = O.init_flat_opt_state(opt, spec)
        newp, newst, gn = O.fused_server_update(
            params, grads, wts, st, opt=opt, lr=lr, clip_norm=clip,
            momentum=0.9, use_ref=use_ref)
        out[use_ref] = (newp, gn)
    # Pallas kernels == oracle (same flat math, bit-level expectations loose)
    for a, b in zip(jax.tree.leaves(out[False][0]),
                    jax.tree.leaves(out[True][0])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-6, atol=1e-7)

    # legacy tree-map pipeline on the same inputs
    G = weighted_mean(grads, wts)
    if clip > 0:
        gn_l = jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                            for x in jax.tree.leaves(G)))
        s = jnp.minimum(1.0, clip / jnp.maximum(gn_l, 1e-9))
        G = jax.tree.map(lambda g: (g.astype(jnp.float32) * s
                                    ).astype(g.dtype), G)
    lp, _ = server_opt.apply(opt, server_opt.init_state(opt, params),
                             params, G, lr, momentum=0.9)
    for a, b in zip(jax.tree.leaves(out[False][0]), jax.tree.leaves(lp)):
        a = np.asarray(a, np.float32)
        b = np.asarray(b, np.float32)
        rel = np.max(np.abs(a - b) / (np.abs(b) + 1e-6))
        assert rel <= 1e-5, (opt, clip, rel)


@pytest.mark.parametrize("opt", ["sgd", "adam"])
def test_fused_round_matches_legacy_round(key, opt):
    model = make_mlp_model()
    rng = np.random.default_rng(0)
    batch = sample_batch(rng, cohort=4, b=16)
    meta = {"x": batch["x"][0], "y": batch["y"][0]}
    wts = jnp.asarray([1.0, 2.0, 3.0, 4.0])
    kw = dict(algorithm="uga", meta=True, cohort=4, local_steps=2,
              client_lr=0.05, server_lr=0.1, meta_lr=0.05, server_opt=opt,
              clip_norm=1.0)
    states, metrics = {}, {}
    for fused in (False, True):
        fed = FedConfig(fused_update=fused, **kw)
        rf = jax.jit(make_federated_round(model, fed))
        st = init_server_state(model, fed, key)
        states[fused], metrics[fused] = rf(st, batch, meta, wts, key)
    for k in states[False]["params"]:
        a = np.asarray(states[True]["params"][k])
        b = np.asarray(states[False]["params"][k])
        rel = np.max(np.abs(a - b) / (np.abs(b) + 1e-6))
        assert rel <= 1e-5, (opt, k, rel)
    for name in ("client_loss", "grad_norm", "meta_loss"):
        np.testing.assert_allclose(float(metrics[True][name]),
                                   float(metrics[False][name]),
                                   rtol=1e-4, atol=1e-6)


# ---------------------------------------------------------------------------
# scanned multi-round driver
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("fused", [False, True])
def test_rounds_per_call_matches_sequential(key, fused):
    model = make_mlp_model()
    K = 3
    fed = FedConfig(algorithm="uga", meta=True, cohort=4, local_steps=2,
                    client_lr=0.05, server_lr=0.1, meta_lr=0.05,
                    server_opt="adam", clip_norm=1.0, lr_decay=0.9,
                    fused_update=fused)
    rng = np.random.default_rng(1)
    batches = [sample_batch(rng, cohort=4, b=16) for _ in range(K)]
    metas = [{"x": b["x"][0], "y": b["y"][0]} for b in batches]
    wts = jnp.asarray([1.0, 2.0, 3.0, 4.0])
    rngs = jnp.stack([jax.random.fold_in(key, r) for r in range(K)])

    rf1 = jax.jit(make_federated_round(model, fed))
    st = init_server_state(model, fed, key)
    per_round = []
    for r in range(K):
        st, m = rf1(st, batches[r], metas[r], wts, rngs[r])
        per_round.append(m)

    rfK = jax.jit(make_federated_round(model, fed, rounds_per_call=K))
    stK = init_server_state(model, fed, key)
    stK, mK = rfK(stK,
                  jax.tree.map(lambda *xs: jnp.stack(xs), *batches),
                  jax.tree.map(lambda *xs: jnp.stack(xs), *metas),
                  jnp.stack([wts] * K), rngs)

    assert int(stK["round"]) == int(st["round"]) == K
    for a, b in zip(jax.tree.leaves(stK["params"]),
                    jax.tree.leaves(st["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-7)
    for name in mK:
        assert mK[name].shape == (K,)
        for r in range(K):
            np.testing.assert_allclose(float(mK[name][r]),
                                       float(per_round[r][name]),
                                       rtol=1e-5, atol=1e-7)


# ---------------------------------------------------------------------------
# epoch schedule: modulo indexing == the old jnp.tile expansion
# ---------------------------------------------------------------------------
def _tile_batch(batch, epochs):
    return jax.tree.map(
        lambda x: jnp.tile(x, (epochs,) + (1,) * (x.ndim - 1)), batch)


# ---------------------------------------------------------------------------
# custom-VJP backward: jax.grad through the fused engine == legacy autodiff
# ---------------------------------------------------------------------------
def f32_tree(key):
    """All-f32 mixed-shape params (grad comparisons at 1e-5 need both paths
    to share the leaf dtype; bf16 leaves round each path differently)."""
    ks = jax.random.split(key, 3)
    return {"w1": jax.random.normal(ks[0], (10, 16)) * 0.3,
            "w2": jax.random.normal(ks[1], (16, 4)) * 0.3,
            "b": jax.random.normal(ks[2], (5,))}


def _coeff_like(key, tree, salt):
    return jax.tree.map(
        lambda p: jax.random.normal(
            jax.random.fold_in(key, p.size + salt), p.shape), tree)


def _tree_dot(a, b):
    return sum(jnp.sum(x.astype(jnp.float32) * y.astype(jnp.float32))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def assert_grads_close(got, want, tol=1e-5):
    """Per-leaf max error <= tol * the leaf's gradient scale (fp32
    reduction order differs between the engines, so elementwise relative
    error on entries ~1000x below the leaf scale is pure ulp noise)."""
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        a = np.asarray(a, np.float32)
        b = np.asarray(b, np.float32)
        scale = max(float(np.max(np.abs(b))), 1e-8)
        err = float(np.max(np.abs(a - b))) / scale
        assert err <= tol, (a.shape, err)


@pytest.mark.parametrize("opt", ["sgd", "sgdm", "adam", "yogi"])
@pytest.mark.parametrize("clip", [0.0, 0.5])
@pytest.mark.parametrize("use_ref", [False, True])
def test_grad_through_fused_matches_legacy_autodiff(key, opt, clip, use_ref):
    """d(objective)/d(grad_stack, client_weights, lr) through the fused
    custom VJP == autodiff through the legacy tree-map path, where the
    objective touches new params, the clipped grad norm AND the new
    optimizer state (so every backward-kernel output cotangent is live).

    adam/yogi use a warm (t=5, random m, v>0) state: at t=1 from zeros the
    update saturates to lr*sign(g) whose g-derivative is a catastrophic
    fp32 cancellation in ANY implementation — the same conditioning caveat
    the forward bench documents for its numerics gate."""
    params = f32_tree(key)
    spec = F.make_flat_spec(params)
    cohort = 5
    gkey = jax.random.fold_in(key, 9)
    grads = jax.tree.map(
        lambda p: jax.random.normal(
            jax.random.fold_in(gkey, p.size), (cohort,) + p.shape,
            jnp.float32), params)
    wts = jnp.asarray([1.0, 2.0, 3.0, 4.0, 5.0])
    lr = 0.07
    c_p = _coeff_like(key, params, 7)
    c_m = _coeff_like(key, params, 8)
    c_v = _coeff_like(key, params, 9)
    m_tree = jax.tree.map(lambda p: 0.3 * p, _coeff_like(key, params, 11))
    v_tree = jax.tree.map(lambda p: 0.1 + jnp.abs(p),
                          _coeff_like(key, params, 12))
    t0 = 5

    def _flat_dot(bufs, coeff_tree):
        return sum(jnp.sum(a * c) for a, c in
                   zip(bufs, F.flatten_tree(spec, coeff_tree)))

    def fused_obj(g, w, lr_):
        st = O.init_flat_opt_state(opt, spec)
        if "m" in st:
            st["m"] = tuple(F.flatten_tree(spec, m_tree))
        if "v" in st:
            st["v"] = tuple(F.flatten_tree(spec, v_tree))
            st["t"] = jnp.asarray(t0, jnp.int32)
        newp, newst, gn = O.fused_server_update(
            params, g, w, st, opt=opt, lr=lr_, clip_norm=clip,
            momentum=0.9, use_ref=use_ref)
        obj = _tree_dot(newp, c_p) + 0.3 * gn
        if "m" in newst:
            obj = obj + _flat_dot(newst["m"], c_m)
        if "v" in newst:
            obj = obj + _flat_dot(newst["v"], c_v)
        return obj

    def legacy_obj(g, w, lr_):
        G = weighted_mean(g, w)
        gn = jnp.sqrt(sum(jnp.sum(jnp.square(x))
                          for x in jax.tree.leaves(G)))
        if clip > 0:
            s = jnp.minimum(1.0, clip / jnp.maximum(gn, 1e-9))
            G = jax.tree.map(lambda x: x * s, G)
            gn = gn * s
        st = server_opt.init_state(opt, params)
        if "m" in st:
            st["m"] = m_tree
        if "v" in st:
            st["v"] = v_tree
            st["t"] = jnp.asarray(t0, jnp.int32)
        newp, newst = server_opt.apply(opt, st, params, G, lr_, momentum=0.9)
        obj = _tree_dot(newp, c_p) + 0.3 * gn
        if "m" in newst:
            obj = obj + _tree_dot(newst["m"], c_m)
        if "v" in newst:
            obj = obj + _tree_dot(newst["v"], c_v)
        return obj

    fg = jax.grad(fused_obj, argnums=(0, 1, 2))(grads, wts, lr)
    lg = jax.grad(legacy_obj, argnums=(0, 1, 2))(grads, wts, lr)
    assert_grads_close(fg, lg)


@pytest.mark.parametrize("opt", ["sgd", "adam"])
def test_grad_wrt_params_through_fused_matches_legacy(key, opt):
    """Cotangents also flow into the *parameters* (dp = d new_p through
    p' = p - lr*step is the identity in the custom bwd)."""
    params = f32_tree(key)
    spec = F.make_flat_spec(params)
    grads = jax.tree.map(
        lambda p: jax.random.normal(
            jax.random.fold_in(key, p.size + 1), (3,) + p.shape), params)
    wts = jnp.asarray([1.0, 2.0, 3.0])
    c_p = _coeff_like(key, params, 7)

    def fused_obj(p):
        st = O.init_flat_opt_state(opt, spec)
        newp, _, _ = O.fused_server_update(p, grads, wts, st, opt=opt,
                                           lr=0.07, clip_norm=0.5)
        return _tree_dot(newp, c_p)

    def legacy_obj(p):
        G = weighted_mean(grads, wts)
        gn = jnp.sqrt(sum(jnp.sum(jnp.square(x))
                          for x in jax.tree.leaves(G)))
        s = jnp.minimum(1.0, 0.5 / jnp.maximum(gn, 1e-9))
        G = jax.tree.map(lambda x: x * s, G)
        newp, _ = server_opt.apply(opt, server_opt.init_state(opt, p), p,
                                   G, 0.07)
        return _tree_dot(newp, c_p)

    assert_grads_close(jax.grad(fused_obj)(params),
                       jax.grad(legacy_obj)(params))


# ---------------------------------------------------------------------------
# scan-strategy cohort fusion: streaming flat accumulation
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("use_ref", [False, True])
def test_accumulate_pass_matches_formula_and_vjp(key, use_ref):
    """acc + w*g forward (Pallas == ref == jnp) and the custom VJP
    (d_acc identity, dg = w d_out, dw = <g, d_out>) == XLA autodiff."""
    rng = np.random.default_rng(3)
    acc = jnp.asarray(rng.normal(0, 1, (16, F.LANES)), jnp.float32)
    g = jnp.asarray(rng.normal(0, 1, (16, F.LANES)), jnp.float32)
    w = jnp.float32(0.37)
    got = (R.accumulate_ref(acc, g, w) if use_ref
           else K.accumulate_pass(acc, g, w, interpret=True))
    np.testing.assert_allclose(np.asarray(got), np.asarray(acc + w * g),
                               rtol=1e-6, atol=1e-6)

    accum = O.flat_accumulate(use_ref=use_ref, interpret=True)
    obj = lambda a, gg, ww: jnp.sum(jnp.sin(accum(a, gg, ww)))
    ref = lambda a, gg, ww: jnp.sum(jnp.sin(a + ww * gg))
    got_g = jax.grad(obj, argnums=(0, 1, 2))(acc, g, w)
    want_g = jax.grad(ref, argnums=(0, 1, 2))(acc, g, w)
    assert_grads_close(got_g, want_g)


@pytest.mark.parametrize("use_ref", [False, True])
@pytest.mark.parametrize("algo", ["uga", "fedavg"])
def test_scan_flat_cohort_bitmatches_legacy_carry(key, use_ref, algo):
    """The streaming flat accumulation is the SAME fp32 math in the same
    client order as the legacy pytree carry — the aggregate and the
    weighted client loss must match bit for bit."""
    model = make_mlp_model()
    params = model.init(key)
    spec = F.make_flat_spec(params)
    rng = np.random.default_rng(4)
    batch = sample_batch(rng, cohort=4, b=16)
    wts = jnp.asarray(rng.uniform(1.0, 5.0, 4), jnp.float32)
    cu = make_client_update(algo, model.loss, local_steps=2)

    G_legacy, l_legacy = jax.jit(lambda p: cohort_gradient(
        cu, p, batch, wts, 0.05, key, strategy="scan"))(params)
    G_flat, l_flat = jax.jit(lambda p: scan_cohort_gradient_flat(
        cu, p, batch, wts, 0.05, key, spec=spec, use_ref=use_ref))(params)
    G_flat_tree = F.unflatten_tree(spec, G_flat)
    np.testing.assert_array_equal(np.asarray(l_flat), np.asarray(l_legacy))
    for a, b in zip(jax.tree.leaves(G_flat_tree), jax.tree.leaves(G_legacy)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("opt", ["sgd", "sgdm"])
@pytest.mark.parametrize("clip", [0.0, 1.0])
def test_scan_fused_round_matches_legacy_scan_round(key, opt, clip):
    """Full round, cohort_strategy='scan': fused flat streaming == legacy
    pytree carry to <= 1e-5 relative on params and round metrics (smooth
    optimizers; adam/yogi are gated warm-state below, same as the vmap
    engine's sign-step caveat)."""
    model = make_mlp_model()
    rng = np.random.default_rng(0)
    batch = sample_batch(rng, cohort=4, b=16)
    meta = {"x": batch["x"][0], "y": batch["y"][0]}
    wts = jnp.asarray([1.0, 2.0, 3.0, 4.0])
    kw = dict(algorithm="uga", meta=True, cohort=4, local_steps=2,
              client_lr=0.05, server_lr=0.1, meta_lr=0.05, server_opt=opt,
              clip_norm=clip, cohort_strategy="scan")
    states, metrics = {}, {}
    for fused in (False, True):
        fed = FedConfig(fused_update=fused, **kw)
        rf = jax.jit(make_federated_round(model, fed))
        st = init_server_state(model, fed, key)
        states[fused], metrics[fused] = rf(st, batch, meta, wts, key)
    for k in states[False]["params"]:
        a = np.asarray(states[True]["params"][k])
        b = np.asarray(states[False]["params"][k])
        rel = np.max(np.abs(a - b) / (np.abs(b) + 1e-6))
        assert rel <= 1e-5, (opt, clip, k, rel)
    for name in ("client_loss", "grad_norm", "meta_loss"):
        np.testing.assert_allclose(float(metrics[True][name]),
                                   float(metrics[False][name]),
                                   rtol=1e-4, atol=1e-6)


@pytest.mark.parametrize("opt", ["adam", "yogi"])
@pytest.mark.parametrize("clip", [0.0, 1.0])
def test_scan_fused_round_matches_legacy_warm_adam_yogi(key, opt, clip):
    """adam/yogi arm of the scan bit-compat gate, warm (t=5) opt state: at
    t=1 from zeros the step saturates to lr*sign(g) whose params are ulp-
    unstable in ANY engine (the documented vmap caveat); warm state makes
    the comparison well-conditioned and both paths must agree <= 1e-5."""
    model = make_mlp_model()
    params0 = model.init(key)
    spec = F.make_flat_spec(params0)
    rng = np.random.default_rng(1)
    batch = sample_batch(rng, cohort=4, b=16)
    meta = {"x": batch["x"][0], "y": batch["y"][0]}
    wts = jnp.asarray([1.0, 2.0, 3.0, 4.0])
    m_tree = jax.tree.map(
        lambda p: 0.3 * jax.random.normal(jax.random.fold_in(key, p.size + 3),
                                          p.shape), params0)
    v_tree = jax.tree.map(
        lambda p: 0.1 + jnp.abs(jax.random.normal(
            jax.random.fold_in(key, p.size + 4), p.shape)), params0)
    kw = dict(algorithm="uga", meta=True, cohort=4, local_steps=2,
              client_lr=0.05, server_lr=0.1, meta_lr=0.05, server_opt=opt,
              clip_norm=clip, cohort_strategy="scan")
    states = {}
    for fused in (False, True):
        fed = FedConfig(fused_update=fused, **kw)
        st = init_server_state(model, fed, key)
        if fused:
            st["opt"] = {"m": tuple(F.flatten_tree(spec, m_tree)),
                         "v": tuple(F.flatten_tree(spec, v_tree)),
                         "t": jnp.asarray(5, jnp.int32)}
        else:
            st["opt"] = {"m": m_tree, "v": v_tree,
                         "t": jnp.asarray(5, jnp.int32)}
        rf = jax.jit(make_federated_round(model, fed))
        states[fused], _ = rf(st, batch, meta, wts, key)
    for k in states[False]["params"]:
        a = np.asarray(states[True]["params"][k])
        b = np.asarray(states[False]["params"][k])
        rel = np.max(np.abs(a - b) / (np.abs(b) + 1e-6))
        assert rel <= 1e-5, (opt, clip, k, rel)


@pytest.mark.parametrize("fused", [False, True])
def test_scan_rounds_per_call_matches_sequential(key, fused):
    """The scanned multi-round driver composes with the scan cohort
    strategy (nested lax.scan: rounds over clients)."""
    model = make_mlp_model()
    Kr = 3
    fed = FedConfig(algorithm="uga", meta=True, cohort=4, local_steps=2,
                    client_lr=0.05, server_lr=0.1, meta_lr=0.05,
                    server_opt="sgdm", clip_norm=1.0, lr_decay=0.9,
                    cohort_strategy="scan", fused_update=fused)
    rng = np.random.default_rng(1)
    batches = [sample_batch(rng, cohort=4, b=16) for _ in range(Kr)]
    metas = [{"x": b["x"][0], "y": b["y"][0]} for b in batches]
    wts = jnp.asarray([1.0, 2.0, 3.0, 4.0])
    rngs = jnp.stack([jax.random.fold_in(key, r) for r in range(Kr)])

    rf1 = jax.jit(make_federated_round(model, fed))
    st = init_server_state(model, fed, key)
    for r in range(Kr):
        st, _ = rf1(st, batches[r], metas[r], wts, rngs[r])

    rfK = jax.jit(make_federated_round(model, fed, rounds_per_call=Kr))
    stK = init_server_state(model, fed, key)
    stK, mK = rfK(stK,
                  jax.tree.map(lambda *xs: jnp.stack(xs), *batches),
                  jax.tree.map(lambda *xs: jnp.stack(xs), *metas),
                  jnp.stack([wts] * Kr), rngs)
    assert int(stK["round"]) == int(st["round"]) == Kr
    for a, b in zip(jax.tree.leaves(stK["params"]),
                    jax.tree.leaves(st["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-7)


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("algo", ["uga", "fedavg"])
def test_epoch_cycling_equals_tiled_path(key, seed, algo):
    """local_epochs=E with the in-scan modulo schedule must equal the old
    materialized path, which is exactly local_steps*E steps over the
    example-tiled batch (same microbatch sequence, same step rngs)."""
    model = make_mlp_model()
    rng = np.random.default_rng(seed)
    batch = {"x": jnp.asarray(rng.normal(0, 1, (16, 10)), jnp.float32),
             "y": jnp.asarray(rng.integers(0, 4, 16), jnp.int32)}
    steps, epochs = 2, 3
    fn = uga_update if algo == "uga" else fedavg_update
    g_new, l_new = fn(model.loss, model.init(key), batch, 0.05,
                      local_steps=steps, local_epochs=epochs)
    g_old, l_old = fn(model.loss, model.init(key), _tile_batch(batch, epochs),
                      0.05, local_steps=steps * epochs, local_epochs=1)
    np.testing.assert_allclose(float(l_new), float(l_old),
                               rtol=1e-6, atol=1e-7)
    for a, b in zip(jax.tree.leaves(g_new), jax.tree.leaves(g_old)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)

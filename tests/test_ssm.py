"""SSM (Mamba2 SSD): chunked == sequential, chunk-size invariance, decode
step == full scan, conv cache semantics, full block prefill/decode parity."""
import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests skip; unit tests still run
    from _hypothesis_stub import given, settings, st

from repro.configs.base import SSMConfig
from repro.models.ssm import (causal_conv, causal_conv_step, mamba_block,
                              mamba_block_decode, mamba_init,
                              mamba_make_cache, ssd_chunked, ssd_decode_step)


def _ssd_inputs(key, B=2, S=64, H=4, P=16, N=8):
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, H, N))
    Cm = jax.random.normal(ks[4], (B, S, H, N))
    return x, dt, A, Bm, Cm


def _sequential(x, dt, A, Bm, Cm):
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    h = jnp.zeros((B, H, N, P))
    ys = []
    for t in range(S):
        a = jnp.exp(dt[:, t] * A[None])
        h = a[..., None, None] * h + jnp.einsum(
            "bhn,bhp->bhnp", Bm[:, t], x[:, t] * dt[:, t, ..., None])
        ys.append(jnp.einsum("bhn,bhnp->bhp", Cm[:, t], h))
    return jnp.stack(ys, 1), h


@settings(max_examples=8, deadline=None)
@given(chunk=st.sampled_from([8, 16, 32, 64, 128]))
def test_chunked_equals_sequential(chunk):
    x, dt, A, Bm, Cm = _ssd_inputs(jax.random.PRNGKey(0))
    y, hf = ssd_chunked(x, dt, A, Bm, Cm, chunk)
    yr, hr = _sequential(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(y, yr, atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(hf, hr, atol=1e-4, rtol=1e-3)


def test_chunk_invariance(key):
    x, dt, A, Bm, Cm = _ssd_inputs(key)
    y1, _ = ssd_chunked(x, dt, A, Bm, Cm, 8)
    y2, _ = ssd_chunked(x, dt, A, Bm, Cm, 64)
    np.testing.assert_allclose(y1, y2, atol=1e-4, rtol=1e-3)


def test_decode_step_continues_scan(key):
    x, dt, A, Bm, Cm = _ssd_inputs(key, S=33)
    y_full, _ = ssd_chunked(x, dt, A, Bm, Cm, 16)
    y_pre, h = ssd_chunked(x[:, :32], dt[:, :32], A, Bm[:, :32], Cm[:, :32], 16)
    y_t, h2 = ssd_decode_step(x[:, 32], dt[:, 32], A, Bm[:, 32], Cm[:, 32], h)
    np.testing.assert_allclose(y_t, y_full[:, 32], atol=1e-4, rtol=1e-3)


def test_causal_conv_matches_step(key):
    B, S, C, W = 2, 12, 6, 4
    x = jax.random.normal(key, (B, S, C))
    w = jax.random.normal(jax.random.PRNGKey(1), (W, C))
    y, cache = causal_conv(x, w)
    # replay one token at a time
    c = jnp.zeros((B, W - 1, C))
    for t in range(S):
        yt, c = causal_conv_step(x[:, t], w, c)
        np.testing.assert_allclose(yt, y[:, t], atol=1e-5)
    np.testing.assert_allclose(c, cache, atol=1e-6)


def test_mamba_block_decode_parity(key):
    cfg = SSMConfig(d_state=8, d_head=16, expand=2, chunk=16)
    d_model = 32
    p = mamba_init(key, d_model, cfg)
    B, S = 2, 17
    u = jax.random.normal(jax.random.PRNGKey(2), (B, S, d_model))
    y_full = mamba_block(u, p, cfg)
    cache = mamba_make_cache(B, d_model, cfg, jnp.float32)
    for t in range(S):
        y_t, cache = mamba_block_decode(u[:, t], p, cfg, cache)
    np.testing.assert_allclose(y_t, y_full[:, -1], atol=1e-4, rtol=1e-3)

import jax
import pytest

# CPU test determinism; dry-run device-count flags are NOT set here on
# purpose (smoke tests must see the real 1-device environment).
jax.config.update("jax_default_matmul_precision", "highest")


@pytest.fixture
def key():
    return jax.random.PRNGKey(0)

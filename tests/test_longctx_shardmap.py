"""Sequence-parallel flash decode (shard_map) == plain decode attention.
Runs in a subprocess with 8 fake host devices (device count locks at jax
init, so the main test process can't host it)."""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.launch.mesh import make_debug_mesh
    from repro.models.attention import decode_attention
    from repro.sharding.longctx import sharded_flash_decode

    mesh = make_debug_mesh(4, 2)
    key = jax.random.PRNGKey(0)
    B, S, H, Hkv, D = 2, 64, 4, 2, 16
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, H, D))
    k = jax.random.normal(ks[1], (B, S, Hkv, D))
    v = jax.random.normal(ks[2], (B, S, Hkv, D))
    index = jnp.asarray(40)
    out = sharded_flash_decode(q, k, v, index, mesh=mesh, axis="data")
    ref = decode_attention(q, k, v, index)
    err = float(jnp.max(jnp.abs(out - ref)))
    assert err < 2e-5, err
    print("OK", err)
""")


@pytest.mark.slow
def test_sharded_flash_decode_subprocess():
    r = subprocess.run([sys.executable, "-c", SCRIPT],
                       env={**os.environ, "PYTHONPATH": SRC},
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "OK" in r.stdout

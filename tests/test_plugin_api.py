"""Plugin-API redesign (algorithm/executor/engine registries + facade):

  * equivalence MATRIX: the registry-built round is BIT-identical to the
    pre-registry (PR-3) round — reconstructed here from the unchanged
    primitives (cohort_gradient / fused_server_update / server_opt /
    meta_*) — across {legacy, fused} x {vmap, scan} x {post,
    through_aggregation} x {sgd, adam}, including rounds_per_call > 1;
  * a toy ClientAlgorithm and a toy ServerEngine land purely through
    ``register_algorithm`` / ``register_engine`` (no core/round.py edits)
    and run a round end to end;
  * fednova (the shipped registry-only algorithm): tau-normalized deltas,
    == fedavg exactly when the server step size equals tau;
  * partial participation: ``fed.participation < 1`` == manually zeroing
    the same clients' weights (the mask folds out of the round rng, so
    participation=1 keeps historical rng streams bit-exactly);
  * FederatedTrainer: the deduplicated driver reproduces the legacy
    ``k==1`` loop's history bit-exactly, and save/restore mid-run
    continues identically to never stopping;
  * back-compat import surface + actionable ``sample_round`` cohort error.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FedConfig
from repro.core import (FederatedTrainer, RoundFnCache, available_algorithms,
                        cohort_gradient, init_server_state,
                        make_client_update, make_federated_round,
                        meta_update, participation_mask, register_algorithm,
                        register_engine, scan_cohort_gradient_flat,
                        server_opt, stack_round_inputs)
from repro.core import flat as F
from repro.core.client import fedavg_update
from repro.core.engines import ServerEngine, tree_global_norm
from repro.core.meta import (meta_update_through_aggregation,
                             meta_update_through_aggregation_scan)
from repro.data.pipeline import FederatedData
from repro.kernels.fused_update.ops import (fused_apply_flat,
                                            fused_server_update)
from repro.models.model import Model


def make_mlp_model(d=10, h=16, classes=4):
    def init(k):
        k1, k2 = jax.random.split(k)
        return {"w1": jax.random.normal(k1, (d, h)) * 0.3,
                "w2": jax.random.normal(k2, (h, classes)) * 0.3}

    def loss(w, batch, rng=None):
        logits = jnp.tanh(batch["x"] @ w["w1"]) @ w["w2"]
        l = -jnp.mean(jnp.take_along_axis(
            jax.nn.log_softmax(logits), batch["y"][:, None], 1))
        return l, {}

    return Model(name="mlp", init=init, loss=loss)


def sample_batch(rng, cohort, b, d=10, classes=4):
    return {"x": jnp.asarray(rng.normal(0, 1, (cohort, b, d)), jnp.float32),
            "y": jnp.asarray(rng.integers(0, classes, (cohort, b)),
                             jnp.int32)}


def _round_inputs(seed=0, cohort=4, b=16):
    rng = np.random.default_rng(seed)
    batch = sample_batch(rng, cohort, b)
    meta = {"x": jnp.asarray(rng.normal(0, 1, (8, 10)), jnp.float32),
            "y": jnp.asarray(rng.integers(0, 4, 8), jnp.int32)}
    wts = jnp.asarray(rng.uniform(1.0, 5.0, cohort), jnp.float32)
    return batch, meta, wts


def tree_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(la, lb))


# ---------------------------------------------------------------------------
# the PR-3 round, reconstructed from the unchanged primitives
# ---------------------------------------------------------------------------
def _ref_resolve_server_lr(fed):
    if fed.algorithm == "uga" or fed.server_opt != "sgd":
        return fed.server_lr
    return 1.0


def make_reference_round(model, fed):
    """Line-for-line reconstruction of the pre-registry one_round (PR 3's
    ``core/round.py`` branch tree) over the primitives the redesign did NOT
    touch — the bit-identity oracle for the equivalence matrix."""
    client_update = make_client_update(
        fed.algorithm, model.loss, local_steps=fed.local_steps,
        local_epochs=fed.local_epochs, prox_mu=fed.prox_mu,
        remat=fed.remat_local_steps)
    agg_dtype = jnp.dtype(fed.grad_agg_dtype)
    server_lr = _ref_resolve_server_lr(fed)
    through_agg = fed.meta and fed.meta_mode == "through_aggregation"

    def one_round(state, cohort_batch, meta_batch, client_weights, rng):
        params = state["params"]
        r = state["round"].astype(jnp.float32)
        lr_c = fed.client_lr * (fed.lr_decay ** r)
        rng_c, rng_m = jax.random.split(rng)

        if fed.fused_update:
            meta_metrics = {}
            if fed.cohort_strategy == "scan":
                if through_agg:
                    (new_params, opt_state, gn_post, client_loss,
                     new_ctrl, meta_metrics) = \
                        meta_update_through_aggregation_scan(
                            model.loss, client_update, params, cohort_batch,
                            client_weights, lr_c, rng_c, state["opt"],
                            meta_batch, state["ctrl"], opt=fed.server_opt,
                            clip_norm=fed.clip_norm,
                            momentum=fed.server_momentum,
                            ctrl_lr=fed.ctrl_lr, rng=rng_m)
                else:
                    spec = F.make_flat_spec(params)
                    G_groups, client_loss = scan_cohort_gradient_flat(
                        client_update, params, cohort_batch, client_weights,
                        lr_c, rng_c, spec=spec)
                    new_params, opt_state, gn_post = fused_apply_flat(
                        params, G_groups, state["opt"], opt=fed.server_opt,
                        lr=server_lr, clip_norm=fed.clip_norm,
                        momentum=fed.server_momentum, spec=spec)
            else:
                g_stack, client_loss = cohort_gradient(
                    client_update, params, cohort_batch, client_weights,
                    lr_c, rng_c, strategy="vmap", agg_dtype=agg_dtype,
                    aggregate=False)
                if through_agg:
                    new_params, opt_state, gn_post, new_ctrl, meta_metrics \
                        = meta_update_through_aggregation(
                            model.loss, params, g_stack, client_weights,
                            state["opt"], meta_batch, state["ctrl"],
                            opt=fed.server_opt, clip_norm=fed.clip_norm,
                            momentum=fed.server_momentum,
                            ctrl_lr=fed.ctrl_lr, rng=rng_m)
                else:
                    new_params, opt_state, gn_post = fused_server_update(
                        params, g_stack, client_weights, state["opt"],
                        opt=fed.server_opt, lr=server_lr,
                        clip_norm=fed.clip_norm,
                        momentum=fed.server_momentum)
            metrics = {"client_loss": client_loss, "grad_norm": gn_post,
                       **meta_metrics}
        else:
            G, client_loss = cohort_gradient(
                client_update, params, cohort_batch, client_weights, lr_c,
                rng_c, strategy=fed.cohort_strategy, agg_dtype=agg_dtype)
            if fed.clip_norm > 0:
                gn = tree_global_norm(G)
                scale = jnp.minimum(1.0,
                                    fed.clip_norm / jnp.maximum(gn, 1e-9))
                G = jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                            ).astype(g.dtype), G)
            new_params, opt_state = server_opt.apply(
                fed.server_opt, state["opt"], params, G, server_lr,
                momentum=fed.server_momentum)
            metrics = {"client_loss": client_loss,
                       "grad_norm": tree_global_norm(G)}

        if fed.meta and not through_agg:
            lr_m = fed.meta_lr * (fed.lr_decay ** r)
            new_params, meta_loss = meta_update(
                model.loss, new_params, meta_batch, lr_m, rng_m)
            metrics["meta_loss"] = meta_loss

        new_state = {"params": new_params, "opt": opt_state,
                     "round": state["round"] + 1}
        if through_agg:
            new_state["ctrl"] = new_ctrl
        return new_state, metrics

    return one_round


MATRIX = [(fused, strat, mode, opt)
          for fused in (False, True)
          for strat in ("vmap", "scan")
          for mode in ("post", "through_aggregation")
          for opt in ("sgd", "adam")
          if not (mode == "through_aggregation" and not fused)]


@pytest.mark.parametrize("fused,strat,mode,opt", MATRIX)
def test_equivalence_matrix_bit_identical(key, fused, strat, mode, opt):
    """Registry-built round == PR-3 round, bit for bit: params, opt state,
    ctrl and every metric, over two chained rounds (so round-1 outputs feed
    round-2 inputs on both sides)."""
    model = make_mlp_model()
    fed = FedConfig(algorithm="uga", meta=True, cohort=4, local_steps=2,
                    client_lr=0.05, server_lr=0.1, meta_lr=0.05,
                    server_opt=opt, clip_norm=1.0, lr_decay=0.9,
                    cohort_strategy=strat, fused_update=fused,
                    meta_mode=mode)
    batch, meta, wts = _round_inputs()
    new_rf = jax.jit(make_federated_round(model, fed))
    ref_rf = jax.jit(make_reference_round(model, fed))
    st_new = init_server_state(model, fed, key)
    st_ref = jax.tree.map(jnp.copy, st_new)
    for r in range(2):
        st_new, m_new = new_rf(st_new, batch, meta, wts,
                               jax.random.fold_in(key, r))
        st_ref, m_ref = ref_rf(st_ref, batch, meta, wts,
                               jax.random.fold_in(key, r))
    assert tree_equal(st_new, st_ref)
    assert sorted(m_new) == sorted(m_ref)
    for name in m_new:
        np.testing.assert_array_equal(np.asarray(m_new[name]),
                                      np.asarray(m_ref[name]), err_msg=name)


@pytest.mark.parametrize("fused,strat,mode,opt",
                         [(True, "vmap", "through_aggregation", "adam"),
                          (True, "scan", "post", "sgd"),
                          (False, "vmap", "post", "adam")])
def test_equivalence_matrix_rounds_per_call(key, fused, strat, mode, opt):
    """Same gate under the K-chunked driver: new rounds_per_call=2 round ==
    the reference body wrapped in the same lax.scan."""
    from jax import lax
    model = make_mlp_model()
    fed = FedConfig(algorithm="uga", meta=True, cohort=4, local_steps=2,
                    client_lr=0.05, server_lr=0.1, meta_lr=0.05,
                    server_opt=opt, clip_norm=1.0, lr_decay=0.9,
                    cohort_strategy=strat, fused_update=fused,
                    meta_mode=mode)
    Kr = 2
    batch, meta, wts = _round_inputs()
    stack = lambda t: jax.tree.map(lambda x: jnp.stack([x] * Kr), t)
    rngs = jnp.stack([jax.random.fold_in(key, r) for r in range(Kr)])

    new_rf = jax.jit(make_federated_round(model, fed, rounds_per_call=Kr))
    ref_body = make_reference_round(model, fed)

    def ref_rf(state, cbs, mbs, ws, rs):
        return lax.scan(lambda st, xs: ref_body(st, *xs), state,
                        (cbs, mbs, ws, rs))

    st_new, m_new = new_rf(init_server_state(model, fed, key), stack(batch),
                           stack(meta), jnp.stack([wts] * Kr), rngs)
    st_ref, m_ref = jax.jit(ref_rf)(init_server_state(model, fed, key),
                                    stack(batch), stack(meta),
                                    jnp.stack([wts] * Kr), rngs)
    assert tree_equal(st_new, st_ref)
    for name in m_new:
        np.testing.assert_array_equal(np.asarray(m_new[name]),
                                      np.asarray(m_ref[name]), err_msg=name)


# ---------------------------------------------------------------------------
# registry-only extensions: toy algorithm, toy engine, fednova
# ---------------------------------------------------------------------------
@register_algorithm("_test_halfavg", pseudo_gradient=True,
                    description="fedavg deltas scaled by 1/2 (test only)")
def _build_halfavg(loss_fn, *, local_steps, local_epochs, prox_mu, remat):
    del prox_mu

    def update(w_t, batch, lr, rng):
        pseudo, l = fedavg_update(loss_fn, w_t, batch, lr, rng,
                                  local_steps=local_steps,
                                  local_epochs=local_epochs, remat=remat)
        return jax.tree.map(lambda g: 0.5 * g, pseudo), l
    return update


@register_engine("_test_sign_sgd")
class _SignSgdEngine(ServerEngine):
    """Tree-consuming sign-SGD engine (test only): w <- w - lr * sign(G)."""
    name = "_test_sign_sgd"
    accepts = frozenset({"tree"})
    preferred = "tree"
    meta_capabilities = frozenset({"post"})

    def __init__(self, fed):
        del fed

    def init_state(self, params):
        return {}

    def apply(self, params, handle, opt_state, *, lr):
        G = handle.tree
        new_p = jax.tree.map(
            lambda p, g: (p.astype(jnp.float32)
                          - lr * jnp.sign(g.astype(jnp.float32))
                          ).astype(p.dtype), params, G)
        return new_p, opt_state, tree_global_norm(G)


def test_registered_toy_algorithm_runs_end_to_end(key):
    """A client algorithm lands via register_algorithm ONLY (no core/round
    edits): halved fedavg deltas => exactly half the parameter step under
    the plain-SGD unit-lr server."""
    model = make_mlp_model()
    batch, meta, wts = _round_inputs()
    p0 = model.init(key)
    deltas = {}
    for algo in ("fedavg", "_test_halfavg"):
        fed = FedConfig(algorithm=algo, meta=False, cohort=4, local_steps=2,
                        client_lr=0.05)
        st = init_server_state(model, fed, key)
        st, m = jax.jit(make_federated_round(model, fed))(
            st, batch, meta, wts, key)
        assert np.isfinite(float(m["client_loss"]))
        deltas[algo] = jax.tree.map(
            lambda new, old: np.asarray(new, np.float32)
            - np.asarray(old, np.float32), st["params"], p0)
    # atol ~ eps32 * |param|: the delta is recovered as new - old, so each
    # entry carries one ulp of the PARAMETER scale from the p - G/2 round
    for k_ in deltas["fedavg"]:
        np.testing.assert_allclose(deltas["_test_halfavg"][k_],
                                   0.5 * deltas["fedavg"][k_],
                                   rtol=1e-5, atol=2e-7)


@pytest.mark.parametrize("strat", ["vmap", "scan"])
def test_registered_toy_engine_runs_end_to_end(key, strat):
    """A server engine lands via register_engine ONLY and composes with
    both built-in cohort executors through the tree handle."""
    model = make_mlp_model()
    batch, meta, wts = _round_inputs()
    fed = FedConfig(algorithm="uga", meta=False, cohort=4, local_steps=2,
                    client_lr=0.05, server_lr=0.01, cohort_strategy=strat)
    st = init_server_state(model, fed, key, engine="_test_sign_sgd")
    rf = jax.jit(make_federated_round(model, fed, engine="_test_sign_sgd"))
    st1, m = rf(st, batch, meta, wts, key)
    # sign-SGD: every parameter moved by exactly +-lr (fp32 grid)
    p0 = model.init(key)
    for a, b in zip(jax.tree.leaves(st1["params"]), jax.tree.leaves(p0)):
        step = np.abs(np.asarray(a) - np.asarray(b))
        np.testing.assert_allclose(step, 0.01, rtol=1e-5)
    assert np.isfinite(float(m["client_loss"]))


def test_fednova_matches_fedavg_at_tau_server_lr(key):
    """fednova normalizes deltas by tau = local_steps * local_epochs; with
    server_opt=sgd and server_lr=tau the round recovers fedavg exactly up
    to XLA fusion (tau=2 keeps the normalize+rescale mathematically exact,
    but the two programs contract the server FMA differently — ~1 ulp)."""
    model = make_mlp_model()
    batch, meta, wts = _round_inputs()
    states = {}
    for algo, slr in (("fedavg", 0.123), ("fednova", 2.0)):
        fed = FedConfig(algorithm=algo, meta=False, cohort=4, local_steps=2,
                        local_epochs=1, client_lr=0.05, server_lr=slr)
        st = init_server_state(model, fed, key)
        states[algo], _ = jax.jit(make_federated_round(model, fed))(
            st, batch, meta, wts, key)
    for a, b in zip(jax.tree.leaves(states["fedavg"]["params"]),
                    jax.tree.leaves(states["fednova"]["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-7)


def test_fednova_registered_and_validates():
    assert "fednova" in available_algorithms()
    FedConfig(algorithm="fednova")                       # validates
    with pytest.raises(ValueError, match="register_algorithm"):
        FedConfig(algorithm="not-a-thing")


def test_sharded_is_not_a_base_cohort_strategy():
    """'sharded' wraps cohort_strategy as its base (selected by
    grad_shardings), so using it AS the base must fail actionably at
    config time, not as a bare ValueError deep in the cohort dispatch."""
    with pytest.raises(ValueError, match="grad_shardings"):
        FedConfig(cohort_strategy="sharded")


def test_config_engine_field_drives_capability_and_round(key):
    """FedConfig.engine names a registry engine directly: a capability-
    declaring engine makes through_aggregation valid WITHOUT
    fused_update=True (the capability check runs against the resolved
    engine, not the fused_update flag), and the round runs end to end."""
    fed = FedConfig(algorithm="uga", meta=True, cohort=4, local_steps=2,
                    client_lr=0.05, server_lr=0.1, server_opt="sgd",
                    fused_update=False, engine="fused_flat",
                    meta_mode="through_aggregation", ctrl_lr=0.5)
    model = make_mlp_model()
    batch, meta, wts = _round_inputs()
    st = init_server_state(model, fed, key)
    st, m = jax.jit(make_federated_round(model, fed))(
        st, batch, meta, wts, key)
    assert np.isfinite(float(m["meta_loss"]))
    assert not np.allclose(np.asarray(st["ctrl"]["w_logits"]), 0.0)
    # an engine without the capability still fails loudly at config time
    with pytest.raises(ValueError, match="capability"):
        FedConfig(meta=True, meta_mode="through_aggregation",
                  fused_update=True, engine="_test_sign_sgd")


# ---------------------------------------------------------------------------
# partial participation / straggler dropout
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("fused,strat", [(False, "vmap"), (True, "vmap"),
                                         (True, "scan")])
def test_participation_equals_manual_weight_masking(key, fused, strat):
    """participation<1 == zeroing the same clients' weights by hand: the
    mask folds out of the round rng (never perturbing the client/meta
    streams), so a participation=1 round fed pre-masked weights is bit-
    identical on params and shared metrics."""
    model = make_mlp_model()
    batch, meta, wts = _round_inputs()
    rate = 0.5
    kw = dict(algorithm="uga", meta=True, cohort=4, local_steps=2,
              client_lr=0.05, server_lr=0.1, meta_lr=0.05, clip_norm=1.0,
              cohort_strategy=strat, fused_update=fused)
    fed_p = FedConfig(participation=rate, **kw)
    fed_1 = FedConfig(**kw)
    mask = participation_mask(key, 4, rate)
    assert 0 < float(mask.sum()) < 4, "seed gives a non-trivial mask"

    st_p = init_server_state(model, fed_p, key)
    st_p, m_p = jax.jit(make_federated_round(model, fed_p))(
        st_p, batch, meta, wts, key)
    st_1 = init_server_state(model, fed_1, key)
    st_1, m_1 = jax.jit(make_federated_round(model, fed_1))(
        st_1, batch, meta, wts * mask, key)

    assert tree_equal(st_p["params"], st_1["params"])
    assert float(m_p["participants"]) == float(mask.sum())
    for name in m_1:
        np.testing.assert_array_equal(np.asarray(m_p[name]),
                                      np.asarray(m_1[name]), err_msg=name)


def test_participation_one_is_bit_identical_to_default(key):
    """participation=1.0 must not change ANYTHING (same rng splits, same
    metric keys) — the historical-stream guard."""
    model = make_mlp_model()
    batch, meta, wts = _round_inputs()
    outs = {}
    for p in (None, 1.0):
        fed = FedConfig(algorithm="uga", meta=True, cohort=4, local_steps=2,
                        client_lr=0.05, server_lr=0.1, meta_lr=0.05,
                        **({} if p is None else {"participation": p}))
        st = init_server_state(model, fed, key)
        outs[p] = jax.jit(make_federated_round(model, fed))(
            st, batch, meta, wts, key)
    assert tree_equal(outs[None][0], outs[1.0][0])
    assert sorted(outs[None][1]) == sorted(outs[1.0][1])
    assert "participants" not in outs[1.0][1]


def test_participation_with_through_aggregation(key):
    """Dropped clients get zero effective weight AND zero w_logits
    hypergradient (d eff_w / d logit = n_k * mask * exp = 0)."""
    model = make_mlp_model()
    batch, meta, wts = _round_inputs()
    fed = FedConfig(algorithm="uga", meta=True, cohort=4, local_steps=2,
                    client_lr=0.05, server_lr=0.1, server_opt="sgd",
                    fused_update=True, meta_mode="through_aggregation",
                    ctrl_lr=1.0, participation=0.5)
    mask = np.asarray(participation_mask(key, 4, 0.5))
    st = init_server_state(model, fed, key)
    st, m = jax.jit(make_federated_round(model, fed))(
        st, batch, meta, wts, key)
    wl = np.asarray(st["ctrl"]["w_logits"])
    assert np.all(wl[mask == 0.0] == 0.0)
    assert np.any(wl[mask == 1.0] != 0.0)
    assert np.isfinite(float(m["meta_loss"]))


def test_participation_validation():
    with pytest.raises(ValueError, match="participation"):
        FedConfig(participation=0.0)
    with pytest.raises(ValueError, match="participation"):
        FedConfig(participation=1.5)


# ---------------------------------------------------------------------------
# FederatedTrainer: driver dedup, resume, records
# ---------------------------------------------------------------------------
def _toy_fed_data(seed=0, n=256, d=10, classes=4, clients=8):
    rng = np.random.default_rng(seed)
    x = rng.normal(0, 1, (n, d)).astype(np.float32)
    y = rng.integers(0, classes, n).astype(np.int32)
    parts = np.array_split(rng.permutation(n), clients)
    meta = rng.choice(n, 16, replace=False)
    return FederatedData(arrays={"x": x, "y": y}, client_indices=parts,
                         meta_indices=meta, seed=seed)


def test_trainer_k1_history_matches_legacy_driver_loop(key):
    """The deduplicated rounds_per_call=1 path must reproduce the old
    driver branch (direct unstacked call + scalar float()) bit-exactly —
    the regression gate for routing k==1 through the shared assembly."""
    model = make_mlp_model()
    fed = FedConfig(algorithm="uga", meta=True, cohort=4, local_steps=2,
                    client_lr=0.05, server_lr=0.1, meta_lr=0.05)
    data = _toy_fed_data()
    rounds, batch, meta_bs = 4, 16, 8

    # --- the pre-facade k==1 loop, verbatim ---
    legacy_key = jax.random.PRNGKey(0)
    get_rf = RoundFnCache(model, fed)
    state = init_server_state(model, fed, legacy_key)
    legacy_hist = []
    for r in range(rounds):
        s = data.sample_round(r, cohort=4, batch=batch, share=False)
        mb = data.sample_meta(r, meta_bs)
        state, m = get_rf(1)(
            state, jax.tree.map(jnp.asarray, s["cohort_batch"]),
            jax.tree.map(jnp.asarray, mb),
            jnp.asarray(s["client_weights"]),
            jax.random.fold_in(legacy_key, r))
        rec = {name: float(v) for name, v in m.items()}
        rec["round"] = r
        legacy_hist.append(rec)

    trainer = FederatedTrainer(model, fed, rounds_per_call=1, seed=0)
    hist = trainer.run(data, rounds=rounds, cohort=4, batch=batch,
                       meta_batch=meta_bs)
    assert hist == legacy_hist
    assert tree_equal(trainer.state["params"], state["params"])


def test_trainer_chunked_records_and_tail(key):
    """rounds_per_call=4 over 6 rounds: one full chunk + a 2-round tail,
    one record per round, on_records sees every chunk."""
    model = make_mlp_model()
    fed = FedConfig(algorithm="fedavg", meta=False, cohort=4, local_steps=2,
                    client_lr=0.05, fused_update=True)
    data = _toy_fed_data()
    seen = []
    trainer = FederatedTrainer(model, fed, rounds_per_call=4, seed=0)
    hist = trainer.run(data, rounds=6, cohort=4, batch=16,
                       on_records=lambda recs, tr: seen.append(len(recs)))
    assert [h["round"] for h in hist] == list(range(6))
    assert seen == [4, 2]
    assert trainer.round == 6
    assert all(np.isfinite(h["client_loss"]) for h in hist)


@pytest.mark.parametrize("fused", [False, True])
def test_trainer_resume_continues_bit_identically(key, tmp_path, fused):
    """save at round 2 of 6 (mid-chunk schedule), restore into a FRESH
    trainer, finish: params and history tail == the uninterrupted run."""
    model = make_mlp_model()
    fed = FedConfig(algorithm="uga", meta=True, cohort=4, local_steps=2,
                    client_lr=0.05, server_lr=0.1, meta_lr=0.05,
                    server_opt="adam", fused_update=fused)
    data = _toy_fed_data()
    kw = dict(cohort=4, batch=16, meta_batch=8)

    straight = FederatedTrainer(model, fed, rounds_per_call=2, seed=0)
    full_hist = straight.run(data, rounds=6, **kw)

    part = FederatedTrainer(model, fed, rounds_per_call=2, seed=0)
    part.run(data, rounds=2, **kw)
    path = os.path.join(tmp_path, "state.msgpack")
    part.save(path, extra={"arch": "mlp"})

    resumed = FederatedTrainer(model, fed, rounds_per_call=2, seed=0)
    extra = resumed.restore(path)
    assert extra["arch"] == "mlp"
    assert resumed.round == 2
    tail = resumed.run(data, rounds=6, **kw)
    assert tree_equal(resumed.state, straight.state)
    assert tail == full_hist[2:]


# ---------------------------------------------------------------------------
# back-compat import surface + data-pipeline error
# ---------------------------------------------------------------------------
def test_backcompat_import_surface():
    """Every pre-registry entry point stays importable from repro.core AND
    its original module, with working call signatures."""
    from repro.core import (init_server_state, make_federated_round,  # noqa
                            resolve_server_lr, RoundFnCache,
                            stack_round_inputs, grad_global_norm)
    from repro.core.round import (init_server_state as r_init,  # noqa
                                  make_federated_round as r_make,
                                  RoundFnCache as r_cache,
                                  stack_round_inputs as r_stack,
                                  grad_global_norm as r_norm,
                                  resolve_server_lr as r_lr)
    from repro.core.client import make_client_update
    model = make_mlp_model()
    # make_client_update resolves EVERY registered algorithm (incl. the
    # registry-only fednova) and still raises for unknown names
    for algo in available_algorithms():
        assert callable(make_client_update(algo, model.loss, local_steps=2))
    with pytest.raises(ValueError):
        make_client_update("nope", model.loss, local_steps=2)
    # grad_global_norm keeps its semantics
    g = {"a": jnp.asarray([3.0, 4.0])}
    np.testing.assert_allclose(float(grad_global_norm(g)), 5.0, rtol=1e-6)
    # RoundFnCache / stack_round_inputs keep their pre-facade signatures
    fed = FedConfig(algorithm="uga", meta=False, cohort=2, local_steps=2)
    assert callable(RoundFnCache(model, fed)(1))
    cb, mb, w, r = stack_round_inputs(
        [{"x": np.ones((2, 4))}] * 2, [None, None],
        [np.ones(2)] * 2, [jax.random.PRNGKey(0)] * 2)
    assert cb["x"].shape == (2, 2, 4) and mb is None and w.shape == (2, 2)


def test_explicit_executor_override_with_grad_shardings_raises():
    """An explicit executor name + grad_shardings would silently drop the
    sharding constraints (flat/scan paths never attach them) — it must be
    rejected with the sharded executor named."""
    model = make_mlp_model()
    fed = FedConfig(algorithm="uga", meta=False, cohort=2, local_steps=2,
                    fused_update=True)
    with pytest.raises(ValueError, match="sharded"):
        make_federated_round(model, fed, grad_shardings={"w1": None},
                             executor="vmap")


def test_train_cli_plugin_flag_registers_algorithm(tmp_path):
    """The documented one-file CLI plugin workflow: --plugin imports the
    module before --algorithm's choices freeze, so a register_algorithm
    name is selectable in the same invocation."""
    import subprocess
    import sys
    import textwrap
    (tmp_path / "cli_demo_plugin.py").write_text(textwrap.dedent("""
        from functools import partial
        from repro.core.algorithms import register_algorithm
        from repro.core.client import fedavg_update

        @register_algorithm("cli_demo", pseudo_gradient=True,
                            description="CLI plugin smoke algorithm")
        def build(loss_fn, *, local_steps, local_epochs, prox_mu, remat):
            del prox_mu
            return partial(fedavg_update, loss_fn, local_steps=local_steps,
                           local_epochs=local_epochs, remat=remat)
    """))
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(root, "src"), str(tmp_path)] +
        env.get("PYTHONPATH", "").split(os.pathsep))
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.train",
         "--plugin", "cli_demo_plugin", "--algorithm", "cli_demo",
         "--arch", "smollm-360m-smoke", "--rounds", "2", "--cohort", "2",
         "--client-batch", "4", "--seq", "16", "--no-meta",
         "--num-clients", "4", "--examples", "32", "--log-every", "1"],
        capture_output=True, text=True, cwd=root, env=env, timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "round    1" in out.stdout


def test_sample_round_cohort_exceeds_clients_actionable_error():
    """cohort > num_clients used to surface numpy's opaque 'Cannot take a
    larger sample than population' — it must name both numbers now."""
    data = _toy_fed_data(clients=4)
    with pytest.raises(ValueError, match=r"cohort=9.*num_clients=4"):
        data.sample_round(0, cohort=9, batch=8)
    # boundary: cohort == num_clients still samples
    s = data.sample_round(0, cohort=4, batch=8)
    assert len(s["clients"]) == 4

"""Paper CNN/GRU models + loss plumbing (chunked LM xent == direct)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs import paper_models as pm
from repro.models import transformer
from repro.models.layers import softmax_xent
from repro.models.model import build_model, build_paper_cnn, build_paper_gru
from repro.optim import sgd_step


def test_cnn_shapes_and_overfit(key):
    model = build_paper_cnn(pm.CIFAR_CNN_SMOKE)
    params = model.init(key)
    x = jax.random.normal(key, (8, 32, 32, 3))
    y = jnp.arange(8) % 10
    batch = {"x": x, "y": y}
    l0, m0 = model.loss(params, batch)
    step = jax.jit(lambda p: sgd_step(
        p, jax.grad(lambda q: model.loss(q, batch)[0])(p), 0.05))
    for _ in range(60):
        params = step(params)
    l1, m1 = model.loss(params, batch)
    assert float(l1) < float(l0) * 0.3
    assert float(m1["acc"]) > 0.8


def test_femnist_cnn_forward(key):
    model = build_paper_cnn(pm.FEMNIST_CNN_SMOKE)
    params = model.init(key)
    batch = {"x": jax.random.normal(key, (4, 28, 28, 1)),
             "y": jnp.array([0, 1, 2, 3])}
    l, m = model.loss(params, batch)
    assert np.isfinite(float(l))


def test_gru_overfit(key):
    model = build_paper_gru(pm.SHAKESPEARE_GRU_SMOKE)
    params = model.init(key)
    batch = {"tokens": jax.random.randint(key, (4, 12), 0, 90)}
    l0, _ = model.loss(params, batch)
    step = jax.jit(lambda p: sgd_step(
        p, jax.grad(lambda q: model.loss(q, batch)[0])(p), 0.5))
    for _ in range(200):
        params = step(params)
    l1, _ = model.loss(params, batch)
    assert float(l1) < float(l0) * 0.5


@pytest.mark.parametrize("chunk", [4, 16, 64])
def test_chunked_lm_loss_equals_direct(key, chunk):
    cfg = configs.get_smoke("smollm-360m")
    model = build_model(cfg, dtype=jnp.float32, loss_chunk=chunk)
    params = model.init(key)
    toks = jax.random.randint(key, (2, 33), 0, cfg.vocab_size)
    l, m = model.loss(params, {"tokens": toks})
    logits, aux = transformer.forward(params, toks[:, :-1], cfg, remat=False)
    direct = softmax_xent(logits, toks[:, 1:]) + aux
    np.testing.assert_allclose(float(l), float(direct), rtol=1e-5)


def test_chunked_lm_loss_respects_mask(key):
    cfg = configs.get_smoke("smollm-360m")
    model = build_model(cfg, dtype=jnp.float32, loss_chunk=8)
    params = model.init(key)
    toks = jax.random.randint(key, (2, 33), 0, cfg.vocab_size)
    mask = jnp.ones((2, 33)).at[:, 20:].set(0.0)
    l_m, _ = model.loss(params, {"tokens": toks, "mask": mask})
    logits, aux = transformer.forward(params, toks[:, :-1], cfg, remat=False)
    direct = softmax_xent(logits, toks[:, 1:], mask[:, 1:]) + aux
    np.testing.assert_allclose(float(l_m), float(direct), rtol=1e-5)

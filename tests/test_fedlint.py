"""fedlint self-tests: one good + one bad fixture per pass.

Each fixture is a tiny synthetic tree written under tmp_path; ``run_on``
materializes it and runs the full analyzer.  Bad fixtures must produce the
documented FLNNN code (and ONLY findings of that code, so passes never
bleed into each other's fixtures); good fixtures must come back clean.
"""
import textwrap

from repro.analysis.fedlint import Finding, run_fedlint
from repro.analysis.fedlint.__main__ import main as fedlint_main


def run_on(tmp_path, files):
    for rel, src in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(src))
    return run_fedlint([str(tmp_path)])


def codes(findings):
    return sorted(f.code for f in findings)


# ---------------------------------------------------------------------------
# FL001 — parse failure
# ---------------------------------------------------------------------------
def test_fl001_unparseable_file(tmp_path):
    found = run_on(tmp_path, {"broken.py": "def f(:\n"})
    assert codes(found) == ["FL001"]


# ---------------------------------------------------------------------------
# FL101 — inline constant rng tag
# ---------------------------------------------------------------------------
def test_fl101_inline_fold_tag(tmp_path):
    found = run_on(tmp_path, {"mod.py": """\
        import jax

        def derive(k):
            return jax.random.fold_in(k, 0x1234)
    """})
    assert codes(found) == ["FL101"]
    assert "rngtags" in found[0].message


def test_fl101_local_constant_tag(tmp_path):
    found = run_on(tmp_path, {"mod.py": """\
        import jax

        MY_TAG = 99

        def derive(k):
            return jax.random.fold_in(k, MY_TAG)
    """})
    assert codes(found) == ["FL101"]


def test_fl101_good_registry_import_and_dynamic_tags(tmp_path):
    found = run_on(tmp_path, {
        "core/rngtags.py": "EVAL_FOLD = 10_000\n",
        "mod.py": """\
            import jax
            from core.rngtags import EVAL_FOLD

            def derive(k, i):
                a = jax.random.fold_in(k, EVAL_FOLD)
                b = jax.random.fold_in(k, i)          # dynamic: fine
                return a, b
        """})
    assert found == []


# ---------------------------------------------------------------------------
# FL102 — duplicate tag values
# ---------------------------------------------------------------------------
def test_fl102_registry_collision(tmp_path):
    found = run_on(tmp_path, {"core/rngtags.py": """\
        A_FOLD = 0x42
        B_FOLD = 0x42
    """})
    assert codes(found) == ["FL102"]
    assert "A_FOLD" in found[0].message and "B_FOLD" in found[0].message


def test_fl102_good_distinct_registry(tmp_path):
    found = run_on(tmp_path, {"core/rngtags.py": """\
        A_FOLD = 0x42
        B_FOLD = 0x43
    """})
    assert found == []


# ---------------------------------------------------------------------------
# FL103 — key consumed twice
# ---------------------------------------------------------------------------
def test_fl103_key_reuse(tmp_path):
    found = run_on(tmp_path, {"mod.py": """\
        import jax

        def sample(key):
            a = jax.random.normal(key, (2,))
            b = jax.random.uniform(key, (2,))
            return a + b
    """})
    assert codes(found) == ["FL103"]
    assert "'key'" in found[0].message


def test_fl103_good_split_and_branches(tmp_path):
    found = run_on(tmp_path, {"mod.py": """\
        import jax

        def sample(key, flag):
            k1, k2 = jax.random.split(key)
            a = jax.random.normal(k1, (2,))
            b = jax.random.uniform(k2, (2,))
            # if/else arms are alternatives, not sequential consumption
            if flag:
                c = jax.random.normal(k1, (2,))
            else:
                c = jax.random.uniform(k1, (2,))
            return a + b + c
    """})
    assert found == []


def test_fl103_rebind_resets_tracking(tmp_path):
    found = run_on(tmp_path, {"mod.py": """\
        import jax

        def sample(key, n):
            out = []
            for _ in range(n):
                key, sub = jax.random.split(key)
                out.append(jax.random.normal(sub, (2,)))
            return out
    """})
    assert found == []


# ---------------------------------------------------------------------------
# FL201/FL202/FL203 — kernel / ref / ops contracts
# ---------------------------------------------------------------------------
_OPS_DISPATCH = """\
    from . import kernel as K
    from . import ref as R

    def foo(x, *, use_ref=False):
        if use_ref:
            return R.foo_ref(x)
        return K.foo_pass(x)
"""


def test_fl201_missing_oracle(tmp_path):
    found = run_on(tmp_path, {
        "kernels/foo/kernel.py": "def foo_pass(x):\n    return x\n",
        "kernels/foo/ref.py": "",
        "kernels/foo/ops.py": _OPS_DISPATCH,
    })
    assert codes(found) == ["FL201"]
    assert "foo_ref" in found[0].message


def test_fl202_signature_drift(tmp_path):
    found = run_on(tmp_path, {
        "kernels/foo/kernel.py":
            "def foo_pass(x, *, block_rows=8):\n    return x\n",
        "kernels/foo/ref.py": "def foo_ref(x, y):\n    return x + y\n",
        "kernels/foo/ops.py": _OPS_DISPATCH,
    })
    assert codes(found) == ["FL202"]
    assert "signature drift" in found[0].message


def test_fl203_no_use_ref_dispatch(tmp_path):
    found = run_on(tmp_path, {
        "kernels/foo/kernel.py": "def foo_pass(x):\n    return x\n",
        "kernels/foo/ref.py": "def foo_ref(x):\n    return x\n",
        "kernels/foo/ops.py": """\
            from . import kernel as K

            def foo(x):
                return K.foo_pass(x)
        """,
    })
    assert codes(found) == ["FL203"]
    assert "use_ref" in found[0].message


def test_kernel_triple_good(tmp_path):
    found = run_on(tmp_path, {
        "kernels/foo/kernel.py":
            "def foo_pass(x, *, block_rows=8, interpret=False):\n"
            "    return x\n",
        "kernels/foo/ref.py": "def foo_ref(x):\n    return x\n",
        "kernels/foo/ops.py": _OPS_DISPATCH,
    })
    assert found == []


def test_kernel_rules_ignore_non_kernel_dirs(tmp_path):
    # a kernel.py outside kernels/ is not part of the contract
    found = run_on(tmp_path, {
        "misc/kernel.py": "def bar_pass(x):\n    return x\n"})
    assert found == []


# ---------------------------------------------------------------------------
# FL204 — custom_vjp without defvjp
# ---------------------------------------------------------------------------
def test_fl204_missing_defvjp(tmp_path):
    found = run_on(tmp_path, {"mod.py": """\
        import jax

        @jax.custom_vjp
        def f(x):
            return x * x
    """})
    assert codes(found) == ["FL204"]
    assert "f.defvjp" in found[0].message


def test_fl204_good_paired_defvjp(tmp_path):
    found = run_on(tmp_path, {"mod.py": """\
        import jax

        @jax.custom_vjp
        def f(x):
            return x * x

        def f_fwd(x):
            return x * x, x

        def f_bwd(res, g):
            return (2.0 * res * g,)

        f.defvjp(f_fwd, f_bwd)
    """})
    assert found == []


# ---------------------------------------------------------------------------
# FL301 — registry capability surfaces
# ---------------------------------------------------------------------------
def test_fl301_engine_missing_capabilities(tmp_path):
    found = run_on(tmp_path, {"mod.py": """\
        from engines import register_engine

        @register_engine("half")
        class HalfEngine:
            accepts = ("delta",)
            preferred = "delta"
    """})
    assert codes(found) == ["FL301"]
    msg = found[0].message
    assert "is_async" in msg and "codec_capabilities" in msg


def test_fl301_good_capabilities_via_base(tmp_path):
    found = run_on(tmp_path, {"mod.py": """\
        from engines import register_engine

        class Base:
            meta_capabilities = ("none",)
            codec_capabilities = ("identity",)
            is_async = False

        @register_engine("full")
        class FullEngine(Base):
            accepts = ("delta",)
            preferred = "delta"
    """})
    assert found == []


def test_fl301_algorithm_without_pseudo_gradient(tmp_path):
    found = run_on(tmp_path, {"mod.py": """\
        from algorithms import register_algorithm

        register_algorithm("fedavg", description="plain averaging")
    """})
    assert codes(found) == ["FL301"]
    assert "pseudo_gradient" in found[0].message


# ---------------------------------------------------------------------------
# FL302 — stale ValueError field guidance
# ---------------------------------------------------------------------------
def test_fl302_stale_config_field(tmp_path):
    found = run_on(tmp_path, {"mod.py": """\
        class FedConfig:
            cohort_size: int = 4

        def guard(cfg):
            raise ValueError("bad setup; set num_cohorts=8 instead")
    """})
    assert codes(found) == ["FL302"]
    assert "num_cohorts" in found[0].message


def test_fl302_good_real_field_and_param(tmp_path):
    found = run_on(tmp_path, {"mod.py": """\
        class FedConfig:
            cohort_size: int = 4

        def guard(cfg, server_lr):
            raise ValueError(
                f"bad setup (server_lr={server_lr}); set cohort_size=8")
    """})
    assert found == []


# ---------------------------------------------------------------------------
# FL401/FL402/FL403 — jit hygiene
# ---------------------------------------------------------------------------
def test_fl401_item_and_float_in_jit(tmp_path):
    found = run_on(tmp_path, {"mod.py": """\
        import jax

        @jax.jit
        def f(x):
            s = x.sum().item()
            return float(x) + s
    """})
    assert codes(found) == ["FL401", "FL401"]


def test_fl402_host_numpy_in_scanned_body(tmp_path):
    found = run_on(tmp_path, {"mod.py": """\
        import jax
        import numpy as np
        from jax import lax

        def step(carry, x):
            return carry + np.mean(x), None

        def run(xs):
            return lax.scan(step, 0.0, xs)
    """})
    assert codes(found) == ["FL402"]


def test_fl403_wall_clock_in_jit(tmp_path):
    found = run_on(tmp_path, {"mod.py": """\
        import time
        import jax

        @jax.jit
        def f(x):
            t = time.time()
            return x + t
    """})
    assert codes(found) == ["FL403"]


def test_jit_rules_good_host_code_untouched(tmp_path):
    found = run_on(tmp_path, {"mod.py": """\
        import time
        import jax
        import numpy as np

        @jax.jit
        def f(x):
            return x * 2.0

        def host_loop(x):
            t0 = time.time()                 # host side: fine
            y = np.asarray(f(x)).item()      # outside the traced body: fine
            return y, time.time() - t0
    """})
    assert found == []


# ---------------------------------------------------------------------------
# FL501 — sanitize-probe coverage
# ---------------------------------------------------------------------------
_FULL_ENGINE = """\
    from engines import register_engine

    @register_engine("full")
    class FullEngine:
        accepts = ("delta",)
        preferred = "delta"
        meta_capabilities = ("none",)
        codec_capabilities = ("identity",)
        is_async = False
"""


def test_fl501_builder_lost_its_probe(tmp_path):
    found = run_on(tmp_path, {
        "engine.py": _FULL_ENGINE,
        "round.py": """\
            def make_federated_round(model, fed, sanitize=False):
                def one_round(state, batch):
                    return state, {}
                return one_round
        """})
    assert codes(found) == ["FL501"]
    msg = found[0].message
    assert "make_federated_round" in msg and "check_flat_groups" in msg


def test_fl501_good_guarded_probe_in_builder(tmp_path):
    found = run_on(tmp_path, {
        "engine.py": _FULL_ENGINE,
        "round.py": """\
            from sanitize import check_flat_groups

            def make_federated_round(model, fed, sanitize=False):
                def one_round(state, batch):
                    if sanitize:
                        check_flat_groups(None, state, "post-round params")
                    return state, {}
                return one_round
        """})
    assert found == []


def test_fl501_async_engine_checks_make_async_tick(tmp_path):
    found = run_on(tmp_path, {
        "engine.py": """\
            from engines import register_engine

            @register_engine("buffered")
            class BufferedEngine:
                accepts = ("delta",)
                preferred = "delta"
                meta_capabilities = ("none",)
                codec_capabilities = ("identity",)
                is_async = True
        """,
        "async_round.py": """\
            def make_async_tick(model, fed, sanitize=False):
                def one_tick(state, batch):
                    return state, {}
                return one_tick
        """,
        # the SYNC builder has its probe; the async engine must not be
        # considered covered by it
        "round.py": """\
            from sanitize import check_flat_groups

            def make_federated_round(model, fed, sanitize=False):
                def one_round(state, batch):
                    if sanitize:
                        check_flat_groups(None, state, "post-round params")
                    return state, {}
                return one_round
        """})
    assert codes(found) == ["FL501"]
    assert "make_async_tick" in found[0].message


def test_fl501_good_class_local_probe(tmp_path):
    # an engine may carry its own guarded probe (e.g. inside apply())
    # instead of relying on the builder's
    found = run_on(tmp_path, {
        "engine.py": """\
            from engines import register_engine
            from sanitize import check_flat_groups

            @register_engine("careful")
            class CarefulEngine:
                accepts = ("delta",)
                preferred = "delta"
                meta_capabilities = ("none",)
                codec_capabilities = ("identity",)
                is_async = False

                def apply(self, params, handle, opt, lr, sanitize=False):
                    if sanitize:
                        check_flat_groups(None, handle, "engine apply")
                    return params, opt, 0.0
        """,
        "round.py": """\
            def make_federated_round(model, fed, sanitize=False):
                def one_round(state, batch):
                    return state, {}
                return one_round
        """})
    assert found == []


def test_fl501_silent_without_builder_in_tree(tmp_path):
    # single-file plugin snippets never carry the builder: no finding
    # (under-approximation — also keeps the FL301 fixtures clean)
    found = run_on(tmp_path, {"engine.py": _FULL_ENGINE})
    assert found == []


# ---------------------------------------------------------------------------
# suppressions, output format, CLI exit codes
# ---------------------------------------------------------------------------
def test_suppression_comment_drops_finding(tmp_path):
    found = run_on(tmp_path, {"mod.py": """\
        import jax

        def derive(k):
            return jax.random.fold_in(k, 0x1234)  # fedlint: disable=FL101
    """})
    assert found == []


def test_suppression_is_code_specific(tmp_path):
    found = run_on(tmp_path, {"mod.py": """\
        import jax

        def derive(k):
            return jax.random.fold_in(k, 0x1234)  # fedlint: disable=FL999
    """})
    assert codes(found) == ["FL101"]


def test_finding_format():
    f = Finding("src/x.py", 12, "FL101", "inline tag")
    assert f.format() == "src/x.py:12: FL101 inline tag"


def test_cli_exit_codes(tmp_path, capsys):
    bad = tmp_path / "bad"
    bad.mkdir()
    (bad / "mod.py").write_text(
        "import jax\n\ndef f(k):\n    return jax.random.fold_in(k, 7)\n")
    assert fedlint_main([str(bad)]) == 1
    out = capsys.readouterr().out
    assert "FL101" in out

    good = tmp_path / "good"
    good.mkdir()
    (good / "mod.py").write_text("def f(x):\n    return x\n")
    assert fedlint_main([str(good)]) == 0
    assert "clean" in capsys.readouterr().out

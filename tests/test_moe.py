"""MoE: gather/scatter dispatch vs the dense einsum oracle, capacity
semantics, shared experts, router aux loss."""
import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests skip; unit tests still run
    from _hypothesis_stub import given, settings, st

from repro.configs.base import MoEConfig
from repro.models.moe import (moe_ffn, moe_ffn_einsum, moe_ffn_gather,
                              moe_init)


def _setup(key, E, K, shared, cf, gs, d=16, de=32, B=2, S=50):
    cfg = MoEConfig(num_experts=E, top_k=K, num_shared=shared,
                    capacity_factor=cf, group_size=gs)
    p = moe_init(key, d, cfg, de)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, d))
    return cfg, p, x


@settings(max_examples=10, deadline=None)
@given(E=st.sampled_from([4, 8, 16]), K=st.integers(1, 3),
       shared=st.integers(0, 2), cf=st.sampled_from([1.0, 1.25, 4.0]),
       gs=st.sampled_from([32, 64, 4096]))
def test_gather_equals_einsum(E, K, shared, cf, gs):
    key = jax.random.PRNGKey(0)
    cfg, p, x = _setup(key, E, K, shared, cf, gs)
    y1, a1 = moe_ffn_gather(x, p, cfg)
    y2, a2 = moe_ffn_einsum(x, p, cfg)
    np.testing.assert_allclose(y1, y2, atol=2e-5, rtol=1e-4)
    np.testing.assert_allclose(a1, a2, rtol=1e-5)


def test_gather_equals_einsum_grads(key):
    cfg, p, x = _setup(key, 8, 2, 1, 1.25, 64)
    g1 = jax.grad(lambda p_: jnp.sum(moe_ffn_gather(x, p_, cfg)[0] ** 2))(p)
    g2 = jax.grad(lambda p_: jnp.sum(moe_ffn_einsum(x, p_, cfg)[0] ** 2))(p)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(a, b, atol=1e-4, rtol=1e-3)


def test_dropless_at_high_capacity(key):
    """With capacity >= K*gs/E every token is served: output must equal the
    unconstrained per-token mixture."""
    E, K = 4, 2
    cfg, p, x = _setup(key, E, K, 0, float(E), 32)  # cf=E => C = K*gs: no drop
    y, _ = moe_ffn(x, p, cfg)
    # direct dense mixture
    logits = x.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    gv, ei = jax.lax.top_k(probs, K)
    gv = gv / jnp.sum(gv, -1, keepdims=True)
    outs = []
    for e in range(E):
        h = jax.nn.silu(x @ p["w_gate"][e]) * (x @ p["w_up"][e])
        outs.append(h @ p["w_down"][e])
    dense = jnp.stack(outs, axis=-2)                       # (B,S,E,d)
    pick = jnp.take_along_axis(dense, ei[..., None], axis=-2)
    ref = jnp.sum(pick * gv[..., None], axis=-2)
    np.testing.assert_allclose(y, ref, atol=2e-5, rtol=1e-4)


def test_capacity_drops_tokens(key):
    """With tiny capacity some tokens must be dropped (their output only
    from shared path / zero) — and outputs stay finite."""
    cfg, p, x = _setup(key, 4, 2, 0, 0.25, 32)
    y, aux = moe_ffn(x, p, cfg)
    assert bool(jnp.all(jnp.isfinite(y)))
    # dropped tokens => strictly smaller L2 than dropless
    cfg2, _, _ = _setup(key, 4, 2, 0, 4.0, 32)
    y2, _ = moe_ffn(x, p, cfg2)
    assert float(jnp.sum(y ** 2)) < float(jnp.sum(y2 ** 2))


def test_aux_loss_prefers_balance(key):
    """A router collapsed onto one expert gets a larger aux loss than a
    uniform router."""
    cfg, p, x = _setup(key, 4, 1, 0, 2.0, 32)
    p_uni = dict(p, router=jnp.zeros_like(p["router"]))
    # collapsed router: every token to expert 0
    p_col = dict(p, router=jnp.zeros_like(p["router"]).at[:, 0].set(100.0))
    _, a_uni = moe_ffn(x, p_uni, cfg)
    _, a_col = moe_ffn(x, p_col, cfg)
    assert float(a_col) > float(a_uni)


def test_single_token_decode_path(key):
    """One-token groups (decode) keep every routed token (C >= 1)."""
    cfg, p, x = _setup(key, 4, 2, 1, 1.25, 64, B=3, S=1)
    y, _ = moe_ffn(x, p, cfg)
    cfg_hi, _, _ = _setup(key, 4, 2, 1, 8.0, 64)
    y2, _ = moe_ffn(x, p, cfg_hi)
    np.testing.assert_allclose(y, y2, atol=2e-5, rtol=1e-4)

"""Attention variants: flash custom-vjp vs direct softmax oracle, chunked
scan, decode paths, sequence-sharded decode partials."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import (chunked_attention,
                                    decode_attention, flash_attention,
                                    flash_decode_partial, simple_attention)


def _qkv(key, B, Sq, Skv, H, Hkv, D, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    return (jax.random.normal(ks[0], (B, Sq, H, D), dtype),
            jax.random.normal(ks[1], (B, Skv, Hkv, D), dtype),
            jax.random.normal(ks[2], (B, Skv, Hkv, D), dtype))


@pytest.mark.parametrize("causal,window", [(True, 0), (True, 64), (False, 0)])
@pytest.mark.parametrize("H,Hkv", [(4, 4), (6, 2), (4, 1)])
def test_flash_matches_oracle(key, causal, window, H, Hkv):
    q, k, v = _qkv(key, 2, 128, 128, H, Hkv, 32)
    out = flash_attention(q, k, v, causal, window, 64, 64)
    ref = simple_attention(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=1e-4)


@pytest.mark.parametrize("causal,window", [(True, 0), (True, 32)])
def test_flash_gradients_match_oracle(key, causal, window):
    q, k, v = _qkv(key, 1, 128, 128, 4, 2, 16)
    do = jax.random.normal(key, q.shape[:3] + (16,))

    def loss(fn):
        return lambda q, k, v: jnp.sum(fn(q, k, v) * do)

    g1 = jax.grad(loss(lambda q, k, v: flash_attention(
        q, k, v, causal, window, 32, 32)), argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss(lambda q, k, v: simple_attention(
        q, k, v, causal=causal, window=window)), argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, atol=5e-5, rtol=1e-3)


def test_flash_second_order_finite(key):
    q, k, v = _qkv(key, 1, 64, 64, 2, 2, 16)

    def inner(q):
        return jnp.sum(flash_attention(q, k, v, True, 0, 32, 32) ** 2)

    h = jax.grad(lambda q: jnp.sum(jax.grad(inner)(q) ** 2))(q)
    assert bool(jnp.all(jnp.isfinite(h)))


def test_chunked_matches_oracle_nondivisible(key):
    q, k, v = _qkv(key, 2, 100, 100, 4, 2, 16)
    out = chunked_attention(q, k, v, causal=True, q_block=32, kv_block=32)
    ref = simple_attention(q, k, v, causal=True)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=1e-4)


def test_chunked_kv_len_mask(key):
    q, k, v = _qkv(key, 2, 16, 32, 2, 2, 8)
    kv_len = jnp.array([20, 32], jnp.int32)
    out = chunked_attention(q, k, v, causal=False, kv_len=kv_len,
                            q_block=8, kv_block=8)
    ref0 = simple_attention(q[:1], k[:1, :20], v[:1, :20], causal=False)
    np.testing.assert_allclose(out[0], ref0[0], atol=2e-5, rtol=1e-4)


def test_decode_attention_matches_full(key):
    B, S, H, Hkv, D = 2, 32, 4, 2, 16
    q1, k, v = _qkv(key, B, 1, S, H, Hkv, D)
    index = 20  # 21 valid cache entries
    out = decode_attention(q1[:, 0], k, v, jnp.asarray(index))
    ref = simple_attention(q1, k[:, :index + 1], v[:, :index + 1],
                           causal=False)
    np.testing.assert_allclose(out, ref[:, 0], atol=2e-5, rtol=1e-4)


def test_flash_decode_partials_combine(key):
    """Sequence-sharded decode: partials over 4 shards == full attention."""
    B, S, H, D, shards = 2, 64, 4, 16, 4
    q1, k, v = _qkv(key, B, 1, S, H, H, D)
    q = q1[:, 0]
    index = jnp.asarray(S - 1)
    loc = S // shards
    ms, ls, os = [], [], []
    for i in range(shards):
        m, l, o = flash_decode_partial(q, k[:, i * loc:(i + 1) * loc],
                                       v[:, i * loc:(i + 1) * loc],
                                       index, i * loc)
        ms.append(m), ls.append(l), os.append(o)
    # emulate pmax/psum combine across the shard axis
    m = jnp.stack(ms)                                # (shards, B, H)
    m_g = jnp.max(m, 0)
    corr = jnp.exp(m - m_g[None])
    l_g = jnp.sum(jnp.stack(ls) * corr, 0)
    o_g = jnp.sum(jnp.stack(os) * corr[..., None], 0)
    out = o_g / l_g[..., None]
    ref = decode_attention(q, k, v, index)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=1e-4)


def test_ring_buffer_window_validity(key):
    """decode_attention with window: before wraparound only written slots
    are attended."""
    B, W, H, D = 1, 8, 2, 8
    q1, k, v = _qkv(key, B, 1, W, H, H, D)
    q = q1[:, 0]
    # only 3 tokens written (index=2): slots 3..7 must be masked
    out = decode_attention(q, k, v, jnp.asarray(2), window=W)
    ref = simple_attention(q1, k[:, :3], v[:, :3], causal=False)
    np.testing.assert_allclose(out, ref[:, 0], atol=2e-5, rtol=1e-4)

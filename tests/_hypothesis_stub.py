"""Soft-dependency shim for ``hypothesis`` (see requirements-dev.txt).

When hypothesis is installed the property tests run for real; when it is
absent (minimal CI image) each ``@given`` test collects as a clean skip and
every plain unit test in the same module still runs — strictly more coverage
than a module-level ``pytest.importorskip``.
"""
import pytest


def given(*_args, **_kwargs):
    def deco(fn):
        def _skipped():
            pytest.skip("hypothesis not installed (pip install -r "
                        "requirements-dev.txt)")
        _skipped.__name__ = fn.__name__
        _skipped.__doc__ = fn.__doc__
        return _skipped
    return deco


def settings(*_args, **_kwargs):
    def deco(fn):
        return fn
    return deco


class _AnyStrategy:
    """Accepts any ``st.<strategy>(...)`` call at decoration time."""

    def __getattr__(self, _name):
        return lambda *a, **k: None


st = _AnyStrategy()

"""Round/driver-level tests for the communication-compression subsystem:

  * codec='none' (the default) is BIT-identical to the pre-codec round on
    every {legacy, fused} x {vmap, scan} combination — reusing the PR-3
    reconstruction from test_plugin_api as the oracle, so the codec wiring
    cannot perturb the uncompressed paths;
  * vmap and scan executors agree under every lossy codec (+/- EF);
  * measured comm_bytes metric == the transport arithmetic, int8 <= 30%
    of fp32;
  * error-feedback: residual norm non-increasing on a quadratic, and the
    state["comm"] slot checkpoint/resumes bit-identically mid-run;
  * capability guards: lossy codecs reject through_aggregation and the
    legacy_tree engine with actionable errors (sharded cohorts now BUILD —
    the two-tier executor streams a per-client uplink); error_feedback
    rejects codec='none';
  * satellite regression: participation Bernoulli streams are bit-equal
    across rounds_per_call in {1, 4} (audit result: the mask folds off the
    PER-ROUND rng — which the chunked scan threads per round — so chunking
    cannot perturb it; this test pins that);
  * the fedagg example plugin composes with codecs end to end.
"""
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import comm_bytes_per_client, resolve_codec
from repro.configs.base import FedConfig
from repro.core import (FederatedTrainer, init_server_state,
                        make_federated_round)
from repro.core.flat import flat_sq_norm, make_flat_spec
from repro.models.model import Model
from test_plugin_api import (_round_inputs, _toy_fed_data,
                             make_mlp_model, make_reference_round,
                             tree_equal)


# ---------------------------------------------------------------------------
# codec='none' bit-identity (equivalence-matrix style)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("fused,strat", [(False, "vmap"), (False, "scan"),
                                         (True, "vmap"), (True, "scan")])
def test_codec_none_bit_identical_to_precodec_round(key, fused, strat):
    """An EXPLICIT codec='none' round == the PR-3 reconstruction, bit for
    bit, on every executor/engine — and it must neither emit comm metrics
    nor grow a comm state slot."""
    model = make_mlp_model()
    fed = FedConfig(algorithm="uga", meta=True, cohort=4, local_steps=2,
                    client_lr=0.05, server_lr=0.1, meta_lr=0.05,
                    server_opt="adam", clip_norm=1.0, lr_decay=0.9,
                    cohort_strategy=strat, fused_update=fused,
                    codec="none")
    batch, meta, wts = _round_inputs()
    new_rf = jax.jit(make_federated_round(model, fed))
    ref_rf = jax.jit(make_reference_round(model, fed))
    st_new = init_server_state(model, fed, key)
    assert "comm" not in st_new
    st_ref = jax.tree.map(jnp.copy, st_new)
    for r in range(2):
        st_new, m_new = new_rf(st_new, batch, meta, wts,
                               jax.random.fold_in(key, r))
        st_ref, m_ref = ref_rf(st_ref, batch, meta, wts,
                               jax.random.fold_in(key, r))
    assert tree_equal(st_new, st_ref)
    assert "comm_bytes" not in m_new
    for name in m_new:
        np.testing.assert_array_equal(np.asarray(m_new[name]),
                                      np.asarray(m_ref[name]), err_msg=name)


# ---------------------------------------------------------------------------
# lossy codecs: executor agreement + measured bytes
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("codec,ef", [("int8", False), ("int8", True),
                                      ("sign1bit", True), ("topk", True)])
def test_vmap_and_scan_coded_rounds_agree(key, codec, ef):
    """Both executors run the identical per-client encode/decode/accumulate
    math (same clients, same order), so coded rounds agree to fp32
    reduction noise across strategies."""
    model = make_mlp_model()
    batch, meta, wts = _round_inputs()
    outs = {}
    for strat in ("vmap", "scan"):
        fed = FedConfig(algorithm="uga", meta=True, cohort=4, local_steps=2,
                        client_lr=0.05, server_lr=0.1, meta_lr=0.05,
                        clip_norm=1.0, cohort_strategy=strat,
                        fused_update=True, codec=codec, error_feedback=ef)
        st = init_server_state(model, fed, key)
        assert ("comm" in st) == ef
        st, m = jax.jit(make_federated_round(model, fed))(
            st, batch, meta, wts, key)
        outs[strat] = (st, m)
    for a, b in zip(jax.tree.leaves(outs["vmap"][0]),
                    jax.tree.leaves(outs["scan"][0])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-7)
    assert float(outs["vmap"][1]["comm_bytes"]) == \
        float(outs["scan"][1]["comm_bytes"])


def test_comm_bytes_metric_measures_transport(key):
    """comm_bytes == cohort * sum-over-groups payload bytes, and the int8
    uplink is <= 30% of shipping fp32 (the acceptance budget)."""
    model = make_mlp_model()
    batch, meta, wts = _round_inputs()
    spec = make_flat_spec(model.init(key))
    fp32 = comm_bytes_per_client(resolve_codec(None, codec="none"), spec)
    for codec in ("int8", "sign1bit", "topk"):
        fed = FedConfig(algorithm="uga", meta=False, cohort=4,
                        local_steps=2, client_lr=0.05, fused_update=True,
                        codec=codec)
        st = init_server_state(model, fed, key)
        _, m = jax.jit(make_federated_round(model, fed))(
            st, batch, meta, wts, key)
        expect = 4 * comm_bytes_per_client(resolve_codec(fed), spec)
        assert float(m["comm_bytes"]) == float(expect), codec
        if codec == "int8":
            assert float(m["comm_bytes"]) <= 0.30 * 4 * fp32


def test_comm_bytes_counts_only_participants(key):
    """Under participation<1 only reporting clients ship bytes."""
    model = make_mlp_model()
    batch, meta, wts = _round_inputs()
    fed = FedConfig(algorithm="uga", meta=False, cohort=4, local_steps=2,
                    client_lr=0.05, fused_update=True, codec="int8",
                    participation=0.5)
    st = init_server_state(model, fed, key)
    _, m = jax.jit(make_federated_round(model, fed))(
        st, batch, meta, wts, key)
    spec = make_flat_spec(model.init(key))
    per_client = comm_bytes_per_client(resolve_codec(fed), spec)
    assert float(m["comm_bytes"]) == \
        float(m["participants"]) * per_client


# ---------------------------------------------------------------------------
# error feedback: contraction + checkpoint/resume
# ---------------------------------------------------------------------------
def make_quadratic_model(d=24):
    """L(w) = 0.5 ||w - t||^2 per client target t — gradients decay along
    training, so EF residuals (one quantization error behind) must not
    grow."""
    def init(k):
        return {"w": jax.random.normal(k, (d,)) * 2.0}

    def loss(w, batch, rng=None):
        diff = w["w"][None, :] - batch["t"]
        return 0.5 * jnp.mean(jnp.sum(diff * diff, axis=-1)), {}

    return Model(name="quad", init=init, loss=loss)


@pytest.mark.parametrize("codec", ["int8", "sign1bit"])
def test_error_feedback_residual_contraction_on_quadratic(key, codec):
    """Residual norm is non-increasing after the short EF warm-up on the
    quadratic: the memory builds to its steady-state fraction of ||g||
    over the first ~3 rounds, then never makes a new high and contracts
    with the decaying gradient — and training still converges."""
    model = make_quadratic_model()
    rng = np.random.default_rng(0)
    batch = {"t": jnp.asarray(rng.normal(0, 1, (4, 8, 24)), jnp.float32)}
    wts = jnp.ones((4,), jnp.float32)
    fed = FedConfig(algorithm="uga", meta=False, cohort=4, local_steps=2,
                    client_lr=0.05, server_lr=0.3, fused_update=True,
                    codec=codec, error_feedback=True)
    st = init_server_state(model, fed, key)
    rf = jax.jit(make_federated_round(model, fed))
    norms, losses = [], []
    for r in range(14):
        st, m = rf(st, batch, None, wts, jax.random.fold_in(key, r))
        norms.append(float(jnp.sqrt(sum(
            float(flat_sq_norm([b])) for b in st["comm"]["residual"]))))
        losses.append(float(m["client_loss"]))
    peak = max(norms)
    assert norms.index(peak) <= 2, norms        # growth only during warm-up
    assert norms[-1] <= 0.6 * peak, norms       # genuine contraction after
    assert losses[-1] < 0.25 * losses[0]


@pytest.mark.parametrize("strat", ["vmap", "scan"])
def test_resume_with_comm_state_continues_bit_identically(key, tmp_path,
                                                          strat):
    """save at round 2 of 6 with state['comm'] populated, restore into a
    FRESH trainer, finish: the EF residuals round-trip the msgpack
    checkpoint and the tail == the uninterrupted run, bit for bit."""
    model = make_mlp_model()
    fed = FedConfig(algorithm="uga", meta=True, cohort=4, local_steps=2,
                    client_lr=0.05, server_lr=0.1, meta_lr=0.05,
                    server_opt="adam", cohort_strategy=strat,
                    fused_update=True, codec="int8", error_feedback=True)
    data = _toy_fed_data()
    kw = dict(cohort=4, batch=16, meta_batch=8)

    straight = FederatedTrainer(model, fed, rounds_per_call=2, seed=0)
    full_hist = straight.run(data, rounds=6, **kw)

    part = FederatedTrainer(model, fed, rounds_per_call=2, seed=0)
    part.run(data, rounds=2, **kw)
    assert float(flat_sq_norm(part.state["comm"]["residual"])) > 0.0
    path = os.path.join(tmp_path, "state.msgpack")
    part.save(path, extra={"arch": "mlp"})

    resumed = FederatedTrainer(model, fed, rounds_per_call=2, seed=0)
    resumed.restore(path)
    tail = resumed.run(data, rounds=6, **kw)
    assert tree_equal(resumed.state, straight.state)
    assert tail == full_hist[2:]


@pytest.mark.parametrize("strat", ["vmap", "scan"])
def test_dropped_clients_keep_their_ef_residual(key, strat):
    """EF x participation: a straggler dropped by the participation mask
    did NOT transmit, so its error-feedback memory must stay byte-for-byte
    unchanged that round — overwriting it would discard the decoded part
    of the error as if the server had received it (regression for the EF
    telescoping under partial participation)."""
    from repro.core import participation_mask
    model = make_mlp_model()
    batch, meta, wts = _round_inputs()
    fed = FedConfig(algorithm="uga", meta=False, cohort=4, local_steps=2,
                    client_lr=0.05, fused_update=True, codec="int8",
                    error_feedback=True, participation=0.5,
                    cohort_strategy=strat)
    mask = np.asarray(participation_mask(key, 4, 0.5))
    assert 0 < mask.sum() < 4, "seed gives a non-trivial mask"
    st = init_server_state(model, fed, key)
    st, _ = jax.jit(make_federated_round(model, fed))(
        st, batch, meta, wts, key)
    for buf in st["comm"]["residual"]:
        res = np.asarray(buf)                       # (cohort, rows, LANES)
        np.testing.assert_array_equal(res[mask == 0.0], 0.0)
        assert np.all(np.any(res[mask == 1.0] != 0.0, axis=(1, 2)))


# ---------------------------------------------------------------------------
# capability guards
# ---------------------------------------------------------------------------
def test_error_feedback_requires_lossy_codec():
    with pytest.raises(ValueError, match="error_feedback"):
        FedConfig(error_feedback=True)                  # codec defaults none


def test_lossy_codec_rejects_through_aggregation():
    with pytest.raises(ValueError, match="through_aggregation"):
        FedConfig(meta=True, meta_mode="through_aggregation",
                  fused_update=True, codec="int8")


def test_lossy_codec_rejects_legacy_tree_engine():
    with pytest.raises(ValueError, match="fused_update"):
        FedConfig(codec="int8")                         # legacy engine


def test_lossy_codec_on_sharded_cohorts_builds(key):
    """Sharded cohorts used to reject lossy codecs (no per-client uplink
    after the per-leaf pre-aggregate); the two-tier sharded executor runs
    the chunk-local decode-FMA, so the same config now builds."""
    model = make_mlp_model()
    fed = FedConfig(algorithm="uga", meta=False, cohort=2, local_steps=2,
                    fused_update=True, codec="sign1bit")
    round_fn = make_federated_round(model, fed,
                                    grad_shardings={"w1": None,
                                                    "w2": None})
    assert callable(round_fn)


def test_unknown_codec_actionable_at_config_time():
    with pytest.raises(ValueError, match="register_codec"):
        FedConfig(codec="gzip")
    with pytest.raises(ValueError, match="topk_ratio"):
        FedConfig(topk_ratio=0.0)


# ---------------------------------------------------------------------------
# satellite: participation streams vs rounds_per_call chunking
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("strat", ["vmap", "scan"])
def test_participation_stream_bit_equal_across_rounds_per_call(strat):
    """participation<1 + cohort_strategy=scan (and vmap): the Bernoulli
    mask folds off each ROUND's rng, which the rounds_per_call lax.scan
    threads per round, so chunk size must not perturb the participation
    stream.  Audit regression: history AND final state bit-equal across
    rounds_per_call in {1, 4}."""
    model = make_mlp_model()
    fed = FedConfig(algorithm="uga", meta=True, cohort=4, local_steps=2,
                    client_lr=0.05, server_lr=0.1, meta_lr=0.05,
                    cohort_strategy=strat, fused_update=True,
                    participation=0.5)
    data = _toy_fed_data()
    runs = {}
    for k in (1, 4):
        tr = FederatedTrainer(model, fed, rounds_per_call=k, seed=0)
        hist = tr.run(data, rounds=4, cohort=4, batch=16, meta_batch=8)
        runs[k] = (hist, tr.state)
    assert runs[1][0] == runs[4][0]
    assert tree_equal(runs[1][1], runs[4][1])
    # the stream is non-trivial (some round actually dropped a client)
    assert any(h["participants"] < 4 for h in runs[1][0])


# ---------------------------------------------------------------------------
# fedagg example plugin x codec composition
# ---------------------------------------------------------------------------
def test_fedagg_plugin_composes_with_codecs(key):
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    if root not in sys.path:
        sys.path.insert(0, root)
    import importlib
    importlib.import_module("examples.plugins.fedagg")

    model = make_mlp_model()
    batch, meta, wts = _round_inputs()
    p0 = model.init(key)

    def delta_norm(algo, codec="none", ef=False):
        fed = FedConfig(algorithm=algo, meta=False, cohort=4, local_steps=2,
                        client_lr=0.05, fused_update=True, codec=codec,
                        error_feedback=ef)
        st = init_server_state(model, fed, key)
        st, m = jax.jit(make_federated_round(model, fed))(
            st, batch, meta, wts, key)
        assert np.isfinite(float(m["client_loss"]))
        return float(jnp.sqrt(sum(
            jnp.sum(jnp.square(a - b)) for a, b in
            zip(jax.tree.leaves(st["params"]), jax.tree.leaves(p0)))))

    # drift damping: a_k = 1/(1 + ||delta_k||) < 1 strictly shrinks the
    # aggregated step vs fedavg on the same cohort
    assert delta_norm("fedagg") < delta_norm("fedavg")
    # and the adaptive weighting composes with a lossy uplink end to end
    assert delta_norm("fedagg", codec="int8", ef=True) > 0.0
